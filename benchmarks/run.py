"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  ``us_per_call`` is the wall
time of the underlying model evaluation on this host; ``derived`` carries
the reproduced quantity vs the paper's reported value.

  table1_chip_summary    Table I  : power / GOPS / TOPS/W grid
  fig4_aer_overhead      Fig 4    : AER vs raw break-even sparsity
  fig5_sparsity_profile  Fig 5    : per-layer input sparsity of both SNNs
  fig10_switching        Fig 10   : even/odd batching energy amortization
  fig13_pipeline         Fig 13   : async handshake vs rigid-sync makespan
  fig14_energy_breakdown Fig 14   : component energy at 75% / 95% sparsity
  fig16_accuracy_energy  Fig 16   : accuracy/energy trade-off at 4/6/8 bit
  fig17_sparsity_sweep   Fig 17   : peak GOPS + TOPS/W vs sparsity x precision
  spike_gemm_kernel      (TPU adaptation): zero-skip kernel tile-skip rates
  engine_zero_skip       (TPU adaptation): fused multi-timestep engine —
                         zero-skip vs dense ablation at several sparsity
                         levels, exactness vs the pure-jnp reference
  kernel_blocksparse     (perf gate): the block-sparse Vmem-stationary
                         hot path — T_blk-tiled fused kernels vs the
                         per-timestep fused path vs the jnp oracle at
                         several sparsities, recording measured wall-us
                         NEXT TO the analytic roofline bound
                         (``roofline.analysis.PerfModel``) so
                         ``tools/check_bench.py --tol-roofline`` can gate
                         the measured/bound ratio across PRs
  streaming_occupancy    (serving): chunked stateful streaming vs
                         whole-stream batch at several occupancy levels —
                         throughput, latency, and exactness of the
                         persistent-Vmem session path
  fleet_scaling          (serving): spidr.serve fleet of 1/2/4 engine
                         replicas under an open-loop arrival process —
                         p50/p99 chunk latency, streams/s, shed rate,
                         live-migration count, with every completed
                         stream gated bit-exact vs a whole-stream run
  compiler_multicore     (compiler): single- vs 4-core compiled execution
                         at 60/90/95% input sparsity — exactness, per-core
                         cycles, routing overhead, load imbalance
  qat_sweep              (train->deploy): deploy-exact QAT training at
                         every weight/Vmem precision pair, exported and
                         compiled onto 1 and 4 cores — deployed
                         accuracy/AEE vs modeled cycles/energy, with the
                         train->deploy round trip asserted bit-exact
  facade_overhead        (api): spidr-facade dispatch cost vs a direct
                         jitted engine call — asserts the unified
                         deployment API adds <1% wall time
  telemetry_overhead     (obs): instrumented streaming tick with telemetry
                         hard-off vs disabled (the default) vs fully
                         enabled — asserts the disabled-mode hooks add
                         <1% to ``StreamSessionManager.step``

Every ablation deploys through the unified ``repro.spidr`` facade
(``DeployTarget`` -> ``spidr.compile`` -> ``CompiledSNN``) — the same
entry path as the launchers, examples and docs.

``python benchmarks/run.py`` runs everything; ``--streaming`` runs only the
streaming-vs-whole-stream ablation; ``--qat-sweep`` only the train->deploy
precision sweep; ``--facade-overhead`` only the facade micro-bench;
``--perf`` only the block-sparse kernel perf ablation; ``--smoke`` runs a
reduced compiler/engine/QAT/facade/kernel subset sized for CI.

Ablations that feed the cross-PR perf trajectory also append
machine-readable records to ``BENCH_compiler.json`` (``--out`` to
relocate): one object per ablation with cycles, energy, wall time and
sparsity — ``tools/check_bench.py`` diffs that file against the committed
``benchmarks/baseline.json`` to gate regressions in CI.
"""
from __future__ import annotations

import argparse
import dataclasses
import datetime
import json
import pathlib
import platform
import subprocess
import sys
import time

import numpy as np

# Machine-readable results accumulated across ablations, written to
# ``BENCH_compiler.json`` by ``main`` so the perf trajectory is trackable
# across PRs (CI uploads the file as an artifact).
RESULTS: list = []


def _row(name: str, us: float, derived: str):
    print(f"{name},{us:.1f},{derived}")


def _record(name: str, **fields):
    RESULTS.append({"name": name, **fields})


def _run_meta() -> dict:
    """Provenance stamped into every results file (git sha, versions, host).

    ``tools/check_bench.py`` only reads the ``results`` list, so this key
    rides along without affecting the regression gate — it exists so a
    regression flagged weeks later can be tied to the exact commit,
    dependency set and host that produced the numbers.
    """
    import jax
    import jaxlib

    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=pathlib.Path(__file__).resolve().parent,
        ).stdout.strip() or "unknown"
    except OSError:
        sha = "unknown"
    return {
        "git_sha": sha,
        "jax": jax.__version__,
        "jaxlib": jaxlib.__version__,
        "python": platform.python_version(),
        "platform": platform.platform(),
        "argv": sys.argv[1:],
        "timestamp": datetime.datetime.now(datetime.timezone.utc)
        .isoformat(timespec="seconds"),
    }


def _write_results(path: str) -> None:
    payload = {
        "schema": 1,
        "suite": "spidr-benchmarks",
        "meta": _run_meta(),
        "results": RESULTS,
    }
    p = pathlib.Path(path)
    p.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"# wrote {len(RESULTS)} records to {p}")


def _timeit(fn, n=3):
    fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


def table1_chip_summary():
    from repro.core.energy import HW, TABLE1_PAPER, gops, power_mw, tops_per_watt

    for hw, key in ((HW(50e6, 0.9), "50MHz_0.9V"), (HW(150e6, 1.0), "150MHz_1.0V")):
        us = _timeit(lambda: power_mw(hw))
        p = power_mw(hw)
        _row(f"table1_power_{key}", us,
             f"model={p:.2f}mW paper={TABLE1_PAPER[key]['power_mw']}mW")
        for bits in (4, 6, 8):
            g = gops(0.95, bits, hw.freq_hz)
            tw = tops_per_watt(0.95, bits, hw)
            _row(
                f"table1_{key}_{bits}b", 0.0,
                f"GOPS={g:.2f}/{TABLE1_PAPER[key]['gops'][bits]} "
                f"TOPSW={tw:.2f}/{TABLE1_PAPER[key]['topsw'][bits]}",
            )


def fig4_aer_overhead():
    from repro.core.zero_skip import aer_breakeven_sparsity, aer_overhead

    n = 288 * 384 * 2  # optical-flow input layer positions
    us = _timeit(lambda: aer_overhead(n, 0.9))
    brk = aer_breakeven_sparsity(n)
    _row("fig4_breakeven", us, f"sparsity={brk:.3f} paper~0.947")
    for s in (0.6, 0.8, 0.9, 0.947, 0.99):
        _row(f"fig4_overhead_s{int(s*1000)}", 0.0,
             f"aer/raw={aer_overhead(n, s):.2f}")


def fig5_sparsity_profile():
    import jax

    from repro.core.network import gesture_net, init_params, run_snn
    from repro.core.quant import QuantSpec
    from repro.snn.data import make_gesture_batch

    spec = gesture_net()
    params = init_params(jax.random.PRNGKey(0), spec)
    ev, _ = make_gesture_batch(jax.random.PRNGKey(1), batch=2, timesteps=8,
                               hw=(64, 64))

    def run():
        return run_snn(params, ev, spec, QuantSpec(4), record_spikes=True)[1]

    us = _timeit(run, n=1)
    counts = np.asarray(run())  # (T, layers)
    sizes = []
    h = w = 64
    for l in spec.layers:
        if l.kind == "conv":
            sizes.append(2 * h * w * l.c_out)
        elif l.kind == "pool":
            h, w = h // 2, w // 2
        elif l.kind == "adaptive_pool":
            h = w = l.target_hw
        elif l.kind == "fc":
            sizes.append(2 * l.c_out)
    for i, sz in enumerate(sizes):
        sp = 1.0 - counts[:, i].mean() / sz * 8  # per-timestep mean over T...
        sp = max(0.0, min(1.0, 1.0 - counts[:, i].mean() / (sz / 8)))
        _row(f"fig5_layer{i}_sparsity", us if i == 0 else 0.0, f"sparsity={sp:.3f}")


def fig10_switching():
    from repro.core.energy import energy_per_op_batched
    from repro.core.s2a import S2AConfig, simulate_s2a

    rng = np.random.default_rng(0)
    m = (rng.random((128, 16)) < 0.15).astype(np.int8)
    us = _timeit(lambda: simulate_s2a(m, S2AConfig(16)), n=1)
    st = simulate_s2a(m, S2AConfig(16))
    reduction = energy_per_op_batched(1) / energy_per_op_batched(15)
    _row("fig10_batch15_reduction", us,
         f"energy_ratio={reduction:.2f} paper=1.5")
    _row("fig10_fifo16_runlen", 0.0,
         f"mean_run={st.mean_run_length:.1f} switches={st.switches}")
    for b in (1, 2, 4, 8, 15, 16, 32):
        _row(f"fig10_eop_b{b}", 0.0, f"E/op={energy_per_op_batched(b):.3f}")


def fig13_pipeline():
    from repro.core.pipeline import simulate_pipeline

    rng = np.random.default_rng(0)
    cc = rng.integers(100, 900, (20, 9))
    us = _timeit(lambda: simulate_pipeline(cc), n=2)
    res = simulate_pipeline(cc)
    _row("fig13_async_speedup", us,
         f"vs_sync={res.speedup_vs_sync:.2f}x util={res.cm_utilization.mean():.2f}")


def fig14_energy_breakdown():
    from repro.core.energy import chunk_energy_breakdown_nj

    us = _timeit(lambda: chunk_energy_breakdown_nj(0.75))
    for s in (0.75, 0.95):
        br = chunk_energy_breakdown_nj(s)
        total = sum(br.values())
        parts = " ".join(f"{k}={v/total:.2f}" for k, v in br.items())
        _row(f"fig14_breakdown_s{int(s*100)}", us if s == 0.75 else 0.0,
             f"total={total:.1f}nJ {parts}")
    e75 = sum(chunk_energy_breakdown_nj(0.75).values())
    e95 = sum(chunk_energy_breakdown_nj(0.95).values())
    _row("fig14_75_to_95_reduction", 0.0,
         f"ratio={e75/e95:.2f} paper>2.0")


def fig16_accuracy_energy(steps: int = 120):
    """Accuracy/energy trade-off at 4/6/8-bit (trend; synthetic data)."""
    import jax

    from repro.core.energy import chunk_energy_total_nj
    from repro.core.network import gesture_net
    from repro.snn.data import make_gesture_batch
    from repro.snn.train import TrainConfig, evaluate, init_train_state, train_step

    spec = gesture_net()
    for bits in (4, 6, 8):
        cfg = TrainConfig(weight_bits=bits, lr=4e-3)
        state = init_train_state(jax.random.PRNGKey(0), spec, cfg)
        key = jax.random.PRNGKey(1)
        t0 = time.perf_counter()
        for step in range(steps):
            key, k = jax.random.split(key)
            ev, lbl = make_gesture_batch(k, batch=8, timesteps=5, hw=(64, 64))
            state, m = train_step(state, (ev, lbl), spec, cfg)
        us = (time.perf_counter() - t0) / steps * 1e6
        key, k = jax.random.split(key)
        ev, lbl = make_gesture_batch(k, batch=32, timesteps=5, hw=(64, 64))
        acc = evaluate(state.params, [(ev, lbl)], spec, cfg)
        # Energy per inference from the calibrated model: chunks x E_chunk.
        # 20 timesteps, measured layer mapping -> chunk count per timestep.
        from repro.core.modes import CoreConfig, map_layer
        from repro.core.quant import QuantSpec

        core = CoreConfig(QuantSpec(bits))
        passes = sum(map_layer(ls, core).total_passes for ls in spec.layer_shapes())
        e_inf = passes * spec.timesteps * chunk_energy_total_nj(0.95) / 1e3  # uJ
        # The optical-flow net (32 ch) shows the precision->passes effect the
        # paper plots (gesture's 16 channels fit one pass at every precision).
        from repro.core.network import optical_flow_net

        fspec = optical_flow_net()
        fpasses = sum(map_layer(ls, core).total_passes for ls in fspec.layer_shapes())
        e_flow = fpasses * fspec.timesteps * chunk_energy_total_nj(0.95) / 1e6  # mJ
        _row(f"fig16_{bits}b", us,
             f"gesture_acc={acc:.2f} gesture_E={e_inf:.1f}uJ flow_E={e_flow:.2f}mJ")


def fig17_sparsity_sweep():
    from repro.core.energy import HW, gops, tops_per_watt

    us = _timeit(lambda: gops(0.9, 4))
    for bits in (4, 6, 8):
        for s in (0.6, 0.7, 0.8, 0.9, 0.95, 0.99):
            _row(f"fig17_{bits}b_s{int(s*100)}", us if s == 0.6 else 0.0,
                 f"GOPS={gops(s, bits, 150e6):.1f} TOPSW={tops_per_watt(s, bits, HW(50e6, 0.9)):.2f}")


def spike_gemm_kernel():
    """TPU-adaptation ablation: tile zero-skip on REAL event structure.

    Unstructured Bernoulli sparsity never empties a 128x128 tile (measured
    0% skip) — but DVS events are spatially clustered, and after im2col the
    cluster structure makes whole fan-in tiles empty.  This is the finding
    recorded in DESIGN.md §2: the S2A's per-event skip transfers to the MXU
    only at tile granularity and only because event data is clustered.
    """
    import jax
    import jax.numpy as jnp

    from repro.core.layers import im2col
    from repro.core.zero_skip import tile_skip_fraction
    from repro.kernels.ref import spike_gemm_ref
    from repro.kernels.spike_gemm import spike_gemm
    from repro.snn.data import make_gesture_batch

    rng = np.random.default_rng(0)
    # Clustered events from the DVS synthesizer -> im2col spike matrix.
    ev, _ = make_gesture_batch(jax.random.PRNGKey(0), batch=1, timesteps=1,
                               hw=(128, 128))
    cols = np.asarray(im2col(ev[0], 3, 3, 1, 1)[0], np.int8)  # (P, 18)
    m = cols[: (cols.shape[0] // 128) * 128]
    w = rng.integers(-8, 8, (m.shape[1], 48)).astype(np.int8)
    sparsity = float((m == 0).mean())
    for tile in ((128, 18), (8, 18)):
        frac = tile_skip_fraction(m, tile)
        _row(f"spike_gemm_dvs_tile{tile[0]}x{tile[1]}", 0.0,
             f"sparsity={sparsity:.3f} tiles_skipped={frac:.2f}")
    out = spike_gemm(jnp.array(m), jnp.array(w), interpret=True)
    ok = bool((np.asarray(out) == np.asarray(
        spike_gemm_ref(jnp.array(m), jnp.array(w)))).all())
    us = _timeit(
        lambda: spike_gemm(jnp.array(m), jnp.array(w), interpret=True).block_until_ready(),
        n=1,
    )
    _row("spike_gemm_dvs_exact", us, f"exact={ok}")
    # Unstructured control: shows WHY clustering matters.
    for s in (0.95, 0.99):
        mr = (rng.random((512, 512)) > s).astype(np.int8)
        frac = tile_skip_fraction(mr, (128, 128))
        frac8 = tile_skip_fraction(mr, (8, 128))
        _row(f"spike_gemm_iid_s{int(s*100)}", 0.0,
             f"tiles128_skipped={frac:.2f} tiles8_skipped={frac8:.2f}")


def engine_zero_skip():
    """Fused engine ablation: tile zero-skip vs dense at several sparsities.

    Runs the reduced gesture network end to end (scan over timesteps, fused
    Pallas kernels in interpret mode) on Bernoulli event streams at 60/90/95%
    input sparsity.  Reports: exactness of the fused zero-skip path vs both
    the dense fused path and the pure-jnp reference, the fraction of
    (block_m x block_k) spike tiles the kernel skips at the first layer, and
    wall time per stream for skip vs dense.
    """
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro import spidr
    from repro.configs import spidr_gesture
    from repro.core.layers import im2col
    from repro.core.network import init_params
    from repro.core.zero_skip import tile_skip_fraction

    spec = spidr_gesture.reduced(hw=(32, 32), timesteps=3)
    params = init_params(jax.random.PRNGKey(0), spec)
    block = (128, 128, 128)
    target = spidr.DeployTarget(weight_bits=4, backend="fused",
                                interpret=True, block=block)
    skip_eng = spidr.compile(spec, params, target)
    dense_eng = spidr.compile(spec, params,
                              dataclasses.replace(target, skip_empty=False))
    ref_eng = spidr.compile(spec, params,
                            dataclasses.replace(target, backend="reference"))

    rng = np.random.default_rng(0)
    for s in (0.60, 0.90, 0.95):
        ev = jnp.asarray(
            (rng.random((spec.timesteps, 1) + spec.input_hw + (2,)) > s)
            .astype(np.float32)
        )
        out = skip_eng.run(ev)
        us = _timeit(lambda: jax.block_until_ready(skip_eng.run(ev)), n=1)
        us_dense = _timeit(
            lambda: jax.block_until_ready(dense_eng.run(ev)), n=1
        )
        dense = dense_eng.run(ev)
        ref = ref_eng.run(ev)
        exact = bool(
            (np.asarray(out.readout) == np.asarray(dense.readout)).all()
            and (np.asarray(out.readout) == np.asarray(ref.readout)).all()
            and (np.asarray(out.spike_counts)
                 == np.asarray(ref.spike_counts)).all()
        )
        cols = np.asarray(im2col(ev[0], 3, 3, 1, 1)[0], np.int8)
        frac = tile_skip_fraction(cols, (block[0], cols.shape[1]))
        cost = skip_eng.cost(out)
        _row(f"engine_s{int(s*100)}_skip", us,
             f"exact={exact} tiles_skipped={frac:.2f} "
             f"chip_uJ={cost.energy_uj:.1f}")
        _row(f"engine_s{int(s*100)}_dense", us_dense,
             f"skip_vs_dense_wall={us_dense/max(us,1):.2f}x")


def _clustered_events(rng, timesteps, hw, sparsity, batch=1):
    """DVS-like clustered event frames at a target global sparsity.

    Real event streams are spatially clustered — a moving edge lights a
    patch, not i.i.d. pixels — and that clustering is what empties whole
    (bm x bk) im2col tiles (DESIGN.md: Bernoulli sparsity at the same
    level never empties a 128-wide tile, measured 0% skip).  Each
    timestep actives one moving square patch at ~50% internal density,
    sized so the frame-global sparsity hits ``sparsity``.
    """
    h, w = hw
    budget = (1.0 - sparsity) * h * w * 2   # active sites per timestep
    side = min(h, max(2, int(np.ceil(np.sqrt(budget)))))
    density = budget / (2 * side * side)
    ev = np.zeros((timesteps, batch) + hw + (2,), np.float32)
    for t in range(timesteps):
        y = (t * 7) % max(1, h - side + 1)
        x = (t * 11) % max(1, w - side + 1)
        for b in range(batch):
            patch = (rng.random((side, side, 2)) < density).astype(np.float32)
            ev[t, b, y:y + side, x:x + side] = patch
    return ev


def kernel_blocksparse(smoke: bool = False):
    """Perf-gate ablation: the block-sparse Vmem-stationary hot path.

    Runs the reduced gesture network through four schedules of the SAME
    computation — the T_blk-tiled fused kernel with block skipping
    (``t_block=T``), the same tiling dense (``skip_empty=False``), the
    per-timestep fused kernel (``t_block=1``) and the pure-jnp oracle —
    on clustered DVS-like event streams at several global sparsities,
    asserting all four bit-exact.  Next to every measured wall time it
    records the analytic roofline bound from
    ``roofline.analysis.PerfModel`` (via ``CompiledSNN.roofline``),
    priced with the MEASURED first-layer nonzero-tile fraction
    (``kernels.spike_tile_bitmap`` over the im2col spike matrix).  The
    bound is an ideal-hardware floor — interpret-mode CPU wall clock sits
    far above it — so ``tools/check_bench.py`` gates the measured/bound
    RATIO against the committed baseline: the bound normalizes
    shape/sparsity/tiling out of the wall clock, and a ratio regression
    means the implementation got slower relative to what the dataflow
    says it should cost.
    """
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro import spidr
    from repro.configs import spidr_gesture
    from repro.core.layers import im2col
    from repro.core.network import init_params
    from repro.kernels.fused_lif_gemm import spike_tile_bitmap

    hw = (16, 16) if smoke else (32, 32)
    timesteps = 4 if smoke else 6
    spec = spidr_gesture.reduced(hw=hw, timesteps=timesteps)
    params = init_params(jax.random.PRNGKey(0), spec)
    block = (128, 128, 128)
    tblk_target = spidr.DeployTarget(
        weight_bits=4, backend="fused", interpret=True, block=block,
        skip_empty=True, t_block=timesteps)
    tblk_eng = spidr.compile(spec, params, tblk_target)
    dense_eng = spidr.compile(spec, params,
                              dataclasses.replace(tblk_target,
                                                  skip_empty=False))
    pert_eng = spidr.compile(spec, params,
                             dataclasses.replace(tblk_target, t_block=1))
    jnp_eng = spidr.compile(spec, params,
                            dataclasses.replace(tblk_target, backend="jnp",
                                                t_block=1))
    n_weight_layers = len(spec.layer_shapes())

    rng = np.random.default_rng(0)
    sparsities = (0.95,) if smoke else (0.60, 0.90, 0.95)
    for s in sparsities:
        ev = jnp.asarray(_clustered_events(rng, timesteps, hw, s))
        out_tblk = tblk_eng.run(ev)
        out_dense = dense_eng.run(ev)
        out_pert = pert_eng.run(ev)
        out_jnp = jnp_eng.run(ev)
        exact = bool(
            (np.asarray(out_tblk.readout) == np.asarray(out_dense.readout)).all()
            and (np.asarray(out_tblk.readout) == np.asarray(out_pert.readout)).all()
            and (np.asarray(out_tblk.readout) == np.asarray(out_jnp.readout)).all()
            and (np.asarray(out_tblk.spike_counts)
                 == np.asarray(out_jnp.spike_counts)).all()
        )
        us_tblk = _timeit(lambda: jax.block_until_ready(tblk_eng.run(ev)), n=1)
        us_dense = _timeit(lambda: jax.block_until_ready(dense_eng.run(ev)), n=1)
        us_pert = _timeit(lambda: jax.block_until_ready(pert_eng.run(ev)), n=1)
        # Measured block sparsity at the input layer: the im2col spike
        # matrix's nonzero-(bm x bk)-tile fraction, exactly what the kernel
        # prologue computes.  Deeper layers are priced dense (their spike
        # stacks live on-device; pricing them dense only makes the bound a
        # firmer floor — the ratio gate tracks relative change either way).
        cols = jnp.stack([im2col(ev[t], 3, 3, 1, 1)[0] for t in range(timesteps)])
        frac0 = float(spike_tile_bitmap(cols.astype(jnp.int8), block).mean())
        fracs = [frac0] + [1.0] * (n_weight_layers - 1)
        bound_tblk = tblk_eng.roofline(batch=1, nonzero_tile_fracs=fracs)
        bound_pert = pert_eng.roofline(batch=1, nonzero_tile_fracs=fracs)
        pct = int(s * 100)
        _row(f"kernel_s{pct}_tblk", us_tblk,
             f"exact={exact} bound_us={bound_tblk['bound_us']:.1f} "
             f"nonzero_tile_frac={frac0:.2f} "
             f"speedup_vs_dense={us_dense / max(us_tblk, 1):.2f}x "
             f"speedup_vs_per_t={us_pert / max(us_tblk, 1):.2f}x")
        _row(f"kernel_s{pct}_per_t", us_pert,
             f"bound_us={bound_pert['bound_us']:.1f}")
        common = dict(ablation="kernel_blocksparse", sparsity=s,
                      nonzero_tile_frac=frac0, exact=exact)
        _record(f"kernel_s{pct}_tblk", t_block=timesteps,
                wall_us=float(us_tblk), bound_us=float(bound_tblk["bound_us"]),
                bytes_moved=float(bound_tblk["bytes_moved"]),
                macs=float(bound_tblk["macs"]),
                speedup_vs_dense=float(us_dense / max(us_tblk, 1)),
                speedup_vs_per_t=float(us_pert / max(us_tblk, 1)), **common)
        _record(f"kernel_s{pct}_per_t", t_block=1,
                wall_us=float(us_pert), bound_us=float(bound_pert["bound_us"]),
                bytes_moved=float(bound_pert["bytes_moved"]),
                macs=float(bound_pert["macs"]), **common)


def compiler_multicore(smoke: bool = False):
    """Compiler ablation: single-core vs compiled 4-core execution.

    Runs the reduced gesture network through the multi-core compiler
    (``compile_network`` -> ``compile_engine``) at 60/90/95% input
    sparsity and reports, per sparsity level: bit-exactness of the 4-core
    engine vs the single-core path, wall time for both, the modeled
    single-core makespan vs the multi-core per-core makespans (max =
    plan latency), the spike-routing overhead, and the load-imbalance
    metric.  The crossover is the point: routing costs cycles per spike,
    so the multi-core plan wins only once sparsity (or per-core load)
    is high enough — exactly the trade the partitioner's cost model makes.

    Every level appends machine-readable records (cycles, energy, wall
    time, sparsity) to ``BENCH_compiler.json`` for cross-PR tracking.
    """
    import jax
    import jax.numpy as jnp

    from repro import spidr
    from repro.configs import spidr_gesture
    from repro.core.network import init_params

    hw = (16, 16) if smoke else (32, 32)
    timesteps = 2 if smoke else 4
    n_cores = 4
    spec = spidr_gesture.reduced(hw=hw, timesteps=timesteps)
    params = init_params(jax.random.PRNGKey(0), spec)
    eng = spidr.compile(spec, params, spidr.DeployTarget(backend="jnp"))
    meng = spidr.compile(spec, params,
                         spidr.DeployTarget(backend="jnp", n_cores=n_cores))

    rng = np.random.default_rng(0)
    for s in (0.60, 0.90, 0.95):
        ev = jnp.asarray(
            (rng.random((timesteps, 1) + spec.input_hw + (2,)) > s)
            .astype(np.float32)
        )
        out1 = eng.run(ev)
        out4 = meng.run(ev)
        us1 = _timeit(lambda: jax.block_until_ready(eng.run(ev)), n=1)
        us4 = _timeit(lambda: jax.block_until_ready(meng.run(ev)), n=1)
        exact = bool(
            (np.asarray(out1.readout) == np.asarray(out4.readout)).all()
            and (np.asarray(out1.spike_counts)
                 == np.asarray(out4.spike_counts)).all()
        )
        counts = np.asarray(out1.input_counts)
        c1 = eng.cost(out1)
        c4 = meng.cost(input_counts=counts)
        # Observability invariant: the pipeline-timeline export conserves
        # cycles — per core, summed busy+routing event durations must equal
        # the cost model's busy_cycles exactly (no sampling, no rounding).
        from repro.obs.timeline import busy_cycle_totals

        totals = busy_cycle_totals(meng.pipeline_trace(input_counts=counts))
        timeline_exact = all(
            int(totals.get(core, 0)) == int(c4.busy_cycles[core])
            for core in range(n_cores))
        _row(f"compiler_s{int(s*100)}_1core", us1,
             f"makespan={c1.makespan_cycles} uJ={c1.energy_uj:.1f}")
        _row(
            f"compiler_s{int(s*100)}_{n_cores}core", us4,
            f"exact={exact} makespan={c4.makespan_cycles} "
            f"imbalance={c4.load_imbalance:.2f} "
            f"routing={int(c4.routing_cycles.sum())} "
            f"dup={c4.duplication_cycles} timeline_exact={timeline_exact}",
        )
        _record(
            f"compiler_s{int(s*100)}_1core",
            ablation="compiler_multicore", n_cores=1, sparsity=s,
            cycles=int(c1.makespan_cycles), energy_uj=float(c1.energy_uj),
            wall_us=float(us1), measured_sparsity=float(c1.mean_sparsity),
        )
        _record(
            f"compiler_s{int(s*100)}_{n_cores}core",
            ablation="compiler_multicore", n_cores=n_cores, sparsity=s,
            cycles=int(c4.makespan_cycles), energy_uj=float(c4.energy_uj),
            wall_us=float(us4), measured_sparsity=float(c4.mean_sparsity),
            exact=exact, timeline_exact=timeline_exact,
            per_core_busy_cycles=[int(x) for x in c4.busy_cycles],
            routing_cycles=int(c4.routing_cycles.sum()),
            duplication_cycles=int(c4.duplication_cycles),
            load_imbalance=float(c4.load_imbalance),
        )


def qat_sweep(smoke: bool = False):
    """Train->deploy ablation: the Fig 16 trade-off as a deployable pipeline.

    For each weight/Vmem precision pair (4/7, 6/11, 8/15): train the
    reduced gesture net (plus, in the full run, the reduced optical-flow
    net) with the deploy-exact QAT forward for a smoke budget, fold the
    weights into the engine's integer format (``snn.export``), deploy
    through the multi-core compiler on 1 and 4 cores, and report the
    *deployed* accuracy/AEE together with the modeled cycles/energy — the
    accuracy-vs-energy reconfigurability trade the paper claims (C2).
    Every combination appends a machine-readable record, and a broken
    train->deploy round trip raises — full/nightly runs fail loudly, not
    only through the JSON gate.

    The train+export loop is ``snn.train.precision_sweep`` itself (one
    source of truth); this ablation layers the deployment costs on top.
    """
    import jax
    import jax.numpy as jnp

    from repro import spidr
    from repro.snn.export import dequantize_readout, verify_roundtrip
    from repro.snn.train import (
        TrainConfig, effective_spec, make_batch_fn, precision_sweep, spec_for,
    )

    steps = 4 if smoke else 30
    tasks = ("gesture",) if smoke else ("gesture", "optical-flow")
    for task in tasks:
        spec0 = spec_for(task)
        hw = (16, 16) if (smoke or task != "gesture") else (32, 32)
        cfg0 = TrainConfig(
            lr=4e-3, steps=steps, warmup=1, batch=4 if smoke else 8,
            hw=hw, timesteps=2 if smoke else 4, seed=0, eval_batches=1,
        )
        sweep = precision_sweep(task, bits=(4, 6, 8), cfg=cfg0, spec=spec0)
        for bits, res in sweep.items():
            cfg = dataclasses.replace(cfg0, weight_bits=bits)
            state, history, exported = (res["state"], res["history"],
                                        res["exported"])
            train_us = history["wall_s"] / steps * 1e6
            espec = effective_spec(spec0, cfg)
            # 32 eval samples: the accuracy quantum (1/32) stays below
            # check_bench's default --tol-metric so single-sample flips on
            # a dependency bump cannot trip the CI gate.
            ev, target = make_batch_fn(espec, cfg, batch=32)(
                jax.random.PRNGKey(123))

            eng1 = spidr.compile(exported, state.params,
                                 spidr.DeployTarget(weight_bits=bits),
                                 spec=espec)
            out1 = eng1.run(ev)
            # Reuse the engine output for the QAT parity proof (the full
            # verify() would re-run the engine plus the python-loop
            # reference oracle for results this ablation never records).
            rt = verify_roundtrip(state.params, espec, eng1.engine, ev,
                                  exported, engine_out=out1)
            readout = dequantize_readout(exported, espec, out1.readout)
            if espec.readout == "rate":
                metric, value = "accuracy", float(
                    jnp.mean(jnp.argmax(readout, axis=-1) == target))
            else:
                metric, value = "aee", float(
                    jnp.mean(jnp.linalg.norm(readout - target, axis=-1)))
            counts = np.asarray(out1.input_counts)
            c1 = eng1.cost(out1)

            eng4 = spidr.compile(exported, state.params,
                                 spidr.DeployTarget(weight_bits=bits,
                                                    n_cores=4), spec=espec)
            out4 = eng4.run(ev)
            exact4 = rt.exact and bool(
                (np.asarray(out1.readout) == np.asarray(out4.readout)).all())
            c4 = eng4.cost(input_counts=counts)
            assert rt.exact, (
                f"train->deploy parity broken for {task} @ {bits}b: {rt}")
            assert exact4, (
                f"4-core deployment diverged for {task} @ {bits}b")

            _row(f"qat_{task}_{bits}b", train_us,
                 f"{metric}={value:.3f} roundtrip_exact={rt.exact} "
                 f"loss={history['loss'][-1]:.3f}")
            _row(f"qat_{task}_{bits}b_deploy", 0.0,
                 f"1core_cycles={c1.makespan_cycles} uJ={c1.energy_uj:.2f} "
                 f"4core_cycles={c4.makespan_cycles} uJ={c4.energy_uj:.2f} "
                 f"4core_exact={exact4}")
            common = dict(ablation="qat_sweep", task=task, weight_bits=bits,
                          metric=metric, metric_value=value,
                          train_loss=float(history["loss"][-1]))
            _record(f"qat_{task}_{bits}b_1core", n_cores=1,
                    cycles=int(c1.makespan_cycles),
                    energy_uj=float(c1.energy_uj), exact=bool(rt.exact),
                    wall_us=float(train_us), **common)
            _record(f"qat_{task}_{bits}b_4core", n_cores=4,
                    cycles=int(c4.makespan_cycles),
                    energy_uj=float(c4.energy_uj), exact=exact4,
                    wall_us=float(train_us), **common)


def facade_overhead(smoke: bool = False):
    """Facade micro-bench: ``CompiledSNN.run`` vs a direct jitted engine call.

    The ``spidr`` facade is the single entry path for every launcher,
    benchmark and example, so its dispatch cost must be negligible.  Both
    calls bottom out in the *same* jitted computation, so the facade can
    only add Python-side dispatch; end-to-end wall deltas at the 1% level
    are unmeasurable under scheduler noise (shared CI runners jitter far
    more than that between identical runs).  This ablation therefore
    measures exactly the added term: the async (unblocked) dispatch cost
    of ``CompiledSNN.run`` vs a hand-jitted ``run_engine`` closure over
    the same engine — min over rounds of round-averaged call cost — and
    asserts that delta is under 1% of the blocked whole-run wall time.
    The record lands in ``BENCH_compiler.json`` (``within_budget`` is a
    hard exactness-style gate in ``tools/check_bench.py``).
    """
    import jax
    import jax.numpy as jnp

    from repro import spidr
    from repro.configs import spidr_gesture
    from repro.core.network import init_params
    from repro.engine import run_engine

    spec = spidr_gesture.reduced(hw=(16, 16), timesteps=8)
    params = init_params(jax.random.PRNGKey(0), spec)
    compiled = spidr.compile(spec, params, spidr.DeployTarget(backend="jnp"))
    direct = jax.jit(lambda ev: run_engine(compiled.engine, ev))

    rng = np.random.default_rng(0)
    ev = jnp.asarray(
        (rng.random((spec.timesteps, 8) + spec.input_hw + (2,)) > 0.9)
        .astype(np.float32))
    jax.block_until_ready(compiled.run(ev))   # warm both jit caches
    jax.block_until_ready(direct(ev))

    def dispatch_us(fn, calls=10):
        """Average async dispatch cost per call (enqueue, don't block)."""
        t0 = time.perf_counter()
        for _ in range(calls):
            out = fn(ev)
        dt = (time.perf_counter() - t0) / calls * 1e6
        jax.block_until_ready(out)
        return dt

    rounds = 5 if smoke else 10
    disp_facade = min(dispatch_us(compiled.run) for _ in range(rounds))
    disp_direct = min(dispatch_us(direct) for _ in range(rounds))
    us_run = float(np.median(
        [_timeit(lambda: jax.block_until_ready(compiled.run(ev)), n=1)
         for _ in range(3)]))
    overhead = max(0.0, disp_facade - disp_direct) / us_run
    within_budget = overhead < 0.01
    _row("facade_overhead", us_run,
         f"dispatch_facade_us={disp_facade:.1f} "
         f"dispatch_direct_us={disp_direct:.1f} "
         f"overhead={overhead*100:.3f}% within_budget={within_budget}")
    _record("facade_overhead", ablation="facade_overhead",
            wall_us=float(us_run), dispatch_facade_us=float(disp_facade),
            dispatch_direct_us=float(disp_direct),
            overhead_frac=float(overhead), within_budget=bool(within_budget))
    assert within_budget, (
        f"facade dispatch added {overhead*100:.2f}% wall time over the "
        "direct jitted engine call (budget: <1%)")


def telemetry_overhead(smoke: bool = False):
    """Telemetry micro-bench: instrumented streaming step, off vs on.

    ``StreamSessionManager.step`` is the serving hot loop, so its telemetry
    hooks must be free when telemetry is off — the default.  Three
    identically-configured managers run the same steady-state tick on the
    same engine: telemetry pinned hard off (``metrics=False, tracer=False``),
    the shipping default (a process-wide registry that is *disabled* — every
    hook reduces to one ``if`` check), and fully enabled (live registry +
    tracer recording every tick).  Per-tick wall time is min-over-rounds of
    round-averaged ticks, the same noise discipline as ``facade_overhead``.

    The hard <1% gate is on the DISABLED mode — the cost the
    instrumentation imposes on users who never asked for telemetry
    (``within_budget`` is exactness-gated in ``tools/check_bench.py``).
    The enabled-mode overhead is recorded alongside for tracking; it does
    real per-tick work (sparsity/occupancy/cycle-delta metrics + one span)
    and is expected to cost ~1%.
    """
    import jax

    from repro import obs, spidr
    from repro.configs import spidr_gesture
    from repro.core.network import init_params
    from repro.engine.streaming import StreamSessionManager

    spec = spidr_gesture.reduced(hw=(16, 16), timesteps=8)
    params = init_params(jax.random.PRNGKey(0), spec)
    compiled = spidr.compile(spec, params, spidr.DeployTarget(backend="jnp"))
    capacity, chunk_T = 4, 2

    rng = np.random.default_rng(0)
    chunks = {i: (rng.random((chunk_T,) + spec.input_hw + (2,)) > 0.9)
              .astype(np.float32) for i in range(capacity)}

    def make(metrics, tracer):
        mgr = StreamSessionManager(compiled.engine, capacity=capacity,
                                   chunk_T=chunk_T, metrics=metrics,
                                   tracer=tracer)
        for _ in range(capacity):
            mgr.open()
        mgr.step(chunks)   # warm the jit cache
        return mgr

    mgr_off = make(False, False)
    mgr_default = make(obs.MetricsRegistry(enabled=False),
                       obs.Tracer(enabled=False))
    mgr_on = make(obs.MetricsRegistry(enabled=True),
                  obs.Tracer(enabled=True))

    def tick_us(mgr, ticks=10):
        t0 = time.perf_counter()
        for _ in range(ticks):
            mgr.step(chunks)
        return (time.perf_counter() - t0) / ticks * 1e6

    # Interleave the three managers within every round: host-load drift
    # between rounds then hits all three equally, and the per-manager min
    # picks each one's best case under the same conditions.
    rounds = 6 if smoke else 10
    samples: dict = {"off": [], "default": [], "on": []}
    for _ in range(rounds):
        samples["off"].append(tick_us(mgr_off))
        samples["default"].append(tick_us(mgr_default))
        samples["on"].append(tick_us(mgr_on))
    t_off = min(samples["off"])
    t_default = min(samples["default"])
    t_on = min(samples["on"])
    overhead_disabled = max(0.0, t_default - t_off) / t_off
    overhead_enabled = max(0.0, t_on - t_off) / t_off
    within_budget = overhead_disabled < 0.01
    _row("telemetry_overhead", t_off,
         f"tick_off_us={t_off:.1f} tick_disabled_us={t_default:.1f} "
         f"tick_enabled_us={t_on:.1f} "
         f"overhead_disabled={overhead_disabled*100:.3f}% "
         f"overhead_enabled={overhead_enabled*100:.3f}% "
         f"within_budget={within_budget}")
    _record("telemetry_overhead", ablation="telemetry_overhead",
            wall_us=float(t_off), tick_disabled_us=float(t_default),
            tick_enabled_us=float(t_on),
            overhead_disabled_frac=float(overhead_disabled),
            overhead_enabled_frac=float(overhead_enabled),
            within_budget=bool(within_budget))
    assert within_budget, (
        f"disabled telemetry added {overhead_disabled*100:.2f}% to the "
        "streaming tick (budget: <1% — the hooks must be free when off)")


def streaming_occupancy():
    """Serving ablation: chunked streaming vs whole-stream batch inference.

    Serves the reduced gesture network at several occupancy levels (how many
    of the session's slots hold live streams).  For each level: wall time and
    per-stream latency through the persistent-Vmem streaming path
    (``StreamSessionManager`` via ``repro.serving.StreamWorker``, chunk_T
    timesteps per tick) vs one whole-stream ``run_engine`` call over the
    same streams,
    plus bit-exactness of the streamed readouts against the whole-stream
    result.  Uses the jnp backend so the numbers measure the serving loop,
    not the Pallas interpreter.
    """
    import jax
    import jax.numpy as jnp

    from repro import spidr
    from repro.configs import spidr_gesture
    from repro.core.network import init_params
    from repro.serving import StreamRequest, StreamWorker
    from repro.snn.data import make_gesture_batch

    spec = spidr_gesture.reduced(hw=(16, 16), timesteps=6)
    params = init_params(jax.random.PRNGKey(0), spec)
    eng = spidr.compile(spec, params, spidr.DeployTarget(backend="jnp"))
    capacity, chunk_T = 4, 3

    ev, _ = make_gesture_batch(jax.random.PRNGKey(1), batch=capacity,
                               timesteps=spec.timesteps, hw=spec.input_hw)
    ev_np = np.asarray(ev)

    for occ in (1, 2, 4):
        whole = eng.run(jnp.asarray(ev_np[:, :occ]))
        # One server per occupancy level: after a drain every slot is free
        # again, so repeated drains measure the steady-state serving loop
        # (the jitted session step compiles once, on the warm-up drain).
        server = StreamWorker(eng, capacity=capacity, chunk_T=chunk_T)

        def drain():
            for r in range(occ):
                server.submit(StreamRequest(rid=r, events=ev_np[:, r]))
            while server.step():
                pass

        us_stream = _timeit(drain, n=2)
        ev_occ = jnp.asarray(ev_np[:, :occ])  # CompiledSNN.run is jitted
        us_whole = _timeit(
            lambda: jax.block_until_ready(eng.run(ev_occ)), n=2)
        done = {r.rid: r for r in server.done[-occ:]}
        exact = all(
            (np.asarray(done[r].readout) == np.asarray(whole.readout)[r]).all()
            for r in range(occ)
        )
        lat = [r.done_at - r.submitted_at for r in server.done[-occ:]]
        _row(
            f"streaming_occ{occ}of{capacity}", us_stream,
            f"exact={exact} streams_per_s={occ / (us_stream / 1e6):.1f} "
            f"p50_latency_ms={np.median(lat) * 1e3:.1f} "
            f"whole_stream_us={us_whole:.0f} "
            f"stream_vs_whole={us_stream / max(us_whole, 1):.2f}x",
        )


def fleet_scaling(smoke: bool = False, trace_out: str = None):
    """Serving-fleet ablation: throughput/latency scaling across replicas.

    Drives ``spidr.serve`` end to end: a synthetic open-loop arrival
    process submits DVS streams into a sync-mode fleet of 1/2/4 engine
    replicas (1024 streams in the full run, 48 in ``--smoke``) with a
    bounded admission queue, so the run exercises scheduling, explicit
    load shedding (``FleetOverloaded``) and at least one live cross-replica
    migration per multi-replica point.  Reports p50/p99 per-chunk (fleet
    tick) latency, streams/sec, shed rate and migration count, and gates
    exactness: every completed stream's readout — including migrated and
    re-placed ones — must match a whole-stream ``CompiledSNN.run`` of the
    same events bit for bit.  ``trace_out`` additionally exports the
    fleet's Chrome trace (serve.tick + fleet.migrate spans) for the CI
    artifact.
    """
    import jax
    import jax.numpy as jnp

    from repro import obs, spidr
    from repro.configs import spidr_gesture
    from repro.core.network import init_params
    from repro.serving import FleetOverloaded
    from repro.snn.data import make_gesture_batch

    if trace_out:
        obs.enable_tracing()

    spec = spidr_gesture.reduced(hw=(16, 16), timesteps=6)
    params = init_params(jax.random.PRNGKey(0), spec)
    compiled = spidr.compile(spec, params, spidr.DeployTarget(backend="jnp"))

    if smoke:
        n_streams, capacity, chunk_T = 48, 2, 3
        burst, max_queue, replica_counts = 4, 8, (1, 2)
    else:
        n_streams, capacity, chunk_T = 1024, 8, 3
        burst, max_queue, replica_counts = 6, 32, (1, 2, 4)

    # A bank of distinct synthetic streams, cycled over by rid.  Stream
    # lengths alternate between the full window and half of it (variable-
    # length DVS streams stagger completions, so slots free up while other
    # streams still run — the window live migration needs).  The
    # whole-stream run of the bank at each length is the bit-exactness
    # reference for every completed stream (migrated ones included).
    bank = 16
    lengths = (spec.timesteps, spec.timesteps // 2)
    ev, _ = make_gesture_batch(jax.random.PRNGKey(1), batch=bank,
                               timesteps=spec.timesteps, hw=spec.input_hw)
    ev_np = np.asarray(ev)
    whole = {length: np.asarray(compiled.run(
        jnp.asarray(ev_np[:length])).readout) for length in set(lengths)}

    def _events(rid):
        return ev_np[:lengths[rid % len(lengths)], rid % bank]

    for r in replica_counts:
        fleet = spidr.serve(compiled, spidr.ServeConfig(
            n_replicas=r, capacity=capacity, chunk_T=chunk_T,
            max_queue=max_queue, migrate_every=8))
        tick_s: list = []
        shed = 0
        i = 0
        t0 = time.perf_counter()
        while True:
            for _ in range(burst):
                if i >= n_streams:
                    break
                try:
                    fleet.submit(_events(i), rid=i)
                except FleetOverloaded:
                    shed += 1
                i += 1
            t1 = time.perf_counter()
            alive = fleet.step()
            tick_s.append(time.perf_counter() - t1)
            if r > 1 and fleet.migrations == 0 \
                    and any(w.slots for w in fleet.workers):
                # Force one live migration per multi-replica point (the
                # backlogged phase has no free slot; the drain tail does).
                try:
                    fleet.migrate()
                except (RuntimeError, ValueError):
                    pass
            if i >= n_streams and not alive:
                break
        wall_s = time.perf_counter() - t0
        done = fleet.done
        exact = all(
            np.array_equal(
                np.asarray(req.readout),
                whole[lengths[req.rid % len(lengths)]][req.rid % bank])
            for req in done)
        fleet.shutdown()

        p50 = float(np.percentile(tick_s, 50) * 1e3)
        p99 = float(np.percentile(tick_s, 99) * 1e3)
        shed_rate = shed / max(n_streams, 1)
        name = f"fleet_r{r}" + ("_smoke" if smoke else "")
        _row(name, wall_s * 1e6 / max(len(tick_s), 1),
             f"exact={exact} completed={len(done)}/{n_streams} "
             f"shed_rate={shed_rate:.3f} migrations={fleet.migrations} "
             f"streams_per_s={len(done) / wall_s:.1f} "
             f"p50_chunk_ms={p50:.2f} p99_chunk_ms={p99:.2f}")
        rec = dict(
            ablation="fleet_scaling", replicas=r, streams=n_streams,
            completed=len(done), shed=shed, shed_rate=round(shed_rate, 4),
            migrations=fleet.migrations,
            streams_per_s=round(len(done) / wall_s, 2),
            p50_chunk_ms=round(p50, 3), p99_chunk_ms=round(p99, 3),
            exact=bool(exact))
        if r > 1:
            rec["migration_exact"] = bool(exact and fleet.migrations > 0)
        _record(name, **rec)
        if trace_out and r == replica_counts[-1]:
            obs.default_tracer().export(trace_out)
            print(f"# fleet chrome trace written to {trace_out}")


ALL = [
    table1_chip_summary,
    fig4_aer_overhead,
    fig5_sparsity_profile,
    fig10_switching,
    fig13_pipeline,
    fig14_energy_breakdown,
    fig16_accuracy_energy,
    fig17_sparsity_sweep,
    spike_gemm_kernel,
    engine_zero_skip,
    kernel_blocksparse,
    streaming_occupancy,
    fleet_scaling,
    compiler_multicore,
    qat_sweep,
    facade_overhead,
    telemetry_overhead,
]

# CI-sized subset: every ablation that feeds BENCH_compiler.json, on
# reduced shapes (a compiled-path or train->deploy regression fails this
# job visibly).
SMOKE = [lambda: compiler_multicore(smoke=True), lambda: qat_sweep(smoke=True),
         lambda: facade_overhead(smoke=True),
         lambda: kernel_blocksparse(smoke=True),
         lambda: telemetry_overhead(smoke=True)]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--streaming", action="store_true",
                    help="run only the streaming-vs-whole-stream ablation")
    ap.add_argument("--qat-sweep", action="store_true",
                    help="run only the train->deploy precision sweep")
    ap.add_argument("--facade-overhead", action="store_true",
                    help="run only the spidr-facade dispatch micro-bench "
                         "(asserts <1%% overhead vs direct engine calls)")
    ap.add_argument("--perf", action="store_true",
                    help="run only the block-sparse kernel perf ablation "
                         "(wall-us vs roofline bound, for the CI perf gate)")
    ap.add_argument("--fleet", action="store_true",
                    help="run only the spidr.serve fleet-scaling ablation "
                         "(1k streams over 1/2/4 replicas; p50/p99 chunk "
                         "latency, streams/s, shed rate, migration "
                         "exactness; --smoke serves a CI-sized subset)")
    ap.add_argument("--fleet-trace-out", default=None, dest="fleet_trace_out",
                    help="--fleet: also export the fleet's Chrome trace "
                         "(serve.tick/fleet.migrate spans) to this path")
    ap.add_argument("--telemetry-overhead", action="store_true",
                    dest="telemetry_overhead",
                    help="run only the telemetry micro-bench (asserts "
                         "disabled-mode hooks add <1%% to the streaming "
                         "tick)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized subset of the tracked ablations")
    ap.add_argument("--out", default="BENCH_compiler.json",
                    help="path for the machine-readable results JSON")
    args = ap.parse_args()
    if args.streaming:
        fns = [streaming_occupancy]
    elif args.qat_sweep:
        fns = [lambda: qat_sweep(smoke=args.smoke)]
    elif args.facade_overhead:
        fns = [lambda: facade_overhead(smoke=args.smoke)]
    elif args.perf:
        fns = [lambda: kernel_blocksparse(smoke=args.smoke)]
    elif args.fleet:
        fns = [lambda: fleet_scaling(smoke=args.smoke,
                                     trace_out=args.fleet_trace_out)]
    elif args.telemetry_overhead:
        fns = [lambda: telemetry_overhead(smoke=args.smoke)]
    elif args.smoke:
        fns = SMOKE
    else:
        fns = ALL
    print("name,us_per_call,derived")
    for fn in fns:
        fn()
    if RESULTS:
        _write_results(args.out)


if __name__ == "__main__":
    main()
