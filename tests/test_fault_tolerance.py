"""Unit tests for ``runtime.fault_tolerance`` — previously dormant code
that the streaming server now depends on (watchdog around every tick,
``retrying`` rewind-and-replay), so its contracts are pinned directly.
"""
import time

import pytest

from repro.runtime.fault_tolerance import (
    RestartableFailure,
    StepWatchdog,
    StragglerDetector,
    retrying,
)


class TestStepWatchdog:
    def test_fast_step_never_fires(self):
        wd = StepWatchdog(deadline_s=5.0)
        wd.arm()
        wd.disarm()
        wd.check()  # no exception
        assert not wd.timed_out and wd.timeouts == 0

    def test_expired_deadline_fires_and_check_raises(self):
        wd = StepWatchdog(deadline_s=0.01)
        wd.arm()
        time.sleep(0.1)
        wd.disarm()
        assert wd.timed_out and wd.timeouts == 1
        with pytest.raises(RestartableFailure, match="deadline"):
            wd.check()

    def test_on_timeout_callback_fires(self):
        fired = []
        wd = StepWatchdog(deadline_s=0.01, on_timeout=lambda: fired.append(1))
        wd.arm()
        time.sleep(0.1)
        wd.disarm()
        assert fired == [1]

    def test_rearm_clears_timed_out(self):
        wd = StepWatchdog(deadline_s=0.01)
        wd.arm()
        time.sleep(0.1)
        assert wd.timed_out
        wd.arm()          # new step: flag resets, count persists
        wd.disarm()
        wd.check()
        assert wd.timeouts == 1

    def test_disarm_without_arm_is_a_noop(self):
        StepWatchdog(deadline_s=1.0).disarm()


class TestStragglerDetector:
    def test_no_flags_before_min_steps(self):
        det = StragglerDetector(window=16, z_thresh=1.0, min_steps=8)
        for _ in range(7):
            assert not det.record(1.0)
        assert not det.record(1000.0)  # 8th sample: still warming up
        assert det.flagged == 0

    def test_outlier_is_flagged_after_warmup(self):
        det = StragglerDetector(window=32, z_thresh=3.0, min_steps=4)
        for _ in range(8):
            det.record(1.0)
        assert det.record(100.0)
        assert det.flagged == 1
        assert not det.record(1.0)

    def test_window_evicts_old_samples(self):
        det = StragglerDetector(window=4, z_thresh=3.0, min_steps=2)
        for _ in range(10):
            det.record(100.0)
        # The ring only remembers recent (uniform) history: another 100
        # is not a straggler relative to it.
        assert not det.record(100.0)
        assert len(det.times) == 4

    def test_stats_reflect_recorded_times(self):
        det = StragglerDetector(window=8, min_steps=2)
        for s in (1.0, 2.0, 3.0):
            det.record(s)
        st = det.stats()
        assert st.mean_s == pytest.approx(2.0)
        assert st.last_s == 3.0
        assert st.flagged == 0


class TestRetrying:
    def test_success_passes_through(self):
        step = retrying(lambda x: x + 1, lambda x: None)
        assert step(1) == 2
        assert step.state["restarts"] == 0

    def test_restartable_failure_restores_and_replays(self):
        calls = {"step": 0, "restore": 0}

        def step():
            calls["step"] += 1
            if calls["step"] < 3:
                raise RestartableFailure("poisoned")
            return "ok"

        def restore():
            calls["restore"] += 1

        wrapped = retrying(step, restore, max_restarts=5)
        assert wrapped() == "ok"
        assert calls == {"step": 3, "restore": 2}
        assert wrapped.state["restarts"] == 2

    def test_restart_budget_is_enforced(self):
        def always_fails():
            raise RestartableFailure("wedged")

        wrapped = retrying(always_fails, lambda: None, max_restarts=3)
        with pytest.raises(RestartableFailure, match="wedged"):
            wrapped()
        # max_restarts bounds the *extra* attempts: 1 + 3 retries.
        assert wrapped.state["restarts"] == 4

    def test_budget_spans_calls(self):
        # Crash-looping across ticks exhausts the same budget.
        flaky = {"n": 0}

        def step():
            flaky["n"] += 1
            if flaky["n"] % 2 == 1:
                raise RestartableFailure("every other call")
            return flaky["n"]

        wrapped = retrying(step, lambda: None, max_restarts=2)
        assert wrapped() == 2
        assert wrapped() == 4
        with pytest.raises(RestartableFailure):
            wrapped()

    def test_non_restartable_exceptions_propagate(self):
        def step():
            raise ValueError("not restartable")

        restores = []
        wrapped = retrying(step, lambda: restores.append(1))
        with pytest.raises(ValueError):
            wrapped()
        assert restores == []  # restore_fn never invoked

    def test_restore_fn_may_replace_args(self):
        def step(state):
            if state["poisoned"]:
                raise RestartableFailure("bad state")
            return state["value"]

        def restore(state):
            return ({"poisoned": False, "value": 42},)

        wrapped = retrying(step, restore, max_restarts=1)
        assert wrapped({"poisoned": True, "value": 0}) == 42

    def test_restore_fn_returning_none_keeps_args(self):
        seen = []

        def step(state):
            seen.append(state)
            if len(seen) == 1:
                raise RestartableFailure("once")
            return "done"

        wrapped = retrying(step, lambda state: state.clear(), max_restarts=1)
        marker = {"k": 1}
        assert wrapped(marker) == "done"
        assert seen[0] is marker and seen[1] is marker  # same object retried
