"""Checkpoint robustness: corruption, truncation, versioning, atomicity.

The serving tier's durability story (spidr session snapshots, the upgrade
drill) rides entirely on ``checkpoint.Checkpointer``'s guarantees, so they
are pinned here directly: a damaged checkpoint must raise a clean
:class:`CheckpointError` naming the problem — never silently deploy
corrupted state — and a crash mid-save must never corrupt the previous
completed checkpoint.
"""
import json
import os
import threading

import numpy as np
import pytest

from repro.checkpoint.checkpoint import (
    CheckpointError,
    Checkpointer,
    FORMAT_VERSION,
)


def _tree(seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    return {
        "w": rng.normal(size=(8, 4)).astype(np.float32),
        "counts": rng.integers(0, 1000, size=(5,)).astype(np.int64),
        "none_leaf": None,
        "nested": {"acc": rng.random((3, 3)).astype(np.float32)},
    }


def _like() -> dict:
    return {
        "w": np.zeros((8, 4), np.float32),
        "counts": np.zeros((5,), np.int64),
        "none_leaf": None,
        "nested": {"acc": np.zeros((3, 3), np.float32)},
    }


def _step_dir(ckpt: Checkpointer, step: int) -> str:
    return os.path.join(ckpt.directory, f"step_{step:09d}")


def _leaf_files(path: str) -> list:
    return sorted(f for f in os.listdir(path) if f.endswith(".npy"))


class TestRoundTrip:
    def test_save_restore_is_exact(self, tmp_path):
        ckpt = Checkpointer(str(tmp_path))
        tree = _tree()
        ckpt.save(3, tree)
        out = ckpt.restore(3, _like())
        assert np.array_equal(np.asarray(out["w"]), tree["w"])
        assert np.array_equal(np.asarray(out["nested"]["acc"]),
                              tree["nested"]["acc"])
        assert out["none_leaf"] is None

    def test_host_restore_preserves_wide_dtypes(self, tmp_path):
        # int64/float64 accounting must round-trip exactly; device arrays
        # would truncate them under 32-bit jax.
        ckpt = Checkpointer(str(tmp_path))
        tree = {"t": np.asarray([2**40, 7], np.int64),
                "e": np.asarray([1.0 + 2.0**-40], np.float64)}
        ckpt.save(0, tree)
        out = ckpt.restore(0, {"t": np.zeros(2, np.int64),
                               "e": np.zeros(1, np.float64)}, host=True)
        assert out["t"].dtype == np.int64 and out["t"][0] == 2**40
        assert out["e"].dtype == np.float64 and out["e"][0] == 1.0 + 2.0**-40

    def test_save_async_then_restore(self, tmp_path):
        ckpt = Checkpointer(str(tmp_path))
        tree = _tree(1)
        ckpt.save_async(5, tree)
        ckpt.wait()
        out = ckpt.restore(5, _like())
        assert np.array_equal(np.asarray(out["w"]), tree["w"])

    def test_manifest_records_every_leaf(self, tmp_path):
        ckpt = Checkpointer(str(tmp_path))
        ckpt.save(0, _tree())
        with open(os.path.join(_step_dir(ckpt, 0), "meta.json")) as f:
            meta = json.load(f)
        assert meta["format_version"] == FORMAT_VERSION
        assert len(meta["manifest"]) == meta["n_leaves"]
        # None leaves have no file and a null manifest entry; real leaves
        # carry dtype/shape/crc32.
        real = [m for m in meta["manifest"] if m is not None]
        assert len(real) == len(_leaf_files(_step_dir(ckpt, 0)))
        assert all({"dtype", "shape", "crc32"} <= set(m) for m in real)


class TestCorruptionDetection:
    def test_flipped_byte_raises_checkpoint_error(self, tmp_path):
        ckpt = Checkpointer(str(tmp_path))
        ckpt.save(0, _tree())
        path = _step_dir(ckpt, 0)
        leaf = os.path.join(path, _leaf_files(path)[0])
        blob = bytearray(open(leaf, "rb").read())
        blob[-1] ^= 0xFF  # flip a payload byte, header stays valid
        open(leaf, "wb").write(bytes(blob))
        with pytest.raises(CheckpointError, match="crc32"):
            ckpt.restore(0, _like())

    def test_truncated_leaf_raises_checkpoint_error(self, tmp_path):
        ckpt = Checkpointer(str(tmp_path))
        ckpt.save(0, _tree())
        path = _step_dir(ckpt, 0)
        leaf = os.path.join(path, _leaf_files(path)[-1])
        blob = open(leaf, "rb").read()
        open(leaf, "wb").write(blob[: len(blob) // 2])
        with pytest.raises(CheckpointError):
            ckpt.restore(0, _like())

    def test_wrong_shape_leaf_raises_checkpoint_error(self, tmp_path):
        ckpt = Checkpointer(str(tmp_path))
        ckpt.save(0, _tree())
        path = _step_dir(ckpt, 0)
        leaf = os.path.join(path, _leaf_files(path)[0])
        np.save(leaf, np.zeros((2, 2), np.float32))
        with pytest.raises(CheckpointError, match="manifest"):
            ckpt.restore(0, _like())

    def test_newer_format_version_refused(self, tmp_path):
        ckpt = Checkpointer(str(tmp_path))
        ckpt.save(0, _tree())
        meta_path = os.path.join(_step_dir(ckpt, 0), "meta.json")
        meta = json.load(open(meta_path))
        meta["format_version"] = 99
        json.dump(meta, open(meta_path, "w"))
        with pytest.raises(CheckpointError, match="format version"):
            ckpt.restore(0, _like())

    def test_garbage_meta_raises_checkpoint_error(self, tmp_path):
        ckpt = Checkpointer(str(tmp_path))
        ckpt.save(0, _tree())
        meta_path = os.path.join(_step_dir(ckpt, 0), "meta.json")
        open(meta_path, "w").write("{not json")
        with pytest.raises(CheckpointError, match="meta.json"):
            ckpt.restore(0, _like())

    def test_missing_step_is_file_not_found(self, tmp_path):
        # Absence is not corruption: callers distinguish "no snapshot yet"
        # from "snapshot damaged".
        ckpt = Checkpointer(str(tmp_path))
        with pytest.raises(FileNotFoundError):
            ckpt.restore(7, _like())

    def test_checkpoint_error_is_a_value_error(self):
        assert issubclass(CheckpointError, ValueError)


class TestAtomicity:
    def test_partial_write_is_invisible_to_latest_step(self, tmp_path):
        ckpt = Checkpointer(str(tmp_path))
        ckpt.save(1, _tree())
        # A crash mid-save leaves only the .tmp staging dir behind.
        os.makedirs(os.path.join(ckpt.directory, "step_000000002.tmp"))
        assert ckpt.latest_step() == 1
        out = ckpt.restore(1, _like())
        assert np.array_equal(np.asarray(out["w"]), _tree()["w"])

    def test_crash_mid_save_keeps_previous_snapshot_valid(self, tmp_path):
        # The serving contract: a process SIGKILLed while writing snapshot
        # k leaves snapshot k-1 complete and restorable.  Stall step 2's
        # commit rename ("crashed before the rename") and prove step 1 is
        # still the visible, restorable latest.
        ckpt = Checkpointer(str(tmp_path))
        tree = _tree()
        ckpt.save(1, tree)
        blocker = threading.Event()
        release = threading.Event()

        orig_rename = os.rename

        def stalled_rename(src, dst):
            if src.endswith(".tmp"):
                blocker.set()
                release.wait(timeout=10)
            return orig_rename(src, dst)

        os.rename = stalled_rename
        try:
            t = threading.Thread(
                target=ckpt._write,
                args=(2, [np.ones((8, 4), np.float32),
                          np.zeros((5,), np.int64), None,
                          np.zeros((3, 3), np.float32)], "td", {}),
                daemon=True)
            t.start()
            assert blocker.wait(timeout=10)
            assert ckpt.latest_step() == 1
            out = ckpt.restore(1, _like())
            assert np.array_equal(np.asarray(out["w"]), tree["w"])
        finally:
            release.set()
            t.join(timeout=10)
            os.rename = orig_rename
        assert ckpt.latest_step() == 2  # released: the save completed

    def test_rename_is_the_commit_point(self, tmp_path):
        ckpt = Checkpointer(str(tmp_path))
        renames = []
        orig_rename = os.rename

        def spy(src, dst):
            renames.append((src, dst))
            return orig_rename(src, dst)

        os.rename = spy
        try:
            ckpt.save(4, _tree())
        finally:
            os.rename = orig_rename
        assert [(s, d) for s, d in renames
                if s.endswith(".tmp") and d.endswith("step_000000004")]
