"""Gradient accumulation: accum_steps=N must equal the full-batch gradient."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.model import init_opt_state, init_params, make_train_step


def test_accum_matches_full_batch():
    cfg = get_config("qwen1.5-0.5b").reduced()
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    batch = {
        "tokens": jax.random.randint(key, (4, 16), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (4, 16), 0, cfg.vocab_size),
    }
    p1, _, m1 = make_train_step(cfg, lr=1e-3)(params, init_opt_state(params), 0, batch)
    p2, _, m2 = make_train_step(cfg, lr=1e-3, accum_steps=2)(
        params, init_opt_state(params), 0, batch
    )
    # Loss means agree...
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-3)
    # ...and the updated params agree. Tolerance is on the ADAM UPDATE scale
    # (lr=1e-3): bf16 forward reordering perturbs a few grads enough for the
    # normalizer m/sqrt(v) to move those updates by O(lr).
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=2e-2, atol=2.5e-3,
        )


def test_accum_runs_moe():
    cfg = get_config("granite-moe-3b-a800m").reduced()
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    batch = {
        "tokens": jax.random.randint(key, (4, 16), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (4, 16), 0, cfg.vocab_size),
    }
    _, _, m = make_train_step(cfg, accum_steps=4)(
        params, init_opt_state(params), 0, batch
    )
    assert np.isfinite(float(m["loss"]))
