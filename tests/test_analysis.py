"""Deploy-time static verification (``repro.analysis``).

What this suite pins:

  * both paper networks **certify** at every silicon precision pair, and
    the emitted overflow certificate survives independent re-derivation;
  * each of the four passes **catches its seeded negative** — a synthetic
    IR that overflows int32, a corrupted ``CoreSchedule``, an illegal
    precision pair, a lock-discipline fixture — with the exact diagnostic
    code, location and message the docs promise;
  * tampered certificates fail ``check_certificate``, not just eyeballs;
  * the facade wiring: ``spidr.compile(..., check=...)`` gates builds,
    ``CompiledSNN.report()`` always has the certificate;
  * the sync-vs-threaded stress harness agrees bit for bit on a real
    fleet;
  * the baseline ratchet waives old findings and fails new ones.
"""
import dataclasses
import functools
import json
import types
import warnings

import jax
import pytest

from repro import analysis, spidr
from repro.analysis import (
    AnalysisError,
    AnalysisReport,
    Violation,
    analyze_deployment,
    certify_overflow,
    check_certificate,
    check_lock_discipline,
    check_purity,
    check_schedule,
    check_serving,
    stress_fleet,
)
from repro.analysis.__main__ import main as analysis_main
from repro.compiler import compile_network
from repro.compiler.ir import LayerNode, NetworkGraph
from repro.configs import spidr_gesture
from repro.core.modes import LayerShape
from repro.core.network import gesture_net, init_params, optical_flow_net
from repro.core.quant import PRECISION_PAIRS, QuantSpec

HW, T = (16, 16), 6


@functools.lru_cache(maxsize=None)
def _compiled(n_cores=1, check="warn"):
    spec = spidr_gesture.reduced(hw=HW, timesteps=T)
    params = init_params(jax.random.PRNGKey(0), spec)
    return spidr.compile(spec, params, spidr.DeployTarget(
        weight_bits=4, backend="jnp", chunk_T=3, stream_capacity=2,
        n_cores=n_cores), check=check)


# ---------------------------------------------------------------------------
# Overflow certification.
# ---------------------------------------------------------------------------
class TestOverflow:
    @pytest.mark.parametrize("net", [gesture_net, optical_flow_net])
    @pytest.mark.parametrize("bits", [w for w, _ in PRECISION_PAIRS])
    def test_paper_networks_certify(self, net, bits):
        report = certify_overflow(net(), QuantSpec(bits))
        assert report.ok and not report.violations
        cert = report.certificates["overflow"]
        assert cert["ok"] and cert["saturation_points"] == 1
        assert check_certificate(cert) == []

    def test_synthetic_ir_overflows_int32(self):
        # fan_in * |w_min| = 2^28 * 2^7 = 2^35 >> int32 — a single spiking
        # frame can wrap the accumulator before the saturation point.
        graph = NetworkGraph("synthetic", (LayerNode(
            0, "fc", LayerShape.fc(1 << 28, 4), (),
            in_positions=1 << 28, out_positions=4),))
        report = certify_overflow(graph, QuantSpec(8))
        assert not report.ok
        (v,) = report.violations
        assert v.pass_name == "overflow" and v.code == "OVF001"
        assert v.location == "synthetic.L0"
        assert v.message == (
            "int32 accumulator can wrap before its single saturation "
            "point: fan_in 268435456 x |w|_max 128 = 34359738368 exceeds "
            "2147483647; any 16777216 simultaneously-active inputs "
            "overflows at 8/15-bit precision")
        cert = report.certificates["overflow"]
        assert cert["ok"] is False
        assert cert["layers"][0]["min_violating_active_inputs"] == 16777216
        assert check_certificate(cert) == []  # honest about failing

    def test_gesture_wraps_at_16_bit_accumulator(self):
        # The docs example: safe on the silicon's int32, provably unsafe
        # at 8/15-bit on a hypothetical 16-bit accumulator — the interim
        # of the Vmem accumulate reaches 2*|v_min| = 2^15 = int16 max + 1.
        report = certify_overflow(gesture_net(), QuantSpec(8), acc_bits=16)
        assert not report.ok
        assert report.violations and all(
            v.code == "OVF002" for v in report.violations)
        assert "neuron-step interim" in report.violations[0].message
        assert check_certificate(report.certificates["overflow"]) == []

    def test_gesture_gemm_wraps_on_narrow_accumulator(self):
        # OVF001 on a real network: at 4/7-bit an 11-bit accumulator is
        # one bit short of the widest layer's worst case (144 * 8 = 1152
        # > 1023), and the certificate names the minimal violating count.
        report = certify_overflow(gesture_net(), QuantSpec(4), acc_bits=11)
        bad = [v for v in report.violations if v.code == "OVF001"]
        assert bad and all("L" in v.location for v in bad)
        cert = report.certificates["overflow"]
        worst = max(cert["layers"], key=lambda f: f["fan_in"])
        assert worst["min_violating_active_inputs"] == 1023 // 8 + 1
        assert check_certificate(cert) == []

    def test_tampered_certificate_fails_reverification(self):
        graph = NetworkGraph("synthetic", (LayerNode(
            0, "fc", LayerShape.fc(1 << 28, 4), (),
            in_positions=1 << 28, out_positions=4),))
        cert = certify_overflow(graph, QuantSpec(8)).certificates["overflow"]
        cert = json.loads(json.dumps(cert))  # a round-tripped artifact
        cert["ok"] = True
        problems = check_certificate(cert)
        assert any("re-derivation gives False" in p for p in problems)

        good = certify_overflow(gesture_net(), QuantSpec(4))
        cert = json.loads(json.dumps(good.certificates["overflow"]))
        cert["layers"][0]["fan_in"] = 7
        assert check_certificate(cert)  # stale primitive fact detected

    def test_rejects_non_network(self):
        with pytest.raises(TypeError, match="SNNSpec or a compiler"):
            certify_overflow(object(), QuantSpec(4))


# ---------------------------------------------------------------------------
# Schedule verification.
# ---------------------------------------------------------------------------
def _schedule(net=gesture_net, n_cores=4, bits=4):
    return compile_network(net(), n_cores=n_cores, qspec=QuantSpec(bits))


class TestSchedule:
    @pytest.mark.parametrize("net", [gesture_net, optical_flow_net])
    @pytest.mark.parametrize("cores", [1, 4])
    def test_compiled_schedules_verify(self, net, cores):
        spec = net()
        schedule = compile_network(spec, n_cores=cores, qspec=QuantSpec(4))
        report = check_schedule(schedule, spec=spec)
        assert report.ok and not report.violations
        cert = report.certificates["schedule"]
        assert cert["ok"] and cert["n_cores"] == cores
        if cores > 1:
            assert cert["conservation"]  # the replay actually ran

    def test_over_capacity_schedule(self):
        # Shrink the grid under a 4-core placement: every slice on cores
        # 2..3 now over-subscribes the declared capacity.
        sched = _schedule(n_cores=4)
        bad = dataclasses.replace(sched, n_cores=2)
        report = check_schedule(bad)
        assert not report.ok
        codes = {v.code for v in report.violations}
        assert "SCH001" in codes and "SCH002" in codes
        v001 = next(v for v in report.violations if v.code == "SCH001")
        assert v001.location == sched.name
        assert v001.message == (
            "schedule declares n_cores=2 but its grid has 4 cores")
        v002 = next(v for v in report.violations if v.code == "SCH002")
        assert "outside the grid of 2 cores" in v002.message
        assert v002.location.startswith(f"{sched.name}.L")

    def test_illegal_precision_pair(self):
        sched = _schedule(n_cores=2)
        fake = types.SimpleNamespace(weight_bits=5, vmem_bits=9)
        bad = dataclasses.replace(sched, qspec=fake)
        report = check_schedule(bad)
        v = next(v for v in report.violations if v.code == "SCH010")
        assert v.location == sched.name
        assert v.message == (
            "illegal precision pair 5/9: supported pairs are 4/7, 6/11, "
            "8/15")

    def test_tampered_route_fractions(self):
        sched = _schedule(n_cores=4)
        layers = list(sched.layers)
        victim = next(i for i, l in enumerate(layers)
                      if any(f > 0 for f in l.route_fractions))
        fr = list(layers[victim].route_fractions)
        fr[0] = 2.0  # impossible: more than every spike routed
        layers[victim] = dataclasses.replace(
            layers[victim], route_fractions=tuple(fr))
        bad = dataclasses.replace(sched, layers=tuple(layers))
        codes = {v.code for v in check_schedule(bad).violations}
        assert "SCH031" in codes

    def test_conservation_replay_catches_forged_plan(self):
        # Swap one layer's plan mapping for another layer's: structurally
        # plausible, but the static cycle replay no longer matches the
        # cost model's attribution.
        spec = gesture_net()
        sched = compile_network(spec, n_cores=4, qspec=QuantSpec(4))
        report = check_schedule(sched, spec=spec)
        assert report.ok
        layers = list(sched.layers)
        donor = next(l for l in layers
                     if l.plan.mapping != layers[0].plan.mapping)
        forged = dataclasses.replace(
            layers[0], plan=dataclasses.replace(
                layers[0].plan, mapping=donor.plan.mapping))
        bad = dataclasses.replace(
            sched, layers=tuple([forged] + layers[1:]))
        codes = {v.code for v in check_schedule(bad, spec=spec).violations}
        assert codes & {"SCH023", "SCH040", "SCH041", "SCH042", "SCH043"}


# ---------------------------------------------------------------------------
# Concurrency lint + stress harness.
# ---------------------------------------------------------------------------
_RACY = '''\
import threading
import time


class Racy:
    def __init__(self):
        self._lock = threading.Lock()
        self.count = 0

    def bump(self):
        self.count += 1

    def slow(self):
        with self._lock:
            time.sleep(0.1)
'''


class TestConcurrency:
    def test_serving_package_is_clean(self):
        report = check_serving()
        assert report.ok and not report.violations

    def test_seeded_fixture_caught(self):
        report = check_lock_discipline(_RACY, "fixture.py")
        assert {v.code for v in report.violations} == {"CON001", "CON002"}
        v1 = next(v for v in report.violations if v.code == "CON001")
        assert v1.location == "fixture.py:11"
        assert v1.message == (
            "Racy.bump writes self.count without holding self._lock")
        v2 = next(v for v in report.violations if v.code == "CON002")
        assert v2.location == "fixture.py:15"
        assert v2.message == (
            "Racy.slow calls time.sleep() while holding self._lock — "
            "blocking under the fleet lock stalls every replica")

    def test_locked_helper_fixpoint(self):
        src = _RACY.replace(
            "    def bump(self):\n        self.count += 1\n",
            "    def bump(self):\n"
            "        with self._lock:\n"
            "            self._inc()\n\n"
            "    def _inc(self):\n"
            "        self.count += 1\n")
        report = check_lock_discipline(src, "fixture.py")
        assert not any(v.code == "CON001" for v in report.violations)

    def test_stress_sync_vs_threaded_bit_exact(self):
        result = stress_fleet(_compiled(), n_streams=4, n_replicas=2,
                              seed=7)
        assert result.ok, result.mismatches
        assert result.n_streams == 4
        assert result.ticks_sync > 0 and result.ticks_threaded > 0


# ---------------------------------------------------------------------------
# Purity lint.
# ---------------------------------------------------------------------------
_IMPURE = '''\
import functools
import random
import time

import jax
from dataclasses import dataclass
from jax.tree_util import register_pytree_node

_CACHE = {}


@functools.partial(jax.jit, static_argnames=("n",))
def step(x, n):
    t0 = time.perf_counter()
    return x * _CACHE["scale"] + random.random() + t0


def scale_int(x):
    return float(x / 2) + 0.5


@dataclass
class BadSched:
    slices: list


register_pytree_node(BadSched, lambda s: ((), s), lambda s, _: s)
'''


class TestPurity:
    def test_repo_is_clean(self):
        report = check_purity()
        assert report.ok, report.summary()

    def test_seeded_fixture_caught(self):
        report = analysis.check_module_purity(_IMPURE, "fixture.py")
        codes = sorted({v.code for v in report.violations})
        assert codes == ["PUR001", "PUR002", "PUR003", "PUR004"]
        msgs = {v.code: v for v in report.violations}
        assert msgs["PUR001"].location in ("fixture.py:14", "fixture.py:15")
        assert "host-side time/randomness" in msgs["PUR001"].message
        assert "mutable module global '_CACHE'" in msgs["PUR002"].message
        assert msgs["PUR003"].location == "fixture.py:19"
        assert msgs["PUR004"].location == "fixture.py:27"
        assert "BadSched is not frozen" in msgs["PUR004"].message

    def test_jax_random_is_safe(self):
        src = (
            "import jax\n"
            "from jax import random\n"
            "@jax.jit\n"
            "def step(key, x):\n"
            "    return x + random.normal(key, x.shape)\n")
        report = analysis.check_module_purity(src, "ok.py")
        assert report.ok

    def test_frozen_immutable_leafless_pytree_passes(self):
        src = (
            "from dataclasses import dataclass\n"
            "from jax.tree_util import register_pytree_node\n"
            "@dataclass(frozen=True)\n"
            "class Sched:\n"
            "    name: str\n"
            "    cores: tuple\n"
            "register_pytree_node(Sched, lambda s: ((), s), "
            "lambda s, _: s)\n")
        assert analysis.check_module_purity(src, "ok.py").ok

    def test_leafy_pytree_exempt(self):
        src = (
            "from dataclasses import dataclass\n"
            "from jax.tree_util import register_pytree_node\n"
            "@dataclass\n"
            "class State:\n"
            "    v: list\n"
            "register_pytree_node(State, lambda s: ((s.v,), None), "
            "lambda _, c: State(list(c)))\n")
        assert analysis.check_module_purity(src, "ok.py").ok


# ---------------------------------------------------------------------------
# Facade wiring: spidr.compile(check=...) + CompiledSNN.report().
# ---------------------------------------------------------------------------
class TestFacade:
    def test_compile_populates_report(self):
        c = _compiled()
        rep = c.report()
        assert isinstance(rep, AnalysisReport) and rep.ok
        assert "overflow" in rep.certificates
        assert check_certificate(rep.certificates["overflow"]) == []

    def test_multicore_report_includes_schedule_pass(self):
        rep = _compiled(n_cores=4).report()
        assert set(rep.passes) == {"overflow", "schedule"}
        assert rep.ok

    def test_check_off_is_lazy(self):
        c = _compiled(check="off")
        assert c._analysis is None
        assert c.report().ok
        assert c._analysis is not None

    def test_invalid_mode_rejected(self):
        spec = spidr_gesture.reduced(hw=HW, timesteps=T)
        params = init_params(jax.random.PRNGKey(0), spec)
        with pytest.raises(ValueError, match="check must be one of"):
            spidr.compile(spec, params, check="nope")

    def test_strict_raises_and_warn_warns(self, monkeypatch):
        spec = spidr_gesture.reduced(hw=HW, timesteps=T)
        params = init_params(jax.random.PRNGKey(0), spec)
        seeded = AnalysisReport(
            subject="seeded", passes=("overflow",),
            violations=(Violation(
                pass_name="overflow", code="OVF001",
                location="seeded.L0", message="seeded failure"),))
        monkeypatch.setattr(
            analysis, "analyze_deployment", lambda *a, **k: seeded)
        with pytest.raises(AnalysisError) as err:
            spidr.compile(spec, params, check="strict")
        assert err.value.report is seeded
        assert "seeded failure" in str(err.value)
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            c = spidr.compile(spec, params, check="warn")
        assert any("static analysis found 1 violation" in str(w.message)
                   for w in caught)
        assert c.report() is seeded


# ---------------------------------------------------------------------------
# Report plumbing, baseline ratchet, CLI.
# ---------------------------------------------------------------------------
class TestReportAndCLI:
    def test_violation_key_excludes_message(self):
        a = Violation("overflow", "OVF001", "net.L0", "run-dependent 123")
        b = Violation("overflow", "OVF001", "net.L0", "run-dependent 456")
        assert a.key == b.key == "overflow:OVF001:net.L0"
        with pytest.raises(ValueError, match="severity"):
            Violation("overflow", "OVF001", "net.L0", "m", severity="fatal")

    def test_report_json_roundtrip(self):
        rep = certify_overflow(gesture_net(), QuantSpec(4))
        back = AnalysisReport.from_dict(json.loads(rep.to_json()))
        assert back.subject == rep.subject
        assert back.certificates == json.loads(
            json.dumps(rep.certificates))

    def test_baseline_ratchet(self, tmp_path):
        old = Violation("schedule", "SCH001", "net", "old finding")
        new = Violation("schedule", "SCH002", "net.L0", "new finding")
        path = tmp_path / "baseline.json"
        analysis.write_baseline(str(path), [old])
        waived = analysis.load_baseline(str(path))
        assert analysis.new_violations([old, new], waived) == (new,)

    def test_cli_certifies_and_writes_artifact(self, tmp_path, capsys):
        out = tmp_path / "report.json"
        rc = analysis_main([
            "--network", "gesture", "--bits", "4", "--cores", "1",
            "--skip-lints", "--json", str(out)])
        assert rc == 0
        data = json.loads(out.read_text())
        assert data["ok"] is True
        assert any("overflow" in k for k in data["certificates"])
        assert "certified" in capsys.readouterr().out

    def test_cli_baseline_flow(self, tmp_path):
        # A corrupted deployment fails ... unless baselined.
        base = tmp_path / "b.json"
        rep = certify_overflow(
            gesture_net(), QuantSpec(4), acc_bits=16)
        analysis.write_baseline(str(base), rep.violations)
        waived = analysis.load_baseline(str(base))
        assert analysis.new_violations(rep.violations, waived) == ()

    def test_analyze_deployment_merges_passes(self):
        spec = gesture_net()
        sched = compile_network(spec, n_cores=4, qspec=QuantSpec(4))
        rep = analyze_deployment(spec, QuantSpec(4), sched)
        assert set(rep.passes) == {"overflow", "schedule"}
        assert {"overflow", "schedule"} <= set(rep.certificates)
