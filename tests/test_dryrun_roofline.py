"""Integration tests: dry-run machinery + HLO roofline parser.

These need a forced host device count (XLA locks it at first init), so
they run in subprocesses.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}


def _run(code: str, timeout=900):
    return subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=ENV, cwd=REPO,
    )


class TestRooflineParser:
    def test_scan_trip_count_inflation(self):
        """Parser FLOPs for a scanned matmul == fully unrolled compile."""
        r = _run("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            import jax, jax.numpy as jnp
            from jax.sharding import PartitionSpec as P
            from repro.roofline.analysis import parse_hlo

            from repro.launch.mesh import make_mesh_compat
            mesh = make_mesh_compat((4, 2), ("data", "model"))

            def scanned(x, w):
                return jnp.sum(jax.lax.scan(lambda c, wi: (jnp.dot(c, wi), None), x, w)[0])

            def unrolled(x, w):
                for i in range(6):
                    x = jnp.dot(x, w[i])
                return jnp.sum(x)

            x = jax.ShapeDtypeStruct((256, 512), jnp.float32)
            w = jax.ShapeDtypeStruct((6, 512, 512), jnp.float32)
            sh = (jax.sharding.NamedSharding(mesh, P("data", None)),
                  jax.sharding.NamedSharding(mesh, P(None, None, "model")))
            with mesh:
                fs = parse_hlo(jax.jit(scanned, in_shardings=sh).lower(x, w).compile().as_text())
                fu = parse_hlo(jax.jit(unrolled, in_shardings=sh).lower(x, w).compile().as_text())
            assert fs["dot_flops"] == fu["dot_flops"], (fs["dot_flops"], fu["dot_flops"])
            # exact analytic check: 2 * M_loc * K * N_loc * L
            assert fs["dot_flops"] == 2 * 64 * 512 * 256 * 6
            print("PARSER_OK")
        """)
        assert "PARSER_OK" in r.stdout, r.stdout + r.stderr

    def test_shape_bytes(self):
        from repro.roofline.analysis import _shape_bytes

        assert _shape_bytes("f32[16,4096,1024]") == 16 * 4096 * 1024 * 4
        assert _shape_bytes("bf16[8]") == 16
        assert _shape_bytes("(f32[4], bf16[4])") == 16 + 8


@pytest.mark.slow
class TestDryrunIntegration:
    def test_one_cell_end_to_end(self, tmp_path):
        """Lower+compile a real cell on the 512-device production mesh."""
        r = _run(f"""
            import sys
            sys.argv = ["dryrun", "--arch", "rwkv6-7b", "--shape", "long_500k",
                        "--mesh", "pod1", "--out", r"{tmp_path}", "--force"]
            from repro.launch import dryrun
            dryrun.main()
        """)
        assert r.returncode == 0, r.stdout + r.stderr
        out = json.load(open(tmp_path / "rwkv6-7b__long_500k__pod1.json"))
        assert out["status"] == "ok"
        assert out["roofline"]["dot_flops_local"] > 0
        # fits in v5e HBM
        mem = out["memory_analysis"]
        total = mem["argument_size_in_bytes"] + mem["temp_size_in_bytes"]
        assert total < 16 * 2**30

    def test_skip_rule_recorded(self, tmp_path):
        r = _run(f"""
            import sys
            sys.argv = ["dryrun", "--arch", "qwen1.5-0.5b", "--shape", "long_500k",
                        "--mesh", "pod1", "--out", r"{tmp_path}", "--force"]
            from repro.launch import dryrun
            dryrun.main()
        """)
        assert r.returncode == 0, r.stdout + r.stderr
        out = json.load(open(tmp_path / "qwen1.5-0.5b__long_500k__pod1.json"))
        assert out["status"] == "skipped"
        assert "sub-quadratic" in out["reason"]
