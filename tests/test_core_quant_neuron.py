"""Unit + property tests: quantization (C2) and neuron models (C8)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # dev extra absent: property tests skip, rest run
    from _hypothesis_stub import given, settings, st

from repro.core.neuron import NeuronConfig, neuron_step, neuron_step_int, spike_surrogate
from repro.core.quant import (
    SUPPORTED_PRECISIONS,
    QuantSpec,
    dequantize,
    quantize,
    sat_add,
    ste_quantize,
)


class TestQuantSpec:
    def test_supported_pairs(self):
        assert [(s.weight_bits, s.vmem_bits) for s in SUPPORTED_PRECISIONS] == [
            (4, 7), (6, 11), (8, 15)
        ]

    def test_vmem_invariant(self):
        for s in SUPPORTED_PRECISIONS:
            assert s.vmem_bits == 2 * s.weight_bits - 1

    def test_neurons_per_row(self):
        # Sec II-E: 48/W_b weights per row -> 12 / 8 / 6
        assert [s.neurons_per_row for s in SUPPORTED_PRECISIONS] == [12, 8, 6]

    def test_invalid_bits(self):
        with pytest.raises(ValueError):
            QuantSpec(5)

    def test_ranges(self):
        s = QuantSpec(4)
        assert (s.w_min, s.w_max) == (-8, 7)
        assert (s.v_min, s.v_max) == (-64, 63)


class TestQuantize:
    @pytest.mark.parametrize("bits", [4, 6, 8])
    def test_roundtrip_error_bound(self, bits):
        spec = QuantSpec(bits)
        w = jax.random.normal(jax.random.PRNGKey(0), (64, 32))
        q, scale = quantize(w, spec)
        err = jnp.max(jnp.abs(dequantize(q, scale) - w))
        assert float(err) <= float(scale) / 2 + 1e-6

    def test_quantize_in_range(self):
        spec = QuantSpec(4)
        w = jax.random.normal(jax.random.PRNGKey(1), (100,)) * 100
        q, _ = quantize(w, spec)
        assert int(q.min()) >= spec.w_min and int(q.max()) <= spec.w_max

    def test_ste_gradient_is_identity(self):
        g = jax.grad(lambda w: jnp.sum(ste_quantize(w, 4) * 3.0))(jnp.ones((5,)))
        np.testing.assert_allclose(np.asarray(g), 3.0)

    @given(st.integers(min_value=-64, max_value=63), st.integers(min_value=-8, max_value=7))
    @settings(max_examples=50, deadline=None)
    def test_sat_add_stays_in_range(self, v, w):
        spec = QuantSpec(4)
        out = int(sat_add(jnp.int32(v), jnp.int32(w), spec))
        assert spec.v_min <= out <= spec.v_max
        clamped = max(spec.v_min, min(spec.v_max, v + w))
        assert out == clamped


class TestNeuron:
    def test_if_hard_reset(self):
        cfg = NeuronConfig(model="if", reset="hard", threshold=1.0)
        v, s = neuron_step(jnp.array([0.5, 0.9]), jnp.array([0.6, 0.0]), cfg)
        np.testing.assert_allclose(np.asarray(s), [1.0, 0.0])
        np.testing.assert_allclose(np.asarray(v), [0.0, 0.9])

    def test_if_soft_reset_keeps_residual(self):
        cfg = NeuronConfig(model="if", reset="soft", threshold=1.0)
        v, s = neuron_step(jnp.array([0.9]), jnp.array([0.6]), cfg)
        np.testing.assert_allclose(np.asarray(v), [0.5], atol=1e-6)

    def test_lif_leak(self):
        cfg = NeuronConfig(model="lif", reset="hard", threshold=10.0, leak=0.5)
        v, _ = neuron_step(jnp.array([1.0]), jnp.array([0.0]), cfg)
        np.testing.assert_allclose(np.asarray(v), [0.5])

    def test_surrogate_grad_triangle(self):
        g = jax.grad(lambda v: spike_surrogate(v, 1.0, 1.0))(jnp.float32(1.0))
        assert float(g) == pytest.approx(1.0)  # peak of triangle
        g0 = jax.grad(lambda v: spike_surrogate(v, 1.0, 1.0))(jnp.float32(3.0))
        assert float(g0) == 0.0  # outside support

    @pytest.mark.parametrize("reset", ["hard", "soft"])
    @pytest.mark.parametrize("model", ["if", "lif"])
    def test_int_neuron_in_range(self, model, reset):
        spec = QuantSpec(4)
        cfg = NeuronConfig(model=model, reset=reset, leak_shift=2)
        rng = np.random.default_rng(0)
        v = jnp.array(rng.integers(spec.v_min, spec.v_max + 1, (64,)), jnp.int32)
        p = jnp.array(rng.integers(-30, 30, (64,)), jnp.int32)
        v2, s = neuron_step_int(v, p, cfg, spec, threshold_int=20)
        assert int(v2.min()) >= spec.v_min and int(v2.max()) <= spec.v_max
        assert set(np.unique(np.asarray(s))).issubset({0, 1})

    @given(st.integers(min_value=-50, max_value=50))
    @settings(max_examples=30, deadline=None)
    def test_int_hard_reset_zeroes_fired(self, vmem):
        spec = QuantSpec(4)
        cfg = NeuronConfig(model="if", reset="hard")
        v2, s = neuron_step_int(
            jnp.array([vmem], jnp.int32), jnp.array([30], jnp.int32), cfg, spec, 20
        )
        if int(s[0]) == 1:
            assert int(v2[0]) == 0
