"""Fused multi-timestep engine: kernel bit-exactness, engine-vs-reference
equivalence over sparse streams, and batch-vmap consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import spidr_gesture
from repro.core.cim_macro import accumulate_sequential
from repro.core.layers import SpikingConvParams, SpikingDenseParams
from repro.core.network import SNNLayer, SNNSpec, gesture_net, init_params
from repro.core.neuron import NeuronConfig
from repro.core.quant import QuantSpec
from repro.engine import (
    EngineConfig,
    build_engine,
    estimate_cost,
    run_engine,
    run_reference,
)
from repro.kernels import ref
from repro.kernels.fused_lif_gemm import fused_lif_gemm, fused_lif_gemm_int


class TestFusedKernel:
    @pytest.mark.parametrize("m,k,n", [(32, 64, 16), (100, 300, 50), (257, 140, 33)])
    @pytest.mark.parametrize("density", [0.0, 0.05, 0.5])
    def test_int_matches_oracle(self, m, k, n, density):
        rng = np.random.default_rng(m + n)
        s = (rng.random((m, k)) < density).astype(np.int8)
        w = rng.integers(-7, 8, (k, n)).astype(np.int8)
        v = rng.integers(-40, 40, (m, n)).astype(np.int32)
        vo, so = fused_lif_gemm_int(
            jnp.array(s), jnp.array(w), jnp.array(v), threshold=15,
            leak_shift=3, soft_reset=True, vmem_bits=7, interpret=True,
        )
        ve, se = ref.fused_lif_gemm_int_ref(
            jnp.array(s), jnp.array(w), jnp.array(v), 15, 3, True, 7)
        np.testing.assert_array_equal(np.asarray(vo), np.asarray(ve))
        np.testing.assert_array_equal(np.asarray(so), np.asarray(se))

    @pytest.mark.parametrize("leak,soft", [(1.0, False), (0.9, True)])
    def test_float_matches_oracle(self, leak, soft):
        rng = np.random.default_rng(1)
        s = (rng.random((65, 130)) < 0.1).astype(np.float32)
        w = rng.normal(size=(130, 40)).astype(np.float32)
        v = rng.normal(size=(65, 40)).astype(np.float32)
        vo, so = fused_lif_gemm(
            jnp.array(s), jnp.array(w), jnp.array(v), threshold=0.5,
            leak=leak, soft_reset=soft, interpret=True,
        )
        ve, se = ref.fused_lif_gemm_ref(
            jnp.array(s), jnp.array(w), jnp.array(v), 0.5, leak, soft)
        np.testing.assert_allclose(np.asarray(vo), np.asarray(ve),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(np.asarray(so), np.asarray(se))

    def test_skip_and_dense_agree(self):
        """Tile zero-skipping must not change results (C3 exactness)."""
        rng = np.random.default_rng(2)
        s = (rng.random((256, 256)) < 0.02).astype(np.int8)
        w = rng.integers(-7, 8, (256, 64)).astype(np.int8)
        v = rng.integers(-30, 30, (256, 64)).astype(np.int32)
        a = fused_lif_gemm_int(jnp.array(s), jnp.array(w), jnp.array(v),
                               threshold=10, interpret=True, skip_empty=True)
        b = fused_lif_gemm_int(jnp.array(s), jnp.array(w), jnp.array(v),
                               threshold=10, interpret=True, skip_empty=False)
        np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
        np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))

    @pytest.mark.parametrize("bits", [4, 6, 8])
    def test_matches_accumulate_sequential_no_overflow(self, bits):
        """Fused accumulation == silicon-order saturating chain when no
        intermediate sum leaves the Vmem range (QuantSpec semantics)."""
        spec = QuantSpec(bits)
        rng = np.random.default_rng(bits)
        rows, pairs, n = 128, 16, 12
        spikes = (rng.random((rows, pairs)) < 0.05).astype(np.int8)
        # |w| <= 2 and <= ~13 spikes/column keeps every partial sum in range.
        w = rng.integers(-2, 3, (rows, n)).astype(np.int8)
        vmem = rng.integers(-8, 8, (pairs, n)).astype(np.int32)
        want = accumulate_sequential(spikes, w, vmem, spec)
        # Kernel view: Vmem[x, n] = clip(v + clip(S^T @ W)); IF, no firing.
        vo, so = fused_lif_gemm_int(
            jnp.array(spikes.T), jnp.array(w), jnp.array(vmem),
            threshold=spec.v_max, leak_shift=0, soft_reset=False,
            vmem_bits=spec.vmem_bits, interpret=True,
        )
        assert int(jnp.sum(so)) == 0  # stayed below threshold by construction
        np.testing.assert_array_equal(np.asarray(vo), want)


def _mini_spec(readout="rate", hw=(16, 16), timesteps=3):
    n = NeuronConfig(model="lif", reset="soft", threshold=0.5, leak_shift=3)
    return SNNSpec(
        name="mini", input_hw=hw, in_channels=2, timesteps=timesteps,
        layers=(
            SNNLayer("conv", 2, 8, conv=SpikingConvParams(3, 3, 1, 1, n)),
            SNNLayer("pool"),
            SNNLayer("conv", 8, 8, conv=SpikingConvParams(3, 3, 1, 1, n)),
            SNNLayer("adaptive_pool", target_hw=2),
            SNNLayer("fc", 32, 5, fc=SpikingDenseParams(n)),
        ),
        readout=readout,
    )


def _engine(spec, seed=0, **over):
    params = init_params(jax.random.PRNGKey(seed), spec)
    cfg = EngineConfig(QuantSpec(over.pop("bits", 4)), interpret=True,
                       block=(64, 64, 64), **over)
    return build_engine(spec, params, cfg)


class TestEngine:
    @pytest.mark.parametrize("sparsity", [0.60, 0.90, 0.95])
    def test_engine_matches_reference_sparse_streams(self, sparsity):
        """Fused scan engine == pure-jnp per-timestep loop, spike for spike."""
        spec = _mini_spec()
        eng = _engine(spec)
        rng = np.random.default_rng(int(sparsity * 100))
        ev = jnp.asarray(
            (rng.random((spec.timesteps, 2) + spec.input_hw + (2,)) > sparsity)
            .astype(np.float32))
        out = run_engine(eng, ev)
        want = run_reference(eng, ev)
        np.testing.assert_array_equal(np.asarray(out.readout),
                                      np.asarray(want.readout))
        np.testing.assert_array_equal(np.asarray(out.spike_counts),
                                      np.asarray(want.spike_counts))
        np.testing.assert_array_equal(np.asarray(out.input_counts),
                                      np.asarray(want.input_counts))

    def test_two_layer_gesture_config(self):
        """Acceptance: identical spike counts on a 2-layer gesture network."""
        from repro.snn.data import make_gesture_batch

        full = gesture_net()
        spec = SNNSpec(
            name="gesture2", input_hw=(32, 32), in_channels=2, timesteps=4,
            layers=full.layers[:2], readout="vmem",
        )
        eng = _engine(spec)
        ev, _ = make_gesture_batch(jax.random.PRNGKey(1), batch=2,
                                   timesteps=spec.timesteps, hw=spec.input_hw)
        out = run_engine(eng, ev)
        want = run_reference(eng, ev)
        np.testing.assert_array_equal(np.asarray(out.spike_counts),
                                      np.asarray(want.spike_counts))
        np.testing.assert_array_equal(np.asarray(out.readout),
                                      np.asarray(want.readout))

    def test_skip_vs_dense_engine(self):
        spec = _mini_spec()
        eng = _engine(spec)
        dense = dataclasses.replace(
            eng, cfg=dataclasses.replace(eng.cfg, skip_empty=False))
        rng = np.random.default_rng(7)
        ev = jnp.asarray((rng.random((3, 2, 16, 16, 2)) > 0.9)
                         .astype(np.float32))
        a, b = run_engine(eng, ev), run_engine(dense, ev)
        np.testing.assert_array_equal(np.asarray(a.readout),
                                      np.asarray(b.readout))

    @pytest.mark.parametrize("backend", ["fused", "jnp"])
    def test_batch_fold_vs_vmap(self, backend):
        """Folding B into GEMM rows == vmapping per-sample runs."""
        spec = _mini_spec()
        eng = _engine(spec, backend=backend)
        rng = np.random.default_rng(9)
        ev = jnp.asarray((rng.random((3, 3, 16, 16, 2)) > 0.85)
                         .astype(np.float32))
        fold = run_engine(eng, ev, batch_mode="fold")
        vm = run_engine(eng, ev, batch_mode="vmap")
        np.testing.assert_array_equal(np.asarray(fold.readout),
                                      np.asarray(vm.readout))
        np.testing.assert_array_equal(np.asarray(fold.spike_counts),
                                      np.asarray(vm.spike_counts))

    def test_lif_zero_leak_shift_backends_agree(self):
        """leak_shift=0 means no leak in BOTH backends (regression)."""
        n = NeuronConfig(model="lif", reset="soft", threshold=0.5, leak_shift=0)
        spec = SNNSpec(
            name="noleak", input_hw=(16, 16), in_channels=2, timesteps=3,
            layers=(SNNLayer("conv", 2, 8, conv=SpikingConvParams(3, 3, 1, 1, n)),),
            readout="vmem",
        )
        fused = _engine(spec)
        rng = np.random.default_rng(11)
        ev = jnp.asarray((rng.random((3, 2, 16, 16, 2)) > 0.9)
                         .astype(np.float32))
        out = run_engine(fused, ev)
        want = run_reference(fused, ev)
        np.testing.assert_array_equal(np.asarray(out.readout),
                                      np.asarray(want.readout))
        # Vmem must be able to carry across steps (not zeroed by v >> 0).
        assert int(jnp.sum(jnp.abs(out.readout))) > 0

    def test_vmem_readout_with_pooling(self):
        """Vmem carry shape follows the pooled plane, not input_hw."""
        n = NeuronConfig(model="if", reset="soft", threshold=0.5)
        spec = SNNSpec(
            name="pooled_vmem", input_hw=(16, 16), in_channels=2, timesteps=2,
            layers=(
                SNNLayer("conv", 2, 4, conv=SpikingConvParams(3, 3, 1, 1, n)),
                SNNLayer("pool"),
                SNNLayer("conv", 4, 4, conv=SpikingConvParams(3, 3, 1, 1, n)),
            ),
            readout="vmem",
        )
        eng = _engine(spec)
        rng = np.random.default_rng(12)
        ev = jnp.asarray((rng.random((2, 2, 16, 16, 2)) > 0.9)
                         .astype(np.float32))
        out = run_engine(eng, ev)
        assert out.readout.shape == (2, 8, 8, 4)
        want = run_reference(eng, ev)
        np.testing.assert_array_equal(np.asarray(out.readout),
                                      np.asarray(want.readout))

    def test_reduced_hw_guard(self):
        with pytest.raises(AssertionError):
            spidr_gesture.reduced(hw=(12, 12))

    def test_vmem_readout(self):
        spec = _mini_spec()
        flow = SNNSpec(name="mini_vmem", input_hw=(16, 16), in_channels=2,
                       timesteps=3, layers=spec.layers[:1], readout="vmem")
        eng = _engine(flow)
        rng = np.random.default_rng(3)
        ev = jnp.asarray((rng.random((3, 2, 16, 16, 2)) > 0.9)
                         .astype(np.float32))
        out = run_engine(eng, ev)
        want = run_reference(eng, ev)
        np.testing.assert_array_equal(np.asarray(out.readout),
                                      np.asarray(want.readout))
        assert out.readout.shape == (2, 16, 16, 8)

    def test_cost_model_threads_pipeline_and_energy(self):
        spec = _mini_spec()
        eng = _engine(spec)
        rng = np.random.default_rng(4)
        ev = jnp.asarray((rng.random((3, 2, 16, 16, 2)) > 0.9)
                         .astype(np.float32))
        out = run_engine(eng, ev)
        cost = estimate_cost(spec, QuantSpec(4),
                             np.asarray(out.input_counts) / 2)
        assert cost.makespan_cycles > 0
        assert cost.sync_makespan_cycles >= cost.makespan_cycles
        assert cost.energy_uj > 0
        assert 0.0 <= cost.mean_sparsity <= 1.0
        # Denser input must never be cheaper in cycles.
        ev2 = jnp.asarray((rng.random((3, 2, 16, 16, 2)) > 0.5)
                          .astype(np.float32))
        out2 = run_engine(eng, ev2)
        cost2 = estimate_cost(spec, QuantSpec(4),
                              np.asarray(out2.input_counts) / 2)
        assert cost2.makespan_cycles >= cost.makespan_cycles

    def test_reduced_gesture_config_runs(self):
        """The serving config (configs.spidr_gesture.reduced) end to end."""
        spec = spidr_gesture.reduced(hw=(16, 16), timesteps=2)
        eng = _engine(spec)
        rng = np.random.default_rng(5)
        ev = jnp.asarray((rng.random((2, 1, 16, 16, 2)) > 0.95)
                         .astype(np.float32))
        out = run_engine(eng, ev)
        assert out.readout.shape == (1, 11)
        want = run_reference(eng, ev)
        np.testing.assert_array_equal(np.asarray(out.readout),
                                      np.asarray(want.readout))
