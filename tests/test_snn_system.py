"""System tests: the paper's SNNs — bit-exact int path, QAT training,
Pallas-kernel-backed layer equivalence, synthetic data."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.layers import im2col, quantize_layer_weights, spiking_conv, SpikingConvParams
from repro.core.network import gesture_net, init_params, optical_flow_net, run_snn
from repro.core.neuron import NeuronConfig
from repro.core.quant import QuantSpec
from repro.snn.data import make_flow_batch, make_gesture_batch
from repro.snn.train import TrainConfig, init_train_state, train_step


class TestIm2col:
    def test_matches_conv(self):
        """im2col + matmul == lax.conv (the input-loader contract, C5)."""
        rng = np.random.default_rng(0)
        x = jnp.array(rng.random((2, 8, 8, 3)).astype(np.float32))
        w = jnp.array(rng.random((3 * 3 * 3, 5)).astype(np.float32))
        cols = im2col(x, 3, 3, stride=1, padding=1)
        got = (cols @ w).reshape(2, 8, 8, 5)
        w_hwio = w.reshape(3, 3, 3, 5)
        want = jax.lax.conv_general_dilated(
            x, w_hwio, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
        )
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)

    @pytest.mark.parametrize("stride,pad", [(1, 0), (2, 1), (2, 0)])
    def test_stride_padding(self, stride, pad):
        x = jnp.ones((1, 9, 9, 2))
        cols = im2col(x, 3, 3, stride, pad)
        h_out = (9 + 2 * pad - 3) // stride + 1
        assert cols.shape == (1, h_out * h_out, 18)


class TestNetworks:
    def test_table2_shapes(self):
        g = gesture_net()
        assert g.input_hw == (64, 64) and g.timesteps == 20
        conv_layers = [l for l in g.layers if l.kind == "conv"]
        assert len(conv_layers) == 5  # Conv(2,16) + 4x Conv(16,16)
        assert g.layers[-1].c_in == 64 and g.layers[-1].c_out == 11

        f = optical_flow_net()
        assert f.input_hw == (288, 384) and f.timesteps == 10
        convs = [l for l in f.layers if l.kind == "conv"]
        assert [c.c_out for c in convs] == [32] * 7 + [2]

    def test_forward_shapes_and_finite(self):
        spec = gesture_net()
        params = init_params(jax.random.PRNGKey(0), spec)
        x = (jax.random.uniform(jax.random.PRNGKey(1), (3, 2, 64, 64, 2)) < 0.05
             ).astype(jnp.float32)
        out, _ = run_snn(params, x, spec, QuantSpec(4))
        assert out.shape == (2, 11)
        assert bool(jnp.all(jnp.isfinite(out)))

    def test_flow_net_readout(self):
        spec = optical_flow_net()
        params = init_params(jax.random.PRNGKey(0), spec)
        x = (jax.random.uniform(jax.random.PRNGKey(1), (2, 1, 288, 384, 2)) < 0.02
             ).astype(jnp.float32)
        out, _ = run_snn(params, x, spec, QuantSpec(4))
        assert out.shape == (1, 288, 384, 2)

    def test_int_mode_bit_exact_under_requant(self):
        """Integer path: quantized weights + int Vmem stay in range."""
        spec = QuantSpec(4)
        p = SpikingConvParams(3, 3, 1, 1, NeuronConfig(model="if", threshold=0.5))
        w = jax.random.normal(jax.random.PRNGKey(0), (18, 8)) * 0.3
        wq, scale = quantize_layer_weights(w, spec)
        spikes = (jax.random.uniform(jax.random.PRNGKey(1), (2, 8, 8, 2)) < 0.1
                  ).astype(jnp.float32)
        vmem = jnp.zeros((2, 8, 8, 8), jnp.int32)
        v2, s = spiking_conv(spikes, wq, vmem, p, spec, mode="int", w_scale=scale)
        assert int(v2.min()) >= spec.v_min and int(v2.max()) <= spec.v_max
        assert set(np.unique(np.asarray(s))).issubset({0.0, 1.0})


class TestTraining:
    def test_gesture_loss_decreases(self):
        spec = gesture_net()
        cfg = TrainConfig(weight_bits=4, lr=2e-3)
        state = init_train_state(jax.random.PRNGKey(0), spec, cfg)
        key = jax.random.PRNGKey(1)
        losses = []
        # fixed batch: loss must drop when overfitting a single batch
        ev, lbl = make_gesture_batch(key, batch=4, timesteps=5, hw=(64, 64))
        for _ in range(12):
            state, m = train_step(state, (ev, lbl), spec, cfg)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0], losses

    @pytest.mark.parametrize("bits", [4, 6, 8])
    def test_all_precisions_trainable(self, bits):
        spec = gesture_net()
        cfg = TrainConfig(weight_bits=bits, lr=1e-3)
        state = init_train_state(jax.random.PRNGKey(0), spec, cfg)
        ev, lbl = make_gesture_batch(jax.random.PRNGKey(2), batch=2, timesteps=3,
                                     hw=(64, 64))
        state, m = train_step(state, (ev, lbl), spec, cfg)
        assert np.isfinite(float(m["loss"]))


class TestSyntheticData:
    def test_gesture_determinism(self):
        a, la = make_gesture_batch(jax.random.PRNGKey(7), batch=2, timesteps=3, hw=(32, 32))
        b, lb = make_gesture_batch(jax.random.PRNGKey(7), batch=2, timesteps=3, hw=(32, 32))
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))

    def test_gesture_sparsity_band(self):
        ev, _ = make_gesture_batch(jax.random.PRNGKey(0), batch=4, timesteps=5, hw=(64, 64))
        sparsity = float(jnp.mean(ev == 0))
        assert 0.9 < sparsity <= 1.0  # event-camera-like

    def test_flow_groundtruth_shape(self):
        ev, flow = make_flow_batch(jax.random.PRNGKey(0), batch=2, timesteps=4, hw=(32, 48))
        assert ev.shape == (4, 2, 32, 48, 2)
        assert flow.shape == (2, 32, 48, 2)
        assert float(jnp.max(jnp.abs(flow))) <= 2.0
