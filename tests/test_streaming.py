"""Streaming stateful serving: chunked-engine exactness under any chunking,
session-manager slot lifecycle, per-slot cost attribution, and O(1)-in-T
memory of the carry-threaded accumulators."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import spidr_gesture
from repro.core.layers import SpikingConvParams, SpikingDenseParams
from repro.core.network import SNNLayer, SNNSpec, init_params
from repro.core.neuron import NeuronConfig
from repro.core.quant import QuantSpec
from repro.engine import (
    EngineConfig,
    StreamSessionManager,
    build_engine,
    init_state,
    run_chunk,
    run_engine,
)
from repro.snn.data import (
    iter_event_chunks,
    make_flow_batch,
    make_gesture_batch,
    make_gesture_chunk,
)


def _mini_spec(readout="rate", hw=(16, 16), timesteps=6):
    n = NeuronConfig(model="lif", reset="soft", threshold=0.5, leak_shift=3)
    return SNNSpec(
        name="mini", input_hw=hw, in_channels=2, timesteps=timesteps,
        layers=(
            SNNLayer("conv", 2, 8, conv=SpikingConvParams(3, 3, 1, 1, n)),
            SNNLayer("pool"),
            SNNLayer("conv", 8, 8, conv=SpikingConvParams(3, 3, 1, 1, n)),
            SNNLayer("adaptive_pool", target_hw=2),
            SNNLayer("fc", 32, 5, fc=SpikingDenseParams(n)),
        ),
        readout=readout,
    )


def _engine(spec, seed=0, **over):
    params = init_params(jax.random.PRNGKey(seed), spec)
    cfg = EngineConfig(QuantSpec(over.pop("bits", 4)), interpret=True,
                       block=(64, 64, 64), **over)
    return build_engine(spec, params, cfg)


def _events(spec, batch, seed=0, sparsity=0.9):
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        (rng.random((spec.timesteps, batch) + spec.input_hw + (2,)) > sparsity)
        .astype(np.float32))


def _run_chunked(engine, events, bounds):
    """Drive run_chunk over the chunking given by ``bounds`` offsets."""
    state = init_state(engine, events.shape[1])
    out = None
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        state, out = run_chunk(engine, state, events[lo:hi])
    return state, out


class TestChunkedEngine:
    @pytest.mark.parametrize("backend", ["fused", "jnp"])
    @pytest.mark.parametrize("chunk_T", [1, 3, 6])
    def test_any_chunking_matches_whole_stream(self, backend, chunk_T):
        """Acceptance: chunk_T in {1, 3, T} bit-equals one run_engine call."""
        spec = _mini_spec()
        eng = _engine(spec, backend=backend)
        ev = _events(spec, batch=2)
        whole = run_engine(eng, ev)
        bounds = list(range(0, spec.timesteps + 1, chunk_T))
        state, out = _run_chunked(eng, ev, bounds)
        np.testing.assert_array_equal(np.asarray(out.readout),
                                      np.asarray(whole.readout))
        np.testing.assert_array_equal(
            np.asarray(state.in_counts).sum(axis=1),
            np.asarray(whole.input_counts).sum(axis=0))
        np.testing.assert_array_equal(
            np.asarray(state.out_counts).sum(axis=1),
            np.asarray(whole.spike_counts).sum(axis=0))

    def test_uneven_chunking_matches_whole_stream(self):
        spec = _mini_spec()
        eng = _engine(spec, backend="jnp")
        ev = _events(spec, batch=2, seed=1)
        whole = run_engine(eng, ev)
        _, out = _run_chunked(eng, ev, [0, 1, 5, 6])
        np.testing.assert_array_equal(np.asarray(out.readout),
                                      np.asarray(whole.readout))

    def test_vmem_readout_chunked(self):
        """Vmem (flow-style) readout also carries exactly across chunks."""
        spec = _mini_spec(readout="vmem")
        spec = SNNSpec(name="mini_vmem", input_hw=spec.input_hw, in_channels=2,
                       timesteps=spec.timesteps, layers=spec.layers[:3],
                       readout="vmem")
        eng = _engine(spec, backend="jnp")
        ev = _events(spec, batch=2, seed=2)
        whole = run_engine(eng, ev)
        _, out = _run_chunked(eng, ev, [0, 2, 4, 6])
        np.testing.assert_array_equal(np.asarray(out.readout),
                                      np.asarray(whole.readout))

    def test_per_slot_counts_sum_to_batch_counts(self):
        spec = _mini_spec()
        eng = _engine(spec, backend="jnp")
        ev = _events(spec, batch=3, seed=3, sparsity=0.8)
        state = init_state(eng, 3)
        _, out = run_chunk(eng, state, ev)
        np.testing.assert_array_equal(
            np.asarray(out.slot_input_counts).sum(axis=2),
            np.asarray(out.input_counts))
        # Per-slot counts equal each sample's solo run (slots independent).
        for b in range(3):
            solo = run_engine(eng, ev[:, b:b + 1])
            np.testing.assert_array_equal(
                np.asarray(out.slot_input_counts)[:, :, b],
                np.asarray(solo.input_counts))

    def test_chunk_readout_snapshots(self):
        """collect_readouts exposes the cumulative readout at every step."""
        spec = _mini_spec()
        eng = _engine(spec, backend="jnp")
        ev = _events(spec, batch=1, seed=4)
        state = init_state(eng, 1)
        _, out = run_chunk(eng, state, ev, collect_readouts=True)
        for t in (1, 3, 6):
            part = run_engine(eng, ev[:t])
            np.testing.assert_array_equal(np.asarray(out.readouts)[t - 1],
                                          np.asarray(part.readout))

    def test_long_stream_memory_o1_T512(self):
        """T=512 reduced-config smoke: accumulators live in the scan carry
        (collect_counts=False materializes nothing per-timestep), and the
        chunked path still bit-matches the whole-stream engine."""
        n = NeuronConfig(model="lif", reset="soft", threshold=0.5, leak_shift=3)
        spec = SNNSpec(
            name="long", input_hw=(16, 16), in_channels=2, timesteps=512,
            layers=(SNNLayer("conv", 2, 4,
                             conv=SpikingConvParams(3, 3, 1, 1, n)),),
            readout="vmem",
        )
        eng = _engine(spec, backend="jnp")
        rng = np.random.default_rng(5)
        ev = jnp.asarray((rng.random((512, 1, 16, 16, 2)) > 0.97)
                         .astype(np.float32))
        whole = run_engine(eng, ev)
        state = init_state(eng, 1)
        for t0 in range(0, 512, 128):
            state, _ = run_chunk(eng, state, ev[t0:t0 + 128],
                                 collect_counts=False)
        np.testing.assert_array_equal(np.asarray(state.readout_acc),
                                      np.asarray(whole.readout))
        np.testing.assert_array_equal(
            np.asarray(state.in_counts).sum(axis=1),
            np.asarray(whole.input_counts).sum(axis=0))


class TestSessionManager:
    def test_sessions_bit_exact_vs_whole_stream(self):
        """Streams multiplexed through the session manager == solo runs."""
        spec = _mini_spec()
        eng = _engine(spec, backend="jnp")
        ev = _events(spec, batch=2, seed=6, sparsity=0.85)
        whole = run_engine(eng, ev)
        mgr = StreamSessionManager(eng, capacity=4, chunk_T=2)
        s0, s1 = mgr.open(), mgr.open()
        ev_np = np.asarray(ev)
        last = {}
        for t0 in range(0, spec.timesteps, 2):
            last = mgr.step({s0: ev_np[t0:t0 + 2, 0], s1: ev_np[t0:t0 + 2, 1]})
        np.testing.assert_array_equal(last[s0].readout,
                                      np.asarray(whole.readout)[0])
        np.testing.assert_array_equal(last[s1].readout,
                                      np.asarray(whole.readout)[1])

    def test_sessions_bit_exact_fused_backend(self):
        """The acceptance bar holds on the Pallas (interpret) backend too."""
        spec = _mini_spec(timesteps=2)
        eng = _engine(spec, backend="fused")
        ev = _events(spec, batch=1, seed=7, sparsity=0.9)
        whole = run_engine(eng, ev)
        mgr = StreamSessionManager(eng, capacity=2, chunk_T=1)
        s0 = mgr.open()
        ev_np = np.asarray(ev)
        for t0 in range(spec.timesteps):
            last = mgr.step({s0: ev_np[t0:t0 + 1, 0]})
        np.testing.assert_array_equal(last[s0].readout,
                                      np.asarray(whole.readout)[0])

    def test_slot_retirement_and_reuse_preserve_unrelated_slots(self):
        """Closing a slot and admitting a new stream into it must not
        perturb the state of streams living in other slots."""
        spec = _mini_spec()
        eng = _engine(spec, backend="jnp")
        ev = _events(spec, batch=3, seed=8, sparsity=0.85)
        whole = run_engine(eng, ev)
        ev_np = np.asarray(ev)
        mgr = StreamSessionManager(eng, capacity=2, chunk_T=2)
        sa, sb = mgr.open(), mgr.open()          # stream 0, stream 1
        mgr.step({sa: ev_np[0:2, 0], sb: ev_np[0:2, 1]})
        # Stream 0 aborts; its slot is retired and immediately reused by
        # stream 2, which starts from t=0 while stream 1 is mid-flight.
        mgr.close(sa)
        sc = mgr.open()
        assert sc == sa, "retired slot must be reusable"
        up = mgr.step({sc: ev_np[0:2, 2], sb: ev_np[2:4, 1]})
        assert up[sc].timesteps == 2 and up[sb].timesteps == 4
        last = mgr.step({sc: ev_np[2:4, 2], sb: ev_np[4:6, 1]})
        # Stream 1 ran to completion across the churn: bit-exact.
        np.testing.assert_array_equal(last[sb].readout,
                                      np.asarray(whole.readout)[1])
        mgr.close(sb)   # done at t=6; enforcement requires closing it
        # Stream 2, finishing its remaining timesteps, is bit-exact too.
        final = mgr.step({sc: ev_np[4:6, 2]})
        np.testing.assert_array_equal(final[sc].readout,
                                      np.asarray(whole.readout)[2])

    def test_masked_slots_zero_counts_and_zero_energy(self):
        """Slots without a live stream contribute no spikes and are never
        charged: their cumulative energy/cycles stay exactly zero."""
        spec = _mini_spec()
        eng = _engine(spec, backend="jnp")
        ev = _events(spec, batch=1, seed=9, sparsity=0.8)
        ev_np = np.asarray(ev)
        mgr = StreamSessionManager(eng, capacity=4, chunk_T=2)
        s0 = mgr.open()
        up = {}
        for t0 in range(0, spec.timesteps, 2):
            up = mgr.step({s0: ev_np[t0:t0 + 2, 0]})
        # The live slot accrued cost; the three idle slots accrued none.
        assert up[s0].energy_uj > 0 and up[s0].cycles > 0
        idle = [i for i in range(4) if i != s0]
        assert all(mgr.slot_energy_uj[i] == 0 for i in idle)
        assert all(mgr.slot_cycles[i] == 0 for i in idle)
        # And their state never saw a spike: per-slot counts are all zero.
        in_counts = np.asarray(mgr.state.in_counts)
        out_counts = np.asarray(mgr.state.out_counts)
        assert (in_counts[:, idle] == 0).all()
        assert (out_counts[:, idle] == 0).all()
        assert (in_counts[:, s0] > 0).any()

    def test_short_final_chunk_snapshots_true_end(self):
        """A stream whose length is not a chunk_T multiple reads out at its
        true last timestep — the zero-padded tail never leaks in."""
        spec = _mini_spec(timesteps=5)
        eng = _engine(spec, backend="jnp")
        ev = _events(spec, batch=1, seed=10, sparsity=0.85)
        whole = run_engine(eng, ev)
        ev_np = np.asarray(ev)
        mgr = StreamSessionManager(eng, capacity=2, chunk_T=3)
        s0 = mgr.open()
        mgr.step({s0: ev_np[0:3, 0]})
        last = mgr.step({s0: ev_np[3:5, 0]})     # 2 of 3 timesteps valid
        assert last[s0].timesteps == 5
        np.testing.assert_array_equal(last[s0].readout,
                                      np.asarray(whole.readout)[0])

    def test_cumulative_cycles_chunking_invariant(self):
        """Per-stream cycle accounting resumes the async-handshake clocks,
        so the cumulative makespan equals a whole-stream estimate whatever
        chunk_T the serving layer happens to use."""
        from repro.engine import estimate_cost

        spec = _mini_spec()
        eng = _engine(spec, backend="jnp")
        ev = _events(spec, batch=1, seed=12, sparsity=0.85)
        whole = run_engine(eng, ev)
        want = estimate_cost(spec, QuantSpec(4),
                             np.asarray(whole.input_counts))
        ev_np = np.asarray(ev)
        for chunk_T in (1, 2, 3, 6):
            mgr = StreamSessionManager(eng, capacity=2, chunk_T=chunk_T)
            s0 = mgr.open()
            up = {}
            for t0 in range(0, spec.timesteps, chunk_T):
                up = mgr.step({s0: ev_np[t0:t0 + chunk_T, 0]})
            assert up[s0].cycles == want.makespan_cycles, chunk_T

    def test_open_returns_none_when_full(self):
        spec = _mini_spec()
        eng = _engine(spec, backend="jnp")
        mgr = StreamSessionManager(eng, capacity=2, chunk_T=1)
        assert mgr.open() is not None and mgr.open() is not None
        assert mgr.open() is None
        assert mgr.occupancy == 2

    def test_contract_violations_raise_instead_of_corrupting(self):
        """An open slot idling through a tick, or continuing after a short
        (final) chunk, would silently diverge from the whole-stream result
        — both are rejected up front."""
        spec = _mini_spec()
        eng = _engine(spec, backend="jnp")
        ev = np.asarray(_events(spec, batch=1, seed=11))
        mgr = StreamSessionManager(eng, capacity=2, chunk_T=2)
        s0, s1 = mgr.open(), mgr.open()
        # s1 delivers nothing while open: refused.
        with pytest.raises(AssertionError, match="delivered no chunk"):
            mgr.step({s0: ev[0:2, 0]})
        mgr.close(s1)
        mgr.step({s0: ev[0:2, 0]})
        # A short chunk ends the stream; delivering more is refused.
        mgr.step({s0: ev[2:3, 0]})
        with pytest.raises(AssertionError, match="short"):
            mgr.step({s0: ev[3:5, 0]})
        mgr.close(s0)   # the sanctioned path out
        assert mgr.occupancy == 0


class TestPipelineResume:
    def test_resumed_simulation_matches_whole_stream(self):
        """Chunked pipeline pricing with carried state reproduces every
        whole-stream quantity (makespan, sync baseline, busy counters, and
        the derived speedup/utilization), for an uneven chunking."""
        from repro.core.pipeline import simulate_pipeline

        rng = np.random.default_rng(0)
        cc = rng.integers(100, 900, (12, 9))
        whole = simulate_pipeline(cc)
        st, res = None, None
        for lo, hi in ((0, 1), (1, 5), (5, 12)):
            res = simulate_pipeline(cc[lo:hi], state=st)
            st = res.state
        assert res.makespan == whole.makespan
        assert res.sync_makespan == whole.sync_makespan
        np.testing.assert_array_equal(res.cm_busy, whole.cm_busy)
        assert res.nu_busy == whole.nu_busy
        assert res.speedup_vs_sync == whole.speedup_vs_sync
        np.testing.assert_array_equal(res.cm_utilization,
                                      whole.cm_utilization)


class TestStreamingServer:
    def test_serves_more_streams_than_capacity_bit_exact(self):
        from repro import spidr
        from repro.serving import StreamRequest, StreamWorker

        spec = spidr_gesture.reduced(hw=(16, 16), timesteps=6)
        params = init_params(jax.random.PRNGKey(0), spec)
        # The server consumes the deployment facade; the whole-stream
        # reference stays on the hand-built legacy engine (same integers).
        eng = build_engine(spec, params,
                           EngineConfig(QuantSpec(4), backend="jnp"))
        compiled = spidr.compile(spec, params,
                                 spidr.DeployTarget(backend="jnp"))
        ev, _ = make_gesture_batch(jax.random.PRNGKey(1), batch=5,
                                   timesteps=6, hw=(16, 16))
        whole = run_engine(eng, ev)
        server = StreamWorker(compiled, capacity=2, chunk_T=2)
        for r in range(5):
            server.submit(StreamRequest(rid=r, events=np.asarray(ev[:, r])))
        ticks = 0
        while server.step():
            ticks += 1
            assert ticks < 100, "server did not drain"
        assert len(server.done) == 5
        assert not server.slots and server.sessions.occupancy == 0
        for req in server.done:
            np.testing.assert_array_equal(
                np.asarray(req.readout), np.asarray(whole.readout)[req.rid])
            assert req.cycles > 0 and req.energy_uj > 0
            assert req.first_reply_at is not None
            assert req.done_at >= req.first_reply_at


class TestChunkedData:
    def test_gesture_chunks_concat_to_whole_batch(self):
        k = jax.random.PRNGKey(2)
        whole, labels = make_gesture_batch(k, batch=2, timesteps=7,
                                           hw=(16, 16))
        cat = jnp.concatenate(
            list(iter_event_chunks(k, 7, 3, batch=2, hw=(16, 16))))
        np.testing.assert_array_equal(np.asarray(cat), np.asarray(whole))
        ch, lbl = make_gesture_chunk(k, 4, batch=2, chunk_T=2, hw=(16, 16))
        np.testing.assert_array_equal(np.asarray(ch),
                                      np.asarray(whole)[4:6])
        np.testing.assert_array_equal(np.asarray(lbl), np.asarray(labels))

    def test_flow_chunks_concat_to_whole_batch(self):
        k = jax.random.PRNGKey(3)
        whole, _ = make_flow_batch(k, batch=2, timesteps=5, hw=(16, 16))
        cat = jnp.concatenate(
            list(iter_event_chunks(k, 5, 2, batch=2, hw=(16, 16),
                                   kind="flow")))
        np.testing.assert_array_equal(np.asarray(cat), np.asarray(whole))

    def test_generator_feeds_session_bit_exact(self):
        """A sensor-style chunked feed through a session == whole stream."""
        spec = _mini_spec()
        eng = _engine(spec, backend="jnp")
        k = jax.random.PRNGKey(4)
        whole, _ = make_gesture_batch(k, batch=1, timesteps=6, hw=(16, 16))
        ref = run_engine(eng, whole)
        mgr = StreamSessionManager(eng, capacity=2, chunk_T=2)
        s0 = mgr.open()
        last = {}
        for chunk in iter_event_chunks(k, 6, 2, batch=1, hw=(16, 16)):
            last = mgr.step({s0: np.asarray(chunk)[:, 0]})
        np.testing.assert_array_equal(last[s0].readout,
                                      np.asarray(ref.readout)[0])
