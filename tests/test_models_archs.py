"""Per-arch smoke tests (assignment requirement): every assigned arch at a
REDUCED same-family config — one forward/train step on CPU, output shapes +
no NaNs; plus chunked-vs-recurrent equivalence for the stateful families
and dense prefill/decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config, input_specs, list_archs
from repro.models.model import (
    init_opt_state,
    init_params,
    make_decode_step,
    make_prefill_step,
    make_train_step,
    forward,
)
from repro.models.transformer import init_decode_state

ARCHS = list_archs()
KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=32):
    if cfg.embed_inputs:
        return {
            "tokens": jax.random.randint(KEY, (b, s), 0, cfg.vocab_size),
            "labels": jax.random.randint(KEY, (b, s), 0, cfg.vocab_size),
        }
    return {
        "embeds": jax.random.normal(KEY, (b, s, cfg.d_model), jnp.bfloat16),
        "labels": jax.random.randint(KEY, (b, s), 0, cfg.vocab_size),
    }


@pytest.mark.parametrize("arch", ARCHS)
class TestArchSmoke:
    def test_train_step(self, arch):
        cfg = get_config(arch).reduced()
        params = init_params(KEY, cfg)
        ts = make_train_step(cfg)
        p2, o2, m = ts(params, init_opt_state(params), 0, _batch(cfg))
        assert np.isfinite(float(m["loss"])), arch
        # params actually updated
        leaf0 = jax.tree.leaves(params)[0]
        leaf1 = jax.tree.leaves(p2)[0]
        assert not np.allclose(np.asarray(leaf0), np.asarray(leaf1))

    def test_prefill_and_decode(self, arch):
        cfg = get_config(arch).reduced()
        params = init_params(KEY, cfg)
        b, s = 2, 32
        batch = {k: v for k, v in _batch(cfg, b, s).items() if k != "labels"}
        logits, cache = make_prefill_step(cfg)(params, batch)
        assert logits.shape == (b, cfg.padded_vocab)
        assert np.isfinite(np.asarray(logits, np.float32)).all(), arch

        ds = make_decode_step(cfg)
        dc = init_decode_state(cfg, b, s)
        db = ({"tokens": jnp.zeros((b, 1), jnp.int32)} if cfg.embed_inputs
              else {"embeds": jnp.zeros((b, 1, cfg.d_model), jnp.bfloat16)})
        dl, dc2 = ds(params, dc, db)
        assert dl.shape == (b, cfg.padded_vocab)
        assert np.isfinite(np.asarray(dl, np.float32)).all(), arch
        assert int(dc2["len"]) == 1


@pytest.mark.parametrize("arch", ["rwkv6-7b", "zamba2-7b"])
def test_chunked_vs_recurrent_equivalence(arch):
    """Train-time chunked scan == token-by-token recurrence (independent
    implementations of the same math)."""
    cfg = get_config(arch).reduced()
    params = init_params(jax.random.PRNGKey(3), cfg)
    b, s = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(4), (b, s), 0, cfg.vocab_size)
    logits_full, _, _ = forward(params, cfg, tokens=tokens)
    ds = make_decode_step(cfg)
    cache = init_decode_state(cfg, b, s, dtype=jnp.float32)
    outs = []
    for t in range(s):
        lg, cache = ds(params, cache, {"tokens": tokens[:, t : t + 1]})
        outs.append(lg)
    logits_dec = jnp.stack(outs, axis=1)
    a = np.asarray(logits_full, np.float32)
    bb = np.asarray(logits_dec, np.float32)
    rel = np.max(np.abs(a - bb)) / (np.max(np.abs(a)) + 1e-9)
    assert rel < 0.05, (arch, rel)


def test_dense_prefill_decode_consistency():
    """Decoding one token after prefill == forward over seq+1 (dense attn)."""
    cfg = get_config("qwen1.5-0.5b").reduced()
    params = init_params(jax.random.PRNGKey(5), cfg)
    b, s = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(6), (b, s + 1), 0, cfg.vocab_size)
    # full forward over s+1 tokens
    logits_full, _, _ = forward(params, cfg, tokens=toks)
    last_full = np.asarray(logits_full[:, -1, :], np.float32)
    # prefill s tokens, then decode token s
    _, cache = make_prefill_step(cfg)(params, {"tokens": toks[:, :s]})
    cache = dict(cache)
    cache["len"] = jnp.asarray(s, jnp.int32)
    # pad cache seq dim to s+1 capacity
    cache["k"] = jnp.pad(cache["k"], ((0, 0), (0, 0), (0, 0), (0, 1), (0, 0)))
    cache["v"] = jnp.pad(cache["v"], ((0, 0), (0, 0), (0, 0), (0, 1), (0, 0)))
    dl, _ = make_decode_step(cfg)(params, cache, {"tokens": toks[:, s : s + 1]})
    rel = np.max(np.abs(np.asarray(dl, np.float32) - last_full)) / (
        np.max(np.abs(last_full)) + 1e-9
    )
    assert rel < 0.05, rel


def test_param_counts_sane():
    """Analytic param counts in the right ballpark for named sizes."""
    expected = {
        "qwen1.5-0.5b": (0.3e9, 0.8e9),
        "starcoder2-3b": (2.5e9, 3.5e9),
        "qwen3-14b": (12e9, 16e9),
        "stablelm-3b": (2e9, 3.5e9),
        "rwkv6-7b": (6e9, 9e9),
        # assignment specifies 48L x 64e x d_ff 1408 (the HF Moonlight-16B
        # has 27L; the explicit assigned numbers give ~28B and we follow them)
        "moonshot-v1-16b-a3b": (24e9, 32e9),
        "chameleon-34b": (30e9, 37e9),
        "zamba2-7b": (5e9, 9e9),
    }
    for arch, (lo, hi) in expected.items():
        n = get_config(arch).param_count()
        assert lo < n < hi, (arch, n)


def test_moe_active_params():
    cfg = get_config("moonshot-v1-16b-a3b")
    assert cfg.active_param_count() < 0.35 * cfg.param_count()


def test_long_context_skip_rules():
    long = SHAPES["long_500k"]
    for arch in ARCHS:
        cfg = get_config(arch)
        if arch in ("rwkv6-7b", "zamba2-7b"):
            assert cfg.supports(long), arch
        else:
            assert not cfg.supports(long), arch
            assert cfg.skip_reason(long)


def test_input_specs_are_abstract():
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            specs = input_specs(cfg, shape)
            for v in specs.values():
                assert isinstance(v, jax.ShapeDtypeStruct)
