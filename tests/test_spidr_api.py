"""The unified deployment facade: validation, parity with the legacy
call chains, and the save/load round trip.

The contract under test (ISSUE 5): ``spidr.compile``/``spidr.load`` are
the only way consumers construct deployments, and everything they produce
is bit-identical to hand-wiring the internals — ``build_engine`` /
``snn.export.deploy`` -> ``compile_network`` -> ``compile_engine`` ->
``init_state``/``run_chunk`` / ``StreamSessionManager`` — at every
supported precision pair, on 1 and 4 cores, for both paper networks.
"""
import numpy as np
import jax
import pytest

from repro import spidr
from repro.configs import spidr_gesture, spidr_optflow
from repro.core.network import init_params
from repro.core.quant import QuantSpec
from repro.engine import (
    EngineConfig,
    StreamSessionManager,
    build_engine,
    compile_engine,
    estimate_cost,
    estimate_multicore_cost,
    init_state,
    run_chunk,
)
from repro.compiler import compile_network
from repro.snn.export import (
    deploy,
    export_network,
    load_exported,
    save_exported,
)

BITS = (4, 6, 8)
CORES = (1, 4)


def _spec(task):
    if task == "gesture":
        return spidr_gesture.reduced(hw=(16, 16), timesteps=4)
    return spidr_optflow.reduced(hw=(8, 8), timesteps=4)


def _events(spec, batch=2, seed=0, sparsity=0.9):
    rng = np.random.default_rng(seed)
    return (rng.random((spec.timesteps, batch) + spec.input_hw + (2,))
            > sparsity).astype(np.float32)


def _legacy_engine(spec, params, bits, n_cores):
    """The pre-facade build chain, hand-wired."""
    qspec = QuantSpec(bits)
    engine = build_engine(spec, params, EngineConfig(qspec, backend="jnp"))
    if n_cores > 1:
        schedule = compile_network(spec, n_cores=n_cores, qspec=qspec)
        engine = compile_engine(engine, schedule)
    return engine


def _legacy_run_chunked(engine, events, chunk=2):
    """init_state + run_chunk over ``chunk``-sized pieces (legacy path)."""
    state = init_state(engine, events.shape[1])
    outs, counts = None, []
    for lo in range(0, events.shape[0], chunk):
        state, outs = run_chunk(engine, state, events[lo:lo + chunk])
        counts.append(np.asarray(outs.input_counts))
    return np.asarray(outs.readout), np.concatenate(counts, axis=0)


# ---------------------------------------------------------------------------
# DeployTarget validation: actionable messages, never bare asserts.
# ---------------------------------------------------------------------------
class TestDeployTargetValidation:
    def test_defaults_derive_vmem_bits(self):
        t = spidr.DeployTarget()
        assert (t.weight_bits, t.vmem_bits) == (4, 7)
        assert t.qspec == QuantSpec(4)
        for bits, vmem in spidr.PRECISION_PAIRS:
            assert spidr.DeployTarget(weight_bits=bits).vmem_bits == vmem

    def test_unsupported_pair_names_nearest(self):
        with pytest.raises(ValueError) as e:
            spidr.DeployTarget(weight_bits=5, vmem_bits=9)
        assert "(5, 9) unsupported" in str(e.value)
        assert "nearest supported: (4, 7), (6, 11)" in str(e.value)

    def test_unsupported_weight_bits_names_nearest(self):
        with pytest.raises(ValueError) as e:
            spidr.DeployTarget(weight_bits=3)
        assert "(3, 5) unsupported" in str(e.value)
        assert "(4, 7)" in str(e.value)

    def test_mismatched_vmem_bits_names_the_invariant_pair(self):
        with pytest.raises(ValueError) as e:
            spidr.DeployTarget(weight_bits=4, vmem_bits=8)
        assert "(4, 8) unsupported" in str(e.value)
        assert "(4, 7)" in str(e.value)

    def test_unknown_backend_lists_supported(self):
        with pytest.raises(ValueError) as e:
            spidr.DeployTarget(backend="pallas")
        assert "'pallas' unsupported" in str(e.value)
        assert "fused, jnp, reference" in str(e.value)

    @pytest.mark.parametrize("field", ["n_cores", "chunk_T",
                                       "stream_capacity"])
    def test_counts_need_positive_integers(self, field):
        with pytest.raises(ValueError) as e:
            spidr.DeployTarget(**{field: 0})
        assert f"{field}=0 unsupported" in str(e.value)
        assert "integer >= 1" in str(e.value)

    def test_force_mode_names_the_modes(self):
        with pytest.raises(ValueError) as e:
            spidr.DeployTarget(force_mode=3)
        assert "force_mode=3 unsupported" in str(e.value)
        assert "modes 1" in str(e.value) and "2" in str(e.value)

    def test_stationarity_names_the_choices(self):
        with pytest.raises(ValueError) as e:
            spidr.DeployTarget(stationarity="input")
        assert "'input' unsupported" in str(e.value)
        assert "'weight'" in str(e.value) and "'vmem'" in str(e.value)

    def test_assumed_sparsity_range(self):
        with pytest.raises(ValueError) as e:
            spidr.DeployTarget(assumed_sparsity=1.5)
        assert "assumed_sparsity=1.5 unsupported" in str(e.value)
        assert "0.0 <= s < 1.0" in str(e.value)


# ---------------------------------------------------------------------------
# compile()/run()/cost() input validation.
# ---------------------------------------------------------------------------
class TestCompileValidation:
    def test_spec_without_params(self):
        with pytest.raises(ValueError, match="needs its float params"):
            spidr.compile(_spec("gesture"))

    def test_exported_without_spec(self):
        spec = _spec("gesture")
        exported = export_network(
            init_params(jax.random.PRNGKey(0), spec), spec, QuantSpec(4))
        with pytest.raises(ValueError, match="needs its SNNSpec"):
            spidr.compile(exported)

    def test_exported_precision_mismatch(self):
        spec = _spec("gesture")
        exported = export_network(
            init_params(jax.random.PRNGKey(0), spec), spec, QuantSpec(6))
        with pytest.raises(ValueError, match="exported at 6-bit"):
            spidr.compile(exported, spec, spidr.DeployTarget(weight_bits=4))

    def test_garbage_network_type(self):
        with pytest.raises(TypeError, match="SNNSpec or an ExportedNetwork"):
            spidr.compile(object())

    def test_run_requires_batch_axis(self):
        spec = _spec("gesture")
        c = spidr.compile(spec, init_params(jax.random.PRNGKey(0), spec))
        with pytest.raises(ValueError, match=r"events\[:, None\]"):
            c.run(_events(spec)[:, 0])

    def test_cost_without_counts(self):
        spec = _spec("gesture")
        c = spidr.compile(spec, init_params(jax.random.PRNGKey(0), spec))
        with pytest.raises(ValueError, match="spike statistics"):
            c.cost()

    def test_save_needs_exported_weights(self, tmp_path):
        spec = _spec("gesture")
        c = spidr.compile(spec, init_params(jax.random.PRNGKey(0), spec))
        with pytest.raises(ValueError, match="per-tensor scales"):
            c.save(tmp_path / "ckpt")


# ---------------------------------------------------------------------------
# The parity matrix: facade == legacy chains, gesture + flow, all three
# precision pairs, 1 and 4 cores, whole-tensor AND streaming.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("task", ["gesture", "flow"])
@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("n_cores", CORES)
class TestFacadeLegacyParity:
    def test_run_and_stream_bit_match_legacy(self, task, bits, n_cores):
        spec = _spec(task)
        params = init_params(jax.random.PRNGKey(0), spec)
        ev = _events(spec, batch=2)

        legacy = _legacy_engine(spec, params, bits, n_cores)
        want_readout, want_counts = _legacy_run_chunked(legacy, ev)

        compiled = spidr.compile(
            spec, params,
            spidr.DeployTarget(weight_bits=bits, n_cores=n_cores,
                               backend="jnp"))
        out = compiled.run(ev)
        np.testing.assert_array_equal(np.asarray(out.readout), want_readout)
        np.testing.assert_array_equal(np.asarray(out.input_counts),
                                      want_counts)

        # Streaming: the facade session vs the raw manager, same two
        # streams delivered in the same chunks, slot for slot.
        mgr = StreamSessionManager(legacy, capacity=2, chunk_T=2)
        session = compiled.open_stream(capacity=2, chunk_T=2)
        slots_legacy = [mgr.open(), mgr.open()]
        slots_facade = [session.open(), session.open()]
        assert slots_legacy == slots_facade
        for lo in range(0, spec.timesteps, 2):
            chunks = {s: ev[lo:lo + 2, i]
                      for i, s in enumerate(slots_legacy)}
            want = mgr.step(chunks)
            got = session.step({s: ev[lo:lo + 2, i]
                                for i, s in enumerate(slots_facade)})
            for s in slots_legacy:
                np.testing.assert_array_equal(got[s].readout,
                                              want[s].readout)
                assert got[s].cycles == want[s].cycles
                assert got[s].energy_uj == want[s].energy_uj
                assert got[s].chunk_spikes == want[s].chunk_spikes
        # And the streamed readout equals the whole-tensor facade run.
        np.testing.assert_array_equal(got[slots_facade[0]].readout,
                                      np.asarray(out.readout)[0])


@pytest.mark.parametrize("bits", BITS)
class TestExportedParity:
    """compile(exported, ...) == legacy snn.export.deploy, and save/load
    round-trips through the existing export checkpoint format."""

    def test_exported_run_matches_legacy_deploy(self, bits):
        spec = _spec("gesture")
        params = init_params(jax.random.PRNGKey(1), spec)
        exported = export_network(params, spec, QuantSpec(bits))
        ev = _events(spec, batch=2, seed=1)
        for n_cores in CORES:
            legacy = deploy(exported, spec, n_cores=n_cores)
            want_readout, want_counts = _legacy_run_chunked(legacy, ev)
            compiled = spidr.compile(
                exported, spec,
                spidr.DeployTarget(weight_bits=bits, n_cores=n_cores))
            out = compiled.run(ev)
            np.testing.assert_array_equal(np.asarray(out.readout),
                                          want_readout)
            np.testing.assert_array_equal(np.asarray(out.input_counts),
                                          want_counts)

    def test_save_load_roundtrip(self, bits, tmp_path):
        spec = _spec("gesture")
        params = init_params(jax.random.PRNGKey(1), spec)
        exported = export_network(params, spec, QuantSpec(bits))
        ev = _events(spec, batch=2, seed=1)

        compiled = spidr.compile(exported, spec,
                                 spidr.DeployTarget(weight_bits=bits))
        compiled.save(tmp_path / "ckpt", step=7)

        # The artifact is the standard snn.export checkpoint: the legacy
        # loader reads what the facade saved...
        from repro.checkpoint.checkpoint import Checkpointer

        legacy_loaded = load_exported(Checkpointer(str(tmp_path / "ckpt")),
                                      spec, step=7)
        assert legacy_loaded.weight_bits == bits
        for ex, lx in zip(exported.layers, legacy_loaded.layers):
            if ex is None:
                assert lx is None
                continue
            np.testing.assert_array_equal(ex.w_q, lx.w_q)
            np.testing.assert_array_equal(ex.thr_int, lx.thr_int)

        # ...and the facade loads what the legacy saver wrote.
        save_exported(Checkpointer(str(tmp_path / "legacy")), 3, exported)
        reloaded = spidr.load(tmp_path / "legacy", spec=spec)
        assert reloaded.target.weight_bits == bits
        out = compiled.run(ev)
        out2 = reloaded.run(ev)
        np.testing.assert_array_equal(np.asarray(out.readout),
                                      np.asarray(out2.readout))

    def test_load_without_spec_restores_saved_geometry(self, bits, tmp_path):
        """save() records input_hw/timesteps, so load() without a spec
        rebuilds the reduced-geometry deployment instead of defaulting to
        the paper network's full-size frames (which would crash run())."""
        spec = _spec("gesture")   # reduced: (16, 16) x 4 timesteps
        params = init_params(jax.random.PRNGKey(1), spec)
        exported = export_network(params, spec, QuantSpec(bits))
        saved = spidr.compile(exported, spec,
                              spidr.DeployTarget(weight_bits=bits))
        saved.save(tmp_path / "ckpt")

        reloaded = spidr.load(tmp_path / "ckpt")
        assert reloaded.spec.input_hw == spec.input_hw
        assert reloaded.spec.timesteps == spec.timesteps
        ev = _events(spec, batch=2, seed=1)
        np.testing.assert_array_equal(np.asarray(saved.run(ev).readout),
                                      np.asarray(reloaded.run(ev).readout))

    def test_load_onto_multicore_target(self, bits, tmp_path):
        spec = _spec("gesture")
        params = init_params(jax.random.PRNGKey(1), spec)
        exported = export_network(params, spec, QuantSpec(bits))
        spidr.compile(exported, spec,
                      spidr.DeployTarget(weight_bits=bits)).save(
            tmp_path / "ckpt")
        ev = _events(spec, batch=2, seed=1)
        c1 = spidr.load(tmp_path / "ckpt", spec=spec)
        c4 = spidr.load(tmp_path / "ckpt", spec=spec,
                        target=spidr.DeployTarget(weight_bits=bits,
                                                  n_cores=4))
        assert c4.schedule is not None and c4.schedule.n_cores == 4
        np.testing.assert_array_equal(np.asarray(c1.run(ev).readout),
                                      np.asarray(c4.run(ev).readout))


class TestLifecycle:
    def test_cost_matches_internal_models(self):
        spec = _spec("gesture")
        params = init_params(jax.random.PRNGKey(0), spec)
        ev = _events(spec)
        c1 = spidr.compile(spec, params, spidr.DeployTarget(backend="jnp"))
        out = c1.run(ev)
        counts = np.asarray(out.input_counts)
        got = c1.cost(out)
        want = estimate_cost(spec, QuantSpec(4), counts)
        assert got.makespan_cycles == want.makespan_cycles
        assert got.energy_uj == want.energy_uj

        c4 = spidr.compile(spec, params,
                           spidr.DeployTarget(backend="jnp", n_cores=4))
        got4 = c4.cost(input_counts=counts)
        want4 = estimate_multicore_cost(spec, c4.schedule, counts)
        assert got4.makespan_cycles == want4.makespan_cycles
        np.testing.assert_array_equal(got4.busy_cycles, want4.busy_cycles)

    def test_reference_backend_matches_jnp(self):
        spec = _spec("gesture")
        params = init_params(jax.random.PRNGKey(0), spec)
        ev = _events(spec)
        jnp_out = spidr.compile(spec, params,
                                spidr.DeployTarget(backend="jnp")).run(ev)
        ref_out = spidr.compile(
            spec, params, spidr.DeployTarget(backend="reference")).run(ev)
        np.testing.assert_array_equal(np.asarray(jnp_out.readout),
                                      np.asarray(ref_out.readout))
        np.testing.assert_array_equal(np.asarray(jnp_out.spike_counts),
                                      np.asarray(ref_out.spike_counts))

    def test_verify_proves_the_roundtrip(self):
        spec = _spec("gesture")
        params = init_params(jax.random.PRNGKey(0), spec)
        exported = export_network(params, spec, QuantSpec(4))
        c = spidr.compile(exported, params,
                          spidr.DeployTarget(weight_bits=4, n_cores=4),
                          spec=spec)
        report = c.verify(_events(spec))
        assert report.exact
        assert report.reference_exact
        assert report.single_core_exact is True
        assert report.roundtrip is not None and report.roundtrip.exact

    def test_verify_without_params_skips_roundtrip(self):
        spec = _spec("gesture")
        params = init_params(jax.random.PRNGKey(0), spec)
        exported = export_network(params, spec, QuantSpec(4))
        c = spidr.compile(exported, spec, spidr.DeployTarget(weight_bits=4))
        report = c.verify(_events(spec))
        assert report.exact and report.roundtrip is None
        assert report.single_core_exact is None

    def test_compiler_overrides_pin_the_plan_and_stay_exact(self):
        spec = _spec("gesture")
        params = init_params(jax.random.PRNGKey(0), spec)
        ev = _events(spec)
        base = spidr.compile(spec, params,
                             spidr.DeployTarget(backend="jnp", n_cores=4))
        pinned = spidr.compile(
            spec, params,
            spidr.DeployTarget(backend="jnp", n_cores=4, force_mode=2,
                               stationarity="vmem", assumed_sparsity=0.6))
        for ls in pinned.schedule.layers:
            assert ls.plan.mode == 2
            assert ls.plan.stationarity == "vmem"
        # Overrides only move the modeled cost, never the computed spikes.
        np.testing.assert_array_equal(np.asarray(base.run(ev).readout),
                                      np.asarray(pinned.run(ev).readout))

    def test_open_stream_validates_overrides(self):
        spec = _spec("gesture")
        params = init_params(jax.random.PRNGKey(0), spec)
        c = spidr.compile(spec, params, spidr.DeployTarget(backend="jnp"))
        with pytest.raises(ValueError, match="capacity=0 unsupported"):
            c.open_stream(capacity=0)
        with pytest.raises(ValueError, match="chunk_T=-1 unsupported"):
            c.open_stream(chunk_T=-1)

    def test_stream_session_context_manager_closes_slots(self):
        spec = _spec("gesture")
        params = init_params(jax.random.PRNGKey(0), spec)
        c = spidr.compile(spec, params, spidr.DeployTarget(backend="jnp"))
        with c.open_stream(capacity=2, chunk_T=2) as session:
            assert session.open() == 0
            assert session.occupancy == 1
        assert session.occupancy == 0
