"""Docs can't rot: every ```python block in README.md and docs/*.md must
execute (the same check CI's `docs` job runs via tools/check_docs.py)."""
import pathlib
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "tools"))

from check_docs import DEFAULT_FILES, extract_python_blocks, run_file  # noqa: E402

DOC_FILES = [p for p in DEFAULT_FILES if p.exists()]


def test_docs_exist_and_have_snippets():
    assert DOC_FILES, "no doc files found"
    total = sum(
        len(list(extract_python_blocks(p.read_text()))) for p in DOC_FILES
    )
    assert total >= 3, "expected runnable python examples in the docs"


@pytest.mark.parametrize("path", DOC_FILES, ids=lambda p: p.name)
def test_every_python_snippet_runs(path):
    failures = run_file(path)
    assert not failures, f"{len(failures)} failing snippet(s) in {path.name}"
