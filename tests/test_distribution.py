"""Distribution substrate tests: sharding specs, optimizer, compression,
checkpoint (atomic/async/elastic), data pipeline, fault tolerance."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import sharding as S
from repro.checkpoint.checkpoint import Checkpointer
from repro.configs import get_config
from repro.data.pipeline import TokenPipeline, synth_tokens
from repro.models.model import abstract_params
from repro.optim.compression import (
    init_error_state,
    int8_compress,
    int8_decompress,
)
from repro.optim.optimizer import (
    adamw,
    apply_updates,
    clip_by_global_norm,
    lion,
    linear_warmup_cosine,
    sgd,
)
from repro.runtime.fault_tolerance import (
    RestartableFailure,
    StepWatchdog,
    StragglerDetector,
)


class TestShardingSpecs:
    def test_param_specs_cover_all_leaves(self):
        for arch in ["qwen3-14b", "rwkv6-7b", "moonshot-v1-16b-a3b", "zamba2-7b"]:
            cfg = get_config(arch)
            pa = abstract_params(cfg)
            specs = S.param_specs(pa)
            n_p = len(jax.tree.leaves(pa))
            n_s = len(jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)))
            assert n_p == n_s, arch

    def test_big_weights_are_2d_sharded(self):
        cfg = get_config("qwen3-14b")
        pa = abstract_params(cfg)
        specs = S.param_specs(pa)
        wq_spec = specs["blocks"]["layers"]["attn"].wq
        assert wq_spec == P(None, "data", "model")

    def test_validate_spec_drops_nondividing(self):
        import types

        # validate_spec only reads mesh.shape — abstract stand-in works on 1 CPU
        mesh = types.SimpleNamespace(shape={"data": 2, "model": 2})
        # 5 not divisible by 2 -> relocate to dim with 4
        out = S.validate_spec(P("model", None), (5, 4), mesh)
        assert out == P(None, "model")
        # nothing divides -> fully replicated
        out = S.validate_spec(P("model", "data"), (5, 3), mesh)
        assert out == P(None, None)

    def test_batch_specs(self):
        cfg = get_config("qwen1.5-0.5b")
        from repro.configs import SHAPES, input_specs

        b = input_specs(cfg, SHAPES["train_4k"])
        specs = S.batch_specs(b, multi_pod=True)
        assert specs["tokens"] == P(("pod", "data"), None)
        b1 = input_specs(cfg, SHAPES["long_500k"])
        specs1 = S.batch_specs(b1, multi_pod=False)
        assert specs1["tokens"] == P(None, None)  # batch 1: unsharded


class TestOptimizers:
    def _quad(self, opt_fn, steps=200):
        params = {"w": jnp.array([3.0, -2.0])}
        update_fn, state = opt_fn(params=params)
        for step in range(steps):
            grads = {"w": 2 * params["w"]}  # d/dw w^2
            updates, state = update_fn(grads, state, params, step)
            params = apply_updates(params, updates)
        return float(jnp.abs(params["w"]).max())

    def test_adamw_converges(self):
        assert self._quad(lambda params: adamw(lr=5e-2, params=params)) < 0.1

    def test_sgd_converges(self):
        assert self._quad(lambda params: sgd(lr=1e-2, params=params)) < 0.1

    def test_lion_converges(self):
        # Sign descent with short momentum (long b2 overshoots by ~lr/(1-b2)
        # on a noiseless quadratic before turning around).
        assert self._quad(
            lambda params: lion(lr=1e-2, b2=0.9, params=params), steps=400
        ) < 0.5

    def test_clip(self):
        g = {"a": jnp.full((10,), 100.0)}
        clipped, norm = clip_by_global_norm(g, 1.0)
        assert float(norm) > 100
        from repro.optim.optimizer import global_norm

        assert float(global_norm(clipped)) <= 1.01

    def test_schedule(self):
        fn = linear_warmup_cosine(1.0, warmup=10, total_steps=100)
        assert float(fn(0)) == 0.0
        assert float(fn(10)) == pytest.approx(1.0, abs=0.01)
        assert float(fn(100)) < 0.2

    def test_none_leaves_skipped(self):
        params = {"a": jnp.ones(3), "b": None}
        update_fn, state = adamw(lr=0.1, params=params)
        grads = {"a": jnp.ones(3), "b": None}
        updates, _ = update_fn(grads, state, params, 0)
        assert updates["b"] is None


class TestCompression:
    def test_int8_roundtrip_error(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (1000,))
        q, scale = int8_compress(x)
        err = jnp.max(jnp.abs(int8_decompress(q, scale) - x))
        assert float(err) <= float(scale) * 0.51

    def test_error_feedback_unbiased_over_time(self):
        """EF: compressed sum over steps converges to true sum."""
        grads = {"w": jnp.array([1e-3, 5e-4, -2e-3])}  # small: big quant error
        err = init_error_state(grads)
        total = jnp.zeros(3)

        def fake_allreduce(g, e):
            def one(gl, el):
                corrected = gl + el
                q, s = int8_compress(corrected)
                deq = int8_decompress(q, s)
                return deq, corrected - deq
            out = jax.tree.map(one, g, e)
            return {"w": out["w"][0]}, {"w": out["w"][1]}

        for _ in range(50):
            reduced, err = fake_allreduce(grads, err)
            total = total + reduced["w"]
        want = grads["w"] * 50
        np.testing.assert_allclose(np.asarray(total), np.asarray(want), rtol=0.05)


class TestCheckpointer:
    def test_atomic_roundtrip(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        tree = {"w": jnp.arange(6.0).reshape(2, 3), "none": None,
                "nested": {"b": jnp.ones(4, jnp.int32)}}
        ck.save(3, tree)
        assert ck.latest_step() == 3
        out = ck.restore(3, tree)
        np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))
        assert out["none"] is None

    def test_async_save(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        ck.save_async(1, {"w": jnp.ones(8)})
        ck.wait()
        assert ck.latest_step() == 1

    def test_latest_picks_max_and_ignores_tmp(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        ck.save(1, {"w": jnp.ones(2)})
        ck.save(5, {"w": jnp.ones(2)})
        os.makedirs(tmp_path / "step_000000099.tmp")
        assert ck.latest_step() == 5

    def test_elastic_restore_new_sharding(self, tmp_path):
        """Restore onto a different device layout (elastic)."""
        ck = Checkpointer(str(tmp_path))
        tree = {"w": jnp.arange(16.0).reshape(4, 4)}
        ck.save(0, tree)
        from repro.launch.mesh import make_mesh_compat

        mesh = make_mesh_compat((1,), ("data",))
        sh = {"w": jax.sharding.NamedSharding(mesh, P("data", None))}
        out = ck.restore(0, tree, shardings=sh)
        np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))


class TestDataPipeline:
    def test_deterministic(self):
        a = synth_tokens(0, 7, 4, 64, 1000)
        b = synth_tokens(0, 7, 4, 64, 1000)
        np.testing.assert_array_equal(a, b)
        c = synth_tokens(0, 8, 4, 64, 1000)
        assert not np.array_equal(a, c)

    def test_learnable_structure(self):
        toks = synth_tokens(0, 0, 8, 64, 100)
        assert toks.min() >= 0 and toks.max() < 100

    def test_batch_at_pure(self):
        pipe = TokenPipeline(batch=2, seq_len=16, vocab=50)
        b1 = pipe.batch_at(5)
        b2 = pipe.batch_at(5)
        np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))

    def test_prefetch_iterator(self):
        pipe = TokenPipeline(batch=2, seq_len=16, vocab=50)
        it = iter(pipe)
        batches = [next(it) for _ in range(3)]
        pipe.close()
        assert all(b["tokens"].shape == (2, 16) for b in batches)


class TestFaultTolerance:
    def test_watchdog_fires(self):
        wd = StepWatchdog(deadline_s=0.05)
        wd.arm()
        import time

        time.sleep(0.15)
        with pytest.raises(RestartableFailure):
            wd.check()
        assert wd.timeouts == 1

    def test_watchdog_disarm(self):
        wd = StepWatchdog(deadline_s=10.0)
        wd.arm()
        wd.disarm()
        wd.check()  # no raise

    def test_straggler_detection(self):
        det = StragglerDetector(window=32, z_thresh=3.0, min_steps=8)
        for _ in range(20):
            det.record(0.1)
        assert det.record(10.0) is True
        assert det.flagged == 1
        assert det.stats().p95_s < 1.0 or det.stats().last_s == 10.0

    def test_loop_restores_after_failure(self, tmp_path):
        """End-to-end: crash mid-training -> restore from checkpoint -> finish."""
        from repro.runtime.loop import LoopConfig, TrainingLoop

        calls = {"n": 0}

        def step_fn(params, opt_state, step, batch):
            calls["n"] += 1
            if step == 5 and calls["n"] == 6:  # fail once at step 5
                raise RestartableFailure("injected")
            return params + 1, opt_state, {"loss": jnp.float32(1.0 / (step + 1))}

        loop = TrainingLoop(
            step_fn=step_fn,
            batch_fn=lambda s: {"x": s},
            checkpointer=Checkpointer(str(tmp_path)),
            cfg=LoopConfig(total_steps=8, checkpoint_every=2, log_every=100),
        )
        params, _, history = loop.run(jnp.float32(0.0), jnp.float32(0.0))
        assert loop.restarts == 1
        assert len(history) >= 8  # replayed steps included
        assert float(params) == 8.0  # exactly 8 successful increments
