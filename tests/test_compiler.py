"""Tests: multi-core compiler (IR -> partition -> select -> schedule) and
the engine's compiled execution + per-core cost attribution.

The load-bearing contract is the ISSUE-3 acceptance criterion: compiling
the gesture network onto 4 cores must produce a schedule whose engine
outputs are bit-exact with the single-core path — spike counts and final
Vmem — under whole-stream and chunked (chunk_T in {1, 3}) execution, with
per-core cycle sums matching the single-core total within the modeled
spike-routing/duplication overhead.
"""
import dataclasses
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compiler import (
    CoreGrid,
    build_graph,
    compile_network,
    partition_graph,
    select_layer,
)
from repro.configs import spidr_gesture
from repro.core.network import gesture_net, init_params, optical_flow_net
from repro.core.quant import QuantSpec
from repro.engine import (
    EngineConfig,
    StreamSessionManager,
    build_engine,
    compile_engine,
    estimate_cost,
    estimate_multicore_cost,
    init_state,
    run_chunk,
    run_engine,
)


def _events(spec, batch=2, seed=0, sparsity=0.9):
    rng = np.random.default_rng(seed)
    shape = (spec.timesteps, batch) + tuple(spec.input_hw) + (2,)
    return jnp.asarray((rng.random(shape) > sparsity).astype(np.float32))


@pytest.fixture(scope="module")
def gesture_setup():
    spec = spidr_gesture.reduced(hw=(16, 16), timesteps=6)
    params = init_params(jax.random.PRNGKey(0), spec)
    qspec = QuantSpec(4)
    eng = build_engine(spec, params, EngineConfig(qspec, backend="jnp"))
    schedule = compile_network(spec, n_cores=4, qspec=qspec)
    meng = compile_engine(eng, schedule)
    return spec, eng, schedule, meng


# ---------------------------------------------------------------------------
# IR
# ---------------------------------------------------------------------------
class TestIR:
    def test_graph_structure(self):
        spec = gesture_net()
        g = build_graph(spec)
        assert len(g.nodes) == len(spec.layers)
        kinds = [n.kind for n in g.nodes]
        assert kinds == [l.kind for l in spec.layers]
        # Chain: node i consumes node i-1.
        for i, n in enumerate(g.nodes):
            assert n.inputs == ((i - 1,) if i else ())
        assert len(g.weight_nodes) == len(spec.layer_shapes())

    def test_routing_volumes(self):
        g = build_graph(gesture_net())
        # First conv consumes the 64x64x2 event plane.
        assert g.nodes[0].in_positions == 64 * 64 * 2
        # FC consumes the adaptive-pooled 2*2*16 = 64 plane.
        fc = g.weight_nodes[-1]
        assert fc.kind == "fc" and fc.in_positions == 64

    def test_producer_skips_pools(self):
        g = build_graph(gesture_net())
        fc = g.weight_nodes[-1]
        prod = g.producer_of(fc)
        # Nearest weight ancestor of the FC is the last conv (idx 5),
        # through both pool nodes.
        assert prod is not None and prod.idx == 5 and prod.kind == "conv"


# ---------------------------------------------------------------------------
# Partitioner
# ---------------------------------------------------------------------------
class TestPartition:
    def test_gesture_4b_is_pure_pipeline(self):
        """Every gesture layer fits one core at 4-bit: whole-layer
        placement only, spread over all cores."""
        g = build_graph(gesture_net())
        parts = partition_graph(g, CoreGrid(4), QuantSpec(4))
        assert all(not p.split and len(p.slices) == 1 for p in parts)
        used = {p.slices[0].core for p in parts}
        assert used == {0, 1, 2, 3}  # greedy balance touches every core

    def test_flow_8b_channel_splits(self):
        """32-channel convs at 8-bit need 2 channel tiles -> split."""
        g = build_graph(optical_flow_net())
        parts = partition_graph(g, CoreGrid(4), QuantSpec(8))
        split = [p for p in parts if p.split]
        assert split, "expected channel-split layers at 8-bit"
        for p in split:
            assert len(p.slices) >= 2

    def test_slices_contiguous_cover(self):
        for spec, bits in ((gesture_net(), 4), (optical_flow_net(), 8)):
            g = build_graph(spec)
            parts = partition_graph(g, CoreGrid(4), QuantSpec(bits))
            for node, p in zip(g.weight_nodes, parts):
                lo = 0
                for s in sorted(p.slices, key=lambda s: s.lo):
                    assert s.lo == lo and s.width >= 1
                    lo = s.hi
                assert lo == node.shape.out_channels

    def test_single_core_grid(self):
        g = build_graph(gesture_net())
        parts = partition_graph(g, CoreGrid(1), QuantSpec(4))
        assert all(p.slices[0].core == 0 for p in parts)


# ---------------------------------------------------------------------------
# Selector
# ---------------------------------------------------------------------------
class TestSelect:
    def test_conv_weight_stationary_fc_vmem(self):
        g = build_graph(gesture_net())
        nodes = g.weight_nodes
        deep_conv = nodes[1]          # conv(16->16): real position reuse
        plan = select_layer(deep_conv, deep_conv.shape, (QuantSpec(4),))
        assert plan.stationarity == "weight"
        fc = nodes[-1]
        plan = select_layer(fc, fc.shape, (QuantSpec(4),))
        assert plan.stationarity == "vmem"

    def test_mode_matches_fig12_for_paper_layers(self):
        """Where Mode 1's 3x channel parallelism is actually used
        (out_channels > 48/W_b) the cost model rediscovers the Fig 12
        fan-in rule.  Narrow layers (gesture's FC(64,11), flow's final
        conv to 2 channels) legitimately flip to Mode 2: with channel
        tiles == 1 either way, compute is identical and Mode 2 stores the
        fan-in across all 9 macros instead of replicating it per pipeline
        — less weight-load traffic."""
        from repro.core.modes import CM_WEIGHT_ROWS

        qspec = QuantSpec(4)
        for spec in (gesture_net(), optical_flow_net()):
            g = build_graph(spec)
            for node in g.weight_nodes:
                plan = select_layer(node, node.shape, (qspec,))
                if node.shape.out_channels > qspec.neurons_per_row:
                    want = 1 if node.shape.fan_in <= CM_WEIGHT_ROWS * 3 else 2
                    assert plan.mode == want, (spec.name, node.idx)
                else:
                    assert plan.mode == 2, (spec.name, node.idx)

    def test_precision_pinned_by_default(self):
        sch = compile_network(gesture_net(), n_cores=2, qspec=QuantSpec(6))
        assert all(l.plan.spec == QuantSpec(6) for l in sch.layers)

    def test_precision_exploration_rejected_by_engine(self):
        spec = spidr_gesture.reduced(hw=(16, 16), timesteps=2)
        params = init_params(jax.random.PRNGKey(0), spec)
        eng = build_engine(spec, params,
                           EngineConfig(QuantSpec(8), backend="jnp"))
        sch = compile_network(
            spec, n_cores=2, qspec=QuantSpec(8),
            allowed_specs=(QuantSpec(4), QuantSpec(6), QuantSpec(8)))
        if any(l.plan.spec != QuantSpec(8) for l in sch.layers):
            with pytest.raises(ValueError, match="cost analysis"):
                compile_engine(eng, sch)
        else:  # pragma: no cover - selector kept 8-bit everywhere
            pytest.skip("selector picked the engine precision anyway")


# ---------------------------------------------------------------------------
# Schedule
# ---------------------------------------------------------------------------
class TestSchedule:
    def test_leafless_pytree(self, gesture_setup):
        _, _, schedule, _ = gesture_setup
        leaves, treedef = jax.tree_util.tree_flatten(schedule)
        assert leaves == []
        assert jax.tree_util.tree_unflatten(treedef, leaves) is schedule

    def test_describe(self, gesture_setup):
        _, _, schedule, _ = gesture_setup
        text = schedule.describe()
        assert "4 cores" in text and "mode=" in text and "core" in text

    def test_route_factors(self, gesture_setup):
        _, _, schedule, _ = gesture_setup
        first = schedule.layers[0]
        # Sensor feed to a single consumer core is free.
        assert first.route_factor == 0.0
        # Consecutive whole layers on different cores route every spike once.
        for prev, cur in zip(schedule.layers, schedule.layers[1:]):
            if prev.slices[0].core != cur.slices[0].core:
                assert cur.route_factor == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# Acceptance: bit-exact multi-core execution.
# ---------------------------------------------------------------------------
class TestMulticoreExecution:
    def test_whole_stream_bit_exact(self, gesture_setup):
        spec, eng, _, meng = gesture_setup
        ev = _events(spec)
        a, b = run_engine(eng, ev), run_engine(meng, ev)
        np.testing.assert_array_equal(np.asarray(a.readout),
                                      np.asarray(b.readout))
        np.testing.assert_array_equal(np.asarray(a.spike_counts),
                                      np.asarray(b.spike_counts))
        np.testing.assert_array_equal(np.asarray(a.input_counts),
                                      np.asarray(b.input_counts))

    @pytest.mark.parametrize("chunk_T", [1, 3])
    def test_chunked_bit_exact_with_final_vmem(self, gesture_setup, chunk_T):
        spec, eng, _, meng = gesture_setup
        ev = _events(spec)
        ref_state = init_state(eng, ev.shape[1])
        ref_state, ref_out = run_chunk(eng, ref_state, ev)
        st = init_state(meng, ev.shape[1])
        for t0 in range(0, ev.shape[0], chunk_T):
            st, out = run_chunk(meng, st, ev[t0:t0 + chunk_T])
        np.testing.assert_array_equal(np.asarray(ref_out.readout),
                                      np.asarray(out.readout))
        for v_ref, v in zip(ref_state.vmem, st.vmem):
            if v_ref is None:
                assert v is None
            else:
                np.testing.assert_array_equal(np.asarray(v_ref),
                                              np.asarray(v))

    def test_split_layers_bit_exact(self):
        """Channel-split placement (8-bit flow-style convs) stays exact."""
        spec = dataclasses.replace(
            optical_flow_net(), input_hw=(16, 16), timesteps=3)
        params = init_params(jax.random.PRNGKey(1), spec)
        qspec = QuantSpec(8)
        eng = build_engine(spec, params, EngineConfig(qspec, backend="jnp"))
        sch = compile_network(spec, n_cores=4, qspec=qspec)
        assert sch.n_split_layers > 0
        meng = compile_engine(eng, sch)
        ev = _events(spec, batch=1, seed=2)
        a, b = run_engine(eng, ev), run_engine(meng, ev)
        np.testing.assert_array_equal(np.asarray(a.readout),
                                      np.asarray(b.readout))
        np.testing.assert_array_equal(np.asarray(a.spike_counts),
                                      np.asarray(b.spike_counts))

    def test_fused_backend_multicore(self):
        """The Pallas fused kernel vmaps over the cores axis (interpret)."""
        spec = spidr_gesture.reduced(hw=(16, 16), timesteps=2)
        params = init_params(jax.random.PRNGKey(0), spec)
        qspec = QuantSpec(4)
        cfg = EngineConfig(qspec, backend="fused", interpret=True,
                           block=(128, 128, 128))
        eng = build_engine(spec, params, cfg)
        meng = compile_engine(eng, compile_network(spec, n_cores=2,
                                                   qspec=qspec))
        ev = _events(spec, batch=1)[:2]
        a, b = run_engine(eng, ev), run_engine(meng, ev)
        np.testing.assert_array_equal(np.asarray(a.readout),
                                      np.asarray(b.readout))

    def test_double_compile_rejected(self, gesture_setup):
        _, _, schedule, meng = gesture_setup
        with pytest.raises(AssertionError):
            compile_engine(meng, schedule)

    def test_shard_map_device_parallel(self):
        """Real device parallelism over the cores mesh axis: 4 forced host
        devices, outputs bit-exact with single-core, in a subprocess so
        the device count doesn't leak into this process's jax."""
        code = """
import numpy as np, jax, jax.numpy as jnp
from repro.configs import spidr_gesture
from repro.core.network import init_params
from repro.core.quant import QuantSpec
from repro.engine import EngineConfig, build_engine, compile_engine, run_engine
from repro.compiler import compile_network
assert len(jax.devices()) == 4
spec = spidr_gesture.reduced(hw=(16, 16), timesteps=3)
params = init_params(jax.random.PRNGKey(0), spec)
eng = build_engine(spec, params, EngineConfig(QuantSpec(4), backend="jnp"))
meng = compile_engine(eng, compile_network(spec, n_cores=4,
                                           qspec=QuantSpec(4)))
assert meng.device_parallel
rng = np.random.default_rng(0)
ev = jnp.asarray((rng.random((3, 2, 16, 16, 2)) > 0.9).astype(np.float32))
a, b = run_engine(eng, ev), run_engine(meng, ev)
assert (np.asarray(a.readout) == np.asarray(b.readout)).all()
assert (np.asarray(a.spike_counts) == np.asarray(b.spike_counts)).all()
print("SHARD_MAP_OK")
"""
        import os

        env = dict(os.environ)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + " --xla_force_host_platform_device_count=4")
        env["JAX_PLATFORMS"] = "cpu"
        res = subprocess.run([sys.executable, "-c", code], env=env,
                             capture_output=True, text=True, timeout=560)
        assert res.returncode == 0, res.stderr[-2000:]
        assert "SHARD_MAP_OK" in res.stdout


# ---------------------------------------------------------------------------
# Per-core cost attribution.
# ---------------------------------------------------------------------------
class TestMulticoreCost:
    def test_cycle_sums_match_single_core(self, gesture_setup):
        """Acceptance: per-core cycle sums == single-core total within the
        modeled overheads (routing + split duplication + ceil rounding)."""
        spec, eng, schedule, meng = gesture_setup
        ev = _events(spec)
        counts = np.asarray(run_engine(meng, ev).input_counts)
        mc = estimate_multicore_cost(spec, schedule, counts)
        # Exact accounting identity of the model:
        assert (int(mc.compute_cycles.sum())
                == mc.single_core_compute_cycles + mc.duplication_cycles)
        # No split layers in this plan: duplication is ceil rounding only,
        # bounded by T * sum of active macros per layer.
        T = counts.shape[0]
        slack = T * sum(
            l.plan.mapping.pipelines * l.plan.mapping.macros_per_pipeline
            for l in schedule.layers)
        assert 0 <= mc.duplication_cycles <= slack
        # Routing overhead is the only other modeled gap vs single core.
        assert (mc.compute_cycles.sum()
                <= mc.single_core_compute_cycles + slack
                + mc.routing_cycles.sum())

    def test_single_core_model_agrees_with_estimate_cost(self, gesture_setup):
        """The multicore model's single-core baseline is a total-busy sum
        over all 9 macros of the same row-op rule estimate_cost feeds its
        pipeline sim, so it must fit inside 9x the simulated makespan (no
        macro can be busier than the wall clock)."""
        spec, eng, schedule, _ = gesture_setup
        ev = _events(spec)
        counts = np.asarray(run_engine(eng, ev).input_counts)
        mc = estimate_multicore_cost(spec, schedule, counts)
        sc = estimate_cost(spec, QuantSpec(4), counts)
        assert 0 < mc.single_core_compute_cycles <= 9 * sc.makespan_cycles

    def test_idle_chunk_imbalance_invariant(self, gesture_setup):
        """A zero-spike chunk (quiet DVS window) is perfectly balanced:
        load_imbalance keeps its >= 1.0 invariant instead of reporting 0."""
        spec, _, schedule, _ = gesture_setup
        counts = np.zeros((3, len(schedule.layers)))
        mc = estimate_multicore_cost(spec, schedule, counts)
        assert mc.load_imbalance == 1.0
        assert mc.routing_cycles.sum() == 0

    def test_route_fractions_single_source(self, gesture_setup):
        """route_factor is derived from the per-core fractions the cost
        model consumes — one routing model, two views."""
        _, _, schedule, _ = gesture_setup
        for ls in schedule.layers:
            assert ls.route_factor == pytest.approx(sum(ls.route_fractions))
            for c, f in enumerate(ls.route_fractions):
                if f > 0:
                    assert c in ls.consumer_cores

    def test_imbalance_and_energy(self, gesture_setup):
        spec, _, schedule, meng = gesture_setup
        ev = _events(spec)
        counts = np.asarray(run_engine(meng, ev).input_counts)
        mc = estimate_multicore_cost(spec, schedule, counts)
        assert mc.load_imbalance >= 1.0
        assert mc.energy_uj > mc.routing_energy_uj >= 0.0
        assert len(mc.per_core) == 4
        assert sum(pc.energy_uj for pc in mc.per_core) == pytest.approx(
            mc.energy_uj - mc.routing_energy_uj)

    def test_chunked_pricing_invariant(self, gesture_setup):
        """Per-core clocks resume across chunks: pricing chunk by chunk
        equals pricing the whole stream (any chunking)."""
        spec, _, schedule, meng = gesture_setup
        ev = _events(spec)
        counts = np.asarray(run_engine(meng, ev).input_counts)
        whole = estimate_multicore_cost(spec, schedule, counts)
        states, routing = None, np.zeros(4, np.int64)
        for t0 in range(0, counts.shape[0], 2):
            mc = estimate_multicore_cost(spec, schedule,
                                         counts[t0:t0 + 2],
                                         pipeline_states=states)
            states = mc.pipeline_states
            routing += mc.routing_cycles
        final = np.array([pc.makespan_cycles for pc in mc.per_core])
        whole_final = np.array([pc.makespan_cycles for pc in whole.per_core])
        np.testing.assert_array_equal(final, whole_final)
        np.testing.assert_array_equal(routing, whole.routing_cycles)


# ---------------------------------------------------------------------------
# Streaming on a compiled plan.
# ---------------------------------------------------------------------------
class TestMulticoreStreaming:
    def test_sessions_bit_exact_and_attributed(self, gesture_setup):
        spec, eng, schedule, meng = gesture_setup
        ev = _events(spec)
        evn = np.asarray(ev)
        whole = run_engine(eng, ev)

        mgr = StreamSessionManager(meng, capacity=2, chunk_T=3)
        s0, s1 = mgr.open(), mgr.open()
        for t0 in range(0, spec.timesteps, 3):
            ups = mgr.step({s0: evn[t0:t0 + 3, 0], s1: evn[t0:t0 + 3, 1]})
        np.testing.assert_array_equal(
            ups[s0].readout, np.asarray(whole.readout)[0])
        np.testing.assert_array_equal(
            ups[s1].readout, np.asarray(whole.readout)[1])
        # Per-core attribution present and consistent with whole-stream
        # pricing of this slot's own spikes.
        st = init_state(meng, 1)
        _, out = run_chunk(meng, st, ev[:, 0:1])
        mc = estimate_multicore_cost(
            spec, schedule, np.asarray(out.slot_input_counts)[:, :, 0])
        expect = (np.array([pc.makespan_cycles for pc in mc.per_core])
                  + mc.routing_cycles)
        np.testing.assert_array_equal(ups[s0].per_core_cycles, expect)
        assert ups[s0].cycles == int(expect.max())
        assert ups[s0].load_imbalance >= 1.0

    def test_slot_reuse(self, gesture_setup):
        spec, eng, _, meng = gesture_setup
        ev = _events(spec, batch=1, seed=7)
        evn = np.asarray(ev)
        whole = run_engine(eng, ev)
        mgr = StreamSessionManager(meng, capacity=2, chunk_T=2)
        slot = mgr.open()
        for t0 in range(0, spec.timesteps, 2):
            ups = mgr.step({slot: evn[t0:t0 + 2, 0]})
        mgr.close(slot)
        slot2 = mgr.open()
        for t0 in range(0, spec.timesteps, 2):
            ups = mgr.step({slot2: evn[t0:t0 + 2, 0]})
        np.testing.assert_array_equal(ups[slot2].readout,
                                      np.asarray(whole.readout)[0])
