"""Zero-downtime stream state: snapshot schema, bit-exact migration, drills.

The serving durability contract, end to end:

  * ``StreamSessionManager.state_dict`` is a deterministic, alias-free,
    schema-versioned tree (pinned here — changing the layout must bump
    ``SESSION_SCHEMA_VERSION``);
  * ``CompiledSNN.snapshot`` -> ``spidr.restore`` migrates live streams
    onto a freshly compiled replica **bit-exactly**: same spikes, readout
    and cumulative cycle/energy attribution as the uninterrupted run, for
    fused-Pallas and jnp backends, 1 and 4 cores, any snapshot tick, any
    chunking, any slot open/close interleaving;
  * the streaming server rewinds poisoned/hung ticks
    (``runtime.fault_tolerance``) and restores across process death
    (``tools/upgrade_drill.py`` runs the full kill matrix in CI).
"""
import functools
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # dev extra absent: property tests skip, rest run
    from _hypothesis_stub import given, settings, st

import repro
from repro import spidr
from repro.configs import spidr_gesture, spidr_optflow
from repro.core.network import init_params
from repro.engine.streaming import SESSION_SCHEMA_VERSION
from repro.serving import StreamRequest, StreamWorker
from repro.runtime.fault_tolerance import RestartableFailure

HW, T = (16, 16), 6


def _spec(task: str):
    mod = spidr_gesture if task == "gesture" else spidr_optflow
    return mod.reduced(hw=HW, timesteps=T)


@functools.lru_cache(maxsize=None)
def _compiled(task="gesture", backend="jnp", n_cores=1, seed=0,
              chunk_T=2, capacity=3):
    spec = _spec(task)
    params = init_params(jax.random.PRNGKey(seed), spec)
    target = spidr.DeployTarget(weight_bits=4, backend=backend,
                                n_cores=n_cores, chunk_T=chunk_T,
                                stream_capacity=capacity)
    return spidr.compile(spec, params, target)


def _chunk(rng, t):
    return (rng.random((t,) + HW + (2,)) < 0.1).astype(np.float32)


def _update_key(up):
    return (up.timesteps, np.asarray(up.readout).tolist(), up.chunk_spikes,
            up.spikes, up.cycles, up.energy_uj,
            None if up.per_core_cycles is None
            else np.asarray(up.per_core_cycles).tolist(),
            up.load_imbalance)


# ---------------------------------------------------------------------------
# The serialized-session schema (satellite: deterministic serializable view).
# ---------------------------------------------------------------------------
class TestSessionStateDict:
    def test_schema_is_pinned(self):
        # Changing this layout is a compatibility break: bump
        # SESSION_SCHEMA_VERSION and teach load_state_dict the old form.
        assert SESSION_SCHEMA_VERSION == 1
        sess = _compiled().open_stream(2, 2)
        sess.open()
        d = sess.state_dict()
        assert sorted(d) == ["clocks", "engine_state", "schema", "table"]
        assert int(d["schema"]) == SESSION_SCHEMA_VERSION
        assert sorted(d["engine_state"]) == [
            "in_counts", "out_counts", "readout_acc", "vmem"]
        assert sorted(d["table"]) == [
            "active", "core_cycles", "cycles", "ended", "energy_uj",
            "imbalance", "route_cycles", "spikes", "ticks", "timesteps"]
        assert d["table"]["active"].dtype == np.bool_
        assert d["table"]["timesteps"].dtype == np.int64
        assert d["table"]["energy_uj"].dtype == np.float64
        # One clock set per slot per core, fixed even for idle slots.
        assert len(d["clocks"]) == 2
        assert all(len(c) == 1 for c in d["clocks"])
        assert sorted(d["clocks"][0][0]) == [
            "cm_busy", "cm_free", "nu_busy", "nu_free", "recv_ready",
            "total_T", "worst_compute"]

    def test_state_dict_never_aliases_live_state(self):
        compiled = _compiled()
        sess = compiled.open_stream(2, 2)
        s0 = sess.open()
        rng = np.random.default_rng(0)
        sess.step({s0: _chunk(rng, 2)})
        frozen = sess.state_dict()
        # Corrupt every array in the snapshot...
        def smash(x):
            if isinstance(x, np.ndarray) and x.ndim:
                x.fill(-1)
        jax.tree.map(smash, frozen, is_leaf=lambda x: x is None)
        # ...and the live session must not notice.
        clean = sess.state_dict()
        assert int(clean["table"]["timesteps"][s0]) == 2
        assert not np.array_equal(clean["table"]["timesteps"],
                                  frozen["table"]["timesteps"])

    def test_state_dict_is_immutable_evidence_of_its_tick(self):
        compiled = _compiled()
        sess = compiled.open_stream(2, 2)
        s0 = sess.open()
        rng = np.random.default_rng(1)
        sess.step({s0: _chunk(rng, 2)})
        at_tick_1 = sess.state_dict()
        t1 = int(at_tick_1["table"]["timesteps"][s0])
        sess.step({s0: _chunk(rng, 2)})
        assert int(at_tick_1["table"]["timesteps"][s0]) == t1

    def test_roundtrip_through_fresh_session_is_bit_exact(self):
        compiled = _compiled()
        sess = compiled.open_stream(3, 2)
        s0, s1 = sess.open(), sess.open()
        rng = np.random.default_rng(2)
        for _ in range(2):
            sess.step({s0: _chunk(rng, 2), s1: _chunk(rng, 2)})
        snap = sess.state_dict()
        later = [{s0: _chunk(rng, 2), s1: _chunk(rng, 2)}]
        ref = [sess.step(c) for c in later]
        twin = compiled.open_stream(3, 2)
        twin.load_state_dict(snap)
        assert twin.active == (True, True, False)
        got = [twin.step(c) for c in later]
        for r, g in zip(ref, got):
            for slot in r:
                assert _update_key(r[slot]) == _update_key(g[slot])

    def test_newer_schema_is_refused(self):
        sess = _compiled().open_stream(2, 2)
        snap = sess.state_dict()
        snap["schema"] = np.int64(SESSION_SCHEMA_VERSION + 1)
        with pytest.raises(ValueError, match="schema"):
            sess.load_state_dict(snap)

    def test_capacity_mismatch_is_refused(self):
        compiled = _compiled()
        snap = compiled.open_stream(2, 2).state_dict()
        with pytest.raises(ValueError, match="capacity"):
            compiled.open_stream(3, 2).load_state_dict(snap)

    def test_clock_layout_mismatch_is_refused(self):
        compiled = _compiled()
        snap = compiled.open_stream(2, 2).state_dict()
        snap["clocks"] = [c + c for c in snap["clocks"]]  # pretend 2 cores
        with pytest.raises(ValueError, match="clock layout"):
            compiled.open_stream(2, 2).load_state_dict(snap)

    def test_wrong_network_is_refused(self):
        snap = _compiled("gesture").open_stream(2, 2).state_dict()
        with pytest.raises(ValueError, match="Vmem shapes"):
            _compiled("optical-flow").open_stream(2, 2).load_state_dict(snap)

    def test_slot_update_spikes_is_cumulative(self):
        sess = _compiled().open_stream(2, 2)
        s0 = sess.open()
        rng = np.random.default_rng(3)
        total = 0
        for _ in range(3):
            up = sess.step({s0: _chunk(rng, 2)})[s0]
            total += up.chunk_spikes
            assert up.spikes == total


# ---------------------------------------------------------------------------
# Tentpole: snapshot -> restore migration is bit-exact (the proof matrix).
# ---------------------------------------------------------------------------
MATRIX = [
    ("gesture", "jnp", 1),
    ("gesture", "fused", 1),
    ("gesture", "jnp", 4),
    ("optical-flow", "jnp", 1),
    ("optical-flow", "fused", 4),
]


class TestSnapshotRestoreMigration:
    @pytest.mark.parametrize("task,backend,n_cores", MATRIX)
    def test_migrated_stream_is_bit_identical(self, tmp_path, task,
                                              backend, n_cores):
        compiled = _compiled(task, backend, n_cores)
        sess = compiled.open_stream(3, 2)
        s0, s1 = sess.open(), sess.open()
        rng = np.random.default_rng(7)
        for _ in range(2):
            sess.step({s0: _chunk(rng, 2), s1: _chunk(rng, 2)})
        compiled.snapshot(str(tmp_path), step=2, sessions=[sess],
                          extra={"tick": 2})
        # Continue the original: one full tick, then s1 ends on a short
        # final chunk (slot churn after the snapshot point).
        later = [{s0: _chunk(rng, 2), s1: _chunk(rng, 2)},
                 {s0: _chunk(rng, 2), s1: _chunk(rng, 1)}]
        ref = [sess.step(c) for c in later]

        restored = spidr.restore(str(tmp_path))
        assert restored is not compiled
        assert restored.target == compiled.target
        twin = restored.sessions[0]
        assert twin.active == (True, True, False)
        got = [twin.step(c) for c in later]
        for r, g in zip(ref, got):
            assert sorted(r) == sorted(g)
            for slot in r:
                assert _update_key(r[slot]) == _update_key(g[slot])
        # Slot churn stays in lockstep after migration: retire the ended
        # stream, admit a new one, and both sessions keep agreeing.
        sess.close(s1)
        twin.close(s1)
        n0, n1 = sess.open(), twin.open()
        assert n0 == n1
        tick = {s0: _chunk(rng, 2), n0: _chunk(rng, 2)}
        r, g = sess.step(tick), twin.step(tick)
        for slot in r:
            assert _update_key(r[slot]) == _update_key(g[slot])

    def test_snapshot_restore_of_exported_network(self, tmp_path):
        from repro.core.quant import QuantSpec
        from repro.snn.export import export_network

        spec = _spec("gesture")
        params = init_params(jax.random.PRNGKey(0), spec)
        exported = export_network(params, spec, QuantSpec(4))
        target = spidr.DeployTarget(weight_bits=4, chunk_T=2,
                                    stream_capacity=2)
        compiled = spidr.compile(exported, spec, target)
        sess = compiled.open_stream()
        s0 = sess.open()
        rng = np.random.default_rng(11)
        sess.step({s0: _chunk(rng, 2)})
        compiled.snapshot(str(tmp_path), sessions=[sess])
        restored = spidr.restore(str(tmp_path))
        assert restored.exported is not None  # provenance survives
        later = {s0: _chunk(rng, 2)}
        assert _update_key(sess.step(later)[s0]) \
            == _update_key(restored.sessions[0].step(later)[s0])

    def test_restore_onto_prepared_replica(self, tmp_path):
        compiled = _compiled()
        sess = compiled.open_stream(2, 2)
        s0 = sess.open()
        rng = np.random.default_rng(13)
        sess.step({s0: _chunk(rng, 2)})
        compiled.snapshot(str(tmp_path), sessions=[sess])
        # Same weights and target, but a genuinely distinct CompiledSNN.
        replica = _compiled.__wrapped__("gesture", "jnp", 1, 0, 2, 3)
        assert replica is not compiled
        before = len(replica.sessions)
        out = spidr.restore(str(tmp_path), compiled=replica)
        assert out is replica and len(replica.sessions) == before + 1
        later = {s0: _chunk(rng, 2)}
        assert _update_key(sess.step(later)[s0]) \
            == _update_key(replica.sessions[-1].step(later)[s0])

    def test_replica_with_different_target_is_refused(self, tmp_path):
        compiled = _compiled()
        compiled.snapshot(str(tmp_path), sessions=[])
        other = _compiled(backend="fused")
        with pytest.raises(ValueError, match="DeployTarget"):
            spidr.restore(str(tmp_path), compiled=other)

    def test_replica_with_different_weights_is_refused(self, tmp_path):
        compiled = _compiled()
        compiled.snapshot(str(tmp_path), sessions=[])
        other = _compiled(seed=1)
        with pytest.raises(ValueError, match="identical"):
            spidr.restore(str(tmp_path), compiled=other)

    def test_non_snapshot_checkpoint_is_refused(self, tmp_path):
        from repro.checkpoint.checkpoint import Checkpointer

        Checkpointer(str(tmp_path)).save(0, {"w": np.zeros(3)})
        with pytest.raises(ValueError, match="not a spidr session snapshot"):
            spidr.restore(str(tmp_path))
        with pytest.raises(ValueError):
            spidr.read_snapshot_meta(str(tmp_path))

    def test_missing_snapshot_is_file_not_found(self, tmp_path):
        with pytest.raises(FileNotFoundError):
            spidr.restore(str(tmp_path / "nothing"))

    def test_snapshot_meta_round_trips_bookkeeping(self, tmp_path):
        compiled = _compiled()
        extra = {"cursors": {"0": 4}, "note": "pre-upgrade"}
        compiled.snapshot(str(tmp_path), step=9, sessions=[], extra=extra)
        info = spidr.read_snapshot_meta(str(tmp_path))
        assert info["step"] == 9
        assert info["extra"] == extra
        assert info["spec"]["input_hw"] == list(HW)
        assert info["target"]["n_cores"] == 1

    def test_migration_across_processes(self, tmp_path):
        # The real thing, minimally: snapshot here, resume in a fresh
        # interpreter (cold jax, cold caches), byte-compare the replies.
        compiled = _compiled()
        sess = compiled.open_stream(2, 2)
        s0 = sess.open()
        rng = np.random.default_rng(17)
        sess.step({s0: _chunk(rng, 2)})
        compiled.snapshot(str(tmp_path / "snap"), sessions=[sess])
        later = _chunk(rng, 2)
        np.save(tmp_path / "later.npy", later)
        ref = _update_key(sess.step({s0: later})[s0])

        child = (
            "import json, sys, numpy as np\n"
            "from repro import spidr\n"
            "c = spidr.restore(sys.argv[1])\n"
            "up = c.sessions[0].step({0: np.load(sys.argv[2])})[0]\n"
            "print(json.dumps([up.timesteps, np.asarray(up.readout).tolist(),"
            " up.chunk_spikes, up.spikes, up.cycles, up.energy_uj,"
            " None if up.per_core_cycles is None else"
            " np.asarray(up.per_core_cycles).tolist(), up.load_imbalance]))\n")
        env = dict(os.environ, JAX_PLATFORMS="cpu")
        src = os.path.dirname(os.path.dirname(os.path.abspath(
            repro.__file__)))
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        out = subprocess.run(
            [sys.executable, "-c", child, str(tmp_path / "snap"),
             str(tmp_path / "later.npy")],
            env=env, capture_output=True, text=True, timeout=600)
        assert out.returncode == 0, out.stderr[-2000:]
        assert tuple(json.loads(out.stdout.strip().splitlines()[-1])) \
            == tuple(json.loads(json.dumps(list(ref))))


# ---------------------------------------------------------------------------
# Invariance properties: any snapshot tick, any chunking, any interleaving.
# ---------------------------------------------------------------------------
def _serve(compiled, lens, seed, chunk_T, snapshot_tick=None, tmp=None):
    """Serve seeded streams of the given lengths; optionally snapshot at a
    tick and finish on a server restored from disk.  Returns {rid: result}."""
    def requests():
        rng = np.random.default_rng(seed)
        return {rid: StreamRequest(rid=rid, events=(
            rng.random((t,) + HW + (2,)) < 0.1).astype(np.float32))
            for rid, t in enumerate(lens)}

    server = StreamWorker(
        compiled, capacity=2, chunk_T=chunk_T,
        snapshot_dir=tmp if snapshot_tick is not None else None,
        snapshot_every=1 if snapshot_tick is not None else 0)
    for rid, req in sorted(requests().items()):
        server.submit(req)
    while server.step():
        if snapshot_tick is not None and server.ticks >= snapshot_tick:
            server = StreamWorker.restore(tmp, requests(),
                                                compiled=compiled)
            snapshot_tick = None  # abandoned mid-run, resumed from disk
    return {r.rid: (np.asarray(r.readout).tolist(), r.cycles, r.energy_uj)
            for r in server.done}


class TestInvariance:
    def test_every_snapshot_tick_restores_identically(self, tmp_path):
        lens = [6, 4, 5, 6]
        compiled = _compiled(chunk_T=2, capacity=2)
        ref = _serve(compiled, lens, seed=23, chunk_T=2)
        total_ticks = 7  # 2 slots x interleaved admissions
        for k in range(1, total_ticks):
            tmp = str(tmp_path / f"t{k}")
            got = _serve(compiled, lens, seed=23, chunk_T=2,
                         snapshot_tick=k, tmp=tmp)
            assert got == ref, f"diverged when killed after tick {k}"

    def test_chunking_invariance_survives_migration(self, tmp_path):
        lens = [6, 5, 4]
        results = {}
        for chunk_T in (1, 2, 3):
            compiled = _compiled(chunk_T=chunk_T, capacity=2)
            tmp = str(tmp_path / f"c{chunk_T}")
            results[chunk_T] = _serve(compiled, lens, seed=29,
                                      chunk_T=chunk_T, snapshot_tick=2,
                                      tmp=tmp)
        # Readout and cycle attribution are chunking-invariant integers, so
        # every chunking (each snapshotted/restored mid-run) must agree
        # exactly; energy is a float sum whose order follows the chunk
        # boundaries, so across *different* chunkings it only matches to
        # rounding (within one chunking it is bit-exact — tests above).
        for chunk_T in (2, 3):
            assert sorted(results[chunk_T]) == sorted(results[1])
            for rid, (readout, cycles, energy) in results[1].items():
                r2, c2, e2 = results[chunk_T][rid]
                assert (r2, c2) == (readout, cycles)
                assert e2 == pytest.approx(energy, rel=1e-12)

    def test_multicore_interleaving_restores_identically(self, tmp_path):
        lens = [6, 3, 5, 4]
        compiled = _compiled(n_cores=4, chunk_T=2, capacity=2)
        ref = _serve(compiled, lens, seed=31, chunk_T=2)
        got = _serve(compiled, lens, seed=31, chunk_T=2, snapshot_tick=3,
                     tmp=str(tmp_path / "mc"))
        assert got == ref

    @given(k=st.integers(min_value=1, max_value=6),
           seed=st.integers(min_value=0, max_value=2**16),
           chunk_T=st.integers(min_value=1, max_value=3))
    @settings(max_examples=8, deadline=None)
    def test_property_restore_matches_uninterrupted(self, tmp_path_factory,
                                                    k, seed, chunk_T):
        rng = np.random.default_rng(seed)
        lens = [int(rng.integers(2, T + 1)) for _ in range(3)]
        compiled = _compiled(chunk_T=chunk_T, capacity=2)
        ref = _serve(compiled, lens, seed=seed, chunk_T=chunk_T)
        tmp = str(tmp_path_factory.mktemp("prop"))
        got = _serve(compiled, lens, seed=seed, chunk_T=chunk_T,
                     snapshot_tick=k, tmp=tmp)
        assert got == ref


# ---------------------------------------------------------------------------
# The durable server: watchdog, rewind-and-replay, restart budget.
# ---------------------------------------------------------------------------
class TestDurableServer:
    def _requests(self, seed=37, lens=(6, 4, 5, 6)):
        rng = np.random.default_rng(seed)
        return {rid: StreamRequest(rid=rid, events=(
            rng.random((t,) + HW + (2,)) < 0.1).astype(np.float32))
            for rid, t in enumerate(lens)}

    def _run(self, server, reqs):
        for rid in sorted(reqs):
            server.submit(reqs[rid])
        while server.step():
            pass
        return {r.rid: (np.asarray(r.readout).tolist(), r.cycles,
                        r.energy_uj) for r in server.done}

    def test_poisoned_tick_rewinds_and_replays_bit_exactly(self):
        compiled = _compiled(capacity=2)
        ref = self._run(StreamWorker(compiled, 2, 2),
                        self._requests())
        srv = StreamWorker(compiled, 2, 2, fail_at_tick=3)
        got = self._run(srv, self._requests())
        assert srv.restarts == 1
        assert got == ref

    def test_hung_tick_trips_watchdog_then_recovers(self):
        compiled = _compiled(capacity=2)
        ref = self._run(StreamWorker(compiled, 2, 2),
                        self._requests())
        srv = StreamWorker(compiled, 2, 2, watchdog_s=0.05)
        real_step = srv.sessions.step
        hung = {"n": 0}

        def slow_once(chunks):
            out = real_step(chunks)
            if hung["n"] == 0:
                hung["n"] += 1
                import time
                time.sleep(0.2)  # blow the deadline exactly once
            return out

        srv.sessions.step = slow_once
        got = self._run(srv, self._requests())
        srv.sessions.step = real_step
        assert srv.restarts == 1
        assert got == ref

    def test_restart_budget_exhausts_into_failure(self):
        from repro.runtime.fault_tolerance import RestartableFailure as RF

        srv = StreamWorker(_compiled(capacity=2), 2, 2,
                                 max_restarts=2)

        def always_poisoned(tick):
            raise RF("wedged hardware")

        srv.mid_tick_hook = always_poisoned
        for rid, req in sorted(self._requests().items()):
            srv.submit(req)
        with pytest.raises(RestartableFailure, match="wedged"):
            srv.step()
        assert srv.restarts == 3  # 1 try + max_restarts replays
