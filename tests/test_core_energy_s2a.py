"""Tests: energy model calibration vs Table I (C9), S2A (C4), zero-skip (C3),
pipeline DES (C7)."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # dev extra absent: property tests skip, rest run
    from _hypothesis_stub import given, settings, st

from repro.core import energy, zero_skip
from repro.core.energy import HW, TABLE1_PAPER, gops, power_mw, tops_per_watt
from repro.core.pipeline import simulate_pipeline
from repro.core.s2a import S2AConfig, simulate_s2a, switch_count_batched


class TestTable1Calibration:
    """The reproduction's headline claim: Table I within tolerance."""

    @pytest.mark.parametrize("hw,key", [(HW(50e6, 0.9), "50MHz_0.9V"),
                                        (HW(150e6, 1.0), "150MHz_1.0V")])
    def test_power(self, hw, key):
        want = TABLE1_PAPER[key]["power_mw"]
        assert power_mw(hw) == pytest.approx(want, rel=0.02)

    @pytest.mark.parametrize("bits", [4, 6, 8])
    @pytest.mark.parametrize("hw,key", [(HW(50e6, 0.9), "50MHz_0.9V"),
                                        (HW(150e6, 1.0), "150MHz_1.0V")])
    def test_throughput(self, bits, hw, key):
        want = TABLE1_PAPER[key]["gops"][bits]
        assert gops(0.95, bits, hw.freq_hz) == pytest.approx(want, rel=0.01)

    @pytest.mark.parametrize("bits", [4, 6, 8])
    @pytest.mark.parametrize("hw,key", [(HW(50e6, 0.9), "50MHz_0.9V"),
                                        (HW(150e6, 1.0), "150MHz_1.0V")])
    def test_efficiency(self, bits, hw, key):
        want = TABLE1_PAPER[key]["topsw"][bits]
        assert tops_per_watt(0.95, bits, hw) == pytest.approx(want, rel=0.02)

    def test_fig17_sparsity_2x_claim(self):
        """~2x throughput from 80% -> 95% sparsity at 4-bit (Sec III)."""
        ratio = gops(0.95, 4) / gops(0.80, 4)
        assert 1.8 < ratio < 2.6

    def test_precision_scaling_is_48_over_wb(self):
        assert gops(0.9, 4) / gops(0.9, 8) == pytest.approx(2.0)
        assert gops(0.9, 4) / gops(0.9, 6) == pytest.approx(1.5)

    def test_fig10_switching_amortization(self):
        """1.5x energy/op reduction at batch 15 vs every-cycle switching."""
        ratio = energy.energy_per_op_batched(1) / energy.energy_per_op_batched(15)
        assert ratio == pytest.approx(1.5, rel=0.01)
        # diminishing returns beyond depth 16
        gain = energy.energy_per_op_batched(16) / energy.energy_per_op_batched(64)
        assert gain < 1.03

    def test_fig14_breakdown(self):
        """CIM macros dominate; total drops >50%... (>2x) from 75 -> 95%."""
        e75 = energy.chunk_energy_breakdown_nj(0.75)
        e95 = energy.chunk_energy_breakdown_nj(0.95)
        assert max(e95, key=e95.get) == "cim_macros"
        assert max(e75, key=e75.get) == "cim_macros"
        assert sum(e75.values()) > 1.5 * sum(e95.values())
        # data movement is a small fraction (in-memory compute claim)
        assert e95["data_movement"] / sum(e95.values()) < 0.15


class TestS2A:
    def test_empty_map(self):
        st_ = simulate_s2a(np.zeros((128, 16), np.int8))
        assert st_.row_ops == 0 and st_.switches == 0

    def test_two_ops_per_spike(self):
        rng = np.random.default_rng(0)
        m = (rng.random((128, 16)) < 0.1).astype(np.int8)
        st_ = simulate_s2a(m)
        assert st_.row_ops == 2 * st_.spikes

    def test_pingpong_amortizes_switches(self):
        """Ping-pong FIFO must get mean run length near the FIFO depth."""
        rng = np.random.default_rng(1)
        m = (rng.random((128, 16)) < 0.2).astype(np.int8)
        st_ = simulate_s2a(m, S2AConfig(fifo_depth=16))
        naive_switches = 2 * st_.spikes - 1
        assert st_.switches < naive_switches / 8
        assert st_.mean_run_length > 10

    def test_closed_form_switches(self):
        assert switch_count_batched(8, 1) == 15
        assert switch_count_batched(8, 16) == 0

    @given(st.floats(min_value=0.01, max_value=0.5), st.integers(0, 2**31 - 1))
    @settings(max_examples=15, deadline=None)
    def test_all_spikes_processed_property(self, density, seed):
        rng = np.random.default_rng(seed)
        m = (rng.random((64, 16)) < density).astype(np.int8)
        st_ = simulate_s2a(m)
        assert st_.spikes == int(m.sum())
        assert st_.row_ops == 2 * st_.spikes  # every spike: even + odd


class TestZeroSkip:
    def test_fig4_breakeven(self):
        """AER break-even for the optical-flow input layer ~94.7%."""
        n = 288 * 384 * 2
        brk = zero_skip.aer_breakeven_sparsity(n, framing_bits=1)
        assert 0.94 < brk < 0.96

    def test_aer_overhead_monotone(self):
        n = 64 * 64 * 2
        assert zero_skip.aer_overhead(n, 0.5) > zero_skip.aer_overhead(n, 0.99)

    def test_tile_skip(self):
        m = np.zeros((128, 128), np.int8)
        m[:8, :8] = 1
        frac = zero_skip.tile_skip_fraction(m, (8, 8))
        assert frac == pytest.approx(1 - 1 / 256)


class TestPipelineDES:
    def test_async_beats_sync(self):
        """Fig 13's motivation: handshake beats worst-case-sync pipeline."""
        rng = np.random.default_rng(0)
        cc = rng.integers(50, 800, (20, 9))  # high sparsity variance
        res = simulate_pipeline(cc)
        assert res.speedup_vs_sync > 1.1

    def test_uniform_work_near_sync(self):
        cc = np.full((10, 9), 300)
        res = simulate_pipeline(cc)
        assert res.makespan <= res.sync_makespan

    def test_makespan_lower_bound(self):
        cc = np.full((5, 9), 100)
        res = simulate_pipeline(cc)
        # at least the critical path of one timestep
        assert res.makespan >= 9 * 100

    @given(st.integers(1, 12), st.integers(0, 2**31 - 1))
    @settings(max_examples=10, deadline=None)
    def test_monotone_in_timesteps(self, t, seed):
        rng = np.random.default_rng(seed)
        cc = rng.integers(10, 200, (t + 1, 9))
        r1 = simulate_pipeline(cc[:t])
        r2 = simulate_pipeline(cc)
        assert r2.makespan >= r1.makespan
