"""Property tests on model math: chunk-size invariance of the linear-
attention scans, flash-vs-naive attention equivalence, MoE dispatch
invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config


class TestChunkInvariance:
    """Chunked scan results must not depend on the chunk size."""

    def test_rwkv6_wkv(self):
        from repro.models.rwkv6 import _wkv_chunked

        rng = np.random.default_rng(0)
        b, s, h, n = 2, 32, 3, 8
        r, k, v = (jnp.array(rng.normal(size=(b, s, h, n)).astype(np.float32))
                   for _ in range(3))
        lw = -jnp.array(rng.uniform(0.01, 1.0, (b, s, h, n)).astype(np.float32))
        u = jnp.array(rng.normal(size=(h, n)).astype(np.float32))
        s0 = jnp.zeros((b, h, n, n), jnp.float32)
        outs = [
            _wkv_chunked(r, k, v, lw, u, s0, c) for c in (4, 8, 16, 32)
        ]
        for y, sf in outs[1:]:
            np.testing.assert_allclose(np.asarray(y), np.asarray(outs[0][0]),
                                       rtol=2e-4, atol=2e-5)
            np.testing.assert_allclose(np.asarray(sf), np.asarray(outs[0][1]),
                                       rtol=2e-4, atol=2e-5)

    def test_mamba2_ssd(self):
        from repro.models.mamba2 import _ssd_chunked

        rng = np.random.default_rng(1)
        b, s, h, p, n = 2, 32, 3, 4, 8
        xh = jnp.array(rng.normal(size=(b, s, h, p)).astype(np.float32))
        bb = jnp.array(rng.normal(size=(b, s, n)).astype(np.float32))
        cc = jnp.array(rng.normal(size=(b, s, n)).astype(np.float32))
        dt = jnp.array(rng.uniform(0.01, 0.5, (b, s, h)).astype(np.float32))
        la = -jnp.array(rng.uniform(0.01, 1.0, (b, s, h)).astype(np.float32))
        s0 = jnp.zeros((b, h, n, p), jnp.float32)
        outs = [_ssd_chunked(xh, bb, cc, dt, la, s0, c) for c in (4, 8, 32)]
        for y, sf in outs[1:]:
            np.testing.assert_allclose(np.asarray(y), np.asarray(outs[0][0]),
                                       rtol=2e-4, atol=2e-5)
            np.testing.assert_allclose(np.asarray(sf), np.asarray(outs[0][1]),
                                       rtol=2e-4, atol=2e-5)


class TestFlashAttention:
    def test_matches_naive_softmax(self):
        """Online-softmax chunked attention == exact softmax attention."""
        from repro.models.attention import _flash_inner

        rng = np.random.default_rng(2)
        b, hkv, g, s, d = 2, 2, 3, 64, 16
        q = jnp.array(rng.normal(size=(b, hkv, g, s, d)).astype(np.float32))
        k = jnp.array(rng.normal(size=(b, hkv, s, d)).astype(np.float32))
        v = jnp.array(rng.normal(size=(b, hkv, s, d)).astype(np.float32))

        for chunk in (8, 16, 64):
            out = _flash_inner(q, k, v, 0, chunk, causal=True)
            # naive reference
            scores = jnp.einsum("bhgqd,bhkd->bhgqk", q, k)
            mask = jnp.tril(jnp.ones((s, s), bool))
            scores = jnp.where(mask[None, None, None], scores, -1e30)
            w = jax.nn.softmax(scores, axis=-1)
            want = jnp.einsum("bhgqk,bhkd->bhgqd", w, v)
            np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                       rtol=2e-4, atol=2e-5)


class TestMoEInvariants:
    def _setup(self, e=8, k=2, t=64, d=16, f=32, seed=0):
        from repro.models.moe import MoEParams

        rng = np.random.default_rng(seed)
        p = MoEParams(
            w_router=jnp.array(rng.normal(size=(d, e)).astype(np.float32)),
            w_gate=jnp.array(rng.normal(size=(e, d, f)).astype(np.float32)) * 0.1,
            w_up=jnp.array(rng.normal(size=(e, d, f)).astype(np.float32)) * 0.1,
            w_down=jnp.array(rng.normal(size=(e, f, d)).astype(np.float32)) * 0.1,
        )
        x = jnp.array(rng.normal(size=(t, d)).astype(np.float32))
        return p, x

    def test_no_drops_matches_dense_reference(self):
        """With unbounded capacity, dispatch == dense top-k mixture."""
        from repro.models.moe import _local_moe

        p, x = self._setup()
        e, k = 8, 2
        out, lb, zl, drop = _local_moe(
            x, p.w_router, p.w_gate, p.w_up, p.w_down, k, 100.0, e
        )
        assert float(drop) == 0.0
        # dense reference: compute every expert for every token
        logits = x @ p.w_router
        probs = jax.nn.softmax(logits, axis=-1)
        top_w, top_ids = jax.lax.top_k(probs, k)
        top_w = top_w / top_w.sum(-1, keepdims=True)
        gate = jnp.einsum("td,edf->tef", x, p.w_gate)
        up = jnp.einsum("td,edf->tef", x, p.w_up)
        h = jax.nn.silu(gate) * up
        dense = jnp.einsum("tef,efd->ted", h, p.w_down)
        want = jnp.einsum(
            "tkd,tk->td",
            jnp.take_along_axis(dense, top_ids[:, :, None], axis=1),
            top_w,
        )
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-4, atol=2e-5)

    def test_expert_slices_sum_to_whole(self):
        """EP decomposition: sum of per-slice outputs == single-device out."""
        from repro.models.moe import _local_moe

        p, x = self._setup()
        e, k = 8, 2
        full, *_ = _local_moe(x, p.w_router, p.w_gate, p.w_up, p.w_down,
                              k, 100.0, e)
        partial_sum = jnp.zeros_like(full)
        for shard in range(4):  # 4-way expert slicing
            e0 = shard * 2
            out, *_ = _local_moe(
                x, p.w_router,
                p.w_gate[e0:e0 + 2], p.w_up[e0:e0 + 2], p.w_down[e0:e0 + 2],
                k, 100.0, e, lambda e0=e0: e0,
            )
            partial_sum = partial_sum + out
        np.testing.assert_allclose(np.asarray(partial_sum), np.asarray(full),
                                   rtol=2e-4, atol=2e-5)

    def test_capacity_drops_are_counted(self):
        from repro.models.moe import _local_moe

        p, x = self._setup(t=128)
        out, lb, zl, drop = _local_moe(
            x, p.w_router, p.w_gate, p.w_up, p.w_down, 2, 0.25, 8
        )
        assert float(drop) > 0.0
        assert np.isfinite(np.asarray(out)).all()
