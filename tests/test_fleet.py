"""The serving fleet contract: ``spidr.serve`` end to end.

What this suite pins:

  * placement is a pure function of arrival order — two fleets fed the
    same submissions place every stream identically;
  * live cross-replica migration (``export_slot``/``import_slot``) is
    bit-exact: a migrated stream's readout, cycles and energy equal a
    never-migrated run's;
  * admission is bounded — past ``max_queue`` the fleet sheds with an
    explicit :class:`FleetOverloaded` reply, and recovers once capacity
    frees up;
  * a crashed replica's streams re-place deterministically (queue front,
    progress reset) and still finish bit-exact;
  * lifecycle edges: double ``close()`` is a no-op, ``submit()`` after
    ``shutdown()`` raises, duplicate rids raise, threaded fleets drain;
  * the pre-fleet server classes survive as deprecated-but-working shims.
"""
import functools
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs, spidr
from repro.configs import spidr_gesture
from repro.core.network import init_params
from repro.serving import (
    FleetOverloaded,
    ServeConfig,
    SessionScheduler,
    StreamRequest,
    StreamWorker,
)

HW, T = (16, 16), 6


@functools.lru_cache(maxsize=None)
def _compiled(chunk_T=3, capacity=2):
    spec = spidr_gesture.reduced(hw=HW, timesteps=T)
    params = init_params(jax.random.PRNGKey(0), spec)
    return spidr.compile(spec, params, spidr.DeployTarget(
        weight_bits=4, backend="jnp", chunk_T=chunk_T,
        stream_capacity=capacity))


def _streams(n, t=T, seed=1):
    rng = np.random.default_rng(seed)
    return [(rng.random((t,) + HW + (2,)) < 0.1).astype(np.float32)
            for _ in range(n)]


@functools.lru_cache(maxsize=None)
def _reference_readouts(n=6, t=T, seed=1):
    """Whole-stream ``CompiledSNN.run`` readouts — the exactness oracle."""
    ev = np.stack(_streams(n, t, seed), axis=1)
    return np.asarray(_compiled().run(jnp.asarray(ev)).readout)


def _serve_all(fleet, evs):
    handles = [fleet.submit(e, rid=i) for i, e in enumerate(evs)]
    fleet.drain()
    return handles


# ---------------------------------------------------------------------------
# ServeConfig validation.
# ---------------------------------------------------------------------------
class TestServeConfig:
    @pytest.mark.parametrize("kwargs", [
        dict(n_replicas=0),
        dict(max_queue=0),
        dict(placement="random"),
        dict(mode="async"),
        dict(batch=True, migrate_every=2),
        dict(capacity=-1),
        dict(chunk_T=0),
        dict(devices=42),
    ])
    def test_invalid_configs_raise(self, kwargs):
        with pytest.raises(ValueError):
            ServeConfig(**kwargs)

    def test_replica_list_count_mismatch(self):
        c = _compiled()
        with pytest.raises(ValueError, match="n_replicas"):
            spidr.serve([c, c], ServeConfig(n_replicas=3))
        with pytest.raises(ValueError):
            spidr.serve([], ServeConfig())

    def test_device_list_length_mismatch(self):
        with pytest.raises(ValueError, match="device"):
            spidr.serve(_compiled(), ServeConfig(
                n_replicas=2, devices=[None]))


# ---------------------------------------------------------------------------
# Deterministic placement.
# ---------------------------------------------------------------------------
class TestPlacement:
    def test_same_arrival_order_places_identically(self):
        evs = _streams(6)

        def run():
            fleet = spidr.serve(_compiled(), ServeConfig(
                n_replicas=2, capacity=2, chunk_T=3))
            hs = _serve_all(fleet, evs)
            placements = {h.rid: list(h.placements) for h in hs}
            fleet.shutdown()
            return placements

        assert run() == run()

    def test_least_loaded_prefers_emptier_replica(self):
        fleet = spidr.serve(_compiled(), ServeConfig(
            n_replicas=2, capacity=2, chunk_T=3))
        hs = [fleet.submit(e, rid=i) for i, e in enumerate(_streams(3))]
        fleet.step()
        # 3 streams over 2x2 slots: replicas 0,1,0 in arrival order.
        assert [h.replica for h in hs] == [0, 1, 0]
        fleet.shutdown()

    def test_round_robin_policy_cycles(self):
        fleet = spidr.serve(_compiled(), ServeConfig(
            n_replicas=2, capacity=2, chunk_T=3, placement="round-robin"))
        hs = [fleet.submit(e, rid=i) for i, e in enumerate(_streams(4))]
        fleet.step()
        assert [h.replica for h in hs] == [0, 1, 0, 1]
        fleet.shutdown()

    def test_results_match_whole_stream_reference(self):
        fleet = spidr.serve(_compiled(), ServeConfig(
            n_replicas=2, capacity=2, chunk_T=3))
        hs = _serve_all(fleet, _streams(6))
        ref = _reference_readouts()
        for h in hs:
            assert h.done and h.timesteps == T
            np.testing.assert_array_equal(np.asarray(h.readout), ref[h.rid])
        fleet.shutdown()


# ---------------------------------------------------------------------------
# Live migration (the PR-6 snapshot path, per slot).
# ---------------------------------------------------------------------------
class TestMigration:
    def test_mid_stream_migration_is_bit_exact(self):
        evs = _streams(3)
        # Reference: same arrival order, single replica, no migration.
        ref_fleet = spidr.serve(_compiled(), ServeConfig(
            n_replicas=1, capacity=2, chunk_T=3))
        ref = {h.rid: (np.asarray(h.readout).copy(), h.cycles, h.energy_uj)
               for h in _serve_all(ref_fleet, evs)}
        ref_fleet.shutdown()

        fleet = spidr.serve(_compiled(), ServeConfig(
            n_replicas=2, capacity=2, chunk_T=3))
        hs = [fleet.submit(e, rid=i) for i, e in enumerate(evs)]
        fleet.step()  # every stream mid-flight (1 of 2 chunks delivered)
        moved = next(h for h in hs if h.status == "running")
        dst = fleet.migrate(moved.rid)
        assert moved.replica == dst and moved.migrations == 1
        fleet.drain()
        for h in hs:
            r, cyc, uj = ref[h.rid]
            np.testing.assert_array_equal(np.asarray(h.readout), r)
            assert (h.cycles, h.energy_uj) == (cyc, uj)
        assert fleet.migrations == 1
        fleet.shutdown()

    def test_migrate_unknown_or_finished_stream_raises(self):
        fleet = spidr.serve(_compiled(), ServeConfig(
            n_replicas=2, capacity=2, chunk_T=3))
        with pytest.raises(ValueError, match="no stream"):
            fleet.migrate()
        with pytest.raises(ValueError, match="not running"):
            fleet.migrate(99)
        fleet.shutdown()

    def test_batch_fleet_rejects_migration(self):
        fleet = spidr.serve(_compiled(), ServeConfig(
            n_replicas=2, capacity=2, batch=True))
        with pytest.raises(RuntimeError, match="batch"):
            fleet.migrate()
        fleet.shutdown()

    def test_export_import_slot_roundtrip(self):
        ev = _streams(1)[0]
        a = _compiled().open_stream(capacity=2, chunk_T=3)
        b = _compiled().open_stream(capacity=2, chunk_T=3)
        slot = a.open()
        first = a.step({slot: ev[:3]})[slot]
        payload = a.export_slot(slot)
        new_slot = b.import_slot(payload)
        a.close(slot)
        rest = b.step({new_slot: ev[3:]})[new_slot]
        ref = _reference_readouts(1)  # bank seed matches stream 0
        assert first.timesteps == 3 and rest.timesteps == T
        np.testing.assert_array_equal(np.asarray(rest.readout), ref[0])
        a.close()
        b.close()

    def test_export_slot_requires_live_stream(self):
        sess = _compiled().open_stream(capacity=2, chunk_T=3)
        with pytest.raises(ValueError):
            sess.export_slot(0)
        sess.close()


# ---------------------------------------------------------------------------
# Bounded admission + explicit shedding.
# ---------------------------------------------------------------------------
class TestShedding:
    def test_overloaded_submit_sheds_explicitly(self):
        fleet = spidr.serve(_compiled(), ServeConfig(
            n_replicas=1, capacity=1, chunk_T=3, max_queue=2))
        evs = _streams(4)
        fleet.submit(evs[0], rid=0)
        fleet.submit(evs[1], rid=1)
        with pytest.raises(FleetOverloaded, match="queue is full"):
            fleet.submit(evs[2], rid=2)
        assert fleet.shed == 1
        # Shed streams are not admitted: rid 2 never appears.
        assert set(fleet.handles) == {0, 1}
        # Capacity frees after a drain; the same rid can re-enter.
        fleet.drain()
        h = fleet.submit(evs[2], rid=2)
        fleet.drain()
        assert h.done
        np.testing.assert_array_equal(
            np.asarray(h.readout), _reference_readouts(4)[2])
        fleet.shutdown()

    def test_scheduler_counts_and_queue_bound(self):
        sched = SessionScheduler([], max_queue=1)
        req = StreamRequest(rid=0, events=np.zeros((3,) + HW + (2,),
                                                   np.float32))

        class H:
            rid, request, status = 0, req, "queued"

        sched.admit(H())
        with pytest.raises(FleetOverloaded):
            sched.admit(H())
        assert (sched.submitted, sched.shed, sched.queue_depth) == (1, 1, 1)


# ---------------------------------------------------------------------------
# Replica crash -> deterministic re-placement.
# ---------------------------------------------------------------------------
class TestCrashReplacement:
    def test_killed_replicas_streams_replay_bit_exact(self):
        evs = _streams(6)
        fleet = spidr.serve(_compiled(), ServeConfig(
            n_replicas=2, capacity=2, chunk_T=3))
        hs = [fleet.submit(e, rid=i) for i, e in enumerate(evs)]
        fleet.step()  # streams mid-flight on both replicas
        requeued = fleet.kill_replica(0)
        assert requeued and all(h.status == "queued" for h in requeued)
        assert fleet.crashes == 1
        fleet.drain()
        ref = _reference_readouts()
        for h in hs:
            assert h.done
            np.testing.assert_array_equal(np.asarray(h.readout), ref[h.rid])
            # Nothing lands on the dead replica after the crash.
            assert h.placements[-1][0] == 1
        fleet.shutdown()

    def test_kill_is_idempotent_and_all_dead_fails_loudly(self):
        fleet = spidr.serve(_compiled(), ServeConfig(
            n_replicas=1, capacity=2, chunk_T=3))
        fleet.submit(_streams(1)[0], rid=0)
        fleet.kill_replica(0)
        assert fleet.kill_replica(0) == []
        with pytest.raises(RuntimeError, match="dead"):
            fleet.drain()
        fleet.shutdown()


# ---------------------------------------------------------------------------
# Lifecycle edges (bugfix sweep).
# ---------------------------------------------------------------------------
class TestLifecycle:
    def test_submit_after_shutdown_raises(self):
        fleet = spidr.serve(_compiled(), ServeConfig(capacity=2, chunk_T=3))
        fleet.shutdown()
        fleet.shutdown()  # idempotent
        with pytest.raises(RuntimeError, match="shut down"):
            fleet.submit(_streams(1)[0])

    def test_duplicate_rid_rejected(self):
        with spidr.serve(_compiled(),
                         ServeConfig(capacity=2, chunk_T=3)) as fleet:
            fleet.submit(_streams(1)[0], rid=7)
            with pytest.raises(ValueError, match="already submitted"):
                fleet.submit(_streams(1)[0], rid=7)
            fleet.drain()
        assert fleet.closed  # the with-block shut it down

    def test_stream_yields_incremental_progress(self):
        fleet = spidr.serve(_compiled(), ServeConfig(capacity=2, chunk_T=3))
        h = fleet.submit(_streams(1)[0], rid=0)
        updates = list(fleet.stream(h))
        assert [u.timesteps for u in updates] == [3, 6]
        assert updates[-1].status == "done"
        np.testing.assert_array_equal(
            np.asarray(updates[-1].readout), _reference_readouts(1)[0])
        fleet.shutdown()

    def test_double_close_session_is_noop(self):
        sess = _compiled().open_stream(capacity=2, chunk_T=3)
        slot = sess.open()
        sess.close(slot)
        sess.close(slot)  # per-slot double close: no-op
        sess.close()
        sess.close()      # whole-session double close: no-op
        assert sess.closed
        with pytest.raises(RuntimeError, match="closed StreamSession"):
            sess.open()
        with pytest.raises(RuntimeError, match="closed StreamSession"):
            sess.step({})

    def test_iter_chunks_serves_and_frees_its_slot(self):
        ev = _streams(1)[0]
        with _compiled().open_stream(capacity=2, chunk_T=3) as sess:
            ups = list(sess.iter_chunks(ev))
            assert [u.timesteps for u in ups] == [3, 6]
            np.testing.assert_array_equal(
                np.asarray(ups[-1].readout), _reference_readouts(1)[0])
            assert sess.occupancy == 0  # the helper closed its own slot
        assert sess.closed


# ---------------------------------------------------------------------------
# Threaded mode.
# ---------------------------------------------------------------------------
class TestThreadedMode:
    def test_threaded_fleet_drains_bit_exact(self):
        fleet = spidr.serve(_compiled(), ServeConfig(
            n_replicas=2, capacity=2, chunk_T=3, mode="threaded"))
        hs = [fleet.submit(e, rid=i) for i, e in enumerate(_streams(6))]
        fleet.drain(timeout=120)
        ref = _reference_readouts()
        for h in hs:
            np.testing.assert_array_equal(np.asarray(h.readout), ref[h.rid])
        with pytest.raises(RuntimeError, match="threaded"):
            fleet.step()
        fleet.shutdown()


# ---------------------------------------------------------------------------
# Fleet telemetry.
# ---------------------------------------------------------------------------
class TestFleetTelemetry:
    def test_fleet_metrics_flow_through_the_registry(self):
        prev = obs.default_registry()
        obs.set_default_registry(obs.MetricsRegistry(enabled=True))
        try:
            fleet = spidr.serve(_compiled(), ServeConfig(
                n_replicas=2, capacity=2, chunk_T=3))
            hs = [fleet.submit(e, rid=i) for i, e in enumerate(_streams(3))]
            fleet.step()
            fleet.migrate(next(h.rid for h in hs
                               if h.status == "running"))
            fleet.drain()
            fleet.shutdown()
            d = obs.default_registry().to_dict()
            assert d["spidr_fleet_submitted_total"][0]["value"] == 3.0
            assert d["spidr_fleet_completed_total"][0]["value"] == 3.0
            assert d["spidr_fleet_migrations_total"][0]["value"] == 1.0
            assert "spidr_fleet_tick_seconds" in d
            assert "spidr_fleet_stream_latency_seconds" in d
            assert "spidr_serve_admissions_total" in d  # worker-level
        finally:
            obs.set_default_registry(prev)


# ---------------------------------------------------------------------------
# Deprecated shims (the old public serving surface).
# ---------------------------------------------------------------------------
class TestDeprecatedShims:
    def test_old_names_warn_but_serve(self):
        from repro.launch import serve as launch_serve

        assert launch_serve.SNNRequest is StreamRequest
        with pytest.warns(DeprecationWarning, match="StreamingSNNServer"):
            srv = launch_serve.StreamingSNNServer(
                _compiled(), capacity=2, chunk_T=3)
        assert isinstance(srv, StreamWorker)
        ev = _streams(1)[0]
        srv.submit(StreamRequest(rid=0, events=ev))
        while srv.step():
            pass
        np.testing.assert_array_equal(
            np.asarray(srv.done[0].readout), _reference_readouts(1)[0])
        srv.shutdown()
        with pytest.warns(DeprecationWarning, match="SNNServer"):
            batch = launch_serve.SNNServer(_compiled(), capacity=2)
        batch.submit(StreamRequest(rid=0, events=ev))
        while batch.step():
            pass
        assert len(batch.done) == 1

    def test_new_path_does_not_warn(self):
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            fleet = spidr.serve(_compiled(), ServeConfig(
                capacity=2, chunk_T=3))
            fleet.submit(_streams(1)[0], rid=0)
            fleet.drain()
            fleet.shutdown()
