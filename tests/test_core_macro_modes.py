"""Tests: CIM macro semantics (C1) + operating modes (C6) + paper constants."""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # dev extra absent: property tests skip, rest run
    from _hypothesis_stub import given, settings, st

from repro.core import modes
from repro.core.cim_macro import (
    IFSPAD_COLS,
    IFSPAD_ROWS,
    NEURON_MACRO_CYCLES,
    MacroConfig,
    accumulate,
    accumulate_sequential,
    macro_cycles,
    pack_weight_rows,
)
from repro.core.modes import CoreConfig, LayerShape, map_layer
from repro.core.quant import QuantSpec


class TestMacroGeometry:
    def test_eq3_neuron_cycles(self):
        assert NEURON_MACRO_CYCLES == 66  # Eq. (3): 2*32 + 2

    def test_eq1_output_neurons_per_macro(self):
        # Eq. (1): (48/W_b) * 16
        for bits, want in [(4, 192), (6, 128), (8, 96)]:
            assert MacroConfig(QuantSpec(bits)).max_output_neurons == want

    def test_pack_rejects_overflow(self):
        cfg = MacroConfig(QuantSpec(4))
        with pytest.raises(ValueError):
            pack_weight_rows(jnp.zeros((129, 12)), cfg)
        with pytest.raises(ValueError):
            pack_weight_rows(jnp.zeros((128, 13)), cfg)


class TestAccumulate:
    @pytest.mark.parametrize("bits", [4, 6, 8])
    def test_matches_sequential_no_overflow(self, bits):
        """Vectorized == silicon-order when no intermediate saturation."""
        spec = QuantSpec(bits)
        rng = np.random.default_rng(bits)
        spikes = (rng.random((IFSPAD_ROWS, IFSPAD_COLS)) < 0.05).astype(np.int8)
        w = rng.integers(-2, 3, (IFSPAD_ROWS, spec.neurons_per_row)).astype(np.int8)
        v0 = np.zeros((IFSPAD_COLS, spec.neurons_per_row), np.int32)
        seq = accumulate_sequential(spikes, w, v0, spec)
        vec = np.asarray(accumulate(jnp.array(spikes), jnp.array(w), jnp.array(v0), spec))
        np.testing.assert_array_equal(seq, vec)

    def test_saturation_stays_in_range(self):
        spec = QuantSpec(4)
        rng = np.random.default_rng(7)
        spikes = (rng.random((128, 16)) < 0.5).astype(np.int8)  # dense -> overflow
        w = rng.integers(spec.w_min, spec.w_max + 1, (128, 12)).astype(np.int8)
        v0 = np.zeros((16, 12), np.int32)
        for out in (
            accumulate_sequential(spikes, w, v0, spec),
            np.asarray(accumulate(jnp.array(spikes), jnp.array(w), jnp.array(v0), spec)),
        ):
            assert out.min() >= spec.v_min and out.max() <= spec.v_max

    @given(st.floats(min_value=0.0, max_value=0.3), st.integers(0, 2**31 - 1))
    @settings(max_examples=20, deadline=None)
    def test_zero_vmem_when_no_spikes_property(self, density, seed):
        spec = QuantSpec(4)
        rng = np.random.default_rng(seed)
        spikes = (rng.random((128, 16)) < density).astype(np.int8)
        w = rng.integers(-2, 3, (128, 12)).astype(np.int8)
        out = np.asarray(
            accumulate(jnp.array(spikes), jnp.array(w),
                       jnp.zeros((16, 12), jnp.int32), spec)
        )
        # Columns with zero spikes anywhere contribute nothing.
        empty_cols = spikes.sum(axis=0) == 0
        assert (out[empty_cols] == 0).all()

    def test_macro_cycles(self):
        assert macro_cycles(0) == 0
        assert macro_cycles(10) == 22  # 2 ops/spike + fill


class TestModes:
    def test_paper_cross_checks(self):
        # Table III footnotes at 4-bit
        assert modes.max_output_neurons_conv_mode1(QuantSpec(4)) == 576
        assert modes.max_input_neurons_fc_mode2() == 1152

    def test_mode1_small_fanin(self):
        core = CoreConfig(QuantSpec(4))
        m = map_layer(LayerShape.conv(3, 3, 2, 16, 64, 64), core)  # fan-in 18
        assert m.mode == 1 and m.pipelines == 3
        assert m.parallel_channels == 36  # Eq. (2): 3 * 12

    def test_mode2_large_fanin(self):
        core = CoreConfig(QuantSpec(4))
        m = map_layer(LayerShape.conv(3, 3, 64, 32, 32, 32), core)  # fan-in 576
        assert m.mode == 2 and m.pipelines == 1
        assert m.parallel_channels == 12  # Eq. (2): 48/4

    def test_fc_uses_one_vmem_pair(self):
        core = CoreConfig(QuantSpec(4))
        m = map_layer(LayerShape.fc(512, 11), core)
        assert m.vmem_pairs_used == 1

    def test_fanin_beyond_mode2_tiles(self):
        core = CoreConfig(QuantSpec(4))
        m = map_layer(LayerShape.fc(3000, 10), core)  # > 1152
        assert m.fan_in_tiles >= 2

    @pytest.mark.parametrize("bits,chs", [(4, 36), (6, 24), (8, 18)])
    def test_eq2_mode1_channels(self, bits, chs):
        core = CoreConfig(QuantSpec(bits))
        m = map_layer(LayerShape.conv(3, 3, 2, 64, 8, 8), core)
        assert m.parallel_channels == chs

    def test_paper_network_layers_map(self):
        """Every layer of both Table II networks must map."""
        from repro.core.network import gesture_net, optical_flow_net

        core = CoreConfig(QuantSpec(4))
        for spec in (gesture_net(), optical_flow_net()):
            for shape in spec.layer_shapes():
                m = map_layer(shape, core)
                assert m.total_passes >= 1

    def test_multicore_config_rejected(self):
        """n_cores > 1 must not be silently ignored: map_layer maps one
        core; the error points at the compiler entry point."""
        core = CoreConfig(QuantSpec(4), n_cores=4)
        with pytest.raises(ValueError, match="compile_network"):
            map_layer(LayerShape.fc(64, 11), core)

    @pytest.mark.parametrize("bits", [4, 6, 8])
    def test_mode_boundary_fanin_384(self, bits):
        """fan_in == 128*3 is the last Mode-1 shape; +1 tips into Mode 2,
        at every precision pair (the partitioner slices right up to these
        edges)."""
        core = CoreConfig(QuantSpec(bits))
        at = map_layer(LayerShape.fc(128 * 3, 8), core)
        assert at.mode == 1 and at.pipelines == 3
        assert at.fan_in_tiles == 1
        assert at.rows_per_macro == 128          # exactly full macros
        assert at.parallel_channels == 3 * (48 // bits)
        over = map_layer(LayerShape.fc(128 * 3 + 1, 8), core)
        assert over.mode == 2 and over.pipelines == 1
        assert over.fan_in_tiles == 1
        assert over.parallel_channels == 48 // bits

    @pytest.mark.parametrize("bits", [4, 6, 8])
    def test_mode_boundary_fanin_1152(self, bits):
        """fan_in == 128*9 fills Mode 2 exactly; +1 forces sequential
        fan-in tiling, at every precision pair."""
        core = CoreConfig(QuantSpec(bits))
        at = map_layer(LayerShape.fc(128 * 9, 8), core)
        assert at.mode == 2 and at.fan_in_tiles == 1
        assert at.rows_per_macro == 128
        over = map_layer(LayerShape.fc(128 * 9 + 1, 8), core)
        assert over.mode == 2 and over.fan_in_tiles == 2
        # Balanced tiling (Sec II-F): both tiles near-equal rows.
        assert over.rows_per_macro == 65

    @pytest.mark.parametrize("bits,vbits,chs", [(4, 7, 12), (6, 11, 8),
                                                (8, 15, 6)])
    def test_precision_pairs(self, bits, vbits, chs):
        """All three supported weight/Vmem pairs and their row packing."""
        spec = QuantSpec(bits)
        assert spec.vmem_bits == vbits
        assert spec.neurons_per_row == chs
        core = CoreConfig(spec)
        m = map_layer(LayerShape.conv(3, 3, 16, 48, 8, 8), core)  # fan-in 144
        assert m.mode == 1
        assert m.parallel_channels == 3 * chs
        assert m.channel_tiles == -(-48 // (3 * chs))

    def test_force_mode_override(self):
        """The compiler's selector can force Mode 2 below the Mode-1 cap
        (and Mode 1 above it, with fan-in tiling)."""
        core = CoreConfig(QuantSpec(4))
        small = LayerShape.fc(100, 8)
        forced2 = map_layer(small, core, force_mode=2)
        assert forced2.mode == 2 and forced2.pipelines == 1
        big = LayerShape.fc(500, 8)
        forced1 = map_layer(big, core, force_mode=1)
        assert forced1.mode == 1 and forced1.fan_in_tiles == 2
        with pytest.raises(ValueError):
            map_layer(small, core, force_mode=3)
