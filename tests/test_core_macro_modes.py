"""Tests: CIM macro semantics (C1) + operating modes (C6) + paper constants."""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # dev extra absent: property tests skip, rest run
    from _hypothesis_stub import given, settings, st

from repro.core import cim_macro, modes
from repro.core.cim_macro import (
    CM_COLS,
    CM_WEIGHT_ROWS,
    IFSPAD_COLS,
    IFSPAD_ROWS,
    NEURON_MACRO_CYCLES,
    MacroConfig,
    accumulate,
    accumulate_sequential,
    macro_cycles,
    pack_weight_rows,
)
from repro.core.modes import CoreConfig, LayerShape, map_layer
from repro.core.quant import QuantSpec


class TestMacroGeometry:
    def test_eq3_neuron_cycles(self):
        assert NEURON_MACRO_CYCLES == 66  # Eq. (3): 2*32 + 2

    def test_eq1_output_neurons_per_macro(self):
        # Eq. (1): (48/W_b) * 16
        for bits, want in [(4, 192), (6, 128), (8, 96)]:
            assert MacroConfig(QuantSpec(bits)).max_output_neurons == want

    def test_pack_rejects_overflow(self):
        cfg = MacroConfig(QuantSpec(4))
        with pytest.raises(ValueError):
            pack_weight_rows(jnp.zeros((129, 12)), cfg)
        with pytest.raises(ValueError):
            pack_weight_rows(jnp.zeros((128, 13)), cfg)


class TestAccumulate:
    @pytest.mark.parametrize("bits", [4, 6, 8])
    def test_matches_sequential_no_overflow(self, bits):
        """Vectorized == silicon-order when no intermediate saturation."""
        spec = QuantSpec(bits)
        rng = np.random.default_rng(bits)
        spikes = (rng.random((IFSPAD_ROWS, IFSPAD_COLS)) < 0.05).astype(np.int8)
        w = rng.integers(-2, 3, (IFSPAD_ROWS, spec.neurons_per_row)).astype(np.int8)
        v0 = np.zeros((IFSPAD_COLS, spec.neurons_per_row), np.int32)
        seq = accumulate_sequential(spikes, w, v0, spec)
        vec = np.asarray(accumulate(jnp.array(spikes), jnp.array(w), jnp.array(v0), spec))
        np.testing.assert_array_equal(seq, vec)

    def test_saturation_stays_in_range(self):
        spec = QuantSpec(4)
        rng = np.random.default_rng(7)
        spikes = (rng.random((128, 16)) < 0.5).astype(np.int8)  # dense -> overflow
        w = rng.integers(spec.w_min, spec.w_max + 1, (128, 12)).astype(np.int8)
        v0 = np.zeros((16, 12), np.int32)
        for out in (
            accumulate_sequential(spikes, w, v0, spec),
            np.asarray(accumulate(jnp.array(spikes), jnp.array(w), jnp.array(v0), spec)),
        ):
            assert out.min() >= spec.v_min and out.max() <= spec.v_max

    @given(st.floats(min_value=0.0, max_value=0.3), st.integers(0, 2**31 - 1))
    @settings(max_examples=20, deadline=None)
    def test_zero_vmem_when_no_spikes_property(self, density, seed):
        spec = QuantSpec(4)
        rng = np.random.default_rng(seed)
        spikes = (rng.random((128, 16)) < density).astype(np.int8)
        w = rng.integers(-2, 3, (128, 12)).astype(np.int8)
        out = np.asarray(
            accumulate(jnp.array(spikes), jnp.array(w),
                       jnp.zeros((16, 12), jnp.int32), spec)
        )
        # Columns with zero spikes anywhere contribute nothing.
        empty_cols = spikes.sum(axis=0) == 0
        assert (out[empty_cols] == 0).all()

    def test_macro_cycles(self):
        assert macro_cycles(0) == 0
        assert macro_cycles(10) == 22  # 2 ops/spike + fill


class TestModes:
    def test_paper_cross_checks(self):
        # Table III footnotes at 4-bit
        assert modes.max_output_neurons_conv_mode1(QuantSpec(4)) == 576
        assert modes.max_input_neurons_fc_mode2() == 1152

    def test_mode1_small_fanin(self):
        core = CoreConfig(QuantSpec(4))
        m = map_layer(LayerShape.conv(3, 3, 2, 16, 64, 64), core)  # fan-in 18
        assert m.mode == 1 and m.pipelines == 3
        assert m.parallel_channels == 36  # Eq. (2): 3 * 12

    def test_mode2_large_fanin(self):
        core = CoreConfig(QuantSpec(4))
        m = map_layer(LayerShape.conv(3, 3, 64, 32, 32, 32), core)  # fan-in 576
        assert m.mode == 2 and m.pipelines == 1
        assert m.parallel_channels == 12  # Eq. (2): 48/4

    def test_fc_uses_one_vmem_pair(self):
        core = CoreConfig(QuantSpec(4))
        m = map_layer(LayerShape.fc(512, 11), core)
        assert m.vmem_pairs_used == 1

    def test_fanin_beyond_mode2_tiles(self):
        core = CoreConfig(QuantSpec(4))
        m = map_layer(LayerShape.fc(3000, 10), core)  # > 1152
        assert m.fan_in_tiles >= 2

    @pytest.mark.parametrize("bits,chs", [(4, 36), (6, 24), (8, 18)])
    def test_eq2_mode1_channels(self, bits, chs):
        core = CoreConfig(QuantSpec(bits))
        m = map_layer(LayerShape.conv(3, 3, 2, 64, 8, 8), core)
        assert m.parallel_channels == chs

    def test_paper_network_layers_map(self):
        """Every layer of both Table II networks must map."""
        from repro.core.network import gesture_net, optical_flow_net

        core = CoreConfig(QuantSpec(4))
        for spec in (gesture_net(), optical_flow_net()):
            for shape in spec.layer_shapes():
                m = map_layer(shape, core)
                assert m.total_passes >= 1
