"""Public-surface contract: ``repro``/``repro.spidr`` export exactly this.

The facade is the API; these tests pin it.  An accidental addition to (or
removal from) ``__all__`` fails here — growing the public surface is a
deliberate act that updates EXPECTED in the same commit.
"""
import importlib

import pytest

import repro
from repro import spidr

EXPECTED_REPRO = {
    # The deployment facade.
    "spidr",
    "CompiledSNN",
    "DeployTarget",
    "StreamSession",
    "VerifyReport",
    # The serving fleet (spidr.serve).
    "Fleet",
    "ServeConfig",
    # Network construction.
    "SNNSpec",
    "gesture_net",
    "optical_flow_net",
    "init_params",
    # Precision configuration.
    "QuantSpec",
    "SUPPORTED_PRECISIONS",
    # Trained integer artifact.
    "ExportedNetwork",
}

EXPECTED_SPIDR = {
    "BACKENDS",
    "CompiledSNN",
    "DeployTarget",
    "Fleet",
    "FleetOverloaded",
    "PRECISION_PAIRS",
    "ServeConfig",
    "SlotUpdate",
    "StreamHandle",
    "StreamSession",
    "VerifyReport",
    "compile",
    "load",
    "read_snapshot_meta",
    "restore",
    "serve",
}


class TestPublicSurface:
    def test_repro_all_is_exactly_the_contract(self):
        assert set(repro.__all__) == EXPECTED_REPRO, (
            "repro.__all__ drifted from the public-surface contract — "
            "additions/removals must update tests/test_public_api.py "
            "deliberately")

    def test_spidr_all_is_exactly_the_contract(self):
        assert set(spidr.__all__) == EXPECTED_SPIDR

    @pytest.mark.parametrize("module,name", sorted(
        [("repro", n) for n in EXPECTED_REPRO]
        + [("repro.spidr", n) for n in EXPECTED_SPIDR]))
    def test_every_exported_symbol_imports(self, module, name):
        mod = importlib.import_module(module)
        assert getattr(mod, name) is not None

    def test_no_duplicate_exports(self):
        assert len(repro.__all__) == len(set(repro.__all__))
        assert len(spidr.__all__) == len(set(spidr.__all__))

    def test_facade_symbols_are_the_same_objects(self):
        """Top-level re-exports alias the spidr package's objects."""
        assert repro.CompiledSNN is spidr.CompiledSNN
        assert repro.DeployTarget is spidr.DeployTarget
        assert repro.StreamSession is spidr.StreamSession
        assert repro.VerifyReport is spidr.VerifyReport
        assert repro.Fleet is spidr.Fleet
        assert repro.ServeConfig is spidr.ServeConfig
