"""Continuous-batching server logic: admission, slot reuse, completion."""
import numpy as np
import jax
import pytest

from repro.configs import get_config
from repro.launch.serve import Request, Server
from repro.models.model import init_params


@pytest.fixture(scope="module")
def server_setup():
    cfg = get_config("qwen1.5-0.5b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_all_requests_complete(server_setup):
    cfg, params = server_setup
    server = Server(cfg, params, capacity=3, ctx_len=48)
    rng = np.random.default_rng(0)
    n_req, max_new = 7, 5
    for r in range(n_req):
        server.submit(Request(
            rid=r, prompt=rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
            max_new=max_new,
        ))
    steps = 0
    while server.step():
        steps += 1
        assert steps < 200, "server did not drain"
    assert len(server.done) == n_req
    for req in server.done:
        assert len(req.generated) == max_new
        assert req.first_token_at is not None and req.done_at is not None
        # generated ids are valid vocab entries (pad logits masked to -inf)
        assert all(0 <= t < cfg.padded_vocab for t in req.generated)


def test_slot_reuse_beyond_capacity(server_setup):
    """More requests than slots forces continuous-batching slot reuse."""
    cfg, params = server_setup
    server = Server(cfg, params, capacity=2, ctx_len=32)
    rng = np.random.default_rng(1)
    for r in range(5):
        server.submit(Request(
            rid=r, prompt=rng.integers(0, cfg.vocab_size, 4).astype(np.int32),
            max_new=3,
        ))
    while server.step():
        pass
    assert len(server.done) == 5
    assert all(s is None for s in server.slots)  # all slots freed
