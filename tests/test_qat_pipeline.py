"""Train->deploy QAT pipeline: deploy-exact QAT mode, integer export,
checkpoint round trips, bit-exact parity between the post-STE training
graph and the compiled integer engine (1 and 4 cores, chunk_T in {1, T}),
and the benchmark regression gate."""
import dataclasses
import importlib.util
import json
import os
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import Checkpointer
from repro.core.network import gesture_net, init_params, optical_flow_net, run_snn
from repro.core.quant import QuantSpec, po2_quantize, requantize_threshold
from repro.engine import (
    EngineConfig,
    init_state,
    run_chunk,
    run_engine,
)
from repro.snn.export import (
    deploy,
    dequantize_readout,
    export_network,
    load_exported,
    save_exported,
    verify_roundtrip,
)
from repro.snn.train import (
    TrainConfig,
    effective_spec,
    fit,
    precision_sweep,
)


def reduced_gesture(hw=(16, 16), timesteps=4):
    return dataclasses.replace(gesture_net(), input_hw=hw, timesteps=timesteps)


def reduced_flow(hw=(16, 16), timesteps=3):
    return dataclasses.replace(optical_flow_net(), input_hw=hw,
                               timesteps=timesteps)


def events_for(spec, batch=2, seed=1, density=0.1):
    shape = (spec.timesteps, batch) + spec.input_hw + (2,)
    u = jax.random.uniform(jax.random.PRNGKey(seed), shape)
    return (u < density).astype(jnp.float32)


class TestPo2Quantization:
    @pytest.mark.parametrize("bits", [4, 6, 8])
    def test_scales_are_powers_of_two(self, bits):
        spec = QuantSpec(bits)
        w = jax.random.normal(jax.random.PRNGKey(0), (18, 16)) * 0.3
        q, scale = po2_quantize(w, spec, axis=0)
        exps = np.log2(np.asarray(scale))
        np.testing.assert_array_equal(exps, np.round(exps))
        assert int(np.asarray(q).min()) >= spec.w_min
        assert int(np.asarray(q).max()) <= spec.w_max

    def test_grid_covers_amax(self):
        spec = QuantSpec(4)
        w = jnp.array([[0.9, -1.7, 0.0]])
        q, scale = po2_quantize(w, spec, axis=0)
        deq = np.asarray(q, np.float32) * np.asarray(scale)
        # Quantization error bounded by half a step per channel.
        assert np.all(np.abs(deq - np.asarray(w)) <= np.asarray(scale)[0] / 2)
        # All-zero channel gets the neutral scale 1.0.
        assert float(np.asarray(scale)[0, 2]) == 1.0

    def test_threshold_requantization_exact(self):
        spec = QuantSpec(6)
        scale = jnp.asarray([0.25, 0.015625])  # powers of two
        thr_int, thr_scaled = requantize_threshold(0.5, scale, spec)
        np.testing.assert_array_equal(np.asarray(thr_int), [2, 32])
        np.testing.assert_array_equal(np.asarray(thr_scaled),
                                      np.asarray(thr_int) * np.asarray(scale))


class TestDeployExactParity:
    """run_snn(mode="qat") must equal the deployed integer engine exactly."""

    @pytest.mark.parametrize("bits", [4, 6, 8])
    def test_gesture_parity_1_and_4_cores(self, bits):
        spec = reduced_gesture()
        params = init_params(jax.random.PRNGKey(bits), spec)
        ev = events_for(spec)
        exported = export_network(params, spec, QuantSpec(bits))
        for n_cores in (1, 4):
            engine = deploy(exported, spec, n_cores=n_cores)
            rt = verify_roundtrip(params, spec, engine, ev, exported)
            assert rt.exact, (bits, n_cores, rt)

    def test_flow_parity_soft_reset_if(self):
        spec = reduced_flow()
        params = init_params(jax.random.PRNGKey(0), spec)
        ev = events_for(spec, density=0.15)
        exported = export_network(params, spec, QuantSpec(4))
        rt = verify_roundtrip(params, spec, deploy(exported, spec), ev,
                              exported)
        assert rt.exact, rt

    def test_vmem_readout_dequantizes_exactly(self):
        spec = reduced_flow()
        params = init_params(jax.random.PRNGKey(2), spec)
        ev = events_for(spec, density=0.15)
        qspec = QuantSpec(6)
        exported = export_network(params, spec, qspec)
        out = run_engine(deploy(exported, spec), ev)
        qat_out, _ = run_snn(params, ev, spec, qspec, mode="qat")
        deq = dequantize_readout(exported, spec, out.readout)
        np.testing.assert_array_equal(np.asarray(deq), np.asarray(qat_out))

    def test_fused_backend_matches_jnp(self):
        spec = reduced_gesture(timesteps=2)
        params = init_params(jax.random.PRNGKey(3), spec)
        ev = events_for(spec, batch=1)
        exported = export_network(params, spec, QuantSpec(4))
        a = run_engine(deploy(exported, spec), ev)
        fused_cfg = EngineConfig(QuantSpec(4), backend="fused",
                                 interpret=True, block=(32, 32, 32))
        b = run_engine(deploy(exported, spec, cfg=fused_cfg), ev)
        np.testing.assert_array_equal(np.asarray(a.readout),
                                      np.asarray(b.readout))
        np.testing.assert_array_equal(np.asarray(a.spike_counts),
                                      np.asarray(b.spike_counts))

    def test_qat_mode_gradients_flow(self):
        spec = reduced_gesture(timesteps=2)
        params = init_params(jax.random.PRNGKey(4), spec)
        ev = events_for(spec)

        def loss(p):
            out, _ = run_snn(p, ev, spec, QuantSpec(4), mode="qat")
            return jnp.sum(out)

        grads = jax.grad(loss)(params)
        for g, l in zip(grads, spec.layers):
            if l.kind in ("conv", "fc"):
                assert g is not None and bool(jnp.any(g != 0)), l.kind


class TestTrainedExportDeploy:
    """Acceptance: train (smoke budget) -> export -> checkpoint -> reload ->
    deploy on 1 and 4 cores, bit-exact vs the training graph, chunked."""

    @pytest.mark.parametrize("bits", [4, 6, 8])
    def test_full_pipeline(self, bits, tmp_path):
        spec0 = gesture_net()
        cfg = TrainConfig(weight_bits=bits, lr=2e-3, steps=3, warmup=0,
                          batch=2, hw=(16, 16), timesteps=3, seed=bits)
        state, history = fit(spec0, cfg, log_every=0)
        assert all(np.isfinite(history["loss"]))
        spec = effective_spec(spec0, cfg)
        qspec = QuantSpec(bits)

        exported = export_network(state.params, spec, qspec)
        ckpt = Checkpointer(str(tmp_path / "exported"))
        save_exported(ckpt, step=cfg.steps, exported=exported)
        reloaded = load_exported(ckpt, spec)
        assert reloaded.weight_bits == bits
        for ex, re_ in zip(exported.layers, reloaded.layers):
            if ex is None:
                assert re_ is None
                continue
            np.testing.assert_array_equal(ex.w_q, re_.w_q)
            np.testing.assert_array_equal(ex.scale, re_.scale)
            np.testing.assert_array_equal(ex.thr_int, re_.thr_int)

        ev = events_for(spec, batch=2, seed=7)
        qat_out, qat_counts = run_snn(state.params, ev, spec, qspec,
                                      mode="qat", record_spikes=True)
        for n_cores in (1, 4):
            engine = deploy(reloaded, spec, n_cores=n_cores)
            rt = verify_roundtrip(state.params, spec, engine, ev, reloaded)
            assert rt.exact, (bits, n_cores, rt)
            # chunk_T = T (one whole-stream chunk) and chunk_T = 1.
            whole = run_engine(engine, ev)
            st = init_state(engine, ev.shape[1])
            for t in range(ev.shape[0]):
                st, out = run_chunk(engine, st, ev[t:t + 1])
            np.testing.assert_array_equal(np.asarray(out.readout),
                                          np.asarray(whole.readout))
            np.testing.assert_array_equal(np.asarray(whole.readout),
                                          np.asarray(qat_out).astype(np.int64))
            np.testing.assert_array_equal(
                np.asarray(whole.spike_counts),
                np.asarray(qat_counts).astype(np.int64))

    def test_precision_sweep_driver(self):
        cfg = TrainConfig(steps=2, warmup=0, batch=2, hw=(16, 16),
                          timesteps=2, lr=2e-3, eval_batch=4, eval_batches=1)
        out = precision_sweep("gesture", bits=(4, 8), cfg=cfg)
        assert set(out) == {4, 8}
        for b, res in out.items():
            assert res["exported"].weight_bits == b
            assert np.isfinite(res["metric"])


class TestExportCheckpointFailures:
    def _exported(self, bits=4):
        spec = reduced_gesture(timesteps=2)
        params = init_params(jax.random.PRNGKey(0), spec)
        return spec, export_network(params, spec, QuantSpec(bits))

    def test_load_latest_and_explicit_step(self, tmp_path):
        spec, exported = self._exported()
        ckpt = Checkpointer(str(tmp_path))
        save_exported(ckpt, 5, exported)
        save_exported(ckpt, 9, exported)
        assert load_exported(ckpt, spec).weight_bits == 4
        assert load_exported(ckpt, spec, step=5).weight_bits == 4

    def test_load_empty_dir(self, tmp_path):
        spec, _ = self._exported()
        with pytest.raises(FileNotFoundError, match="no checkpoint steps"):
            load_exported(Checkpointer(str(tmp_path)), spec)

    def test_load_non_export_checkpoint(self, tmp_path):
        spec, _ = self._exported()
        ckpt = Checkpointer(str(tmp_path))
        ckpt.save(1, init_params(jax.random.PRNGKey(0), spec))
        with pytest.raises(ValueError, match="no 'exported_snn' metadata"):
            load_exported(ckpt, spec)

    def test_load_missing_meta_field(self, tmp_path):
        spec, exported = self._exported()
        ckpt = Checkpointer(str(tmp_path))
        save_exported(ckpt, 1, exported)
        meta_path = tmp_path / "step_000000001" / "meta.json"
        meta = json.loads(meta_path.read_text())
        del meta["exported_snn"]["weight_bits"]
        meta_path.write_text(json.dumps(meta))
        with pytest.raises(ValueError, match="weight_bits.*missing"):
            load_exported(ckpt, spec)

    def test_load_corrupt_weight_bits(self, tmp_path):
        spec, exported = self._exported()
        ckpt = Checkpointer(str(tmp_path))
        save_exported(ckpt, 1, exported)
        meta_path = tmp_path / "step_000000001" / "meta.json"
        meta = json.loads(meta_path.read_text())
        meta["exported_snn"]["weight_bits"] = 5
        meta_path.write_text(json.dumps(meta))
        with pytest.raises(ValueError, match="not a supported precision"):
            load_exported(ckpt, spec)

    def test_load_corrupt_leaf_shape(self, tmp_path):
        spec, exported = self._exported()
        ckpt = Checkpointer(str(tmp_path))
        save_exported(ckpt, 1, exported)
        # Right leaf count, wrong shape/dtype: must fail validation loudly
        # instead of deploying a silently cast/truncated tensor.  The
        # checkpoint manifest catches this before the export layer's own
        # shape validation even runs.
        np.save(tmp_path / "step_000000001" / "0.npy",
                np.zeros((3, 3), np.float64))
        with pytest.raises(ValueError, match="manifest|corrupted: layer"):
            load_exported(ckpt, spec)

    def test_load_missing_leaf_file(self, tmp_path):
        spec, exported = self._exported()
        ckpt = Checkpointer(str(tmp_path))
        save_exported(ckpt, 1, exported)
        step_dir = tmp_path / "step_000000001"
        os.remove(step_dir / "0.npy")
        with pytest.raises(FileNotFoundError):
            load_exported(ckpt, spec)

    def test_load_structure_mismatch(self, tmp_path):
        spec, exported = self._exported()
        ckpt = Checkpointer(str(tmp_path))
        save_exported(ckpt, 1, exported)
        other = reduced_flow()
        with pytest.raises(ValueError, match="does not match"):
            load_exported(ckpt, other)

    def test_deploy_precision_mismatch(self):
        spec, exported = self._exported(bits=4)
        with pytest.raises(ValueError, match="exported at 4-bit"):
            deploy(exported, spec, cfg=EngineConfig(QuantSpec(8), backend="jnp"))


# ---------------------------------------------------------------------------
# tools/check_bench.py — the CI regression gate.
# ---------------------------------------------------------------------------
def _load_check_bench():
    path = pathlib.Path(__file__).resolve().parent.parent / "tools" / "check_bench.py"
    ispec = importlib.util.spec_from_file_location("check_bench", path)
    mod = importlib.util.module_from_spec(ispec)
    ispec.loader.exec_module(mod)
    return mod


def _write_bench(path, records):
    path.write_text(json.dumps(
        {"schema": 1, "suite": "spidr-benchmarks", "results": records}))


BASE_RECORDS = [
    {"name": "a_1core", "cycles": 1000, "energy_uj": 4.0, "exact": True,
     "metric": "accuracy", "metric_value": 0.8, "wall_us": 10.0},
    {"name": "a_4core", "cycles": 400, "energy_uj": 4.4, "exact": True,
     "metric": "accuracy", "metric_value": 0.8, "wall_us": 99.0},
    {"name": "flow_1core", "cycles": 2000, "energy_uj": 9.0, "exact": True,
     "metric": "aee", "metric_value": 1.5},
]


class TestCheckBench:
    @pytest.fixture()
    def cb(self):
        return _load_check_bench()

    def _run(self, cb, tmp_path, fresh_records, extra=()):
        base = tmp_path / "baseline.json"
        fresh = tmp_path / "fresh.json"
        _write_bench(base, BASE_RECORDS)
        _write_bench(fresh, fresh_records)
        return cb.main([str(fresh), "--baseline", str(base), *extra])

    def test_identical_passes(self, cb, tmp_path):
        assert self._run(cb, tmp_path, BASE_RECORDS) == 0

    def test_wall_time_ignored(self, cb, tmp_path):
        fresh = [dict(r, wall_us=123456.0) for r in BASE_RECORDS]
        assert self._run(cb, tmp_path, fresh) == 0

    def test_cycle_regression_fails(self, cb, tmp_path, capsys):
        fresh = [dict(r) for r in BASE_RECORDS]
        fresh[0]["cycles"] = 1400  # +40% > default 25% tolerance
        assert self._run(cb, tmp_path, fresh) == 1
        out = capsys.readouterr().out
        assert "cycles regressed" in out
        assert "refresh" in out.lower()
        assert "benchmarks/run.py --smoke" in out

    def test_tolerance_is_configurable(self, cb, tmp_path):
        fresh = [dict(r) for r in BASE_RECORDS]
        fresh[0]["cycles"] = 1400
        assert self._run(cb, tmp_path, fresh, extra=["--tol", "0.5"]) == 0

    def test_improvement_passes(self, cb, tmp_path):
        fresh = [dict(r) for r in BASE_RECORDS]
        fresh[0]["cycles"] = 100
        fresh[1]["energy_uj"] = 0.5
        assert self._run(cb, tmp_path, fresh) == 0

    def test_missing_record_fails(self, cb, tmp_path, capsys):
        assert self._run(cb, tmp_path, BASE_RECORDS[:-1]) == 1
        assert "missing from the fresh run" in capsys.readouterr().out

    def test_exactness_regression_fails(self, cb, tmp_path, capsys):
        fresh = [dict(r) for r in BASE_RECORDS]
        fresh[1]["exact"] = False
        assert self._run(cb, tmp_path, fresh) == 1
        assert "was True in the baseline" in capsys.readouterr().out

    def test_accuracy_drop_fails_aee_rise_fails(self, cb, tmp_path):
        fresh = [dict(r) for r in BASE_RECORDS]
        fresh[0]["metric_value"] = 0.6  # accuracy down 0.2 > 0.05
        assert self._run(cb, tmp_path, fresh) == 1
        fresh = [dict(r) for r in BASE_RECORDS]
        fresh[2]["metric_value"] = 2.5  # aee up 1.0 > 0.05
        assert self._run(cb, tmp_path, fresh) == 1
        # The right directions pass: accuracy up, aee down.
        fresh = [dict(r) for r in BASE_RECORDS]
        fresh[0]["metric_value"] = 0.95
        fresh[2]["metric_value"] = 0.5
        assert self._run(cb, tmp_path, fresh) == 0

    def test_subset_mode(self, cb, tmp_path):
        assert self._run(cb, tmp_path, BASE_RECORDS[:1],
                         extra=["--subset"]) == 0
        assert self._run(cb, tmp_path, BASE_RECORDS[:1]) == 1

    # -- the wall_us/bound_us roofline-ratio gate ---------------------------
    # BASE_RECORDS stay bound_us-free on purpose: test_wall_time_ignored
    # above pins the contract that wall_us ALONE is never gated.  The ratio
    # gate engages only for records whose baseline commits both fields.
    ROOFLINE_RECORDS = [
        {"name": "kernel_s95_tblk", "wall_us": 1000.0, "bound_us": 10.0,
         "exact": True, "sparsity": 0.95},
        {"name": "kernel_s95_per_t", "wall_us": 1500.0, "bound_us": 12.0,
         "exact": True, "sparsity": 0.95},
    ]

    def _run_vs(self, cb, tmp_path, base_records, fresh_records, extra=()):
        base = tmp_path / "baseline.json"
        fresh = tmp_path / "fresh.json"
        _write_bench(base, base_records)
        _write_bench(fresh, fresh_records)
        return cb.main([str(fresh), "--baseline", str(base), *extra])

    def test_roofline_identical_passes(self, cb, tmp_path):
        assert self._run_vs(cb, tmp_path, self.ROOFLINE_RECORDS,
                            self.ROOFLINE_RECORDS) == 0

    def test_roofline_ratio_regression_fails(self, cb, tmp_path, capsys):
        fresh = [dict(r) for r in self.ROOFLINE_RECORDS]
        fresh[0]["wall_us"] = 5000.0  # ratio 100 -> 500 > 4x limit
        assert self._run_vs(cb, tmp_path, self.ROOFLINE_RECORDS, fresh) == 1
        out = capsys.readouterr().out
        assert "wall/roofline ratio regressed" in out
        assert "refresh" in out.lower()

    def test_roofline_tolerance_edges(self, cb, tmp_path):
        # Exactly AT the limit (ratio x (1 + tol)) passes; just past fails.
        fresh = [dict(r) for r in self.ROOFLINE_RECORDS]
        fresh[0]["wall_us"] = 4000.0  # ratio 400 == 100 * (1 + 3.0)
        assert self._run_vs(cb, tmp_path, self.ROOFLINE_RECORDS, fresh) == 0
        fresh[0]["wall_us"] = 4100.0
        assert self._run_vs(cb, tmp_path, self.ROOFLINE_RECORDS, fresh) == 1

    def test_roofline_tolerance_is_configurable(self, cb, tmp_path):
        fresh = [dict(r) for r in self.ROOFLINE_RECORDS]
        fresh[0]["wall_us"] = 5000.0
        assert self._run_vs(cb, tmp_path, self.ROOFLINE_RECORDS, fresh,
                            extra=["--tol-roofline", "9.0"]) == 0

    def test_roofline_improvement_passes(self, cb, tmp_path):
        # A faster kernel OR a tighter bound both shrink the ratio: pass.
        fresh = [dict(r) for r in self.ROOFLINE_RECORDS]
        fresh[0]["wall_us"] = 200.0
        fresh[1]["bound_us"] = 50.0
        assert self._run_vs(cb, tmp_path, self.ROOFLINE_RECORDS, fresh) == 0

    def test_missing_bound_key_fails(self, cb, tmp_path, capsys):
        # bound_us vanishing from the fresh run means the ablation stopped
        # pricing its roofline — the field-disappeared path reports it.
        fresh = [dict(r) for r in self.ROOFLINE_RECORDS]
        del fresh[0]["bound_us"]
        assert self._run_vs(cb, tmp_path, self.ROOFLINE_RECORDS, fresh) == 1
        assert "'bound_us' disappeared" in capsys.readouterr().out

    def test_bound_appearing_fresh_is_not_gated(self, cb, tmp_path):
        # Baseline without bound_us keeps the wall_us-ignored contract even
        # when the fresh run starts reporting a bound.
        base = [{"name": "kernel_s95_tblk", "wall_us": 10.0, "exact": True}]
        fresh = [{"name": "kernel_s95_tblk", "wall_us": 999999.0,
                  "bound_us": 1.0, "exact": True}]
        assert self._run_vs(cb, tmp_path, base, fresh) == 0

    def test_roofline_subset_mode(self, cb, tmp_path):
        # The CI perf-gate job runs --perf --smoke: kernel records only.
        assert self._run_vs(cb, tmp_path,
                            BASE_RECORDS + self.ROOFLINE_RECORDS,
                            self.ROOFLINE_RECORDS, extra=["--subset"]) == 0

    def test_committed_baseline_is_current(self):
        """The committed baseline must carry the QAT sweep records the CI
        gate relies on, all bit-exact."""
        base = json.loads(
            (pathlib.Path(__file__).resolve().parent.parent / "benchmarks" /
             "baseline.json").read_text())
        names = {r["name"] for r in base["results"]}
        for bits in (4, 6, 8):
            assert f"qat_gesture_{bits}b_1core" in names
            assert f"qat_gesture_{bits}b_4core" in names
        assert all(r.get("exact", True) for r in base["results"])
        # The perf gate needs committed measured-vs-bound ratios for the
        # block-sparse kernel ablation.
        by_name = {r["name"]: r for r in base["results"]}
        for rec in ("kernel_s95_tblk", "kernel_s95_per_t"):
            assert rec in names
            assert by_name[rec]["wall_us"] > 0
            assert by_name[rec]["bound_us"] > 0


@pytest.mark.slow
class TestFullSizeParity:
    def test_paper_gesture_shapes_roundtrip(self):
        """Full 64x64x20-timestep gesture net: train graph == engine."""
        spec = gesture_net()
        params = init_params(jax.random.PRNGKey(0), spec)
        ev = events_for(spec, batch=1, density=0.05)
        exported = export_network(params, spec, QuantSpec(4))
        rt = verify_roundtrip(params, spec, deploy(exported, spec), ev,
                              exported)
        assert rt.exact, rt
