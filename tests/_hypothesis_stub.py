"""Fallback shims when ``hypothesis`` (the ``dev`` extra) is not installed.

Property-based tests are skipped with a pointer to ``pip install -e .[dev]``;
every plain pytest test in the same module still collects and runs.  With
hypothesis installed these shims are never imported.
"""
import pytest

_SKIP = pytest.mark.skip(
    reason="hypothesis not installed (pip install -e .[dev])"
)


def given(*_args, **_kwargs):
    def deco(fn):
        return _SKIP(fn)
    return deco


def settings(*_args, **_kwargs):
    def deco(fn):
        return fn
    return deco


class _Strategy:
    """Inert stand-in for any ``strategies.*`` call."""

    def __call__(self, *args, **kwargs):
        return self

    def __getattr__(self, name):
        return self


st = _Strategy()
