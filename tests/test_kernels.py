"""Per-kernel validation: shape/dtype/sparsity sweeps vs the jnp oracles.

Kernels execute in interpret mode (CPU container); on TPU the same code
compiles to Mosaic.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.lif_step import lif_step_fused, lif_step_fused_int
from repro.kernels.quant_matmul import pack_int4, quant_matmul, unpack_int4
from repro.kernels.spike_gemm import spike_gemm


class TestSpikeGemm:
    @pytest.mark.parametrize("m,k,n", [
        (32, 64, 16), (128, 128, 128), (100, 300, 50), (257, 511, 129),
        (16, 1024, 12),  # macro-like: fan-in chunk x 12 neurons
    ])
    @pytest.mark.parametrize("density", [0.0, 0.05, 0.5])
    def test_matches_oracle(self, m, k, n, density):
        rng = np.random.default_rng(m + k + n)
        s = (rng.random((m, k)) < density).astype(np.int8)
        w = rng.integers(-8, 8, (k, n)).astype(np.int8)
        out = spike_gemm(jnp.array(s), jnp.array(w), interpret=True)
        want = ref.spike_gemm_ref(jnp.array(s), jnp.array(w))
        np.testing.assert_array_equal(np.asarray(out), np.asarray(want))

    @pytest.mark.parametrize("dtype", [jnp.int8, jnp.uint8, jnp.bool_, jnp.int32])
    def test_dtypes(self, dtype):
        rng = np.random.default_rng(0)
        s = jnp.array((rng.random((64, 64)) < 0.1)).astype(dtype)
        w = jnp.array(rng.integers(-8, 8, (64, 24)).astype(np.int8))
        out = spike_gemm(s, w, interpret=True)
        want = ref.spike_gemm_ref(s.astype(jnp.int8), w)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(want))

    def test_skip_and_dense_agree(self):
        """Zero-skipping must not change results (C3: exactness)."""
        rng = np.random.default_rng(3)
        s = (rng.random((256, 256)) < 0.02).astype(np.int8)
        w = rng.integers(-8, 8, (256, 128)).astype(np.int8)
        a = spike_gemm(jnp.array(s), jnp.array(w), interpret=True, skip_empty=True)
        b = spike_gemm(jnp.array(s), jnp.array(w), interpret=True, skip_empty=False)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_block_shapes(self):
        rng = np.random.default_rng(4)
        s = (rng.random((96, 192)) < 0.1).astype(np.int8)
        w = rng.integers(-8, 8, (192, 64)).astype(np.int8)
        want = np.asarray(ref.spike_gemm_ref(jnp.array(s), jnp.array(w)))
        for block in [(32, 32, 32), (64, 64, 64), (128, 128, 128)]:
            out = spike_gemm(jnp.array(s), jnp.array(w), block=block, interpret=True)
            np.testing.assert_array_equal(np.asarray(out), want)


class TestLifKernel:
    @pytest.mark.parametrize("leak,soft", [(1.0, False), (0.9, True), (0.8, False)])
    @pytest.mark.parametrize("shape", [(7,), (33, 65), (3, 17, 29)])
    def test_float_matches_oracle(self, leak, soft, shape):
        rng = np.random.default_rng(0)
        v = jnp.array(rng.normal(size=shape).astype(np.float32))
        i = jnp.array(rng.normal(size=shape).astype(np.float32))
        vo, so = lif_step_fused(v, i, threshold=0.5, leak=leak, soft_reset=soft,
                                interpret=True)
        ve, se = ref.lif_step_ref(v, i, 0.5, leak, soft)
        np.testing.assert_allclose(np.asarray(vo), np.asarray(ve), rtol=1e-4, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(so), np.asarray(se))

    @pytest.mark.parametrize("shift,soft,bits", [(0, False, 7), (3, True, 7),
                                                 (2, False, 11), (1, True, 15)])
    def test_int_matches_oracle(self, shift, soft, bits):
        rng = np.random.default_rng(1)
        hi = (1 << (bits - 1)) - 1
        v = jnp.array(rng.integers(-hi, hi, (50, 33)).astype(np.int32))
        p = jnp.array(rng.integers(-hi // 2, hi // 2, (50, 33)).astype(np.int32))
        vo, so = lif_step_fused_int(v, p, threshold=hi // 3, leak_shift=shift,
                                    soft_reset=soft, vmem_bits=bits, interpret=True)
        ve, se = ref.lif_step_int_ref(v, p, hi // 3, shift, soft, bits)
        np.testing.assert_array_equal(np.asarray(vo), np.asarray(ve))
        np.testing.assert_array_equal(np.asarray(so), np.asarray(se))

    def test_int_kernel_matches_neuron_module(self):
        """Kernel == core.neuron integer datapath (bit-exactness chain)."""
        from repro.core.neuron import NeuronConfig, neuron_step_int
        from repro.core.quant import QuantSpec

        spec = QuantSpec(4)
        cfg = NeuronConfig(model="lif", reset="soft", leak_shift=3)
        rng = np.random.default_rng(2)
        v = jnp.array(rng.integers(-60, 60, (40,)).astype(np.int32))
        p = jnp.array(rng.integers(-20, 20, (40,)).astype(np.int32))
        v_mod, s_mod = neuron_step_int(v, p, cfg, spec, 15)
        v_k, s_k = lif_step_fused_int(v, p, 15, leak_shift=3, soft_reset=True,
                                      vmem_bits=7, interpret=True)
        np.testing.assert_array_equal(np.asarray(v_mod), np.asarray(v_k))
        np.testing.assert_array_equal(np.asarray(s_mod), np.asarray(s_k))


class TestQuantMatmul:
    @pytest.mark.parametrize("m,k,n", [(16, 64, 32), (64, 200, 96), (130, 514, 258)])
    def test_int8(self, m, k, n):
        rng = np.random.default_rng(m)
        x = jnp.array(rng.normal(size=(m, k)).astype(np.float32))
        w = jnp.array(rng.integers(-127, 128, (k, n)).astype(np.int8))
        sc = jnp.array((rng.random(n) * 0.01 + 1e-4).astype(np.float32))
        out = quant_matmul(x, w, sc, bits=8, interpret=True)
        want = ref.quant_matmul_ref(x, w, sc, bits=8)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-4, atol=1e-4)

    def test_int4_pack_roundtrip(self):
        rng = np.random.default_rng(0)
        w = rng.integers(-8, 8, (64, 32)).astype(np.int8)
        packed = pack_int4(jnp.array(w))
        assert packed.shape == (32, 32)
        np.testing.assert_array_equal(np.asarray(unpack_int4(packed)), w)

    @pytest.mark.parametrize("m,k,n", [(16, 64, 32), (32, 256, 128)])
    def test_int4(self, m, k, n):
        rng = np.random.default_rng(n)
        x = jnp.array(rng.normal(size=(m, k)).astype(np.float32))
        w4 = rng.integers(-8, 8, (k, n)).astype(np.int8)
        packed = pack_int4(jnp.array(w4))
        sc = jnp.array((rng.random(n) * 0.01 + 1e-4).astype(np.float32))
        out = quant_matmul(x, packed, sc, bits=4, interpret=True)
        want = ref.quant_matmul_ref(x, packed, sc, bits=4)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-4, atol=1e-4)

    def test_quant_dequant_accuracy_envelope(self):
        """w4 matmul error vs full precision stays within quant noise."""
        rng = np.random.default_rng(5)
        x = rng.normal(size=(8, 128)).astype(np.float32)
        w = rng.normal(size=(128, 64)).astype(np.float32) * 0.1
        from repro.core.quant import QuantSpec, quantize

        q, sc = quantize(jnp.array(w), QuantSpec(4), axis=0)
        out = quant_matmul(jnp.array(x), q, sc.reshape(-1), bits=8, interpret=True)
        rel = np.abs(np.asarray(out) - x @ w).max() / np.abs(x @ w).max()
        assert rel < 0.15
