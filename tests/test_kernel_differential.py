"""Differential harness for the block-sparse Vmem-stationary hot path.

The T_blk fused kernel (``fused_lif_gemm_int_tblk``) re-schedules the
engine's hot loop three ways at once — whole-tile spike skipping from a
host-computed bitmap, multi-timestep Vmem-stationary tiling, and autotuned
block shapes — and every one of those levers must be *invisible* in the
output: integer accumulation is exact, so any divergence from the
sequential per-timestep oracle is a bug, not noise.

This module is the oracle sweep:

  * a parametrized differential matrix over pinned shapes (including every
    non-divisible-by-block edge we have hit), all three precision pairs
    (4/7, 6/11, 8/15), sparsities {0.0, 0.5, 0.95, 1.0}, scalar and
    per-neuron thresholds, hard and soft reset, leak shifts, and
    saturation-boundary inputs pinned at the +-Vmem clip;
  * failures name the FIRST divergent (timestep, row, col) with both
    values — a schedule bug localizes to a tile boundary instantly;
  * a hypothesis-driven random-shape sweep (nightly: the ``slow`` marker)
    that searches the shape space the pinned matrix cannot cover;
  * chunking x tiling: ``run_chunk`` with chunk_T that is NOT a multiple
    of T_blk, and stream snapshot/restore round-trips taken mid-tile;
  * the autotuner's cache contract (``autotune`` marker for the sweep).

Everything runs the kernels in interpret mode (CPU container); on TPU the
same code compiles to Mosaic.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # dev extra absent: property tests skip, rest run
    from _hypothesis_stub import given, settings, st

from repro import spidr
from repro.core.layers import SpikingConvParams, SpikingDenseParams
from repro.core.network import SNNLayer, SNNSpec, init_params
from repro.core.neuron import NeuronConfig
from repro.core.quant import QuantSpec
from repro.engine import (
    EngineConfig,
    build_engine,
    init_state,
    run_chunk,
    run_engine,
)
from repro.kernels import ref
from repro.kernels.autotune import (
    KernelConfig,
    _default_candidates,
    autotune_layer,
    cache_key,
    clear_cache,
    load_cache,
    save_cache,
)
from repro.kernels.fused_lif_gemm import (
    fused_lif_gemm_int,
    fused_lif_gemm_int_tblk,
    spike_tile_bitmap,
)
from repro.spidr.target import PRECISION_PAIRS

# Small blocks so test-sized shapes still produce multi-tile grids (the
# schedule bugs this harness hunts live on tile boundaries).
BLOCK = (32, 32, 32)

# Pinned regression shapes: every (T, M, K, N) that exercises a distinct
# padding/masking edge of the (bm, bn, bk) = (32, 32, 32) tiling.
PINNED_SHAPES = [
    (1, 1, 1, 1),        # degenerate minimum: everything is padding
    (5, 7, 33, 19),      # no dimension divides its block
    (3, 65, 96, 70),     # m and n overrun one tile, k exact
    (4, 32, 32, 32),     # exactly one tile — no masking at all
    (6, 9, 5, 33),       # n overruns the tile by one lane
    (2, 130, 30, 4),     # tall-skinny: 5 m-tiles, sub-tile k and n
]

SPARSITIES = (0.0, 0.5, 0.95, 1.0)


def _case(T, M, K, N, vmem_bits, sparsity, seed=0, weight_bits=None,
          v0_mode="random"):
    """Random inputs for one differential case (deterministic by seed)."""
    rng = np.random.default_rng(seed)
    wb = weight_bits or (vmem_bits + 1) // 2
    w_max = (1 << (wb - 1)) - 1
    v_max = (1 << (vmem_bits - 1)) - 1
    spikes = jnp.asarray(
        (rng.random((T, M, K)) >= sparsity).astype(np.int8))
    weights = jnp.asarray(
        rng.integers(-w_max - 1, w_max + 1, (K, N)), jnp.int8)
    if v0_mode == "random":
        v0 = jnp.asarray(
            rng.integers(-v_max - 1, v_max + 1, (M, N)), jnp.int32)
    else:  # saturation boundary: start pinned at the clip rails
        rail = v_max if v0_mode == "high" else -v_max - 1
        v0 = jnp.full((M, N), rail, jnp.int32)
    return spikes, weights, v0


def _oracle(spikes, weights, v0, threshold, leak_shift, soft_reset,
            vmem_bits):
    """Sequential per-timestep oracle: ``ref.fused_lif_gemm_int_ref``."""
    v = jnp.asarray(v0, jnp.int32)
    vs, ss = [], []
    for t in range(spikes.shape[0]):
        v, s = ref.fused_lif_gemm_int_ref(
            spikes[t], weights, v, threshold, leak_shift, soft_reset,
            vmem_bits)
        vs.append(v)
        ss.append(s)
    return jnp.stack(vs), jnp.stack(ss)


def _assert_traj_equal(got, want, what):
    """Bit-exact or name the FIRST divergent (timestep, row, col)."""
    g, w = np.asarray(got), np.asarray(want)
    assert g.shape == w.shape, f"{what}: shape {g.shape} != {w.shape}"
    if (g == w).all():
        return
    t, r, c = np.argwhere(g != w)[0]
    raise AssertionError(
        f"{what} diverges first at (timestep={t}, row={r}, col={c}): "
        f"got {g[t, r, c]}, want {w[t, r, c]} "
        f"[{int((g != w).sum())} of {g.size} entries differ]")


def _run_and_compare(spikes, weights, v0, threshold, *, vmem_bits,
                     leak_shift=0, soft_reset=False, skip_empty=True,
                     block=BLOCK):
    v_traj, s_traj = fused_lif_gemm_int_tblk(
        spikes, weights, v0, threshold=threshold, leak_shift=leak_shift,
        soft_reset=soft_reset, vmem_bits=vmem_bits, block=block,
        interpret=True, skip_empty=skip_empty)
    want_v, want_s = _oracle(spikes, weights, v0, threshold, leak_shift,
                             soft_reset, vmem_bits)
    _assert_traj_equal(s_traj, want_s, "spike trajectory")
    _assert_traj_equal(v_traj, want_v, "Vmem trajectory")


# ---------------------------------------------------------------------------
# The differential matrix (tier-1).
# ---------------------------------------------------------------------------
class TestDifferentialMatrix:
    @pytest.mark.parametrize("wb,vb", PRECISION_PAIRS)
    @pytest.mark.parametrize("sparsity", SPARSITIES)
    def test_precision_pairs_at_every_sparsity(self, wb, vb, sparsity):
        """All three silicon precision pairs on a nothing-divides shape."""
        spikes, weights, v0 = _case(5, 7, 33, 19, vb, sparsity,
                                    seed=wb, weight_bits=wb)
        thr = max(1, 1 << (vb - 3))
        _run_and_compare(spikes, weights, v0, thr, vmem_bits=vb,
                         leak_shift=2, soft_reset=(wb == 6))

    @pytest.mark.parametrize("shape", PINNED_SHAPES)
    def test_pinned_nondivisible_shapes(self, shape):
        """Regression pins for the padding/masking bug class: shapes whose
        every dimension sits off a tile boundary must not read or write
        padding lanes."""
        T, M, K, N = shape
        spikes, weights, v0 = _case(T, M, K, N, 7, 0.5, seed=sum(shape))
        _run_and_compare(spikes, weights, v0, 16, vmem_bits=7,
                         leak_shift=3, soft_reset=(T % 2 == 0))

    @pytest.mark.parametrize("v0_mode", ["high", "low"])
    @pytest.mark.parametrize("soft_reset", [False, True])
    def test_saturation_boundary(self, v0_mode, soft_reset):
        """Vmem pinned at the clip rails: accumulate straight into (and
        past) saturation in both directions; the kernel's single-clip
        order must match the oracle exactly."""
        rng = np.random.default_rng(7)
        vb, wb = 7, 4
        w_max = (1 << (wb - 1)) - 1
        spikes = jnp.asarray((rng.random((4, 33, 40)) < 0.8).astype(np.int8))
        # Extreme same-sign weights force the accumulator over the rail.
        sign = 1 if v0_mode == "high" else -1
        weights = jnp.full((40, 21), sign * w_max, jnp.int8)
        _, _, v0 = _case(4, 33, 40, 21, vb, 0.5, v0_mode=v0_mode,
                         weight_bits=wb)
        _run_and_compare(spikes, weights, v0, 16, vmem_bits=vb,
                         soft_reset=soft_reset)

    def test_vector_threshold(self):
        """Per-neuron thresholds route through the vector kernel variant."""
        spikes, weights, v0 = _case(3, 40, 17, 50, 11, 0.5, seed=11)
        rng = np.random.default_rng(5)
        thr = jnp.asarray(rng.integers(1, 1 << 9, (50,)), jnp.int32)
        v_traj, s_traj = fused_lif_gemm_int_tblk(
            spikes, weights, v0, threshold=thr, vmem_bits=11, block=BLOCK,
            interpret=True)
        want_v, want_s = _oracle(spikes, weights, v0, thr, 0, False, 11)
        _assert_traj_equal(s_traj, want_s, "spike trajectory")
        _assert_traj_equal(v_traj, want_v, "Vmem trajectory")

    def test_skip_and_dense_agree(self):
        """Block skipping must be invisible (C3: exactness)."""
        spikes, weights, v0 = _case(4, 70, 65, 33, 7, 0.97, seed=3)
        args = dict(threshold=16, vmem_bits=7, block=BLOCK, interpret=True)
        a = fused_lif_gemm_int_tblk(spikes, weights, v0, skip_empty=True,
                                    **args)
        b = fused_lif_gemm_int_tblk(spikes, weights, v0, skip_empty=False,
                                    **args)
        _assert_traj_equal(a[0], b[0], "Vmem trajectory (skip vs dense)")
        _assert_traj_equal(a[1], b[1], "spike trajectory (skip vs dense)")

    def test_all_zero_input_skips_every_tile(self):
        """sparsity=1.0: the bitmap is all zero, every tile is skipped, and
        the output is still exactly the oracle's (leak-only dynamics)."""
        spikes, weights, v0 = _case(4, 40, 33, 20, 7, 1.0, seed=9)
        assert int(spike_tile_bitmap(spikes, BLOCK).sum()) == 0
        _run_and_compare(spikes, weights, v0, 8, vmem_bits=7, leak_shift=1)

    def test_tblk_equals_per_timestep_kernel(self):
        """The T_blk schedule == T independent per-timestep kernel calls
        (the second, independently-implemented oracle)."""
        spikes, weights, v0 = _case(6, 65, 40, 33, 7, 0.8, seed=13)
        v_traj, s_traj = fused_lif_gemm_int_tblk(
            spikes, weights, v0, threshold=16, vmem_bits=7, block=BLOCK,
            interpret=True)
        v = v0
        for t in range(6):
            v, s = fused_lif_gemm_int(spikes[t], weights, v, threshold=16,
                                      vmem_bits=7, block=BLOCK,
                                      interpret=True)
            _assert_traj_equal(s_traj[t][None], s[None],
                               f"spikes (per-t kernel, t={t})")
            _assert_traj_equal(v_traj[t][None], v[None],
                               f"Vmem (per-t kernel, t={t})")


class TestBitmapFormat:
    def test_shape_and_dtype(self):
        s = jnp.zeros((3, 100, 70), jnp.int8)
        bm = spike_tile_bitmap(s, BLOCK)
        assert bm.shape == (3, 4, 3)  # ceil(100/32) x ceil(70/32)
        assert bm.dtype == jnp.int32
        assert int(bm.sum()) == 0

    def test_single_spike_lights_exactly_one_tile(self):
        s = np.zeros((2, 100, 70), np.int8)
        s[1, 99, 69] = 1  # last row/col: lives in the padded edge tile
        bm = np.asarray(spike_tile_bitmap(jnp.asarray(s), BLOCK))
        assert bm.sum() == 1 and bm[1, 3, 2] == 1

    def test_2d_input_is_one_timestep(self):
        s = np.zeros((40, 40), np.int8)
        s[0, 0] = 1
        bm = np.asarray(spike_tile_bitmap(jnp.asarray(s), BLOCK))
        assert bm.shape == (2, 2) and bm[0, 0] == 1 and bm.sum() == 1


# ---------------------------------------------------------------------------
# Hypothesis sweep (nightly: random shapes the pinned matrix cannot cover).
# ---------------------------------------------------------------------------
@pytest.mark.slow
class TestHypothesisSweep:
    @settings(max_examples=40, deadline=None)
    @given(
        T=st.integers(1, 6),
        M=st.integers(1, 140),
        K=st.integers(1, 140),
        N=st.integers(1, 70),
        pair=st.sampled_from(PRECISION_PAIRS),
        sparsity=st.sampled_from(SPARSITIES),
        leak_shift=st.integers(0, 3),
        soft_reset=st.booleans(),
        seed=st.integers(0, 2**16),
    )
    def test_random_shapes(self, T, M, K, N, pair, sparsity, leak_shift,
                           soft_reset, seed):
        wb, vb = pair
        spikes, weights, v0 = _case(T, M, K, N, vb, sparsity, seed=seed,
                                    weight_bits=wb)
        thr = max(1, 1 << (vb - 3))
        _run_and_compare(spikes, weights, v0, thr, vmem_bits=vb,
                         leak_shift=leak_shift, soft_reset=soft_reset)


# ---------------------------------------------------------------------------
# Chunking x tiling: chunk_T need not respect T_blk.
# ---------------------------------------------------------------------------
def _mini_spec(hw=(16, 16), timesteps=6):
    n = NeuronConfig(model="lif", reset="soft", threshold=0.5, leak_shift=3)
    return SNNSpec(
        name="mini", input_hw=hw, in_channels=2, timesteps=timesteps,
        layers=(
            SNNLayer("conv", 2, 8, conv=SpikingConvParams(3, 3, 1, 1, n)),
            SNNLayer("pool"),
            SNNLayer("conv", 8, 8, conv=SpikingConvParams(3, 3, 1, 1, n)),
            SNNLayer("adaptive_pool", target_hw=2),
            SNNLayer("fc", 32, 5, fc=SpikingDenseParams(n)),
        ),
        readout="rate",
    )


def _tiled_engine(spec, t_block, seed=0):
    params = init_params(jax.random.PRNGKey(seed), spec)
    cfg = EngineConfig(QuantSpec(4), interpret=True, block=(64, 64, 64),
                       backend="fused", t_block=t_block)
    return build_engine(spec, params, cfg)


def _jnp_engine(spec, seed=0):
    params = init_params(jax.random.PRNGKey(seed), spec)
    return build_engine(spec, params,
                        EngineConfig(QuantSpec(4), backend="jnp"))


def _events(spec, batch=2, seed=0, sparsity=0.9):
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        (rng.random((spec.timesteps, batch) + spec.input_hw + (2,))
         > sparsity).astype(np.float32))


class TestChunkingTimesTiling:
    @pytest.mark.parametrize("chunk_T", [1, 3, 6])
    def test_chunking_not_multiple_of_tblk(self, chunk_T):
        """chunk_T in {1, 3, T} with T_blk=4: every chunk boundary falls
        mid-tile somewhere, and the remainder-slab specialization must
        carry Vmem exactly."""
        spec = _mini_spec()
        eng = _tiled_engine(spec, t_block=4)
        ev = _events(spec)
        whole = run_engine(_jnp_engine(spec), ev)
        state = init_state(eng, ev.shape[1])
        out = None
        for t0 in range(0, spec.timesteps, chunk_T):
            state, out = run_chunk(eng, state, ev[t0:t0 + chunk_T])
        np.testing.assert_array_equal(np.asarray(out.readout),
                                      np.asarray(whole.readout))
        np.testing.assert_array_equal(
            np.asarray(state.out_counts).sum(axis=1),
            np.asarray(whole.spike_counts).sum(axis=0))

    @pytest.mark.parametrize("t_block", [2, 3, 5, 7])
    def test_tblk_values_including_nondivisors(self, t_block):
        """T_blk in {2, 3, 5, 7} over T=6: non-divisors and T_blk > T both
        reduce to remainder slabs — all bit-equal to the jnp oracle."""
        spec = _mini_spec()
        eng = _tiled_engine(spec, t_block=t_block)
        ev = _events(spec, seed=t_block)
        got = run_engine(eng, ev)
        want = run_engine(_jnp_engine(spec), ev)
        np.testing.assert_array_equal(np.asarray(got.readout),
                                      np.asarray(want.readout))
        np.testing.assert_array_equal(np.asarray(got.spike_counts),
                                      np.asarray(want.spike_counts))

    def test_stream_snapshot_restore_mid_tile(self):
        """A session snapshot taken at a tick where delivered timesteps are
        NOT a multiple of T_blk (chunk_T=3, T_blk=2) must restore into a
        twin that replays the remaining chunks bit-exactly."""
        spec = _mini_spec()
        params = init_params(jax.random.PRNGKey(0), spec)
        target = spidr.DeployTarget(weight_bits=4, backend="fused",
                                    interpret=True, block=(64, 64, 64),
                                    t_block=2, chunk_T=3, stream_capacity=2)
        compiled = spidr.compile(spec, params, target)
        sess = compiled.open_stream(2, 3)
        s0 = sess.open()
        rng = np.random.default_rng(4)

        def chunk():
            return (rng.random((3,) + spec.input_hw + (2,)) < 0.1) \
                .astype(np.float32)

        sess.step({s0: chunk()})          # 3 delivered: mid-tile for T_blk=2
        snap = sess.state_dict()
        later = [chunk() for _ in range(2)]
        want = [sess.step({s0: c})[s0] for c in later]
        twin = compiled.open_stream(2, 3)
        twin.load_state_dict(snap)
        got = [twin.step({s0: c})[s0] for c in later]
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g.readout),
                                          np.asarray(w.readout))
            assert g.spikes == w.spikes and g.timesteps == w.timesteps


# ---------------------------------------------------------------------------
# Autotuner cache contract.
# ---------------------------------------------------------------------------
TINY_CANDIDATES = [KernelConfig(32, 32, 32, 1), KernelConfig(32, 32, 32, 2)]


class TestAutotuneCache:
    def setup_method(self):
        clear_cache()

    def teardown_method(self):
        clear_cache()

    def test_cache_key_separates_shape_and_precision(self):
        a = cache_key(64, 18, 16, 4, 7)
        assert a == "r64_f18_c16_w4_v7"
        assert a != cache_key(64, 18, 16, 6, 11)
        assert a != cache_key(65, 18, 16, 4, 7)

    def test_winner_is_cached_and_persisted(self, tmp_path):
        path = tmp_path / "tune.json"
        win = autotune_layer(8, 8, 8, 4, 7, timesteps=2,
                             candidates=TINY_CANDIDATES, cache_path=path)
        assert win in TINY_CANDIDATES
        # Second call must hit the in-memory cache (same object back).
        assert autotune_layer(8, 8, 8, 4, 7, timesteps=2,
                              candidates=TINY_CANDIDATES,
                              cache_path=path) is win
        # And the disk cache reloads it in a cold process (simulated).
        data = json.loads(path.read_text())
        assert data[cache_key(8, 8, 8, 4, 7)] == list(win.kcfg)
        clear_cache()
        loaded = load_cache(path)
        assert loaded[cache_key(8, 8, 8, 4, 7)] == win

    def test_save_load_roundtrip(self, tmp_path):
        path = tmp_path / "cache.json"
        clear_cache()
        autotune_layer(4, 4, 4, 4, 7, timesteps=1,
                       candidates=[KernelConfig(32, 32, 32, 1)])
        save_cache(path)
        clear_cache()
        loaded = load_cache(path)
        assert loaded[cache_key(4, 4, 4, 4, 7)] == KernelConfig(32, 32, 32, 1)

    def test_candidate_space_clips_to_shape(self):
        cands = _default_candidates(8, 8, 8, timesteps=4)
        # Small dims keep only the 32-blocks; t_blk sweeps {1, 2, 4}.
        assert {c.block for c in cands} == {(32, 32, 32)}
        assert {c.t_block for c in cands} == {1, 2, 4}
        big = _default_candidates(1024, 144, 32, timesteps=8)
        assert (128, 32, 128) in {c.block for c in big}

    @pytest.mark.autotune
    def test_every_default_candidate_is_bitexact(self):
        """The tuner only chooses among equivalent schedules: every default
        candidate for a conv-like shape produces the oracle's output."""
        T, M, K, N = 4, 70, 33, 20
        spikes, weights, v0 = _case(T, M, K, N, 7, 0.9, seed=21)
        want_v, want_s = _oracle(spikes, weights, v0, 16, 0, False, 7)
        for cand in _default_candidates(M, K, N, T):
            v_parts, s_parts, v = [], [], v0
            for t0 in range(0, T, cand.t_block):
                v_traj, s = fused_lif_gemm_int_tblk(
                    spikes[t0:t0 + cand.t_block], weights, v, threshold=16,
                    vmem_bits=7, block=cand.block, interpret=True)
                v = v_traj[-1]
                v_parts.append(v_traj)
                s_parts.append(s)
            _assert_traj_equal(jnp.concatenate(s_parts), want_s,
                               f"spikes under {cand}")
            _assert_traj_equal(jnp.concatenate(v_parts), want_v,
                               f"Vmem under {cand}")

    @pytest.mark.autotune
    def test_autotuned_facade_is_bitexact(self):
        """DeployTarget(autotune=True) bakes per-layer kcfgs and the result
        still bit-matches the jnp oracle."""
        clear_cache()
        spec = _mini_spec(hw=(8, 8), timesteps=4)
        params = init_params(jax.random.PRNGKey(1), spec)
        tuned = spidr.compile(
            spec, params,
            spidr.DeployTarget(weight_bits=4, backend="fused",
                               interpret=True, autotune=True))
        oracle = spidr.compile(spec, params,
                               spidr.DeployTarget(backend="jnp"))
        kcfgs = [el.kcfg for el in tuned.engine.layers
                 if el.kind in ("conv", "fc")]
        assert all(k is not None for k in kcfgs)
        ev = _events(spec, batch=1, seed=2)
        got, want = tuned.run(ev), oracle.run(ev)
        np.testing.assert_array_equal(np.asarray(got.readout),
                                      np.asarray(want.readout))
        np.testing.assert_array_equal(np.asarray(got.spike_counts),
                                      np.asarray(want.spike_counts))
