"""wkv_chunk Pallas kernel vs the pure-jnp chunked oracle AND the
token-by-token recurrence (three independent implementations agree)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.wkv_chunk import wkv_chunk, wkv_sequence
from repro.models.rwkv6 import _wkv_chunked


def _rand(seed, b=2, s=64, h=3, n=16):
    rng = np.random.default_rng(seed)
    r, k, v = (jnp.array(rng.normal(size=(b, s, h, n)).astype(np.float32))
               for _ in range(3))
    lw = -jnp.array(rng.uniform(0.01, 1.0, (b, s, h, n)).astype(np.float32))
    u = jnp.array(rng.normal(size=(h, n)).astype(np.float32))
    s0 = jnp.array(rng.normal(size=(b, h, n, n)).astype(np.float32)) * 0.1
    return r, k, v, lw, u, s0


@pytest.mark.parametrize("chunk", [8, 16, 32])
@pytest.mark.parametrize("seed", [0, 1])
def test_kernel_matches_jnp_chunked(chunk, seed):
    r, k, v, lw, u, s0 = _rand(seed)
    y_k, s_k = wkv_sequence(r, k, v, lw, u, s0, chunk=chunk, interpret=True)
    y_j, s_j = _wkv_chunked(r, k, v, lw, u, s0, chunk)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_j), rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_j), rtol=2e-4, atol=2e-5)


def test_kernel_matches_recurrence():
    """Kernel == plain per-token recurrence (ground truth)."""
    r, k, v, lw, u, s0 = _rand(7, b=1, s=32, h=2, n=8)
    y_k, s_k = wkv_sequence(r, k, v, lw, u, s0, chunk=8, interpret=True)

    b, s, h, n = r.shape
    S = np.asarray(s0, np.float64)[0]  # (h, n, n)
    rn, kn, vn = (np.asarray(t, np.float64)[0] for t in (r, k, v))
    w = np.exp(np.asarray(lw, np.float64))[0]
    un = np.asarray(u, np.float64)
    ys = np.zeros((s, h, n))
    for t in range(s):
        for hh in range(h):
            kv = np.outer(kn[t, hh], vn[t, hh])
            ys[t, hh] = rn[t, hh] @ (S[hh] + un[hh][:, None] * kv)
            S[hh] = S[hh] * w[t, hh][:, None] + kv
    np.testing.assert_allclose(np.asarray(y_k)[0], ys, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_k)[0], S, rtol=1e-3, atol=1e-4)


def test_single_chunk_shapes():
    r, k, v, lw, u, s0 = _rand(3, b=1, s=16, h=2, n=8)
    bh = 2
    rc = r.reshape(1, 16, 2, 8).transpose(0, 2, 1, 3).reshape(bh, 16, 8)
    y, s1 = wkv_chunk(rc, rc, rc, -jnp.abs(rc), jnp.ones((bh, 1, 8)),
                      jnp.zeros((bh, 8, 8)), interpret=True)
    assert y.shape == (bh, 16, 8) and s1.shape == (bh, 8, 8)
    assert np.isfinite(np.asarray(y)).all()
