"""Observability stack: metrics registry, span tracer, pipeline timeline.

The contracts under test (docs/observability.md):

* metric primitives behave (bucket edges pinned, Prometheus/JSON export,
  kind conflicts rejected);
* session metrics are *chunking-invariant* — the cumulative stream
  counters read identically whether a stream was served 1, 3 or T
  timesteps per tick;
* telemetry-disabled serving is bit-exact with telemetry enabled (the
  hooks only read engine state) and the disabled default registry is
  inert;
* traces are schema-valid Chrome-trace JSON with monotonic timestamps;
* the pipeline-timeline export conserves cycles exactly: per core,
  summed busy+routing durations equal ``MulticoreCost.busy_cycles``;
* the serving/durability layers record their counters (admissions,
  rejections, watchdog firings, rewinds) and ``benchmarks/run.py``'s
  ``meta`` key rides through ``tools/check_bench.py`` unseen.
"""
import argparse
import io
import json
import logging
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs, spidr
from repro.configs import spidr_gesture
from repro.core.network import init_params
from repro.obs.metrics import FRACTION_BUCKETS, LATENCY_BUCKETS_S


@pytest.fixture(autouse=True)
def _isolate_obs_defaults():
    """Each test gets fresh (disabled) process-wide defaults."""
    prev_reg, prev_tr = obs.default_registry(), obs.default_tracer()
    obs.set_default_registry(obs.MetricsRegistry(enabled=False))
    obs.set_default_tracer(obs.Tracer(enabled=False))
    yield
    obs.set_default_registry(prev_reg)
    obs.set_default_tracer(prev_tr)


def _compile(n_cores=1, timesteps=6, hw=(16, 16)):
    spec = spidr_gesture.reduced(hw=hw, timesteps=timesteps)
    params = init_params(jax.random.PRNGKey(0), spec)
    return spidr.compile(
        spec, params, spidr.DeployTarget(backend="jnp", n_cores=n_cores))


@pytest.fixture(scope="module")
def compiled1():
    return _compile(n_cores=1)


@pytest.fixture(scope="module")
def compiled4():
    return _compile(n_cores=4, timesteps=2)


def _stream(t=6, hw=(16, 16), seed=0, thresh=0.9):
    rng = np.random.default_rng(seed)
    return (rng.random((t,) + hw + (2,)) > thresh).astype(np.float32)


# ---------------------------------------------------------------------------
# Metric primitives.
# ---------------------------------------------------------------------------
class TestMetricsRegistry:
    def test_bucket_edges_are_pinned(self):
        # Dashboards and recorded baselines depend on these exact edges —
        # changing them is a breaking change, not a tweak.
        assert FRACTION_BUCKETS == (0.01, 0.05, 0.10, 0.25, 0.50, 0.75,
                                    0.90, 0.95, 0.99, 1.0)
        assert LATENCY_BUCKETS_S == (0.0005, 0.001, 0.0025, 0.005, 0.01,
                                     0.025, 0.05, 0.1, 0.25, 0.5, 1.0,
                                     2.5, 5.0, 10.0)

    def test_counter_gauge_histogram(self):
        reg = obs.MetricsRegistry(enabled=True)
        c = reg.counter("c_total", "a counter")
        c.inc()
        c.inc(2.5)
        assert c.value == 3.5
        with pytest.raises(ValueError):
            c.inc(-1)
        g = reg.gauge("g", "a gauge")
        g.set(7)
        g.dec(3)
        assert g.value == 4
        h = reg.histogram("h", "a histogram", edges=(1.0, 2.0))
        for v in (0.5, 1.5, 99.0):
            h.observe(v)
        assert list(h.bucket_counts) == [1, 1, 1]  # +Inf overflow bucket
        assert h.count == 3 and h.total == 101.0
        assert list(h.cumulative()) == [1, 2, 3]

    def test_kind_conflict_rejected(self):
        reg = obs.MetricsRegistry(enabled=True)
        reg.counter("x", "as counter")
        with pytest.raises(ValueError, match="x"):
            reg.gauge("x", "as gauge")

    def test_histogram_edges_must_ascend(self):
        reg = obs.MetricsRegistry(enabled=True)
        with pytest.raises(ValueError):
            reg.histogram("bad", "edges", edges=(2.0, 1.0))

    def test_prometheus_text_format(self):
        reg = obs.MetricsRegistry(enabled=True)
        reg.counter("req_total", "requests", labels={"slot": 0}).inc(5)
        reg.histogram("lat", "latency", edges=(0.1, 1.0)).observe(0.05)
        text = reg.to_prometheus()
        assert "# HELP req_total requests" in text
        assert "# TYPE req_total counter" in text
        assert 'req_total{slot="0"} 5' in text
        assert 'lat_bucket{le="0.1"} 1' in text
        assert 'lat_bucket{le="+Inf"} 1' in text
        assert "lat_sum 0.05" in text and "lat_count 1" in text

    def test_write_picks_format_from_suffix(self, tmp_path):
        reg = obs.MetricsRegistry(enabled=True)
        reg.counter("n_total", "n").inc()
        as_json = json.loads(reg.write(tmp_path / "m.json").read_text())
        assert as_json["n_total"][0]["value"] == 1.0
        as_prom = reg.write(tmp_path / "m.prom").read_text()
        assert "n_total 1" in as_prom

    def test_registry_truthiness_is_the_enable_switch(self):
        # Instrumentation sites guard with `if reg:` — a disabled registry
        # costs one __bool__ per site and nothing else.
        assert not obs.MetricsRegistry(enabled=False)
        assert obs.MetricsRegistry(enabled=True)
        assert not obs.default_registry()  # fixture default: disabled


# ---------------------------------------------------------------------------
# Session metrics through the facade.
# ---------------------------------------------------------------------------
def _serve_stream(compiled, stream, chunk_T, metrics=None, tracer=None):
    session = compiled.open_stream(capacity=2, chunk_T=chunk_T,
                                   metrics=metrics, tracer=tracer)
    slot = session.open()
    update = None
    for start in range(0, stream.shape[0], chunk_T):
        update = session.step({slot: stream[start:start + chunk_T]})[slot]
    session.close(slot)
    return update


class TestSessionMetrics:
    def test_chunking_invariant_counters(self, compiled1):
        """Cumulative stream counters are identical at chunk_T 1, 3 and T."""
        stream = _stream(t=6)
        dumps = []
        for chunk_T in (1, 3, 6):
            reg = obs.MetricsRegistry(enabled=True)
            _serve_stream(compiled1, stream, chunk_T, metrics=reg)
            dumps.append(reg.to_dict())
        invariant = ("spidr_stream_timesteps_total",
                     "spidr_stream_input_spikes_total",
                     "spidr_stream_output_spikes_total",
                     "spidr_stream_cycles_total")
        for name in invariant:
            vals = [d[name][0]["value"] for d in dumps]
            assert vals[0] == vals[1] == vals[2], (name, vals)
        uj = [d["spidr_stream_energy_uj_total"][0]["value"] for d in dumps]
        assert uj[1] == pytest.approx(uj[0], rel=1e-9)
        assert uj[2] == pytest.approx(uj[0], rel=1e-9)
        # Tick count is chunking-DEPENDENT by design: 6, 2 and 1 ticks.
        ticks = [d["spidr_session_ticks_total"][0]["value"] for d in dumps]
        assert ticks == [6.0, 2.0, 1.0]

    def test_disabled_mode_bit_exact(self, compiled1):
        """Telemetry on vs pinned-off: identical readout/cycles/energy."""
        stream = _stream(t=6, seed=3)
        reg, tr = obs.MetricsRegistry(enabled=True), obs.Tracer()
        on = _serve_stream(compiled1, stream, 3, metrics=reg, tracer=tr)
        off = _serve_stream(compiled1, stream, 3, metrics=False, tracer=False)
        np.testing.assert_array_equal(np.asarray(on.readout),
                                      np.asarray(off.readout))
        assert (on.cycles, on.energy_uj) == (off.cycles, off.energy_uj)

    def test_sparsity_histogram_and_occupancy(self, compiled1):
        reg = obs.MetricsRegistry(enabled=True)
        session = compiled1.open_stream(capacity=2, chunk_T=3, metrics=reg)
        slot = session.open()
        session.step({slot: _stream(t=3, thresh=0.95)})
        d = reg.to_dict()
        h = d["spidr_chunk_sparsity"][0]
        assert tuple(h["buckets"]["edges"]) == FRACTION_BUCKETS
        assert h["count"] == 1
        assert d["spidr_session_occupancy"][0]["value"] == 1.0
        assert d["spidr_chunk_nonzero_tile_frac"][0]["count"] == 1

    def test_compiled_metrics_scrape(self, compiled1):
        obs.enable_metrics()
        session = compiled1.open_stream(capacity=2, chunk_T=3)
        slot = session.open()
        session.step({slot: _stream(t=3)})
        assert "spidr_session_ticks_total 1" in compiled1.metrics()
        as_json = compiled1.metrics(fmt="json")
        assert as_json["spidr_session_ticks_total"][0]["value"] == 1.0
        with pytest.raises(ValueError):
            compiled1.metrics(fmt="xml")


# ---------------------------------------------------------------------------
# Span tracer.
# ---------------------------------------------------------------------------
class TestTracer:
    def test_chrome_trace_schema_and_monotonic_ts(self, tmp_path):
        tr = obs.Tracer()
        with tr.span("outer", cat="t", k=1):
            with tr.span("inner", cat="t"):
                pass
        tr.instant("tick")
        path = tmp_path / "trace.json"
        tr.export(path)
        doc = json.loads(path.read_text())
        assert doc["displayTimeUnit"] == "ms"
        spans = [e for e in doc["traceEvents"] if e["ph"] == "X"]
        assert {e["name"] for e in spans} == {"outer", "inner"}
        for e in spans:
            assert {"name", "cat", "ph", "ts", "dur", "pid", "tid"} <= set(e)
            assert e["dur"] >= 0
        ts = [e["ts"] for e in spans]
        assert ts == sorted(ts)
        # Export sorts by open time, so the enclosing span leads even
        # though it closed last.
        assert spans[0]["name"] == "outer"
        assert any(e["ph"] == "i" and e["name"] == "tick"
                   for e in doc["traceEvents"])
        assert any(e["ph"] == "M" for e in doc["traceEvents"])

    def test_span_args_recorded(self):
        tr = obs.Tracer()
        with tr.span("s", cat="c", layer=3, kind="conv"):
            pass
        (ev,) = [e for e in tr.to_chrome()["traceEvents"] if e["ph"] == "X"]
        assert ev["args"] == {"layer": 3, "kind": "conv"}

    def test_disabled_tracer_records_nothing(self):
        tr = obs.Tracer(enabled=False)
        assert not tr
        with tr.span("s"):
            pass
        assert [e for e in tr.to_chrome()["traceEvents"]
                if e["ph"] == "X"] == []

    def test_max_events_drops_and_counts(self):
        tr = obs.Tracer(max_events=2)
        for i in range(5):
            with tr.span(f"s{i}"):
                pass
        assert len([e for e in tr.to_chrome()["traceEvents"]
                    if e["ph"] == "X"]) == 2
        assert tr.dropped_events == 3

    def test_session_tracing_via_facade(self, compiled1):
        tr = obs.Tracer()
        _serve_stream(compiled1, _stream(t=6), 3, tracer=tr)
        spans = [e for e in tr.to_chrome()["traceEvents"] if e["ph"] == "X"]
        assert [e["name"] for e in spans] == ["run_chunk", "run_chunk"]
        assert all(e["cat"] == "session" for e in spans)

    def test_compile_spans_on_default_tracer(self):
        obs.enable_tracing()
        _compile(n_cores=1, timesteps=2)
        names = {e["name"] for e in
                 obs.default_tracer().to_chrome()["traceEvents"]
                 if e["ph"] == "X"}
        assert {"spidr.compile", "engine.build"} <= names


# ---------------------------------------------------------------------------
# Pipeline timeline: the cost model as a trace.
# ---------------------------------------------------------------------------
class TestPipelineTimeline:
    def test_busy_cycles_conserved_exactly(self, compiled4):
        ev = jnp.asarray(_stream(t=2)[:, None])
        out = compiled4.run(ev)
        events = compiled4.pipeline_trace(out)
        totals = obs.busy_cycle_totals(events)
        cost = compiled4.cost(out)
        n_cores = len(cost.busy_cycles)
        assert n_cores == 4
        for core in range(n_cores):
            assert int(totals.get(core, 0)) == int(cost.busy_cycles[core])

    def test_collect_timeline_does_not_change_cost(self, compiled4):
        from repro.engine.cost import estimate_multicore_cost

        ev = jnp.asarray(_stream(t=2)[:, None])
        out = compiled4.run(ev)
        counts = np.asarray(out.input_counts)
        plain = estimate_multicore_cost(compiled4.spec, compiled4.schedule,
                                        counts)
        timed = estimate_multicore_cost(compiled4.spec, compiled4.schedule,
                                        counts, collect_timeline=True)
        assert plain.timeline is None and timed.timeline
        assert plain.makespan_cycles == timed.makespan_cycles
        np.testing.assert_array_equal(plain.busy_cycles, timed.busy_cycles)
        np.testing.assert_array_equal(plain.compute_cycles,
                                      timed.compute_cycles)

    def test_core_tracks_are_gapless_with_idle_tail(self, compiled4):
        """Per core: back-to-back intervals; a core shorter than the plan
        makespan gets an idle tail up to it."""
        ev = jnp.asarray(_stream(t=2)[:, None])
        out = compiled4.run(ev)
        cost = compiled4.cost(out)
        events = compiled4.pipeline_trace(out)
        totals = obs.busy_cycle_totals(events)
        for core in range(4):
            spans = sorted((e for e in events
                            if e.get("ph") == "X" and e["tid"] == core),
                           key=lambda e: e["ts"])
            for prev, nxt in zip(spans, spans[1:]):
                assert prev["ts"] + prev["dur"] == nxt["ts"]
            end = spans[-1]["ts"] + spans[-1]["dur"]
            assert end == max(float(cost.makespan_cycles), totals[core])

    def test_timeline_requires_collect_flag(self, compiled4):
        ev = jnp.asarray(_stream(t=2)[:, None])
        cost = compiled4.cost(compiled4.run(ev))  # priced WITHOUT timeline
        with pytest.raises(ValueError, match="collect_timeline"):
            obs.multicore_timeline(cost)

    def test_single_core_has_no_pipeline_trace(self, compiled1):
        ev = jnp.asarray(_stream(t=6)[:, None])
        out = compiled1.run(ev)
        with pytest.raises(ValueError):
            compiled1.pipeline_trace(out)

    def test_write_chrome_trace_sorted(self, tmp_path, compiled4):
        from repro.obs.timeline import write_chrome_trace

        ev = jnp.asarray(_stream(t=2)[:, None])
        events = compiled4.pipeline_trace(compiled4.run(ev))
        path = write_chrome_trace(list(reversed(events)), tmp_path / "p.json")
        doc = json.loads(path.read_text())
        ts = [e["ts"] for e in doc["traceEvents"] if e.get("ph") == "X"]
        assert ts == sorted(ts)


# ---------------------------------------------------------------------------
# Serving + durability counters.
# ---------------------------------------------------------------------------
class TestServingTelemetry:
    def test_streaming_server_counters(self, compiled1):
        from repro.serving import StreamRequest, StreamWorker

        obs.enable_metrics()
        server = StreamWorker(compiled1, capacity=2, chunk_T=3)
        for rid in range(3):   # 3 streams into 2 slots: 1+ deferred ticks
            server.submit(StreamRequest(rid=rid, events=_stream(t=6, seed=rid)))
        ticks = 0
        while server.step():
            ticks += 1
        d = obs.default_registry().to_dict()
        assert d["spidr_serve_admissions_total"][0]["value"] == 3.0
        assert d["spidr_serve_rejections_total"][0]["value"] >= 1.0
        assert d["spidr_serve_tick_seconds"][0]["count"] == ticks
        assert tuple(d["spidr_serve_tick_seconds"][0]["buckets"]["edges"]) \
            == LATENCY_BUCKETS_S
        assert len(server.done) == 3

    def test_watchdog_counter(self):
        from repro.runtime.fault_tolerance import StepWatchdog

        reg = obs.MetricsRegistry(enabled=True)
        c = reg.counter("spidr_serve_watchdog_timeouts_total", "firings")
        wd = StepWatchdog(0.01, counter=c)
        wd.arm()
        time.sleep(0.05)
        wd.disarm()
        assert wd.timed_out and c.value == 1.0

    def test_retrying_on_restart_hook(self):
        from repro.runtime.fault_tolerance import (
            RestartableFailure, retrying,
        )

        calls = {"n": 0}
        restarts = []

        def step():
            calls["n"] += 1
            if calls["n"] == 1:
                raise RestartableFailure("poisoned")
            return "ok"

        fn = retrying(step, lambda *a, **k: None,
                      on_restart=lambda: restarts.append(1))
        assert fn() == "ok"
        assert restarts == [1]

    def test_rewind_counter_via_injected_fault(self, compiled1):
        from repro.serving import StreamRequest, StreamWorker

        obs.enable_metrics()
        server = StreamWorker(compiled1, capacity=2, chunk_T=3,
                                    fail_at_tick=1)
        server.submit(StreamRequest(rid=0, events=_stream(t=6)))
        while server.step():
            pass
        assert server.restarts == 1
        d = obs.default_registry().to_dict()
        assert d["spidr_serve_rewinds_total"][0]["value"] == 1.0


# ---------------------------------------------------------------------------
# Structured logging.
# ---------------------------------------------------------------------------
class TestLogging:
    def _logger(self, name, json_mode):
        buf = io.StringIO()
        lg = logging.getLogger(name)
        lg.handlers.clear()
        obs.logging_setup(json_mode=json_mode, logger=lg, stream=buf)
        return lg, buf

    def test_request_id_in_text_records(self):
        lg, buf = self._logger("test.obs.text", json_mode=False)
        from repro.obs.logs import request_context

        lg.info("outside")
        with request_context(42):
            lg.info("inside")
        lines = buf.getvalue().strip().splitlines()
        assert "rid=- outside" in lines[0]
        assert "rid=42 inside" in lines[1]

    def test_request_id_in_json_records(self):
        lg, buf = self._logger("test.obs.json", json_mode=True)
        from repro.obs.logs import request_context

        with request_context(7):
            lg.warning("hot slot %d", 3)
        rec = json.loads(buf.getvalue())
        assert rec["request_id"] == "7"
        assert rec["level"] == "WARNING"
        assert rec["message"] == "hot slot 3"
        assert rec["logger"] == "test.obs.json"

    def test_setup_is_idempotent(self):
        lg, _ = self._logger("test.obs.idem", json_mode=False)
        obs.logging_setup(logger=lg, stream=io.StringIO())
        obs.logging_setup(logger=lg, stream=io.StringIO())
        ours = [h for h in lg.handlers
                if getattr(h, "_spidr_obs_handler", False)]
        assert len(ours) == 1


# ---------------------------------------------------------------------------
# End to end: the serving CLI path and the bench-meta contract.
# ---------------------------------------------------------------------------
class TestEndToEnd:
    def test_serve_snn_writes_metrics_and_trace(self, tmp_path):
        from repro.launch.serve import serve_snn

        args = argparse.Namespace(
            snn="gesture", weight_bits=4, jnp=True, n_cores=4, chunk_T=2,
            capacity=2, requests=3, streaming=True,
            metrics_out=str(tmp_path / "m.prom"),
            metrics_every=1, trace_out=str(tmp_path / "t.json"))
        server = serve_snn(args)
        assert len(server.done) == 3
        prom = (tmp_path / "m.prom").read_text()
        assert "spidr_session_ticks_total" in prom
        assert "spidr_serve_admissions_total 3" in prom
        assert "spidr_serve_tick_seconds_bucket" in prom
        doc = json.loads((tmp_path / "t.json").read_text())
        spans = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
        names = {e["name"] for e in spans}
        assert {"spidr.compile", "serve.tick", "run_chunk"} <= names
        # One pipeline-timeline process row per finished stream.
        stream_pids = {e["pid"] for e in spans if e.get("cat") == "busy"}
        assert stream_pids == {100, 101, 102}
        ts = [e["ts"] for e in doc["traceEvents"] if e.get("ph") == "X"]
        assert ts == sorted(ts)

    def test_check_bench_ignores_meta_key(self, tmp_path):
        results = [{"name": "x", "ablation": "a", "cycles": 100,
                    "exact": True}]
        base = {"schema": 1, "suite": "s", "results": results}
        fresh = {"schema": 1, "suite": "s", "results": results,
                 "meta": {"git_sha": "deadbeef", "jax": "0.0.0",
                          "timestamp": "2026-01-01T00:00:00+00:00"}}
        (tmp_path / "baseline.json").write_text(json.dumps(base))
        (tmp_path / "fresh.json").write_text(json.dumps(fresh))
        proc = subprocess.run(
            [sys.executable, "tools/check_bench.py",
             str(tmp_path / "fresh.json"),
             "--baseline", str(tmp_path / "baseline.json")],
            capture_output=True, text=True)
        assert proc.returncode == 0, proc.stdout + proc.stderr

    def test_committed_baseline_has_meta(self):
        payload = json.loads(
            open("benchmarks/baseline.json", encoding="utf-8").read())
        assert {"git_sha", "jax", "jaxlib", "python",
                "timestamp"} <= set(payload["meta"])
