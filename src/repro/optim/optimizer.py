"""Optimizers in pure JAX (no external deps): AdamW, SGD-momentum, Lion.

Small, pytree-generic, and shard-transparent: optimizer state mirrors the
parameter pytree, so under pjit the moments inherit the params' sharding
(ZeRO-style: FSDP-sharded params => FSDP-sharded optimizer state for free).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

__all__ = [
    "adamw",
    "sgd",
    "lion",
    "apply_updates",
    "clip_by_global_norm",
    "global_norm",
    "cosine_schedule",
    "linear_warmup_cosine",
]

Pytree = Any


def global_norm(tree: Pytree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x)) for x in jax.tree.leaves(tree) if x is not None]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads: Pytree, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: None if g is None else g * scale, grads,
                        is_leaf=lambda x: x is None), norm


def apply_updates(params: Pytree, updates: Pytree) -> Pytree:
    return jax.tree.map(
        lambda p, u: p if u is None else p + u, params, updates,
        is_leaf=lambda x: x is None,
    )


def _zeros_like_tree(params: Pytree) -> Pytree:
    return jax.tree.map(lambda p: None if p is None else jnp.zeros_like(p), params,
                        is_leaf=lambda x: x is None)


def adamw(lr=1e-3, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0, params=None,
          lr_schedule: Callable | None = None):
    """Returns (update_fn, init_state). update_fn(grads, state, params, step)."""
    state = None
    if params is not None:
        state = {"mu": _zeros_like_tree(params), "nu": _zeros_like_tree(params)}

    def update_fn(grads, state, params, step):
        step_f = jnp.asarray(step, jnp.float32) + 1.0
        cur_lr = lr_schedule(step_f) if lr_schedule is not None else lr

        def upd(g, mu, nu, p):
            if g is None:
                return None, None, None
            mu = b1 * mu + (1 - b1) * g
            nu = b2 * nu + (1 - b2) * jnp.square(g)
            mu_hat = mu / (1 - b1**step_f)
            nu_hat = nu / (1 - b2**step_f)
            u = -cur_lr * (mu_hat / (jnp.sqrt(nu_hat) + eps) + weight_decay * p)
            return u, mu, nu

        flat_g, treedef = jax.tree.flatten(grads, is_leaf=lambda x: x is None)
        flat_mu = treedef.flatten_up_to(state["mu"])
        flat_nu = treedef.flatten_up_to(state["nu"])
        flat_p = treedef.flatten_up_to(params)
        out = [upd(g, m, n, p) for g, m, n, p in zip(flat_g, flat_mu, flat_nu, flat_p)]
        updates = treedef.unflatten([o[0] for o in out])
        new_state = {
            "mu": treedef.unflatten([o[1] for o in out]),
            "nu": treedef.unflatten([o[2] for o in out]),
        }
        return updates, new_state

    return update_fn, state


def sgd(lr=1e-2, momentum=0.9, nesterov=False, params=None):
    state = _zeros_like_tree(params) if params is not None else None

    def update_fn(grads, state, params, step):
        def upd(g, v):
            if g is None:
                return None, None
            v = momentum * v + g
            u = -(lr * (g + momentum * v)) if nesterov else -(lr * v)
            return u, v

        flat_g, treedef = jax.tree.flatten(grads, is_leaf=lambda x: x is None)
        flat_v = treedef.flatten_up_to(state)
        out = [upd(g, v) for g, v in zip(flat_g, flat_v)]
        return treedef.unflatten([o[0] for o in out]), treedef.unflatten(
            [o[1] for o in out]
        )

    return update_fn, state


def lion(lr=1e-4, b1=0.9, b2=0.99, weight_decay=0.0, params=None):
    state = _zeros_like_tree(params) if params is not None else None

    def update_fn(grads, state, params, step):
        def upd(g, m, p):
            if g is None:
                return None, None
            u = -lr * (jnp.sign(b1 * m + (1 - b1) * g) + weight_decay * p)
            m = b2 * m + (1 - b2) * g
            return u, m

        flat_g, treedef = jax.tree.flatten(grads, is_leaf=lambda x: x is None)
        flat_m = treedef.flatten_up_to(state)
        flat_p = treedef.flatten_up_to(params)
        out = [upd(g, m, p) for g, m, p in zip(flat_g, flat_m, flat_p)]
        return treedef.unflatten([o[0] for o in out]), treedef.unflatten(
            [o[1] for o in out]
        )

    return update_fn, state


def cosine_schedule(base_lr: float, total_steps: int, final_frac: float = 0.1):
    def fn(step):
        t = jnp.clip(step / total_steps, 0.0, 1.0)
        return base_lr * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))

    return fn


def linear_warmup_cosine(base_lr: float, warmup: int, total_steps: int,
                         final_frac: float = 0.1):
    cos = cosine_schedule(base_lr, max(total_steps - warmup, 1), final_frac)

    def fn(step):
        warm = base_lr * step / max(warmup, 1)
        return jnp.where(step < warmup, warm, cos(step - warmup))

    return fn
