"""Gradient compression for the cross-pod (DCN) all-reduce.

At 1000+ node scale the pod-axis gradient reduction crosses the data-center
network, which is ~10x slower than ICI.  We compress that hop:

  * int8 quantization with per-tensor scales + error feedback (the residual
    is carried to the next step, keeping the scheme unbiased in the limit —
    standard EF-SGD construction), or
  * top-k sparsification with error feedback.

Compression is applied ONLY to the pod-axis reduction (`pod_allreduce_int8`
composes reduce-scatter intra-pod in full precision with the compressed
cross-pod sum), mirroring hierarchical-collective practice.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

__all__ = [
    "int8_compress",
    "int8_decompress",
    "ef_int8_allreduce",
    "topk_compress",
    "init_error_state",
]

Pytree = Any


def int8_compress(x: jax.Array):
    scale = jnp.max(jnp.abs(x)) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_state(params: Pytree) -> Pytree:
    return jax.tree.map(
        lambda p: None if p is None else jnp.zeros_like(p), params,
        is_leaf=lambda x: x is None,
    )


def ef_int8_allreduce(grads: Pytree, error: Pytree, axis_name: str):
    """Error-feedback int8 all-reduce over ``axis_name`` (inside shard_map/pjit).

    g_hat = Q(g + e);  e' = (g + e) - dequant(g_hat);  reduce(g_hat).
    """

    def one(g, e):
        if g is None:
            return None, None
        corrected = g + e
        q, scale = int8_compress(corrected)
        deq = int8_decompress(q, scale)
        new_e = corrected - deq
        # Sum dequantized int8 payloads across the axis. On the wire this is
        # the int8 tensor + one f32 scale; jax.lax.psum models the reduction.
        reduced = jax.lax.psum(deq, axis_name)
        return reduced, new_e

    flat_g, treedef = jax.tree.flatten(grads, is_leaf=lambda x: x is None)
    flat_e = treedef.flatten_up_to(error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return treedef.unflatten([o[0] for o in out]), treedef.unflatten(
        [o[1] for o in out]
    )


def topk_compress(x: jax.Array, k_frac: float = 0.01):
    """Keep the top-k|x| entries (dense mask representation for SPMD)."""
    flat = x.reshape(-1)
    k = max(1, int(flat.shape[0] * k_frac))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    mask = (jnp.abs(x) >= thresh).astype(x.dtype)
    return x * mask, mask
