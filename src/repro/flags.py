"""Performance-variant flags (EXPERIMENTS.md §Perf).

Each flag is one hillclimb lever; the dry-run's ``--variant`` composes them
so every hypothesis->change->measure cycle is reproducible:

  base            paper-faithful baseline (all off)
  remat_saveout   activation-checkpoint policy saves the POST-collective
                  block output, so backward recompute does not re-issue the
                  forward TP all-reduces
  seqpar          Megatron-style sequence parallelism: the residual stream
                  between blocks is sequence-sharded over 'model'
  dp_only         no tensor parallelism: params FSDP over (data x model),
                  batch over every axis — for models too small to TP
  opt             remat_saveout + seqpar (the shipping configuration)
"""
from __future__ import annotations

FLAGS = {
    "remat_saveout": False,
    "sequence_parallel": False,
    "dp_only": False,
    "serve_tp": False,
    "bf16_params": False,
    "serve_bf16_weights": False,
}

VARIANTS = {
    "base": {},
    "remat_saveout": {"remat_saveout": True},
    "seqpar": {"sequence_parallel": True},
    "remat_seqpar": {"remat_saveout": True, "sequence_parallel": True},
    "dp_only": {"dp_only": True},
    "dp_only_remat": {"dp_only": True, "remat_saveout": True},
    "serve_tp": {"serve_tp": True},
    "bf16": {"bf16_params": True},
    "bf16_seqpar": {"bf16_params": True, "sequence_parallel": True, "remat_saveout": True},
    "dp_only_bf16": {"dp_only": True, "bf16_params": True},
    "dp_only_bf16_remat": {"dp_only": True, "bf16_params": True, "remat_saveout": True},
    "serve_tp_bf16": {"serve_tp": True, "bf16_params": True},
    "serve_opt": {"serve_tp": True, "serve_bf16_weights": True},
    "opt": {"remat_saveout": True, "sequence_parallel": True},
    # resolved per-cell by launch.dryrun.resolve_auto
    "auto": {},
}


def set_variant(name: str):
    for k in FLAGS:
        FLAGS[k] = False
    for k, v in VARIANTS[name].items():
        FLAGS[k] = v


def flag(name: str) -> bool:
    return FLAGS[name]
