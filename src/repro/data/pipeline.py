"""Deterministic synthetic token pipeline (sharded, prefetching, elastic).

Every batch is a pure function of (seed, step) — no iterator state to
checkpoint, and restores on a DIFFERENT device count resume bit-identically
(elastic scaling): the global batch is generated per host shard via
``jax.make_array_from_callback`` so each process only materializes its
addressable slice.

The stream is a mixture of structured sequences (repeated n-grams, copy
tasks, arithmetic-progression tokens) rather than iid noise, so small
models show a real, monotonically-decreasing loss — useful for the
end-to-end examples and convergence tests.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["TokenPipeline", "synth_tokens"]


def synth_tokens(seed: int, step: int, batch: int, seq_len: int, vocab: int) -> np.ndarray:
    """(batch, seq_len) int32 — deterministic, structured."""
    rng = np.random.default_rng(np.uint64(seed) * np.uint64(1_000_003) + np.uint64(step))
    out = np.empty((batch, seq_len), np.int32)
    for i in range(batch):
        kind = rng.integers(0, 3)
        if kind == 0:  # repeated n-gram
            n = int(rng.integers(2, 9))
            gram = rng.integers(0, vocab, n)
            reps = -(-seq_len // n)
            out[i] = np.tile(gram, reps)[:seq_len]
        elif kind == 1:  # arithmetic progression mod vocab
            a, d = rng.integers(0, vocab), int(rng.integers(1, 17))
            out[i] = (a + d * np.arange(seq_len)) % vocab
        else:  # noisy copy: first half random, second half copies
            half = seq_len // 2
            first = rng.integers(0, vocab, half)
            out[i, :half] = first
            out[i, half:] = np.resize(first, seq_len - half)
    return out


class TokenPipeline:
    """Prefetching host data pipeline producing sharded global arrays."""

    def __init__(
        self,
        batch: int,
        seq_len: int,
        vocab: int,
        seed: int = 0,
        sharding: Optional[jax.sharding.Sharding] = None,
        prefetch: int = 2,
        embeds_dim: int = 0,  # >0: emit precomputed-embedding stub inputs
    ):
        self.batch, self.seq_len, self.vocab = batch, seq_len, vocab
        self.seed, self.sharding = seed, sharding
        self.embeds_dim = embeds_dim
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._step = 0
        self._worker: Optional[threading.Thread] = None
        self._stop = threading.Event()

    def _make(self, step: int) -> dict:
        toks = synth_tokens(self.seed, step, self.batch, self.seq_len, self.vocab)
        batch = {"labels": toks}
        if self.embeds_dim:
            rng = np.random.default_rng(step)
            batch["embeds"] = rng.standard_normal(
                (self.batch, self.seq_len, self.embeds_dim), np.float32
            ).astype(jnp.bfloat16)
        else:
            batch["tokens"] = toks
        if self.sharding is not None:
            batch = {
                k: jax.make_array_from_callback(
                    v.shape, self.sharding, lambda idx, vv=v: vv[idx]
                )
                for k, v in batch.items()
            }
        else:
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
        return batch

    def batch_at(self, step: int) -> dict:
        """Pure access — used for elastic resume and tests."""
        return self._make(step)

    def __iter__(self) -> Iterator[dict]:
        def worker():
            s = self._step
            while not self._stop.is_set():
                try:
                    self._q.put(self._make(s), timeout=0.5)
                    s += 1
                except queue.Full:
                    continue

        self._worker = threading.Thread(target=worker, daemon=True)
        self._worker.start()
        while True:
            yield self._q.get()

    def close(self):
        self._stop.set()
