"""Graph IR for the multi-core compiler (stage 1 of 4: IR -> partition ->
select -> schedule).

An :class:`SNNSpec` is a flat layer list; the compiler works on a small
explicit graph instead, because partitioning and routing are graph
questions: *which core produces the spikes that this layer consumes, and
how many of them cross a core boundary?*

Every spec layer becomes a :class:`LayerNode` (pool layers included — they
transform the spike plane between weight layers and determine routing
volumes).  Weight nodes carry their accelerator-view :class:`LayerShape`
plus the size of the spike plane they consume per timestep
(``in_positions`` — the routing-volume proxy: at input density ``d`` the
layer receives ``d * in_positions`` spikes per timestep).

The IR is deliberately a chain with explicit predecessor links rather than
a general DAG: both paper networks are chains, but everything downstream
(partitioner, router) only uses ``inputs``/``consumers``, so branching
topologies are an IR extension, not a rewrite.
"""
from __future__ import annotations

import dataclasses

from ..core.modes import LayerShape
from ..core.network import SNNSpec

__all__ = ["LayerNode", "NetworkGraph", "build_graph"]


@dataclasses.dataclass(frozen=True)
class LayerNode:
    """One spec layer as a graph node.

    ``idx``          position in ``spec.layers`` (== params index).
    ``kind``         "conv" | "fc" | "pool" | "adaptive_pool".
    ``shape``        accelerator-view :class:`LayerShape` (weight nodes only).
    ``inputs``       predecessor node indices (empty for the input layer).
    ``in_positions`` spike-plane positions consumed per timestep
                     (H*W*C_in for conv, N_in for fc) — routing volume.
    ``out_positions``spike-plane positions produced per timestep.
    """

    idx: int
    kind: str
    shape: LayerShape | None
    inputs: tuple
    in_positions: int = 0
    out_positions: int = 0

    @property
    def is_weight(self) -> bool:
        return self.kind in ("conv", "fc")


@dataclasses.dataclass(frozen=True)
class NetworkGraph:
    """Layer graph of one network, annotated for partitioning/routing."""

    name: str
    nodes: tuple  # of LayerNode, in execution order

    @property
    def weight_nodes(self) -> tuple:
        return tuple(n for n in self.nodes if n.is_weight)

    def producer_of(self, node: LayerNode) -> LayerNode | None:
        """Nearest *weight* ancestor — the layer whose output spikes this
        node consumes (pool nodes are transparent: they reshape the spike
        plane on whichever core produced it)."""
        seen = node
        while seen.inputs:
            seen = self.nodes[seen.inputs[0]]
            if seen.is_weight:
                return seen
        return None


def build_graph(spec: SNNSpec) -> NetworkGraph:
    """Lower an :class:`SNNSpec` into the compiler IR."""
    h, w = spec.input_hw
    c = spec.in_channels
    shapes = iter(spec.layer_shapes())
    nodes = []
    for i, l in enumerate(spec.layers):
        inputs = (i - 1,) if i else ()
        if l.kind == "conv":
            shape = next(shapes)
            in_pos = h * w * c
            p = l.conv
            h = (h + 2 * p.padding - p.kh) // p.stride + 1
            w = (w + 2 * p.padding - p.kw) // p.stride + 1
            c = l.c_out
            nodes.append(LayerNode(i, "conv", shape, inputs,
                                   in_positions=in_pos,
                                   out_positions=h * w * c))
        elif l.kind == "fc":
            shape = next(shapes)
            nodes.append(LayerNode(i, "fc", shape, inputs,
                                   in_positions=shape.fan_in,
                                   out_positions=shape.out_channels))
            c = l.c_out
        elif l.kind == "pool":
            in_pos = h * w * c
            h, w = h // 2, w // 2
            nodes.append(LayerNode(i, "pool", None, inputs,
                                   in_positions=in_pos,
                                   out_positions=h * w * c))
        elif l.kind == "adaptive_pool":
            in_pos = h * w * c
            h = w = l.target_hw
            nodes.append(LayerNode(i, "adaptive_pool", None, inputs,
                                   in_positions=in_pos,
                                   out_positions=h * w * c))
        else:  # pragma: no cover - spec validated upstream
            raise ValueError(l.kind)
    return NetworkGraph(name=spec.name, nodes=tuple(nodes))
