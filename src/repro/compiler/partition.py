"""Partitioner (stage 2 of 4): split or place every weight layer on cores.

Two placement regimes, chosen per layer (Chauvaux et al.'s observation that
the right level of parallelism is a *per-layer* decision):

* **intra-layer channel split** — a layer whose fan-in or fan-out exceeds
  what one core executes in a single weight-stationary pass
  (``fan_in_tiles > 1`` or ``channel_tiles > 1``) is split along its
  *output channels* across several cores.  Each core holds a contiguous
  channel slice of the weights and scans the full input spike plane into
  its own macros, so input spikes must be routed (AER, 2 cycles/spike) to
  every core holding a slice.  Channel-splitting divides the sequential
  channel tiles (the dominant term when ``channel_tiles > 1``) and divides
  weight storage (the constraint when ``fan_in_tiles > 1``).

* **inter-layer pipeline** — a layer that fits one core is assigned whole
  to the currently least-loaded core (greedy bin-packing on modeled
  row-op cycles at the assumed input density).  Consecutive layers on
  different cores form a core-to-core pipeline; the spikes between them
  are the routed traffic.

Output channels are always partitioned into *contiguous* slices covering
``[0, out_channels)`` in order — the engine reassembles a layer's output
by concatenating slice results, which keeps multi-core execution bit-exact
with the single-core path (an integer GEMM + per-channel neuron update is
column-independent).
"""
from __future__ import annotations

import dataclasses
import math

from ..core.modes import CoreConfig, map_layer
from ..core.pipeline import ROUTE_CYCLES_PER_SPIKE
from ..core.quant import QuantSpec
from .ir import NetworkGraph

__all__ = ["ChannelSlice", "CoreGrid", "LayerPartition", "partition_graph"]


@dataclasses.dataclass(frozen=True)
class CoreGrid:
    """A grid of identical SpiDR cores joined by an AER spike fabric."""

    n_cores: int = 1
    route_cycles_per_spike: int = ROUTE_CYCLES_PER_SPIKE

    def __post_init__(self):
        assert self.n_cores >= 1, self.n_cores


@dataclasses.dataclass(frozen=True)
class ChannelSlice:
    """Contiguous output-channel range ``[lo, hi)`` owned by ``core``."""

    core: int
    lo: int
    hi: int

    @property
    def width(self) -> int:
        return self.hi - self.lo


@dataclasses.dataclass(frozen=True)
class LayerPartition:
    """Placement of one weight layer: its channel slices, in ``lo`` order."""

    node: int                  # graph node index
    slices: tuple              # of ChannelSlice, contiguous, covering the layer
    split: bool                # True = intra-layer channel split

    @property
    def cores(self) -> tuple:
        return tuple(s.core for s in self.slices)


def _est_row_op_cycles(node, mapping, density: float) -> float:
    """Modeled per-timestep row-op cycles of a layer at ``density``.

    Mirrors ``engine/cost.py``: each input spike triggers 2 row ops per
    sequential channel tile (even+odd Vmem rows).
    """
    return 2.0 * density * node.in_positions * mapping.channel_tiles


def partition_graph(
    graph: NetworkGraph,
    grid: CoreGrid,
    qspec: QuantSpec,
    assumed_density: float = 0.1,
) -> tuple:
    """Place every weight layer of ``graph`` on the ``grid``.

    Returns a tuple of :class:`LayerPartition`, one per weight node in
    network order.  ``assumed_density`` (1 - expected input sparsity) only
    drives the load-balancing heuristic, never correctness: any partition
    executes bit-exactly.
    """
    core = CoreConfig(qspec)
    load = [0.0] * grid.n_cores          # modeled cycles already packed per core
    parts = []
    for node in graph.weight_nodes:
        mapping = map_layer(node.shape, core)
        too_big = mapping.channel_tiles > 1 or mapping.fan_in_tiles > 1
        if too_big and grid.n_cores > 1:
            # Channel split: enough cores to bring per-core channel tiles
            # down to 1 when possible, never more cores than channels.
            n_split = min(grid.n_cores,
                          max(mapping.channel_tiles, 2),
                          node.shape.out_channels)
            k = node.shape.out_channels
            width = math.ceil(k / n_split)
            slices = tuple(
                ChannelSlice(c, c * width, min((c + 1) * width, k))
                for c in range(n_split)
                if c * width < k
            )
            sub = dataclasses.replace(node.shape, out_channels=width)
            per_core = _est_row_op_cycles(node, map_layer(sub, core),
                                          assumed_density)
            for s in slices:
                load[s.core] += per_core
            parts.append(LayerPartition(node.idx, slices, split=True))
        else:
            # Whole layer -> least-loaded core (greedy inter-layer pipeline).
            c = min(range(grid.n_cores), key=lambda i: load[i])
            load[c] += _est_row_op_cycles(node, mapping, assumed_density)
            parts.append(LayerPartition(
                node.idx,
                (ChannelSlice(c, 0, node.shape.out_channels),),
                split=False,
            ))
    return tuple(parts)
