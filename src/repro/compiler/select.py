"""Per-layer selector (stage 3 of 4): mode, precision, stationarity.

For every placed layer (or channel slice of a split layer) the selector
enumerates the discrete execution choices SpiDR exposes and keeps the
cheapest under the repo's calibrated cycle/energy models:

* **operating mode** — Mode 1 (three 3-CM pipelines) vs Mode 2 (one 9-CM
  chain).  Fig 12's rule picks by fan-in, but both modes are *feasible*
  for any fan-in once sequential fan-in tiling is allowed; the selector
  scores both and usually rediscovers Fig 12 (Mode 1's 3x parallel output
  channels win whenever the fan-in fits), which is itself a useful check.

* **precision** — a :class:`QuantSpec` from ``allowed_specs``.  Lower
  precision packs more channels per Vmem row pair (48/W_b), trading
  channel tiles against accuracy.  Executable schedules pin this to the
  engine's own qspec (bit-exactness!); passing several specs is for
  design-space analysis (the Fig 16/17 axis).

* **stationarity** — weight-stationary (weights resident, partial Vmems
  swapped per pass; SpiDR's native regime) vs Vmem/output-stationary
  (Vmem resident per position tile, weights re-streamed), per Chauvaux et
  al.'s layer-wise weight/output-stationarity result.  The traffic model:
  a weight load writes ``rows_per_macro x active-macros`` SRAM rows; a
  Vmem swap moves the 2x32 staggered partial rows.  Convs (large position
  reuse) keep weights resident; FC layers (no reuse) tie on traffic and
  break toward Vmem-stationary.
"""
from __future__ import annotations

import dataclasses

from ..core.cim_macro import NEURON_MACRO_CYCLES
from ..core.energy import chunk_energy_total_nj
from ..core.modes import CoreConfig, LayerMapping, LayerShape, map_layer
from ..core.pipeline import RESET_CYCLES, TRANSFER_CYCLES
from ..core.quant import QuantSpec
from .ir import LayerNode

__all__ = ["LayerPlan", "select_layer"]

# SRAM traffic constants for the stationarity trade (cycles).
VMEM_SWAP_CYCLES = 2 * 32       # drain + refill the 32 staggered row pairs


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    """The selector's verdict for one placed layer (or slice)."""

    mode: int                   # 1 | 2
    spec: QuantSpec             # chosen precision
    stationarity: str           # "weight" | "vmem"
    mapping: LayerMapping       # tiling at (mode, spec) for the placed shape
    est_cycles_per_ts: float    # compute + per-pass overhead, per timestep
    est_traffic_cycles: float   # stationarity-dependent reload traffic
    est_energy_nj_per_ts: float


def _weight_load_cycles(mapping: LayerMapping) -> int:
    """Cycles to (re)write one pass's weight rows across the active macros."""
    active = mapping.pipelines * mapping.macros_per_pipeline
    return mapping.rows_per_macro * active


def _traffic(mapping: LayerMapping, stationarity: str) -> float:
    """Total reload traffic (cycles) for a full sweep of the layer's tiles."""
    w_load = _weight_load_cycles(mapping)
    w_tiles = mapping.channel_tiles * mapping.fan_in_tiles
    if stationarity == "weight":
        # Weights written once per weight tile; partial Vmems swapped out and
        # back in on every pass (each position tile revisits the weights).
        return w_load * w_tiles + VMEM_SWAP_CYCLES * mapping.total_passes
    # Vmem-stationary: a position tile's Vmem stays resident while every
    # weight tile streams through; Vmem moves only once per weight tile.
    return w_load * mapping.total_passes + VMEM_SWAP_CYCLES * w_tiles


def select_layer(
    node: LayerNode,
    placed_shape: LayerShape,
    allowed_specs: tuple,
    assumed_density: float = 0.1,
    force_mode: int | None = None,
    force_stationarity: str | None = None,
) -> LayerPlan:
    """Pick (mode, precision, stationarity) minimizing modeled cycles.

    ``placed_shape`` is the shape actually landing on one core — the full
    layer, or a channel slice of it.  Primary score is cycles (compute +
    per-pass pipeline overhead + reload traffic); ties break on modeled
    energy, then on the Fig 12 default mode.  ``force_mode`` /
    ``force_stationarity`` pin that dimension of the search to one value
    (the deployment API's reconfigurability overrides) — the selector then
    only optimizes over the remaining free dimensions.
    """
    sparsity = 1.0 - assumed_density
    fig12_mode = map_layer(placed_shape, CoreConfig(allowed_specs[0])).mode
    modes = (force_mode,) if force_mode is not None else (1, 2)
    stationarities = ((force_stationarity,) if force_stationarity is not None
                      else ("weight", "vmem"))
    best = None
    for spec in allowed_specs:
        core = CoreConfig(spec)
        for mode in modes:
            mapping = map_layer(placed_shape, core, force_mode=mode)
            compute = 2.0 * assumed_density * node.in_positions \
                * mapping.channel_tiles
            overhead = mapping.total_passes * (RESET_CYCLES + TRANSFER_CYCLES) \
                + NEURON_MACRO_CYCLES
            energy = mapping.total_passes * chunk_energy_total_nj(sparsity)
            for stationarity in stationarities:
                traffic = _traffic(mapping, stationarity)
                plan = LayerPlan(
                    mode=mode,
                    spec=spec,
                    stationarity=stationarity,
                    mapping=mapping,
                    est_cycles_per_ts=compute + overhead,
                    est_traffic_cycles=traffic,
                    est_energy_nj_per_ts=energy,
                )
                key = (
                    compute + overhead + traffic,
                    energy,
                    mode != fig12_mode,
                    # FC layers have no weight reuse across positions:
                    # remaining ties break toward keeping the output (Vmem)
                    # resident; convs break toward weight-stationary.
                    (stationarity == "vmem") if node.kind != "fc"
                    else (stationarity == "weight"),
                )
                if best is None or key < best[0]:
                    best = (key, plan)
    return best[1]
