"""Scheduler (stage 4 of 4): emit the executable :class:`CoreSchedule`.

``compile_network`` runs the full pipeline — IR -> partition -> select ->
schedule — and returns a :class:`CoreSchedule`: one :class:`LayerSchedule`
per weight layer carrying its channel slices, the selector's
:class:`LayerPlan`, and the routing model (which cores must receive the
layer's input spikes, and how many AER copies cross the fabric per input
spike).

The schedule is registered as a JAX pytree whose leaves are empty — it is
pure static metadata, safe to close over inside ``jit`` and to carry in
other pytrees without tracing surprises.  The engine consumes it via
``repro.engine.compile_engine``, which bakes the channel slices into
stacked per-core weight tensors and executes them lockstep (``vmap``) or
on real devices (``shard_map`` over a ``cores`` mesh axis).

Routing model.  A layer's input spikes live on the core(s) that produced
them (the previous weight layer's slices; pools are core-transparent).
Every core holding a slice of the consuming layer needs the *full* input
plane, so each input spike is sent to every consumer core except the one
that already has it:

    copies/spike = n_consumers - overlap
    overlap      = fraction of producer channels whose core is a consumer

The network's first layer receives its events from the sensor/host feed,
which is charged one delivery per consumer core beyond the first.
"""
from __future__ import annotations

import dataclasses

import jax

from ..core.network import SNNSpec
from ..core.quant import QuantSpec
from .ir import build_graph
from .partition import ChannelSlice, CoreGrid, LayerPartition, partition_graph
from .select import LayerPlan, select_layer

__all__ = ["CoreSchedule", "LayerSchedule", "compile_network"]


@dataclasses.dataclass(frozen=True)
class LayerSchedule:
    """Everything the engine and the cost model need for one weight layer."""

    node: int                   # spec.layers / params index
    kind: str                   # "conv" | "fc"
    out_channels: int
    slices: tuple               # of ChannelSlice, contiguous, in lo order
    plan: LayerPlan             # selector verdict for the per-core slice
    split: bool                 # intra-layer channel split?
    route_fractions: tuple      # per-core fraction of input spikes received
                                # over the fabric (len n_cores; 0.0 = local
                                # or not a consumer) — the cost model's
                                # single source of routing truth
    consumer_cores: tuple       # cores that receive this layer's inputs

    @property
    def route_factor(self) -> float:
        """Total AER copies per input spike crossing cores (sum per core)."""
        return float(sum(self.route_fractions))

    def slice_of(self, core: int) -> ChannelSlice | None:
        """This layer's channel slice on ``core`` (None if idle there)."""
        for s in self.slices:
            if s.core == core:
                return s
        return None


@dataclasses.dataclass(frozen=True)
class CoreSchedule:
    """Executable multi-core plan for one network.

    ``layers`` holds one :class:`LayerSchedule` per *weight* layer in
    network order (pool layers need no placement — they follow their
    input's core(s) for free).  The schedule is a leafless pytree.
    """

    name: str
    n_cores: int
    grid: CoreGrid
    qspec: QuantSpec
    layers: tuple               # of LayerSchedule

    @property
    def n_split_layers(self) -> int:
        return sum(1 for l in self.layers if l.split)

    @property
    def cores_used(self) -> tuple:
        used = set()
        for l in self.layers:
            used.update(s.core for s in l.slices)
        return tuple(sorted(used))

    def describe(self) -> str:
        """Human-readable placement table (docs/serving logs)."""
        lines = [f"{self.name}: {len(self.layers)} weight layers "
                 f"on {self.n_cores} cores "
                 f"({self.n_split_layers} channel-split)"]
        for l in self.layers:
            placement = ", ".join(
                f"core{s.core}[{s.lo}:{s.hi}]" for s in l.slices)
            lines.append(
                f"  L{l.node} {l.kind:<4} mode={l.plan.mode} "
                f"{l.plan.spec.weight_bits}b {l.plan.stationarity}-stationary "
                f"route x{l.route_factor:.2f} -> {placement}")
        return "\n".join(lines)


jax.tree_util.register_pytree_node(
    CoreSchedule,
    lambda s: ((), s),
    lambda aux, _: aux,
)


def _route_fractions(prev: LayerPartition | None, part: LayerPartition,
                     prev_channels: int, n_cores: int) -> tuple:
    """(per-core routed fraction, consumer cores) for one weight layer.

    ``fractions[c]`` is the share of the layer's input spikes core ``c``
    receives over the fabric: 0 for non-consumers, ``1 - local_share`` for
    consumers (spikes produced on ``c`` itself arrive for free).
    """
    consumers = tuple(sorted({s.core for s in part.slices}))
    fractions = [0.0] * n_cores
    if prev is None:
        # Sensor/host feed: the first consumer core gets the events free,
        # every further consumer needs its own delivery.
        for c in consumers[1:]:
            fractions[c] = 1.0
        return tuple(fractions), consumers
    for c in consumers:
        local = sum(s.width for s in prev.slices if s.core == c)
        fractions[c] = 1.0 - local / max(prev_channels, 1)
    return tuple(fractions), consumers


def compile_network(
    spec: SNNSpec,
    n_cores: int = 1,
    qspec: QuantSpec | None = None,
    grid: CoreGrid | None = None,
    assumed_sparsity: float = 0.9,
    allowed_specs: tuple | None = None,
    force_mode: int | None = None,
    force_stationarity: str | None = None,
) -> CoreSchedule:
    """Partition, place and schedule ``spec`` across a grid of SpiDR cores.

    ``qspec`` is the precision the engine will execute (default 4/7-bit);
    by default the selector is pinned to it so the schedule is bit-exact
    with single-core execution.  Pass ``allowed_specs`` (a tuple of
    :class:`QuantSpec`) to let the selector explore precision for
    design-space analysis — such schedules are for cost modeling, not for
    ``compile_engine`` (which asserts the plan's precision matches the
    engine's).

    ``assumed_sparsity`` feeds the load-balancing and selection heuristics
    only; any returned schedule executes bit-exactly regardless.
    ``force_mode`` / ``force_stationarity`` pin the selector's per-layer
    operating-mode (1/2) and weight-vs-Vmem stationarity choices — the
    deployment API's reconfigurability overrides (``repro.spidr``'s
    ``DeployTarget``); like sparsity they only move the modeled cost, never
    the computed spikes.
    """
    qspec = qspec or QuantSpec(4)
    grid = grid or CoreGrid(n_cores)
    assert grid.n_cores == n_cores or n_cores == 1, \
        "pass either n_cores or an explicit grid, not conflicting values"
    allowed = tuple(allowed_specs) if allowed_specs else (qspec,)
    density = 1.0 - assumed_sparsity

    graph = build_graph(spec)
    parts = partition_graph(graph, grid, qspec, assumed_density=density)
    weight_nodes = graph.weight_nodes

    layers = []
    prev_part, prev_channels = None, 0
    for node, part in zip(weight_nodes, parts):
        widest = max(part.slices, key=lambda s: s.width)
        placed_shape = dataclasses.replace(
            node.shape, out_channels=widest.width)
        plan = select_layer(node, placed_shape, allowed,
                            assumed_density=density,
                            force_mode=force_mode,
                            force_stationarity=force_stationarity)
        fractions, consumers = _route_fractions(prev_part, part,
                                                prev_channels, grid.n_cores)
        layers.append(LayerSchedule(
            node=node.idx,
            kind=node.kind,
            out_channels=node.shape.out_channels,
            slices=part.slices,
            plan=plan,
            split=part.split,
            route_fractions=fractions,
            consumer_cores=consumers,
        ))
        prev_part, prev_channels = part, node.shape.out_channels
    return CoreSchedule(
        name=spec.name,
        n_cores=grid.n_cores,
        grid=grid,
        qspec=qspec,
        layers=tuple(layers),
    )
