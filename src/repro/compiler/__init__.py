"""Multi-core CIM compiler: partition, place and schedule SNNs across a
grid of SpiDR cores (paper Sec II-E's ``n_cores`` extension, made real).

Four stages, one module each:

  ``ir``         lower an :class:`~repro.core.network.SNNSpec` into a small
                 layer graph annotated with routing volumes
                 (:func:`build_graph`).
  ``partition``  split over-capacity layers across cores channel-wise
                 (intra-layer, with spike routing) or place whole layers on
                 the least-loaded core (inter-layer pipeline)
                 (:func:`partition_graph`).
  ``select``     pick per-layer operating mode (1/2), precision
                 (:class:`~repro.core.quant.QuantSpec`) and weight- vs
                 Vmem-stationarity by minimizing the calibrated
                 cycle/energy models (:func:`select_layer`).
  ``schedule``   emit the executable :class:`CoreSchedule` pytree
                 (:func:`compile_network`).

The engine runs a schedule via :func:`repro.engine.compile_engine` —
lockstep ``vmap`` emulation on one device, ``shard_map`` over a ``cores``
mesh axis when the host has enough devices — bit-exactly with the
single-core path.  ``repro.engine.cost.estimate_multicore_cost`` prices a
run per core, including the modeled spike-routing overhead and the load-
imbalance metric.

This package imports only ``repro.core`` (never ``repro.engine``), so the
engine can depend on it without cycles.
"""
from .ir import LayerNode, NetworkGraph, build_graph
from .partition import ChannelSlice, CoreGrid, LayerPartition, partition_graph
from .schedule import CoreSchedule, LayerSchedule, compile_network
from .select import LayerPlan, select_layer

__all__ = [
    "ChannelSlice",
    "CoreGrid",
    "CoreSchedule",
    "LayerNode",
    "LayerPartition",
    "LayerPlan",
    "LayerSchedule",
    "NetworkGraph",
    "build_graph",
    "compile_network",
    "partition_graph",
    "select_layer",
]
