"""Span tracer emitting Chrome-trace / Perfetto JSON.

A :class:`Tracer` records complete-duration events (``ph: "X"``) from
``with tracer.span("name", key=value):`` blocks and instant events from
``tracer.instant(...)``.  ``export(path)`` writes the standard trace-event
envelope ``{"traceEvents": [...]}`` which loads directly in
https://ui.perfetto.dev or ``chrome://tracing``.

Conventions:

* timestamps are microseconds from the tracer's construction, taken from
  ``time.perf_counter_ns`` (monotonic); ``export`` sorts events by ``ts``
  so the emitted stream is non-decreasing even with nested spans (a parent
  span is *recorded* after its children finish but *starts* before them);
* ``pid`` is the OS pid, ``tid`` is a stable small integer per Python
  thread (thread names are emitted as ``thread_name`` metadata);
* a disabled tracer hands back a shared no-op context manager, so the
  disabled cost of a span site is one truthiness check plus one attribute
  call.

The tracer is intentionally unbounded: it is meant for bounded runs
(compile, a serve session, an upgrade drill), not always-on production
capture.  ``max_events`` provides a safety valve — past it, new events are
dropped and ``dropped_events`` counts them.
"""
from __future__ import annotations

import json
import os
import pathlib
import threading
import time
from contextlib import contextmanager
from typing import Dict, List, Optional

__all__ = [
    "Tracer",
    "default_tracer",
    "set_default_tracer",
    "enable_tracing",
    "disable_tracing",
    "tracing_enabled",
]


class _NullSpan:
    """Shared no-op context manager for disabled tracers."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class Tracer:
    def __init__(self, enabled: bool = True, max_events: int = 1_000_000):
        self.enabled = bool(enabled)
        self.max_events = int(max_events)
        self.dropped_events = 0
        self.events: List[dict] = []
        self._lock = threading.Lock()
        self._t0_ns = time.perf_counter_ns()
        self._tids: Dict[int, int] = {}

    def __bool__(self) -> bool:
        return self.enabled

    # -- internals -------------------------------------------------------
    def _now_us(self) -> float:
        return (time.perf_counter_ns() - self._t0_ns) / 1000.0

    def _tid(self) -> int:
        ident = threading.get_ident()
        tid = self._tids.get(ident)
        if tid is None:
            with self._lock:
                tid = self._tids.setdefault(ident, len(self._tids))
        return tid

    def _emit(self, event: dict) -> None:
        with self._lock:
            if len(self.events) >= self.max_events:
                self.dropped_events += 1
                return
            self.events.append(event)

    # -- recording -------------------------------------------------------
    @contextmanager
    def _span(self, name: str, cat: str, args: dict):
        t0 = self._now_us()
        try:
            yield self
        finally:
            t1 = self._now_us()
            ev = {
                "name": name,
                "cat": cat,
                "ph": "X",
                "ts": t0,
                "dur": max(t1 - t0, 0.0),
                "pid": os.getpid(),
                "tid": self._tid(),
            }
            if args:
                ev["args"] = args
            self._emit(ev)

    def span(self, name: str, cat: str = "spidr", **args):
        """Context manager recording a complete (``ph: "X"``) event."""
        if not self.enabled:
            return _NULL_SPAN
        return self._span(name, cat, args)

    def instant(self, name: str, cat: str = "spidr", **args) -> None:
        """Record an instant (``ph: "i"``) event at the current time."""
        if not self.enabled:
            return
        ev = {
            "name": name,
            "cat": cat,
            "ph": "i",
            "s": "t",
            "ts": self._now_us(),
            "pid": os.getpid(),
            "tid": self._tid(),
        }
        if args:
            ev["args"] = args
        self._emit(ev)

    # -- export ----------------------------------------------------------
    def to_chrome(self, extra_events: Optional[List[dict]] = None) -> dict:
        """Build the Chrome-trace envelope (events sorted by ``ts``)."""
        with self._lock:
            events = list(self.events)
        meta = [
            {
                "name": "thread_name",
                "ph": "M",
                "pid": os.getpid(),
                "tid": tid,
                "args": {"name": f"py-thread-{tid}" if tid else "main"},
            }
            for tid in sorted(self._tids.values())
        ]
        if extra_events:
            events = events + list(extra_events)
        events.sort(key=lambda e: e.get("ts", 0.0))
        return {
            "traceEvents": meta + events,
            "displayTimeUnit": "ms",
        }

    def export(self, path, extra_events: Optional[List[dict]] = None
               ) -> pathlib.Path:
        path = pathlib.Path(path)
        path.write_text(json.dumps(self.to_chrome(extra_events)))
        return path

    def clear(self) -> None:
        with self._lock:
            self.events.clear()
            self.dropped_events = 0


# -- process-wide default tracer (disabled by default) --------------------
_default = Tracer(enabled=False)


def default_tracer() -> Tracer:
    return _default


def set_default_tracer(tracer: Tracer) -> Tracer:
    global _default
    _default = tracer
    return _default


def enable_tracing() -> Tracer:
    _default.enabled = True
    return _default


def disable_tracing() -> Tracer:
    _default.enabled = False
    return _default


def tracing_enabled() -> bool:
    return _default.enabled
