"""SpiDR serving-stack telemetry: metrics, span tracing, pipeline timelines.

Three pillars (see docs/observability.md):

* :mod:`repro.obs.metrics` — in-process counters/gauges/histograms with
  Prometheus-text and JSON export; process-wide default registry that is
  **disabled by default** and costs one truthiness check per site when off.
* :mod:`repro.obs.trace` — context-manager span tracer emitting
  Chrome-trace/Perfetto JSON (compile -> autotune -> per-chunk run_chunk
  -> snapshot/restore).
* :mod:`repro.obs.timeline` — renders the simulated per-core async
  pipeline clocks of ``estimate_multicore_cost`` (busy / AER-routing /
  idle intervals) in the same Chrome-trace format.

Plus :mod:`repro.obs.logs`: shared structured-logging setup with a
per-stream request id on every record.

Quick start::

    from repro import obs
    obs.enable_metrics(); obs.enable_tracing()
    ...  # compile / serve as usual
    print(obs.default_registry().to_prometheus())
    obs.default_tracer().export("trace.json")
"""
from . import logs, metrics, timeline, trace  # noqa: F401
from .logs import logging_setup, request_context
from .metrics import (
    MetricsRegistry, default_registry, disable_metrics, enable_metrics,
    metrics_enabled, set_default_registry,
)
from .timeline import busy_cycle_totals, export_timeline, multicore_timeline
from .trace import (
    Tracer, default_tracer, disable_tracing, enable_tracing,
    set_default_tracer, tracing_enabled,
)

__all__ = [
    "logs", "metrics", "timeline", "trace",
    "logging_setup", "request_context",
    "MetricsRegistry", "default_registry", "set_default_registry",
    "enable_metrics", "disable_metrics", "metrics_enabled",
    "Tracer", "default_tracer", "set_default_tracer",
    "enable_tracing", "disable_tracing", "tracing_enabled",
    "multicore_timeline", "busy_cycle_totals", "export_timeline",
]
