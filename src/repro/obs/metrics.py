"""Metrics registry: counters, gauges, and fixed-bucket histograms.

Design constraints (see docs/observability.md):

* **Near-zero overhead when disabled.**  A disabled :class:`MetricsRegistry`
  is falsy, so every instrumentation site is written as

      if metrics:
          metrics.counter("spidr_stream_ticks_total").inc()

  and the disabled cost is a single truthiness check.  The hot-path gate is
  enforced by the ``telemetry_overhead`` ablation in ``benchmarks/run.py``
  (same <1% budget as the facade-dispatch gate).

* **Chunking-invariant totals.**  Counters only ever accumulate *deltas*
  (spikes, timesteps, cycle increments), so the totals after a stream are
  identical for any ``chunk_T`` split — tested in ``tests/test_obs.py``.

* **Stable bucket edges.**  Histogram edges are pinned module constants
  (:data:`FRACTION_BUCKETS`, :data:`LATENCY_BUCKETS_S`); dashboards may
  depend on them, so changing an edge is a breaking change and is caught
  by the pinned-edge test.

The registry is deliberately not a Prometheus client: it is an in-process
aggregator whose state is exported on demand as Prometheus text exposition
format (``to_prometheus``) or JSON (``to_dict``).  There is no background
thread and no sockets; ``launch/serve.py --metrics-out`` dumps to a file.
"""
from __future__ import annotations

import json
import math
import pathlib
import threading
from typing import Dict, Iterable, Mapping, Optional, Tuple

__all__ = [
    "FRACTION_BUCKETS",
    "LATENCY_BUCKETS_S",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "default_registry",
    "set_default_registry",
    "enable_metrics",
    "disable_metrics",
    "metrics_enabled",
]

# Pinned bucket edges.  FRACTION_BUCKETS covers [0, 1] quantities (spike
# sparsity, nonzero-tile fraction, occupancy); LATENCY_BUCKETS_S covers
# wall-clock seconds (serve tick latency, snapshot duration).  Tests pin
# these tuples exactly — see test_histogram_bucket_edges_stable.
FRACTION_BUCKETS: Tuple[float, ...] = (
    0.01, 0.05, 0.10, 0.25, 0.50, 0.75, 0.90, 0.95, 0.99, 1.0,
)
LATENCY_BUCKETS_S: Tuple[float, ...] = (
    0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
    0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
)

LabelPairs = Tuple[Tuple[str, str], ...]


def _label_key(labels: Optional[Mapping[str, object]]) -> LabelPairs:
    if not labels:
        return ()
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _format_labels(pairs: LabelPairs, extra: str = "") -> str:
    body = ",".join(f'{k}="{v}"' for k, v in pairs)
    if extra:
        body = f"{body},{extra}" if body else extra
    return "{" + body + "}" if body else ""


class Counter:
    """Monotonically increasing float counter."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counter increment must be >= 0, got {amount}")
        self.value += amount


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount

    def dec(self, amount: float = 1.0) -> None:
        self.value -= amount


class Histogram:
    """Fixed-bucket histogram with Prometheus cumulative-bucket semantics.

    ``edges`` are the inclusive upper bounds of the finite buckets; an
    implicit ``+Inf`` bucket catches the overflow.  Edges are pinned at
    construction and never change afterwards.
    """

    __slots__ = ("edges", "bucket_counts", "total", "count")

    def __init__(self, edges: Iterable[float]) -> None:
        edges = tuple(float(e) for e in edges)
        if not edges or list(edges) != sorted(edges):
            raise ValueError(f"histogram edges must be ascending, got {edges}")
        self.edges = edges
        self.bucket_counts = [0] * (len(edges) + 1)  # +1 for +Inf
        self.total = 0.0
        self.count = 0

    def observe(self, value: float) -> None:
        value = float(value)
        if math.isnan(value):
            return
        lo, hi = 0, len(self.edges)
        while lo < hi:  # first edge >= value (bisect_left on upper bounds)
            mid = (lo + hi) // 2
            if self.edges[mid] < value:
                lo = mid + 1
            else:
                hi = mid
        self.bucket_counts[lo] += 1
        self.total += value
        self.count += 1

    def cumulative(self) -> list:
        """Cumulative counts per bucket, Prometheus-style (ends at count)."""
        out, acc = [], 0
        for c in self.bucket_counts:
            acc += c
            out.append(acc)
        return out


class MetricsRegistry:
    """Named metric store.  Truthiness == enabled.

    Instrumentation sites hold a reference to a registry and guard every
    record with ``if metrics:``; a disabled registry therefore costs one
    ``__bool__`` call per site.  Metric objects are created lazily on
    first use and keyed by ``(name, sorted(labels))``.
    """

    def __init__(self, enabled: bool = True) -> None:
        self.enabled = bool(enabled)
        self._lock = threading.Lock()
        # name -> (kind, help)
        self._families: Dict[str, Tuple[str, str]] = {}
        # (name, label_pairs) -> metric object
        self._metrics: Dict[Tuple[str, LabelPairs], object] = {}

    def __bool__(self) -> bool:
        return self.enabled

    # -- metric accessors ------------------------------------------------
    def _get(self, kind: str, name: str, help: str,
             labels: Optional[Mapping[str, object]], factory):
        known = self._families.get(name)
        if known is not None and known[0] != kind:
            # Checked on the lock-free fast path too: a name collision must
            # never hand a Counter to a site that asked for a Gauge.
            raise ValueError(
                f"metric {name!r} already registered as {known[0]}, "
                f"cannot re-register as {kind}"
            )
        key = (name, _label_key(labels))
        metric = self._metrics.get(key)
        if metric is not None:
            return metric
        with self._lock:
            metric = self._metrics.get(key)
            if metric is None:
                known = self._families.get(name)
                if known is not None and known[0] != kind:
                    raise ValueError(
                        f"metric {name!r} already registered as {known[0]}, "
                        f"cannot re-register as {kind}"
                    )
                self._families.setdefault(name, (kind, help))
                metric = factory()
                self._metrics[key] = metric
        return metric

    def counter(self, name: str, help: str = "",
                labels: Optional[Mapping[str, object]] = None) -> Counter:
        return self._get("counter", name, help, labels, Counter)

    def gauge(self, name: str, help: str = "",
              labels: Optional[Mapping[str, object]] = None) -> Gauge:
        return self._get("gauge", name, help, labels, Gauge)

    def histogram(self, name: str, help: str = "",
                  labels: Optional[Mapping[str, object]] = None,
                  edges: Iterable[float] = FRACTION_BUCKETS) -> Histogram:
        return self._get("histogram", name, help, labels,
                         lambda: Histogram(edges))

    # -- export ----------------------------------------------------------
    def _sorted_items(self):
        return sorted(self._metrics.items(), key=lambda kv: kv[0])

    def to_prometheus(self) -> str:
        """Render as Prometheus text exposition format (version 0.0.4)."""
        lines, seen = [], set()
        for (name, pairs), metric in self._sorted_items():
            kind, help = self._families[name]
            if name not in seen:
                seen.add(name)
                if help:
                    lines.append(f"# HELP {name} {help}")
                lines.append(f"# TYPE {name} {kind}")
            if isinstance(metric, Histogram):
                cum = metric.cumulative()
                for edge, acc in zip(metric.edges, cum):
                    le = _format_labels(pairs, f'le="{edge:g}"')
                    lines.append(f"{name}_bucket{le} {acc}")
                le = _format_labels(pairs, 'le="+Inf"')
                lines.append(f"{name}_bucket{le} {cum[-1]}")
                lbl = _format_labels(pairs)
                lines.append(f"{name}_sum{lbl} {metric.total:g}")
                lines.append(f"{name}_count{lbl} {metric.count}")
            else:
                lines.append(f"{name}{_format_labels(pairs)} {metric.value:g}")
        return "\n".join(lines) + "\n" if lines else ""

    def to_dict(self) -> dict:
        """JSON-friendly dump: {name: [{labels, ...payload}]}."""
        out: Dict[str, list] = {}
        for (name, pairs), metric in self._sorted_items():
            kind, _help = self._families[name]
            entry: dict = {"labels": dict(pairs), "kind": kind}
            if isinstance(metric, Histogram):
                entry["buckets"] = {
                    "edges": list(metric.edges),
                    "counts": list(metric.bucket_counts),
                }
                entry["sum"] = metric.total
                entry["count"] = metric.count
            else:
                entry["value"] = metric.value
            out.setdefault(name, []).append(entry)
        return out

    def write(self, path) -> pathlib.Path:
        """Write a dump to ``path``: ``.json`` -> JSON, else Prometheus text."""
        path = pathlib.Path(path)
        if path.suffix == ".json":
            path.write_text(json.dumps(self.to_dict(), indent=2, sort_keys=True))
        else:
            path.write_text(self.to_prometheus())
        return path

    def clear(self) -> None:
        with self._lock:
            self._families.clear()
            self._metrics.clear()


# -- process-wide default registry ---------------------------------------
# Disabled by default: importing repro must not make the engine pay for
# telemetry.  ``enable_metrics()`` flips the same object that every already
# constructed StreamSessionManager holds, so enabling is retroactive.
_default = MetricsRegistry(enabled=False)


def default_registry() -> MetricsRegistry:
    return _default


def set_default_registry(registry: MetricsRegistry) -> MetricsRegistry:
    global _default
    _default = registry
    return _default


def enable_metrics() -> MetricsRegistry:
    _default.enabled = True
    return _default


def disable_metrics() -> MetricsRegistry:
    _default.enabled = False
    return _default


def metrics_enabled() -> bool:
    return _default.enabled
