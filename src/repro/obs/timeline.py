"""Render the multi-core async-pipeline cost model as a Chrome trace.

``engine/cost.py::estimate_multicore_cost(..., collect_timeline=True)``
records, per (layer, core), the spike-driven row-op cycles of every
timestep exactly as they land in the per-core ``compute`` matrix.  This
module turns those records into Chrome-trace complete events so the
paper's handshaking pipeline and load-imbalance metric become visually
inspectable: one track per core, back-to-back busy intervals per layer
per timestep, one AER-routing interval, and an idle tail up to the plan
makespan.

The invariant (tested in ``tests/test_obs.py`` and asserted in the
``compiler_multicore`` benchmark): per core, the summed duration of
``busy`` + ``routing`` events equals ``MulticoreCost.busy_cycles`` —
cycle for cycle, no sampling, no rounding.

Timestamps/durations are *cycles* exported in the trace's microsecond
field, so Perfetto's "1 ms" reads as 1k cycles.
"""
from __future__ import annotations

import json
import pathlib
from typing import Dict, List, Optional

__all__ = [
    "multicore_timeline",
    "busy_cycle_totals",
    "export_timeline",
    "write_chrome_trace",
]


def multicore_timeline(cost, label: str = "stream", pid: int = 1,
                       ts_offset: float = 0.0) -> List[dict]:
    """Chrome-trace events for one priced run (``collect_timeline=True``).

    ``cost`` is a :class:`repro.engine.cost.MulticoreCost` whose
    ``timeline`` field was populated.  One ``tid`` per core; ``pid``
    separates streams when merging several runs into one trace.
    """
    if getattr(cost, "timeline", None) is None:
        raise ValueError(
            "MulticoreCost.timeline is empty — price the run with "
            "estimate_multicore_cost(..., collect_timeline=True)"
        )
    # Group records per core, preserving layer order within each timestep.
    per_core: Dict[int, List[dict]] = {}
    n_t = 0
    for rec in cost.timeline:
        per_core.setdefault(int(rec["core"]), []).append(rec)
        n_t = max(n_t, len(rec["cycles"]))

    events: List[dict] = []
    cores = sorted(set(per_core) | set(range(len(cost.compute_cycles))))
    for core in cores:
        events.append({
            "name": "thread_name", "ph": "M", "pid": pid, "tid": core,
            "args": {"name": f"{label} core{core}"},
        })
        cursor = float(ts_offset)
        for t in range(n_t):
            for rec in per_core.get(core, ()):
                dur = float(rec["cycles"][t]) if t < len(rec["cycles"]) else 0.0
                if dur <= 0.0:
                    continue
                events.append({
                    "name": rec["name"], "cat": "busy", "ph": "X",
                    "ts": cursor, "dur": dur, "pid": pid, "tid": core,
                    "args": {"layer": rec["layer"], "t": t,
                             "stream": label},
                })
                cursor += dur
        route = float(cost.routing_cycles[core])
        if route > 0.0:
            events.append({
                "name": "AER routing", "cat": "routing", "ph": "X",
                "ts": cursor, "dur": route, "pid": pid, "tid": core,
                "args": {"stream": label},
            })
            cursor += route
        idle = float(ts_offset) + float(cost.makespan_cycles) - cursor
        if idle > 0.0:
            events.append({
                "name": "idle", "cat": "idle", "ph": "X",
                "ts": cursor, "dur": idle, "pid": pid, "tid": core,
                "args": {"stream": label},
            })
    return events


def busy_cycle_totals(events: List[dict]) -> Dict[int, float]:
    """Summed busy+routing duration per core tid (the conservation check)."""
    totals: Dict[int, float] = {}
    for ev in events:
        if ev.get("ph") == "X" and ev.get("cat") in ("busy", "routing"):
            tid = int(ev["tid"])
            totals[tid] = totals.get(tid, 0.0) + float(ev["dur"])
    return totals


def write_chrome_trace(events: List[dict], path) -> pathlib.Path:
    """Write raw events in the standard Chrome-trace envelope."""
    path = pathlib.Path(path)
    events = sorted(events, key=lambda e: e.get("ts", 0.0))
    path.write_text(json.dumps(
        {"traceEvents": events, "displayTimeUnit": "ms"}))
    return path


def export_timeline(cost, path, label: str = "stream",
                    pid: int = 1) -> Optional[pathlib.Path]:
    """One-call export: timeline events for ``cost`` -> Chrome-trace file."""
    return write_chrome_trace(multicore_timeline(cost, label, pid), path)
