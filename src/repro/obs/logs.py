"""Structured logging with per-stream request-id propagation.

``logging_setup()`` replaces the ad-hoc ``logging.basicConfig`` calls in
the launch scripts with one shared configuration: a text formatter that
carries ``rid=<request-id>`` in every record, or JSON-lines with
``--log-json``.  The request id rides a :class:`contextvars.ContextVar`,
so nested library code logs with the right id without threading it
through every call:

    with request_context("7"):
        log.info("stream done")     # ... rid=7 stream done

The filter/formatter pair only ever *adds* fields; third-party records
without a request context get ``rid=-``.
"""
from __future__ import annotations

import contextlib
import contextvars
import json
import logging
import time
from typing import Optional

__all__ = [
    "logging_setup",
    "request_context",
    "current_request_id",
    "JsonFormatter",
    "TEXT_FORMAT",
]

_request_id: contextvars.ContextVar = contextvars.ContextVar(
    "spidr_request_id", default="-")

TEXT_FORMAT = "%(asctime)s %(levelname)s %(name)s rid=%(request_id)s %(message)s"


def current_request_id() -> str:
    return _request_id.get()


@contextlib.contextmanager
def request_context(rid):
    """Bind a request id to every log record emitted inside the block."""
    token = _request_id.set(str(rid))
    try:
        yield
    finally:
        _request_id.reset(token)


class _RequestIdFilter(logging.Filter):
    def filter(self, record: logging.LogRecord) -> bool:
        if not hasattr(record, "request_id"):
            record.request_id = _request_id.get()
        return True


class JsonFormatter(logging.Formatter):
    """One JSON object per line; stable keys for log shippers."""

    def format(self, record: logging.LogRecord) -> str:
        payload = {
            "ts": round(record.created, 6),
            "level": record.levelname,
            "logger": record.name,
            "request_id": getattr(record, "request_id", _request_id.get()),
            "message": record.getMessage(),
        }
        if record.exc_info:
            payload["exc"] = self.formatException(record.exc_info)
        return json.dumps(payload)


def logging_setup(json_mode: bool = False, level: int = logging.INFO,
                  logger: Optional[logging.Logger] = None,
                  stream=None) -> logging.Logger:
    """Configure ``logger`` (root by default) for structured output.

    Idempotent: an existing handler installed by a previous call is
    replaced, not duplicated, so re-running ``serve.py`` entry points in
    one process (tests, notebooks) keeps a single handler.
    """
    logger = logger if logger is not None else logging.getLogger()
    handler = logging.StreamHandler(stream) if stream is not None \
        else logging.StreamHandler()
    handler.addFilter(_RequestIdFilter())
    if json_mode:
        handler.setFormatter(JsonFormatter())
    else:
        fmt = logging.Formatter(TEXT_FORMAT)
        fmt.converter = time.gmtime
        handler.setFormatter(fmt)
    handler._spidr_obs_handler = True  # marker for idempotent replacement
    for h in list(logger.handlers):
        if getattr(h, "_spidr_obs_handler", False):
            logger.removeHandler(h)
    logger.addHandler(handler)
    logger.setLevel(level)
    return logger
