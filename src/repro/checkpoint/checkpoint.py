"""Sharded checkpointing: atomic, async, elastic.

Layout: <dir>/step_<n>/
  meta.json            step, arch, leaf manifest
  <leaf_idx>.npy       one file per pytree leaf

Guarantees:
  * ATOMIC — written to ``.tmp-...`` then os.rename'd; a crash mid-save
    never corrupts the latest checkpoint; ``latest_step`` only sees
    completed saves.
  * ASYNC — ``save_async`` snapshots to host memory synchronously (cheap)
    and writes in a background thread; ``wait()`` joins before the next
    save (single outstanding write, bounded memory).
  * ELASTIC — restore() re-shards onto WHATEVER mesh/sharding the caller
    provides: leaves are full logical arrays on disk, so a 512-chip
    checkpoint restores on 256 chips (or 1 CPU) unchanged.

Fault-tolerance contract with runtime.fault_tolerance: the training loop
checkpoints every N steps; on failure the watchdog restarts from
``latest_step`` and the data pipeline replays deterministically from that
step (data/pipeline.py is a pure function of step).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["Checkpointer"]

Pytree = Any


class Checkpointer:
    def __init__(self, directory: str):
        self.directory = directory
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Pytree, extra_meta: Optional[dict] = None):
        leaves, treedef = jax.tree.flatten(tree, is_leaf=lambda x: x is None)
        host_leaves = [None if l is None else np.asarray(l) for l in leaves]
        self._write(step, host_leaves, str(treedef), extra_meta or {})

    def save_async(self, step: int, tree: Pytree, extra_meta: Optional[dict] = None):
        self.wait()
        leaves, treedef = jax.tree.flatten(tree, is_leaf=lambda x: x is None)
        # Synchronous device->host snapshot; disk IO deferred to the thread.
        host_leaves = [None if l is None else np.asarray(l) for l in leaves]
        self._thread = threading.Thread(
            target=self._write, args=(step, host_leaves, str(treedef), extra_meta or {}),
            daemon=True,
        )
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_leaves, treedef_str: str, extra_meta: dict):
        final = os.path.join(self.directory, f"step_{step:09d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = []
        for i, leaf in enumerate(host_leaves):
            if leaf is None:
                manifest.append(None)
            else:
                np.save(os.path.join(tmp, f"{i}.npy"), leaf)
                manifest.append({"dtype": str(leaf.dtype), "shape": list(leaf.shape)})
        meta = {"step": step, "n_leaves": len(host_leaves), "manifest": manifest,
                "treedef": treedef_str, **extra_meta}
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)

    # --------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        steps = [
            int(d.split("_")[1])
            for d in os.listdir(self.directory)
            if d.startswith("step_") and not d.endswith(".tmp")
        ]
        return max(steps) if steps else None

    def restore(
        self,
        step: int,
        like: Pytree,
        shardings: Optional[Pytree] = None,
    ) -> Pytree:
        """Restore into the structure of ``like``; device_put with
        ``shardings`` if given (elastic re-shard happens here)."""
        path = os.path.join(self.directory, f"step_{step:09d}")
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        leaves_like, treedef = jax.tree.flatten(like, is_leaf=lambda x: x is None)
        assert meta["n_leaves"] == len(leaves_like), "pytree structure changed"
        out = []
        shard_leaves = (
            jax.tree.flatten(shardings, is_leaf=lambda x: x is None)[0]
            if shardings is not None else [None] * len(leaves_like)
        )
        for i, (ll, sh) in enumerate(zip(leaves_like, shard_leaves)):
            if ll is None:
                out.append(None)
                continue
            arr = np.load(os.path.join(path, f"{i}.npy"))
            out.append(jax.device_put(arr, sh) if sh is not None else jax.numpy.asarray(arr))
        return jax.tree.unflatten(treedef, out)
