"""Sharded checkpointing: atomic, async, elastic, checksummed.

Layout: <dir>/step_<n>/
  meta.json            step, arch, leaf manifest (dtype/shape/crc32), version
  <leaf_idx>.npy       one file per pytree leaf

Guarantees:
  * ATOMIC — written to ``.tmp-...`` then os.rename'd; a crash mid-save
    never corrupts the latest checkpoint; ``latest_step`` only sees
    completed saves.
  * VALIDATED — every leaf's crc32 is recorded in the manifest and checked
    on restore, and the manifest carries a format version; a truncated or
    bit-flipped leaf, or a checkpoint written by a newer format, raises
    :class:`CheckpointError` (a ``ValueError``) naming the damage instead
    of silently deploying corrupted state.
  * ASYNC — ``save_async`` snapshots to host memory synchronously (cheap)
    and writes in a background thread; ``wait()`` joins before the next
    save (single outstanding write, bounded memory).
  * ELASTIC — restore() re-shards onto WHATEVER mesh/sharding the caller
    provides: leaves are full logical arrays on disk, so a 512-chip
    checkpoint restores on 256 chips (or 1 CPU) unchanged.

Fault-tolerance contract with runtime.fault_tolerance: the training loop
checkpoints every N steps; on failure the watchdog restarts from
``latest_step`` and the data pipeline replays deterministically from that
step (data/pipeline.py is a pure function of step).  The streaming tier
(``spidr`` session snapshots, ``launch/serve.py``) rides on the same
guarantees: a serving process SIGKILLed mid-save leaves only the previous
completed snapshot visible.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["CheckpointError", "Checkpointer", "FORMAT_VERSION"]

Pytree = Any

# Bump when the on-disk layout changes incompatibly.  restore() refuses
# checkpoints stamped with a newer version (clean error, no guessing);
# version-0 checkpoints (pre-checksum) load without validation.
FORMAT_VERSION = 1


class CheckpointError(ValueError):
    """A checkpoint failed validation (corrupt, truncated, or wrong version)."""


class Checkpointer:
    def __init__(self, directory: str):
        self.directory = directory
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Pytree, extra_meta: Optional[dict] = None):
        leaves, treedef = jax.tree.flatten(tree, is_leaf=lambda x: x is None)
        host_leaves = [None if l is None else np.asarray(l) for l in leaves]
        self._write(step, host_leaves, str(treedef), extra_meta or {})

    def save_async(self, step: int, tree: Pytree, extra_meta: Optional[dict] = None):
        self.wait()
        leaves, treedef = jax.tree.flatten(tree, is_leaf=lambda x: x is None)
        # Synchronous device->host snapshot; disk IO deferred to the thread.
        host_leaves = [None if l is None else np.asarray(l) for l in leaves]
        self._thread = threading.Thread(
            target=self._write, args=(step, host_leaves, str(treedef), extra_meta or {}),
            daemon=True,
        )
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_leaves, treedef_str: str, extra_meta: dict):
        final = os.path.join(self.directory, f"step_{step:09d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = []
        for i, leaf in enumerate(host_leaves):
            if leaf is None:
                manifest.append(None)
            else:
                # NOT ascontiguousarray: that promotes 0-d scalars to (1,),
                # breaking shape round-trips for scalar leaves.
                leaf = np.asarray(leaf, order="C")
                np.save(os.path.join(tmp, f"{i}.npy"), leaf)
                manifest.append({
                    "dtype": str(leaf.dtype),
                    "shape": list(leaf.shape),
                    "crc32": zlib.crc32(leaf.tobytes()),
                })
        meta = {"step": step, "format_version": FORMAT_VERSION,
                "n_leaves": len(host_leaves), "manifest": manifest,
                "treedef": treedef_str, **extra_meta}
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)

    # --------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        steps = [
            int(d.split("_")[1])
            for d in os.listdir(self.directory)
            if d.startswith("step_") and not d.endswith(".tmp")
        ]
        return max(steps) if steps else None

    def restore(
        self,
        step: int,
        like: Pytree,
        shardings: Optional[Pytree] = None,
        host: bool = False,
    ) -> Pytree:
        """Restore into the structure of ``like``; device_put with
        ``shardings`` if given (elastic re-shard happens here).

        Every leaf is validated against the manifest (crc32 + dtype/shape)
        before it is returned; damage raises :class:`CheckpointError`.

        ``host=True`` returns the leaves as numpy arrays with their exact
        on-disk dtypes instead of device arrays — required for trees that
        carry int64/float64 accounting (e.g. spidr session snapshots),
        which ``jnp.asarray`` would silently truncate under 32-bit jax.
        """
        path = os.path.join(self.directory, f"step_{step:09d}")
        try:
            with open(os.path.join(path, "meta.json")) as f:
                meta = json.load(f)
        except FileNotFoundError:
            raise
        except (json.JSONDecodeError, OSError) as e:
            raise CheckpointError(
                f"checkpoint step {step} in {self.directory} has an "
                f"unreadable meta.json: {e}") from e
        version = meta.get("format_version", 0)
        if version > FORMAT_VERSION:
            raise CheckpointError(
                f"checkpoint step {step} was written by format version "
                f"{version}, but this build reads <= {FORMAT_VERSION} — "
                "upgrade the code or re-save the checkpoint")
        manifest = meta.get("manifest") or [None] * meta["n_leaves"]
        leaves_like, treedef = jax.tree.flatten(like, is_leaf=lambda x: x is None)
        assert meta["n_leaves"] == len(leaves_like), "pytree structure changed"
        out = []
        shard_leaves = (
            jax.tree.flatten(shardings, is_leaf=lambda x: x is None)[0]
            if shardings is not None else [None] * len(leaves_like)
        )
        for i, (ll, sh) in enumerate(zip(leaves_like, shard_leaves)):
            if ll is None:
                out.append(None)
                continue
            leaf_path = os.path.join(path, f"{i}.npy")
            try:
                arr = np.load(leaf_path)
            except FileNotFoundError:
                raise
            except Exception as e:
                raise CheckpointError(
                    f"checkpoint step {step} leaf {i} is unreadable "
                    f"(truncated or corrupt {leaf_path}): {e}") from e
            entry = manifest[i] if i < len(manifest) else None
            if entry is not None and "crc32" in entry:
                if (str(arr.dtype) != entry["dtype"]
                        or list(arr.shape) != entry["shape"]):
                    raise CheckpointError(
                        f"checkpoint step {step} leaf {i} is "
                        f"{arr.dtype}{arr.shape}, but the manifest records "
                        f"{entry['dtype']}{tuple(entry['shape'])} — the "
                        "leaf file was modified after the save")
                crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
                if crc != entry["crc32"]:
                    raise CheckpointError(
                        f"checkpoint step {step} leaf {i} fails its crc32 "
                        f"check ({crc} != recorded {entry['crc32']}) — the "
                        "data is corrupt; restore from another snapshot")
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            elif host:
                out.append(arr)
            else:
                out.append(jax.numpy.asarray(arr))
        return jax.tree.unflatten(treedef, out)
