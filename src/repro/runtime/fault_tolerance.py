"""Fault tolerance & straggler mitigation for long-running training.

Components (composed by ``runtime.loop.TrainingLoop``):

  * ``StepWatchdog`` — a deadline timer armed per step; if a step exceeds
    ``deadline_s`` (hung collective, dead host) the registered callback
    fires (default: raise in the main thread via a flag the loop checks).
    At 1000+ nodes a hung all-reduce is the common failure mode; the
    watchdog converts it from a silent stall into a restartable failure.

  * ``StragglerDetector`` — ring buffer of per-step wall times; flags
    steps > mean + z*std.  On a real pod this feeds the scheduler
    (drop/replace the slow host); here it logs and counts, and the
    TrainingLoop exposes the stats.

  * ``retrying`` — wraps the step fn; on failure restores the latest
    checkpoint and replays (the data pipeline is a pure function of step,
    so replay is deterministic).  ``max_restarts`` bounds crash loops.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Callable, Optional

import numpy as np

__all__ = ["StepWatchdog", "StragglerDetector", "RestartableFailure", "retrying"]


class RestartableFailure(RuntimeError):
    """A failure the loop should handle by restore-and-replay."""


class StepWatchdog:
    def __init__(self, deadline_s: float, on_timeout: Optional[Callable] = None,
                 counter=None):
        """``counter``: optional ``repro.obs`` Counter (or any object with
        ``inc()``) bumped on every firing — lets a serving loop export
        watchdog timeouts without this module importing telemetry."""
        self.deadline_s = deadline_s
        self.on_timeout = on_timeout
        self.counter = counter
        self._timer: Optional[threading.Timer] = None
        self.timed_out = False
        self.timeouts = 0

    def _fire(self):
        self.timed_out = True
        self.timeouts += 1
        if self.counter is not None:
            self.counter.inc()
        if self.on_timeout:
            self.on_timeout()

    def arm(self):
        self.disarm()
        self.timed_out = False
        self._timer = threading.Timer(self.deadline_s, self._fire)
        self._timer.daemon = True
        self._timer.start()

    def disarm(self):
        if self._timer is not None:
            self._timer.cancel()
            self._timer = None

    def check(self):
        if self.timed_out:
            raise RestartableFailure(
                f"step exceeded watchdog deadline {self.deadline_s}s"
            )


@dataclasses.dataclass
class StragglerStats:
    flagged: int
    mean_s: float
    p95_s: float
    last_s: float


class StragglerDetector:
    def __init__(self, window: int = 64, z_thresh: float = 3.0, min_steps: int = 8):
        self.times = collections.deque(maxlen=window)
        self.z_thresh = z_thresh
        self.min_steps = min_steps
        self.flagged = 0

    def record(self, seconds: float) -> bool:
        """Returns True if this step is a straggler."""
        is_straggler = False
        if len(self.times) >= self.min_steps:
            arr = np.asarray(self.times)
            mu, sd = arr.mean(), arr.std() + 1e-9
            if seconds > mu + self.z_thresh * sd:
                is_straggler = True
                self.flagged += 1
        self.times.append(seconds)
        return is_straggler

    def stats(self) -> StragglerStats:
        arr = np.asarray(self.times) if self.times else np.zeros(1)
        return StragglerStats(
            flagged=self.flagged,
            mean_s=float(arr.mean()),
            p95_s=float(np.percentile(arr, 95)),
            last_s=float(arr[-1]),
        )


def retrying(step_fn, restore_fn, max_restarts: int = 3,
             on_restart: Optional[Callable] = None):
    """Wrap step_fn; on RestartableFailure restore state and retry.

    ``restore_fn`` is called with the failing call's arguments; if it
    returns a tuple, that replaces the positional args for the retry —
    a ``None`` return keeps them (stateful restore: the serving loop's
    restore_fn rewinds internal session state and retries the same tick).
    Any other exception type passes straight through: only failures
    explicitly marked restartable are retried.  ``wrapped.state``
    exposes the cumulative restart count.  ``on_restart`` (no args) is
    invoked after each successful restore — telemetry hook for counting
    rewinds without coupling this module to ``repro.obs``.
    """
    state = {"restarts": 0}

    def wrapped(*args, **kwargs):
        while True:
            try:
                return step_fn(*args, **kwargs)
            except RestartableFailure:
                state["restarts"] += 1
                if state["restarts"] > max_restarts:
                    raise
                new_args = restore_fn(*args, **kwargs)
                if new_args is not None:
                    args = tuple(new_args)
                if on_restart is not None:
                    on_restart()

    wrapped.state = state
    return wrapped
