"""Fault-tolerant training loop: checkpoint/restart + watchdog + stragglers.

The composition point for the runtime substrate: a crash (or watchdog
timeout) inside ``run()`` restores the latest checkpoint and REPLAYS from
that step — deterministic because the data pipeline is a pure function of
the step index.  This is the control loop ``launch/train.py`` drives.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable, Optional

from ..checkpoint.checkpoint import Checkpointer
from .fault_tolerance import RestartableFailure, StepWatchdog, StragglerDetector

log = logging.getLogger("repro.loop")

__all__ = ["LoopConfig", "TrainingLoop"]


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    checkpoint_every: int = 100
    watchdog_deadline_s: float = 3600.0
    max_restarts: int = 3
    log_every: int = 10


class TrainingLoop:
    def __init__(
        self,
        step_fn: Callable,        # (params, opt_state, step, batch) -> (p, o, metrics)
        batch_fn: Callable,       # step -> batch (pure)
        checkpointer: Checkpointer,
        cfg: LoopConfig,
        metrics_cb: Optional[Callable] = None,
    ):
        self.step_fn = step_fn
        self.batch_fn = batch_fn
        self.ckpt = checkpointer
        self.cfg = cfg
        self.metrics_cb = metrics_cb
        self.watchdog = StepWatchdog(cfg.watchdog_deadline_s)
        self.stragglers = StragglerDetector()
        self.restarts = 0

    def run(self, params, opt_state, start_step: int = 0):
        step = start_step
        # Resume from latest checkpoint if one exists past start_step.
        latest = self.ckpt.latest_step()
        if latest is not None and latest > step:
            log.info("resuming from checkpoint step %d", latest)
            params, opt_state = self.ckpt.restore(latest, (params, opt_state))
            step = latest

        history = []
        while step < self.cfg.total_steps:
            try:
                batch = self.batch_fn(step)
                self.watchdog.arm()
                t0 = time.monotonic()
                params, opt_state, metrics = self.step_fn(params, opt_state, step, batch)
                # Block on the loss so watchdog timing covers real execution.
                loss = float(metrics["loss"])
                dt = time.monotonic() - t0
                self.watchdog.disarm()
                self.watchdog.check()
                if self.stragglers.record(dt):
                    log.warning("straggler step %d: %.3fs", step, dt)
                if step % self.cfg.log_every == 0:
                    log.info("step %d loss %.4f (%.3fs)", step, loss, dt)
                if self.metrics_cb:
                    self.metrics_cb(step, metrics, dt)
                history.append(loss)
                step += 1
                if step % self.cfg.checkpoint_every == 0 or step == self.cfg.total_steps:
                    self.ckpt.save_async(step, (params, opt_state))
            except (RestartableFailure, RuntimeError) as e:
                self.watchdog.disarm()
                self.restarts += 1
                if self.restarts > self.cfg.max_restarts:
                    raise
                latest = self.ckpt.latest_step()
                log.warning("failure at step %d (%s); restoring step %s", step, e, latest)
                if latest is None:
                    raise
                params, opt_state = self.ckpt.restore(latest, (params, opt_state))
                step = latest
        self.ckpt.wait()
        return params, opt_state, history
