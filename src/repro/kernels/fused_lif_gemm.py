"""Pallas TPU kernel: fused spike-GEMM + neuron update (one timestep, one layer).

SpiDR's inner loop interleaves the compute macro (weight->Vmem accumulation,
C1) and the neuron macro (leak/threshold/reset, C8) on SRAM-resident state;
the membrane potential never leaves the array between the two phases.  The
TPU analogue is to fuse both phases into a single kernel invocation so the
Vmem tile stays in VMEM between the MXU accumulation and the VPU neuron
update — composing ``spike_gemm`` + ``lif_step_fused`` instead costs two
extra HBM round-trips of the (M, N) Vmem tensor per timestep.

    acc[m, n]  = sum_k S[m, k] * W[k, n]          (MXU, zero-skipped tiles)
    v', s      = neuron_update(v[m, n], acc[m, n]) (VPU, same invocation)

Grid = (M/bm, N/bn, K/bk) with k innermost (sequential on TPU): the output
Vmem block doubles as the revisited accumulator; the neuron update runs once,
on the final k step.  Tile-level zero-skipping is identical to
``spike_gemm``: an all-zero (bm x bk) spike tile issues no MXU work.

Two variants share this structure:

* ``fused_lif_gemm``      — float32; bit-identical to
  ``lif_step_ref(v, spike_gemm_ref(S, W))``.
* ``fused_lif_gemm_int``  — integer datapath with ``QuantSpec`` saturation
  semantics: the wide int32 accumulation is saturated once into the
  (2W-1)-bit Vmem field (``partial``), then added (saturating) into the
  carried Vmem — exactly ``neuron_step_int(v, saturate(S @ W))``, and
  bit-equal to ``cim_macro.accumulate_sequential`` whenever no intermediate
  sum leaves the Vmem range.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

__all__ = [
    "fused_lif_gemm",
    "fused_lif_gemm_int",
    "fused_lif_gemm_int_tblk",
    "spike_tile_bitmap",
    "DEFAULT_BLOCK",
]

DEFAULT_BLOCK = (128, 128, 128)  # (bm, bn, bk)


def _fused_kernel_f32(
    s_ref, w_ref, v_ref, o_v_ref, o_s_ref,
    *, n_k, threshold, leak, soft_reset, skip_empty,
):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_v_ref[...] = jnp.zeros_like(o_v_ref)
        o_s_ref[...] = jnp.zeros_like(o_s_ref)

    s_tile = s_ref[...]
    if skip_empty:
        @pl.when(jnp.any(s_tile != 0))
        def _accumulate():
            o_v_ref[...] += jnp.dot(
                s_tile, w_ref[...], preferred_element_type=jnp.float32
            )
    else:
        o_v_ref[...] += jnp.dot(
            s_tile, w_ref[...], preferred_element_type=jnp.float32
        )

    @pl.when(k == n_k - 1)
    def _neuron():
        v = v_ref[...]
        if leak != 1.0:
            v = v * leak
        v = v + o_v_ref[...]
        s = (v >= threshold).astype(v.dtype)
        if soft_reset:
            v_next = v - s * threshold
        else:
            v_next = v * (1.0 - s)
        o_v_ref[...] = v_next
        o_s_ref[...] = s


def _fused_int_body(
    s_ref, w_ref, v_ref, o_v_ref, o_s_ref, get_threshold,
    *, n_k, leak_shift, soft_reset, v_min, v_max, skip_empty,
):
    """Shared integer kernel body.

    ``get_threshold`` supplies the firing threshold at neuron time: a
    static scalar (per-tensor quantization) or a ``(1, bn)`` int32 tile
    read from a threshold operand (per-channel exported networks) — the
    accumulate/leak/saturate/fire/reset program is identical either way.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_v_ref[...] = jnp.zeros_like(o_v_ref)
        o_s_ref[...] = jnp.zeros_like(o_s_ref)

    s_tile = s_ref[...]

    def _accumulate():
        o_v_ref[...] += jax.lax.dot_general(
            s_tile.astype(jnp.int32),
            w_ref[...].astype(jnp.int32),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )

    if skip_empty:
        pl.when(jnp.any(s_tile != 0))(_accumulate)
    else:
        _accumulate()

    @pl.when(k == n_k - 1)
    def _neuron():
        # Column-adder saturation of the accumulated partials (quant.sat_add
        # semantics), then the neuron-macro program on the carried Vmem.
        threshold = get_threshold()
        partial = jnp.clip(o_v_ref[...], v_min, v_max)
        v = v_ref[...]
        if leak_shift > 0:
            v = v - (v >> leak_shift)
        v = jnp.clip(v + partial, v_min, v_max)
        s = (v >= threshold).astype(jnp.int32)
        if soft_reset:
            v_next = jnp.clip(v - s * threshold, v_min, v_max)
        else:
            v_next = v * (1 - s)
        o_v_ref[...] = v_next
        o_s_ref[...] = s


def _fused_kernel_int(s_ref, w_ref, v_ref, o_v_ref, o_s_ref,
                      *, threshold, **kw):
    _fused_int_body(s_ref, w_ref, v_ref, o_v_ref, o_s_ref,
                    lambda: threshold, **kw)


def _fused_kernel_int_vec(s_ref, w_ref, v_ref, t_ref, o_v_ref, o_s_ref, **kw):
    # t_ref is (1, bn) — one threshold per output channel, broadcast down
    # the rows at the compare.
    _fused_int_body(s_ref, w_ref, v_ref, o_v_ref, o_s_ref,
                    lambda: t_ref[...], **kw)


def _fused_call(kernel, s, w, v, out_dtype, block, interpret, thr=None,
                thr_pad=0):
    """Shared pallas_call plumbing; ``thr`` adds an optional per-output-
    channel ``(N,)`` operand (padded with ``thr_pad``), blocked ``(1, bn)``
    and broadcast down the rows inside the kernel."""
    m, k = s.shape
    k2, n = w.shape
    assert k == k2, (s.shape, w.shape)
    assert v.shape == (m, n), (v.shape, (m, n))
    bm, bn, bk = block

    pad_m, pad_n, pad_k = -m % bm, -n % bn, -k % bk
    s = jnp.pad(s, ((0, pad_m), (0, pad_k)))
    w = jnp.pad(w, ((0, pad_k), (0, pad_n)))
    v = jnp.pad(v, ((0, pad_m), (0, pad_n)))
    gm, gn, gk = s.shape[0] // bm, w.shape[1] // bn, s.shape[1] // bk

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
    ]
    operands = [s, w, v]
    if thr is not None:
        assert thr.shape == (n,), (thr.shape, n)
        operands.append(
            jnp.pad(thr, (0, pad_n), constant_values=thr_pad)[None, :])
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)))

    v_out, s_out = pl.pallas_call(
        functools.partial(kernel, n_k=gk),
        grid=(gm, gn, gk),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s.shape[0], w.shape[1]), out_dtype),
            jax.ShapeDtypeStruct((s.shape[0], w.shape[1]), out_dtype),
        ],
        interpret=interpret,
    )(*operands)
    return v_out[:m, :n], s_out[:m, :n]


@functools.partial(
    jax.jit,
    static_argnames=(
        "threshold", "leak", "soft_reset", "block", "interpret", "skip_empty"
    ),
)
def fused_lif_gemm(
    spikes: jax.Array,   # (M, K) in {0,1}, any int/bool/float dtype
    weights: jax.Array,  # (K, N) float32
    v: jax.Array,        # (M, N) float32 carried Vmem
    threshold: float = 1.0,
    leak: float = 1.0,
    soft_reset: bool = False,
    block: tuple = DEFAULT_BLOCK,
    interpret: bool = False,
    skip_empty: bool = True,
):
    """Fused float timestep: ``(v', s) = lif(v, spikes @ weights)``."""
    kernel = functools.partial(
        _fused_kernel_f32,
        threshold=threshold, leak=leak, soft_reset=soft_reset,
        skip_empty=skip_empty,
    )
    return _fused_call(
        kernel, spikes.astype(jnp.float32), weights.astype(jnp.float32),
        v.astype(jnp.float32), jnp.float32, block, interpret,
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "threshold", "leak_shift", "soft_reset", "vmem_bits", "block",
        "interpret", "skip_empty",
    ),
)
def _fused_int_scalar(
    spikes, weights, v, *, threshold, leak_shift, soft_reset, vmem_bits,
    block, interpret, skip_empty,
):
    v_min, v_max = -(1 << (vmem_bits - 1)), (1 << (vmem_bits - 1)) - 1
    kernel = functools.partial(
        _fused_kernel_int,
        threshold=threshold, leak_shift=leak_shift, soft_reset=soft_reset,
        v_min=v_min, v_max=v_max, skip_empty=skip_empty,
    )
    return _fused_call(
        kernel, spikes.astype(jnp.int8), weights.astype(jnp.int8),
        v.astype(jnp.int32), jnp.int32, block, interpret,
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "leak_shift", "soft_reset", "vmem_bits", "block", "interpret",
        "skip_empty",
    ),
)
def _fused_int_vec(
    spikes, weights, v, threshold, *, leak_shift, soft_reset, vmem_bits,
    block, interpret, skip_empty,
):
    v_min, v_max = -(1 << (vmem_bits - 1)), (1 << (vmem_bits - 1)) - 1
    kernel = functools.partial(
        _fused_kernel_int_vec,
        leak_shift=leak_shift, soft_reset=soft_reset,
        v_min=v_min, v_max=v_max, skip_empty=skip_empty,
    )
    # Pad channels get threshold v_max+1: a saturated Vmem can never reach
    # it, so the (discarded) padding never spikes.
    return _fused_call(
        kernel, spikes.astype(jnp.int8), weights.astype(jnp.int8),
        v.astype(jnp.int32), jnp.int32, block, interpret,
        thr=threshold.astype(jnp.int32), thr_pad=v_max + 1,
    )


def _tile_bitmap_padded(s: jax.Array, bm: int, bk: int) -> jax.Array:
    """Per-tile spike bitmap of an already block-padded ``(T, M, K)`` stack.

    Entry ``[t, i, kk]`` is 1 iff the ``(bm, bk)`` spike tile at grid cell
    ``(i, kk)`` of timestep ``t`` holds at least one spike.  int32 so the
    kernel can read single entries through a ``(T, 1, 1)`` block.
    """
    t, m, k = s.shape
    tiles = s.reshape(t, m // bm, bm, k // bk, bk)
    return jnp.any(tiles != 0, axis=(2, 4)).astype(jnp.int32)


def spike_tile_bitmap(spikes: jax.Array, block: tuple = DEFAULT_BLOCK):
    """Host-side per-tile spike bitmap: ``(T, ceil(M/bm), ceil(K/bk))``.

    The prologue the T_blk kernel runs before launching: pad ``spikes`` to
    block multiples and mark which ``(bm, bk)`` tiles contain any spike.
    A 2-D ``(M, K)`` input is treated as a single timestep and returns a
    2-D ``(gm, gk)`` map.  ``block`` is ``(bm, bn, bk)``; ``bn`` is unused
    (the bitmap is independent of the output tiling).
    """
    bm, _, bk = block
    squeeze = spikes.ndim == 2
    if squeeze:
        spikes = spikes[None]
    t, m, k = spikes.shape
    s = jnp.pad(spikes, ((0, 0), (0, -m % bm), (0, -k % bk)))
    out = _tile_bitmap_padded(s, bm, bk)
    return out[0] if squeeze else out


def _tblk_int_body(
    s_ref, w_ref, v_ref, bm_ref, o_v_ref, o_s_ref, get_threshold,
    *, n_k, n_t, leak_shift, soft_reset, v_min, v_max, skip_empty,
):
    """Vmem-stationary multi-timestep integer body.

    One grid step sees the weight tile once and accumulates all ``n_t``
    timestep partials against it (``o_v_ref[t]`` doubles as the per-t
    accumulator); the sequential neuron program runs over t on the final
    k step, with the carried Vmem tile staying resident throughout.
    Block-level sparsity comes from the host-computed bitmap: a zero
    entry skips the whole (bm x bk) MXU dot for that (t, i, kk) tile.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_v_ref[...] = jnp.zeros_like(o_v_ref)
        o_s_ref[...] = jnp.zeros_like(o_s_ref)

    w_tile = w_ref[...].astype(jnp.int32)
    for t in range(n_t):
        def _accumulate(t=t):
            o_v_ref[t] += jax.lax.dot_general(
                s_ref[t].astype(jnp.int32), w_tile,
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32,
            )
        if skip_empty:
            pl.when(bm_ref[t, 0, 0] != 0)(_accumulate)
        else:
            _accumulate()

    @pl.when(k == n_k - 1)
    def _neuron():
        threshold = get_threshold()
        v = v_ref[...]
        for t in range(n_t):
            partial = jnp.clip(o_v_ref[t], v_min, v_max)
            if leak_shift > 0:
                v = v - (v >> leak_shift)
            v = jnp.clip(v + partial, v_min, v_max)
            s = (v >= threshold).astype(jnp.int32)
            if soft_reset:
                v = jnp.clip(v - s * threshold, v_min, v_max)
            else:
                v = v * (1 - s)
            o_v_ref[t] = v
            o_s_ref[t] = s


def _tblk_kernel_scalar(s_ref, w_ref, v_ref, bm_ref, o_v_ref, o_s_ref,
                        *, threshold, **kw):
    _tblk_int_body(s_ref, w_ref, v_ref, bm_ref, o_v_ref, o_s_ref,
                   lambda: threshold, **kw)


def _tblk_kernel_vec(s_ref, w_ref, v_ref, bm_ref, t_ref, o_v_ref, o_s_ref,
                     **kw):
    _tblk_int_body(s_ref, w_ref, v_ref, bm_ref, o_v_ref, o_s_ref,
                   lambda: t_ref[...], **kw)


def _tblk_call(kernel, s, w, v, block, interpret, thr=None, thr_pad=0):
    """pallas_call plumbing for the (T, M, K) multi-timestep kernel."""
    t, m, k = s.shape
    k2, n = w.shape
    assert k == k2, (s.shape, w.shape)
    assert v.shape == (m, n), (v.shape, (m, n))
    bm, bn, bk = block

    pad_m, pad_n, pad_k = -m % bm, -n % bn, -k % bk
    s = jnp.pad(s, ((0, 0), (0, pad_m), (0, pad_k)))
    w = jnp.pad(w, ((0, pad_k), (0, pad_n)))
    v = jnp.pad(v, ((0, pad_m), (0, pad_n)))
    gm, gn, gk = s.shape[1] // bm, w.shape[1] // bn, s.shape[2] // bk
    # Prologue: bitmap over the padded stack, so tilings stay aligned.
    bitmap = _tile_bitmap_padded(s, bm, bk)

    in_specs = [
        pl.BlockSpec((t, bm, bk), lambda i, j, kk: (0, i, kk)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        pl.BlockSpec((t, 1, 1), lambda i, j, kk: (0, i, kk)),
    ]
    operands = [s, w, v, bitmap]
    if thr is not None:
        assert thr.shape == (n,), (thr.shape, n)
        operands.append(
            jnp.pad(thr, (0, pad_n), constant_values=thr_pad)[None, :])
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)))

    v_traj, s_out = pl.pallas_call(
        functools.partial(kernel, n_k=gk, n_t=t),
        grid=(gm, gn, gk),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((t, bm, bn), lambda i, j, kk: (0, i, j)),
            pl.BlockSpec((t, bm, bn), lambda i, j, kk: (0, i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, s.shape[1], w.shape[1]), jnp.int32),
            jax.ShapeDtypeStruct((t, s.shape[1], w.shape[1]), jnp.int32),
        ],
        interpret=interpret,
    )(*operands)
    return v_traj[:, :m, :n], s_out[:, :m, :n]


@functools.partial(
    jax.jit,
    static_argnames=(
        "threshold", "leak_shift", "soft_reset", "vmem_bits", "block",
        "interpret", "skip_empty",
    ),
)
def _tblk_int_scalar(
    spikes, weights, v, *, threshold, leak_shift, soft_reset, vmem_bits,
    block, interpret, skip_empty,
):
    v_min, v_max = -(1 << (vmem_bits - 1)), (1 << (vmem_bits - 1)) - 1
    kernel = functools.partial(
        _tblk_kernel_scalar,
        threshold=threshold, leak_shift=leak_shift, soft_reset=soft_reset,
        v_min=v_min, v_max=v_max, skip_empty=skip_empty,
    )
    return _tblk_call(
        kernel, spikes.astype(jnp.int8), weights.astype(jnp.int8),
        v.astype(jnp.int32), block, interpret,
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "leak_shift", "soft_reset", "vmem_bits", "block", "interpret",
        "skip_empty",
    ),
)
def _tblk_int_vec(
    spikes, weights, v, threshold, *, leak_shift, soft_reset, vmem_bits,
    block, interpret, skip_empty,
):
    v_min, v_max = -(1 << (vmem_bits - 1)), (1 << (vmem_bits - 1)) - 1
    kernel = functools.partial(
        _tblk_kernel_vec,
        leak_shift=leak_shift, soft_reset=soft_reset,
        v_min=v_min, v_max=v_max, skip_empty=skip_empty,
    )
    return _tblk_call(
        kernel, spikes.astype(jnp.int8), weights.astype(jnp.int8),
        v.astype(jnp.int32), block, interpret,
        thr=threshold.astype(jnp.int32), thr_pad=v_max + 1,
    )


def fused_lif_gemm_int_tblk(
    spikes: jax.Array,   # (T, M, K) in {0,1}
    weights: jax.Array,  # (K, N) int8
    v: jax.Array,        # (M, N) int32 carried Vmem entering timestep 0
    threshold,           # int, or (N,) int32 per-channel thresholds
    leak_shift: int = 0,
    soft_reset: bool = False,
    vmem_bits: int = 7,
    block: tuple = DEFAULT_BLOCK,
    interpret: bool = False,
    skip_empty: bool = True,
):
    """Vmem-stationary fused timestep *tile*: T timesteps per weight pass.

    Bit-exact with ``fused_lif_gemm_int`` applied sequentially over t —
    integer accumulation is exact, so hoisting the weight-tile loop outside
    the timestep loop reorders nothing observable — but each weight block
    is read from HBM once per T-tile instead of once per timestep, and
    block-level sparsity is decided from a host-computed per-tile bitmap
    (see :func:`spike_tile_bitmap`) instead of an in-kernel reduction.

    Returns ``(v_traj, s_out)``, both ``(T, M, N)`` int32: the post-update
    Vmem after each timestep (``v_traj[-1]`` is the carry for the next
    tile) and the emitted spikes.
    """
    kw = dict(leak_shift=leak_shift, soft_reset=soft_reset,
              vmem_bits=vmem_bits, block=block, interpret=interpret,
              skip_empty=skip_empty)
    if isinstance(threshold, (int, np.integer)):
        return _tblk_int_scalar(spikes, weights, v, threshold=int(threshold),
                                **kw)
    threshold = jnp.asarray(threshold)
    if threshold.ndim == 0:
        threshold = jnp.broadcast_to(threshold, (weights.shape[1],))
    return _tblk_int_vec(spikes, weights, v, threshold, **kw)


def fused_lif_gemm_int(
    spikes: jax.Array,   # (M, K) in {0,1}
    weights: jax.Array,  # (K, N) int8
    v: jax.Array,        # (M, N) int32 holding (2W-1)-bit values
    threshold,           # int, or (N,) int32 per-channel thresholds
    leak_shift: int = 0,
    soft_reset: bool = False,
    vmem_bits: int = 7,
    block: tuple = DEFAULT_BLOCK,
    interpret: bool = False,
    skip_empty: bool = True,
):
    """Fused integer timestep, bit-exact with the macro datapath.

    Equals ``neuron_step_int(v, saturate(spikes @ weights, spec), ...)`` and
    therefore ``accumulate_sequential`` when no intermediate overflow occurs.

    ``threshold`` may be a Python int (per-tensor quantization; baked into
    the kernel as a compile-time constant, the original behavior) or an
    ``(N,)`` integer array of per-output-channel thresholds (per-channel
    exported networks; passed as a kernel operand).
    """
    kw = dict(leak_shift=leak_shift, soft_reset=soft_reset,
              vmem_bits=vmem_bits, block=block, interpret=interpret,
              skip_empty=skip_empty)
    if isinstance(threshold, (int, np.integer)):
        return _fused_int_scalar(spikes, weights, v, threshold=int(threshold),
                                 **kw)
    threshold = jnp.asarray(threshold)
    if threshold.ndim == 0:
        threshold = jnp.broadcast_to(threshold, (weights.shape[1],))
    return _fused_int_vec(spikes, weights, v, threshold, **kw)
