"""Pallas TPU kernel: low-precision-weight matmul with in-kernel dequant.

The LM-side realization of SpiDR's C2 (reconfigurable weight precision with
wide accumulators): weights are stored in HBM at 4 or 8 bits and dequantized
*inside* the kernel after the VMEM DMA, so HBM traffic shrinks by 4x/2x vs
bf16 — exactly the B_Vmem=2B_w-1 trade the macro makes, transplanted to the
TPU memory hierarchy (HBM->VMEM is the analogue of SRAM row reads).

int4 weights are packed two-per-byte along K (even rows in the low nibble,
odd rows in the high nibble — the macro's even/odd column interleave).
Per-output-channel float scales follow the standard w4a16/w8a16 recipe.

  x (M, K) f32/bf16  x  w_packed (K(/2), N) int8  * scale (N,)  -> (M, N) f32
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["quant_matmul", "pack_int4", "unpack_int4"]

_BLOCK = (128, 128, 256)  # (bm, bn, bk) — bk counts UNPACKED rows


def pack_int4(w_int: jax.Array) -> jax.Array:
    """(K, N) int in [-8, 7] -> (K//2, N) uint8, even row low nibble."""
    assert w_int.shape[0] % 2 == 0, "K must be even to pack int4"
    lo = (w_int[0::2] & 0xF).astype(jnp.uint8)
    hi = (w_int[1::2] & 0xF).astype(jnp.uint8)
    return lo | (hi << 4)


def unpack_int4(packed: jax.Array) -> jax.Array:
    """Inverse of pack_int4 -> (K, N) int8 (sign-extended)."""
    lo = (packed & 0xF).astype(jnp.int8)
    hi = ((packed >> 4) & 0xF).astype(jnp.int8)
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    k2, n = packed.shape
    out = jnp.zeros((k2 * 2, n), jnp.int8)
    out = out.at[0::2].set(lo)
    return out.at[1::2].set(hi)


def _qmm_kernel_int8(x_ref, w_ref, s_ref, o_ref, *, n_k):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    acc = jax.lax.dot_general(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    # Scale is per output channel; applying it per k-partial is exact.
    o_ref[...] += acc * s_ref[...]
    del n_k


def _qmm_kernel_int4(x_ref, w_ref, s_ref, o_ref, *, n_k):
    """w_ref block is (bk//2, bn) packed; unpack in VMEM then dot."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    packed = w_ref[...]
    lo = (packed & 0xF).astype(jnp.int8)
    hi = ((packed >> 4) & 0xF).astype(jnp.int8)
    lo = jnp.where(lo >= 8, lo - 16, lo).astype(jnp.float32)
    hi = jnp.where(hi >= 8, hi - 16, hi).astype(jnp.float32)

    x = x_ref[...].astype(jnp.float32)
    x_even = x[:, 0::2]  # multiplies low-nibble (even K) rows
    x_odd = x[:, 1::2]
    acc = jax.lax.dot_general(
        x_even, lo, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ) + jax.lax.dot_general(
        x_odd, hi, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    o_ref[...] += acc * s_ref[...]
    del n_k


@functools.partial(jax.jit, static_argnames=("bits", "block", "interpret"))
def quant_matmul(
    x: jax.Array,        # (M, K) float
    w_q: jax.Array,      # int8: (K, N) for bits=8, (K//2, N) packed for bits=4
    scale: jax.Array,    # (N,) per-channel
    bits: int = 8,
    block: tuple = _BLOCK,
    interpret: bool = False,
) -> jax.Array:
    assert bits in (4, 8)
    m, k = x.shape
    n = w_q.shape[1]
    bm, bn, bk = block
    if bits == 4:
        assert w_q.shape[0] * 2 == k, (w_q.shape, k)
        assert bk % 2 == 0

    pad_m, pad_n, pad_k = -m % bm, -n % bn, -k % bk
    x_p = jnp.pad(x, ((0, pad_m), (0, pad_k))).astype(jnp.float32)
    if bits == 8:
        w_p = jnp.pad(w_q, ((0, pad_k), (0, pad_n)))
        w_block = (bk, bn)
    else:
        w_p = jnp.pad(w_q, ((0, pad_k // 2), (0, pad_n)))
        w_block = (bk // 2, bn)
    s_p = jnp.pad(scale.astype(jnp.float32), (0, pad_n)).reshape(1, -1)

    gm, gn, gk = x_p.shape[0] // bm, w_p.shape[1] // bn, x_p.shape[1] // bk
    kernel = functools.partial(
        _qmm_kernel_int8 if bits == 8 else _qmm_kernel_int4, n_k=gk
    )
    out = pl.pallas_call(
        kernel,
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec(w_block, lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((x_p.shape[0], w_p.shape[1]), jnp.float32),
        interpret=interpret,
    )(x_p, w_p, s_p)
    return out[:m, :n]
