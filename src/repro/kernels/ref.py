"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each ``*_ref`` matches its kernel's contract exactly; tests sweep shapes,
dtypes and sparsity levels asserting allclose/array_equal between kernel
(interpret=True on CPU) and oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "spike_gemm_ref",
    "lif_step_ref",
    "lif_step_int_ref",
    "fused_lif_gemm_ref",
    "fused_lif_gemm_int_ref",
    "quant_matmul_ref",
]


def spike_gemm_ref(spikes: jax.Array, weights: jax.Array) -> jax.Array:
    """int32 spikes @ weights."""
    return jnp.dot(
        spikes.astype(jnp.int32),
        weights.astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )


def lif_step_ref(v, current, threshold=1.0, leak=1.0, soft_reset=False):
    if leak != 1.0:
        v = v * leak
    v = v + current
    s = (v >= threshold).astype(v.dtype)
    v_next = v - s * threshold if soft_reset else v * (1.0 - s)
    return v_next, s


def lif_step_int_ref(v, partial, threshold, leak_shift=0, soft_reset=False, vmem_bits=7):
    v_min, v_max = -(1 << (vmem_bits - 1)), (1 << (vmem_bits - 1)) - 1
    v = v.astype(jnp.int32)
    if leak_shift > 0:
        v = v - (v >> leak_shift)
    v = jnp.clip(v + partial.astype(jnp.int32), v_min, v_max)
    s = (v >= threshold).astype(jnp.int32)
    v_next = jnp.clip(v - s * threshold, v_min, v_max) if soft_reset else v * (1 - s)
    return v_next, s


def fused_lif_gemm_ref(spikes, weights, v, threshold=1.0, leak=1.0,
                       soft_reset=False):
    """Float fused kernel oracle: spike-GEMM then the neuron update."""
    acc = jnp.dot(
        spikes.astype(jnp.float32),
        weights.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    return lif_step_ref(v.astype(jnp.float32), acc, threshold, leak, soft_reset)


def fused_lif_gemm_int_ref(spikes, weights, v, threshold, leak_shift=0,
                           soft_reset=False, vmem_bits=7):
    """Integer fused kernel oracle: wide GEMM, one saturation, neuron step."""
    v_min, v_max = -(1 << (vmem_bits - 1)), (1 << (vmem_bits - 1)) - 1
    partial = jnp.clip(spike_gemm_ref(spikes, weights), v_min, v_max)
    return lif_step_int_ref(v, partial, threshold, leak_shift, soft_reset,
                            vmem_bits)


def quant_matmul_ref(x, w_q, scale, bits=8):
    from .quant_matmul import unpack_int4

    w = unpack_int4(w_q) if bits == 4 else w_q
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32)) * scale[None, :]
