"""Pallas TPU kernel: one RWKV6 wkv chunk step (paper C1 on the LM side).

The wkv state S (N x N per head) is this framework's clearest Vmem
analogue (DESIGN.md §4): a stationary accumulator held in fast memory
while token "events" stream through.  This kernel computes one chunk of
the chunked linear-attention form for EVERY (batch, head) in the grid:

    lw_incl = cumsum(lw)                                  (C, N)
    y       = (r * e^{lw_excl}) @ S0                      inter-chunk
            + [(r_i k_j e^{lw_excl_i - lw_incl_j})_{j<i}] v   intra
            + (sum_n r u k) * v                           bonus diag
    S1      = e^{lw_incl_C} * S0 + (k * e^{lw_incl_C - lw_incl})^T v

Per-program working set at C=32, N=64: 5 x (C,N) + 2 x (N,N) f32
= 73 KB — comfortably VMEM-resident, with the (C,C,N) decay-ratio
tensor (256 KB) materialized on the fly.  The MXU sees three (C,N)x(N,N)
/ (C,C)x(C,N) contractions per chunk; HBM traffic is exactly one read of
the chunk operands and one state read/write — the weight/Vmem co-location
story, transplanted.

Grid: (B*H,). The host-side wrapper scans chunks, carrying S — on TPU the
scan pipelines the next chunk's DMA against the current compute.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["wkv_chunk", "wkv_sequence"]


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref, y_ref, s1_ref):
    r = r_ref[0]        # (C, N)
    k = k_ref[0]
    v = v_ref[0]
    lw = lw_ref[0]
    u = u_ref[0]        # (1, N) block
    s0 = s0_ref[0]      # (N, N)

    c = r.shape[0]
    lw_incl = jnp.cumsum(lw, axis=0)
    lw_excl = lw_incl - lw

    # inter-chunk: (C,N) @ (N,N)
    y = jax.lax.dot_general(
        r * jnp.exp(lw_excl), s0, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    # intra-chunk: A_ij = sum_n r_i k_j e^{lw_excl_i - lw_incl_j}, j < i
    iota_i = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    iota_j = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    strict = (iota_j < iota_i)[:, :, None]
    ratio = jnp.where(
        strict, jnp.exp(lw_excl[:, None, :] - lw_incl[None, :, :]), 0.0
    )  # (C, C, N), exponents <= 0
    a = jnp.sum(r[:, None, :] * k[None, :, :] * ratio, axis=-1)  # (C, C)
    y = y + jax.lax.dot_general(
        a, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    # diagonal bonus: y_i += (sum_n r_i u k_i) v_i
    diag = jnp.sum(r * u * k, axis=-1, keepdims=True)
    y = y + diag * v

    # state update
    decay_all = jnp.exp(lw_incl[-1:, :])                 # (1, N)
    k_scaled = k * jnp.exp(lw_incl[-1:, :] - lw_incl)    # (C, N)
    s1 = s0 * decay_all.T + jax.lax.dot_general(
        k_scaled, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    y_ref[0] = y
    s1_ref[0] = s1


@functools.partial(jax.jit, static_argnames=("interpret",))
def wkv_chunk(r, k, v, lw, u, s0, interpret: bool = False):
    """One chunk for all heads.

    r/k/v/lw: (BH, C, N) f32; u: (BH, 1, N); s0: (BH, N, N).
    Returns (y (BH, C, N), s1 (BH, N, N)).
    """
    bh, c, n = r.shape
    spec_cn = pl.BlockSpec((1, c, n), lambda i: (i, 0, 0))
    spec_nn = pl.BlockSpec((1, n, n), lambda i: (i, 0, 0))
    spec_u = pl.BlockSpec((1, 1, n), lambda i: (i, 0, 0))
    y, s1 = pl.pallas_call(
        _wkv_kernel,
        grid=(bh,),
        in_specs=[spec_cn, spec_cn, spec_cn, spec_cn, spec_u, spec_nn],
        out_specs=[spec_cn, spec_nn],
        out_shape=[
            jax.ShapeDtypeStruct((bh, c, n), jnp.float32),
            jax.ShapeDtypeStruct((bh, n, n), jnp.float32),
        ],
        interpret=interpret,
    )(r, k, v, lw, u, s0)
    return y, s1


def wkv_sequence(r, k, v, lw, u, s0, chunk: int = 32, interpret: bool = False):
    """Full sequence via scan-of-chunks. Shapes as rwkv6._wkv_chunked:

    r/k/v/lw: (B, S, H, N); u: (H, N); s0: (B, H, N, N).
    """
    b, s, h, n = r.shape
    nc = s // chunk
    assert s % chunk == 0

    def to_bh(x):
        # (B,S,H,N) -> (nc, B*H, C, N)
        x = x.reshape(b, nc, chunk, h, n).transpose(1, 0, 3, 2, 4)
        return x.reshape(nc, b * h, chunk, n)

    rc, kc, vc, lwc = map(to_bh, (r, k, v, lw))
    u_bh = jnp.broadcast_to(u[None], (b, h, n)).reshape(b * h, 1, n)
    s = s0.reshape(b * h, n, n)

    def body(carry, inp):
        rb, kb, vb, lwb = inp
        y, s1 = wkv_chunk(rb, kb, vb, lwb, u_bh, carry, interpret=interpret)
        return s1, y

    s_f, ys = jax.lax.scan(body, s, (rc, kc, vc, lwc))
    y = ys.reshape(nc, b, h, chunk, n).transpose(1, 0, 3, 2, 4)
    return y.reshape(b, nc * chunk, h, n), s_f.reshape(b, h, n, n)
