"""Pallas TPU kernel: fused neuron-macro update (paper C8 / Eq. 3).

Fuses the neuron macro's whole per-timestep sequence — partial->full Vmem
accumulation, optional leak, threshold compare, and the conditional-write
soft/hard reset — into one elementwise VPU pass over VMEM-resident tiles.
On the silicon this is the fixed 66-cycle neuron-macro program; on TPU the
fusion saves three HBM round-trips vs composing the ops.

Float variant (training/serving) and integer variant (bit-exact with the
digital macro: int32 Vmem saturated to the (2W-1)-bit range, shift-based
leak) share the kernel body structure.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["lif_step_fused", "lif_step_fused_int"]

_BLOCK = (256, 256)


def _lif_kernel_f32(v_ref, i_ref, o_v_ref, o_s_ref, *, threshold, leak, soft_reset):
    v = v_ref[...]
    if leak != 1.0:
        v = v * leak
    v = v + i_ref[...]
    s = (v >= threshold).astype(v.dtype)
    if soft_reset:
        v_next = v - s * threshold
    else:
        v_next = v * (1.0 - s)
    o_v_ref[...] = v_next
    o_s_ref[...] = s


def _lif_kernel_int(
    v_ref, i_ref, o_v_ref, o_s_ref, *, threshold, leak_shift, soft_reset, v_min, v_max
):
    v = v_ref[...]
    if leak_shift > 0:
        v = v - (v >> leak_shift)
    v = jnp.clip(v + i_ref[...], v_min, v_max)
    s = (v >= threshold).astype(jnp.int32)
    if soft_reset:
        v_next = jnp.clip(v - s * threshold, v_min, v_max)
    else:
        v_next = v * (1 - s)
    o_v_ref[...] = v_next
    o_s_ref[...] = s


def _tiled_call(kernel, v, i, out_dtypes, interpret):
    """Run an elementwise 2-output kernel over a 2D-tiled view of v/i."""
    orig_shape = v.shape
    flat = v.reshape(-1)
    n = flat.shape[0]
    bm, bn = _BLOCK
    cols = bn
    rows = -(-n // cols)
    pad = rows * cols - n
    v2 = jnp.pad(v.reshape(-1), (0, pad)).reshape(rows, cols)
    i2 = jnp.pad(i.reshape(-1), (0, pad)).reshape(rows, cols)
    pad_r = -rows % bm
    v2 = jnp.pad(v2, ((0, pad_r), (0, 0)))
    i2 = jnp.pad(i2, ((0, pad_r), (0, 0)))
    grid = (v2.shape[0] // bm,)

    v_out, s_out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, cols), lambda r: (r, 0)),
            pl.BlockSpec((bm, cols), lambda r: (r, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, cols), lambda r: (r, 0)),
            pl.BlockSpec((bm, cols), lambda r: (r, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(v2.shape, out_dtypes[0]),
            jax.ShapeDtypeStruct(v2.shape, out_dtypes[1]),
        ],
        interpret=interpret,
    )(v2, i2)
    v_out = v_out.reshape(-1)[:n].reshape(orig_shape)
    s_out = s_out.reshape(-1)[:n].reshape(orig_shape)
    return v_out, s_out


@functools.partial(
    jax.jit, static_argnames=("threshold", "leak", "soft_reset", "interpret")
)
def lif_step_fused(
    v: jax.Array,
    current: jax.Array,
    threshold: float = 1.0,
    leak: float = 1.0,
    soft_reset: bool = False,
    interpret: bool = False,
):
    """Float fused neuron step. leak=1.0 -> IF; leak<1 -> LIF."""
    kernel = functools.partial(
        _lif_kernel_f32, threshold=threshold, leak=leak, soft_reset=soft_reset
    )
    return _tiled_call(kernel, v, current, (v.dtype, v.dtype), interpret)


@functools.partial(
    jax.jit,
    static_argnames=("threshold", "leak_shift", "soft_reset", "vmem_bits", "interpret"),
)
def lif_step_fused_int(
    v: jax.Array,
    partial_vmem: jax.Array,
    threshold: int,
    leak_shift: int = 0,
    soft_reset: bool = False,
    vmem_bits: int = 7,
    interpret: bool = False,
):
    """Integer fused neuron step, bit-exact with neuron_step_int."""
    v_min, v_max = -(1 << (vmem_bits - 1)), (1 << (vmem_bits - 1)) - 1
    kernel = functools.partial(
        _lif_kernel_int,
        threshold=threshold,
        leak_shift=leak_shift,
        soft_reset=soft_reset,
        v_min=v_min,
        v_max=v_max,
    )
    return _tiled_call(
        kernel, v.astype(jnp.int32), partial_vmem.astype(jnp.int32),
        (jnp.int32, jnp.int32), interpret,
    )
