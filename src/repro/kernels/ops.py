"""Public jit'd entry points for the Pallas kernels.

On a CPU host (this container) the kernels execute in interpret mode; on a
real TPU they compile to Mosaic.  ``on_tpu()`` picks automatically, and the
layers/models call these wrappers so the backend choice is transparent.
"""
from __future__ import annotations

import jax

from .lif_step import lif_step_fused, lif_step_fused_int
from .quant_matmul import pack_int4, quant_matmul, unpack_int4  # noqa: F401
from .spike_gemm import spike_gemm
from .wkv_chunk import wkv_chunk, wkv_sequence  # noqa: F401

__all__ = [
    "on_tpu",
    "spike_gemm_op",
    "lif_step_op",
    "lif_step_int_op",
    "quant_matmul_op",
    "pack_int4",
    "unpack_int4",
    "wkv_sequence_op",
]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return not on_tpu()


def spike_gemm_op(spikes, weights, block=(128, 128, 128), skip_empty=True):
    return spike_gemm(
        spikes, weights, block=block, interpret=_interpret(), skip_empty=skip_empty
    )


def lif_step_op(v, current, threshold=1.0, leak=1.0, soft_reset=False):
    return lif_step_fused(
        v, current, threshold=threshold, leak=leak, soft_reset=soft_reset,
        interpret=_interpret(),
    )


def lif_step_int_op(v, partial, threshold, leak_shift=0, soft_reset=False, vmem_bits=7):
    return lif_step_fused_int(
        v, partial, threshold, leak_shift=leak_shift, soft_reset=soft_reset,
        vmem_bits=vmem_bits, interpret=_interpret(),
    )


def quant_matmul_op(x, w_q, scale, bits=8, block=(128, 128, 256)):
    return quant_matmul(x, w_q, scale, bits=bits, block=block, interpret=_interpret())


def wkv_sequence_op(r, k, v, lw, u, s0, chunk=32):
    """RWKV6 wkv over a sequence via the Pallas chunk kernel.

    The jnp reference for this kernel is models.rwkv6._wkv_chunked (used as
    the default path and as the test oracle).
    """
    return wkv_sequence(r, k, v, lw, u, s0, chunk=chunk, interpret=_interpret())
