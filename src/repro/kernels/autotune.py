"""Kernel autotuner: pick (block_m, block_n, block_k, T_blk) per layer.

The fused T_blk kernel has a small configuration space whose optimum
depends on the layer's GEMM shape and the precision pair — a 32-wide fc
head wants small output tiles, a 16k-row conv im2col wants the full MXU
block, and the profitable T_blk grows with how much weight traffic a
timestep amortizes.  Rather than hard-coding heuristics, the autotuner
*measures*: it runs each candidate config on synthetic spikes at a
representative sparsity and keeps the fastest.

Results are cached keyed by ``(rows, fan_in, channels, W_b, V_b)`` — the
shape+precision signature that determines kernel behavior — so a network
with repeated layer shapes tunes each shape once, and a JSON disk cache
(``SPIDR_AUTOTUNE_CACHE`` or an explicit path) persists winners across
processes.  ``spidr.compile(..., DeployTarget(autotune=True))`` consults
this module per weight layer and bakes the winner into the engine as
``EngineLayer.kcfg``.

The sweep is deliberately small (a few block shapes x a few T_blk values):
every candidate is bit-exact — the tuner only chooses among equivalent
schedules, so a bad pick costs time, never correctness.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .fused_lif_gemm import DEFAULT_BLOCK, fused_lif_gemm_int_tblk

__all__ = [
    "KernelConfig",
    "autotune_layer",
    "cache_key",
    "clear_cache",
    "load_cache",
    "save_cache",
]

CACHE_ENV = "SPIDR_AUTOTUNE_CACHE"

# Process-wide winner cache: key -> KernelConfig.
_MEMORY_CACHE: dict = {}


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """One point of the tuning space: GEMM block + timestep tile."""

    block_m: int = DEFAULT_BLOCK[0]
    block_n: int = DEFAULT_BLOCK[1]
    block_k: int = DEFAULT_BLOCK[2]
    t_block: int = 1

    @property
    def block(self) -> tuple:
        return (self.block_m, self.block_n, self.block_k)

    @property
    def kcfg(self) -> tuple:
        """The ``EngineLayer.kcfg`` tuple form."""
        return (self.block_m, self.block_n, self.block_k, self.t_block)


def cache_key(rows: int, fan_in: int, channels: int,
              weight_bits: int, vmem_bits: int) -> str:
    """Shape+precision signature a tuned config is valid for."""
    return f"r{rows}_f{fan_in}_c{channels}_w{weight_bits}_v{vmem_bits}"


def clear_cache() -> None:
    _MEMORY_CACHE.clear()


def load_cache(path) -> dict:
    """Load a JSON winner cache into the in-memory cache (merging)."""
    data = json.loads(pathlib.Path(path).read_text())
    loaded = {k: KernelConfig(*v) for k, v in data.items()}
    _MEMORY_CACHE.update(loaded)
    return loaded


def save_cache(path) -> None:
    """Persist the in-memory winner cache as JSON."""
    data = {k: list(v.kcfg) for k, v in sorted(_MEMORY_CACHE.items())}
    pathlib.Path(path).write_text(json.dumps(data, indent=2) + "\n")


def _default_candidates(rows: int, fan_in: int, channels: int,
                        timesteps: int) -> list:
    """A small, shape-clipped sweep.

    Block sizes above the (padded) dimension only waste padding work, so
    candidates clip to the next power-of-two cover of each dimension; the
    T_blk axis sweeps 1 (the scan-equivalent schedule) up to the full
    sample depth.
    """
    def cover(dim, opts):
        kept = [o for o in opts if o < 2 * dim] or [opts[0]]
        return kept

    blocks = []
    for bm in cover(rows, (32, 128)):
        for bn in cover(channels, (32, 128)):
            for bk in cover(fan_in, (32, 128)):
                blocks.append((bm, bn, bk))
    tbs = sorted({1, 2, min(4, timesteps), timesteps})
    return [KernelConfig(bm, bn, bk, tb)
            for (bm, bn, bk) in blocks for tb in tbs if tb >= 1]


def _time_candidate(cand: KernelConfig, spikes, weights, v0, threshold,
                    vmem_bits: int, interpret: bool, skip_empty: bool,
                    repeats: int) -> float:
    """Median wall seconds for one chunk under ``cand``'s schedule."""
    t = spikes.shape[0]

    def run():
        v = v0
        outs = []
        for t0 in range(0, t, cand.t_block):
            v_traj, s = fused_lif_gemm_int_tblk(
                spikes[t0:t0 + cand.t_block], weights, v,
                threshold=threshold, vmem_bits=vmem_bits,
                block=cand.block, interpret=interpret,
                skip_empty=skip_empty,
            )
            v = v_traj[-1]
            outs.append(s)
        return v, outs[-1]

    v, s = run()   # warmup: compile/trace outside the timed region
    jax.block_until_ready((v, s))
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(run())
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def autotune_layer(
    rows: int,
    fan_in: int,
    channels: int,
    weight_bits: int,
    vmem_bits: int,
    *,
    timesteps: int = 8,
    sparsity: float = 0.9,
    interpret: bool = True,
    skip_empty: bool = True,
    candidates: Optional[list] = None,
    cache_path=None,
    repeats: int = 1,
    seed: int = 0,
) -> KernelConfig:
    """Measure and cache the fastest kernel config for one layer shape.

    ``rows``/``fan_in``/``channels`` are the layer's GEMM dimensions
    (M/K/N); ``timesteps`` and ``sparsity`` shape the synthetic sample the
    candidates race on.  Returns the cached winner when the
    shape+precision key was tuned before (in this process, or in the JSON
    cache at ``cache_path`` / ``$SPIDR_AUTOTUNE_CACHE``).
    """
    key = cache_key(rows, fan_in, channels, weight_bits, vmem_bits)
    if key in _MEMORY_CACHE:
        return _MEMORY_CACHE[key]
    if cache_path is None:
        cache_path = os.environ.get(CACHE_ENV)
    if cache_path and pathlib.Path(cache_path).exists():
        load_cache(cache_path)
        if key in _MEMORY_CACHE:
            return _MEMORY_CACHE[key]

    rng = np.random.default_rng(seed)
    spikes = jnp.asarray(
        (rng.random((timesteps, rows, fan_in)) > sparsity).astype(np.int8))
    w_max = (1 << (weight_bits - 1)) - 1
    weights = jnp.asarray(
        rng.integers(-w_max - 1, w_max + 1, (fan_in, channels)), jnp.int8)
    v0 = jnp.zeros((rows, channels), jnp.int32)
    threshold = max(1, (1 << (vmem_bits - 2)))

    if candidates is None:
        candidates = _default_candidates(rows, fan_in, channels, timesteps)
    best, best_t = None, float("inf")
    for cand in candidates:
        dt = _time_candidate(cand, spikes, weights, v0, threshold,
                             vmem_bits, interpret, skip_empty, repeats)
        if dt < best_t:
            best, best_t = cand, dt
    _MEMORY_CACHE[key] = best
    if cache_path:
        save_cache(cache_path)
    return best
