"""Pallas TPU kernels transferring SpiDR's hardware insights to the MXU.

Every kernel runs on CPU under ``interpret=True`` (required off-TPU: the
revisited-accumulator k grid is only sequential on TPU hardware) and
compiles to Mosaic on TPU unchanged; ``ref.py`` holds the pure-jnp oracles
each kernel is tested bit-exact (int) or allclose (float) against.

  spike_gemm      zero-skip binary-activation GEMM (compute macro, C1+C3)
  lif_step        neuron-macro leak/threshold/reset as one VPU pass (C8)
  fused_lif_gemm  both phases fused: Vmem stays VMEM-resident between
                  accumulation and fire — the chip's defining property
  quant_matmul    weight-quantized GEMM for the LM serving path (non-SNN)
  wkv_chunk       chunked WKV scan (non-SNN, RWKV serving path)

``docs/kernels.md`` documents contracts, block-size constraints and the
interpret-mode rules.
"""
