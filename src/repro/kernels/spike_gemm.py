"""Pallas TPU kernel: spike-driven GEMM with tile-level zero-skipping.

TPU adaptation of SpiDR's CIM weight->Vmem accumulation (paper C1+C3).
The silicon processes one spike event per 2 cycles, adding one weight row
into a Vmem row pair.  On a systolic-array machine the same computation is
a binary-activation integer GEMM

    Vmem[m, n] = sum_k S[m, k] * W[k, n],   S in {0,1}

and the zero-skipping insight transfers at *tile* granularity: a
(block_m x block_k) spike tile that is entirely zero contributes nothing,
so the kernel skips the MXU dot for it (``@pl.when``).  At SNN sparsity
levels (60-99 %, Fig 5) a large fraction of tiles is empty, especially for
the small fan-in tiles that mirror the 128-row macro chunks.

Layout:
  grid = (M/bm, N/bn, K/bk), k innermost (sequential on TPU, so the f32/i32
  accumulation into the output block is the standard revisiting pattern).
  Weights are stationary per (n, k) block — the weight-stationary mapping
  of Sec II-E — and spikes stream through VMEM.

Block shapes default to MXU-aligned (128, 128); int8 operands use the
native int8 MXU path with int32 accumulation (B_Vmem ~ 2*B_w insight: the
accumulator is always wider than the operands).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["spike_gemm", "DEFAULT_BLOCK"]

# Skip-decision strategies for the empty-tile test:
#   "reduce" — in-kernel ``jnp.any`` over the loaded spike tile (original);
#   "bitmap" — host-prologue per-tile bitmap operand (no load-then-test:
#              the flag is one int32 read, and the same bitmap feeds the
#              roofline PerfModel's MACs-at-sparsity term).
SKIP_MODES = ("reduce", "bitmap")

DEFAULT_BLOCK = (128, 128, 128)  # (bm, bn, bk)


def _spike_gemm_kernel(s_ref, w_ref, o_ref, *, n_k: int):
    """One (m, n, k) grid step: o += s_tile @ w_tile, skipping empty tiles."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    s_tile = s_ref[...]
    # Tile-level zero skip: the S2A analogue. nnz==0 -> no MXU work issued.
    tile_has_spikes = jnp.any(s_tile != 0)

    @pl.when(tile_has_spikes)
    def _accumulate():
        o_ref[...] += jax.lax.dot_general(
            s_tile.astype(jnp.int32),
            w_ref[...].astype(jnp.int32),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
    del n_k


def _spike_gemm_bitmap_kernel(s_ref, w_ref, bm_ref, o_ref, *, n_k: int):
    """Skip decision from a host-computed per-tile bitmap operand."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(bm_ref[0, 0] != 0)
    def _accumulate():
        o_ref[...] += jax.lax.dot_general(
            s_ref[...].astype(jnp.int32),
            w_ref[...].astype(jnp.int32),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
    del n_k


@functools.partial(
    jax.jit,
    static_argnames=("block", "interpret", "skip_empty", "skip_mode"),
)
def spike_gemm(
    spikes: jax.Array,   # (M, K) in {0,1}, any int/bool dtype
    weights: jax.Array,  # (K, N) int8
    block: tuple = DEFAULT_BLOCK,
    interpret: bool = False,
    skip_empty: bool = True,
    skip_mode: str = "reduce",
) -> jax.Array:
    """Vmem partials = spikes @ weights, int32. Pads to block multiples.

    ``skip_mode`` picks how empty tiles are detected when ``skip_empty``:
    ``"reduce"`` tests the loaded tile in-kernel, ``"bitmap"`` reads a
    host-prologue per-tile bitmap (see ``SKIP_MODES``).  Both are bit-exact;
    they differ only in where the skip decision is made.
    """
    assert spikes.ndim == 2 and weights.ndim == 2
    assert skip_mode in SKIP_MODES, (skip_mode, SKIP_MODES)
    m, k = spikes.shape
    k2, n = weights.shape
    assert k == k2, (spikes.shape, weights.shape)
    bm, bn, bk = block

    pad_m, pad_n, pad_k = -m % bm, -n % bn, -k % bk
    s = jnp.pad(spikes.astype(jnp.int8), ((0, pad_m), (0, pad_k)))
    w = jnp.pad(weights.astype(jnp.int8), ((0, pad_k), (0, pad_n)))
    gm, gn, gk = s.shape[0] // bm, w.shape[1] // bn, s.shape[1] // bk

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
    ]
    operands = [s, w]
    if not skip_empty:
        kernel = functools.partial(_dense_kernel, n_k=gk)
    elif skip_mode == "bitmap":
        kernel = functools.partial(_spike_gemm_bitmap_kernel, n_k=gk)
        tiles = s.reshape(gm, bm, gk, bk)
        operands.append(jnp.any(tiles != 0, axis=(1, 3)).astype(jnp.int32))
        in_specs.append(pl.BlockSpec((1, 1), lambda i, j, kk: (i, kk)))
    else:
        kernel = functools.partial(_spike_gemm_kernel, n_k=gk)

    out = pl.pallas_call(
        kernel,
        grid=(gm, gn, gk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((s.shape[0], w.shape[1]), jnp.int32),
        interpret=interpret,
    )(*operands)
    return out[:m, :n]


def _dense_kernel(s_ref, w_ref, o_ref, *, n_k: int):
    """Baseline without zero-skipping (for the ablation benchmark)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jax.lax.dot_general(
        s_ref[...].astype(jnp.int32),
        w_ref[...].astype(jnp.int32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    del n_k
