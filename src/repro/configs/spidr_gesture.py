"""The paper's gesture-recognition SNN (Table II)."""
from ..core.network import gesture_net

CONFIG = gesture_net()
