"""The paper's gesture-recognition SNN (Table II)."""
import dataclasses

from ..core.network import SNNSpec, gesture_net

CONFIG = gesture_net()


def reduced(hw: tuple = (32, 32), timesteps: int = 6) -> SNNSpec:
    """CPU-sized variant for serving demos / CI: same topology, smaller
    frames and fewer timesteps (the FC fan-in is fixed by the adaptive
    pool, so any multiple-of-8 ``hw`` works)."""
    # Two stride-2 pools then an adaptive pool to 2x2: hw/4 must be an even
    # number >= 2, i.e. hw divisible by 8.
    assert hw[0] % 8 == 0 and hw[1] % 8 == 0, f"hw must be multiples of 8: {hw}"
    return dataclasses.replace(CONFIG, input_hw=hw, timesteps=timesteps)
