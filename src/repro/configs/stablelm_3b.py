"""StableLM-3B: dense, MHA (kv=32). [hf:stabilityai/stablelm-2 family]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-3b", family="dense",
    n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=6912, vocab_size=50304, head_dim=80,
    qkv_bias=False, rope_theta=1e4, ffn_variant="swiglu",
    source="hf:stabilityai/stablelm-2-1_6b (3B scaling; unverified tier)",
)
