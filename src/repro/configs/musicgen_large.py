"""MusicGen-large backbone: decoder-only over EnCodec tokens.

Modality frontend (EnCodec) is a STUB per the assignment: input_specs()
provides precomputed frame embeddings (B, S, D). [arXiv:2306.05284]
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=2048, head_dim=64,
    ffn_variant="gelu", embed_inputs=False,
    source="arXiv:2306.05284",
)
