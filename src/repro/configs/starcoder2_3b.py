"""StarCoder2-3B: dense, GQA kv=2, RoPE, GELU MLP. [arXiv:2402.19173]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-3b", family="dense",
    n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2,
    d_ff=12288, vocab_size=49152, head_dim=128,
    qkv_bias=True, rope_theta=1e5, ffn_variant="gelu",
    source="arXiv:2402.19173",
)
