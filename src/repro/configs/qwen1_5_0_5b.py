"""Qwen1.5-0.5B: dense, MHA (GQA kv=16), QKV bias. [hf:Qwen/Qwen1.5-0.5B]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-0.5b", family="dense",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=2816, vocab_size=151936, head_dim=64,
    qkv_bias=True, rope_theta=1e6, ffn_variant="swiglu",
    tie_embeddings=True,  # Qwen1.5-0.5B ties input/output embeddings
    source="hf:Qwen/Qwen1.5-0.5B",
)
