"""Chameleon-34B backbone: early-fusion VLM over VQ image tokens.

qk_norm enabled (required for Chameleon training stability per the paper).
Patch/VQ frontend is a STUB: input_specs() provides precomputed embeddings.
[arXiv:2405.09818; unverified tier]
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22016, vocab_size=65536, head_dim=128,
    qk_norm=True, ffn_variant="swiglu", embed_inputs=False,
    source="arXiv:2405.09818",
)
