"""Architecture configuration system + registry.

Every assigned architecture is a module in this package exporting
``CONFIG`` (exact published numbers) — selectable via ``--arch <id>`` in
the launchers.  ``reduced()`` derives the same-family small config used by
the per-arch CPU smoke tests; full configs are only ever lowered abstractly
(ShapeDtypeStruct) by the dry-run.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["ArchConfig", "ShapeSpec", "SHAPES", "get_config", "list_archs"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


# The four assigned LM shape cells.
SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads

    # Attention options
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0

    # MoE
    n_experts: int = 0
    top_k: int = 0
    # d_ff is the PER-EXPERT hidden size for MoE families.

    # SSM / RWKV / hybrid
    ssm_state: int = 0             # Mamba2 N (zamba2) / rwkv head size
    attn_period: int = 0           # zamba2: shared attn block every N slots
    expand: int = 2                # mamba2 d_inner = expand * d_model

    # Modality frontend stub: inputs are precomputed embeddings, not ids.
    embed_inputs: bool = True      # False -> input_specs gives (B,S,D) embeds

    # Long-context capability (sub-quadratic): rwkv6, zamba2.
    sub_quadratic: bool = False

    # Norm/act details
    ffn_variant: str = "swiglu"    # "swiglu" (3 mats) | "gelu" (2 mats)
    rmsnorm_eps: float = 1e-5
    tie_embeddings: bool = False

    source: str = ""

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Megatron-style vocab padding to a TP-shardable multiple (128).

        Pad logits are masked to -inf inside forward/decode, so the loss
        and sampling are exactly those of the true vocab.
        """
        return -(-self.vocab_size // 128) * 128

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    def supports(self, shape: ShapeSpec) -> bool:
        if shape.name == "long_500k" and not self.sub_quadratic:
            return False
        return True

    def skip_reason(self, shape: ShapeSpec) -> Optional[str]:
        if not self.supports(shape):
            return (
                "pure full-attention arch: 500k-context requires sub-quadratic "
                "attention (DESIGN.md §4)"
            )
        return None

    def reduced(self) -> "ArchConfig":
        """Same-family tiny config for CPU smoke tests."""
        # Hybrids need >= 2 full (mamba..attn) groups + a tail to exercise
        # every code path; others use 2 layers.
        n_layers = 7 if self.family == "hybrid" else 2
        return dataclasses.replace(
            self,
            n_layers=n_layers,
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)) if self.n_kv_heads < self.n_heads else 4,
            head_dim=16,
            d_ff=128 if not self.n_experts else 32,
            vocab_size=256,
            n_experts=min(self.n_experts, 8) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            attn_period=min(self.attn_period, 3) if self.attn_period else 0,
        )

    def param_count(self) -> int:
        """Analytic parameter count (for MODEL_FLOPS = 6*N*D)."""
        d, ff, v, hd = self.d_model, self.d_ff, self.vocab_size, self.head_dim_
        nq, nkv = self.n_heads, self.n_kv_heads
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.family in ("ssm",):  # rwkv6
            per_layer = _rwkv6_layer_params(self)
            return emb + self.n_layers * per_layer
        if self.family == "hybrid":  # zamba2
            n_attn, n_mamba = _zamba2_counts(self)
            attn = _attn_params(self) + 2 * d * ff + d * ff  # shared block + mlp
            return emb + n_mamba * _mamba2_layer_params(self) + attn
        attn = _attn_params(self)
        ffn_mats = 3 if self.ffn_variant == "swiglu" else 2
        if self.n_experts:
            ffn = self.n_experts * ffn_mats * d * ff + d * self.n_experts
        else:
            ffn = ffn_mats * d * ff
        return emb + self.n_layers * (attn + ffn)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k experts only)."""
        if not self.n_experts:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        ffn_mats = 3 if self.ffn_variant == "swiglu" else 2
        total = self.param_count()
        all_experts = self.n_layers * self.n_experts * ffn_mats * d * ff
        active = self.n_layers * self.top_k * ffn_mats * d * ff
        return total - all_experts + active


def _attn_params(cfg: ArchConfig) -> int:
    d, hd = cfg.d_model, cfg.head_dim_
    return d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd + cfg.n_heads * hd * d


def _rwkv6_layer_params(cfg: ArchConfig) -> int:
    d = cfg.d_model
    # time-mix: r,k,v,g,w projections + output; channel-mix: 2 mats (d x ff)
    return 5 * d * d + d * d + 2 * d * cfg.d_ff


def _mamba2_layer_params(cfg: ArchConfig) -> int:
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    nh = di // 64
    return d * (2 * di + 2 * n + nh) + di * d  # in_proj(z,x,B,C,dt) + out_proj


def _zamba2_counts(cfg: ArchConfig):
    p = cfg.attn_period or 6
    n_attn_slots = cfg.n_layers // p
    return n_attn_slots, cfg.n_layers - n_attn_slots


_REGISTRY = {
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "starcoder2-3b": "starcoder2_3b",
    "qwen3-14b": "qwen3_14b",
    "stablelm-3b": "stablelm_3b",
    "rwkv6-7b": "rwkv6_7b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "musicgen-large": "musicgen_large",
    "chameleon-34b": "chameleon_34b",
    "zamba2-7b": "zamba2_7b",
    # the paper's own workloads (SNN; not LM shapes)
    "spidr-gesture": "spidr_gesture",
    "spidr-optflow": "spidr_optflow",
}


def list_archs(lm_only: bool = True):
    names = list(_REGISTRY)
    return [n for n in names if not n.startswith("spidr-")] if lm_only else names


def get_config(name: str):
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; available: {list(_REGISTRY)}")
    mod = importlib.import_module(f"repro.configs.{_REGISTRY[name]}")
    return mod.CONFIG


def input_specs(cfg: ArchConfig, shape: ShapeSpec, for_init: bool = False) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    b, s = shape.global_batch, shape.seq_len
    f32, i32, bf16 = jnp.float32, jnp.int32, jnp.bfloat16
    if shape.kind == "train":
        if cfg.embed_inputs:
            return {
                "tokens": jax.ShapeDtypeStruct((b, s), i32),
                "labels": jax.ShapeDtypeStruct((b, s), i32),
            }
        return {
            "embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), bf16),
            "labels": jax.ShapeDtypeStruct((b, s), i32),
        }
    if shape.kind == "prefill":
        if cfg.embed_inputs:
            return {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        return {"embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), bf16)}
    # decode: one new token against a seq_len-deep cache (built elsewhere).
    if cfg.embed_inputs:
        return {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}
    return {"embeds": jax.ShapeDtypeStruct((b, 1, cfg.d_model), bf16)}
