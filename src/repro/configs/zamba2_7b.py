"""Zamba2-7B: Mamba2 backbone + shared attention block. [arXiv:2411.15242]

81 layer slots; every 6th slot applies the SHARED attention+FFN block
(weights reused across applications), the rest are Mamba2 (ssm_state=64).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab_size=32000, head_dim=112,
    ssm_state=64, attn_period=6, expand=2,
    sub_quadratic=True,
    source="arXiv:2411.15242 (unverified tier)",
)
