from .base import SHAPES, ArchConfig, ShapeSpec, get_config, input_specs, list_archs  # noqa: F401
