"""The paper's optical-flow SNN (Table II)."""
import dataclasses

from ..core.network import SNNSpec, optical_flow_net

CONFIG = optical_flow_net()


def reduced(hw: tuple = (24, 32), timesteps: int = 4) -> SNNSpec:
    """CPU-sized variant for serving demos / CI: the all-conv stack is
    shape-agnostic, so only the frame size and timestep count shrink."""
    return dataclasses.replace(CONFIG, input_hw=hw, timesteps=timesteps)
