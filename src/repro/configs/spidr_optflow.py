"""The paper's optical-flow SNN (Table II)."""
from ..core.network import optical_flow_net

CONFIG = optical_flow_net()
