"""Overflow certification: abstract interpretation over the compiler IR.

The integer datapath has exactly one wide accumulation: the spike GEMM
``acc = dot(spikes.int32, w.int32)`` computed at int32 before its single
saturation into the (2W-1)-bit Vmem field (``engine/inference.py``,
``kernels/fused_lif_gemm.py``; see ``core/quant.sat_add``).  Everything
after that point is arithmetic on saturated (2W-1)-bit values whose
interim magnitudes are structurally bounded:

  * GEMM (pre-saturation)  : inputs are binary spikes, weights are
    ``[w_min, w_max]`` integers, so over a fan-in of F active inputs the
    accumulator lies in ``[F*w_min, F*w_max]`` — it can never wrap iff
    ``F * 2^(W-1) <= acc_max``.
  * leak ``v - (v >> k)``  : shrinks ``|v|`` (arithmetic shift rounds
    toward -inf, so the subtracted term has v's sign) — stays in
    ``[v_min, v_max]``.
  * accumulate ``v + partial`` : both operands saturated, so the interim
    sum lies in ``[2*v_min, 2*v_max]`` before re-saturation.
  * threshold              : ``requantize_threshold`` clips ``thr_int``
    into ``[v_min, v_max + 1]``.
  * soft reset ``v - s*thr`` : interim in ``[v_min - (v_max+1),
    v_max - v_min]`` before re-saturation.

This pass propagates those ranges per weight layer of a network (an
:class:`~repro.compiler.ir.NetworkGraph` or the :class:`SNNSpec` it is
built from) and per :class:`QuantSpec`, and emits a *machine-checkable*
certificate: plain JSON holding the primitive facts (fan-in, precision,
accumulator width) and every derived bound, which
:func:`check_certificate` re-derives independently — a tampered or stale
certificate fails re-verification, not just inspection.

``acc_bits`` parameterizes the accumulator width (the silicon's is 32).
Narrower widths are how the negative path is exercised honestly: the
gesture network certifies at 32 bits but provably wraps at 16 — see
``docs/analysis.md``.
"""
from __future__ import annotations

from typing import Union

from ..compiler.ir import LayerNode, NetworkGraph, build_graph
from ..core.network import SNNSpec
from ..core.quant import QuantSpec
from .report import AnalysisReport, Violation

__all__ = [
    "certify_overflow",
    "check_certificate",
    "layer_overflow_facts",
]

#: The silicon's wide-accumulator width (int32 throughout the engine).
DEFAULT_ACC_BITS = 32


def _acc_max(acc_bits: int) -> int:
    return (1 << (acc_bits - 1)) - 1


def layer_overflow_facts(node_idx: int, kind: str, fan_in: int,
                         out_channels: int, qspec: QuantSpec,
                         acc_bits: int = DEFAULT_ACC_BITS) -> dict:
    """Derived integer ranges for one weight layer at one precision.

    Pure arithmetic on the primitive facts — shared by the certifier and
    by :func:`check_certificate`'s independent re-derivation.
    """
    acc_max = _acc_max(acc_bits)
    w_abs_max = 1 << (qspec.weight_bits - 1)          # |w_min| >= w_max
    # Pre-saturation GEMM range over F simultaneously-active binary inputs.
    acc_lo, acc_hi = fan_in * qspec.w_min, fan_in * qspec.w_max
    gemm_bound = fan_in * w_abs_max
    gemm_ok = gemm_bound <= acc_max
    # Smallest count of simultaneously-active inputs that can wrap.
    min_violating = None if gemm_ok else acc_max // w_abs_max + 1
    # Post-saturation neuron-step interims (leak keeps [v_min, v_max];
    # accumulate doubles it; soft reset subtracts thr_int <= v_max + 1).
    interim_max = max(2 * abs(qspec.v_min), 2 * qspec.v_max,
                      abs(qspec.v_min - (qspec.v_max + 1)),
                      qspec.v_max - qspec.v_min)
    neuron_ok = interim_max <= acc_max
    return {
        "node": node_idx,
        "kind": kind,
        "fan_in": fan_in,
        "out_channels": out_channels,
        "w_lo": qspec.w_min,
        "w_hi": qspec.w_max,
        "acc_lo": acc_lo,
        "acc_hi": acc_hi,
        "acc_headroom": acc_max - gemm_bound,
        "saturated_lo": qspec.v_min,
        "saturated_hi": qspec.v_max,
        "threshold_lo": qspec.v_min,
        "threshold_hi": qspec.v_max + 1,
        "neuron_interim_max": interim_max,
        "gemm_ok": gemm_ok,
        "neuron_ok": neuron_ok,
        "ok": gemm_ok and neuron_ok,
        "min_violating_active_inputs": min_violating,
    }


def _graph_of(network: Union[SNNSpec, NetworkGraph]) -> NetworkGraph:
    if isinstance(network, NetworkGraph):
        return network
    if isinstance(network, SNNSpec):
        return build_graph(network)
    raise TypeError(
        f"certify_overflow() takes an SNNSpec or a compiler NetworkGraph, "
        f"got {type(network).__name__}")


def certify_overflow(network: Union[SNNSpec, NetworkGraph],
                     qspec: QuantSpec,
                     acc_bits: int = DEFAULT_ACC_BITS) -> AnalysisReport:
    """Certify that the wide accumulator can never wrap pre-saturation.

    Walks every weight layer of ``network`` and propagates the integer
    value ranges above.  Returns an :class:`AnalysisReport` whose
    ``certificates["overflow"]`` is the machine-checkable certificate and
    whose violations pinpoint each offending layer with the minimal
    violating number of simultaneously-active inputs.
    """
    graph = _graph_of(network)
    acc_max = _acc_max(acc_bits)
    layers = []
    violations = []
    for node in graph.weight_nodes:
        assert isinstance(node, LayerNode) and node.shape is not None
        facts = layer_overflow_facts(node.idx, node.kind, node.shape.fan_in,
                                     node.shape.out_channels, qspec, acc_bits)
        layers.append(facts)
        loc = f"{graph.name}.L{node.idx}"
        if not facts["gemm_ok"]:
            w_abs = 1 << (qspec.weight_bits - 1)
            violations.append(Violation(
                pass_name="overflow", code="OVF001", location=loc,
                message=(
                    f"int{acc_bits} accumulator can wrap before its single "
                    f"saturation point: fan_in {node.shape.fan_in} x |w|_max "
                    f"{w_abs} = {node.shape.fan_in * w_abs} exceeds "
                    f"{acc_max}; any {facts['min_violating_active_inputs']} "
                    f"simultaneously-active inputs overflows at "
                    f"{qspec.weight_bits}/{qspec.vmem_bits}-bit precision")))
        if not facts["neuron_ok"]:
            violations.append(Violation(
                pass_name="overflow", code="OVF002", location=loc,
                message=(
                    f"neuron-step interim |v| can reach "
                    f"{facts['neuron_interim_max']} > int{acc_bits} max "
                    f"{acc_max} at {qspec.weight_bits}/{qspec.vmem_bits}-bit "
                    "precision — the post-saturation datapath itself wraps")))
    certificate = {
        "pass": "overflow",
        "network": graph.name,
        "weight_bits": qspec.weight_bits,
        "vmem_bits": qspec.vmem_bits,
        "acc_bits": acc_bits,
        "acc_max": acc_max,
        "saturation_points": 1,
        "layers": layers,
        "ok": all(f["ok"] for f in layers),
        # Advisory (not a datapath hazard): the engine's per-stream readout
        # accumulator is also int32; a rate readout adds at most one spike
        # per class per timestep, so it cannot wrap before acc_max
        # timesteps — far beyond any stream the serving tier admits.
        "readout_wrap_horizon_timesteps": acc_max,
    }
    return AnalysisReport(
        subject=f"{graph.name}@{qspec.weight_bits}/{qspec.vmem_bits}b",
        passes=("overflow",),
        violations=tuple(violations),
        certificates={"overflow": certificate},
    )


def check_certificate(certificate: dict) -> list:
    """Independently re-verify an overflow certificate.

    Re-derives every bound from the certificate's primitive facts alone
    (fan-in, weight_bits, acc_bits) and compares against the stored
    values.  Returns the list of discrepancies — empty means the
    certificate is arithmetically sound, tampered/stale certificates name
    the first field that fails.
    """
    problems = []
    try:
        qspec = QuantSpec(certificate["weight_bits"])
    except (KeyError, ValueError) as e:
        return [f"certificate has no valid weight_bits: {e}"]
    acc_bits = certificate.get("acc_bits", DEFAULT_ACC_BITS)
    if certificate.get("acc_max") != _acc_max(acc_bits):
        problems.append(
            f"acc_max {certificate.get('acc_max')} != 2^{acc_bits - 1}-1")
    if certificate.get("vmem_bits") != qspec.vmem_bits:
        problems.append(
            f"vmem_bits {certificate.get('vmem_bits')} breaks the "
            f"B_vmem = 2*B_w - 1 invariant (expected {qspec.vmem_bits})")
    ok_all = True
    for stored in certificate.get("layers", ()):
        derived = layer_overflow_facts(
            stored.get("node", -1), stored.get("kind", "?"),
            stored.get("fan_in", 0), stored.get("out_channels", 0),
            qspec, acc_bits)
        ok_all = ok_all and derived["ok"]
        for field, want in derived.items():
            if stored.get(field) != want:
                problems.append(
                    f"layer L{stored.get('node')}: {field} is "
                    f"{stored.get(field)!r}, re-derivation gives {want!r}")
    if certificate.get("ok") != ok_all:
        problems.append(
            f"certificate ok={certificate.get('ok')!r} but re-derivation "
            f"gives {ok_all}")
    return problems
