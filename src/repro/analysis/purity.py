"""Purity / jit-safety lint: the repo-wide AST pass.

JAX traces a jitted function once and replays the jaxpr; anything the
Python body reads besides its arguments is frozen at trace time.  The
engine is built on that contract — this pass checks the whole source
tree stays inside it:

  * **PUR001** a jit-context function calls a host-side impure API —
    wall clocks (``time.*``), host randomness (``random.*``,
    ``numpy.random.*``, ``os.urandom``, ``secrets``/``uuid``) or
    ``datetime`` — whose value would be baked into the trace.
    ``jax.random`` is functional and explicitly safe; import aliases are
    resolved so ``from jax import random`` doesn't trip the stdlib rule.
  * **PUR002** a jit-context function reads a module-level *mutable*
    global (a ``list``/``dict``/``set`` binding): its contents at trace
    time silently become compile-time constants.
  * **PUR003** an integer-engine function (name ending ``_int`` — the
    bit-exact datapath convention) contains float arithmetic: a true
    division, a float literal, a float dtype reference or a ``float()``
    cast.  The integer engine must be closed under integer ops to stay
    bit-identical with the silicon.
  * **PUR004** a *leafless* pytree registration — flatten of the form
    ``lambda s: ((), s)``, which makes the whole object static/hashable
    trace metadata — of a class that is not a frozen dataclass with
    (recursively) immutable fields.  A mutable leafless pytree breaks
    jit caching: equal-looking schedules hash differently, or worse,
    mutate after being baked into a trace.

Jit contexts are found syntactically: functions decorated with
``jax.jit`` (bare or under ``functools.partial``) plus same-module
functions whose *names* are passed into a ``jax.jit(...)`` call
(covering the ``self._step = jax.jit(step)`` idiom in the streaming
engine).  The pass is deliberately intra-module — no cross-module call
graph — which keeps it fast and its findings exact.
"""
from __future__ import annotations

import ast
import dataclasses
import os
from typing import Dict, Iterable, List, Optional

from .report import AnalysisReport, Violation

__all__ = [
    "check_purity",
    "check_module_purity",
]

#: Dotted-call prefixes whose results are host-side entropy or wall time.
IMPURE_PREFIXES = (
    "time.",
    "random.",
    "numpy.random.",
    "datetime.",
    "secrets.",
    "uuid.",
)
IMPURE_EXACT = ("os.urandom",)

#: Explicitly functional/safe namespaces (checked before the impure list).
SAFE_PREFIXES = ("jax.random.", "jax.")

_FLOAT_DTYPES = ("float16", "float32", "float64", "bfloat16")
_IMMUTABLE_NAMES = {
    "int", "float", "str", "bool", "bytes", "complex", "tuple", "Tuple",
    "frozenset", "FrozenSet", "None", "NoneType", "object", "Ellipsis",
}
_MUTABLE_NAMES = {
    "list", "List", "dict", "Dict", "set", "Set", "bytearray",
    "defaultdict", "OrderedDict", "deque", "Counter",
}
#: Generic wrappers whose type arguments carry the mutability question.
_TRANSPARENT_GENERICS = {
    "tuple", "Tuple", "Optional", "Union", "frozenset", "FrozenSet",
    "ClassVar", "Final",
}


def _dotted(node: ast.AST) -> Optional[str]:
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _resolve(dotted: Optional[str], aliases: Dict[str, str]) -> Optional[str]:
    """Rewrite a dotted name's first segment through the import aliases."""
    if dotted is None:
        return None
    head, _, rest = dotted.partition(".")
    real = aliases.get(head, head)
    return f"{real}.{rest}" if rest else real


def _import_aliases(tree: ast.Module) -> Dict[str, str]:
    aliases: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                aliases[a.asname or a.name.partition(".")[0]] = (
                    a.name if a.asname else a.name.partition(".")[0])
        elif isinstance(node, ast.ImportFrom):
            # Relative imports resolve inside the package — they can never
            # be the stdlib entropy/time modules, so prefix with "." to
            # keep them out of the impure namespace.
            base = ("." * node.level) + (node.module or "")
            for a in node.names:
                if a.name == "*":
                    continue
                aliases[a.asname or a.name] = (
                    f"{base}.{a.name}" if base else a.name)
    return aliases


# ---------------------------------------------------------------------------
# Jit-context discovery.
# ---------------------------------------------------------------------------
def _is_jit(dotted: Optional[str], aliases: Dict[str, str]) -> bool:
    return _resolve(dotted, aliases) in ("jax.jit", "jax.pmap")


def _decorated_jit(fn: ast.AST, aliases: Dict[str, str]) -> bool:
    for dec in getattr(fn, "decorator_list", ()):
        if _is_jit(_dotted(dec), aliases):
            return True
        if isinstance(dec, ast.Call):
            if _is_jit(_dotted(dec.func), aliases):
                return True
            # @functools.partial(jax.jit, static_argnames=...)
            if _resolve(_dotted(dec.func), aliases) in (
                    "functools.partial", "partial"):
                if any(_is_jit(_dotted(a), aliases) for a in dec.args):
                    return True
    return False


def _jit_call_names(tree: ast.Module, aliases: Dict[str, str]) -> set:
    """Names passed (possibly through ``partial``) into ``jax.jit(...)``."""
    names: set = set()

    def collect(node: ast.AST) -> None:
        if isinstance(node, ast.Name):
            names.add(node.id)
        elif isinstance(node, ast.Call):
            for a in node.args:
                collect(a)

    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and _is_jit(_dotted(node.func), aliases):
            for a in node.args:
                collect(a)
    return names


# ---------------------------------------------------------------------------
# Mutable module globals.
# ---------------------------------------------------------------------------
def _mutable_globals(tree: ast.Module, aliases: Dict[str, str]) -> set:
    out: set = set()
    for stmt in tree.body:
        targets: List[ast.expr] = []
        value: Optional[ast.expr] = None
        if isinstance(stmt, ast.Assign):
            targets, value = stmt.targets, stmt.value
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            targets, value = [stmt.target], stmt.value
        if value is None:
            continue
        mutable = isinstance(value, (ast.List, ast.Dict, ast.Set,
                                     ast.ListComp, ast.DictComp, ast.SetComp))
        if isinstance(value, ast.Call):
            callee = _resolve(_dotted(value.func), aliases)
            mutable = callee is not None and (
                callee.rpartition(".")[2] in _MUTABLE_NAMES)
        if mutable:
            for t in targets:
                if isinstance(t, ast.Name):
                    out.add(t.id)
    return out


# ---------------------------------------------------------------------------
# Per-function checks.
# ---------------------------------------------------------------------------
def _local_names(fn: ast.AST) -> set:
    """Parameter and locally-assigned names (shadow module globals)."""
    names: set = set()
    args = getattr(fn, "args", None)
    if args is not None:
        for a in (args.posonlyargs + args.args + args.kwonlyargs
                  + ([args.vararg] if args.vararg else [])
                  + ([args.kwarg] if args.kwarg else [])):
            names.add(a.arg)
    for node in ast.walk(fn):
        if isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target])
            for t in targets:
                for leaf in ast.walk(t):
                    if isinstance(leaf, ast.Name):
                        names.add(leaf.id)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            names.add(node.name)
        elif isinstance(node, (ast.For, ast.comprehension)):
            for leaf in ast.walk(node.target):
                if isinstance(leaf, ast.Name):
                    names.add(leaf.id)
    return names


def _check_jit_body(fn: "ast.FunctionDef | ast.AsyncFunctionDef",
                    aliases: Dict[str, str], mutables: set,
                    filename: str, violations: list) -> None:
    locals_ = _local_names(fn)
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            callee = _resolve(_dotted(node.func), aliases)
            if callee is None:
                continue
            if any(callee.startswith(p) for p in SAFE_PREFIXES):
                continue
            if callee in IMPURE_EXACT or any(
                    callee.startswith(p) for p in IMPURE_PREFIXES):
                violations.append(Violation(
                    pass_name="purity", code="PUR001",
                    location=f"{filename}:{node.lineno}",
                    message=(
                        f"{fn.name} is traced under jax.jit but calls "
                        f"{callee}() — host-side time/randomness is frozen "
                        "into the trace at compile time")))
        elif (isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load)
                and node.id in mutables and node.id not in locals_):
            violations.append(Violation(
                pass_name="purity", code="PUR002",
                location=f"{filename}:{node.lineno}",
                message=(
                    f"{fn.name} is traced under jax.jit but reads the "
                    f"mutable module global {node.id!r} — its trace-time "
                    "contents silently become compile-time constants")))


def _check_int_fn(fn: "ast.FunctionDef | ast.AsyncFunctionDef",
                  aliases: Dict[str, str], filename: str,
                  violations: list) -> None:
    def flag(node: ast.AST, what: str) -> None:
        violations.append(Violation(
            pass_name="purity", code="PUR003",
            location=f"{filename}:{node.lineno}",
            message=(
                f"{fn.name} is an integer-engine function (``*_int``) but "
                f"contains {what} — the bit-exact datapath must be closed "
                "under integer arithmetic")))

    for node in ast.walk(fn):
        if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Div):
            flag(node, "a true division (`/`)")
        elif isinstance(node, ast.Constant) and isinstance(node.value, float):
            flag(node, f"the float literal {node.value!r}")
        elif isinstance(node, ast.Attribute) and node.attr in _FLOAT_DTYPES:
            flag(node, f"a {node.attr} dtype reference")
        elif (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
                and node.func.id == "float"):
            flag(node, "a float() cast")


# ---------------------------------------------------------------------------
# Leafless pytree registrations vs the dataclass registry.
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _DataclassInfo:
    name: str
    filename: str
    lineno: int
    frozen: bool
    fields: tuple  # of (field_name, annotation ast | None)


def _dataclass_registry(trees: Dict[str, ast.Module]) -> Dict[str, _DataclassInfo]:
    registry: Dict[str, _DataclassInfo] = {}
    for filename, tree in trees.items():
        aliases = _import_aliases(tree)
        for node in ast.walk(tree):
            if not isinstance(node, ast.ClassDef):
                continue
            frozen = None
            for dec in node.decorator_list:
                target = dec.func if isinstance(dec, ast.Call) else dec
                if _resolve(_dotted(target), aliases) in (
                        "dataclasses.dataclass", "dataclass"):
                    frozen = False
                    if isinstance(dec, ast.Call):
                        for kw in dec.keywords:
                            if kw.arg == "frozen" and isinstance(
                                    kw.value, ast.Constant):
                                frozen = bool(kw.value.value)
            if frozen is None:
                continue
            fields = tuple(
                (stmt.target.id, stmt.annotation)
                for stmt in node.body
                if isinstance(stmt, ast.AnnAssign)
                and isinstance(stmt.target, ast.Name))
            registry[node.name] = _DataclassInfo(
                node.name, filename, node.lineno, frozen, fields)
    return registry


def _annotation_mutable(ann: Optional[ast.AST],
                        registry: Dict[str, _DataclassInfo],
                        seen: set) -> Optional[str]:
    """Reason the annotation admits mutable values, or None if immutable.

    Unknown names are treated as immutable (lenient): the pass flags what
    it can prove, not what it cannot classify.
    """
    if ann is None:
        return None
    if isinstance(ann, ast.Constant):
        if isinstance(ann.value, str):
            try:
                ann = ast.parse(ann.value, mode="eval").body
            except SyntaxError:
                return None
        else:
            return None
    if isinstance(ann, ast.BinOp) and isinstance(ann.op, ast.BitOr):
        return (_annotation_mutable(ann.left, registry, seen)
                or _annotation_mutable(ann.right, registry, seen))
    if isinstance(ann, ast.Subscript):
        base = _dotted(ann.value)
        base = base.rpartition(".")[2] if base else None
        if base in _MUTABLE_NAMES:
            return f"{base}[...]"
        if base in _TRANSPARENT_GENERICS:
            inner = ann.slice
            elts = inner.elts if isinstance(inner, ast.Tuple) else [inner]
            for e in elts:
                reason = _annotation_mutable(e, registry, seen)
                if reason:
                    return reason
        return None
    name = _dotted(ann)
    name = name.rpartition(".")[2] if name else None
    if name is None:
        return None
    if name in _MUTABLE_NAMES:
        return name
    if name in _IMMUTABLE_NAMES:
        return None
    info = registry.get(name)
    if info is not None and name not in seen:
        return _class_mutable(info, registry, seen | {name})
    return None


def _class_mutable(info: _DataclassInfo, registry: Dict[str, _DataclassInfo],
                   seen: set) -> Optional[str]:
    if not info.frozen:
        return f"{info.name} is not frozen"
    for fname, ann in info.fields:
        reason = _annotation_mutable(ann, registry, seen)
        if reason:
            return f"{info.name}.{fname}: {reason}"
    return None


def _check_pytree_registrations(filename: str, tree: ast.Module,
                                registry: Dict[str, _DataclassInfo],
                                violations: list) -> None:
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call) or len(node.args) < 2:
            continue
        callee = _dotted(node.func)
        if callee is None or not callee.endswith("register_pytree_node"):
            continue
        cls_arg, flatten = node.args[0], node.args[1]
        # Leafless flatten: ``lambda s: ((), s)`` — no leaves, the whole
        # object rides in the static half of the pytree.
        leafless = (
            isinstance(flatten, ast.Lambda)
            and isinstance(flatten.body, ast.Tuple)
            and len(flatten.body.elts) == 2
            and isinstance(flatten.body.elts[0], ast.Tuple)
            and not flatten.body.elts[0].elts)
        if not leafless:
            continue
        dotted_cls = _dotted(cls_arg)
        cls_name = dotted_cls.rpartition(".")[2] if dotted_cls else "<unknown>"
        info = registry.get(cls_name)
        if info is None:
            violations.append(Violation(
                pass_name="purity", code="PUR004",
                location=f"{filename}:{node.lineno}",
                message=(
                    f"{cls_name} is registered as a leafless (static) "
                    "pytree but is not a dataclass this pass can verify — "
                    "static pytree nodes must be frozen dataclasses with "
                    "immutable fields")))
            continue
        reason = _class_mutable(info, registry, {cls_name})
        if reason:
            violations.append(Violation(
                pass_name="purity", code="PUR004",
                location=f"{filename}:{node.lineno}",
                message=(
                    f"{cls_name} is registered as a leafless (static) "
                    f"pytree but is mutable: {reason} — equal schedules "
                    "must hash equal and never change after tracing")))


# ---------------------------------------------------------------------------
# Entry points.
# ---------------------------------------------------------------------------
def check_module_purity(source: str, filename: str,
                        registry: Optional[Dict[str, _DataclassInfo]] = None,
                        ) -> AnalysisReport:
    """Lint one module (PUR001–PUR003; PUR004 too when given a registry)."""
    tree = ast.parse(source, filename=filename)
    aliases = _import_aliases(tree)
    mutables = _mutable_globals(tree, aliases)
    jit_names = _jit_call_names(tree, aliases)
    violations: list = []

    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if _decorated_jit(node, aliases) or node.name in jit_names:
            _check_jit_body(node, aliases, mutables, filename, violations)
        if node.name.endswith("_int"):
            _check_int_fn(node, aliases, filename, violations)

    if registry is None:
        registry = _dataclass_registry({filename: tree})
    _check_pytree_registrations(filename, tree, registry, violations)
    return AnalysisReport(
        subject=filename,
        passes=("purity",),
        violations=tuple(violations),
    )


def _package_sources(root: Optional[str]) -> Dict[str, str]:
    if root is None:
        import repro

        root = os.path.dirname(os.path.abspath(repro.__file__))
    sources: Dict[str, str] = {}
    for dirpath, _dirnames, filenames in os.walk(root):
        for f in sorted(filenames):
            if f.endswith(".py"):
                path = os.path.join(dirpath, f)
                with open(path, encoding="utf-8") as fh:
                    sources[os.path.relpath(path)] = fh.read()
    return sources


def check_purity(paths: Optional[Iterable[str]] = None,
                 root: Optional[str] = None) -> AnalysisReport:
    """Run the purity lint repo-wide (default: the ``repro`` package).

    The dataclass registry is built over *all* scanned modules first so
    PUR004 can chase field annotations across files (``CoreSchedule`` →
    ``CoreGrid``/``QuantSpec``), then each module is linted against it.
    """
    if paths is not None:
        sources = {}
        for path in paths:
            with open(path, encoding="utf-8") as f:
                sources[os.path.relpath(path)] = f.read()
    else:
        sources = _package_sources(root)
    trees = {fn: ast.parse(src, filename=fn) for fn, src in sources.items()}
    registry = _dataclass_registry(trees)
    report = AnalysisReport(subject="repro (purity)", passes=("purity",))
    for fn, src in sources.items():
        report = report.merge(check_module_purity(src, fn, registry))
    return report
