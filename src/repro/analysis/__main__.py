"""CLI: certify the paper's deployments and lint the source tree.

``python -m repro.analysis --all`` sweeps both paper networks (DVS
gesture, optical flow) across all three silicon precision pairs at one
and four cores, runs the repo-wide purity and serving-concurrency
lints, and exits nonzero on any error-level finding.

Options::

    --network {gesture,optical_flow}   restrict the sweep (repeatable)
    --bits {4,6,8}                     restrict precisions (repeatable)
    --cores N                          restrict core counts (repeatable)
    --skip-lints                       deployment passes only
    --json PATH                        write the full report (with the
                                       machine-checkable certificates)
    --baseline PATH                    ratchet: pre-existing findings in
                                       the baseline don't fail the run
    --write-baseline PATH              snapshot current findings and exit
"""
from __future__ import annotations

import argparse
import sys
from typing import Optional, Sequence

from ..compiler.schedule import compile_network
from ..core.network import SNNSpec, gesture_net, optical_flow_net
from ..core.quant import PRECISION_PAIRS, QuantSpec
from . import (
    AnalysisReport,
    Violation,
    analyze_deployment,
    check_certificate,
    check_purity,
    check_serving,
    load_baseline,
    new_violations,
    write_baseline,
)

NETWORKS = {
    "gesture": gesture_net,
    "optical_flow": optical_flow_net,
}
DEFAULT_BITS = tuple(w for w, _ in PRECISION_PAIRS)
DEFAULT_CORES = (1, 4)


def _analyze_config(spec: SNNSpec, bits: int, cores: int) -> AnalysisReport:
    qspec = QuantSpec(bits)
    schedule = compile_network(spec, n_cores=cores, qspec=qspec) \
        if cores > 1 else None
    report = analyze_deployment(spec, qspec, schedule)
    # Self-check: the emitted certificate must survive independent
    # re-derivation — a certifier bug shows up here, not in silence.
    problems = check_certificate(report.certificates["overflow"])
    for p in problems:
        report = report.merge(AnalysisReport(
            subject=report.subject,
            passes=("overflow",),
            violations=(Violation(
                pass_name="overflow", code="OVFCHK",
                location=report.subject,
                message=f"certificate failed re-verification: {p}"),),
        ))
    subject = f"{spec.name}@{bits}/{qspec.vmem_bits}b x{cores}core"
    return AnalysisReport(
        subject=subject,
        passes=report.passes,
        violations=report.violations,
        certificates={f"{subject}:{k}": v
                      for k, v in report.certificates.items()},
    )


def main(argv: Optional[Sequence[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Deploy-time static verification for SpiDR deployments.")
    parser.add_argument("--all", action="store_true",
                        help="full sweep (the default when nothing is "
                             "restricted)")
    parser.add_argument("--network", action="append",
                        choices=sorted(NETWORKS))
    parser.add_argument("--bits", action="append", type=int,
                        choices=DEFAULT_BITS)
    parser.add_argument("--cores", action="append", type=int)
    parser.add_argument("--skip-lints", action="store_true",
                        help="skip the repo-wide purity/concurrency lints")
    parser.add_argument("--json", metavar="PATH",
                        help="write the full JSON report (certificates "
                             "included)")
    parser.add_argument("--baseline", metavar="PATH",
                        help="only findings absent from this baseline fail")
    parser.add_argument("--write-baseline", metavar="PATH",
                        help="snapshot current findings as the baseline")
    args = parser.parse_args(argv)

    networks = args.network or sorted(NETWORKS)
    bits = args.bits or list(DEFAULT_BITS)
    cores = args.cores or list(DEFAULT_CORES)

    merged = AnalysisReport(subject="repro.analysis")
    for name in networks:
        spec = NETWORKS[name]()
        for b in bits:
            for c in cores:
                report = _analyze_config(spec, b, c)
                print(report.summary())
                merged = merged.merge(report)
    if not args.skip_lints:
        for report in (check_purity(), check_serving()):
            print(report.summary())
            merged = merged.merge(report)

    if args.write_baseline:
        data = write_baseline(args.write_baseline, merged.errors)
        print(f"wrote baseline with {len(data['waived'])} waived "
              f"finding(s) to {args.write_baseline}")
        return 0

    failing = merged.errors
    if args.baseline:
        waived = load_baseline(args.baseline)
        failing = new_violations(failing, waived)
        n_waived = len(merged.errors) - len(failing)
        if n_waived:
            print(f"baseline: {n_waived} pre-existing finding(s) waived")

    if args.json:
        with open(args.json, "w", encoding="utf-8") as f:
            f.write(merged.to_json())
            f.write("\n")
        print(f"report written to {args.json}")

    n_cfg = len(networks) * len(bits) * len(cores)
    print(f"\n{n_cfg} deployment config(s), "
          f"{len(merged.passes)} pass(es), "
          f"{len(merged.errors)} error(s) "
          f"({len(failing)} failing), "
          f"{len(merged.warnings)} warning(s)")
    return 1 if failing else 0


if __name__ == "__main__":
    sys.exit(main())
