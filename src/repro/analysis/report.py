"""The one report type every static-analysis pass emits into.

A :class:`Violation` is a single finding — which pass produced it, a
stable diagnostic code, the offending location and an exact message.  An
:class:`AnalysisReport` aggregates the findings of one analysis run
together with the machine-checkable certificates the passes emitted
(today: the overflow certificate of ``ranges.py`` and the schedule
certificate of ``schedule_check.py``), and serializes to JSON for the
CI artifact.

Baselines.  ``python -m repro.analysis --baseline FILE`` compares the
run's violation *keys* (pass:code:location — deliberately excluding the
message, which may carry run-dependent numbers) against a committed
snapshot: pre-existing findings are reported but don't fail the run, new
ones do.  ``--write-baseline`` snapshots the current state — the ratchet
only ever shrinks.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Iterable, Optional

__all__ = [
    "AnalysisError",
    "AnalysisReport",
    "Violation",
    "load_baseline",
    "write_baseline",
]

#: Severity levels, in increasing order of badness.
SEVERITIES = ("warning", "error")


@dataclasses.dataclass(frozen=True)
class Violation:
    """One finding of one pass.

    ``pass_name``  "overflow" | "schedule" | "concurrency" | "purity".
    ``code``       stable diagnostic code (e.g. ``OVF001``) — the baseline
                   key and the thing tests assert on.
    ``location``   where: ``<network>.L<idx>`` for compiler passes,
                   ``<file>:<line>`` for the AST lints.
    ``message``    the exact human-readable diagnostic.
    ``severity``   "error" (fails strict/CI) or "warning".
    """

    pass_name: str
    code: str
    location: str
    message: str
    severity: str = "error"

    def __post_init__(self) -> None:
        if self.severity not in SEVERITIES:
            raise ValueError(
                f"severity must be one of {SEVERITIES}, got "
                f"{self.severity!r}")

    @property
    def key(self) -> str:
        """Stable baseline identity (message excluded — it may carry
        run-dependent numbers)."""
        return f"{self.pass_name}:{self.code}:{self.location}"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "Violation":
        return cls(**d)

    def __str__(self) -> str:
        return (f"[{self.pass_name}:{self.code}] {self.severity} at "
                f"{self.location}: {self.message}")


@dataclasses.dataclass
class AnalysisReport:
    """Aggregated result of one static-analysis run.

    ``subject``      what was analyzed (e.g. ``"gesture@4/7b x4cores"``).
    ``passes``       names of the passes that ran.
    ``violations``   every finding, in pass order.
    ``certificates`` machine-checkable pass artifacts by pass name — each
                     is plain JSON whose inequalities an independent
                     checker re-verifies (``ranges.check_certificate``).
    """

    subject: str
    passes: tuple = ()
    violations: tuple = ()
    certificates: dict = dataclasses.field(default_factory=dict)

    @property
    def ok(self) -> bool:
        """No error-severity findings (warnings don't fail a run)."""
        return not self.errors

    @property
    def errors(self) -> tuple:
        return tuple(v for v in self.violations if v.severity == "error")

    @property
    def warnings(self) -> tuple:
        return tuple(v for v in self.violations if v.severity == "warning")

    def merge(self, other: "AnalysisReport") -> "AnalysisReport":
        """Fold another report in (CLI aggregates per-config reports)."""
        certs = dict(self.certificates)
        for k, v in other.certificates.items():
            certs[f"{other.subject}:{k}" if k in certs else k] = v
        return AnalysisReport(
            subject=self.subject,
            passes=tuple(dict.fromkeys(self.passes + other.passes)),
            violations=self.violations + other.violations,
            certificates=certs,
        )

    def summary(self) -> str:
        head = (f"{self.subject}: "
                f"{len(self.passes)} pass(es), "
                f"{len(self.errors)} error(s), "
                f"{len(self.warnings)} warning(s)")
        lines = [head]
        lines += [f"  {v}" for v in self.violations]
        if not self.violations:
            lines.append("  certified: no violations")
        return "\n".join(lines)

    def to_dict(self) -> dict:
        return {
            "subject": self.subject,
            "passes": list(self.passes),
            "violations": [v.to_dict() for v in self.violations],
            "certificates": self.certificates,
            "ok": self.ok,
        }

    def to_json(self, indent: Optional[int] = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_dict(cls, d: dict) -> "AnalysisReport":
        return cls(
            subject=d["subject"],
            passes=tuple(d.get("passes", ())),
            violations=tuple(
                Violation.from_dict(v) for v in d.get("violations", ())),
            certificates=dict(d.get("certificates", {})),
        )


class AnalysisError(RuntimeError):
    """Raised by ``spidr.compile(..., check="strict")`` on any error-level
    finding.  Carries the full :class:`AnalysisReport` as ``.report``."""

    def __init__(self, report: AnalysisReport):
        self.report = report
        super().__init__(
            "static analysis found "
            f"{len(report.errors)} violation(s) in {report.subject}:\n"
            + "\n".join(f"  {v}" for v in report.errors)
            + "\n(compile with check='warn' to proceed anyway, or fix the "
            "deployment)")


# ---------------------------------------------------------------------------
# Baseline ratchet.
# ---------------------------------------------------------------------------
def load_baseline(path: str) -> set:
    """Read a committed baseline: the set of waived violation keys."""
    with open(path, encoding="utf-8") as f:
        data = json.load(f)
    return set(data.get("waived", ()))


def write_baseline(path: str, violations: Iterable[Violation]) -> dict:
    """Snapshot the current findings as the new baseline file."""
    data: dict[str, Any] = {
        "waived": sorted({v.key for v in violations}),
    }
    with open(path, "w", encoding="utf-8") as f:
        json.dump(data, f, indent=2, sort_keys=True)
        f.write("\n")
    return data


def new_violations(violations: Iterable[Violation],
                   baseline: set) -> tuple:
    """Findings not waived by the baseline — the ones that fail CI."""
    return tuple(v for v in violations if v.key not in baseline)
