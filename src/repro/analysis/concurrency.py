"""Concurrency lint for the serving tier: lock discipline + thread stress.

The fleet's threading contract (``repro.serving.fleet``): :class:`Fleet`
owns the single ``self._lock`` (an RLock); every mutation of fleet state
happens under it, either lexically (``with self._lock:``) or inside a
private helper whose *every* call site holds the lock; and nothing blocks
while holding it — the replica loop ticks the jitted step *outside* the
lock precisely so replicas overlap.  ``SessionScheduler`` and the workers
deliberately carry no lock of their own: they are only ever touched under
the fleet's (or before its threads start), which is why the static check
scopes to lock-owning classes and the dynamic harness covers the rest.

Static pass (:func:`check_lock_discipline`) — pure AST, per class that
assigns ``self._lock``:

  * **CON001** a method (other than ``__init__``/``__post_init__``)
    writes a ``self.*`` field (attribute or ``self.x[...]`` subscript)
    without holding the lock — neither inside a lexical ``with
    self._lock`` nor in a private helper whose call sites all hold it
    (computed to a fixpoint over the intra-class call graph; a method
    referenced without being called, e.g. ``Thread(target=self._loop)``,
    counts as an unlocked entry point).
  * **CON002** a blocking call — ``time.sleep``, a thread ``join()``
    (zero positional args, distinguishing it from ``str.join``), or an
    event ``.wait()`` — is reachable while the lock is held.

Dynamic harness (:func:`stress_fleet`) — the seeded cross-check the
static pass cannot give: the same deterministic submissions are served
through a sync fleet and a threaded fleet, and every stream's readout and
cycle attribution must match byte for byte regardless of thread
interleaving.
"""
from __future__ import annotations

import ast
import dataclasses
import os
from typing import Iterable, Optional

from .report import AnalysisReport, Violation

__all__ = [
    "StressResult",
    "check_lock_discipline",
    "check_serving",
    "stress_fleet",
]

LOCK_ATTR = "_lock"
_INIT_METHODS = ("__init__", "__post_init__")
_BLOCKING_DOTTED = ("time.sleep",)
_BLOCKING_ATTRS = ("wait",)        # Event.wait / Condition.wait


def _is_self_attr(node: ast.AST, attr: Optional[str] = None) -> bool:
    return (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"
            and (attr is None or node.attr == attr))


def _dotted(node: ast.AST) -> Optional[str]:
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


@dataclasses.dataclass
class _Site:
    """One fact collected from a method body, with its lock context."""

    line: int
    locked: bool          # lexically inside ``with self._lock``
    detail: str


class _MethodFacts(ast.NodeVisitor):
    """Walk one method body tracking the lexical lock depth."""

    def __init__(self, method_names: set):
        self.method_names = method_names
        self.depth = 0
        self.writes: list = []        # _Site(detail=attr written)
        self.calls: list = []         # _Site(detail=self-method called)
        self.refs: list = []          # _Site(detail=self-method referenced)
        self.blocking: list = []      # _Site(detail=blocking call)

    # -- lock scoping ------------------------------------------------------
    def visit_With(self, node: ast.With) -> None:
        holds = any(_is_self_attr(item.context_expr, LOCK_ATTR)
                    for item in node.items)
        for item in node.items:
            self.visit(item)
        self.depth += 1 if holds else 0
        for stmt in node.body:
            self.visit(stmt)
        self.depth -= 1 if holds else 0

    # -- writes ------------------------------------------------------------
    def _record_write_target(self, target: ast.AST, line: int) -> None:
        if isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self._record_write_target(elt, line)
            return
        if isinstance(target, ast.Starred):
            self._record_write_target(target.value, line)
            return
        node = target
        if isinstance(node, ast.Subscript):
            node = node.value
        if _is_self_attr(node) and node.attr != LOCK_ATTR:
            self.writes.append(_Site(line, self.depth > 0, node.attr))

    def visit_Assign(self, node: ast.Assign) -> None:
        for t in node.targets:
            self._record_write_target(t, node.lineno)
        self.visit(node.value)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self._record_write_target(node.target, node.lineno)
        self.visit(node.value)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        self._record_write_target(node.target, node.lineno)
        if node.value is not None:
            self.visit(node.value)

    def visit_Delete(self, node: ast.Delete) -> None:
        for t in node.targets:
            self._record_write_target(t, node.lineno)

    # -- calls / refs ------------------------------------------------------
    def visit_Call(self, node: ast.Call) -> None:
        locked = self.depth > 0
        if _is_self_attr(node.func) and node.func.attr in self.method_names:
            self.calls.append(_Site(node.lineno, locked, node.func.attr))
        dotted = _dotted(node.func)
        if dotted in _BLOCKING_DOTTED:
            self.blocking.append(_Site(node.lineno, locked, f"{dotted}()"))
        elif isinstance(node.func, ast.Attribute) \
                and not _is_self_attr(node.func):
            # ``x.join()`` with no positional args is a thread join;
            # ``sep.join(parts)`` (str.join) always passes the iterable.
            if node.func.attr == "join" and not node.args:
                self.blocking.append(
                    _Site(node.lineno, locked, ".join()"))
            elif node.func.attr in _BLOCKING_ATTRS:
                self.blocking.append(
                    _Site(node.lineno, locked, f".{node.func.attr}()"))
        # Arguments may reference methods (entry points) — visit children
        # but skip re-recording the func attribute as a bare reference.
        for child in list(node.args) + [kw.value for kw in node.keywords]:
            self.visit(child)

    def visit_Attribute(self, node: ast.Attribute) -> None:
        if _is_self_attr(node) and node.attr in self.method_names:
            self.refs.append(_Site(node.lineno, self.depth > 0, node.attr))
        self.visit(node.value)

    def visit_Lambda(self, node: ast.Lambda) -> None:
        # A lambda body inherits the lock state at its definition site (the
        # fleet only defines them for immediate use).
        self.visit(node.body)

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        # Nested defs inherit the lexical lock state at the def site.
        for stmt in node.body:
            self.visit(stmt)


def _check_class(cls: ast.ClassDef, filename: str,
                 violations: list) -> None:
    methods = {n.name: n for n in cls.body
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
    owns_lock = any(
        _is_self_attr(t, LOCK_ATTR)
        for m in methods.values()
        for stmt in ast.walk(m)
        if isinstance(stmt, ast.Assign)
        for t in stmt.targets)
    if not owns_lock:
        return

    facts = {}
    for name, m in methods.items():
        f = _MethodFacts(set(methods))
        for stmt in m.body:
            f.visit(stmt)
        facts[name] = f

    # Fixpoint: a private helper is lock-held iff it has at least one call
    # site and every call site (and bare reference) holds the lock —
    # lexically or by being inside another lock-held helper.
    locked_methods: set = set()
    changed = True
    while changed:
        changed = False
        for name in methods:
            if name in locked_methods or not name.startswith("_") \
                    or name.startswith("__"):
                continue
            sites = []
            for caller, f in facts.items():
                for site in f.calls + f.refs:
                    if site.detail == name:
                        sites.append(site.locked
                                     or caller in locked_methods)
            if sites and all(sites):
                locked_methods.add(name)
                changed = True

    for name, f in facts.items():
        held = name in locked_methods
        if name not in _INIT_METHODS:
            for w in f.writes:
                if not (w.locked or held):
                    violations.append(Violation(
                        pass_name="concurrency", code="CON001",
                        location=f"{filename}:{w.line}",
                        message=(
                            f"{cls.name}.{name} writes self.{w.detail} "
                            f"without holding self.{LOCK_ATTR}")))
        for b in f.blocking:
            if b.locked or held:
                violations.append(Violation(
                    pass_name="concurrency", code="CON002",
                    location=f"{filename}:{b.line}",
                    message=(
                        f"{cls.name}.{name} calls {b.detail} while "
                        f"holding self.{LOCK_ATTR} — blocking under the "
                        "fleet lock stalls every replica")))


def check_lock_discipline(source: str, filename: str) -> AnalysisReport:
    """Lint one module's lock-owning classes (see module docstring)."""
    violations: list = []
    tree = ast.parse(source, filename=filename)
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef):
            _check_class(node, filename, violations)
    return AnalysisReport(
        subject=filename,
        passes=("concurrency",),
        violations=tuple(violations),
    )


def check_serving(paths: Optional[Iterable[str]] = None) -> AnalysisReport:
    """Run the lock-discipline lint over ``repro.serving`` (or ``paths``)."""
    if paths is None:
        from .. import serving

        pkg_dir = os.path.dirname(os.path.abspath(serving.__file__))
        paths = sorted(
            os.path.join(pkg_dir, f) for f in os.listdir(pkg_dir)
            if f.endswith(".py"))
    report = AnalysisReport(subject="repro.serving",
                            passes=("concurrency",))
    for path in paths:
        with open(path, encoding="utf-8") as f:
            source = f.read()
        rel = os.path.relpath(path)
        report = report.merge(check_lock_discipline(source, rel))
    return report


# ---------------------------------------------------------------------------
# Seeded thread-stress harness: threaded vs sync fleets must agree.
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class StressResult:
    """Outcome of one sync-vs-threaded cross-check."""

    n_streams: int
    ticks_sync: int
    ticks_threaded: int
    mismatches: tuple

    @property
    def ok(self) -> bool:
        return not self.mismatches


def stress_fleet(compiled, n_streams: int = 6, n_replicas: int = 2,
                 timesteps: Optional[int] = None, seed: int = 0,
                 capacity: int = 2, timeout_s: float = 120.0) -> StressResult:
    """Serve identical seeded streams sync and threaded; compare results.

    Every stream's computation is deterministic per chunk, so thread
    interleaving must not change any readout or per-stream cycle count —
    a divergence means fleet state was mutated outside the lock contract
    the static pass checks.
    """
    import numpy as np

    from ..serving import serve

    h, w = compiled.spec.input_hw
    c = compiled.spec.in_channels
    t = timesteps or compiled.spec.timesteps
    rng = np.random.default_rng(seed)
    streams = [(rng.random((t, h, w, c)) < 0.1).astype(np.float32)
               for _ in range(n_streams)]

    def run(mode: str):
        fleet = serve(compiled, n_replicas=n_replicas, capacity=capacity,
                      mode=mode, max_queue=max(n_streams, 1))
        try:
            handles = [fleet.submit(ev, rid=i)
                       for i, ev in enumerate(streams)]
            fleet.drain(timeout=timeout_s if mode == "threaded" else None)
            results = {
                hd.rid: (np.asarray(hd.readout), int(hd.cycles))
                for hd in handles}
            return results, int(fleet.ticks)
        finally:
            fleet.shutdown()

    sync_res, sync_ticks = run("sync")
    thr_res, thr_ticks = run("threaded")
    mismatches = []
    for rid in sorted(sync_res):
        (r_s, c_s), (r_t, c_t) = sync_res[rid], thr_res[rid]
        if not np.array_equal(r_s, r_t):
            mismatches.append(f"stream {rid}: readout diverged")
        elif c_s != c_t:
            mismatches.append(
                f"stream {rid}: cycles diverged ({c_s} vs {c_t})")
    return StressResult(
        n_streams=n_streams,
        ticks_sync=sync_ticks,
        ticks_threaded=thr_ticks,
        mismatches=tuple(mismatches),
    )
