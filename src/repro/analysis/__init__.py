"""Deploy-time static verification for SpiDR deployments.

Four passes over the artifacts ``spidr.compile`` produces — no hardware,
no test vectors, just the compiler IR and the schedule:

  * :mod:`~repro.analysis.ranges` — **overflow certification**: abstract
    interpretation over the integer datapath proving the int32
    accumulator never wraps before its single saturation point, emitting
    a machine-checkable certificate (re-verifiable by
    :func:`check_certificate`).
  * :mod:`~repro.analysis.schedule_check` — **schedule verification**:
    capacity, legal precision pairs, mode/stationarity consistency, AER
    routing acyclicity, and a static replay of cycle conservation
    against ``engine.cost.estimate_multicore_cost``.
  * :mod:`~repro.analysis.concurrency` — **lock-discipline lint** over
    ``repro.serving`` plus the seeded sync-vs-threaded stress harness.
  * :mod:`~repro.analysis.purity` — **jit-safety lint**: host impurity
    in traced functions, float leakage into the integer engine, and
    leafless-pytree registrations that aren't frozen/immutable.

Surfaces: ``spidr.compile(..., check="strict"|"warn"|"off")``,
``CompiledSNN.report()``, and the ``python -m repro.analysis`` CLI
(see ``docs/analysis.md``).
"""
from __future__ import annotations

from typing import Optional

from ..compiler.schedule import CoreSchedule
from ..core.network import SNNSpec
from ..core.quant import QuantSpec
from .concurrency import (
    StressResult,
    check_lock_discipline,
    check_serving,
    stress_fleet,
)
from .purity import check_module_purity, check_purity
from .ranges import certify_overflow, check_certificate, layer_overflow_facts
from .report import (
    AnalysisError,
    AnalysisReport,
    Violation,
    load_baseline,
    new_violations,
    write_baseline,
)
from .schedule_check import check_schedule

__all__ = [
    "AnalysisError",
    "AnalysisReport",
    "StressResult",
    "Violation",
    "analyze_deployment",
    "certify_overflow",
    "check_certificate",
    "check_lock_discipline",
    "check_module_purity",
    "check_purity",
    "check_schedule",
    "check_serving",
    "layer_overflow_facts",
    "load_baseline",
    "new_violations",
    "stress_fleet",
    "write_baseline",
]


def analyze_deployment(spec: SNNSpec, qspec: QuantSpec,
                       schedule: Optional[CoreSchedule] = None,
                       ) -> AnalysisReport:
    """The compile-time bundle: overflow certification + schedule checks.

    This is what ``spidr.compile(..., check=...)`` runs on every
    deployment — the network-shaped passes only.  The repo-wide lints
    (:func:`check_purity`, :func:`check_serving`) are source properties,
    not deployment properties; the CLI and CI run those.
    """
    report = certify_overflow(spec, qspec)
    if schedule is not None:
        report = report.merge(check_schedule(schedule, spec=spec))
    return report
