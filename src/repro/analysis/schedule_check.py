"""Schedule verification: structural lint of a compiled :class:`CoreSchedule`.

``compile_network`` emits schedules that satisfy these invariants by
construction — this pass re-proves them on the *artifact*, so a schedule
that was tampered with, deserialized from an old artifact, or produced by
a future search-based placer (ROADMAP "Compiler v2") is certified before
the engine bakes it into weights:

  * **capacity / coverage** — every slice lands inside the
    :class:`CoreGrid`; each layer's slices are contiguous, non-overlapping
    and cover exactly ``[0, out_channels)`` (the engine reassembles
    outputs by concatenation — a gap or overlap silently corrupts them).
  * **precision legality** — the schedule's ``qspec`` and every plan's
    spec must be a supported ``(B_w, B_vmem)`` pair
    (:data:`repro.core.quant.PRECISION_PAIRS`); a plan precision differing
    from the schedule's is flagged as cost-model-only (warning).
  * **mode / stationarity consistency** — operating mode in {1, 2},
    stationarity in {weight, vmem}, and (given the spec) the plan's
    mapping must equal ``map_layer``'s re-derivation for the placed slice
    shape.
  * **AER routing soundness** — ``route_fractions`` replayed from the
    previous layer's slices (the compiler's local-share rule), fractions
    in [0, 1] and nonzero only on consumer cores, consumers exactly the
    slice-holders, stages in pipeline order, and the routing graph
    acyclic.  Together these give handshake-deadlock freedom: every
    (layer, core) stage waits only on strictly-earlier stages, and no
    core is ever sent spikes it does not consume (which would wedge the
    bufferless handshake).
  * **cycle conservation** — a static replay of
    ``estimate_multicore_cost`` on deterministic worst-case spike counts,
    with the per-core row-op and routing sums re-derived *independently*
    here: splitting a network across cores must conserve total row-op
    cycles exactly, up to the modeled duplication overhead (>= 0).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

from ..compiler.ir import build_graph
from ..compiler.schedule import CoreSchedule, LayerSchedule
from ..core.modes import CoreConfig, map_layer
from ..core.network import SNNSpec
from ..core.pipeline import route_cycles
from ..core.quant import PRECISION_PAIRS
from .report import AnalysisReport, Violation

__all__ = ["check_schedule"]

_PAIRS_TEXT = ", ".join(f"{w}/{v}" for w, v in PRECISION_PAIRS)


def _pair_of(qspec: object) -> tuple:
    """(weight_bits, vmem_bits) of a (possibly duck-typed) spec object."""
    return (getattr(qspec, "weight_bits", None),
            getattr(qspec, "vmem_bits", None))


def _v(code: str, location: str, message: str,
       severity: str = "error") -> Violation:
    return Violation(pass_name="schedule", code=code, location=location,
                     message=message, severity=severity)


def _check_capacity(schedule: CoreSchedule, loc: str,
                    out: list) -> None:
    n = schedule.n_cores
    if n < 1 or schedule.grid.n_cores != n:
        out.append(_v("SCH001", loc,
                      f"schedule declares n_cores={n} but its grid has "
                      f"{schedule.grid.n_cores} cores"))
    for layer in schedule.layers:
        lloc = f"{loc}.L{layer.node}"
        if not layer.slices:
            out.append(_v("SCH004", lloc, "layer has no channel slices — "
                          "nothing would execute it"))
            continue
        for s in layer.slices:
            if not 0 <= s.core < n:
                out.append(_v(
                    "SCH002", lloc,
                    f"slice [{s.lo}:{s.hi}) placed on core {s.core}, "
                    f"outside the grid of {n} cores"))
        expect_lo = 0
        for s in layer.slices:
            if s.lo != expect_lo or s.hi <= s.lo:
                out.append(_v(
                    "SCH003", lloc,
                    f"channel slices must be contiguous over "
                    f"[0, {layer.out_channels}): slice [{s.lo}:{s.hi}) "
                    f"follows coverage up to {expect_lo}"))
                break
            expect_lo = s.hi
        else:
            if expect_lo != layer.out_channels:
                out.append(_v(
                    "SCH003", lloc,
                    f"channel slices must be contiguous over "
                    f"[0, {layer.out_channels}): coverage ends at "
                    f"{expect_lo}"))


def _check_precision(schedule: CoreSchedule, loc: str, out: list) -> None:
    pair = _pair_of(schedule.qspec)
    if pair not in PRECISION_PAIRS:
        out.append(_v(
            "SCH010", loc,
            f"illegal precision pair {pair[0]}/{pair[1]}: supported "
            f"pairs are {_PAIRS_TEXT}"))
    for layer in schedule.layers:
        lloc = f"{loc}.L{layer.node}"
        ppair = _pair_of(layer.plan.spec)
        if ppair not in PRECISION_PAIRS:
            out.append(_v(
                "SCH011", lloc,
                f"illegal plan precision pair {ppair[0]}/{ppair[1]}: "
                f"supported pairs are {_PAIRS_TEXT}"))
        elif ppair != pair and pair in PRECISION_PAIRS:
            out.append(_v(
                "SCH012", lloc,
                f"plan precision {ppair[0]}/{ppair[1]} differs from the "
                f"schedule's {pair[0]}/{pair[1]} — a design-space "
                "(cost-model-only) schedule; compile_engine would reject "
                "it", severity="warning"))


def _check_modes(schedule: CoreSchedule, spec: Optional[SNNSpec],
                 loc: str, out: list) -> None:
    shapes = {}
    if spec is not None:
        graph = build_graph(spec)
        shapes = {n.idx: n.shape for n in graph.weight_nodes}
    for layer in schedule.layers:
        lloc = f"{loc}.L{layer.node}"
        plan = layer.plan
        if plan.mode not in (1, 2):
            out.append(_v("SCH020", lloc,
                          f"operating mode must be 1 or 2, got "
                          f"{plan.mode!r}"))
            continue
        if plan.mapping.mode != plan.mode:
            out.append(_v(
                "SCH021", lloc,
                f"plan says mode {plan.mode} but its mapping was derived "
                f"for mode {plan.mapping.mode}"))
        if plan.stationarity not in ("weight", "vmem"):
            out.append(_v(
                "SCH022", lloc,
                f"stationarity must be 'weight' or 'vmem', got "
                f"{plan.stationarity!r}"))
        shape = shapes.get(layer.node)
        if shape is not None and layer.slices \
                and _pair_of(plan.spec) in PRECISION_PAIRS:
            widest = max(s.hi - s.lo for s in layer.slices)
            placed = dataclasses.replace(shape, out_channels=widest)
            derived = map_layer(placed, CoreConfig(plan.spec),
                                force_mode=plan.mode)
            if derived != plan.mapping:
                out.append(_v(
                    "SCH023", lloc,
                    f"plan mapping {plan.mapping} is not map_layer's "
                    f"derivation {derived} for the placed slice shape "
                    f"(widest slice {widest} channels)"))


def _check_routing(schedule: CoreSchedule, loc: str, out: list) -> None:
    n = schedule.n_cores
    prev: Optional[LayerSchedule] = None
    edges = []        # ((stage_idx, core) -> (stage_idx, core)) wait-for
    last_node = -1
    for stage, layer in enumerate(schedule.layers):
        lloc = f"{loc}.L{layer.node}"
        if layer.node <= last_node:
            out.append(_v(
                "SCH036", lloc,
                f"layers out of pipeline order: L{layer.node} scheduled "
                f"after L{last_node}"))
        last_node = max(last_node, layer.node)
        fr = layer.route_fractions
        if len(fr) != n:
            out.append(_v(
                "SCH030", lloc,
                f"route_fractions has {len(fr)} entries for {n} cores"))
            prev = layer
            continue
        slice_cores = tuple(sorted({s.core for s in layer.slices}))
        if tuple(layer.consumer_cores) != slice_cores:
            out.append(_v(
                "SCH032", lloc,
                f"consumer_cores {tuple(layer.consumer_cores)} != the "
                f"cores holding slices {slice_cores}"))
        for c, f in enumerate(fr):
            if not 0.0 <= f <= 1.0:
                out.append(_v(
                    "SCH031", lloc,
                    f"route fraction {f} on core {c} outside [0, 1]"))
            elif f > 0.0 and c not in layer.consumer_cores:
                out.append(_v(
                    "SCH033", lloc,
                    f"core {c} is sent {f:.3f} of the input spikes but "
                    "holds no slice of the layer — undeliverable spikes "
                    "wedge the bufferless AER handshake"))
        # Static replay of the compiler's local-share routing rule.
        expect = [0.0] * n
        if prev is None:
            for c in slice_cores[1:]:
                expect[c] = 1.0
        else:
            prev_ch = max(prev.out_channels, 1)
            for c in slice_cores:
                local = sum(s.hi - s.lo for s in prev.slices
                            if s.core == c)
                expect[c] = 1.0 - local / prev_ch
        got = [float(f) for f in fr]
        if any(abs(a - b) > 1e-9 for a, b in zip(got, expect)):
            out.append(_v(
                "SCH034", lloc,
                f"route_fractions {tuple(got)} do not replay from the "
                f"previous layer's slices (expected {tuple(expect)})"))
        if prev is not None:
            for p in {s.core for s in prev.slices}:
                for c in layer.consumer_cores:
                    if isinstance(c, int) and c != p:
                        edges.append(((stage - 1, p), (stage, c)))
        prev = layer
    # Acyclicity of the stage wait-for graph: consumers wait on producers.
    # With chain IR every edge advances the stage index, but a tampered or
    # future-DAG schedule is checked generally (iterative DFS).
    adj: dict = {}
    for a, b in edges:
        adj.setdefault(a, []).append(b)
    state: dict = {}
    for root in adj:
        if root in state:
            continue
        stack = [(root, iter(adj.get(root, ())))]
        state[root] = 1
        while stack:
            node, it = stack[-1]
            for nxt in it:
                if state.get(nxt) == 1:
                    out.append(_v(
                        "SCH035", loc,
                        f"AER routing graph has a cycle through stage "
                        f"{nxt[0]} core {nxt[1]} — the handshake pipeline "
                        "can deadlock"))
                    state[nxt] = 2
                elif nxt not in state:
                    state[nxt] = 1
                    stack.append((nxt, iter(adj.get(nxt, ()))))
                    break
            else:
                state[node] = 2
                stack.pop()


def _replay_conservation(schedule: CoreSchedule, spec: SNNSpec,
                         loc: str, out: list) -> dict:
    """Re-derive the cost model's per-core attribution independently and
    cross-check the cycle-conservation identity on worst-case counts."""
    from ..engine.cost import estimate_multicore_cost

    graph = build_graph(spec)
    weight_nodes = graph.weight_nodes
    if len(weight_nodes) != len(schedule.layers):
        out.append(_v(
            "SCH040", loc,
            f"schedule has {len(schedule.layers)} weight layers but the "
            f"spec lowers to {len(weight_nodes)}"))
        return {}
    T = 2
    counts = np.tile(
        np.array([n.in_positions for n in weight_nodes],
                 dtype=np.float64), (T, 1))
    cost = estimate_multicore_cost(spec, schedule, counts)

    C = schedule.n_cores
    rcps = schedule.grid.route_cycles_per_spike
    compute = np.zeros(C, dtype=np.int64)
    routing = np.zeros(C, dtype=np.int64)
    single = 0
    for li, layer in enumerate(schedule.layers):
        m = layer.plan.mapping
        active = m.pipelines * m.macros_per_pipeline
        full_ct = max(1, math.ceil(layer.out_channels
                                   / m.parallel_channels))
        single += int(np.ceil(2.0 * counts[:, li] * full_ct).sum())
        for s in layer.slices:
            ct = max(1, math.ceil((s.hi - s.lo) / m.parallel_channels))
            per_macro = np.ceil(2.0 * counts[:, li] * ct / active)
            compute[s.core] += int(per_macro.sum()) * active
        for c, frac in enumerate(layer.route_fractions):
            if frac > 0.0:
                routing[c] += route_cycles(counts[:, li].sum() * frac, rcps)

    if not np.array_equal(compute, cost.compute_cycles):
        out.append(_v(
            "SCH040", loc,
            f"per-core compute cycles {cost.compute_cycles.tolist()} do "
            f"not replay from the schedule (expected {compute.tolist()})"))
    if single != cost.single_core_compute_cycles:
        out.append(_v(
            "SCH041", loc,
            f"single-core compute cycles {cost.single_core_compute_cycles}"
            f" do not replay from the schedule (expected {single})"))
    duplication = int(compute.sum()) - single
    if duplication < 0 or cost.duplication_cycles != duplication \
            or int(cost.compute_cycles.sum()) != \
            cost.single_core_compute_cycles + cost.duplication_cycles:
        out.append(_v(
            "SCH042", loc,
            "cycle conservation broken: sum(compute) "
            f"{int(cost.compute_cycles.sum())} != single-core "
            f"{cost.single_core_compute_cycles} + duplication "
            f"{cost.duplication_cycles} (replay gives duplication "
            f"{duplication})"))
    if not np.array_equal(routing, cost.routing_cycles):
        out.append(_v(
            "SCH043", loc,
            f"per-core AER routing cycles {cost.routing_cycles.tolist()} "
            f"do not replay from route_fractions (expected "
            f"{routing.tolist()})"))
    return {
        "worst_case_T": T,
        "compute_cycles": compute.tolist(),
        "routing_cycles": routing.tolist(),
        "single_core_compute_cycles": single,
        "duplication_cycles": duplication,
    }


def check_schedule(schedule: CoreSchedule,
                   spec: Optional[SNNSpec] = None) -> AnalysisReport:
    """Verify every structural invariant of a compiled ``CoreSchedule``.

    ``spec`` enables the two checks that need the network itself: the
    mapping re-derivation (SCH023) and the cycle-conservation replay
    against ``estimate_multicore_cost`` (SCH040-43).  Without it the
    purely-structural invariants still run.
    """
    pair = _pair_of(schedule.qspec)
    loc = schedule.name
    violations: list = []
    _check_capacity(schedule, loc, violations)
    _check_precision(schedule, loc, violations)
    _check_modes(schedule, spec, loc, violations)
    _check_routing(schedule, loc, violations)
    conservation = {}
    structural_ok = not any(v.severity == "error" for v in violations)
    if spec is not None and structural_ok:
        # The replay prices the schedule through the real cost model; only
        # meaningful once the structure itself is sound.
        conservation = _replay_conservation(schedule, spec, loc, violations)
    certificate = {
        "pass": "schedule",
        "network": schedule.name,
        "n_cores": schedule.n_cores,
        "precision": list(pair),
        "n_layers": len(schedule.layers),
        "n_split_layers": schedule.n_split_layers,
        "cores_used": list(schedule.cores_used),
        "route_factor_total": sum(
            layer.route_factor for layer in schedule.layers),
        "conservation": conservation,
        "ok": not any(v.severity == "error" for v in violations),
    }
    return AnalysisReport(
        subject=f"{schedule.name}@{pair[0]}/{pair[1]}b x{schedule.n_cores}",
        passes=("schedule",),
        violations=tuple(violations),
        certificates={"schedule": certificate},
    )
