"""Unified SpiDR deployment API: one ``DeployTarget`` -> ``CompiledSNN``.

The public face of the reproduction.  Declare *where* a network deploys
with :class:`DeployTarget` (weight/Vmem precision pair, core count,
backend, chunking, stream capacity, compiler overrides), then compile:

    from repro import spidr

    target = spidr.DeployTarget(weight_bits=4, n_cores=4)
    compiled = spidr.compile(spec, params, target)       # float params
    compiled = spidr.compile(exported, spec, target)     # trained integers

    out = compiled.run(events)            # whole (T, B, H, W, C) tensors
    session = compiled.open_stream()      # persistent-Vmem streaming slots
    cost = compiled.cost(out)             # calibrated cycles/energy
    compiled.save(path)                   # integer artifact ->
    compiled = spidr.load(path)           # ...rebuilt deployment
    report = compiled.verify()            # round-trip parity proof

    compiled.snapshot(path)               # live serving state (weights +
    compiled = spidr.restore(path)        #  every open stream) -> resumed
                                          #  bit-exactly in a fresh process

    fleet = spidr.serve(compiled,         # replicated serving fleet with
                        n_replicas=2)     #  scheduling, shedding and live
    handle = fleet.submit(events)         #  cross-replica migration
    fleet.drain(); fleet.shutdown()

Every path is bit-exact with the internal layers it fronts
(``repro.engine``, ``repro.compiler``, ``repro.serving``,
``repro.snn.export`` — documented internals; see ``docs/api.md`` for the
lifecycle walkthrough and ``docs/serving.md`` for the fleet).
"""
from ..serving import Fleet, FleetOverloaded, ServeConfig, StreamHandle, serve
from .compiled import (
    CompiledSNN,
    SlotUpdate,
    StreamSession,
    VerifyReport,
    compile,
    load,
    read_snapshot_meta,
    restore,
)
from .target import BACKENDS, PRECISION_PAIRS, DeployTarget

__all__ = [
    "BACKENDS",
    "CompiledSNN",
    "DeployTarget",
    "Fleet",
    "FleetOverloaded",
    "PRECISION_PAIRS",
    "ServeConfig",
    "SlotUpdate",
    "StreamHandle",
    "StreamSession",
    "VerifyReport",
    "compile",
    "load",
    "read_snapshot_meta",
    "restore",
    "serve",
]
