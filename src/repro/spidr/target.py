"""The declarative deployment target: every knob of a SpiDR deployment.

SpiDR's pitch is reconfigurability — one chip adapting to neuron models,
bit precisions, core counts and operating modes before execution (paper
Sec I).  :class:`DeployTarget` is that configuration surface in one
declarative object: the weight/Vmem precision pair, the core count, the
execution backend, the streaming chunk geometry, and the compiler's
mode/stationarity overrides.  ``spidr.compile(network, params, target)``
turns a target plus a network into a :class:`~repro.spidr.CompiledSNN`.

Validation is eager and *actionable*: an unsupported setting raises
``ValueError`` naming the nearest supported alternative(s), never a bare
assert — ``DeployTarget(weight_bits=5, vmem_bits=9)`` tells you that
``(5, 9)`` is unsupported and that ``(4, 7)`` and ``(6, 11)`` are the
nearest supported pairs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from ..core.quant import PRECISION_PAIRS, QuantSpec
from ..kernels.fused_lif_gemm import DEFAULT_BLOCK

__all__ = ["BACKENDS", "DeployTarget", "PRECISION_PAIRS"]

# Execution backends: the Pallas fused kernel, its pure-jnp bit-exact
# oracle, and the unjitted python-loop reference (slow; for verification).
BACKENDS = ("fused", "jnp", "reference")


def _nearest_pairs(w: int, v: int, n: int = 2) -> list:
    """The ``n`` supported precision pairs closest to ``(w, v)``."""
    return sorted(PRECISION_PAIRS, key=lambda p: abs(p[0] - w) + abs(p[1] - v))[:n]


def _require_positive_int(name: str, value, minimum: int = 1,
                          hint: str = "") -> None:
    if not isinstance(value, int) or isinstance(value, bool) \
            or value < minimum:
        raise ValueError(
            f"{name}={value!r} unsupported — needs an integer >= {minimum}"
            + (f" ({hint})" if hint else ""))


@dataclasses.dataclass(frozen=True)
class DeployTarget:
    """Where and how a network deploys: one declarative configuration.

    Precision
        ``weight_bits`` (4/6/8) selects the weight/Vmem pair; ``vmem_bits``
        defaults to the silicon invariant ``2*weight_bits - 1`` and may be
        passed explicitly (it is validated against the supported pairs).

    Topology
        ``n_cores`` > 1 routes the build through the multi-core compiler
        (partition/place/schedule onto a core grid) — bit-exact with
        single-core execution.  ``device_parallel`` forces ``shard_map``
        over a real device mesh (None = auto when the host has the
        devices); ``force_mode`` / ``stationarity`` pin the compiler's
        per-layer operating-mode (1/2) and weight-vs-Vmem stationarity
        choices instead of letting the cost model pick;
        ``assumed_sparsity`` feeds its load-balancing heuristics.

    Execution
        ``backend`` is ``"fused"`` (Pallas kernels), ``"jnp"`` (the pure-jnp
        bit-exact oracle) or ``"reference"`` (unjitted python-loop oracle —
        slow, for verification).  ``interpret`` (None = auto: on unless the
        host is a TPU), ``skip_empty`` and ``block`` configure the fused
        kernels; ``t_block`` > 1 switches them to the Vmem-stationary
        multi-timestep tiling and ``autotune=True`` measures the fastest
        per-layer (block, T_blk) at compile time and caches it by
        shape+precision (``repro.kernels.autotune``).

    Streaming
        ``stream_capacity`` slots of persistent Vmem and ``chunk_T``
        timesteps per delivered chunk configure sessions opened with
        :meth:`~repro.spidr.CompiledSNN.open_stream`.
    """

    weight_bits: int = 4
    vmem_bits: Optional[int] = None      # None -> 2*weight_bits - 1
    n_cores: int = 1
    backend: str = "jnp"                 # "fused" | "jnp" | "reference"
    chunk_T: int = 2
    stream_capacity: int = 4
    # Fused-kernel execution knobs.
    interpret: Optional[bool] = None     # None -> auto (on unless on TPU)
    skip_empty: bool = True
    block: tuple = DEFAULT_BLOCK
    # Vmem-stationary timestep tiling: >1 runs fused chunks layer-outer in
    # T_blk-sized slabs (each weight block read once per slab, not once per
    # timestep).  Bit-exact with t_block=1 for any value.
    t_block: int = 1
    # Measure-and-cache the fastest (block_m, block_n, block_k, T_blk) per
    # weight layer at compile time (kernels.autotune); fused backend only.
    autotune: bool = False
    # Multi-core compiler knobs.
    device_parallel: Optional[bool] = None
    force_mode: Optional[int] = None     # pin operating mode 1 | 2
    stationarity: Optional[str] = None   # pin "weight" | "vmem"
    assumed_sparsity: float = 0.9

    def __post_init__(self):
        w = self.weight_bits
        v = self.vmem_bits if self.vmem_bits is not None else 2 * w - 1
        if not isinstance(w, int) or not isinstance(v, int) \
                or (w, v) not in PRECISION_PAIRS:
            near = ", ".join(str(p) for p in _nearest_pairs(
                w if isinstance(w, int) else 0,
                v if isinstance(v, int) else 0))
            raise ValueError(
                f"weight/Vmem precision pair ({w}, {v}) unsupported — "
                f"nearest supported: {near}")
        object.__setattr__(self, "vmem_bits", v)
        if self.backend not in BACKENDS:
            raise ValueError(
                f"backend {self.backend!r} unsupported — supported "
                f"backends: {', '.join(BACKENDS)}")
        _require_positive_int(
            "n_cores", self.n_cores,
            hint="1 runs single-core, 4 matches the paper's grid ablations")
        _require_positive_int(
            "chunk_T", self.chunk_T,
            hint="timesteps delivered per streaming tick")
        _require_positive_int(
            "stream_capacity", self.stream_capacity,
            hint="concurrent persistent-Vmem stream slots")
        _require_positive_int(
            "t_block", self.t_block,
            hint="timesteps per Vmem-stationary kernel slab; 1 disables "
            "tiling")
        if self.autotune and self.backend != "fused":
            raise ValueError(
                f"autotune=True tunes the fused Pallas kernels but "
                f"backend={self.backend!r} never runs them — deploy with "
                "backend='fused' (or drop autotune)")
        if self.force_mode is not None and self.force_mode not in (1, 2):
            raise ValueError(
                f"force_mode={self.force_mode!r} unsupported — the macro "
                "has operating modes 1 (fan-in <= 128) and 2 (serialized "
                "high fan-in); pass 1, 2 or None (auto)")
        if self.stationarity is not None \
                and self.stationarity not in ("weight", "vmem"):
            raise ValueError(
                f"stationarity={self.stationarity!r} unsupported — pass "
                "'weight', 'vmem' or None (let the compiler's cost model "
                "choose per layer)")
        if not 0.0 <= self.assumed_sparsity < 1.0:
            raise ValueError(
                f"assumed_sparsity={self.assumed_sparsity!r} unsupported — "
                "needs 0.0 <= s < 1.0 (it feeds the compiler's load-"
                "balancing heuristics; 0.9 matches DVS event streams)")

    @property
    def qspec(self) -> QuantSpec:
        return QuantSpec(self.weight_bits)

    @property
    def multicore(self) -> bool:
        return self.n_cores > 1
