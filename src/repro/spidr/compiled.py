"""``spidr.compile(network, params, target) -> CompiledSNN``: the facade.

One entry point from a network to a deployed SpiDR instance.  Internally it
routes through the existing layers — ``engine`` (fused timestep loop),
``compiler`` (multi-core partition/place/schedule), ``snn.export``
(train->deploy integer folding) and ``engine.streaming`` (persistent-Vmem
sessions) — which are documented internals; every launcher, benchmark,
example and doc constructs deployments through this module instead.

Two input forms, matching the two legacy build chains bit-for-bit:

  * ``compile(spec, float_params, target)`` quantizes with per-tensor
    scales (the legacy ``build_engine`` chain — untrained/ad-hoc params);
  * ``compile(exported, spec, target)`` deploys a trained
    :class:`~repro.snn.export.ExportedNetwork` (per-channel power-of-two
    scales, the legacy ``snn.export.deploy`` chain) — bit-identical to the
    QAT training graph.

``target.n_cores > 1`` additionally routes through
``compiler.compile_network`` + ``engine.compile_engine``; the compiled
plan is bit-exact with single-core execution under any chunking, so every
:class:`CompiledSNN` method behaves identically at any core count.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
import time
import warnings
from typing import TYPE_CHECKING, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint.checkpoint import Checkpointer
from ..compiler import compile_network
from ..core.network import SNNSpec
from ..core.pipeline import PipelineState
from ..core.quant import QuantSpec
from ..engine.cost import estimate_cost, estimate_multicore_cost
from ..engine.inference import (
    EngineConfig,
    EngineLayer,
    EngineOutput,
    SNNEngine,
    build_engine,
    compile_engine,
    run_engine,
    run_reference,
)
from ..engine.streaming import (
    SESSION_SCHEMA_VERSION,
    SlotUpdate,
    StreamSessionManager,
)
from ..obs import metrics as obs_metrics
from ..obs import timeline as obs_timeline
from ..obs import trace as obs_trace
from ..snn.export import (
    ExportedLayer,
    ExportedNetwork,
    RoundTrip,
    deploy,
    save_exported,
    load_exported,
    verify_roundtrip,
)
from .target import DeployTarget, _require_positive_int

if TYPE_CHECKING:  # pragma: no cover - annotation-only import
    from ..analysis import AnalysisReport

#: ``spidr.compile(..., check=...)`` modes for the static-analysis gate.
CHECK_MODES = ("strict", "warn", "off")

__all__ = [
    "CompiledSNN",
    "SlotUpdate",
    "StreamSession",
    "VerifyReport",
    "compile",
    "load",
    "read_snapshot_meta",
    "restore",
]

# Live-session snapshot artifact: one Checkpointer step whose metadata
# carries this key.  Distinct from the ``snn.export`` weight artifact
# (``CompiledSNN.save``) — a snapshot additionally serializes every open
# session's slot state, table and handshake clocks, so ``spidr.restore``
# resumes serving bit-exactly in a fresh process.
_SNAPSHOT_META_KEY = "spidr_session_snapshot"
SNAPSHOT_VERSION = 1


def _engine_config(target: DeployTarget) -> EngineConfig:
    """Lower a :class:`DeployTarget` onto the engine's execution config."""
    interpret = target.interpret
    if interpret is None:
        # The fused kernels' revisited-accumulator grid is only sequential
        # on TPU hardware; everywhere else they run interpreted.
        interpret = jax.default_backend() != "tpu"
    return EngineConfig(
        QuantSpec(target.weight_bits),
        # "reference" executes the jnp datapath through the unjitted
        # python-loop oracle (see CompiledSNN.run).
        backend="fused" if target.backend == "fused" else "jnp",
        interpret=bool(interpret),
        skip_empty=target.skip_empty,
        block=tuple(target.block),
        t_block=target.t_block,
    )


def _autotune_engine(base: SNNEngine, spec: SNNSpec, target: DeployTarget,
                     cfg: EngineConfig) -> SNNEngine:
    """Bake measured per-layer kernel configs into ``base``.

    Consults :func:`repro.kernels.autotune.autotune_layer` per weight
    layer (cached by shape+precision, optionally persisted via
    ``$SPIDR_AUTOTUNE_CACHE``) and attaches the winner as
    ``EngineLayer.kcfg``.  Every candidate is bit-exact, so tuning
    changes wall time only, never results.
    """
    from ..kernels.autotune import autotune_layer

    tracer = obs_trace.default_tracer()
    reg = obs_metrics.default_registry()
    t_sweep = time.perf_counter()
    shapes = iter(spec.layer_shapes())
    new_layers = []
    with tracer.span("autotune", cat="compile", network=spec.name):
        for li, el in enumerate(base.layers):
            if el.kind not in ("conv", "fc"):
                new_layers.append(el)
                continue
            sh = next(shapes)
            rows = sh.out_positions if el.kind == "conv" else 1
            with tracer.span("autotune.layer", cat="compile", layer=li,
                             kind=el.kind, rows=rows,
                             channels=sh.out_channels):
                winner = autotune_layer(
                    rows, sh.fan_in, sh.out_channels,
                    target.weight_bits, target.vmem_bits,
                    timesteps=min(spec.timesteps, 8),
                    sparsity=target.assumed_sparsity,
                    interpret=cfg.interpret, skip_empty=cfg.skip_empty)
            if reg:
                # Info-gauge: the chosen KernelConfig rides in the labels
                # (value is a constant 1, Prometheus "info" idiom).
                bm, bn, bk, tb = winner.kcfg
                reg.gauge(
                    "spidr_autotune_kcfg_info",
                    "Chosen per-layer kernel config (info gauge)",
                    labels={"network": spec.name, "layer": li,
                            "kind": el.kind, "block_m": bm, "block_n": bn,
                            "block_k": bk, "t_block": tb}).set(1.0)
            new_layers.append(dataclasses.replace(el, kcfg=winner.kcfg))
    if reg:
        reg.counter(
            "spidr_autotune_seconds_total",
            "Wall seconds spent in autotune sweeps").inc(
                time.perf_counter() - t_sweep)
        reg.counter(
            "spidr_autotune_layers_total",
            "Weight layers autotuned").inc(
                sum(1 for el in base.layers if el.kind in ("conv", "fc")))
    return dataclasses.replace(base, layers=tuple(new_layers))


@dataclasses.dataclass(frozen=True)
class VerifyReport:
    """Result of :meth:`CompiledSNN.verify`: the deployment's proof chain.

    ``reference_exact``    engine output == the unjitted pure-jnp
                           python-loop oracle on the same integers.
    ``single_core_exact``  compiled multi-core plan == the single-core
                           engine (None when the target is single-core).
    ``roundtrip``          QAT training-graph parity
                           (:class:`~repro.snn.export.RoundTrip`; None
                           when no float params are available).
    """

    exact: bool
    reference_exact: bool
    single_core_exact: Optional[bool] = None
    roundtrip: Optional[RoundTrip] = None

    def __bool__(self) -> bool:  # pragma: no cover - convenience
        return self.exact


class StreamSession:
    """Session handle over a bank of persistent-Vmem stream slots.

    Wraps an ``engine.streaming.StreamSessionManager``: ``capacity`` slots
    multiplexed into one fixed-shape jitted chunk step per tick.  The
    delivery contract is the manager's (every open slot delivers a chunk
    every tick; a short chunk ends its stream) — violations raise with the
    manager's diagnostics instead of corrupting state.

    Lifecycle contract (tested in ``tests/test_fleet.py``): the session is
    a context manager; :meth:`close` is idempotent — closing an already
    closed slot (or the whole session twice) is a no-op — while
    :meth:`open`/:meth:`step` on a closed session raise ``RuntimeError``.
    """

    def __init__(self, engine: SNNEngine, capacity: int, chunk_T: int,
                 collect_chunk_counts: bool = False, metrics=None,
                 tracer=None, device=None):
        self._manager = StreamSessionManager(
            engine, capacity=capacity, chunk_T=chunk_T, metrics=metrics,
            tracer=tracer, collect_chunk_counts=collect_chunk_counts,
            device=device)
        self._closed = False

    @property
    def capacity(self) -> int:
        return self._manager.capacity

    @property
    def chunk_T(self) -> int:
        return self._manager.chunk_T

    @property
    def occupancy(self) -> int:
        return self._manager.occupancy

    @property
    def active(self) -> tuple:
        """Per-slot open flags (index = slot id)."""
        return tuple(self._manager.active)

    def state_dict(self) -> dict:
        """The session's full durable state as a deterministic pure-numpy
        tree (see ``StreamSessionManager.state_dict``): every slot's
        integer engine state, the session table, and the resumable
        handshake clocks.  Fresh host copies — never aliases live state."""
        return self._manager.state_dict()

    def load_state_dict(self, d: dict) -> None:
        """Restore the session to a :meth:`state_dict` snapshot bit-exactly
        (the session must have matching capacity/engine geometry)."""
        self._manager.load_state_dict(d)

    @property
    def closed(self) -> bool:
        """True once the whole session was retired via no-arg :meth:`close`
        (or by leaving its ``with`` block)."""
        return self._closed

    def _require_open(self, what: str) -> None:
        if self._closed:
            raise RuntimeError(
                f"cannot {what} on a closed StreamSession — open a new "
                "session with CompiledSNN.open_stream()")

    def open(self) -> Optional[int]:
        """Allocate a slot for a new stream; None if the session is full."""
        self._require_open("open a stream")
        return self._manager.open()

    def step(self, chunks: dict) -> dict:
        """Advance every open slot by one chunk: ``{slot: (t, H, W, C)}``
        events in, ``{slot: SlotUpdate}`` incremental replies out."""
        self._require_open("step")
        return self._manager.step(chunks)

    def close(self, slot: Optional[int] = None) -> None:
        """Retire one stream slot — or, with no argument, the whole session.

        Idempotent by contract: closing a slot that is not open, or
        closing an already closed session, is a no-op (the double-close
        of a shared handle is not an error worth crashing a server for).
        A no-arg close retires every open slot and marks the session
        closed; subsequent :meth:`open`/:meth:`step` raise
        ``RuntimeError``.
        """
        if slot is None:
            for s, active in enumerate(self._manager.active):
                if active:
                    self._manager.close(s)
            self._closed = True
            return
        if self._closed or not self._manager.active[slot]:
            return
        self._manager.close(slot)

    def export_slot(self, slot: int) -> dict:
        """One live stream's durable state as a pure-numpy tree — feed to
        another session's :meth:`import_slot` to migrate the stream
        bit-exactly (see ``StreamSessionManager.export_slot``)."""
        self._require_open("export a slot")
        return self._manager.export_slot(slot)

    def import_slot(self, payload: dict, slot: Optional[int] = None) -> int:
        """Install a migrated stream's :meth:`export_slot` payload into a
        free slot (first free by default); returns the destination slot."""
        self._require_open("import a slot")
        return self._manager.import_slot(payload, slot)

    def iter_chunks(self, events, slot: Optional[int] = None):
        """Serve one whole stream through this session, yielding each
        chunk's :class:`SlotUpdate`.

        ``events`` is one stream's ``(T, H, W, C)`` frames; they are
        delivered ``chunk_T`` timesteps per tick.  With no ``slot`` the
        helper opens one (raising ``RuntimeError`` when the session is
        full) and closes it when the stream ends — including on early
        ``break``/error, since generator cleanup runs the ``finally``.
        Other live slots must keep delivering through their own ``step``
        calls as usual; this helper is the one-stream convenience path.
        """
        self._require_open("iterate a stream")
        events = np.asarray(events)
        own = slot is None
        if own:
            slot = self._manager.open()
            if slot is None:
                raise RuntimeError(
                    f"session is full ({self.capacity} slots live) — "
                    "close a stream or open a larger session")
        try:
            for lo in range(0, events.shape[0], self.chunk_T):
                yield self._manager.step(
                    {slot: events[lo:lo + self.chunk_T]})[slot]
        finally:
            if own and not self._closed and self._manager.active[slot]:
                self._manager.close(slot)

    def __enter__(self) -> "StreamSession":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class CompiledSNN:
    """A deployed SpiDR network: engine + schedule behind one lifecycle.

    Built by :func:`compile` / :func:`load`; owns the executable
    :class:`~repro.engine.SNNEngine` (single- or multi-core) and exposes
    the whole deployment lifecycle:

      ``run(events)``      whole-tensor inference over ``(T, B, H, W, C)``
      ``open_stream()``    persistent-Vmem streaming session
      ``cost(result)``     calibrated chip cycles/energy for a run
      ``save(path)``       persist the integer artifact (``spidr.load``
                           rebuilds the deployment from it)
      ``verify()``         round-trip parity proof

    Everything is bit-exact with the internal layers it fronts: the same
    spike trains, costs and checkpoints as hand-wiring ``build_engine`` /
    ``compile_network`` / ``compile_engine`` / ``run_chunk`` /
    ``StreamSessionManager`` / ``snn.export`` directly.
    """

    def __init__(self, spec: SNNSpec, target: DeployTarget,
                 engine: SNNEngine, base_engine: SNNEngine,
                 exported: Optional[ExportedNetwork] = None,
                 params=None):
        self.spec = spec
        self.target = target
        self.engine = engine
        self.exported = exported
        self.params = params
        self._base_engine = base_engine  # single-core engine (oracle)
        self._jit_run = None
        self._sessions: list = []       # every StreamSession opened here
        self._analysis: Optional["AnalysisReport"] = None

    # -- introspection -----------------------------------------------------
    @property
    def schedule(self):
        """The compiler's :class:`CoreSchedule` (None on single core)."""
        return self.engine.schedule

    @property
    def n_cores(self) -> int:
        return self.target.n_cores

    def report(self) -> "AnalysisReport":
        """The deployment's static-analysis report (``repro.analysis``).

        Overflow certificates plus schedule verification for *this*
        network at *this* precision and core count.  Populated by
        :func:`compile` unless it ran with ``check="off"``; computed
        lazily here otherwise — so the certificate is always available,
        the ``check`` mode only decides whether findings gate the build.
        """
        if self._analysis is None:
            from .. import analysis

            self._analysis = analysis.analyze_deployment(
                self.spec, self.target.qspec, self.schedule)
        return self._analysis

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (f"CompiledSNN({self.spec.name!r}, "
                f"{self.target.weight_bits}/{self.target.vmem_bits}-bit, "
                f"{self.target.n_cores} core(s), "
                f"backend={self.target.backend!r}, "
                f"{'exported' if self.exported is not None else 'per-tensor'}"
                " weights)")

    # -- whole-tensor inference --------------------------------------------
    def run(self, events) -> EngineOutput:
        """Run a whole ``(T, B, H, W, C)`` binary event stream.

        Returns the engine's :class:`~repro.engine.EngineOutput` (readout +
        per-timestep spike statistics) — pass it to :meth:`cost` to price
        the run on the calibrated chip models.
        """
        # Hot path: a facade dispatch must cost nothing next to the engine
        # (benchmarks/run.py facade_overhead gates it at <1% wall time).
        run_fn = self._jit_run
        if run_fn is not None and isinstance(events, jax.Array) \
                and events.ndim == 5:
            return run_fn(events)
        events = jnp.asarray(events)
        if events.ndim != 5:
            raise ValueError(
                f"expected events of shape (T, B, H, W, C); got "
                f"{events.shape} — a single stream needs a batch axis "
                "(events[:, None])")
        if self.target.backend == "reference":
            return run_reference(self.engine, events)
        if self._jit_run is None:
            self._jit_run = jax.jit(functools.partial(run_engine, self.engine))
        return self._jit_run(events)

    # -- streaming ---------------------------------------------------------
    def open_stream(self, capacity: Optional[int] = None,
                    chunk_T: Optional[int] = None,
                    collect_chunk_counts: bool = False, metrics=None,
                    tracer=None, device=None) -> StreamSession:
        """Open a persistent-Vmem streaming session.

        ``capacity`` / ``chunk_T`` default to the target's
        ``stream_capacity`` / ``chunk_T``.  A stream served through the
        session is bit-identical to a whole-stream :meth:`run` on that
        stream alone, whatever shares the batch.  (A ``"reference"``
        target streams through the jitted jnp datapath — same integers,
        same spikes.)

        ``collect_chunk_counts=True`` makes every ``SlotUpdate`` carry its
        chunk's per-layer input-spike counts, so a server can re-price a
        finished stream with ``collect_timeline=True`` and export its
        per-core pipeline timeline (``launch/serve.py --trace-out``).

        ``metrics`` / ``tracer``: session telemetry (``repro.obs``).
        ``None`` uses the process-wide defaults (disabled unless
        ``obs.enable_metrics()``/``enable_tracing()`` ran); pass a private
        ``MetricsRegistry``/``Tracer`` to isolate, or ``False`` to pin
        telemetry hard off for this session.

        ``device`` commits the session's resident state to one host
        device, so a fleet of sessions over the same deployment ticks on
        distinct devices (``spidr.serve`` replica placement).
        """
        capacity = self.target.stream_capacity if capacity is None \
            else capacity
        chunk_T = self.target.chunk_T if chunk_T is None else chunk_T
        _require_positive_int("capacity", capacity,
                              hint="concurrent persistent-Vmem stream slots")
        _require_positive_int("chunk_T", chunk_T,
                              hint="timesteps delivered per streaming tick")
        session = StreamSession(self.engine, capacity=capacity,
                                chunk_T=chunk_T, metrics=metrics,
                                tracer=tracer,
                                collect_chunk_counts=collect_chunk_counts,
                                device=device)
        self._sessions.append(session)
        return session

    @property
    def sessions(self) -> tuple:
        """Every :class:`StreamSession` opened on this deployment, in
        :meth:`open_stream` order — the set :meth:`snapshot` serializes."""
        return tuple(self._sessions)

    # -- chip cost ---------------------------------------------------------
    def cost(self, result=None, input_counts=None):
        """Price a run on the calibrated chip models.

        Pass the :class:`~repro.engine.EngineOutput` from :meth:`run` (or
        any object with per-timestep ``input_counts``), or a raw
        ``(T, n_weight_layers)`` array via ``input_counts``.  Returns an
        ``EngineCost`` (single core) or ``MulticoreCost`` (compiled plan,
        with per-core attribution and routing overhead).
        """
        counts = self._counts_of(result, input_counts)
        if self.schedule is not None:
            return estimate_multicore_cost(self.spec, self.schedule, counts)
        return estimate_cost(self.spec, self.target.qspec, counts)

    @staticmethod
    def _counts_of(result, input_counts) -> np.ndarray:
        if input_counts is None:
            if result is None or getattr(result, "input_counts", None) is None:
                raise ValueError(
                    "cost() needs spike statistics: pass the EngineOutput "
                    "from run() (with collect_counts on), or a raw "
                    "(T, n_weight_layers) array via input_counts=")
            input_counts = result.input_counts
        return np.asarray(input_counts)

    # -- telemetry ---------------------------------------------------------
    def metrics(self, fmt: str = "prometheus"):
        """Export the process-wide metrics registry (``repro.obs``).

        ``fmt="prometheus"`` returns the text exposition format,
        ``fmt="json"`` the JSON-friendly dict.  Empty unless metrics were
        enabled (``obs.enable_metrics()`` or ``serve.py --metrics-out``)
        before the instrumented paths ran.
        """
        reg = obs_metrics.default_registry()
        if fmt in ("prometheus", "prom", "text"):
            return reg.to_prometheus()
        if fmt == "json":
            return reg.to_dict()
        raise ValueError(
            f"unknown metrics format {fmt!r} — use 'prometheus' or 'json'")

    def pipeline_trace(self, result=None, input_counts=None, path=None,
                       label: str = "run", pid: int = 1) -> list:
        """Chrome-trace pipeline timeline of a run on the compiled plan.

        Prices the run's spike statistics through
        ``estimate_multicore_cost(..., collect_timeline=True)`` and
        renders the simulated per-core async-pipeline clocks (busy /
        AER-routing / idle intervals, one track per core) as Chrome-trace
        events — summed busy+routing durations equal
        ``MulticoreCost.busy_cycles`` exactly.  Returns the event list;
        ``path`` additionally writes a Perfetto-loadable JSON file.
        Multi-core targets only.
        """
        if self.schedule is None:
            raise ValueError(
                "pipeline_trace() renders the multi-core pipeline clocks — "
                "this deployment is single-core (target.n_cores == 1)")
        counts = self._counts_of(result, input_counts)
        cost = estimate_multicore_cost(self.spec, self.schedule, counts,
                                       collect_timeline=True)
        events = obs_timeline.multicore_timeline(cost, label=label, pid=pid)
        if path is not None:
            obs_timeline.write_chrome_trace(events, path)
        return events

    # -- performance model -------------------------------------------------
    def roofline(self, batch: int = 1, timesteps: Optional[int] = None,
                 nonzero_tile_fracs=None) -> dict:
        """Predicted wall-time bound for one chunk on this deployment.

        Prices the compiled engine's actual tiling (per-layer autotuned
        ``kcfg`` when present, else the target's ``block``/``t_block``)
        through :class:`repro.roofline.PerfModel`: bytes-moved + MACs-at-
        sparsity per weight layer, ``bound_us`` = summed max(compute,
        memory) bound.  ``nonzero_tile_fracs`` is a per-weight-layer list
        of nonzero spike-tile fractions (measure with
        ``kernels.spike_tile_bitmap``); default prices dense spikes.
        """
        from ..roofline.analysis import PerfModel

        kcfgs = [el.kcfg for el in self._base_engine.layers
                 if el.kind in ("conv", "fc")]
        cfg = self._base_engine.cfg
        return PerfModel().network_bound(
            self.spec, batch=batch, timesteps=timesteps,
            t_block=cfg.t_block, block=cfg.block,
            nonzero_tile_fracs=nonzero_tile_fracs,
            layer_kcfgs=kcfgs)

    # -- persistence -------------------------------------------------------
    def save(self, path, step: int = 0) -> None:
        """Persist the deployment's integer artifact under ``path``.

        Writes the standard ``snn.export`` checkpoint (atomic, validated
        on reload); ``spidr.load(path)`` rebuilds an equivalent
        :class:`CompiledSNN` from it, bit-exactly, at any target.
        """
        if self.exported is None:
            raise ValueError(
                "this CompiledSNN was compiled from float params with "
                "per-tensor scales, which the export checkpoint format "
                "does not represent — train/export first (snn.train.fit, "
                "then compile(exported, spec, target)) or deploy an "
                "ExportedNetwork to make save()/load() available")
        save_exported(Checkpointer(str(path)), step, self.exported,
                      spec=self.spec)

    def _layer_arrays(self) -> list:
        """The deployment's integer weights as plain numpy, one
        ``{"w_q", "w_scale", "thr_int"}`` per weight layer (None per pool).

        ``w_scale`` is widened to float64 so both provenances serialize
        losslessly: a per-tensor scale is a python float, a per-channel
        exported scale is float32 — either round-trips exactly.
        """
        out = []
        for el in self._base_engine.layers:
            if el.kind not in ("conv", "fc"):
                out.append(None)
                continue
            out.append({
                "w_q": np.asarray(el.w_q, np.int8),
                "w_scale": np.asarray(el.w_scale, np.float64),
                "thr_int": np.asarray(el.thr_int, np.int32),
            })
        return out

    def snapshot(self, path, step: int = 0, sessions=None,
                 extra: Optional[dict] = None) -> None:
        """Persist the complete live serving state under ``path``.

        One atomic, checksummed checkpoint step holding the deployment's
        integer weights plus every open streaming session's durable state
        (slot Vmems, session table, resumable handshake clocks — see
        ``StreamSessionManager.state_dict``).  ``spidr.restore(path)``
        rebuilds the deployment in a fresh process and resumes every
        stream bit-exactly: the same spikes, readouts and cumulative
        cycle/energy attribution as if serving was never interrupted.

        ``sessions`` defaults to every session opened via
        :meth:`open_stream`; ``extra`` is JSON-serializable caller
        bookkeeping (e.g. a server's stream-id/cursor table), returned by
        :func:`read_snapshot_meta`.
        """
        sessions = self.sessions if sessions is None else tuple(sessions)
        t0 = time.perf_counter()
        with obs_trace.default_tracer().span(
                "snapshot.save", cat="durability", path=str(path),
                sessions=len(sessions)):
            target_info = dataclasses.asdict(self.target)
            target_info["block"] = list(target_info["block"])
            info = {
                "version": SNAPSHOT_VERSION,
                "session_schema": SESSION_SCHEMA_VERSION,
                "provenance": ("exported" if self.exported is not None
                               else "per_tensor"),
                "target": target_info,
                "spec": _spec_info(self.spec),
                "sessions": [{"capacity": s.capacity, "chunk_T": s.chunk_T}
                             for s in sessions],
                "extra": extra or {},
            }
            tree = {"layers": self._layer_arrays(),
                    "sessions": [s.state_dict() for s in sessions]}
            Checkpointer(str(path)).save(
                step, tree, extra_meta={_SNAPSHOT_META_KEY: info})
        reg = obs_metrics.default_registry()
        if reg:
            reg.histogram(
                "spidr_snapshot_seconds",
                "CompiledSNN.snapshot wall duration",
                edges=obs_metrics.LATENCY_BUCKETS_S,
            ).observe(time.perf_counter() - t0)

    # -- the proof ---------------------------------------------------------
    def verify(self, events=None, params=None, batch: int = 2,
               seed: int = 0) -> VerifyReport:
        """Prove the deployment's round-trip parity on ``events``.

        Checks, all bit-exact (equal, not close): the engine against the
        unjitted pure-jnp python-loop oracle; a compiled multi-core plan
        against the single-core engine; and — when float params are
        available (``params`` here, or retained from :func:`compile`) —
        the deployed integers against the QAT training graph
        (``snn.export.verify_roundtrip``).  ``events`` defaults to a
        synthetic DVS batch matching the spec's head.
        """
        if events is None:
            from ..snn.data import make_flow_batch, make_gesture_batch

            make = (make_gesture_batch if self.spec.readout == "rate"
                    else make_flow_batch)
            events, _ = make(jax.random.PRNGKey(seed), batch=batch,
                             timesteps=self.spec.timesteps,
                             hw=self.spec.input_hw)
        events = jnp.asarray(events)
        out = self.run(events)
        ref = run_reference(self._base_engine, events)
        reference_exact = bool(
            (np.asarray(out.readout) == np.asarray(ref.readout)).all()
            and (np.asarray(out.spike_counts)
                 == np.asarray(ref.spike_counts)).all())
        single_core_exact = None
        if self.schedule is not None:
            single = run_engine(self._base_engine, events)
            single_core_exact = bool(
                (np.asarray(out.readout) == np.asarray(single.readout)).all()
                and (np.asarray(out.spike_counts)
                     == np.asarray(single.spike_counts)).all())
        roundtrip = None
        params = params if params is not None else self.params
        if self.exported is not None and params is not None:
            roundtrip = verify_roundtrip(params, self.spec, self.engine,
                                         events, self.exported,
                                         engine_out=out)
        exact = reference_exact \
            and single_core_exact is not False \
            and (roundtrip is None or roundtrip.exact)
        return VerifyReport(exact=exact, reference_exact=reference_exact,
                            single_core_exact=single_core_exact,
                            roundtrip=roundtrip)


def _apply_schedule(base: SNNEngine, spec: SNNSpec, target: DeployTarget,
                    cfg: EngineConfig) -> SNNEngine:
    """Bake the target's multi-core plan into ``base`` (identity on 1 core).

    Deterministic in (spec, target): the compiler's partition/place/
    schedule has no randomness, so a freshly compiled replica gets the
    same plan — a precondition for bit-exact multi-core session migration.
    """
    if target.n_cores <= 1:
        return base
    schedule = compile_network(
        spec, n_cores=target.n_cores, qspec=cfg.qspec,
        assumed_sparsity=target.assumed_sparsity,
        force_mode=target.force_mode,
        force_stationarity=target.stationarity)
    return compile_engine(base, schedule,
                          device_parallel=target.device_parallel)


def compile(network, params=None, target: Optional[DeployTarget] = None,
            *, spec: Optional[SNNSpec] = None,
            check: str = "warn") -> CompiledSNN:
    """Deploy a network onto a :class:`DeployTarget`.

    Two forms, one per quantization provenance:

      ``compile(spec, float_params, target)``
          quantize ``float_params`` into the integer engine with
          per-tensor scales (untrained / ad-hoc parameters — the legacy
          ``build_engine`` chain, bit-for-bit);

      ``compile(exported, spec, target)``
          deploy a trained :class:`~repro.snn.export.ExportedNetwork`
          (per-channel power-of-two scales — the legacy
          ``snn.export.deploy`` chain, bit-for-bit).  Optionally keep the
          trainer's float params for :meth:`CompiledSNN.verify` by passing
          ``compile(exported, float_params, target, spec=spec)``.

    ``target`` defaults to ``DeployTarget()`` (4/7-bit, single core, jnp
    backend).  ``target.n_cores > 1`` compiles the network across a core
    grid — bit-exact with single-core execution.

    ``check`` gates the build on deploy-time static analysis
    (``repro.analysis``: overflow certification + schedule
    verification).  ``"strict"`` raises
    :class:`~repro.analysis.AnalysisError` on any error-level finding,
    ``"warn"`` (the default) emits a ``RuntimeWarning``, ``"off"`` skips
    the analysis at compile time (``CompiledSNN.report()`` still
    computes it on demand).
    """
    if check not in CHECK_MODES:
        raise ValueError(
            f"check must be one of {CHECK_MODES}, got {check!r}")
    target = target or DeployTarget()
    with obs_trace.default_tracer().span(
            "spidr.compile", cat="compile", backend=target.backend,
            n_cores=target.n_cores, weight_bits=target.weight_bits):
        compiled = _compile(network, params, target, spec)
    if check != "off":
        from .. import analysis

        report = analysis.analyze_deployment(
            compiled.spec, target.qspec, compiled.schedule)
        compiled._analysis = report
        if report.errors:
            if check == "strict":
                raise analysis.AnalysisError(report)
            warnings.warn(
                f"static analysis found {len(report.errors)} violation(s) "
                f"in {report.subject} — see CompiledSNN.report() "
                "(compile with check='strict' to fail the build)",
                RuntimeWarning, stacklevel=2)
    return compiled


def _compile(network, params, target: DeployTarget,
             spec: Optional[SNNSpec]) -> CompiledSNN:
    cfg = _engine_config(target)
    if isinstance(network, ExportedNetwork):
        if spec is None and isinstance(params, SNNSpec):
            spec, params = params, None
        if spec is None:
            raise ValueError(
                "deploying an ExportedNetwork needs its SNNSpec: "
                "compile(exported, spec, target) or "
                "compile(exported, float_params, target, spec=spec)")
        if target.weight_bits != network.weight_bits:
            raise ValueError(
                f"target executes {target.weight_bits}-bit weights but the "
                f"network was exported at {network.weight_bits}-bit — "
                f"re-export, or deploy with DeployTarget(weight_bits="
                f"{network.weight_bits})")
        base = deploy(network, spec, cfg, n_cores=1)
        exported = network
    elif isinstance(network, SNNSpec):
        spec = network
        if params is None:
            raise ValueError(
                "compiling an SNNSpec needs its float params: "
                "compile(spec, params, target) — params from "
                "core.network.init_params or a snn.train fit; a trained "
                "integer artifact deploys via compile(exported, spec, "
                "target) instead")
        base = build_engine(spec, params, cfg)
        exported = None
    else:
        raise TypeError(
            f"compile() takes an SNNSpec or an ExportedNetwork, got "
            f"{type(network).__name__} — build a spec with "
            "core.network.gesture_net/optical_flow_net (or a config's "
            "reduced()), or an exported network with snn.train + "
            "snn.export")
    if target.autotune and cfg.backend == "fused":
        base = _autotune_engine(base, spec, target, cfg)
    with obs_trace.default_tracer().span(
            "compiler.schedule", cat="compile", n_cores=target.n_cores):
        engine = _apply_schedule(base, spec, target, cfg)
    return CompiledSNN(spec=spec, target=target, engine=engine,
                       base_engine=base, exported=exported, params=params)


def load(path, spec: Optional[SNNSpec] = None,
         target: Optional[DeployTarget] = None,
         step: Optional[int] = None) -> CompiledSNN:
    """Rebuild a deployment from a :meth:`CompiledSNN.save` checkpoint.

    Reads the standard ``snn.export`` artifact under ``path`` (any
    checkpoint written by ``save_exported`` loads too), validates it, and
    deploys it onto ``target``.  ``spec`` defaults to the paper network
    named in the checkpoint's metadata, restored to the event geometry
    (``input_hw``/``timesteps``) the artifact was saved at —
    ``CompiledSNN.save`` records it, so a save→load round trip rebuilds
    the deployment exactly.  Pass the spec explicitly for artifacts
    written by a bare legacy ``save_exported`` call at reduced geometry
    (without it, the paper network's full-size geometry is assumed).
    ``target`` defaults to the checkpoint's exported precision on one
    core.
    """
    ckpt = Checkpointer(str(path))
    if step is None:
        step = ckpt.latest_step()
        if step is None:
            raise FileNotFoundError(
                f"no checkpoint steps under {ckpt.directory} — was the "
                "deployment saved with CompiledSNN.save (or "
                "snn.export.save_exported)?")
    if spec is None:
        from ..snn.export import read_export_meta
        from ..snn.train import spec_for

        info = read_export_meta(ckpt, step)
        name = info.get("name")
        try:
            spec = spec_for(name)
        except (ValueError, TypeError):
            raise ValueError(
                f"checkpoint step {step} names network {name!r}, which is "
                "not one of the paper's specs — pass the SNNSpec it was "
                "trained with: load(path, spec=...)") from None
        if "input_hw" in info:
            spec = dataclasses.replace(
                spec, input_hw=tuple(info["input_hw"]),
                timesteps=int(info.get("timesteps", spec.timesteps)))
    exported = load_exported(ckpt, spec, step)
    if target is None:
        target = DeployTarget(weight_bits=exported.weight_bits)
    return compile(exported, spec, target)


# ---------------------------------------------------------------------------
# Live-session snapshots: CompiledSNN.snapshot -> spidr.restore
# ---------------------------------------------------------------------------
def _spec_info(spec: SNNSpec) -> dict:
    """The spec geometry a snapshot pins (and restore re-validates)."""
    return {"name": spec.name, "input_hw": list(spec.input_hw),
            "in_channels": int(spec.in_channels),
            "timesteps": int(spec.timesteps), "readout": spec.readout,
            "n_layers": len(spec.layers)}


def _target_from_info(d: dict) -> DeployTarget:
    """Rebuild the snapshot's :class:`DeployTarget` from its JSON form."""
    kw = dict(d)
    kw["block"] = tuple(kw["block"])
    try:
        return DeployTarget(**kw)
    except TypeError as e:
        raise ValueError(
            f"the snapshot's DeployTarget does not match this build's "
            f"fields: {e} — re-snapshot with this version") from e


def _layer_arrays_template(spec: SNNSpec, per_channel: bool) -> list:
    """Structure template for the snapshot's weight tree.

    Shapes are derived from the spec alone (weights are not needed to
    *describe* the tree, only to fill it); ``per_channel`` mirrors the
    provenance recorded in the snapshot — exported networks carry (K,)
    scale/threshold vectors, per-tensor deployments carry scalars.
    """
    like = []
    for layer in spec.layers:
        if layer.kind == "conv":
            f, k = layer.conv.kh * layer.conv.kw * layer.c_in, layer.c_out
        elif layer.kind == "fc":
            f, k = layer.c_in, layer.c_out
        else:
            like.append(None)
            continue
        sshape = (k,) if per_channel else ()
        like.append({"w_q": np.zeros((f, k), np.int8),
                     "w_scale": np.zeros(sshape, np.float64),
                     "thr_int": np.zeros(sshape, np.int32)})
    return like


def _session_state_template(spec: SNNSpec, capacity: int,
                            n_cores: int) -> dict:
    """Structure template matching ``StreamSessionManager.state_dict``.

    Built engine-free: Vmem shapes come from the network definition
    (``core.network._init_state``), so restore can describe the serialized
    tree before any engine exists — the weights themselves are part of the
    same checkpoint being restored.
    """
    from ..core.network import _init_state

    vmem = [None if v is None else np.zeros(v.shape, np.int32)
            for v in _init_state(spec, capacity)]
    if spec.readout == "rate":
        acc = np.zeros((capacity, spec.layers[-1].c_out), np.int32)
    else:
        acc = np.zeros(next(v for v in reversed(vmem)
                            if v is not None).shape, np.int32)
    n_l = sum(1 for layer in spec.layers if layer.kind in ("conv", "fc"))
    return {
        "schema": np.int64(SESSION_SCHEMA_VERSION),
        "engine_state": {
            "vmem": vmem,
            "readout_acc": acc,
            "out_counts": np.zeros((n_l, capacity), np.int32),
            "in_counts": np.zeros((n_l, capacity), np.int32),
        },
        "table": {
            "active": np.zeros(capacity, np.bool_),
            "ended": np.zeros(capacity, np.bool_),
            "timesteps": np.zeros(capacity, np.int64),
            "spikes": np.zeros(capacity, np.int64),
            "cycles": np.zeros(capacity, np.int64),
            "energy_uj": np.zeros(capacity, np.float64),
            "route_cycles": np.zeros((capacity, n_cores), np.int64),
            "core_cycles": np.zeros((capacity, n_cores), np.int64),
            "imbalance": np.ones(capacity, np.float64),
            "ticks": np.int64(0),
        },
        "clocks": [[PipelineState.zero().to_dict()
                    for _ in range(n_cores)] for _ in range(capacity)],
    }


def _compile_from_arrays(spec: SNNSpec, target: DeployTarget,
                         cfg: EngineConfig, arrays: list,
                         per_channel: bool, name: str) -> CompiledSNN:
    """Rebuild a deployment from a snapshot's serialized integer weights,
    through the same build chain the original took (``deploy`` for
    exported networks, direct :class:`EngineLayer` construction mirroring
    ``build_engine`` for per-tensor) — so the restored engine is
    bit-identical to the one snapshotted."""
    if per_channel:
        ex_layers = tuple(
            None if d is None else ExportedLayer(
                w_q=np.asarray(d["w_q"], np.int8),
                scale=np.asarray(d["w_scale"], np.float32),
                thr_int=np.asarray(d["thr_int"], np.int32))
            for d in arrays)
        exported = ExportedNetwork(name=name,
                                   weight_bits=target.weight_bits,
                                   layers=ex_layers)
        base = deploy(exported, spec, cfg, n_cores=1)
    else:
        exported = None
        layers = []
        for layer, d in zip(spec.layers, arrays):
            if layer.kind == "conv":
                layers.append(EngineLayer(
                    kind="conv", neuron=layer.conv.neuron,
                    w_q=jnp.asarray(np.asarray(d["w_q"], np.int8)),
                    w_scale=float(d["w_scale"]),
                    thr_int=int(d["thr_int"]),
                    kh=layer.conv.kh, kw=layer.conv.kw,
                    stride=layer.conv.stride, padding=layer.conv.padding))
            elif layer.kind == "fc":
                layers.append(EngineLayer(
                    kind="fc", neuron=layer.fc.neuron,
                    w_q=jnp.asarray(np.asarray(d["w_q"], np.int8)),
                    w_scale=float(d["w_scale"]),
                    thr_int=int(d["thr_int"])))
            elif layer.kind == "pool":
                layers.append(EngineLayer(kind="pool"))
            else:
                layers.append(EngineLayer(kind="adaptive_pool",
                                          target_hw=layer.target_hw))
        base = SNNEngine(spec=spec, cfg=cfg, layers=tuple(layers))
    if target.autotune and cfg.backend == "fused":
        base = _autotune_engine(base, spec, target, cfg)
    engine = _apply_schedule(base, spec, target, cfg)
    return CompiledSNN(spec=spec, target=target, engine=engine,
                       base_engine=base, exported=exported)


def read_snapshot_meta(path, step: Optional[int] = None) -> dict:
    """Read a :meth:`CompiledSNN.snapshot` artifact's metadata.

    No state is loaded — just the JSON record: format version, deployment
    target, spec geometry, session geometries, and the caller's ``extra``
    bookkeeping, plus the resolved ``step``.  Raises ``FileNotFoundError``
    when no step exists and ``ValueError`` when the checkpoint is not a
    session snapshot.
    """
    ckpt = Checkpointer(str(path))
    if step is None:
        step = ckpt.latest_step()
        if step is None:
            raise FileNotFoundError(
                f"no snapshot steps under {ckpt.directory} — was "
                "CompiledSNN.snapshot called?")
    with open(os.path.join(ckpt.directory,
                           f"step_{step:09d}", "meta.json")) as f:
        meta = json.load(f)
    info = meta.get(_SNAPSHOT_META_KEY)
    if info is None:
        raise ValueError(
            f"checkpoint step {step} under {ckpt.directory} is not a spidr "
            f"session snapshot (no {_SNAPSHOT_META_KEY!r} metadata) — "
            "weight artifacts from CompiledSNN.save load via spidr.load; "
            "snapshots come from CompiledSNN.snapshot")
    return dict(info, step=int(step))


def restore(path, spec: Optional[SNNSpec] = None,
            compiled: Optional[CompiledSNN] = None,
            step: Optional[int] = None) -> CompiledSNN:
    """Resume a serving deployment from a :meth:`CompiledSNN.snapshot`.

    Validates the checkpoint (crc32 per leaf, format/schema versions),
    rebuilds the deployment from its serialized integer weights onto the
    snapshot's :class:`DeployTarget`, reopens every serialized streaming
    session and reloads its slots, table and handshake clocks.  Every
    resumed stream then emits spikes, readouts and cumulative cycle/energy
    attribution byte-identical to the uninterrupted run — on any backend
    and core count the snapshot was taken at.

    ``spec`` is only needed for networks that are not one of the paper's
    named specs (the snapshot records the name + event geometry, like
    :func:`load`).  Pass ``compiled`` to migrate onto a prepared replica
    instead of rebuilding: it must be compiled for the identical target
    and carry byte-identical weights, or ``ValueError`` — a snapshot's
    session state is meaningless on any other deployment.
    """
    with obs_trace.default_tracer().span("snapshot.restore",
                                         cat="durability", path=str(path)):
        return _restore(path, spec, compiled, step)


def _restore(path, spec: Optional[SNNSpec],
             compiled: Optional[CompiledSNN],
             step: Optional[int]) -> CompiledSNN:
    info = read_snapshot_meta(path, step)
    step = info["step"]
    target = _target_from_info(info["target"])
    per_channel = info["provenance"] == "exported"
    sinfo = dict(info["spec"])
    if compiled is not None:
        spec = compiled.spec
    if spec is None:
        from ..snn.train import spec_for

        try:
            spec = spec_for(sinfo["name"])
        except (ValueError, TypeError):
            raise ValueError(
                f"snapshot names network {sinfo['name']!r}, which is not "
                "one of the paper's specs — pass the SNNSpec it was "
                "compiled with: restore(path, spec=...)") from None
        spec = dataclasses.replace(spec, input_hw=tuple(sinfo["input_hw"]),
                                   timesteps=int(sinfo["timesteps"]))
    if _spec_info(spec) != sinfo:
        raise ValueError(
            f"spec geometry {_spec_info(spec)} does not match the "
            f"snapshot's {sinfo} — restore onto the network the snapshot "
            "was taken on")
    cfg = _engine_config(target)
    like = {"layers": _layer_arrays_template(spec, per_channel),
            "sessions": [_session_state_template(spec, s["capacity"],
                                                 target.n_cores)
                         for s in info["sessions"]]}
    # host=True: the session tables carry int64/float64 accounting which
    # must round-trip exactly (32-bit jax would truncate it).
    tree = Checkpointer(str(path)).restore(step, like, host=True)
    if compiled is not None:
        if compiled.target != target:
            raise ValueError(
                f"snapshot was taken on {target}, but the prepared replica "
                f"is compiled for {compiled.target} — migration is only "
                "bit-exact onto the identical DeployTarget")
        mine = compiled._layer_arrays()
        for i, (a, b) in enumerate(zip(mine, tree["layers"])):
            same = (a is None) == (b is None) and (
                a is None or (np.array_equal(a["w_q"], b["w_q"])
                              and np.array_equal(a["w_scale"], b["w_scale"])
                              and np.array_equal(a["thr_int"],
                                                 b["thr_int"])))
            if not same:
                raise ValueError(
                    f"weight layer {i} of the prepared replica is not "
                    "byte-identical to the snapshot's — a session snapshot "
                    "only resumes on the deployment it was taken from")
    else:
        compiled = _compile_from_arrays(spec, target, cfg, tree["layers"],
                                        per_channel, sinfo["name"])
    for geo, sess_state in zip(info["sessions"], tree["sessions"]):
        session = compiled.open_stream(geo["capacity"], geo["chunk_T"])
        session.load_state_dict(sess_state)
    return compiled
