"""Synthetic DVS event streams (stand-ins for IBM DVS Gestures / DSEC-flow).

The real datasets are not redistributable offline (DESIGN.md §7), so we
synthesize event-camera-like data with the same statistical structure:

  * Gesture-like streams: a bright oriented edge sweeping across the frame
    with class-dependent direction/curvature; ON/OFF polarity channels;
    per-pixel Bernoulli events where intensity changes — sparsity in the
    80-99 % band like the real sensor.
  * Flow-like streams: a random dot/texture field translating with a
    constant (per-sample) velocity; ground-truth flow = that velocity.
    Events fire where the pattern edge crosses a pixel.

Everything is deterministic given the seed, making tests and the Fig 16
trade-off reproducible.

Streaming: a live DVS sensor never hands you a complete ``(T, ...)`` tensor.
``make_gesture_chunk`` / ``make_flow_chunk`` synthesize any window
``[t0, t0 + chunk_T)`` of the *same* stream a whole-batch call would
produce (each timestep depends only on the absolute ``t`` and the stream's
seed-derived parameters), so concatenating consecutive chunks is
bit-identical to the whole-stream tensor — the property the streaming
engine tests rely on.  ``iter_event_chunks`` wraps that as a generator.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

__all__ = [
    "GestureBatch",
    "FlowBatch",
    "iter_event_chunks",
    "make_flow_batch",
    "make_flow_chunk",
    "make_gesture_batch",
    "make_gesture_chunk",
]

N_GESTURE_CLASSES = 11  # IBM DVS gestures has 11 classes


@dataclasses.dataclass
class GestureBatch:
    events: jax.Array  # (T, B, H, W, 2) binary
    labels: jax.Array  # (B,) int32


@dataclasses.dataclass
class FlowBatch:
    events: jax.Array  # (T, B, H, W, 2) binary
    flow: jax.Array    # (B, H, W, 2) ground-truth (vx, vy), pixels/timestep


def _moving_edge_frame(t, hw, angle, speed, phase, key, noise=0.002):
    """One timestep of ON/OFF events from an edge sweeping at ``angle``."""
    h, w = hw
    yy, xx = jnp.meshgrid(jnp.arange(h), jnp.arange(w), indexing="ij")
    # Signed distance to a moving line.
    c, s = jnp.cos(angle), jnp.sin(angle)
    pos = (t * speed + phase) % (h + w)
    d = c * xx + s * yy - pos
    band = jnp.abs(d) < 1.5
    on = band & (d >= 0)
    off = band & (d < 0)
    k1, k2 = jax.random.split(key)
    noise_on = jax.random.bernoulli(k1, noise, (h, w))
    noise_off = jax.random.bernoulli(k2, noise, (h, w))
    return jnp.stack([on | noise_on, off | noise_off], axis=-1).astype(jnp.float32)


def _gesture_stream_params(key: jax.Array, batch: int):
    """Seed-derived per-stream parameters, shared by batch and chunk paths."""
    k_lbl, k_phase, k_noise = jax.random.split(key, 3)
    labels = jax.random.randint(k_lbl, (batch,), 0, N_GESTURE_CLASSES)
    angles = 2.0 * jnp.pi * labels / N_GESTURE_CLASSES
    speeds = 1.5 + 0.5 * (labels % 3)
    phases = jax.random.uniform(k_phase, (batch,), minval=0.0, maxval=20.0)
    return labels, angles, speeds, phases, k_noise


def _gesture_events(ts, hw, batch, angles, speeds, phases, k_noise):
    """Event frames for the absolute timesteps ``ts`` of one stream batch."""
    def per_t(t):
        keys = jax.random.split(jax.random.fold_in(k_noise, t), batch)
        return jax.vmap(
            lambda a, sp, ph, kk: _moving_edge_frame(t, hw, a, sp, ph, kk)
        )(angles, speeds, phases, keys)

    return jax.vmap(per_t)(ts)


@partial(jax.jit, static_argnames=("batch", "timesteps", "hw"))
def make_gesture_batch(
    key: jax.Array, batch: int = 16, timesteps: int = 20, hw: tuple = (64, 64)
):
    """Class k sweeps an edge at angle ~ 2*pi*k/11 with class-coded speed."""
    labels, angles, speeds, phases, k_noise = _gesture_stream_params(key, batch)
    events = _gesture_events(jnp.arange(timesteps), hw, batch,
                             angles, speeds, phases, k_noise)
    return events, labels


@partial(jax.jit, static_argnames=("batch", "chunk_T", "hw"))
def make_gesture_chunk(
    key: jax.Array, t0, batch: int = 16, chunk_T: int = 4,
    hw: tuple = (64, 64),
):
    """Timesteps ``[t0, t0 + chunk_T)`` of the stream ``key`` defines.

    Bit-identical to ``make_gesture_batch(key, ...)[0][t0:t0 + chunk_T]``
    for any ``t0`` — each frame depends only on the absolute timestep and
    the seed, so a sensor feed can be synthesized chunk by chunk without
    ever materializing the whole stream.  ``t0`` may be traced: one
    compilation serves every chunk position.
    """
    labels, angles, speeds, phases, k_noise = _gesture_stream_params(key, batch)
    events = _gesture_events(t0 + jnp.arange(chunk_T), hw, batch,
                             angles, speeds, phases, k_noise)
    return events, labels


def _flow_stream_params(key: jax.Array, batch: int, hw: tuple,
                        density: float):
    """Seed-derived texture + velocity, shared by batch and chunk paths."""
    h, w = hw
    k_tex, k_vel = jax.random.split(key)
    # Static random texture per sample (binary dots).
    tex = jax.random.bernoulli(k_tex, density, (batch, h, w)).astype(jnp.float32)
    vel = jax.random.uniform(k_vel, (batch, 2), minval=-2.0, maxval=2.0)
    return tex, vel


def _flow_events(ts, tex, vel):
    """Event frames for the absolute timesteps ``ts`` of one flow batch."""
    def shift(img, dxy):
        # Integer roll (events are discrete); subpixel handled by time.
        dx, dy = jnp.round(dxy[0]).astype(jnp.int32), jnp.round(dxy[1]).astype(jnp.int32)
        return jnp.roll(jnp.roll(img, dy, axis=0), dx, axis=1)

    def per_t(t):
        cur = jax.vmap(shift)(tex, vel * t)
        prev = jax.vmap(shift)(tex, vel * (t - 1))
        on = jnp.clip(cur - prev, 0, 1)
        off = jnp.clip(prev - cur, 0, 1)
        return jnp.stack([on, off], axis=-1)

    return jax.vmap(per_t)(ts)


@partial(jax.jit, static_argnames=("batch", "timesteps", "hw", "density"))
def make_flow_batch(
    key: jax.Array,
    batch: int = 4,
    timesteps: int = 10,
    hw: tuple = (288, 384),
    density: float = 0.05,
):
    """Random texture translating at a per-sample velocity; GT flow = v."""
    h, w = hw
    tex, vel = _flow_stream_params(key, batch, hw, density)
    events = _flow_events(jnp.arange(timesteps), tex, vel)
    flow = jnp.broadcast_to(vel[:, None, None, :], (batch, h, w, 2))
    return events, flow


@partial(jax.jit, static_argnames=("batch", "chunk_T", "hw", "density"))
def make_flow_chunk(
    key: jax.Array,
    t0,
    batch: int = 4,
    chunk_T: int = 4,
    hw: tuple = (288, 384),
    density: float = 0.05,
):
    """Timesteps ``[t0, t0 + chunk_T)`` of the flow stream ``key`` defines.

    Bit-identical to ``make_flow_batch(key, ...)[0][t0:t0 + chunk_T]`` —
    the texture/velocity are seed-derived (shared ``_flow_stream_params``)
    and each frame depends only on the absolute timestep.
    """
    h, w = hw
    tex, vel = _flow_stream_params(key, batch, hw, density)
    events = _flow_events(t0 + jnp.arange(chunk_T), tex, vel)
    flow = jnp.broadcast_to(vel[:, None, None, :], (batch, h, w, 2))
    return events, flow


def iter_event_chunks(
    key: jax.Array,
    total_T: int,
    chunk_T: int,
    batch: int = 1,
    hw: tuple = (64, 64),
    kind: str = "gesture",
):
    """Generator over consecutive ``(t, B, H, W, 2)`` chunks of one stream.

    Yields ``ceil(total_T / chunk_T)`` chunks whose concatenation is
    bit-identical to the corresponding whole-stream batch; the final chunk
    is shorter when ``chunk_T`` does not divide ``total_T``.  This is the
    shape of a live sensor feed: the consumer (``engine.run_chunk`` or a
    ``StreamSessionManager`` slot) sees events only as they "arrive".
    """
    assert kind in ("gesture", "flow"), kind
    make = make_gesture_chunk if kind == "gesture" else make_flow_chunk
    for t0 in range(0, total_T, chunk_T):
        ev, _ = make(key, t0, batch=batch, chunk_T=chunk_T, hw=hw)
        yield ev[: min(chunk_T, total_T - t0)]
