"""Synthetic DVS event streams (stand-ins for IBM DVS Gestures / DSEC-flow).

The real datasets are not redistributable offline (DESIGN.md §7), so we
synthesize event-camera-like data with the same statistical structure:

  * Gesture-like streams: a bright oriented edge sweeping across the frame
    with class-dependent direction/curvature; ON/OFF polarity channels;
    per-pixel Bernoulli events where intensity changes — sparsity in the
    80-99 % band like the real sensor.
  * Flow-like streams: a random dot/texture field translating with a
    constant (per-sample) velocity; ground-truth flow = that velocity.
    Events fire where the pattern edge crosses a pixel.

Everything is deterministic given the seed, making tests and the Fig 16
trade-off reproducible.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

__all__ = ["GestureBatch", "FlowBatch", "make_gesture_batch", "make_flow_batch"]

N_GESTURE_CLASSES = 11  # IBM DVS gestures has 11 classes


@dataclasses.dataclass
class GestureBatch:
    events: jax.Array  # (T, B, H, W, 2) binary
    labels: jax.Array  # (B,) int32


@dataclasses.dataclass
class FlowBatch:
    events: jax.Array  # (T, B, H, W, 2) binary
    flow: jax.Array    # (B, H, W, 2) ground-truth (vx, vy), pixels/timestep


def _moving_edge_frame(t, hw, angle, speed, phase, key, noise=0.002):
    """One timestep of ON/OFF events from an edge sweeping at ``angle``."""
    h, w = hw
    yy, xx = jnp.meshgrid(jnp.arange(h), jnp.arange(w), indexing="ij")
    # Signed distance to a moving line.
    c, s = jnp.cos(angle), jnp.sin(angle)
    pos = (t * speed + phase) % (h + w)
    d = c * xx + s * yy - pos
    band = jnp.abs(d) < 1.5
    on = band & (d >= 0)
    off = band & (d < 0)
    k1, k2 = jax.random.split(key)
    noise_on = jax.random.bernoulli(k1, noise, (h, w))
    noise_off = jax.random.bernoulli(k2, noise, (h, w))
    return jnp.stack([on | noise_on, off | noise_off], axis=-1).astype(jnp.float32)


@partial(jax.jit, static_argnames=("batch", "timesteps", "hw"))
def make_gesture_batch(
    key: jax.Array, batch: int = 16, timesteps: int = 20, hw: tuple = (64, 64)
):
    """Class k sweeps an edge at angle ~ 2*pi*k/11 with class-coded speed."""
    k_lbl, k_phase, k_noise = jax.random.split(key, 3)
    labels = jax.random.randint(k_lbl, (batch,), 0, N_GESTURE_CLASSES)
    angles = 2.0 * jnp.pi * labels / N_GESTURE_CLASSES
    speeds = 1.5 + 0.5 * (labels % 3)
    phases = jax.random.uniform(k_phase, (batch,), minval=0.0, maxval=20.0)

    def per_t(t):
        keys = jax.random.split(jax.random.fold_in(k_noise, t), batch)
        return jax.vmap(
            lambda a, sp, ph, kk: _moving_edge_frame(t, hw, a, sp, ph, kk)
        )(angles, speeds, phases, keys)

    events = jax.vmap(per_t)(jnp.arange(timesteps))
    return events, labels


@partial(jax.jit, static_argnames=("batch", "timesteps", "hw", "density"))
def make_flow_batch(
    key: jax.Array,
    batch: int = 4,
    timesteps: int = 10,
    hw: tuple = (288, 384),
    density: float = 0.05,
):
    """Random texture translating at a per-sample velocity; GT flow = v."""
    h, w = hw
    k_tex, k_vel = jax.random.split(key)
    # Static random texture per sample (binary dots).
    tex = jax.random.bernoulli(k_tex, density, (batch, h, w)).astype(jnp.float32)
    vel = jax.random.uniform(k_vel, (batch, 2), minval=-2.0, maxval=2.0)

    def shift(img, dxy):
        # Integer roll (events are discrete); subpixel handled by time.
        dx, dy = jnp.round(dxy[0]).astype(jnp.int32), jnp.round(dxy[1]).astype(jnp.int32)
        return jnp.roll(jnp.roll(img, dy, axis=0), dx, axis=1)

    def per_t(t):
        cur = jax.vmap(shift)(tex, vel * t)
        prev = jax.vmap(shift)(tex, vel * (t - 1))
        on = jnp.clip(cur - prev, 0, 1)
        off = jnp.clip(prev - cur, 0, 1)
        return jnp.stack([on, off], axis=-1)

    events = jax.vmap(per_t)(jnp.arange(timesteps))
    flow = jnp.broadcast_to(vel[:, None, None, :], (batch, h, w, 2))
    return events, flow
