"""Surrogate-gradient BPTT training for the paper's SNNs (QAT at 4/6/8 bit).

The accelerator needs no modified training methodology (Table III row
"Modified Training: No") — networks are trained offline with standard
surrogate-gradient BPTT + quantization-aware weights, then deployed
bit-exactly (digital CIM).  This module is that offline trainer:

  loss = cross-entropy over rate-coded output spikes   (gesture)
         average endpoint error (AEE) on final Vmem    (optical flow)

The spike nonlinearity's triangle surrogate lives in ``core.neuron``; the
weight fake-quant STE in ``core.quant``; both are exercised here through
``core.network.run_snn`` so training and deployment share one definition.
The default training mode is ``"qat"`` — the *deploy-exact* forward
(per-channel power-of-two fake quant, scaled Vmem saturation, digital leak
shift) whose spike trains are bit-identical to the exported integer engine
(see ``snn.export``); ``mode="train"`` keeps the legacy float-dynamics STE
path for ablations.

Three layers of API:

  * ``train_step`` / ``evaluate``     — one jitted scan-over-T batched
    update / metric pass (the building blocks).
  * ``fit``                           — full training run on the synthetic
    DVS streams: cosine LR schedule with warmup, periodic eval, optional
    checkpointing of the float params.
  * ``precision_sweep``               — the Fig 16 driver: train + export
    at every supported weight/Vmem precision pair (4/7, 6/11, 8/15) for
    either head, returning the trained state, the exported integers and
    the eval metric per precision.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from ..checkpoint.checkpoint import Checkpointer
from ..core.network import (
    SNNSpec,
    gesture_net,
    init_params,
    optical_flow_net,
    run_snn,
)
from ..core.quant import QuantSpec
from ..optim.optimizer import (
    adamw,
    apply_updates,
    clip_by_global_norm,
    linear_warmup_cosine,
)

__all__ = [
    "TrainConfig",
    "TrainState",
    "effective_spec",
    "evaluate",
    "fit",
    "init_train_state",
    "make_batch_fn",
    "precision_sweep",
    "spec_for",
    "train_step",
]

log = logging.getLogger("repro.snn.train")


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """Hashable (jit-static) training configuration."""

    weight_bits: int = 4
    mode: str = "qat"            # "qat" (deploy-exact) | "train" (legacy)
    lr: float = 1e-3
    weight_decay: float = 1e-4
    grad_clip: float = 1.0
    # Schedule / loop shape (used by ``fit``; ``train_step`` only needs the
    # schedule fields).
    steps: int = 100
    warmup: int = 10
    lr_final_frac: float = 0.1
    batch: int = 8
    timesteps: Optional[int] = None     # None -> spec.timesteps
    hw: Optional[tuple] = None          # None -> spec.input_hw
    eval_every: int = 0                 # 0 = eval only at the end
    eval_batch: int = 32
    eval_batches: int = 2
    ckpt_every: int = 0                 # 0 = no checkpointing
    seed: int = 0

    def __post_init__(self):
        assert self.mode in ("qat", "train"), self.mode


@dataclasses.dataclass
class TrainState:
    params: list
    opt_state: dict
    step: int


def init_train_state(key, spec: SNNSpec, cfg: TrainConfig) -> TrainState:
    params = init_params(key, spec)
    _, opt_state = adamw(lr=cfg.lr, weight_decay=cfg.weight_decay, params=params)
    return TrainState(params=params, opt_state=opt_state, step=0)


def _loss_fn(params, batch, spec: SNNSpec, cfg: TrainConfig):
    inputs, target = batch
    qspec = QuantSpec(cfg.weight_bits)
    out, _ = run_snn(params, inputs, spec, qspec, mode=cfg.mode)
    if spec.readout == "rate":
        logits = out  # spike counts as logits (rate code)
        logp = jax.nn.log_softmax(logits)
        loss = -jnp.mean(jnp.take_along_axis(logp, target[:, None], axis=1))
        acc = jnp.mean(jnp.argmax(logits, axis=-1) == target)
        return loss, {"loss": loss, "accuracy": acc}
    # Optical flow: average endpoint error on the Vmem readout.
    aee = jnp.mean(jnp.linalg.norm(out - target, axis=-1))
    return aee, {"loss": aee, "aee": aee}


@partial(jax.jit, static_argnames=("spec", "cfg"))
def _train_step_impl(params, opt_state, step, batch, spec: SNNSpec,
                     cfg: TrainConfig):
    (loss, metrics), grads = jax.value_and_grad(_loss_fn, has_aux=True)(
        params, batch, spec, cfg
    )
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    schedule = linear_warmup_cosine(cfg.lr, cfg.warmup, cfg.steps,
                                    cfg.lr_final_frac)
    update_fn, _ = adamw(lr=cfg.lr, weight_decay=cfg.weight_decay,
                         params=params, lr_schedule=schedule)
    updates, opt_state = update_fn(grads, opt_state, params, step)
    params = apply_updates(params, updates)
    metrics["grad_norm"] = gnorm
    return params, opt_state, metrics


def train_step(state: TrainState, batch, spec: SNNSpec, cfg: TrainConfig):
    """One jitted scan-over-T batched QAT update; returns (state', metrics)."""
    params, opt_state, metrics = _train_step_impl(
        state.params, state.opt_state, state.step, batch, spec, cfg,
    )
    return TrainState(params, opt_state, state.step + 1), metrics


@partial(jax.jit, static_argnames=("spec", "cfg"))
def _eval_impl(params, batch, spec: SNNSpec, cfg: TrainConfig):
    return _loss_fn(params, batch, spec, cfg)[1]


def evaluate(params, batches, spec: SNNSpec, cfg: TrainConfig,
             metric: str = "accuracy") -> float:
    vals = []
    for batch in batches:
        vals.append(float(_eval_impl(params, batch, spec, cfg)[metric]))
    return sum(vals) / len(vals)


# ---------------------------------------------------------------------------
# Full training runs on the synthetic DVS streams.
# ---------------------------------------------------------------------------
def effective_spec(spec: SNNSpec, cfg: TrainConfig) -> SNNSpec:
    """``spec`` with the config's frame-size/timestep overrides applied.

    ``cfg.hw`` / ``cfg.timesteps`` shrink the network *and* its data
    consistently (the topology is shape-agnostic); the returned spec is the
    one training actually runs — and therefore the one to export/deploy.
    """
    return dataclasses.replace(
        spec,
        input_hw=tuple(cfg.hw) if cfg.hw else spec.input_hw,
        timesteps=cfg.timesteps or spec.timesteps,
    )


def make_batch_fn(spec: SNNSpec, cfg: TrainConfig,
                  batch: Optional[int] = None) -> Callable:
    """``key -> (events, target)`` sampler for ``spec``'s head."""
    from .data import make_flow_batch, make_gesture_batch

    spec = effective_spec(spec, cfg)
    hw, ts = spec.input_hw, spec.timesteps
    b = batch or cfg.batch
    if spec.readout == "rate":
        return lambda key: make_gesture_batch(key, batch=b, timesteps=ts, hw=hw)
    return lambda key: make_flow_batch(key, batch=b, timesteps=ts, hw=hw)


def _eval_metric(spec: SNNSpec) -> str:
    return "accuracy" if spec.readout == "rate" else "aee"


def fit(
    spec: SNNSpec,
    cfg: TrainConfig,
    key: Optional[jax.Array] = None,
    ckpt: Optional[Checkpointer] = None,
    log_every: int = 20,
):
    """Train ``spec`` on synthetic DVS streams for ``cfg.steps`` updates.

    Returns ``(state, history)`` where ``history`` carries the per-step
    losses, any periodic eval points and the final eval metric
    (``accuracy`` for rate heads, ``aee`` for flow heads).
    """
    spec = effective_spec(spec, cfg)
    key = jax.random.PRNGKey(cfg.seed) if key is None else key
    k_init, k_data, k_eval = jax.random.split(key, 3)
    state = init_train_state(k_init, spec, cfg)
    batch_fn = make_batch_fn(spec, cfg)
    eval_fn = make_batch_fn(spec, cfg, batch=cfg.eval_batch)
    metric = _eval_metric(spec)

    def run_eval():
        keys = jax.random.split(k_eval, max(cfg.eval_batches, 1))
        return evaluate(state.params, [eval_fn(k) for k in keys], spec, cfg,
                        metric)

    losses, evals = [], []
    t0 = time.time()
    for step in range(cfg.steps):
        k_data, k = jax.random.split(k_data)
        state, m = train_step(state, batch_fn(k), spec, cfg)
        losses.append(float(m["loss"]))
        if log_every and step % log_every == 0:
            log.info("step %d/%d loss=%.4f grad_norm=%.2f", step, cfg.steps,
                     losses[-1], float(m["grad_norm"]))
        if cfg.eval_every and (step + 1) % cfg.eval_every == 0:
            evals.append((step + 1, run_eval()))
        if ckpt is not None and cfg.ckpt_every and \
                (step + 1) % cfg.ckpt_every == 0:
            ckpt.save_async(step + 1, state.params)
    if ckpt is not None:
        ckpt.wait()
    final = run_eval()
    history = {
        "loss": losses,
        "evals": evals,
        "metric": metric,
        "final": final,
        "wall_s": time.time() - t0,
    }
    log.info("fit(%s, %db): loss %.4f -> %.4f, %s=%.4f in %.1fs",
             spec.name, cfg.weight_bits,
             losses[0] if losses else float("nan"),
             losses[-1] if losses else float("nan"),
             metric, final, history["wall_s"])
    return state, history


def spec_for(task: str) -> SNNSpec:
    """``"gesture"`` / ``"optical-flow"`` -> the paper's network spec."""
    if task in ("gesture", "spidr-gesture"):
        return gesture_net()
    if task in ("optical-flow", "optical_flow", "flow", "spidr-optical-flow"):
        return optical_flow_net()
    raise ValueError(f"unknown SNN task {task!r}")


def precision_sweep(
    task: str = "gesture",
    bits: tuple = (4, 6, 8),
    cfg: Optional[TrainConfig] = None,
    spec: Optional[SNNSpec] = None,
    key: Optional[jax.Array] = None,
) -> dict:
    """Train + export one network per weight/Vmem precision pair.

    The Fig 16 trade-off driver: for each ``b`` in ``bits``, trains
    ``task``'s network with the deploy-exact QAT forward at ``b``-bit
    weights ((2b-1)-bit Vmem), folds it into the integer format, and
    records the eval metric.  Returns ``{bits: {"state", "history",
    "exported", "metric"}}``; deployment cost (cycles/energy per core
    count) is layered on by ``benchmarks/run.py --qat-sweep``.
    """
    from .export import export_network

    base = cfg or TrainConfig()
    spec = spec or spec_for(task)
    out = {}
    for b in bits:
        bcfg = dataclasses.replace(base, weight_bits=b)
        state, history = fit(spec, bcfg, key=key)
        exported = export_network(state.params, effective_spec(spec, bcfg),
                                  QuantSpec(b))
        out[b] = {
            "state": state,
            "history": history,
            "exported": exported,
            "metric": history["final"],
        }
    return out
