"""Surrogate-gradient BPTT training for the paper's SNNs (QAT at 4/6/8 bit).

The accelerator needs no modified training methodology (Table III row
"Modified Training: No") — networks are trained offline with standard
surrogate-gradient BPTT + quantization-aware weights, then deployed
bit-exactly (digital CIM).  This module is that offline trainer:

  loss = cross-entropy over rate-coded output spikes   (gesture)
         average endpoint error (AEE) on final Vmem    (optical flow)

The spike nonlinearity's triangle surrogate lives in ``core.neuron``; the
weight fake-quant STE in ``core.quant``; both are exercised here through
``core.network.run_snn`` so training and deployment share one definition.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from ..core.network import SNNSpec, init_params, run_snn
from ..core.quant import QuantSpec
from ..optim.optimizer import adamw, apply_updates, clip_by_global_norm

__all__ = ["TrainConfig", "TrainState", "init_train_state", "train_step", "evaluate"]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    weight_bits: int = 4
    lr: float = 1e-3
    weight_decay: float = 1e-4
    grad_clip: float = 1.0


@dataclasses.dataclass
class TrainState:
    params: list
    opt_state: dict
    step: int


def init_train_state(key, spec: SNNSpec, cfg: TrainConfig) -> TrainState:
    params = init_params(key, spec)
    _, opt_state = adamw(lr=cfg.lr, weight_decay=cfg.weight_decay, params=params)
    return TrainState(params=params, opt_state=opt_state, step=0)


def _loss_fn(params, batch, spec: SNNSpec, qspec: QuantSpec):
    inputs, target = batch
    out, _ = run_snn(params, inputs, spec, qspec, mode="train")
    if spec.readout == "rate":
        logits = out  # spike counts as logits (rate code)
        logp = jax.nn.log_softmax(logits)
        loss = -jnp.mean(jnp.take_along_axis(logp, target[:, None], axis=1))
        acc = jnp.mean(jnp.argmax(logits, axis=-1) == target)
        return loss, {"loss": loss, "accuracy": acc}
    # Optical flow: average endpoint error on the Vmem readout.
    aee = jnp.mean(jnp.linalg.norm(out - target, axis=-1))
    return aee, {"loss": aee, "aee": aee}


@partial(jax.jit, static_argnames=("spec", "weight_bits", "lr", "weight_decay", "grad_clip"))
def _train_step_impl(params, opt_state, step, batch, spec, weight_bits, lr,
                     weight_decay, grad_clip):
    qspec = QuantSpec(weight_bits)
    (loss, metrics), grads = jax.value_and_grad(_loss_fn, has_aux=True)(
        params, batch, spec, qspec
    )
    grads, gnorm = clip_by_global_norm(grads, grad_clip)
    update_fn, _ = adamw(lr=lr, weight_decay=weight_decay, params=params)
    updates, opt_state = update_fn(grads, opt_state, params, step)
    params = apply_updates(params, updates)
    metrics["grad_norm"] = gnorm
    return params, opt_state, metrics


def train_step(state: TrainState, batch, spec: SNNSpec, cfg: TrainConfig):
    params, opt_state, metrics = _train_step_impl(
        state.params, state.opt_state, state.step, batch, spec,
        cfg.weight_bits, cfg.lr, cfg.weight_decay, cfg.grad_clip,
    )
    return TrainState(params, opt_state, state.step + 1), metrics


def evaluate(params, batches, spec: SNNSpec, cfg: TrainConfig,
             metric: str = "accuracy") -> float:
    qspec = QuantSpec(cfg.weight_bits)
    vals = []
    for batch in batches:
        _, m = _loss_fn(params, batch, spec, qspec)
        vals.append(float(m[metric]))
    return sum(vals) / len(vals)
