"""Bit-exact export: fold QAT-trained float params into the integer engine.

SpiDR's training story (Table III, "Modified Training: No") is that networks
are trained offline with standard surrogate-gradient BPTT + QAT and then
deployed *unchanged* on the digital CIM datapath.  This module is that
train->deploy seam:

  * ``export_network``  — fold the trainer's float weights into the engine's
    signed-integer format: per-output-channel power-of-two scales
    (``core.quant.po2_quantize``, the exact quantizer the QAT forward uses),
    int8 weight matrices, and per-channel integer thresholds requantized
    onto each layer's Vmem grid (``B_vmem = 2*B_w - 1`` saturation contract).
  * ``deploy``          — build an executable :class:`SNNEngine` from the
    exported integers, optionally compiled across ``n_cores`` SpiDR cores
    through ``compiler.compile_network``.
  * ``save_exported`` / ``load_exported`` — persist the integer artifact via
    ``checkpoint.Checkpointer`` (atomic, validated on reload).
  * ``verify_roundtrip`` — the proof obligation: run the *training graph*
    (``run_snn(mode="qat")``, post-STE) and the deployed integer engine on
    the same event streams and require identical spike trains and readouts.

Why this is exact rather than approximate: the QAT forward fake-quantizes
with power-of-two per-channel scales, so every float intermediate is
``scale * <integer>`` with the integer far below 2**24 — representable
exactly in float32.  Saturation bounds, the digital leak shift and the
requantized threshold all commute with that scaling, so the float training
graph *is* the integer datapath, viewed through a power-of-two lens.  The
exported integers are produced by the same ``po2_quantize`` call the
training forward used: nothing is re-derived at deploy time.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from ..checkpoint.checkpoint import Checkpointer
from ..compiler import compile_network
from ..core.network import SNNSpec, run_snn
from ..core.quant import (
    PRECISION_PAIRS,
    QuantSpec,
    po2_quantize,
    requantize_threshold,
)
from ..engine.inference import (
    EngineConfig,
    EngineLayer,
    SNNEngine,
    compile_engine,
    run_engine,
)

__all__ = [
    "ExportedLayer",
    "ExportedNetwork",
    "RoundTrip",
    "deploy",
    "dequantize_readout",
    "export_network",
    "load_exported",
    "read_export_meta",
    "save_exported",
    "verify_roundtrip",
]


@dataclasses.dataclass(frozen=True)
class ExportedLayer:
    """One weight layer in deployable integer form."""

    w_q: np.ndarray      # (F, K) int8 signed weights
    scale: np.ndarray    # (K,) float32 power-of-two per-channel scales
    thr_int: np.ndarray  # (K,) int32 thresholds on the layer's Vmem grid


@dataclasses.dataclass(frozen=True)
class ExportedNetwork:
    """A trained network folded into SpiDR's integer weight format.

    ``layers`` is aligned with ``spec.layers`` / the trainer's params list:
    an :class:`ExportedLayer` per weight layer, ``None`` per pool layer.
    """

    name: str
    weight_bits: int
    layers: tuple

    @property
    def qspec(self) -> QuantSpec:
        return QuantSpec(self.weight_bits)


def export_network(params, spec: SNNSpec, qspec: QuantSpec) -> ExportedNetwork:
    """Fold trained float params into the engine's signed-integer format.

    Per weight layer: symmetric per-output-channel power-of-two quantization
    of the weights (the same ``po2_quantize`` the QAT forward ran, so the
    integers are identical to what training saw through the STE), and the
    float firing threshold requantized onto the layer's integer Vmem grid.
    """
    layers = []
    for layer, p in zip(spec.layers, params):
        if layer.kind not in ("conv", "fc"):
            layers.append(None)
            continue
        neuron = layer.conv.neuron if layer.kind == "conv" else layer.fc.neuron
        q, scale = po2_quantize(jnp.asarray(p), qspec, axis=0)
        scale_k = scale[0]  # (1, K) -> (K,)
        thr_int, _ = requantize_threshold(neuron.threshold, scale_k, qspec)
        layers.append(ExportedLayer(
            w_q=np.asarray(q),
            scale=np.asarray(scale_k, np.float32),
            thr_int=np.asarray(thr_int, np.int32),
        ))
    return ExportedNetwork(name=spec.name, weight_bits=qspec.weight_bits,
                           layers=tuple(layers))


def deploy(
    exported: ExportedNetwork,
    spec: SNNSpec,
    cfg: Optional[EngineConfig] = None,
    n_cores: int = 1,
    device_parallel: Optional[bool] = None,
) -> SNNEngine:
    """Build an executable integer engine from an exported network.

    ``n_cores > 1`` compiles the network across a SpiDR core grid
    (``compiler.compile_network`` -> ``engine.compile_engine``); the result
    is bit-exact with single-core execution under any chunking.  ``cfg``
    defaults to the pure-jnp backend at the exported precision.
    """
    cfg = cfg or EngineConfig(exported.qspec, backend="jnp")
    if cfg.qspec.weight_bits != exported.weight_bits:
        raise ValueError(
            f"engine executes {cfg.qspec} but the checkpoint was exported "
            f"at {exported.weight_bits}-bit weights; re-export or change "
            "the EngineConfig")
    layers = []
    for layer, ex in zip(spec.layers, exported.layers):
        if layer.kind == "conv":
            layers.append(EngineLayer(
                kind="conv", neuron=layer.conv.neuron,
                w_q=jnp.asarray(ex.w_q), w_scale=ex.scale,
                thr_int=jnp.asarray(ex.thr_int),
                kh=layer.conv.kh, kw=layer.conv.kw,
                stride=layer.conv.stride, padding=layer.conv.padding,
            ))
        elif layer.kind == "fc":
            layers.append(EngineLayer(
                kind="fc", neuron=layer.fc.neuron,
                w_q=jnp.asarray(ex.w_q), w_scale=ex.scale,
                thr_int=jnp.asarray(ex.thr_int),
            ))
        elif layer.kind == "pool":
            layers.append(EngineLayer(kind="pool"))
        elif layer.kind == "adaptive_pool":
            layers.append(EngineLayer(kind="adaptive_pool",
                                      target_hw=layer.target_hw))
        else:  # pragma: no cover - spec validated upstream
            raise ValueError(layer.kind)
    engine = SNNEngine(spec=spec, cfg=cfg, layers=tuple(layers))
    if n_cores > 1:
        schedule = compile_network(spec, n_cores=n_cores, qspec=cfg.qspec)
        engine = compile_engine(engine, schedule,
                                device_parallel=device_parallel)
    return engine


def dequantize_readout(exported: ExportedNetwork, spec: SNNSpec, readout):
    """Map an integer engine readout back onto the training graph's scale.

    ``"rate"`` readouts are plain spike counts (scale-free); ``"vmem"``
    readouts are integers on the last weight layer's grid and dequantize by
    its per-channel power-of-two scale — exactly, so the result equals the
    QAT graph's float readout bit for bit.
    """
    if spec.readout == "rate":
        return jnp.asarray(readout, jnp.float32)
    last = next(ex for ex in reversed(exported.layers) if ex is not None)
    return jnp.asarray(readout, jnp.float32) * jnp.asarray(last.scale)


# ---------------------------------------------------------------------------
# Persistence: one Checkpointer step per exported artifact.
# ---------------------------------------------------------------------------
_EXPORT_META_KEY = "exported_snn"


def _as_tree(exported: ExportedNetwork):
    return [
        None if ex is None
        else {"w_q": ex.w_q, "scale": ex.scale, "thr_int": ex.thr_int}
        for ex in exported.layers
    ]


def read_export_meta(ckpt: Checkpointer, step: int) -> dict:
    """The ``exported_snn`` metadata of one checkpoint step ({} if absent).

    The single parser for the export artifact's metadata — the facade's
    ``spidr.load`` and :func:`load_exported` both read through it, so the
    key and layout cannot drift between them.
    """
    import json
    import os

    path = os.path.join(ckpt.directory, f"step_{step:09d}", "meta.json")
    with open(path) as f:
        meta = json.load(f)
    return meta.get(_EXPORT_META_KEY) or {}


def save_exported(ckpt: Checkpointer, step: int, exported: ExportedNetwork,
                  spec: Optional[SNNSpec] = None) -> None:
    """Persist an exported network (atomic, one ``step_*`` directory).

    Pass the ``spec`` the network was trained/exported at to record its
    event geometry (``input_hw``/``timesteps``) in the metadata —
    ``spidr.load`` then rebuilds the deployment at that geometry instead
    of the paper network's full-size default when no spec is given.
    """
    info = {
        "name": exported.name,
        "weight_bits": exported.weight_bits,
    }
    if spec is not None:
        info["input_hw"] = list(spec.input_hw)
        info["timesteps"] = int(spec.timesteps)
    ckpt.save(step, _as_tree(exported), extra_meta={_EXPORT_META_KEY: info})


def load_exported(ckpt: Checkpointer, spec: SNNSpec,
                  step: Optional[int] = None) -> ExportedNetwork:
    """Reload an exported network, validating the artifact.

    Raises ``ValueError`` on a checkpoint that was not written by
    ``save_exported``, lacks the export metadata fields, or does not match
    ``spec``'s layer structure; missing leaf files surface as
    ``FileNotFoundError`` from the checkpointer.
    """
    if step is None:
        step = ckpt.latest_step()
        if step is None:
            raise FileNotFoundError(
                f"no checkpoint steps under {ckpt.directory}")
    info = read_export_meta(ckpt, step)
    if not info:
        raise ValueError(
            f"checkpoint step {step} in {ckpt.directory} carries no "
            f"'{_EXPORT_META_KEY}' metadata — not an exported network "
            "(was it written by save_exported?)")
    for field in ("name", "weight_bits"):
        if field not in info:
            raise ValueError(
                f"exported checkpoint step {step} is corrupted: metadata "
                f"field '{field}' is missing")
    if info["weight_bits"] not in {w for w, _ in PRECISION_PAIRS}:
        raise ValueError(
            f"exported checkpoint step {step} is corrupted: weight_bits="
            f"{info['weight_bits']!r} is not a supported precision")

    # Template with the layer shapes ``spec`` dictates; restore() re-checks
    # the leaf count so a structure mismatch fails loudly instead of
    # deploying weights into the wrong layer.
    like = []
    for layer in spec.layers:
        if layer.kind == "conv":
            f, k = layer.conv.kh * layer.conv.kw * layer.c_in, layer.c_out
        elif layer.kind == "fc":
            f, k = layer.c_in, layer.c_out
        else:
            like.append(None)
            continue
        like.append({
            "w_q": np.zeros((f, k), np.int8),
            "scale": np.zeros((k,), np.float32),
            "thr_int": np.zeros((k,), np.int32),
        })
    try:
        tree = ckpt.restore(step, like)
    except AssertionError as e:
        raise ValueError(
            f"exported checkpoint step {step} does not match the "
            f"'{spec.name}' layer structure: {e}") from e
    layers = []
    for idx, (template, d) in enumerate(zip(like, tree)):
        if d is None:
            layers.append(None)
            continue
        # restore() only checks the leaf count; validate shapes/dtypes
        # against the spec-derived template so a truncated or regenerated
        # leaf fails here instead of deploying corrupted weights.
        for field, want in template.items():
            got = np.asarray(d[field])
            if got.shape != want.shape or got.dtype != want.dtype:
                raise ValueError(
                    f"exported checkpoint step {step} is corrupted: layer "
                    f"{idx} field '{field}' is {got.dtype}{got.shape}, "
                    f"expected {want.dtype}{want.shape} for '{spec.name}'")
        layers.append(ExportedLayer(
            w_q=np.asarray(d["w_q"], np.int8),
            scale=np.asarray(d["scale"], np.float32),
            thr_int=np.asarray(d["thr_int"], np.int32),
        ))
    return ExportedNetwork(name=info["name"], weight_bits=info["weight_bits"],
                           layers=tuple(layers))


# ---------------------------------------------------------------------------
# The round-trip proof.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class RoundTrip:
    """Result of comparing the QAT training graph with the deployed engine."""

    exact: bool
    readout_mismatch: float      # max |qat - dequantized engine readout|
    spike_mismatch: int          # max |per-timestep per-layer spike counts|

    def __bool__(self) -> bool:  # pragma: no cover - convenience
        return self.exact


def verify_roundtrip(
    params,
    spec: SNNSpec,
    engine: SNNEngine,
    events,
    exported: Optional[ExportedNetwork] = None,
    engine_out=None,
) -> RoundTrip:
    """Prove train->deploy bit-exactness on ``events``.

    Runs the post-STE training graph (``run_snn(mode="qat")`` on the float
    ``params``) and the deployed integer ``engine`` on the same
    ``(T, B, H, W, C)`` event streams, and compares the full per-timestep
    per-layer output spike counts plus the readout (engine readout
    dequantized through the exported scales first).  Exact means equal —
    not close.  ``engine_out`` accepts a precomputed
    ``run_engine(engine, events)`` result so callers that already ran the
    engine don't pay for the inference twice.
    """
    exported = exported or export_network(params, spec, engine.cfg.qspec)
    qat_out, qat_counts = run_snn(params, events, spec, engine.cfg.qspec,
                                  mode="qat", record_spikes=True)
    eng = engine_out if engine_out is not None else run_engine(engine, events)
    eng_out = dequantize_readout(exported, spec, eng.readout)
    readout_mismatch = float(
        np.max(np.abs(np.asarray(qat_out) - np.asarray(eng_out))))
    spike_mismatch = int(np.max(np.abs(
        np.asarray(qat_counts).astype(np.int64)
        - np.asarray(eng.spike_counts).astype(np.int64))))
    return RoundTrip(
        exact=(readout_mismatch == 0.0 and spike_mismatch == 0),
        readout_mismatch=readout_mismatch,
        spike_mismatch=spike_mismatch,
    )
