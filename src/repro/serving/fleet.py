"""``spidr.serve``: an async serving fleet over replicated deployments.

SpiDR keeps heterogeneous compute units pipelined on-chip through
asynchronous handshaking; this module mirrors that one level up.  A
:class:`Fleet` continuously batches open event streams onto N replicated
``CompiledSNN`` engines: each replica is a
:class:`~repro.serving.worker.StreamWorker` (a bank of persistent-Vmem
session slots ticked by one fixed-shape jitted step), a
:class:`~repro.serving.scheduler.SessionScheduler` admits and places
streams deterministically, and live streams migrate between replicas
through the per-slot snapshot path (``StreamSession.export_slot`` /
``import_slot``) — a migrated stream emits spikes, readouts and
cumulative cycle/energy attribution byte-identical to one that never
moved (tested).

Two drive modes:

  * ``mode="sync"`` — the caller owns the clock: ``Fleet.step()`` places
    queued streams and ticks every replica once; ``drain()`` loops to
    completion.  Fully deterministic — the mode tests, benchmarks and the
    migration-exactness gate run in.
  * ``mode="threaded"`` — one loop thread per replica ticks continuously
    (the jitted session step releases the GIL, so replicas overlap on
    host cores); ``submit``/``drain``/``shutdown`` are thread-safe.

Telemetry: every queue transition, tick and migration lands in the
``repro.obs`` metrics registry (``spidr_fleet_*``) and tracer, so the
fleet is observable end to end with the rest of the stack.
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
from typing import Optional

import numpy as np

from .. import obs
from .config import ServeConfig
from .scheduler import SessionScheduler
from .worker import BatchWorker, StreamRequest, StreamWorker

__all__ = ["Fleet", "StreamHandle", "StreamProgress", "serve"]


@dataclasses.dataclass(frozen=True)
class StreamProgress:
    """One status/log-streaming update from :meth:`Fleet.stream`."""

    rid: int
    status: str
    timesteps: int
    readout: Optional[np.ndarray]
    cycles: int
    energy_uj: float
    replica: Optional[int]


@dataclasses.dataclass
class StreamHandle:
    """The caller's view of one submitted stream (k8s-style status object).

    ``status`` walks ``queued -> placed -> running -> done`` (``"shed"``
    only appears on the handle carried by a :class:`FleetOverloaded`
    reply).  ``placements`` records every ``(replica, slot)`` the stream
    ran in — length > 1 means it was live-migrated.  Result fields proxy
    the underlying request, so a handle is also the stream's incremental
    reply while it runs.
    """

    rid: int
    request: StreamRequest
    status: str = "queued"
    replica: Optional[int] = None
    slot: Optional[int] = None
    placements: list = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        return self.status == "done"

    @property
    def migrations(self) -> int:
        return max(0, len(self.placements) - 1)

    @property
    def timesteps(self) -> int:
        return int(self.request.cursor)

    @property
    def readout(self):
        return self.request.readout

    @property
    def cycles(self) -> int:
        return int(self.request.cycles)

    @property
    def energy_uj(self) -> float:
        return float(self.request.energy_uj)

    def progress(self) -> StreamProgress:
        return StreamProgress(
            rid=self.rid, status=self.status, timesteps=self.timesteps,
            readout=self.readout, cycles=self.cycles,
            energy_uj=self.energy_uj, replica=self.replica)


class Fleet:
    """N replicated engines, one scheduler, one lifecycle.

    Build with :func:`serve` (the public entry point), not directly.
    ``submit`` admits a stream (or sheds with :class:`FleetOverloaded`),
    ``stream`` yields its incremental progress, ``drain`` serves to
    completion, ``shutdown`` retires the fleet — after which ``submit``
    raises ``RuntimeError``.  The fleet is a context manager
    (``with spidr.serve(...) as fleet:``) that shuts down on exit.
    """

    def __init__(self, replicas, config: ServeConfig):
        self.config = config
        self.replicas = list(replicas)
        self._lock = threading.RLock()
        self._closed = False
        self._stop = threading.Event()
        self._threads: list = []
        self._handles: dict = {}       # rid -> StreamHandle
        self._next_rid = 0
        self.ticks = 0
        self.migrations = 0
        self.crashes = 0
        self._metrics = obs.default_registry()
        self._tracer = obs.default_tracer()
        first = self.replicas[0]
        self.capacity = (config.capacity if config.capacity is not None
                         else first.target.stream_capacity)
        self.chunk_T = (config.chunk_T if config.chunk_T is not None
                        else first.target.chunk_T)
        devices = self._resolve_devices()
        self.workers = []
        for i, compiled in enumerate(self.replicas):
            if config.batch:
                self.workers.append(BatchWorker(compiled, self.capacity))
            else:
                snap = (os.path.join(config.snapshot_dir, f"replica{i}")
                        if config.snapshot_dir else None)
                self.workers.append(StreamWorker(
                    compiled, self.capacity, self.chunk_T,
                    watchdog_s=config.watchdog_s,
                    max_restarts=config.max_restarts,
                    snapshot_dir=snap,
                    snapshot_every=config.snapshot_every,
                    collect_chunk_counts=config.collect_chunk_counts,
                    device=devices[i]))
        self.scheduler = SessionScheduler(
            self.workers, max_queue=config.max_queue,
            policy=config.placement, metrics=self._metrics)
        self._done_seen = [0] * len(self.workers)
        if config.mode == "threaded":
            self._start_threads()

    def _resolve_devices(self) -> list:
        cfg = self.config
        n = len(self.replicas)
        if cfg.devices is None or cfg.batch:
            return [None] * n
        if cfg.devices == "auto":
            import jax

            devs = jax.devices()
            # Only spread when every replica gets its own device; a partial
            # spread would co-locate some replicas asymmetrically.
            return list(devs[:n]) if len(devs) >= n else [None] * n
        devs = list(cfg.devices)
        if len(devs) != n:
            raise ValueError(
                f"ServeConfig.devices lists {len(devs)} device(s) for "
                f"{n} replica(s) — pass one device per replica, 'auto', "
                "or None")
        return devs

    # -- introspection -----------------------------------------------------
    @property
    def n_replicas(self) -> int:
        return len(self.workers)

    @property
    def closed(self) -> bool:
        return self._closed

    @property
    def queue_depth(self) -> int:
        return self.scheduler.queue_depth

    @property
    def shed(self) -> int:
        """Streams rejected at admission since the fleet started."""
        return self.scheduler.shed

    @property
    def handles(self) -> dict:
        """Every admitted stream's handle, by rid (shed streams excluded)."""
        return dict(self._handles)

    @property
    def done(self) -> list:
        """Every finished request across all replicas, in completion order."""
        reqs = [r for w in self.workers for r in w.done]
        return sorted(reqs, key=lambda r: (r.done_at or 0.0, r.rid))

    def describe(self) -> str:
        """One status line per replica (occupancy, queue, liveness)."""
        lines = [f"fleet: {self.n_replicas} replica(s), "
                 f"{self.scheduler.queue_depth} queued, "
                 f"{self.scheduler.shed} shed, "
                 f"{self.migrations} migration(s)"]
        for i, w in enumerate(self.workers):
            alive = "live" if self.scheduler.alive[i] else "DEAD"
            if isinstance(w, StreamWorker):
                occ = f"{w.sessions.occupancy}/{w.sessions.capacity} slots"
            else:
                occ = f"{len(w.waiting)} waiting"
            lines.append(f"  replica {i}: {alive}, {occ}, "
                         f"{len(w.done)} done")
        return "\n".join(lines)

    # -- submission --------------------------------------------------------
    def submit(self, events, rid: Optional[int] = None) -> StreamHandle:
        """Admit one event stream; returns its :class:`StreamHandle`.

        ``events`` is ``(T, H, W, C)`` binary frames; ``rid`` defaults to
        an auto-incremented id.  Raises :class:`FleetOverloaded` when the
        admission queue is full (explicit load shedding — the stream was
        not accepted) and ``RuntimeError`` after :meth:`shutdown`.
        """
        with self._lock:
            if self._closed:
                raise RuntimeError(
                    "fleet is shut down — submit() after shutdown() is an "
                    "error; spidr.serve a new fleet")
            if rid is None:
                rid = self._next_rid
            if rid in self._handles:
                raise ValueError(
                    f"stream id {rid} was already submitted — rids are "
                    "unique per fleet")
            self._next_rid = max(self._next_rid, rid) + 1
            req = StreamRequest(rid=rid, events=np.asarray(events))
            req.submitted_at = time.monotonic()
            handle = StreamHandle(rid=rid, request=req)
            self.scheduler.admit(handle)   # may raise FleetOverloaded
            self._handles[rid] = handle
            if self._metrics:
                self._metrics.counter(
                    "spidr_fleet_submitted_total",
                    "Streams admitted into the fleet queue").inc()
                self._metrics.gauge(
                    "spidr_fleet_queue_depth",
                    "Streams waiting for a replica slot"
                ).set(self.scheduler.queue_depth)
            return handle

    # -- the sync clock ----------------------------------------------------
    def step(self) -> bool:
        """One fleet tick: place queued streams, tick every live replica.

        Sync mode only (threaded fleets tick themselves).  Returns True
        while any stream is queued or in flight.
        """
        if self.config.mode != "sync":
            raise RuntimeError(
                "step() drives a sync-mode fleet; a threaded fleet ticks "
                "itself — submit streams and drain() or poll handles")
        with self._lock:
            if self._closed:
                raise RuntimeError("fleet is shut down")
            t0 = time.monotonic()
            self.scheduler.place()
            progressed = False
            for i, w in enumerate(self.workers):
                if not self.scheduler.alive[i]:
                    continue
                if w.step():
                    progressed = True
                self._track_placements(i)
                self._collect(i)
            self.ticks += 1
            cfg = self.config
            if cfg.migrate_every and not cfg.batch \
                    and self.ticks % cfg.migrate_every == 0:
                self._rebalance()
            if self._metrics:
                self._metrics.histogram(
                    "spidr_fleet_tick_seconds",
                    "Fleet tick wall latency",
                    edges=obs.metrics.LATENCY_BUCKETS_S
                ).observe(time.monotonic() - t0)
                self._metrics.gauge(
                    "spidr_fleet_queue_depth",
                    "Streams waiting for a replica slot"
                ).set(self.scheduler.queue_depth)
            if self.scheduler.queue and not progressed \
                    and self.scheduler.n_alive == 0:
                raise RuntimeError(
                    "every replica is dead with streams still queued — "
                    "the fleet cannot make progress")
            return progressed or bool(self.scheduler.queue)

    def _track_placements(self, i: int) -> None:
        """Fold replica ``i``'s slot table into the handles' status/history."""
        w = self.workers[i]
        if not isinstance(w, StreamWorker):
            return
        for slot, req in w.slots.items():
            h = self._handles.get(req.rid)
            if h is None:
                continue
            cur = (i, slot)
            if not h.placements or h.placements[-1] != cur:
                h.placements.append(cur)
            h.replica, h.slot = i, slot
            h.status = "running"

    def _collect(self, i: int) -> None:
        """Resolve replica ``i``'s newly finished requests onto handles."""
        w = self.workers[i]
        new = w.done[self._done_seen[i]:]
        self._done_seen[i] = len(w.done)
        for req in new:
            h = self._handles.get(req.rid)
            if h is None:
                continue
            h.status = "done"
            h.slot = None
            if self._metrics:
                self._metrics.counter(
                    "spidr_fleet_completed_total",
                    "Streams served to completion").inc()
                if req.done_at and req.submitted_at:
                    self._metrics.histogram(
                        "spidr_fleet_stream_latency_seconds",
                        "Submit-to-completion latency per stream",
                        edges=obs.metrics.LATENCY_BUCKETS_S
                    ).observe(req.done_at - req.submitted_at)

    # -- live migration ----------------------------------------------------
    def migrate(self, rid: Optional[int] = None,
                to: Optional[int] = None) -> int:
        """Live-migrate one running stream to another replica.

        Exports the stream's slot state (resident Vmem, accounting,
        handshake clocks) from its current replica and imports it into a
        free slot on the target — the stream's remaining chunks then run
        there, bit-identical to a never-migrated run.  ``rid`` defaults to
        the first running stream on the most-loaded replica; ``to``
        defaults to the least-loaded other replica with a free slot.
        Returns the target replica index.  Sync mode only.
        """
        if self.config.mode != "sync":
            raise RuntimeError(
                "live migration is a sync-scheduler operation — threaded "
                "fleets rebalance at admission instead")
        if self.config.batch:
            raise RuntimeError(
                "batch fleets hold no resident stream state — there is "
                "nothing to migrate")
        with self._lock:
            src, slot, req = self._find_stream(rid)
            if to is None:
                to = self._pick_migration_target(src)
                if to is None:
                    raise RuntimeError(
                        "no other live replica has a free session slot to "
                        "migrate into")
            if to == src:
                raise ValueError(
                    f"stream {req.rid} already runs on replica {to}")
            if not self.scheduler.alive[to]:
                raise ValueError(f"target replica {to} is dead")
            w_src, w_dst = self.workers[src], self.workers[to]

            def _move():
                payload = w_src.sessions.export_slot(slot)
                w_src.sessions.close(slot)
                del w_src.slots[slot]
                new_slot = w_dst.sessions.import_slot(payload)
                w_dst.slots[new_slot] = req
                return new_slot

            if self._tracer:
                with self._tracer.span("fleet.migrate", cat="fleet",
                                       rid=req.rid, src=src, dst=to):
                    new_slot = _move()
            else:
                new_slot = _move()
            h = self._handles.get(req.rid)
            if h is not None:
                h.placements.append((to, new_slot))
                h.replica, h.slot = to, new_slot
            self.migrations += 1
            if self._metrics:
                self._metrics.counter(
                    "spidr_fleet_migrations_total",
                    "Streams live-migrated between replicas").inc()
            return to

    def _find_stream(self, rid: Optional[int]):
        """Locate a running stream: (replica, slot, request)."""
        if rid is not None:
            for i, w in enumerate(self.workers):
                if not self.scheduler.alive[i]:
                    continue
                for slot, req in w.slots.items():
                    if req.rid == rid:
                        return i, slot, req
            raise ValueError(
                f"stream {rid} is not running in any replica slot — only "
                "placed, still-live streams can migrate")
        # Default pick: lowest slot on the most-loaded live replica.
        candidates = [i for i in range(len(self.workers))
                      if self.scheduler.alive[i] and self.workers[i].slots]
        if not candidates:
            raise ValueError("no stream is currently running in the fleet")
        src = max(candidates, key=lambda i: (len(self.workers[i].slots), -i))
        slot = min(self.workers[src].slots)
        return src, slot, self.workers[src].slots[slot]

    def _pick_migration_target(self, src: int) -> Optional[int]:
        best = None
        for i, w in enumerate(self.workers):
            if i == src or not self.scheduler.alive[i]:
                continue
            free = w.sessions.capacity - w.sessions.occupancy
            if free > 0 and (best is None or free > best[1]):
                best = (i, free)
        return None if best is None else best[0]

    def _rebalance(self) -> None:
        """Migrate one stream from the most- to the least-loaded replica
        when their slot occupancy differs by 2+ (``migrate_every``)."""
        live = [i for i in range(len(self.workers))
                if self.scheduler.alive[i]]
        if len(live) < 2:
            return
        loads = {i: len(self.workers[i].slots) for i in live}
        src = max(live, key=lambda i: (loads[i], -i))
        dst = min(live, key=lambda i: (loads[i], i))
        if loads[src] - loads[dst] < 2 or not self.workers[src].slots:
            return
        slot = min(self.workers[src].slots)
        self.migrate(self.workers[src].slots[slot].rid, to=dst)

    # -- replica failure ---------------------------------------------------
    def kill_replica(self, replica: int) -> list:
        """Mark a replica dead and re-place its in-flight streams.

        The crashed replica's resident state is gone by definition, so its
        streams re-enter the admission queue *at the front* (original
        order) with progress reset — deterministic replay from timestep 0
        on whichever replica the scheduler re-places them on produces the
        same final results (tested).  Returns the re-queued handles.
        """
        with self._lock:
            if not self.scheduler.alive[replica]:
                return []
            self.scheduler.mark_dead(replica)
            w = self.workers[replica]
            lost = w.inflight()
            requeued = []
            for req in lost:
                req.cursor = 0
                req.readout = None
                req.cycles = 0
                req.energy_uj = 0.0
                req.input_counts = None
                req.first_reply_at = None
                h = self._handles.get(req.rid)
                if h is not None:
                    h.status = "queued"
                    h.replica = h.slot = None
                    requeued.append(h)
            self.scheduler.requeue_front(requeued)
            self.crashes += 1
            if self._metrics:
                self._metrics.counter(
                    "spidr_fleet_replica_crashes_total",
                    "Replica failures handled by re-placement").inc()
                self._metrics.counter(
                    "spidr_fleet_replaced_streams_total",
                    "Streams re-queued after a replica crash"
                ).inc(len(requeued))
            return requeued

    # -- status streaming --------------------------------------------------
    def stream(self, handle):
        """Yield a stream's incremental progress until it completes.

        ``handle`` is a :class:`StreamHandle` (or a rid).  In sync mode
        each iteration ticks the fleet; in threaded mode it polls.  Yields
        a :class:`StreamProgress` after every chunk the stream consumes,
        ending with the ``"done"`` update.
        """
        if not isinstance(handle, StreamHandle):
            handle = self._handles[int(handle)]
        last = -1
        while True:
            if handle.status in ("done", "failed"):
                break
            if self.config.mode == "sync":
                self.step()
            else:
                time.sleep(0.002)
            if handle.request.cursor != last:
                last = handle.request.cursor
                yield handle.progress()
        if handle.request.cursor != last:
            yield handle.progress()

    # -- completion / teardown ---------------------------------------------
    def drain(self, timeout: Optional[float] = None) -> dict:
        """Serve every admitted stream to completion; returns the handles.

        Sync mode loops :meth:`step`; threaded mode waits for the replica
        loops (``timeout`` seconds at most, raising ``TimeoutError``).
        """
        if self.config.mode == "sync":
            while self.step():
                pass
        else:
            deadline = (time.monotonic() + timeout
                        if timeout is not None else None)
            while True:
                with self._lock:
                    pending = any(h.status not in ("done", "failed")
                                  for h in self._handles.values())
                if not pending:
                    break
                if deadline is not None and time.monotonic() > deadline:
                    raise TimeoutError(
                        f"fleet did not drain within {timeout}s "
                        f"({self.describe()})")
                time.sleep(0.005)
        return dict(self._handles)

    def shutdown(self) -> None:
        """Retire the fleet (idempotent): stop replica loops, close every
        session, reject further submits with ``RuntimeError``."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
        self._stop.set()
        for t in self._threads:
            t.join(timeout=30)
        for w in self.workers:
            w.shutdown()

    def __enter__(self) -> "Fleet":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    # -- threaded mode -----------------------------------------------------
    def _start_threads(self) -> None:
        for i in range(len(self.workers)):
            t = threading.Thread(target=self._replica_loop, args=(i,),
                                 name=f"spidr-replica-{i}", daemon=True)
            self._threads.append(t)
            t.start()

    def _replica_loop(self, i: int) -> None:
        w = self.workers[i]
        while not self._stop.is_set():
            with self._lock:
                if not self.scheduler.alive[i]:
                    return
                self.scheduler.place(only={i})
            # The jitted session step releases the GIL — replicas overlap.
            progressed = w.step()
            with self._lock:
                self._track_placements(i)
                self._collect(i)
                self.ticks += 1
            if not progressed:
                time.sleep(0.002)


def serve(compiled, config: Optional[ServeConfig] = None,
          **overrides) -> Fleet:
    """Deploy a serving fleet over one or more compiled replicas.

    The one public serving entry point (``spidr.serve``)::

        fleet = spidr.serve(compiled, n_replicas=2, capacity=4)
        handle = fleet.submit(events)          # (T, H, W, C) frames
        fleet.drain()                          # or: for up in fleet.stream(handle)
        print(handle.readout, handle.cycles)
        fleet.shutdown()

    ``compiled`` is a single :class:`~repro.spidr.CompiledSNN` — replicated
    ``config.n_replicas`` times over shared weights — or an explicit
    replica list (e.g. separately prepared deployments), which must agree
    on target and spec and carry byte-identical weights.  Keyword
    overrides build/extend the :class:`ServeConfig`.
    """
    if config is None:
        config = ServeConfig(**overrides)
    elif overrides:
        config = dataclasses.replace(config, **overrides)
    if isinstance(compiled, (list, tuple)):
        replicas = list(compiled)
        if not replicas:
            raise ValueError("serve() needs at least one replica")
        if config.n_replicas == 1 and len(replicas) > 1:
            config = dataclasses.replace(config, n_replicas=len(replicas))
        elif config.n_replicas != len(replicas):
            raise ValueError(
                f"ServeConfig.n_replicas={config.n_replicas} but "
                f"{len(replicas)} replicas were passed — drop n_replicas "
                "or make them agree")
        _validate_replicas(replicas)
    else:
        replicas = [compiled] * config.n_replicas
    return Fleet(replicas, config)


def _validate_replicas(replicas) -> None:
    """Explicit replica lists must be interchangeable deployments: same
    target, same spec geometry, byte-identical weights — the precondition
    for bit-exact cross-replica migration."""
    first = replicas[0]
    ref_arrays = None
    for i, r in enumerate(replicas[1:], start=1):
        if r is first:
            continue
        if r.target != first.target:
            raise ValueError(
                f"replica {i} is compiled for {r.target}, replica 0 for "
                f"{first.target} — fleet replicas must share one "
                "DeployTarget")
        if r.spec.name != first.spec.name \
                or r.spec.input_hw != first.spec.input_hw \
                or r.spec.timesteps != first.spec.timesteps:
            raise ValueError(
                f"replica {i} serves spec {r.spec.name!r} "
                f"{r.spec.input_hw}x{r.spec.timesteps}, replica 0 "
                f"{first.spec.name!r} {first.spec.input_hw}x"
                f"{first.spec.timesteps} — fleet replicas must share one "
                "network")
        if ref_arrays is None:
            ref_arrays = first._layer_arrays()
        for li, (a, b) in enumerate(zip(ref_arrays, r._layer_arrays())):
            same = (a is None) == (b is None) and (
                a is None or (np.array_equal(a["w_q"], b["w_q"])
                              and np.array_equal(a["w_scale"], b["w_scale"])
                              and np.array_equal(a["thr_int"],
                                                 b["thr_int"])))
            if not same:
                raise ValueError(
                    f"replica {i} weight layer {li} is not byte-identical "
                    "to replica 0's — a fleet's replicas must be the same "
                    "deployment (compile from the same artifact)")
