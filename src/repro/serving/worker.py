"""Per-replica serving workers: one engine, one queue, one tick loop.

The two SNN serving modes that used to live in ``launch/serve.py`` as
``SNNServer``/``StreamingSNNServer``, collapsed onto one shared
submit/queue/result base and re-homed here so the fleet tier
(``serving.fleet``) can drive N of them as replicas:

  * :class:`BatchWorker` — whole-stream batched inference: waiting
    requests are packed into a fixed ``(T, capacity, H, W, C)`` batch and
    one fused ``CompiledSNN.run`` serves them all;
  * :class:`StreamWorker` — stateful continuous batching over persistent
    Vmem: a bank of ``capacity`` session slots, each holding one live
    stream's neuron state, advanced ``chunk_T`` timesteps per tick in one
    fixed-shape jitted step, with watchdog + rewind-and-replay fault
    tolerance and snapshot/restore durability.

``launch/serve.py`` keeps ``SNNServer``/``StreamingSNNServer`` as thin
deprecated shims over these classes; new code goes through
``spidr.serve`` instead of constructing workers directly.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Optional

import jax.numpy as jnp
import numpy as np

from .. import obs
from ..obs.logs import request_context

__all__ = ["BatchWorker", "StreamRequest", "StreamWorker"]

log = logging.getLogger("repro.serving")


@dataclasses.dataclass
class StreamRequest:
    """One DVS event stream moving through the serving tier."""

    rid: int
    events: np.ndarray                     # (T, H, W, C) binary event frames
    readout: Optional[np.ndarray] = None   # filled on completion
    submitted_at: float = 0.0
    done_at: Optional[float] = None
    # Streaming-path extras: progress + cumulative chip cost for this stream.
    cursor: int = 0                        # timesteps delivered so far
    first_reply_at: Optional[float] = None
    cycles: int = 0
    energy_uj: float = 0.0
    # Concatenated per-chunk input-spike counts (T_so_far, n_layers) —
    # populated only when the worker collects chunk counts for the
    # per-stream pipeline-timeline export (``--trace-out`` on multi-core).
    input_counts: Optional[np.ndarray] = None


class _WorkerBase:
    """Shared submit/queue/result plumbing of both serving modes.

    Lifecycle contract (tested): :meth:`submit` after :meth:`shutdown`
    raises ``RuntimeError``; :meth:`shutdown` itself is idempotent.
    """

    def __init__(self, compiled):
        self.compiled = compiled
        self.waiting: list = []
        self.done: list = []
        self._closed = False
        self._metrics = obs.default_registry()

    @property
    def closed(self) -> bool:
        return self._closed

    def submit(self, req: StreamRequest) -> None:
        if self._closed:
            raise RuntimeError(
                "worker is shut down — submit() after shutdown() is an "
                "error; serve through a live fleet (spidr.serve)")
        # The fleet stamps arrival at admission; a directly-submitted
        # request is stamped here.
        if not req.submitted_at:
            req.submitted_at = time.monotonic()
        self.waiting.append(req)

    def shutdown(self) -> None:
        """Stop accepting work (idempotent); in-flight results stay
        readable on ``done``."""
        self._closed = True

    def _require_live(self) -> None:
        if self._closed:
            raise RuntimeError(
                "worker is shut down — step() after shutdown() is an error")

    # Scheduler interface --------------------------------------------------
    @property
    def busy(self) -> bool:
        """True while the worker holds unfinished work."""
        return bool(self.waiting)

    def free_capacity(self) -> int:
        """Streams the scheduler may place here before the next tick."""
        raise NotImplementedError

    def inflight(self) -> list:
        """Every accepted-but-unfinished request (crash re-placement)."""
        return list(self.waiting)


class BatchWorker(_WorkerBase):
    """Fixed-capacity batched SNN inference worker.

    Waiting requests are packed into a fixed (T, capacity, H, W, C) batch —
    idle slots carry zero events, which the zero-skipping engine makes nearly
    free — and one fused ``CompiledSNN.run`` serves the whole batch.
    """

    def __init__(self, compiled, capacity: int = 4):
        super().__init__(compiled)
        self.capacity = capacity
        self.total_input_counts = None
        self.batches = 0

    def free_capacity(self) -> int:
        return max(0, self.capacity - len(self.waiting))

    def step(self) -> bool:
        self._require_live()
        if not self.waiting:
            return False
        t0 = time.monotonic()
        batch = self.waiting[: self.capacity]
        self.waiting = self.waiting[self.capacity:]
        ev = np.zeros(
            (batch[0].events.shape[0], self.capacity) + batch[0].events.shape[1:],
            np.float32,
        )
        for i, req in enumerate(batch):
            ev[:, i] = req.events
        out = self.compiled.run(jnp.asarray(ev))
        readout = np.asarray(out.readout)
        now = time.monotonic()
        for i, req in enumerate(batch):
            req.readout = readout[i]
            req.done_at = now
            self.done.append(req)
        counts = np.asarray(out.input_counts)
        self.total_input_counts = (
            counts if self.total_input_counts is None
            else self.total_input_counts + counts
        )
        self.batches += 1
        if self._metrics:
            reg = self._metrics
            reg.counter("spidr_serve_batches_total",
                        "Whole-stream batches served").inc()
            reg.histogram("spidr_serve_batch_seconds",
                          "Whole-stream batch wall latency",
                          edges=obs.metrics.LATENCY_BUCKETS_S
                          ).observe(time.monotonic() - t0)
            reg.gauge("spidr_serve_queue_depth",
                      "Requests waiting for a slot").set(len(self.waiting))
        return True


class StreamWorker(_WorkerBase):
    """Stateful continuous-batching worker over persistent Vmem sessions.

    A fixed bank of ``capacity`` slots, each holding one live stream's
    neuron state inside a ``CompiledSNN.open_stream()`` session; every
    ``step()`` delivers each live stream's next ``chunk_T`` event frames
    and advances all slots in one fixed-shape jitted chunk step.  Finished
    streams retire and free their slot for the next waiter; idle slots
    ride along as all-zero spike tiles that the zero-skip path eliminates.

    Durability (``runtime.fault_tolerance`` + ``CompiledSNN.snapshot``):

      * ``watchdog_s`` arms a :class:`StepWatchdog` around every session
        step — a hung tick becomes a :class:`RestartableFailure`;
      * every tick runs through ``retrying``: a poisoned tick rewinds the
        session (and all request cursors) to the last completed tick and
        replays, up to ``max_restarts`` times;
      * ``snapshot_dir``/``snapshot_every`` persist the full serving state
        (weights, session slots, stream-id/cursor table, finished results)
        every N ticks; :meth:`restore` resumes it in a fresh process,
        bit-exactly — the upgrade drill (``tools/upgrade_drill.py``)
        SIGKILLs a serving process mid-chunk and proves zero streams lose
        state.
    """

    def __init__(self, compiled, capacity: int = 4, chunk_T: int = 2, *,
                 watchdog_s: Optional[float] = None, max_restarts: int = 3,
                 snapshot_dir: Optional[str] = None, snapshot_every: int = 0,
                 fail_at_tick: Optional[int] = None, _session=None,
                 collect_chunk_counts: bool = False, device=None):
        from ..runtime.fault_tolerance import StepWatchdog, retrying

        super().__init__(compiled)
        self.sessions = (_session if _session is not None
                         else compiled.open_stream(
                             capacity=capacity, chunk_T=chunk_T,
                             collect_chunk_counts=collect_chunk_counts,
                             device=device))
        self.chunk_T = chunk_T
        self.slots: dict = {}          # slot -> StreamRequest
        self.ticks = 0
        self.snapshot_dir = snapshot_dir
        self.snapshot_every = snapshot_every
        # Telemetry: the process-wide registry/tracer (disabled unless
        # obs.enable_metrics()/enable_tracing() ran, e.g. via the
        # --metrics-out/--trace-out flags).
        self._tracer = obs.default_tracer()
        # Fault injection for tests/drills: raise RestartableFailure once,
        # mid-tick (after the session stepped, before bookkeeping) — the
        # worst case the rewind has to undo.  ``mid_tick_hook`` is the
        # generic form (the upgrade drill SIGKILLs the process from it).
        self.fail_at_tick = fail_at_tick
        self.mid_tick_hook = None
        self._watchdog = (StepWatchdog(
            watchdog_s,
            counter=self._metrics.counter(
                "spidr_serve_watchdog_timeouts_total",
                "Watchdog deadline firings") if self._metrics else None)
            if watchdog_s is not None else None)
        self._rewind_point = None
        self._step = retrying(self._tick, self._rewind,
                              max_restarts=max_restarts,
                              on_restart=self._count_rewind)
        self._mark()

    def _count_rewind(self) -> None:
        if self._metrics:
            self._metrics.counter(
                "spidr_serve_rewinds_total",
                "Rewind-and-replay recoveries").inc()

    @property
    def restarts(self) -> int:
        """Rewind-and-replay count since the worker started."""
        return self._step.state["restarts"]

    @property
    def busy(self) -> bool:
        return bool(self.slots or self.waiting)

    def free_capacity(self) -> int:
        return max(0, self.sessions.capacity - self.sessions.occupancy
                   - len(self.waiting))

    def inflight(self) -> list:
        return list(self.slots.values()) + list(self.waiting)

    def shutdown(self) -> None:
        """Stop accepting work and retire the session (idempotent)."""
        super().shutdown()
        self.sessions.close()

    def _admit(self):
        while self.waiting:
            slot = self.sessions.open()
            if slot is None:
                # Admission deferred: every waiter stays queued this tick.
                if self._metrics:
                    self._metrics.counter(
                        "spidr_serve_rejections_total",
                        "Ticks on which waiting streams found no free slot"
                    ).inc()
                return
            req = self.waiting.pop(0)
            self.slots[slot] = req
            if self._metrics:
                self._metrics.counter(
                    "spidr_serve_admissions_total",
                    "Streams admitted into a session slot").inc()
            with request_context(req.rid):
                log.debug("admitted stream %d into slot %d", req.rid, slot)

    # -- fault tolerance: rewind-and-replay --------------------------------
    def _mark(self):
        """Record the last-completed-tick state the next rewind returns to.

        The session part is a pure-numpy ``state_dict`` (never aliases live
        buffers); the request part saves each request's mutable progress
        fields so the *same* objects callers hold are rolled back.
        """
        reqs = list(self.slots.values()) + self.waiting + self.done
        self._rewind_point = {
            "session": self.sessions.state_dict(),
            "slots": dict(self.slots),
            "waiting": list(self.waiting),
            "done": list(self.done),
            "ticks": self.ticks,
            "reqs": [(r, r.cursor, r.readout, r.cycles, r.energy_uj,
                      r.first_reply_at, r.done_at, r.input_counts)
                     for r in reqs],
        }

    def _rewind(self, *args, **kwargs):
        cp = self._rewind_point
        self.sessions.load_state_dict(cp["session"])
        self.slots = dict(cp["slots"])
        self.waiting = list(cp["waiting"])
        self.done = list(cp["done"])
        self.ticks = cp["ticks"]
        for r, cur, ro, cyc, uj, fr, da, ic in cp["reqs"]:
            r.cursor, r.readout, r.cycles, r.energy_uj = cur, ro, cyc, uj
            r.first_reply_at, r.done_at, r.input_counts = fr, da, ic
        log.info("rewound to tick %d and replaying", self.ticks)

    def _tick(self) -> bool:
        self._admit()
        if not self.slots:
            return False
        chunks = {slot: req.events[req.cursor:req.cursor + self.chunk_T]
                  for slot, req in self.slots.items()}
        if self._watchdog is not None:
            self._watchdog.arm()
        try:
            updates = self.sessions.step(chunks)
        finally:
            if self._watchdog is not None:
                self._watchdog.disarm()
        if self._watchdog is not None:
            self._watchdog.check()
        if self.mid_tick_hook is not None:
            self.mid_tick_hook(self.ticks + 1)
        if self.fail_at_tick is not None and self.ticks + 1 >= self.fail_at_tick:
            from ..runtime.fault_tolerance import RestartableFailure

            self.fail_at_tick = None
            raise RestartableFailure(
                f"injected fault at tick {self.ticks + 1}")
        now = time.monotonic()
        for slot, up in updates.items():
            req = self.slots[slot]
            req.cursor += chunks[slot].shape[0]
            # Incremental reply: cumulative readout + chip cost so far.
            req.readout = up.readout
            req.cycles, req.energy_uj = up.cycles, up.energy_uj
            if up.input_counts is not None:
                req.input_counts = (
                    up.input_counts if req.input_counts is None
                    else np.concatenate([req.input_counts, up.input_counts]))
            if req.first_reply_at is None:
                req.first_reply_at = now
            if req.cursor >= req.events.shape[0]:
                req.done_at = now
                self.done.append(req)
                self.sessions.close(slot)   # free the slot: continuous batching
                del self.slots[slot]
                with request_context(req.rid):
                    log.info(
                        "stream %d done: %d timesteps, %d cycles, %.2f uJ",
                        req.rid, req.cursor, req.cycles, req.energy_uj)
        self.ticks += 1
        return True

    def step(self) -> bool:
        self._require_live()
        # Mark *now*, not after: requests submitted since the last tick are
        # part of the state a mid-tick failure must rewind to.
        self._mark()
        t0 = time.monotonic()
        if self._tracer:
            with self._tracer.span("serve.tick", cat="serve",
                                   tick=self.ticks):
                alive = self._step()
        else:
            alive = self._step()
        if self._metrics and alive:
            reg = self._metrics
            reg.histogram("spidr_serve_tick_seconds",
                          "Streaming tick wall latency",
                          edges=obs.metrics.LATENCY_BUCKETS_S
                          ).observe(time.monotonic() - t0)
            reg.gauge("spidr_serve_queue_depth",
                      "Requests waiting for a slot").set(len(self.waiting))
        if alive and self.snapshot_dir and self.snapshot_every \
                and self.ticks % self.snapshot_every == 0:
            self.save_snapshot()
        return alive

    # -- durability: process-level snapshot/restore ------------------------
    @staticmethod
    def _result_json(req: StreamRequest) -> dict:
        return {"rid": int(req.rid), "cursor": int(req.cursor),
                "readout": (None if req.readout is None
                            else np.asarray(req.readout).tolist()),
                "cycles": int(req.cycles),
                "energy_uj": float(req.energy_uj)}

    def save_snapshot(self) -> None:
        """Persist the complete serving state (atomic, checksummed).

        One ``CompiledSNN.snapshot`` step at ``step=self.ticks``: weights +
        the live session, plus the worker's own bookkeeping (stream-id <->
        slot map, per-stream cursors, finished results) as JSON ``extra``.
        Replay after :meth:`restore` is implicit — chunks are re-derived
        from the restored cursors.
        """
        assert self.snapshot_dir, "construct the worker with snapshot_dir="
        t0 = time.monotonic()
        extra = {"server": {
            "ticks": int(self.ticks),
            "slots": {str(slot): int(req.rid)
                      for slot, req in self.slots.items()},
            "cursors": {str(req.rid): int(req.cursor)
                        for req in list(self.slots.values()) + self.waiting},
            "waiting": [int(req.rid) for req in self.waiting],
            "done": [self._result_json(req) for req in self.done],
        }}
        self.compiled.snapshot(self.snapshot_dir, step=self.ticks,
                               sessions=[self.sessions], extra=extra)
        if self._metrics:
            self._metrics.histogram(
                "spidr_serve_snapshot_seconds",
                "save_snapshot wall duration (server bookkeeping + "
                "checkpoint write)",
                edges=obs.metrics.LATENCY_BUCKETS_S
            ).observe(time.monotonic() - t0)

    @classmethod
    def restore(cls, path, requests_by_rid: dict, compiled=None, *,
                watchdog_s: Optional[float] = None, max_restarts: int = 3,
                snapshot_every: int = 0, step: Optional[int] = None
                ) -> "StreamWorker":
        """Resume a worker from its latest :meth:`save_snapshot`.

        ``requests_by_rid`` maps stream id -> :class:`StreamRequest`
        carrying the stream's (deterministically regenerated) events;
        in-flight requests resume at their snapshotted cursor, finished
        results are reloaded from the snapshot.  The restored worker then
        serves every stream bit-identically to one that was never killed.
        """
        from .. import spidr

        info = spidr.read_snapshot_meta(path, step)
        compiled = spidr.restore(path, compiled=compiled, step=info["step"])
        session = compiled.sessions[-1]
        srv = cls(compiled, capacity=session.capacity,
                  chunk_T=session.chunk_T, watchdog_s=watchdog_s,
                  max_restarts=max_restarts, snapshot_dir=str(path),
                  snapshot_every=snapshot_every, _session=session)
        state = info["extra"]["server"]
        srv.ticks = int(state["ticks"])
        cursors = {int(k): int(v) for k, v in state["cursors"].items()}
        for slot, rid in state["slots"].items():
            req = requests_by_rid[int(rid)]
            req.cursor = cursors[int(rid)]
            srv.slots[int(slot)] = req
        srv.waiting = [requests_by_rid[int(rid)]
                       for rid in state["waiting"]]
        for req in srv.waiting:
            req.cursor = cursors[int(req.rid)]
        for d in state["done"]:
            req = requests_by_rid.get(int(d["rid"])) or StreamRequest(
                rid=int(d["rid"]), events=np.zeros((0,), np.float32))
            req.cursor = int(d["cursor"])
            req.readout = (None if d["readout"] is None
                           else np.asarray(d["readout"], np.int32))
            req.cycles = int(d["cycles"])
            req.energy_uj = float(d["energy_uj"])
            srv.done.append(req)
        srv._mark()
        return srv
