"""The serving tier: replicated engines, session scheduling, live migration.

Public entry point: ``spidr.serve(compiled_or_replicas, ServeConfig) ->
Fleet`` (re-exported on the ``repro.spidr`` facade).  The pieces:

  * :class:`ServeConfig` — declarative fleet shape + scheduling policy;
  * :class:`Fleet` — N replicated deployments behind
    ``submit``/``stream``/``drain``/``shutdown``;
  * :class:`SessionScheduler` — bounded FIFO admission, deterministic
    placement, crash re-placement;
  * :class:`StreamWorker`/:class:`BatchWorker` — the per-replica tick
    loops (formerly ``launch.serve.StreamingSNNServer``/``SNNServer``,
    which remain as deprecated shims);
  * :class:`FleetOverloaded` — the explicit load-shedding reply.
"""
from .config import FleetOverloaded, ServeConfig
from .fleet import Fleet, StreamHandle, StreamProgress, serve
from .scheduler import SessionScheduler
from .worker import BatchWorker, StreamRequest, StreamWorker

__all__ = [
    "BatchWorker",
    "Fleet",
    "FleetOverloaded",
    "ServeConfig",
    "SessionScheduler",
    "StreamHandle",
    "StreamProgress",
    "StreamRequest",
    "StreamWorker",
    "serve",
]
