"""Session scheduling for the fleet: admit -> place -> (re)balance.

The k8s-scheduler shape, one level down: a stream's *spec* (its request)
enters a bounded admission queue, the scheduler binds it to a replica
(*placement*), and the fleet streams its status/progress afterwards.
Everything here is deterministic in (arrival order, completion order):

  * admission is FIFO with a hard bound — the queue never exceeds
    ``max_queue``; beyond it the submit is shed with an explicit
    :class:`~repro.serving.config.FleetOverloaded` reply, never silently
    dropped;
  * placement is head-of-line only (no queue jumping): the next stream
    goes to the least-loaded live replica (most free slots, ties to the
    lowest index) or round-robin, and inside a replica to the session's
    first free slot — two fleets fed the same arrival order place every
    stream identically (tested);
  * a crashed replica's in-flight streams re-enter the queue *at the
    front* in their original order (``requeue_front``), so re-placement
    preserves arrival priority.
"""
from __future__ import annotations

import collections
from typing import Optional

from .. import obs
from .config import FleetOverloaded

__all__ = ["SessionScheduler"]


class SessionScheduler:
    """Admission control + deterministic placement over fleet replicas."""

    def __init__(self, workers, *, max_queue: int = 64,
                 policy: str = "least-loaded", metrics=None):
        self.workers = list(workers)
        self.alive = [True] * len(self.workers)
        self.max_queue = max_queue
        self.policy = policy
        self.queue: collections.deque = collections.deque()  # StreamHandles
        self.submitted = 0
        self.shed = 0
        self.placed = 0
        self._rr = 0  # round-robin cursor
        self._metrics = (obs.default_registry() if metrics is None
                         else metrics)

    # -- admission ---------------------------------------------------------
    @property
    def queue_depth(self) -> int:
        return len(self.queue)

    def admit(self, handle) -> None:
        """FIFO admission with a hard bound; sheds with an explicit reply.

        Raises :class:`FleetOverloaded` when ``max_queue`` streams already
        wait — the stream is *not* enqueued and the handle is marked
        ``"shed"`` so the caller's reply carries the verdict.
        """
        if len(self.queue) >= self.max_queue:
            self.shed += 1
            handle.status = "shed"
            if self._metrics:
                self._metrics.counter(
                    "spidr_fleet_shed_total",
                    "Streams shed at admission (queue full)").inc()
            raise FleetOverloaded(len(self.queue), self.max_queue)
        self.submitted += 1
        self.queue.append(handle)

    def requeue_front(self, handles) -> None:
        """Put a crashed replica's streams back at the head of the queue,
        preserving their original relative order."""
        self.queue.extendleft(reversed(list(handles)))

    # -- placement ---------------------------------------------------------
    def _pick(self, exclude=(), only=None) -> Optional[int]:
        """The replica the next stream binds to, or None when all are full.

        ``least-loaded``: most free slots, ties broken by lowest replica
        index.  ``round-robin``: the next live replica with room, cycling.
        """
        candidates = [i for i in range(len(self.workers))
                      if self.alive[i] and i not in exclude
                      and (only is None or i in only)
                      and self.workers[i].free_capacity() > 0]
        if not candidates:
            return None
        if self.policy == "round-robin":
            ordered = sorted(candidates,
                             key=lambda i: (i - self._rr) % len(self.workers))
            choice = ordered[0]
            self._rr = (choice + 1) % len(self.workers)
            return choice
        return max(candidates,
                   key=lambda i: (self.workers[i].free_capacity(), -i))

    def place(self, only=None) -> list:
        """Bind queued streams to replicas, FIFO, until capacity runs out.

        Head-of-line only: when the next stream in arrival order cannot be
        placed, nothing behind it is — the property that makes placement a
        pure function of arrival order.  Returns ``[(handle, replica)]``.
        """
        placements = []
        while self.queue:
            i = self._pick(only=only)
            if i is None:
                break
            handle = self.queue.popleft()
            self.workers[i].submit(handle.request)
            handle.status = "placed"
            handle.replica = i
            self.placed += 1
            placements.append((handle, i))
        if placements and self._metrics:
            self._metrics.counter(
                "spidr_fleet_placed_total",
                "Streams bound to a replica").inc(len(placements))
        return placements

    # -- liveness ----------------------------------------------------------
    def mark_dead(self, replica: int) -> None:
        self.alive[replica] = False

    @property
    def n_alive(self) -> int:
        return sum(self.alive)
