"""Declarative fleet configuration for ``spidr.serve``.

:class:`ServeConfig` is to the serving tier what
:class:`~repro.spidr.DeployTarget` is to compilation: one frozen record
declaring the fleet's shape (replica count, per-replica session geometry),
its scheduling policy (placement, admission bound, rebalancing cadence)
and its operational knobs (watchdog, snapshots, device placement) —
validated eagerly with actionable errors instead of failing mid-serve.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["FleetOverloaded", "PLACEMENT_POLICIES", "SERVE_MODES",
           "ServeConfig"]

PLACEMENT_POLICIES = ("least-loaded", "round-robin")
SERVE_MODES = ("sync", "threaded")


class FleetOverloaded(RuntimeError):
    """Explicit load-shedding reply: the fleet's admission queue is full.

    Raised by ``Fleet.submit`` when ``ServeConfig.max_queue`` streams are
    already waiting for a slot.  The stream was *not* admitted — re-submit
    later (after ``drain``/completions free capacity) or serve with a
    larger ``max_queue``/more replicas.  ``queue_depth``/``max_queue``
    carry the rejection context for the caller's backpressure logic.
    """

    def __init__(self, queue_depth: int, max_queue: int):
        self.queue_depth = queue_depth
        self.max_queue = max_queue
        super().__init__(
            f"fleet admission queue is full ({queue_depth} streams waiting, "
            f"max_queue={max_queue}) — the stream was shed; re-submit after "
            "capacity frees up, or serve with a larger max_queue or more "
            "replicas")


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """How ``spidr.serve`` shapes and schedules a fleet.

    ``n_replicas``     engine replicas ticking concurrently (ignored when
                       an explicit replica list is passed to ``serve``).
    ``capacity``       persistent-Vmem slots per replica (default: the
                       deployment's ``target.stream_capacity``).
    ``chunk_T``        timesteps per streaming tick (default: the
                       deployment's ``target.chunk_T``).
    ``max_queue``      admission bound: streams waiting for a slot beyond
                       this are shed with :class:`FleetOverloaded`.
    ``placement``      ``"least-loaded"`` (most free slots, ties to the
                       lowest replica index — deterministic) or
                       ``"round-robin"``.
    ``mode``           ``"sync"`` — the caller ticks the fleet
                       (``Fleet.step``/``drain``), fully deterministic —
                       or ``"threaded"`` — one loop thread per replica
                       ticks continuously (the jitted session step
                       releases the GIL, so replicas overlap).
    ``batch``          serve whole streams per tick (the former
                       ``SNNServer`` path) instead of persistent-Vmem
                       streaming chunks.
    ``migrate_every``  sync mode: every N fleet ticks, rebalance one
                       stream from the most- to the least-loaded replica
                       via live migration (0 = never).
    ``watchdog_s`` / ``max_restarts`` / ``snapshot_dir`` /
    ``snapshot_every``  per-replica fault tolerance, as on the streaming
                       worker (snapshots land under
                       ``snapshot_dir/replica<i>``).
    ``devices``        ``None`` (default device), ``"auto"`` (one host
                       device per replica when enough exist), or an
                       explicit per-replica device list.
    """

    n_replicas: int = 1
    capacity: Optional[int] = None
    chunk_T: Optional[int] = None
    max_queue: int = 64
    placement: str = "least-loaded"
    mode: str = "sync"
    batch: bool = False
    migrate_every: int = 0
    watchdog_s: Optional[float] = None
    max_restarts: int = 3
    snapshot_dir: Optional[str] = None
    snapshot_every: int = 0
    collect_chunk_counts: bool = False
    devices: object = None

    def __post_init__(self):
        def positive(name, v):
            if not isinstance(v, int) or isinstance(v, bool) or v < 1:
                raise ValueError(
                    f"ServeConfig.{name} must be a positive int, got {v!r}")

        positive("n_replicas", self.n_replicas)
        if self.capacity is not None:
            positive("capacity", self.capacity)
        if self.chunk_T is not None:
            positive("chunk_T", self.chunk_T)
        if not isinstance(self.max_queue, int) or self.max_queue < 1:
            raise ValueError(
                f"ServeConfig.max_queue must be a positive int (the "
                f"admission bound), got {self.max_queue!r}")
        if self.placement not in PLACEMENT_POLICIES:
            raise ValueError(
                f"ServeConfig.placement must be one of "
                f"{PLACEMENT_POLICIES}, got {self.placement!r}")
        if self.mode not in SERVE_MODES:
            raise ValueError(
                f"ServeConfig.mode must be one of {SERVE_MODES}, got "
                f"{self.mode!r}")
        if self.migrate_every < 0:
            raise ValueError(
                f"ServeConfig.migrate_every must be >= 0 (ticks between "
                f"rebalance checks; 0 disables), got {self.migrate_every!r}")
        if self.batch and self.migrate_every:
            raise ValueError(
                "ServeConfig.batch fleets hold no resident state — there "
                "is nothing to migrate; drop migrate_every or serve "
                "streaming (batch=False)")
        if self.devices is not None and self.devices != "auto" \
                and not isinstance(self.devices, (list, tuple)):
            raise ValueError(
                "ServeConfig.devices must be None, 'auto', or an explicit "
                f"per-replica device sequence, got {self.devices!r}")
