"""Partition specs: FSDP x TP x EP (+ SP for long-context decode).

Mesh axes (launch/mesh.py):
  single-pod : ('data', 'model') = (16, 16)
  multi-pod  : ('pod', 'data', 'model') = (2, 16, 16)

Policy (DESIGN.md §5):
  * params/optimizer state: FSDP over 'data' + TP over 'model';
    REPLICATED over 'pod' (hierarchical DP — cross-pod traffic is the
    gradient all-reduce only, which the int8 compressor targets).
  * batch: sharded over ('pod', 'data') ['data' when single-pod].
  * MoE experts: expert axis over 'model' (EP).
  * decode KV caches: batch over dp axes, kv-heads over 'model';
    long_500k (batch=1): sequence over 'data' (SP) instead.

Specs are assigned by leaf *path name*, then left-padded with None to the
leaf's rank (covers layer stacking (L, ...) and zamba2's (G, P, ...)).
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import PartitionSpec as P

from . import flags

__all__ = [
    "param_specs",
    "opt_specs",
    "batch_specs",
    "decode_cache_specs",
    "logits_spec",
    "dp_axes",
    "set_activation_mesh",
    "constrain",
]

Pytree = Any

# ---------------------------------------------------------------------------
# Activation sharding constraints.
#
# XLA's sharding propagation gives up at a few points (the embedding gather,
# while-loop carries) and silently replicates everything downstream — the
# first dry-run of this repo showed 154 GiB/device temps from exactly that.
# The fix is the standard MaxText practice: pin activation shardings at
# layer boundaries.  ``set_activation_mesh`` arms the constraints (launchers
# only — unit tests on 1 device leave them off and ``constrain`` is a no-op).
# ---------------------------------------------------------------------------
_ACT = {"mesh": None, "dp": ("data",)}


def set_activation_mesh(mesh, multi_pod: bool = False, batch_sharded: bool = True):
    _ACT["mesh"] = mesh
    _ACT["dp"] = dp_axes(multi_pod) if batch_sharded else None


def constrain(x, *dims):
    """Pin x's sharding. dims entries: 'dp' | axis name | None.

    Axes that do not evenly divide the corresponding dim are dropped
    (e.g. 8 KV heads on a 16-way model axis -> replicated KV, the
    standard Megatron GQA fallback).
    """
    mesh = _ACT["mesh"]
    if mesh is None or x is None:
        return x
    spec = []
    dp_only = flags.flag("dp_only")
    for i, d in enumerate(dims):
        if d == "dp":
            d = _ACT["dp"]
        elif dp_only and d == "model":
            d = None  # model axis is data-parallel in dp_only mode
        elif isinstance(d, tuple):  # e.g. ("dp", "model") — flatten dp
            flat = []
            for a in d:
                if a == "dp":
                    flat.extend(_ACT["dp"] or ())
                elif a is not None:
                    flat.append(a)
            d = tuple(flat) or None
        if d is not None:
            axes = d if isinstance(d, tuple) else (d,)
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            if x.shape[i] % size != 0:
                d = None
        spec.append(d)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, P(*spec))
    )


def axis_divides(n: int, *axes) -> bool:
    """True if n is divisible by the (armed) mesh axes' total size."""
    mesh = _ACT["mesh"]
    if mesh is None:
        return True
    size = 1
    for a in axes:
        for ax in (_ACT["dp"] or ()) if a == "dp" else (a,):
            size *= mesh.shape[ax]
    return n % size == 0


def _axis_size(mesh, axis) -> int:
    if axis is None:
        return 1
    axes = axis if isinstance(axis, tuple) else (axis,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def validate_spec(spec: P, shape: tuple, mesh) -> P:
    """Drop/relocate axes so every sharded dim divides evenly.

    A non-dividing axis is moved to the first OTHER unsharded dim that it
    divides (e.g. MoE expert dim 40 on a 16-way axis -> shard the expert
    d_ff instead: EP degrades to per-expert TP); if none exists the axis is
    dropped (that dim replicates).
    """
    if mesh is None:
        return spec
    dims = list(spec) + [None] * (len(shape) - len(spec))
    for i, ax in enumerate(dims):
        if ax is None:
            continue
        if shape[i] % _axis_size(mesh, ax) != 0:
            dims[i] = None
            order = list(range(i + 1, len(shape))) + list(range(0, i))
            for j in order:
                if dims[j] is None and shape[j] % _axis_size(mesh, ax) == 0 and shape[j] > 1:
                    dims[j] = ax
                    break
    return P(*dims)


def validate_tree(specs: Pytree, abstract: Pytree, mesh) -> Pytree:
    return jax.tree.map(
        lambda s, a: validate_spec(s, a.shape, mesh) if a is not None else s,
        specs, abstract,
        is_leaf=lambda x: x is None or isinstance(x, P),
    )


def dp_axes(multi_pod: bool):
    if flags.flag("dp_only"):
        # no TP: the model axis joins data parallelism
        return ("pod", "data", "model") if multi_pod else ("data", "model")
    return ("pod", "data") if multi_pod else ("data",)


# trailing-dims spec per leaf name; padded left with None to leaf rank.
_TRAILING = {
    # top level
    "embed": ("model", "data"),
    "lm_head": ("data", "model"),
    "final_norm": (None,),
    # norms / small vectors
    "ln1": (None,), "ln2": (None,), "ln": (None,),
    "norm_w": (None,), "ln_x": (None,),
    "q_norm": (None,), "k_norm": (None,),
    # attention
    "wq": ("data", "model"), "wk": ("data", "model"), "wv": ("data", "model"),
    "wo": ("model", "data"),
    "bq": ("model",), "bk": ("model",), "bv": ("model",),
    # dense ffn
    "w_gate": ("data", "model"), "w_up": ("data", "model"),
    "w_down": ("model", "data"),
    # moe (E, D, F) / (E, F, D): experts over model (EP), D over data
    "w_router": ("data", None),
    "moe.w_gate": ("model", "data", None),
    "moe.w_up": ("model", "data", None),
    "moe.w_down": ("model", None, "data"),
    # rwkv6
    "wr": ("data", "model"), "wg": ("data", "model"),
    "cm_wk": ("data", "model"), "cm_wv": ("model", "data"),
    "cm_wr": ("data", "model"),
    "tm_w1": (None, None), "tm_w2": (None, None, None),
    "td_w1": (None, None), "td_w2": (None, None),
    "mu_x": (None,), "mu_rkvwg": (None, None),
    "time_decay": (None,), "bonus_u": (None,),
    "cm_mu_k": (None,), "cm_mu_r": (None,),
    # mamba2
    "w_in": ("data", "model"), "w_out": ("model", "data"),
    "conv_w": (None, "model"), "conv_b": ("model",),
    "a_log": (None,), "dt_bias": (None,), "d_skip": (None,),
}


def _leaf_spec(path: tuple, leaf) -> P:
    names = [getattr(p, "key", getattr(p, "name", str(p))) for p in path]
    joined = ".".join(str(n) for n in names)
    key = names[-1] if names else ""
    trailing = None
    if ("moe" in joined or "w_router" in joined) and f"moe.{key}" in _TRAILING:
        trailing = _TRAILING[f"moe.{key}"]
    elif key in _TRAILING:
        trailing = _TRAILING[key]
    if trailing is None:
        return P()  # replicate by default
    rank = getattr(leaf, "ndim", len(getattr(leaf, "shape", ())))
    pad = rank - len(trailing)
    if pad < 0:  # leaf smaller than rule (e.g. unstacked shared block)
        trailing = trailing[-rank:] if rank else ()
        pad = 0
    return P(*((None,) * pad + tuple(trailing)))


def _leaf_spec_dp_only(path, leaf) -> P:
    """Pure FSDP: shard dim 0 of every >=2D weight over (data, model)."""
    rank = getattr(leaf, "ndim", 0)
    if rank < 2:
        return P()
    # layer-stacked leaves: shard the first non-layer dim
    spec = [None] * rank
    spec[rank - 2] = ("data", "model")
    return P(*spec)


def _drop_data(spec: P) -> P:
    """serve_tp: params live TP-only (no FSDP axis) — decode must not
    all-gather params over 'data' on every token."""
    return P(*(None if d == "data" else d for d in spec))


def param_specs(params_abstract: Pytree) -> Pytree:
    if flags.flag("dp_only"):
        return jax.tree_util.tree_map_with_path(_leaf_spec_dp_only, params_abstract)
    tree = jax.tree_util.tree_map_with_path(_leaf_spec, params_abstract)
    if flags.flag("serve_tp"):
        tree = jax.tree.map(_drop_data, tree,
                            is_leaf=lambda x: isinstance(x, P))
    return tree


def opt_specs(opt_abstract: Pytree) -> Pytree:
    """Optimizer moments mirror parameter sharding (ZeRO)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _leaf_spec(path[2:] if len(path) > 2 else path, leaf),
        opt_abstract,
    )


def batch_specs(batch_abstract: dict, multi_pod: bool) -> dict:
    dp = dp_axes(multi_pod)
    out = {}
    for k, v in batch_abstract.items():
        b = v.shape[0] if v.shape else 0
        bspec = dp if b > 1 else None
        out[k] = P(bspec, *((None,) * (len(v.shape) - 1)))
    return out


def decode_cache_specs(cache_abstract: dict, multi_pod: bool, batch: int) -> dict:
    """KV/state cache shardings; SP over sequence when batch == 1."""
    dp = dp_axes(multi_pod)
    bspec = dp if batch > 1 else None
    seq_spec = None if batch > 1 else "data"  # SP for long-context decode
    # dp_only folds 'model' into the data axes — don't shard heads on it too
    model = None if flags.flag("dp_only") else "model"
    specs = {}
    for k, v in cache_abstract.items():
        if k == "len":
            specs[k] = P()
        elif k in ("k", "v"):
            # (L_or_G, B, Hkv, S, hd)
            specs[k] = P(None, bspec, model, seq_spec, None)
        elif k in ("x_tm", "x_cm"):
            specs[k] = P(None, bspec, model)
        elif k == "s":
            specs[k] = P(None, bspec, model, None, None)
        elif k in ("group_conv",):
            specs[k] = P(None, None, bspec, None, model)
        elif k in ("group_ssm",):
            specs[k] = P(None, None, bspec, model, None, None)
        elif k in ("tail_conv",):
            specs[k] = P(None, bspec, None, model)
        elif k in ("tail_ssm",):
            specs[k] = P(None, bspec, model, None, None)
        else:
            specs[k] = P()
    return specs


def logits_spec(multi_pod: bool, batch: int) -> P:
    dp = dp_axes(multi_pod)
    model = None if flags.flag("dp_only") else "model"
    return P(dp if batch > 1 else None, model)
