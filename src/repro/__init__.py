"""SpiDR reproduction — public API.

The supported way in is the ``spidr`` deployment facade: declare a
:class:`~repro.spidr.DeployTarget` (weight/Vmem precision pair, core
count, backend, chunking, stream capacity) and compile a network onto it:

    from repro import spidr

    compiled = spidr.compile(spec, params, spidr.DeployTarget(n_cores=4))
    out = compiled.run(events)
    cost = compiled.cost(out)

plus the objects needed to construct its inputs: network specs
(``SNNSpec`` / ``gesture_net`` / ``optical_flow_net`` / ``init_params``),
the precision configuration (``QuantSpec``) and the trained integer
artifact (``ExportedNetwork``, produced by ``repro.snn.train`` +
``repro.snn.export``).

Everything else — ``repro.engine``, ``repro.compiler``, ``repro.kernels``,
``repro.snn.export`` — is a documented internal layer: importable and
stable enough for tests and power users, but the facade is the contract
(``tests/test_public_api.py`` pins this surface).
"""
from . import spidr
from .core.network import SNNSpec, gesture_net, init_params, optical_flow_net
from .core.quant import SUPPORTED_PRECISIONS, QuantSpec
from .snn.export import ExportedNetwork
from .spidr import (
    CompiledSNN,
    DeployTarget,
    Fleet,
    ServeConfig,
    StreamSession,
    VerifyReport,
)

__all__ = [
    # The deployment facade (the primary public API).
    "spidr",
    "CompiledSNN",
    "DeployTarget",
    "StreamSession",
    "VerifyReport",
    # The serving fleet (spidr.serve).
    "Fleet",
    "ServeConfig",
    # Network construction.
    "SNNSpec",
    "gesture_net",
    "optical_flow_net",
    "init_params",
    # Precision configuration.
    "QuantSpec",
    "SUPPORTED_PRECISIONS",
    # Trained integer artifact (deploys via spidr.compile / spidr.load).
    "ExportedNetwork",
]
