"""Neuron models (paper C8, Sec II-A).

The neuron macro supports integrate-and-fire (IF) and leaky-integrate-and-
fire (LIF) models, each with *soft* or *hard* reset:

  hard reset : V <- 0            after a spike
  soft reset : V <- V - theta    after a spike (residual potential kept)

Neuron parameters (threshold, leak) live in reserved rows of the neuron
macro; here they are per-layer arrays.  Two execution modes are provided:

  * integer mode  — bit-exact with the digital neuron macro: Vmem is a
    (2W-1)-bit signed integer, leak is a right-shift (digital LIF), the
    threshold compare + conditional-write reset mirrors the augmented
    Store stage.
  * float mode    — used for surrogate-gradient training (QAT handles the
    precision; dynamics in float for stable gradients).

``spike_surrogate`` is the custom-vjp Heaviside with a triangle surrogate
derivative, shared by both modes so the integer forward pass can still be
trained through if desired.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Literal

import jax
import jax.numpy as jnp

from .quant import QuantSpec, saturate

__all__ = [
    "NeuronConfig",
    "if_step",
    "lif_step",
    "neuron_step",
    "neuron_step_int",
    "neuron_step_qat",
    "spike_surrogate",
]


@dataclasses.dataclass(frozen=True)
class NeuronConfig:
    model: Literal["if", "lif"] = "if"
    reset: Literal["hard", "soft"] = "hard"
    threshold: float = 1.0
    # LIF leak: float mode multiplies by ``leak``; integer mode right-shifts by
    # ``leak_shift`` (V <- V - (V >> leak_shift)), the standard digital LIF.
    leak: float = 0.9
    leak_shift: int = 3
    surrogate_width: float = 1.0

    def __post_init__(self):
        assert self.model in ("if", "lif")
        assert self.reset in ("hard", "soft")


# --------------------------------------------------------------------------
# Surrogate-gradient spike function (triangle / piecewise-linear surrogate).
# --------------------------------------------------------------------------
@partial(jax.custom_vjp, nondiff_argnums=(2,))
def spike_surrogate(v: jax.Array, threshold: jax.Array, width: float = 1.0):
    return (v >= threshold).astype(v.dtype)


def _spike_fwd(v, threshold, width):
    return spike_surrogate(v, threshold, width), (v, threshold)


def _spike_bwd(width, res, g):
    v, threshold = res
    x = (v - threshold) / width
    surr = jnp.maximum(0.0, 1.0 - jnp.abs(x)) / width
    dv = g * surr
    if jnp.ndim(threshold) == 0:
        dthr = -jnp.sum(dv)
    else:
        # Per-channel thresholds broadcast against v: reduce the cotangent
        # back down to the threshold's shape (sum over broadcast axes).
        extra = tuple(range(jnp.ndim(dv) - jnp.ndim(threshold)))
        dthr = -jnp.sum(dv, axis=extra)
    return dv, dthr


spike_surrogate.defvjp(_spike_fwd, _spike_bwd)


# --------------------------------------------------------------------------
# STE floor: the digital leak shift V <- V - (V >> k) is floor division; in
# the deploy-exact QAT forward it appears as ``v - scale*floor(v_int * 2^-k)``
# and needs a pass-through gradient so the leak contributes ``1 - 2^-k``.
# --------------------------------------------------------------------------
@jax.custom_vjp
def _floor_ste(x: jax.Array) -> jax.Array:
    return jnp.floor(x)


_floor_ste.defvjp(lambda x: (_floor_ste(x), None), lambda _res, g: (g,))


# --------------------------------------------------------------------------
# Float-mode dynamics (training path).
# --------------------------------------------------------------------------
def neuron_step(v: jax.Array, current: jax.Array, cfg: NeuronConfig):
    """One timestep of the neuron macro in float mode.

    Returns ``(v_next, spikes)``.  Order matches the macro: partial->full
    Vmem accumulation, (leak), threshold compare, conditional-write reset.
    """
    if cfg.model == "lif":
        v = v * cfg.leak
    v = v + current
    s = spike_surrogate(v, jnp.asarray(cfg.threshold, v.dtype), cfg.surrogate_width)
    if cfg.reset == "hard":
        v_next = v * (1.0 - s)
    else:  # soft
        v_next = v - s * cfg.threshold
    return v_next, s


def if_step(v, current, cfg: NeuronConfig | None = None):
    cfg = cfg or NeuronConfig(model="if")
    return neuron_step(v, current, cfg)


def lif_step(v, current, cfg: NeuronConfig | None = None):
    cfg = cfg or NeuronConfig(model="lif")
    return neuron_step(v, current, cfg)


# --------------------------------------------------------------------------
# Integer-mode dynamics (bit-exact with the neuron macro datapath).
# --------------------------------------------------------------------------
def neuron_step_int(
    v: jax.Array,
    partial_vmem: jax.Array,
    cfg: NeuronConfig,
    spec: QuantSpec,
    threshold_int: int,
):
    """Bit-exact neuron macro step.

    ``v`` and ``partial_vmem`` are int32 holding (2W-1)-bit values.  The
    macro performs: full += partial (saturating), optional leak shift,
    compare against the integer threshold stored in the reserved parameter
    rows, then the conditional-write reset in the Store stage.
    """
    v = v.astype(jnp.int32)
    if cfg.model == "lif":
        # Digital leak: V <- V - (V >> k). Arithmetic shift keeps sign.
        v = v - (v >> cfg.leak_shift)
    v = saturate(v + partial_vmem.astype(jnp.int32), spec)
    s = (v >= threshold_int).astype(jnp.int32)
    if cfg.reset == "hard":
        v_next = v * (1 - s)
    else:
        v_next = saturate(v - s * threshold_int, spec)
    return v_next, s


# --------------------------------------------------------------------------
# Deploy-exact QAT dynamics (float forward, surrogate gradients) — the exact
# scaled image of ``neuron_step_int`` under a power-of-two ``scale``.
# --------------------------------------------------------------------------
def neuron_step_qat(
    v: jax.Array,
    current: jax.Array,
    cfg: NeuronConfig,
    spec: QuantSpec,
    scale: jax.Array,
    threshold_scaled: jax.Array,
):
    """One deploy-exact QAT timestep: ``(v_next, spikes)``.

    ``v`` and ``current`` are floats of the form ``scale * <integer>``
    (``current`` already saturated to the scaled Vmem range by the layer);
    ``scale`` is the layer's power-of-two weight scale and
    ``threshold_scaled = scale * thr_int`` the requantized threshold.
    Because the scale is a power of two, every operation below computes
    ``scale *`` (the corresponding integer-datapath operation) exactly:
    the emitted spike train is bit-identical to ``neuron_step_int`` on the
    folded integers — while gradients flow through the triangle surrogate,
    pass-through clips and the STE floor of the leak shift.

    Deployment convention (matches the engine/kernels): the leak applies
    only when ``leak_shift > 0`` — shift 0 means "no leak", not "hard
    decay", so an exported LIF layer reproduces exactly.
    """
    scale = jax.lax.stop_gradient(scale)
    threshold_scaled = jax.lax.stop_gradient(threshold_scaled)
    lo, hi = scale * spec.v_min, scale * spec.v_max
    if cfg.model == "lif" and cfg.leak_shift > 0:
        # Digital leak V <- V - (V >> k): arithmetic shift is floor division,
        # mirrored here on the scaled grid with an STE floor.
        v = v - scale * _floor_ste(v / scale * (2.0 ** -cfg.leak_shift))
    v = jnp.clip(v + current, lo, hi)
    s = spike_surrogate(v, threshold_scaled, cfg.surrogate_width)
    if cfg.reset == "hard":
        v_next = v * (1.0 - s)
    else:
        v_next = jnp.clip(v - s * threshold_scaled, lo, hi)
    return v_next, s
