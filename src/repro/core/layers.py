"""Functional spiking layers (paper C5 + C1: input loader + macro compute).

The input loader performs im2col *in hardware* during execution — including
zero padding and stride — so a spiking convolution becomes a spike-matrix x
weight-matrix product the CIM macro can execute (binary inputs make the
GEMM multiplication-free).  We mirror that structure exactly:

    spikes (B, H, W, C) --im2col--> (B, P, R*S*C) binary
    weights (R*S*C, K)  (quantized, weight-stationary)
    partial Vmem (B, P, K) = spike_gemm(im2col, W)
    neuron macro: full Vmem update + fire + reset   (neuron.py)

Three execution paths share this structure:
  * ``mode="train"``  — float weights fake-quantized with STE (QAT);
    surrogate-gradient spike function; differentiable end to end.
  * ``mode="qat"``    — deploy-exact QAT: per-channel power-of-two fake
    quant, scaled saturation and the digital leak shift, so the forward
    spike train is bit-identical to the exported integer engine
    (``snn.export``) while staying differentiable end to end.
  * ``mode="int"``    — int8 weights, int32 Vmem with (2W-1)-bit
    saturation: bit-exact with the macro datapath (tests cross-check
    against ``cim_macro.accumulate_sequential``).

The Pallas `spike_gemm` kernel is a drop-in for the einsum on TPU; layers
take a ``matmul`` callable so the kernel can be injected without changing
layer logic.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from .neuron import NeuronConfig, neuron_step, neuron_step_int, neuron_step_qat
from .quant import (
    QuantSpec,
    quantize,
    requantize_threshold,
    saturate,
    ste_quantize,
    ste_quantize_po2_scaled,
)

__all__ = [
    "SpikingConvParams",
    "SpikingDenseParams",
    "im2col",
    "spiking_conv",
    "spiking_dense",
    "maxpool2d",
    "init_conv",
    "init_dense",
]


def _default_matmul(spikes: jax.Array, w: jax.Array) -> jax.Array:
    """(…, F) x (F, K) — contraction over fan-in."""
    return jnp.einsum("...f,fk->...k", spikes, w)


def _exact_matmul(spikes: jax.Array, w: jax.Array) -> jax.Array:
    """Full-float32 contraction for the deploy-exact QAT path.

    The bit-exactness contract needs every product/partial sum held as an
    exact ``scale * <integer>`` in float32; TPU's default matmul precision
    lowers f32 GEMMs to bf16 MXU passes (8 mantissa bits — the fan-in
    accumulations need ~18), so the qat path pins the highest precision.
    """
    return jnp.einsum("...f,fk->...k", spikes, w,
                      precision=jax.lax.Precision.HIGHEST)


# ---------------------------------------------------------------------------
# Input loader: hardware im2col with padding + stride (Sec II-D).
# ---------------------------------------------------------------------------
def im2col(
    x: jax.Array, kh: int, kw: int, stride: int = 1, padding: int = 0
) -> jax.Array:
    """(B, H, W, C) -> (B, H_out*W_out, kh*kw*C) patches.

    Uses XLA's patch extraction; the IFspad layout (row = fan-in element,
    column = output position) is the transpose of the returned matrix.
    """
    b, h, w, c = x.shape
    x = jnp.pad(x, ((0, 0), (padding, padding), (padding, padding), (0, 0)))
    h_out = (h + 2 * padding - kh) // stride + 1
    w_out = (w + 2 * padding - kw) // stride + 1
    # Gather patches via conv_general_dilated_patches (NHWC).
    patches = jax.lax.conv_general_dilated_patches(
        x.astype(jnp.float32),
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )  # (B, H_out, W_out, C*kh*kw) with feature order (c, kh, kw)
    patches = patches.reshape(b, h_out * w_out, c * kh * kw)
    # Reorder features (c,kh,kw) -> (kh,kw,c) to match HWIO weight layout.
    patches = patches.reshape(b, h_out * w_out, c, kh * kw)
    patches = jnp.swapaxes(patches, -1, -2).reshape(b, h_out * w_out, kh * kw * c)
    return patches


@dataclasses.dataclass(frozen=True)
class SpikingConvParams:
    kh: int
    kw: int
    stride: int = 1
    padding: int = 1
    neuron: NeuronConfig = dataclasses.field(default_factory=NeuronConfig)


@dataclasses.dataclass(frozen=True)
class SpikingDenseParams:
    neuron: NeuronConfig = dataclasses.field(default_factory=NeuronConfig)


def init_conv(key, kh, kw, c_in, c_out, dtype=jnp.float32, gain: float = 3.0):
    """He-style uniform init with an SNN gain (spiking nets need hotter
    init than ANNs so the first layers fire at event-camera sparsity)."""
    scale = gain / jnp.sqrt(kh * kw * c_in)
    return jax.random.uniform(
        key, (kh * kw * c_in, c_out), dtype, minval=-scale, maxval=scale
    )


def init_dense(key, n_in, n_out, dtype=jnp.float32, gain: float = 3.0):
    scale = gain / jnp.sqrt(n_in)
    return jax.random.uniform(key, (n_in, n_out), dtype, minval=-scale, maxval=scale)


def _qat_update(current, scale, vmem, neuron: NeuronConfig, spec: QuantSpec):
    """Deploy-exact QAT tail shared by conv/dense: saturate the scaled
    current (the column-adder ``partial`` image), requantize the threshold
    onto the layer's power-of-two grid, and step the neuron.  ``scale`` is
    the fake-quant's own per-channel scale (shape ``(1, K)``)."""
    scale = jax.lax.stop_gradient(scale)[0]  # (K,)
    _, thr_scaled = requantize_threshold(neuron.threshold, scale, spec)
    current = jnp.clip(current, scale * spec.v_min, scale * spec.v_max)
    return neuron_step_qat(vmem, current, neuron, spec, scale, thr_scaled)


def spiking_conv(
    spikes: jax.Array,          # (B, H, W, C) binary
    w: jax.Array,               # (kh*kw*C, K) float (train) or int8 (int)
    vmem: jax.Array,            # (B, H_out, W_out, K) carry state
    p: SpikingConvParams,
    spec: QuantSpec,
    mode: str = "train",
    matmul: Optional[Callable] = None,
    w_scale: Optional[jax.Array] = None,
):
    """One timestep of a spiking conv layer. Returns (vmem', out_spikes)."""
    matmul = matmul or _default_matmul
    b = spikes.shape[0]
    cols = im2col(spikes, p.kh, p.kw, p.stride, p.padding)  # (B,P,F)
    h_out, w_out, k = vmem.shape[1], vmem.shape[2], w.shape[1]

    if mode == "train":
        wq = ste_quantize(w, spec.weight_bits)
        current = matmul(cols, wq).reshape(b, h_out, w_out, k)
        return neuron_step(vmem, current, p.neuron)

    if mode == "qat":
        wq, scale = ste_quantize_po2_scaled(w, spec.weight_bits, 0)
        mm = matmul if matmul is not _default_matmul else _exact_matmul
        return _qat_update(
            mm(cols, wq).reshape(b, h_out, w_out, k),
            scale, vmem, p.neuron, spec,
        )

    # Integer (bit-exact) path.
    assert w.dtype == jnp.int8 and w_scale is not None
    acc = matmul(cols.astype(jnp.int32), w.astype(jnp.int32))
    partial = saturate(acc, spec).reshape(b, h_out, w_out, k)
    thr_int = jnp.int32(jnp.round(p.neuron.threshold / w_scale))
    v_next, s = neuron_step_int(vmem, partial, p.neuron, spec, thr_int)
    return v_next, s.astype(jnp.float32)


def spiking_dense(
    spikes: jax.Array,          # (B, N_in) binary
    w: jax.Array,               # (N_in, N_out)
    vmem: jax.Array,            # (B, N_out)
    p: SpikingDenseParams,
    spec: QuantSpec,
    mode: str = "train",
    matmul: Optional[Callable] = None,
    w_scale: Optional[jax.Array] = None,
):
    """One timestep of a spiking FC layer. Returns (vmem', out_spikes)."""
    matmul = matmul or _default_matmul
    if mode == "train":
        wq = ste_quantize(w, spec.weight_bits)
        current = matmul(spikes, wq)
        return neuron_step(vmem, current, p.neuron)

    if mode == "qat":
        wq, scale = ste_quantize_po2_scaled(w, spec.weight_bits, 0)
        mm = matmul if matmul is not _default_matmul else _exact_matmul
        return _qat_update(mm(spikes, wq), scale, vmem, p.neuron, spec)

    assert w.dtype == jnp.int8 and w_scale is not None
    acc = matmul(spikes.astype(jnp.int32), w.astype(jnp.int32))
    partial = saturate(acc, spec)
    thr_int = jnp.int32(jnp.round(p.neuron.threshold / w_scale))
    v_next, s = neuron_step_int(vmem, partial, p.neuron, spec, thr_int)
    return v_next, s.astype(jnp.float32)


def maxpool2d(x: jax.Array, window: int = 2, stride: int = 2) -> jax.Array:
    """2x2 max-pool (Table II gesture net uses stride-2 maxpool)."""
    return jax.lax.reduce_window(
        x,
        -jnp.inf,
        jax.lax.max,
        (1, window, window, 1),
        (1, stride, stride, 1),
        "VALID",
    )


def quantize_layer_weights(w: jax.Array, spec: QuantSpec):
    """Float weights -> (int8 weights, scalar scale) for the int path."""
    return quantize(w, spec)
