"""Timestep pipelining with asynchronous handshaking (paper C7, Sec II-F, Fig 13).

Compute units have data-dependent execution times (spike-count dependent);
neuron units are fixed at 66 cycles (Eq. 3).  A rigid synchronous pipeline
would have to assume worst-case sparsity; SpiDR instead uses asynchronous
handshaking so each unit starts as soon as its operands arrive and stalls
only on true data dependences.

This is a discrete-event simulator of that handshake for a chain of
``n_cm`` compute macros feeding one neuron macro (Mode 2), or three
independent 3-CM chains (Mode 1).  Per timestep t and macro i:

  ready[i][t]   = finish of CM i's compute for t
  CM i's compute for t may start when:
    - CM i has finished its own compute for t-1           (resource)
    - CM i-1 has delivered its partial Vmem for t         (data, chained)
  The delivery costs ``transfer_cycles`` on BOTH sides (the SRAM port is
  busy), matching the Wait/Transfer slots of Fig 13.

Outputs: per-timestep latency, makespan, utilization per unit, and the
synchronous-worst-case makespan for comparison (the paper's motivation).

Streaming: the handshake's only cross-timestep coupling is when each unit
becomes free (``cm_free``/``recv_ready``/``nu_free``).  ``simulate_pipeline``
optionally takes and returns that :class:`PipelineState`, so a stream
processed chunk by chunk — resuming each call from the previous chunk's
final state — yields *exactly* the whole-stream makespan, independent of
how the timesteps are chunked (the streaming session manager relies on
this for chunking-invariant cumulative cycle accounting).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .cim_macro import NEURON_MACRO_CYCLES

__all__ = ["PipelineConfig", "PipelineResult", "PipelineState",
           "ROUTE_CYCLES_PER_SPIKE", "route_cycles", "simulate_pipeline"]

# Per-timestep fixed costs (cycles), derived in DESIGN.md from Table I:
# reset of partial Vmems + partial-Vmem transfer between units.
RESET_CYCLES = 32          # reset 32 partial Vmem rows
TRANSFER_CYCLES = 64       # move 32 Vmem rows between adjacent macros
PIPE_FILL = 2

# Multi-core extension (Sec II-E): output spikes crossing a core boundary
# travel as AER packets on the inter-core fabric.  Send + receive each take
# one cycle at the core's S2A-style front end — the same 2-cycles-per-spike
# figure as the intra-core sparsity scan (C3/C4), which is what makes the
# spike-routing overhead model consistent with the rest of the cycle model.
ROUTE_CYCLES_PER_SPIKE = 2


def route_cycles(n_spikes: float,
                 cycles_per_spike: int = ROUTE_CYCLES_PER_SPIKE) -> int:
    """Cycles to move ``n_spikes`` AER events across the inter-core fabric."""
    return int(np.ceil(float(n_spikes) * cycles_per_spike))


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    n_cm: int = 9                 # chained compute macros (mode 2) or 3 (mode 1)
    neuron_cycles: int = NEURON_MACRO_CYCLES
    transfer_cycles: int = TRANSFER_CYCLES
    reset_cycles: int = RESET_CYCLES


@dataclasses.dataclass
class PipelineState:
    """Resumable handshake state (absolute cycles since the stream began).

    Carries everything a chunk-by-chunk simulation needs for *all* of
    :class:`PipelineResult`'s quantities — makespan, busy counters and the
    synchronous-worst-case alternative — to be cumulative since the stream
    began and bit-identical to one whole-stream call, for any chunking.
    """

    cm_free: np.ndarray      # (n_cm,) when each compute macro is next free
    recv_ready: np.ndarray   # (n_cm,) when upstream partials arrive
    nu_free: int             # when the neuron macro is next free
    cm_busy: np.ndarray      # (n_cm,) cumulative busy cycles per macro
    nu_busy: int             # cumulative neuron-macro busy cycles
    total_T: int             # timesteps simulated since the stream began
    worst_compute: int       # max per-timestep CM cycles seen so far

    def to_dict(self) -> dict:
        """Deterministic, alias-free serializable view of the clocks.

        Every value is a fresh int64 numpy array (0-d for scalars): the
        dict can be written through the checkpoint layer and never shares
        storage with the live simulation state.
        """
        return {
            "cm_free": np.asarray(self.cm_free, np.int64).copy(),
            "recv_ready": np.asarray(self.recv_ready, np.int64).copy(),
            "nu_free": np.int64(self.nu_free),
            "cm_busy": np.asarray(self.cm_busy, np.int64).copy(),
            "nu_busy": np.int64(self.nu_busy),
            "total_T": np.int64(self.total_T),
            "worst_compute": np.int64(self.worst_compute),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "PipelineState":
        """Rebuild a resume point from :meth:`to_dict` output."""
        return cls(
            cm_free=np.asarray(d["cm_free"], np.int64).copy(),
            recv_ready=np.asarray(d["recv_ready"], np.int64).copy(),
            nu_free=int(d["nu_free"]),
            cm_busy=np.asarray(d["cm_busy"], np.int64).copy(),
            nu_busy=int(d["nu_busy"]),
            total_T=int(d["total_T"]),
            worst_compute=int(d["worst_compute"]),
        )

    @classmethod
    def zero(cls, n_cm: int = 9) -> "PipelineState":
        """The stream-start state: identical to passing ``state=None``.

        ``simulate_pipeline`` initializes all clocks/counters to zero when
        no state is given, so resuming from ``zero()`` is bit-identical to
        a fresh simulation — snapshots use it to give never-stepped slots
        a fixed serialized shape instead of a structure-changing ``None``.
        """
        return cls(cm_free=np.zeros(n_cm, np.int64),
                   recv_ready=np.zeros(n_cm, np.int64), nu_free=0,
                   cm_busy=np.zeros(n_cm, np.int64), nu_busy=0,
                   total_T=0, worst_compute=0)


@dataclasses.dataclass
class PipelineResult:
    makespan: int                  # total cycles for all timesteps
    sync_makespan: int             # rigid worst-case-synchronous pipeline
    cm_busy: np.ndarray            # (n_cm,) busy cycles per compute macro
    nu_busy: int
    per_timestep_finish: np.ndarray
    state: PipelineState | None = None   # final state (resume point)
    # When resumed from a prior state, every field above (and the derived
    # speedup/utilization properties) is cumulative since the stream began,
    # except per_timestep_finish which covers only this call's timesteps.

    @property
    def speedup_vs_sync(self) -> float:
        return self.sync_makespan / max(self.makespan, 1)

    @property
    def cm_utilization(self) -> np.ndarray:
        return self.cm_busy / max(self.makespan, 1)


def simulate_pipeline(
    compute_cycles: np.ndarray,  # (timesteps, n_cm) data-dependent CM cycles
    cfg: PipelineConfig | None = None,
    state: PipelineState | None = None,
) -> PipelineResult:
    """Simulate Fig 13's handshake for ``timesteps`` over a CM chain + NU.

    Pass the previous call's ``result.state`` as ``state`` to resume the
    clocks mid-stream: simulating a stream chunk by chunk this way produces
    bit-identical makespans to one whole-stream call, for any chunking.
    """
    cfg = cfg or PipelineConfig()
    T, n_cm = compute_cycles.shape
    assert n_cm == cfg.n_cm, (n_cm, cfg.n_cm)

    # finish[i] = time CM i finished its current timestep's compute+send.
    if state is None:
        cm_free = np.zeros(n_cm, dtype=np.int64)   # when the unit is next free
        recv_ready = np.zeros(n_cm, dtype=np.int64)  # upstream-arrival clocks
        nu_free = 0
        cm_busy = np.zeros(n_cm, dtype=np.int64)
        nu_busy = 0
        prior_T, prior_worst = 0, 0
    else:
        assert state.cm_free.shape == (n_cm,), state.cm_free.shape
        cm_free = state.cm_free.astype(np.int64).copy()
        recv_ready = state.recv_ready.astype(np.int64).copy()
        nu_free = int(state.nu_free)
        cm_busy = state.cm_busy.astype(np.int64).copy()
        nu_busy = int(state.nu_busy)
        prior_T, prior_worst = int(state.total_T), int(state.worst_compute)
    finish_t = np.zeros(T, dtype=np.int64)

    for t in range(T):
        upstream_done = 0
        for i in range(n_cm):
            # Start: unit free AND (for chained macros) upstream partials here.
            start = max(cm_free[i], recv_ready[i])
            work = cfg.reset_cycles + int(compute_cycles[t, i]) + PIPE_FILL
            end_compute = start + work
            # Handshake: transfer occupies both sender (i) and receiver (i+1).
            send_start = max(end_compute, upstream_done)
            end_send = send_start + cfg.transfer_cycles
            cm_busy[i] += work + cfg.transfer_cycles
            cm_free[i] = end_send
            if i + 1 < n_cm:
                recv_ready[i + 1] = end_send
            upstream_done = end_send
        # Neuron macro consumes the chain's final partials.
        nu_start = max(nu_free, upstream_done)
        nu_end = nu_start + cfg.neuron_cycles
        nu_busy += cfg.neuron_cycles
        nu_free = nu_end
        finish_t[t] = nu_end

    # Rigid synchronous alternative: every stage takes the worst case of the
    # whole run (so far, when resumed); stages advance in lockstep (the
    # design the paper avoids).
    worst_compute = max(int(compute_cycles.max()), prior_worst)
    total_T = prior_T + T
    stage = worst_compute + cfg.reset_cycles + PIPE_FILL + cfg.transfer_cycles
    sync_makespan = (n_cm + total_T - 1) * stage + cfg.neuron_cycles * total_T

    return PipelineResult(
        makespan=int(finish_t[-1]),
        sync_makespan=int(sync_makespan),
        cm_busy=cm_busy,
        nu_busy=int(nu_busy),
        per_timestep_finish=finish_t,
        state=PipelineState(cm_free=cm_free, recv_ready=recv_ready,
                            nu_free=int(nu_free), cm_busy=cm_busy.copy(),
                            nu_busy=int(nu_busy), total_T=total_T,
                            worst_compute=worst_compute),
    )
