"""Timestep pipelining with asynchronous handshaking (paper C7, Sec II-F, Fig 13).

Compute units have data-dependent execution times (spike-count dependent);
neuron units are fixed at 66 cycles (Eq. 3).  A rigid synchronous pipeline
would have to assume worst-case sparsity; SpiDR instead uses asynchronous
handshaking so each unit starts as soon as its operands arrive and stalls
only on true data dependences.

This is a discrete-event simulator of that handshake for a chain of
``n_cm`` compute macros feeding one neuron macro (Mode 2), or three
independent 3-CM chains (Mode 1).  Per timestep t and macro i:

  ready[i][t]   = finish of CM i's compute for t
  CM i's compute for t may start when:
    - CM i has finished its own compute for t-1           (resource)
    - CM i-1 has delivered its partial Vmem for t         (data, chained)
  The delivery costs ``transfer_cycles`` on BOTH sides (the SRAM port is
  busy), matching the Wait/Transfer slots of Fig 13.

Outputs: per-timestep latency, makespan, utilization per unit, and the
synchronous-worst-case makespan for comparison (the paper's motivation).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .cim_macro import NEURON_MACRO_CYCLES

__all__ = ["PipelineConfig", "PipelineResult", "simulate_pipeline"]

# Per-timestep fixed costs (cycles), derived in DESIGN.md from Table I:
# reset of partial Vmems + partial-Vmem transfer between units.
RESET_CYCLES = 32          # reset 32 partial Vmem rows
TRANSFER_CYCLES = 64       # move 32 Vmem rows between adjacent macros
PIPE_FILL = 2


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    n_cm: int = 9                 # chained compute macros (mode 2) or 3 (mode 1)
    neuron_cycles: int = NEURON_MACRO_CYCLES
    transfer_cycles: int = TRANSFER_CYCLES
    reset_cycles: int = RESET_CYCLES


@dataclasses.dataclass
class PipelineResult:
    makespan: int                  # total cycles for all timesteps
    sync_makespan: int             # rigid worst-case-synchronous pipeline
    cm_busy: np.ndarray            # (n_cm,) busy cycles per compute macro
    nu_busy: int
    per_timestep_finish: np.ndarray

    @property
    def speedup_vs_sync(self) -> float:
        return self.sync_makespan / max(self.makespan, 1)

    @property
    def cm_utilization(self) -> np.ndarray:
        return self.cm_busy / max(self.makespan, 1)


def simulate_pipeline(
    compute_cycles: np.ndarray,  # (timesteps, n_cm) data-dependent CM cycles
    cfg: PipelineConfig | None = None,
) -> PipelineResult:
    """Simulate Fig 13's handshake for ``timesteps`` over a CM chain + NU."""
    cfg = cfg or PipelineConfig()
    T, n_cm = compute_cycles.shape
    assert n_cm == cfg.n_cm, (n_cm, cfg.n_cm)

    # finish[i] = time CM i finished its current timestep's compute+send.
    cm_free = np.zeros(n_cm, dtype=np.int64)    # when the unit is next free
    recv_ready = np.zeros(n_cm, dtype=np.int64)  # when upstream partials arrive
    nu_free = 0
    cm_busy = np.zeros(n_cm, dtype=np.int64)
    nu_busy = 0
    finish_t = np.zeros(T, dtype=np.int64)

    for t in range(T):
        upstream_done = 0
        for i in range(n_cm):
            # Start: unit free AND (for chained macros) upstream partials here.
            start = max(cm_free[i], recv_ready[i])
            work = cfg.reset_cycles + int(compute_cycles[t, i]) + PIPE_FILL
            end_compute = start + work
            # Handshake: transfer occupies both sender (i) and receiver (i+1).
            send_start = max(end_compute, upstream_done)
            end_send = send_start + cfg.transfer_cycles
            cm_busy[i] += work + cfg.transfer_cycles
            cm_free[i] = end_send
            if i + 1 < n_cm:
                recv_ready[i + 1] = end_send
            upstream_done = end_send
        # Neuron macro consumes the chain's final partials.
        nu_start = max(nu_free, upstream_done)
        nu_end = nu_start + cfg.neuron_cycles
        nu_busy += cfg.neuron_cycles
        nu_free = nu_end
        finish_t[t] = nu_end

    # Rigid synchronous alternative: every stage takes the worst case of the
    # whole run; stages advance in lockstep (the design the paper avoids).
    worst = int(compute_cycles.max()) + cfg.reset_cycles + PIPE_FILL
    stage = worst + cfg.transfer_cycles
    sync_makespan = (n_cm + T - 1) * stage + cfg.neuron_cycles * T

    return PipelineResult(
        makespan=int(finish_t[-1]),
        sync_makespan=int(sync_makespan),
        cm_busy=cm_busy,
        nu_busy=int(nu_busy),
        per_timestep_finish=finish_t,
    )
