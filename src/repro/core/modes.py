"""Reconfigurable operating modes + layer mapping (paper C6, Sec II-E, Fig 12).

The SNN core has 9 compute macros (CM) and 3 neuron macros (NU).  A layer's
fan-in (R*S*C for conv, N_in for FC) is mapped across CM *rows* (128 per
macro); output channels/neurons are packed along the 48 columns
(48/W_b per Vmem row pair) and across the 16 Vmem pairs (conv weight
reuse over output positions; FC uses only 1 pair).

  Mode 1  fan-in <= 128*3 : three parallel pipelines of 3 CMs + 1 NU.
          parallel output channels = 3 * 48/W_b            (Eq. 2)
  Mode 2  128*3 < fan-in <= 128*9 : all 9 CMs chained into 1 NU.
          parallel output channels = 48/W_b                (Eq. 2)

Paper cross-checks (Table III footnotes, at 4-bit weights):
  * max input neurons, FC mode 2 : 9 * 128 = 1152
  * max output neurons, conv mode 1: 3 * 12 * 16 = 576
"""
from __future__ import annotations

import dataclasses
import math
from typing import Literal

from .cim_macro import CM_WEIGHT_ROWS, IFSPAD_COLS
from .quant import QuantSpec

__all__ = ["CoreConfig", "LayerShape", "LayerMapping", "map_layer"]

N_COMPUTE_MACROS = 9
N_NEURON_MACROS = 3


@dataclasses.dataclass(frozen=True)
class CoreConfig:
    """One SpiDR core.

    ``n_cores`` declares the multi-core extension (paper Sec II-E) — but a
    single ``map_layer`` call only ever maps one core, so ``n_cores > 1``
    is rejected there: multi-core partition/place/schedule is
    :func:`repro.compiler.compile_network`'s job.
    """

    spec: QuantSpec
    n_compute_macros: int = N_COMPUTE_MACROS
    n_neuron_macros: int = N_NEURON_MACROS
    n_cores: int = 1


@dataclasses.dataclass(frozen=True)
class LayerShape:
    """Shape of one spiking layer in accelerator terms."""

    kind: Literal["conv", "fc"]
    fan_in: int             # R*S*C (conv) or N_in (fc)
    out_channels: int       # K (conv) or N_out (fc)
    out_positions: int = 1  # H_out*W_out for conv; 1 for fc

    @staticmethod
    def conv(r: int, s: int, c: int, k: int, h_out: int, w_out: int) -> "LayerShape":
        return LayerShape("conv", r * s * c, k, h_out * w_out)

    @staticmethod
    def fc(n_in: int, n_out: int) -> "LayerShape":
        return LayerShape("fc", n_in, n_out)


@dataclasses.dataclass(frozen=True)
class LayerMapping:
    mode: int                 # 1 or 2
    pipelines: int            # parallel CM->NU pipelines (3 or 1)
    macros_per_pipeline: int  # CMs chained per pipeline (<= 3 or <= 9)
    rows_per_macro: int       # fan-in rows used per macro (balanced, Sec II-F)
    parallel_channels: int    # output channels computed concurrently (Eq. 2)
    vmem_pairs_used: int      # 16 for conv, 1 for fc
    channel_tiles: int        # sequential tiles over output channels
    position_tiles: int       # sequential tiles over output positions
    fan_in_tiles: int         # sequential tiles when fan-in > mode capacity

    @property
    def total_passes(self) -> int:
        """Weight-stationary passes needed for the full layer."""
        return self.channel_tiles * self.position_tiles * self.fan_in_tiles


def map_layer(shape: LayerShape, core: CoreConfig,
              force_mode: int | None = None) -> LayerMapping:
    """Choose the operating mode and tiling for a layer (Fig 12 logic).

    ``map_layer`` maps a layer onto ONE core.  Multi-core placement is the
    compiler's job: partitioning a network across a grid of cores (and the
    per-layer mode/precision/stationarity selection that goes with it) lives
    in :func:`repro.compiler.compile_network`, which calls ``map_layer`` per
    core on the partitioned slices.

    ``force_mode`` overrides the fan-in-driven mode choice (the compiler's
    selector enumerates both modes when both are feasible); ``None`` keeps
    the paper's Fig 12 rule.
    """
    if core.n_cores > 1:
        raise ValueError(
            f"map_layer maps a layer onto one SpiDR core, but CoreConfig."
            f"n_cores={core.n_cores}; use repro.compiler.compile_network to "
            "partition/place/schedule a network across a multi-core grid "
            "(it invokes map_layer per core on the partitioned slices)"
        )
    spec = core.spec
    ch_per_pair = spec.neurons_per_row  # 48 / W_b

    mode1_cap = CM_WEIGHT_ROWS * 3
    mode2_cap = CM_WEIGHT_ROWS * core.n_compute_macros

    if force_mode is not None and force_mode not in (1, 2):
        raise ValueError(f"mode must be 1 or 2, got {force_mode}")
    mode_choice = force_mode or (1 if shape.fan_in <= mode1_cap else 2)
    if mode_choice == 1:
        mode, pipelines, macros_pp = 1, core.n_neuron_macros, 3
    else:
        mode, pipelines, macros_pp = 2, 1, core.n_compute_macros

    # Balanced row distribution (Sec II-F): input channels spread evenly so
    # spike-density variance, not row count, is the only execution-time skew.
    fan_in_tiles = math.ceil(shape.fan_in / (mode2_cap if mode == 2 else mode1_cap))
    fan_in_per_pass = math.ceil(shape.fan_in / fan_in_tiles)
    rows_per_macro = math.ceil(fan_in_per_pass / macros_pp)

    parallel_channels = pipelines * ch_per_pair  # Eq. (2)

    if shape.kind == "conv":
        vmem_pairs = IFSPAD_COLS
    else:
        vmem_pairs = 1  # no weight reuse: only one even/odd pair active

    channel_tiles = math.ceil(shape.out_channels / parallel_channels)
    position_tiles = math.ceil(shape.out_positions / vmem_pairs)

    return LayerMapping(
        mode=mode,
        pipelines=pipelines,
        macros_per_pipeline=macros_pp,
        rows_per_macro=rows_per_macro,
        parallel_channels=parallel_channels,
        vmem_pairs_used=vmem_pairs,
        channel_tiles=channel_tiles,
        position_tiles=position_tiles,
        fan_in_tiles=fan_in_tiles,
    )


def max_output_neurons_conv_mode1(spec: QuantSpec) -> int:
    """Table III footnote b: 576 at 4-bit."""
    return N_NEURON_MACROS * spec.neurons_per_row * IFSPAD_COLS


def max_input_neurons_fc_mode2() -> int:
    """Table III footnote a: 1152."""
    return N_COMPUTE_MACROS * CM_WEIGHT_ROWS
