"""Calibrated energy / throughput model (paper C9 — Table I, Fig 10/14/17).

Closed-form identities reverse-engineered from the chip measurements (see
DESIGN.md §1 for the derivation):

Throughput.  OPS are *dense-equivalent* synaptic accumulations (the
standard convention for sparsity-exploiting accelerators: zero-skipped ops
count toward throughput).  One IFspad "chunk" is 128x16 = 2048 spike
positions per macro; each position contributes 48/W_b accumulations.

    cycles_per_chunk(s) = 2 * 2048 * (1 - s) + OH
    GOPS(s, W_b, f)     = f * 9 * 2048 * (48/W_b) / cycles_per_chunk(s)

with OH = reset(32) + 2x transfer(64) + neuron(66) + pipeline fill(4)
+ handshake slack (calibrated 15.8) = 245.8 cycles.  This reproduces every
Table I throughput entry to <0.1 % and Fig 17's "~2x from 80->95 %
sparsity" (a pure 1/(1-s) model would wrongly give 4x).

Power.  Pure dynamic CV^2f fits both measured operating points:
    P(f, V) = C_EFF * V^2 * f,  C_EFF = 120.98 pF
    -> 4.90 mW @50 MHz/0.9 V (paper: 4.9), 18.15 mW @150 MHz/1.0 V (paper: 18).
A row operation always drives all 48 columns, so power is precision-
independent — exactly why the paper's TOPS/W scales as 48/W_b.

Energy efficiency.  TOPS/W = GOPS / P; reproduces all six Table I entries
(5 / 3.34 / 2.5 and 4.09 / 2.73 / 2.04).

Peripheral switching (Fig 10).  E_op(b) = e_add + e_sw / b with
e_sw = 5/9 * e_add gives the measured 1.5x energy/op reduction at batch 15
vs every-cycle switching, and <3 % further gain past depth 16.

Component breakdown (Fig 14).  Per-chunk energies distributed over
CIM macros (CM ops + NU), S2A, input loader/IFspad, control/clock, data
movement; calibrated so total average power at the reference point
(95 % sparsity, 4-bit, 50 MHz, 0.9 V) is exactly 4.9 mW.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "HW",
    "cycles_per_chunk",
    "gops",
    "power_mw",
    "tops_per_watt",
    "energy_per_op_batched",
    "chunk_energy_breakdown_nj",
    "table1_grid",
]

# ---------------------------------------------------------------------------
# Hardware constants (Sec II / Table I).
# ---------------------------------------------------------------------------
N_MACROS = 9
CHUNK_POSITIONS = 128 * 16            # IFspad positions per macro
OH_RESET = 32
OH_TRANSFER = 2 * 64
OH_NEURON = 66
OH_FILL = 4
OH_SLACK = 15.8                        # handshake slack, calibrated to Table I
OH_CYCLES = OH_RESET + OH_TRANSFER + OH_NEURON + OH_FILL + OH_SLACK  # 245.8

C_EFF_F = 120.98e-12                   # effective switched capacitance (F)
V_REF = 0.9
F_REF = 50e6
S_REF = 0.95
WB_REF = 4

# Fig 10 switching model: e_sw = (5/9) e_add gives exactly 1.5x at batch 15.
E_SW_OVER_E_ADD = 5.0 / 9.0

# Fig 14 component shares of the *reference-point* chunk energy.  The CIM
# macros dominate at both sparsity levels; data movement is a small slice.
_SHARES_REF = {
    "cim_macros": 0.62,     # compute-macro row ops + neuron units
    "s2a": 0.08,            # detector + FIFOs + controller
    "input_loader": 0.10,   # IFspad writes + im2col addressing
    "control_clock": 0.14,  # FSMs + clock tree (per-cycle)
    "data_movement": 0.06,  # partial-Vmem transfers + IO
}


@dataclasses.dataclass(frozen=True)
class HW:
    """Operating point."""

    freq_hz: float = F_REF
    vdd: float = V_REF

    def scaled(self) -> float:
        """Dynamic-energy scale factor vs the 0.9 V reference."""
        return (self.vdd / V_REF) ** 2


def cycles_per_chunk(sparsity: float) -> float:
    nnz = CHUNK_POSITIONS * (1.0 - sparsity)
    return 2.0 * nnz + OH_CYCLES


def gops(sparsity: float, weight_bits: int, freq_hz: float = F_REF) -> float:
    """Dense-equivalent GOPS (Table I / Fig 17)."""
    dense_accs = N_MACROS * CHUNK_POSITIONS * (48.0 / weight_bits)
    return freq_hz * dense_accs / cycles_per_chunk(sparsity) / 1e9


def power_mw(hw: HW = HW()) -> float:
    """Average power, dynamic CV^2f model (Table I)."""
    return C_EFF_F * hw.vdd**2 * hw.freq_hz * 1e3


def tops_per_watt(sparsity: float, weight_bits: int, hw: HW = HW()) -> float:
    return gops(sparsity, weight_bits, hw.freq_hz) / power_mw(hw)


def energy_per_op_batched(batch: int, e_add: float = 1.0) -> float:
    """Fig 10: energy per row op when peripherals switch every ``batch`` ops."""
    return e_add + E_SW_OVER_E_ADD * e_add / max(batch, 1)


# ---------------------------------------------------------------------------
# Per-chunk component energy model (Fig 14).
# ---------------------------------------------------------------------------
def _reference_chunk_energy_nj(hw: HW = HW()) -> float:
    """Total chunk energy at the reference point so avg power = 4.9 mW."""
    t_chunk_s = cycles_per_chunk(S_REF) / hw.freq_hz
    return power_mw(HW(hw.freq_hz, hw.vdd)) * 1e-3 * t_chunk_s * 1e9


def chunk_energy_breakdown_nj(
    sparsity: float, hw: HW = HW(), switch_batch: int = 15
) -> dict:
    """Energy (nJ) per 9-macro chunk round, by component.

    Activity scaling vs the reference point:
      * CIM macro op energy      ~ row ops          ~ (1 - s)
      * S2A detector energy      ~ spikes + row scan (70 % activity / 30 % scan)
      * input loader             ~ constant (raw map is always written)
      * control/clock            ~ cycles
      * data movement (transfers)~ constant per chunk
    Peripheral-switching energy rides on the macro term via Fig 10's model.
    """
    e_ref = _reference_chunk_energy_nj(hw)
    act_ref = 1.0 - S_REF
    act = 1.0 - sparsity
    cyc_ratio = cycles_per_chunk(sparsity) / cycles_per_chunk(S_REF)
    sw_ratio = energy_per_op_batched(switch_batch) / energy_per_op_batched(15)

    scale = hw.scaled() / HW().scaled()  # voltage scaling vs reference
    out = {
        "cim_macros": e_ref * _SHARES_REF["cim_macros"] * (act / act_ref) * sw_ratio,
        "s2a": e_ref * _SHARES_REF["s2a"] * (0.7 * act / act_ref + 0.3),
        "input_loader": e_ref * _SHARES_REF["input_loader"],
        "control_clock": e_ref * _SHARES_REF["control_clock"] * cyc_ratio,
        "data_movement": e_ref * _SHARES_REF["data_movement"],
    }
    return {k: v * scale for k, v in out.items()}


def chunk_energy_total_nj(sparsity: float, hw: HW = HW()) -> float:
    return float(sum(chunk_energy_breakdown_nj(sparsity, hw).values()))


def table1_grid() -> dict:
    """Reproduce the Table I efficiency/throughput grid."""
    out = {}
    for hw, label in ((HW(50e6, 0.9), "50MHz_0.9V"), (HW(150e6, 1.0), "150MHz_1.0V")):
        p = power_mw(hw)
        entry = {"power_mw": round(p, 2)}
        for wb in (4, 6, 8):
            entry[f"gops_{wb}b_95"] = round(gops(0.95, wb, hw.freq_hz), 2)
            entry[f"topsw_{wb}b_95"] = round(tops_per_watt(0.95, wb, hw), 2)
        out[label] = entry
    return out


# Paper's reported Table I values, for assertions in tests/benchmarks.
TABLE1_PAPER = {
    "50MHz_0.9V": {
        "power_mw": 4.9,
        "gops": {4: 24.54, 6: 16.36, 8: 12.27},
        "topsw": {4: 5.0, 6: 3.34, 8: 2.5},
    },
    "150MHz_1.0V": {
        "power_mw": 18.0,
        "gops": {4: 73.59, 6: 49.06, 8: 36.80},
        "topsw": {4: 4.09, 6: 2.73, 8: 2.04},
    },
}
