"""Zero-skipping vs AER cost model + sparsity accounting (paper C3, Fig 3-5).

SpiDR stores input spikes *raw* (1 bit/position) in the IFmem/IFspad and
skips zeros with the S2A detector, instead of using address-event
representation (AER).  AER encodes each event as an address tuple
(~log2(positions) bits + framing), which only wins at very high sparsity:
Fig 4's example layer breaks even at ~94.7 % — i.e. AER address words of
~19 bits for the optical-flow input layer (288x384x2 positions + polarity).

This module provides the storage/bandwidth cost model behind Fig 4 and the
sparsity statistics of Fig 5, plus the tile-level zero-skip accounting used
by the TPU adaptation (a tile is skipped iff ALL its spikes are zero — the
granularity at which an MXU can skip work).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

__all__ = [
    "aer_bits",
    "raw_bits",
    "aer_overhead",
    "aer_breakeven_sparsity",
    "sparsity",
    "tile_skip_fraction",
    "SparsityProfile",
]


def raw_bits(n_positions: int) -> int:
    """Raw (uncompressed) spike-map cost: 1 bit per position."""
    return int(n_positions)


def address_bits(n_positions: int, framing_bits: int = 1) -> int:
    """Bits per AER event: position address + framing/polarity bits."""
    return math.ceil(math.log2(max(n_positions, 2))) + framing_bits


def aer_bits(n_positions: int, n_events: int, framing_bits: int = 1) -> int:
    return n_events * address_bits(n_positions, framing_bits)


def aer_overhead(n_positions: int, sparsity_: float, framing_bits: int = 1) -> float:
    """AER cost / raw cost at a given input sparsity (Fig 4's y-axis)."""
    n_events = round(n_positions * (1.0 - sparsity_))
    return aer_bits(n_positions, n_events, framing_bits) / raw_bits(n_positions)


def aer_breakeven_sparsity(n_positions: int, framing_bits: int = 1) -> float:
    """Sparsity above which AER beats raw storage: 1 - 1/addr_bits."""
    return 1.0 - 1.0 / address_bits(n_positions, framing_bits)


def sparsity(x) -> float:
    """Fraction of zeros."""
    x = np.asarray(x)
    return float(np.mean(x == 0))


def tile_skip_fraction(spike_map: np.ndarray, tile: tuple[int, int]) -> float:
    """Fraction of (tile[0] x tile[1]) tiles that are all-zero.

    This is the work fraction the TPU spike_gemm kernel skips via
    ``@pl.when`` — the tile-granular analogue of the S2A's per-event skip.
    """
    r, c = spike_map.shape
    tr, tc = tile
    pr, pc = -r % tr, -c % tc
    padded = np.pad(spike_map, ((0, pr), (0, pc)))
    R, C = padded.shape
    tiles = padded.reshape(R // tr, tr, C // tc, tc).sum(axis=(1, 3))
    return float(np.mean(tiles == 0))


@dataclasses.dataclass
class SparsityProfile:
    """Per-layer input sparsity across timesteps (Fig 5)."""

    layer_names: list
    per_timestep: np.ndarray  # (layers, timesteps) sparsity values

    def summary(self):
        return {
            name: (float(row.min()), float(row.mean()), float(row.max()))
            for name, row in zip(self.layer_names, self.per_timestep)
        }
