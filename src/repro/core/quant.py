"""Reconfigurable weight/Vmem bit-precision support (paper C2, Sec II-A).

SpiDR supports three weight/Vmem precision pairs — 4/7, 6/11 and 8/15 bit —
selected as a configuration parameter before execution.  The invariant is

    B_Vmem = 2 * B_weight - 1

Weights are signed two's-complement integers stored in the macro's weight
rows; membrane potentials are signed integers twice as wide (minus one bit)
stored staggered across two Vmem rows.  Because the design is *digital* CIM
there is no analog non-ideality: integer arithmetic in JAX is bit-exact with
the silicon datapath.

This module provides:
  * ``QuantSpec``       — the precision configuration object.
  * ``quantize`` / ``dequantize`` — symmetric per-tensor / per-channel
    weight quantization used both by the functional SNN layers and by the
    LM serving path (``kernels/quant_matmul``).
  * ``sat_add``         — saturating add at Vmem precision (the column
    peripheral adder chain saturates rather than wrapping; see
    ``cim_macro.py`` for the exact per-op ordering).
  * ``ste_quantize``    — straight-through estimator for QAT.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

__all__ = [
    "PRECISION_PAIRS",
    "QuantSpec",
    "SUPPORTED_PRECISIONS",
    "quantize",
    "dequantize",
    "po2_scale",
    "po2_quantize",
    "requantize_threshold",
    "sat_add",
    "saturate",
    "ste_quantize",
    "ste_quantize_po2",
    "ste_quantize_po2_scaled",
    "fake_quant",
]


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """Weight/Vmem precision pair. ``vmem_bits = 2*weight_bits - 1``."""

    weight_bits: int

    def __post_init__(self):
        if self.weight_bits not in (4, 6, 8):
            raise ValueError(
                f"SpiDR supports 4/6/8-bit weights, got {self.weight_bits}"
            )

    @property
    def vmem_bits(self) -> int:
        return 2 * self.weight_bits - 1

    # Signed two's complement ranges -------------------------------------
    @property
    def w_min(self) -> int:
        return -(1 << (self.weight_bits - 1))

    @property
    def w_max(self) -> int:
        return (1 << (self.weight_bits - 1)) - 1

    @property
    def v_min(self) -> int:
        return -(1 << (self.vmem_bits - 1))

    @property
    def v_max(self) -> int:
        return (1 << (self.vmem_bits - 1)) - 1

    # Macro geometry hooks (Sec II-E, Eq. 1) ------------------------------
    @property
    def neurons_per_row(self) -> int:
        """48-column SRAM array packs 48/W_b weights per row."""
        return 48 // self.weight_bits

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"QuantSpec({self.weight_bits}/{self.vmem_bits}b)"


SUPPORTED_PRECISIONS = tuple(QuantSpec(b) for b in (4, 6, 8))

# The silicon's supported (B_weight, B_vmem) pairs, derived from the one
# invariant above.  THE single source of truth for precision validation:
# ``spidr.DeployTarget``, ``snn.export`` and ``repro.analysis`` all import
# this constant rather than restating the pairs.
PRECISION_PAIRS = tuple(
    (s.weight_bits, s.vmem_bits) for s in SUPPORTED_PRECISIONS)


def _scale_for(w: jax.Array, spec: QuantSpec, axis=None) -> jax.Array:
    amax = jnp.max(jnp.abs(w), axis=axis, keepdims=axis is not None)
    # Avoid div-by-zero for all-zero channels.
    amax = jnp.where(amax == 0, 1.0, amax)
    return amax / spec.w_max


def quantize(w: jax.Array, spec: QuantSpec, axis=None):
    """Symmetric quantization of float weights to signed ints.

    Returns ``(q, scale)`` with ``q`` int8 (covers up to 8-bit precision)
    and ``w ≈ q * scale``.  ``axis`` selects per-channel scales.
    """
    scale = _scale_for(w, spec, axis)
    q = jnp.clip(jnp.round(w / scale), spec.w_min, spec.w_max)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def saturate(v: jax.Array, spec: QuantSpec) -> jax.Array:
    """Clamp to the Vmem representable range (column adder saturation)."""
    return jnp.clip(v, spec.v_min, spec.v_max)


def sat_add(v: jax.Array, w: jax.Array, spec: QuantSpec) -> jax.Array:
    """One weight→Vmem accumulation at Vmem precision.

    Matches the peripheral adder: the sum is computed at full width and
    saturated into the (2W-1)-bit Vmem field before the Store stage.
    """
    return saturate(v.astype(jnp.int32) + w.astype(jnp.int32), spec)


# --------------------------------------------------------------------------
# Deploy-exact quantization: power-of-two per-channel scales.
#
# The train->deploy contract (snn/export.py) requires the float QAT forward
# to be an *exact* scaled image of the integer datapath.  With an arbitrary
# float scale that is impossible (every float multiply rounds); with a
# power-of-two scale every product/sum in the training graph is
# ``scale * <integer>`` held exactly in float32 (integers stay far below
# 2**24), so saturation bounds, thresholds and the leak shift all commute
# with the scaling — spike trains match the integer engine bit for bit.
# --------------------------------------------------------------------------
def po2_scale(w: jax.Array, spec: QuantSpec, axis=None) -> jax.Array:
    """Smallest power-of-two scale whose grid covers ``|w|`` per channel."""
    amax = jnp.max(jnp.abs(w), axis=axis, keepdims=axis is not None)
    amax = jnp.where(amax == 0, float(spec.w_max), amax)  # all-zero -> scale 1
    return jnp.exp2(jnp.ceil(jnp.log2(amax / spec.w_max))).astype(jnp.float32)


def po2_quantize(w: jax.Array, spec: QuantSpec, axis=None):
    """Symmetric quantization onto a power-of-two grid.

    Returns ``(q, scale)`` with ``q`` int8 and ``scale`` a power of two
    (per-channel when ``axis`` selects the reduction axis).  Shared verbatim
    by the QAT fake-quant forward (``ste_quantize_po2``) and the exporter
    (``snn.export``), so the deployed integers are *definitionally* the ones
    training saw.
    """
    scale = po2_scale(w, spec, axis)
    q = jnp.clip(jnp.round(w / scale), spec.w_min, spec.w_max)
    return q.astype(jnp.int8), scale


def requantize_threshold(threshold, scale: jax.Array, spec: QuantSpec):
    """Fold a float firing threshold onto a layer's integer Vmem grid.

    Returns ``(thr_int, thr_scaled)`` with ``thr_scaled = thr_int * scale``
    exactly (power-of-two ``scale``).  ``thr_int`` is clipped to
    ``[v_min, v_max + 1]``: above ``v_max`` the saturated Vmem can never
    reach it (the neuron never fires — identically in float and integer),
    below ``v_min`` it always fires.
    """
    t = jnp.clip(jnp.round(threshold / scale), spec.v_min, spec.v_max + 1)
    return t.astype(jnp.int32), (t * scale).astype(jnp.float32)


# --------------------------------------------------------------------------
# QAT: straight-through estimator.  Forward = fake-quantized weights,
# backward = identity.  This is what lets us train the paper's two networks
# at 4/6/8-bit and reproduce the Fig 16 accuracy/energy trade-off.
# --------------------------------------------------------------------------
@partial(jax.custom_vjp, nondiff_argnums=(1,))
def ste_quantize(w: jax.Array, weight_bits: int) -> jax.Array:
    spec = QuantSpec(weight_bits)
    q, scale = quantize(w, spec)
    return dequantize(q, scale)


def _ste_fwd(w, weight_bits):
    return ste_quantize(w, weight_bits), None


def _ste_bwd(weight_bits, _res, g):
    return (g,)


ste_quantize.defvjp(_ste_fwd, _ste_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def ste_quantize_po2_scaled(w: jax.Array, weight_bits: int, axis=0):
    """Deploy-exact fake-quant: per-channel power-of-two scales, STE grad.

    Forward returns ``(q * scale, scale)`` — the exact float image of the
    integers the exporter emits, plus the scale it used (so callers that
    need the scale — saturation bounds, threshold requantization — don't
    recompute the abs-max reduction).  Backward is the identity into ``w``;
    the scale output carries no gradient.
    """
    spec = QuantSpec(weight_bits)
    q, scale = po2_quantize(w, spec, axis)
    return dequantize(q, scale), scale


def _ste_po2_fwd(w, weight_bits, axis):
    return ste_quantize_po2_scaled(w, weight_bits, axis), None


def _ste_po2_bwd(weight_bits, axis, _res, g):
    return (g[0],)


ste_quantize_po2_scaled.defvjp(_ste_po2_fwd, _ste_po2_bwd)


def ste_quantize_po2(w: jax.Array, weight_bits: int, axis=0) -> jax.Array:
    """``ste_quantize_po2_scaled`` without the scale output."""
    return ste_quantize_po2_scaled(w, weight_bits, axis)[0]

# Alias used by the LM serving path.
fake_quant = ste_quantize
