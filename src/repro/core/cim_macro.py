"""Compute / neuron macro functional model (paper C1, Sec II-A, Fig 7-9).

The compute macro is a 160x48 10T SRAM array: the top 128 rows store
synaptic weights, the remaining 32 rows store partial membrane potentials.
Column peripherals implement a 3-stage Read / Compute / Store pipeline that
adds one weight row into one Vmem row per cycle.

Geometry and mapping (Fig 9):
  * Each IFspad row Y (0..127) corresponds to weight row Y.
  * Each IFspad column X (0..15) corresponds to the Vmem row *pair*
    (2X, 2X+1): Vmem precision is 2W-1 bits, so one logical Vmem vector
    occupies two staggered physical rows — the even row holds the Vmems of
    even-numbered weights, the odd row those of odd-numbered weights.
  * A spike at (Y, X) therefore triggers TWO row operations:
      even cycle:  Vmem[2X]   += even-numbered weights of row Y
      odd  cycle:  Vmem[2X+1] += odd-numbered weights of row Y

Because the design is digital, the functional result of processing a whole
IFspad is exactly

    Vmem[x, n] = saturate( sum_y spikes[y, x] * W[y, n] )

for every output neuron n packed in the columns (48/W_b of them).  The
*order* of saturating adds matters only when intermediate sums leave the
(2W-1)-bit range; ``accumulate_sequential`` reproduces the per-op
saturation order of the silicon, ``accumulate`` is the vectorized wide-sum
variant used by the fast path (and by the Pallas kernel).  Tests assert
they agree whenever no intermediate overflow occurs and that both stay in
range always.

Cycle accounting (used by pipeline.py / energy.py):
  * 2 cycles per spike (even+odd), 3-stage pipeline => throughput 1 row
    op/cycle once full, +2 fill/drain cycles per burst.
  * Neuron macro: fixed 66 cycles (Eq. 3) = 2*32 partial->full Vmem
    accumulation + threshold compare sweeps + 2 pipeline cycles.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .quant import QuantSpec, saturate

__all__ = [
    "MacroConfig",
    "CM_WEIGHT_ROWS",
    "CM_VMEM_ROWS",
    "CM_COLS",
    "IFSPAD_ROWS",
    "IFSPAD_COLS",
    "NEURON_MACRO_CYCLES",
    "accumulate",
    "accumulate_sequential",
    "macro_cycles",
    "pack_weight_rows",
]

# Fixed silicon geometry (Sec II-A).
CM_WEIGHT_ROWS = 128   # weight rows per compute macro
CM_VMEM_ROWS = 32      # physical Vmem rows (16 logical pairs)
CM_COLS = 48           # bit columns
IFSPAD_ROWS = 128      # IFspad rows  == weight rows
IFSPAD_COLS = 16       # IFspad cols  == logical Vmem pairs
NEURON_MACRO_CYCLES = 2 * CM_VMEM_ROWS + 2  # Eq. (3): 66


@dataclasses.dataclass(frozen=True)
class MacroConfig:
    spec: QuantSpec
    weight_rows: int = CM_WEIGHT_ROWS
    vmem_pairs: int = IFSPAD_COLS
    cols: int = CM_COLS

    @property
    def neurons(self) -> int:
        """Output neurons whose partial Vmems live in ONE Vmem row pair."""
        return self.cols // self.spec.weight_bits

    @property
    def max_output_neurons(self) -> int:
        """Eq. (1): (48/W_b) * 16 output neurons per macro (conv mode)."""
        return self.neurons * self.vmem_pairs


def pack_weight_rows(w: jax.Array, cfg: MacroConfig) -> jax.Array:
    """Validate/clip a (fan_in_chunk, neurons) int weight block for a macro.

    The silicon stores weights as W_b-bit fields along the 48 columns; the
    functional model just keeps them as int8 with range checking.
    """
    assert w.ndim == 2
    fan_in, neurons = w.shape
    if fan_in > cfg.weight_rows:
        raise ValueError(f"fan-in chunk {fan_in} exceeds {cfg.weight_rows} rows")
    if neurons > cfg.neurons:
        raise ValueError(
            f"{neurons} neurons exceed {cfg.neurons} = 48/{cfg.spec.weight_bits}"
        )
    return jnp.clip(w, cfg.spec.w_min, cfg.spec.w_max).astype(jnp.int8)


def accumulate(
    spikes: jax.Array,  # (rows, pairs) in {0,1}
    weights: jax.Array,  # (rows, neurons) int
    vmem: jax.Array,     # (pairs, neurons) int32, the partial Vmems
    spec: QuantSpec,
) -> jax.Array:
    """Vectorized weight->Vmem accumulation of one full IFspad.

    Wide int32 matmul then one saturation — the fast-path semantics (and the
    semantics of the spike_gemm Pallas kernel).
    """
    acc = jnp.einsum(
        "yx,yn->xn",
        spikes.astype(jnp.int32),
        weights.astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )
    return saturate(vmem.astype(jnp.int32) + acc, spec)


def accumulate_sequential(
    spikes: np.ndarray, weights: np.ndarray, vmem: np.ndarray, spec: QuantSpec
) -> np.ndarray:
    """Per-op saturating accumulation in silicon order (numpy reference).

    Processes spikes row-major (the S2A scans the IFspad row by row), with
    the even cycle then the odd cycle per spike, saturating after every
    row-add exactly like the column adder chain.
    """
    v = vmem.astype(np.int64).copy()
    rows, pairs = spikes.shape
    n = weights.shape[1]
    even = np.arange(n) % 2 == 0
    for y in range(rows):
        for x in range(pairs):
            if spikes[y, x]:
                # even cycle
                v[x, even] = np.clip(
                    v[x, even] + weights[y, even], spec.v_min, spec.v_max
                )
                # odd cycle
                v[x, ~even] = np.clip(
                    v[x, ~even] + weights[y, ~even], spec.v_min, spec.v_max
                )
    return v.astype(np.int32)


def macro_cycles(nnz: int, pipeline_fill: int = 2) -> int:
    """Compute-macro cycles to drain an IFspad with ``nnz`` spikes.

    2 row ops per spike (even+odd), 1 op/cycle steady state, plus fill/
    drain of the 3-stage R/C/S peripheral pipeline.
    """
    if nnz == 0:
        return 0
    return 2 * int(nnz) + pipeline_fill
