"""SpiDR core: the paper's contribution as composable JAX modules.

Layer map (paper mechanism -> module):
  C1 CIM macro            -> cim_macro
  C2 multi-precision      -> quant
  C3 zero-skipping / AER  -> zero_skip, s2a
  C4 even/odd batching    -> s2a, energy
  C5 hardware im2col      -> layers.im2col
  C6 operating modes      -> modes
  C7 timestep pipelining  -> pipeline
  C8 IF/LIF neurons       -> neuron
  C9 calibrated perf model-> energy
"""
from . import (  # noqa: F401
    cim_macro,
    energy,
    layers,
    modes,
    network,
    neuron,
    pipeline,
    quant,
    s2a,
    zero_skip,
)
from .neuron import NeuronConfig  # noqa: F401
from .quant import QuantSpec  # noqa: F401
