"""Spike-to-address converter model (paper C3/C4, Sec II-B/C, Fig 10-11).

The S2A scans the IFspad with a trailing-zero spike detector, pushes
(Y, X) tuples into an even/odd *ping-pong FIFO* pair, and the SRAM
controller drains one FIFO at a time — switching the column peripherals
between even and odd configurations only when the active FIFO empties (or
the other fills).  Consecutive same-parity operations amortize the
peripheral reconfiguration energy (Fig 10: batching 15 ops cuts energy/op
by 1.5x; depth 16 chosen because deeper FIFOs give diminishing returns).

This module is the *cycle/energy accounting* model: given a spike map it
replays the exact controller policy and reports

  * row operations issued (2 per spike: one even + one odd),
  * peripheral switches incurred,
  * average consecutive-run length (the "batch" of Fig 10),
  * compute-macro cycles.

It is deliberately plain Python/numpy — it models control flow that is
sequential in silicon, and is consumed by ``pipeline.py`` / ``energy.py``,
never traced by JAX.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["S2AConfig", "S2AStats", "simulate_s2a", "switch_count_batched"]


@dataclasses.dataclass(frozen=True)
class S2AConfig:
    fifo_depth: int = 16  # per-parity FIFO depth (Sec II-C)


@dataclasses.dataclass
class S2AStats:
    spikes: int
    row_ops: int            # even + odd operations issued
    switches: int           # peripheral reconfigurations
    runs: int               # consecutive same-parity bursts
    cycles: int             # compute-macro cycles (1 op/cycle + fill)

    @property
    def mean_run_length(self) -> float:
        return self.row_ops / max(self.runs, 1)


def simulate_s2a(spike_map: np.ndarray, cfg: S2AConfig | None = None) -> S2AStats:
    """Replay the ping-pong controller over a (rows, cols) 0/1 spike map.

    Policy (Sec II-C): the detector fills the EVEN fifo; after an even tuple
    is processed it is re-queued into the ODD fifo.  The controller keeps
    draining the current-parity fifo and switches parity only when it is
    empty or the opposite fifo is full.
    """
    cfg = cfg or S2AConfig()
    ys, xs = np.nonzero(spike_map)
    order = np.lexsort((xs, ys))  # detector scans row-major
    tuples = list(zip(ys[order].tolist(), xs[order].tolist()))

    n = len(tuples)
    if n == 0:
        return S2AStats(0, 0, 0, 0, 0)

    even_fifo: list[tuple[int, int]] = []
    odd_fifo: list[tuple[int, int]] = []
    pending = iter(tuples)
    exhausted = False

    def refill():
        nonlocal exhausted
        while not exhausted and len(even_fifo) < cfg.fifo_depth:
            try:
                even_fifo.append(next(pending))
            except StopIteration:
                exhausted = True

    refill()
    parity = 0  # 0 = even, 1 = odd
    ops = switches = runs = 0
    runs = 1
    while even_fifo or odd_fifo or not exhausted:
        refill()
        active, other = (even_fifo, odd_fifo) if parity == 0 else (odd_fifo, even_fifo)
        if active and (parity == 1 or len(odd_fifo) < cfg.fifo_depth):
            t = active.pop(0)
            ops += 1
            if parity == 0:
                odd_fifo.append(t)  # ping-pong requeue
        else:
            # switch parity: active empty, or odd fifo full (even side).
            if other or not exhausted:
                parity ^= 1
                switches += 1
                runs += 1
            else:
                break
    cycles = ops + 2 if ops else 0  # +2 R/C/S pipeline fill (Eq. 3 analogue)
    return S2AStats(spikes=n, row_ops=ops, switches=switches, runs=runs, cycles=cycles)


def switch_count_batched(n_spikes: int, batch: int) -> int:
    """Closed-form switches when ops are batched ``batch`` per parity.

    Baseline (batch=1) alternates every op: 2*n - 1 switches for 2*n ops.
    Batching b consecutive same-parity ops gives ceil(2*n / b) - 1.
    """
    if n_spikes == 0:
        return 0
    total_ops = 2 * n_spikes
    return int(np.ceil(total_ops / batch)) - 1
