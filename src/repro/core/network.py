"""The paper's two evaluation networks (Table II) as JAX SNNs.

  Optical flow estimation : input 288x384x2, 10 timesteps,
      Conv(2,32) + 6x Conv(32,32) + Conv(32,2)      (3x3, stride 1, pad 1)
  Gesture recognition     : input 64x64x2, 20 timesteps,
      Conv(2,16) + 4x Conv(16,16) + FC(64,11),
      2x2 stride-2 maxpool after every two intermediate conv layers,
      adaptive 2x2 pool before the FC so N_in = 16ch * 2 * 2 = 64.

Kernel sizes are not given in the paper; 3x3/stride-1/pad-1 is assumed
(standard for both reference tasks) — recorded in DESIGN.md §7.  The
networks are pure functions over a params pytree and scan over timesteps;
the same definition runs in float-QAT training mode and bit-exact integer
inference mode.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from .layers import (
    SpikingConvParams,
    SpikingDenseParams,
    init_conv,
    init_dense,
    maxpool2d,
    spiking_conv,
    spiking_dense,
)
from .modes import LayerShape
from .neuron import NeuronConfig
from .quant import QuantSpec

__all__ = ["SNNSpec", "gesture_net", "optical_flow_net", "init_params", "run_snn"]


@dataclasses.dataclass(frozen=True)
class SNNLayer:
    kind: str          # "conv" | "fc" | "pool" | "adaptive_pool"
    c_in: int = 0
    c_out: int = 0
    conv: SpikingConvParams | None = None
    fc: SpikingDenseParams | None = None
    target_hw: int = 0  # adaptive pool target


@dataclasses.dataclass(frozen=True)
class SNNSpec:
    name: str
    input_hw: tuple
    in_channels: int
    timesteps: int
    layers: tuple
    readout: str  # "rate" (classification) or "vmem" (regression/flow)

    def layer_shapes(self) -> list:
        """Accelerator-view shapes per weight layer (for modes/energy)."""
        h, w = self.input_hw
        out = []
        for l in self.layers:
            if l.kind == "conv":
                p = l.conv
                h_out = (h + 2 * p.padding - p.kh) // p.stride + 1
                w_out = (w + 2 * p.padding - p.kw) // p.stride + 1
                out.append(LayerShape.conv(p.kh, p.kw, l.c_in, l.c_out, h_out, w_out))
                h, w = h_out, w_out
            elif l.kind == "fc":
                out.append(LayerShape.fc(l.c_in, l.c_out))
            elif l.kind == "pool":
                h, w = h // 2, w // 2
            elif l.kind == "adaptive_pool":
                h = w = l.target_hw
        return out


def _conv(c_in, c_out, neuron=None):
    return SNNLayer(
        "conv",
        c_in,
        c_out,
        conv=SpikingConvParams(3, 3, 1, 1, neuron or NeuronConfig()),
    )


def gesture_net(neuron: NeuronConfig | None = None) -> SNNSpec:
    # Threshold/width tuned for event-camera input sparsity: low threshold +
    # wide triangle surrogate keeps early layers alive and gradients flowing.
    n = neuron or NeuronConfig(
        model="lif", reset="hard", threshold=0.5, leak=0.95, surrogate_width=2.0
    )
    return SNNSpec(
        name="gesture",
        input_hw=(64, 64),
        in_channels=2,
        timesteps=20,
        layers=(
            _conv(2, 16, n),
            _conv(16, 16, n),
            _conv(16, 16, n),
            SNNLayer("pool"),
            _conv(16, 16, n),
            _conv(16, 16, n),
            SNNLayer("pool"),
            SNNLayer("adaptive_pool", target_hw=2),
            SNNLayer("fc", 64, 11, fc=SpikingDenseParams(n)),
        ),
        readout="rate",
    )


def optical_flow_net(neuron: NeuronConfig | None = None) -> SNNSpec:
    n = neuron or NeuronConfig(
        model="if", reset="soft", threshold=0.5, surrogate_width=2.0
    )
    layers = [_conv(2, 32, n)]
    layers += [_conv(32, 32, n) for _ in range(6)]
    layers += [_conv(32, 2, n)]
    return SNNSpec(
        name="optical_flow",
        input_hw=(288, 384),
        in_channels=2,
        timesteps=10,
        layers=tuple(layers),
        readout="vmem",
    )


def init_params(key: jax.Array, spec: SNNSpec) -> list:
    params = []
    for l in spec.layers:
        if l.kind == "conv":
            key, k = jax.random.split(key)
            params.append(init_conv(k, l.conv.kh, l.conv.kw, l.c_in, l.c_out))
        elif l.kind == "fc":
            key, k = jax.random.split(key)
            params.append(init_dense(k, l.c_in, l.c_out))
        else:
            params.append(None)
    return params


def _init_state(spec: SNNSpec, batch: int):
    """Vmem carries for every stateful layer."""
    h, w = spec.input_hw
    states = []
    for l in spec.layers:
        if l.kind == "conv":
            p = l.conv
            h = (h + 2 * p.padding - p.kh) // p.stride + 1
            w = (w + 2 * p.padding - p.kw) // p.stride + 1
            states.append(jnp.zeros((batch, h, w, l.c_out)))
        elif l.kind == "fc":
            states.append(jnp.zeros((batch, l.c_out)))
        elif l.kind == "pool":
            h, w = h // 2, w // 2
            states.append(None)
        elif l.kind == "adaptive_pool":
            h = w = l.target_hw
            states.append(None)
    return states


def _forward_t(
    params, state, x_t, spec: SNNSpec, qspec: QuantSpec, mode: str, record_spikes=False
):
    """One timestep through all layers. Returns (state', out, spike_counts)."""
    act = x_t
    new_state = []
    spike_counts = []
    out = None
    for i, l in enumerate(spec.layers):
        if l.kind == "conv":
            v, s = spiking_conv(act, params[i], state[i], l.conv, qspec, mode)
            new_state.append(v)
            if record_spikes:
                spike_counts.append(jnp.sum(s))
            act, out = s, (v, s)
        elif l.kind == "fc":
            flat = act.reshape(act.shape[0], -1)
            v, s = spiking_dense(flat, params[i], state[i], l.fc, qspec, mode)
            new_state.append(v)
            if record_spikes:
                spike_counts.append(jnp.sum(s))
            act, out = s, (v, s)
        elif l.kind == "pool":
            act = maxpool2d(act)
            new_state.append(None)
        elif l.kind == "adaptive_pool":
            hw = act.shape[1]
            k = hw // l.target_hw
            act = maxpool2d(act, window=k, stride=k)
            new_state.append(None)
    return new_state, out, spike_counts


def run_snn(
    params,
    inputs: jax.Array,  # (T, B, H, W, C) binary event frames
    spec: SNNSpec,
    qspec: QuantSpec,
    mode: str = "train",
    record_spikes: bool = False,
):
    """Run all timesteps via lax.scan.

    ``mode`` selects the execution contract per layer (see ``core.layers``):
    ``"train"`` (float QAT, per-tensor STE), ``"qat"`` (deploy-exact QAT —
    the forward spike train is bit-identical to the exported integer
    engine) or ``"int"`` (quantized integer datapath).

    Returns the readout:
      * "rate": (B, n_classes) summed output spikes (rate code)
      * "vmem": (B, H, W, 2) final-layer Vmem (flow regression)
    plus per-layer total spike counts if ``record_spikes`` (for the
    sparsity profile of Fig 5 and the energy model).
    """
    batch = inputs.shape[1]
    state0 = _init_state(spec, batch)

    def step(carry, x_t):
        state, acc = carry
        state, (v, s), counts = _forward_t(
            params, state, x_t, spec, qspec, mode, record_spikes
        )
        acc = acc + s if spec.readout == "rate" else v
        counts = jnp.stack(counts) if record_spikes else jnp.zeros((1,))
        return (state, acc), counts

    n_out = spec.layers[-1].c_out
    if spec.readout == "rate":
        acc0 = jnp.zeros((batch, n_out))
    else:
        # Flow: Vmem of the last conv layer.
        h, w = spec.input_hw
        acc0 = jnp.zeros((batch, h, w, n_out))
    (state, acc), counts = jax.lax.scan(step, (state0, acc0), inputs)
    return acc, counts
