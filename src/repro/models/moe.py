"""Mixture-of-Experts with top-k token-choice routing (granite / moonshot).

The router's top-k selection IS SpiDR's zero-skipping made structural
(DESIGN.md §4): only k of E experts compute per token; the dispatch plays
the role of the S2A address queue (route events to the unit holding the
relevant weights).

Distribution: the MoE layer drops from pjit auto-SPMD into an explicit
``shard_map`` — auto-SPMD cannot partition the dispatch scatter (the first
dry-runs materialized 60 GiB replicated index tensors).  Per-device code
operates on LOCAL token blocks, so the capacity cumsum/scatter never
crosses devices:

  EP path (n_experts divisible by the model axis — moonshot 64/16):
    tokens sharded over data axes and replicated over 'model'; each device
    holds E/model_size experts and computes them for its local
    tokens; the combine is ONE psum over 'model' (same wire cost as a
    dense-FFN TP all-reduce).  Dispatch itself moves ZERO bytes.

  Replicated-experts path (granite 40 on 16): expert weights replicate
    inside the layer (per-layer all-gather) and tokens also shard over
    'model' via the sequence dim — every token is computed exactly once,
    no combine collective at all.

Single-device (tests) falls back to the same local function without
shard_map.  Over-capacity tokens drop (scatter mode='drop'), the standard
static-shape formulation.  Aux: Shazeer load-balance loss + router z-loss.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .. import flags
from ..sharding import _ACT, axis_divides
from .common import dense_init

__all__ = ["MoEParams", "init_moe", "moe_forward"]


class MoEParams(NamedTuple):
    w_router: jax.Array  # (D, E)
    w_gate: jax.Array    # (E, D, F)
    w_up: jax.Array      # (E, D, F)
    w_down: jax.Array    # (E, F, D)


def init_moe(key, d_model: int, d_ff: int, n_experts: int) -> MoEParams:
    ks = jax.random.split(key, 4)
    std = 1.0 / jnp.sqrt(d_model)
    stdf = 1.0 / jnp.sqrt(d_ff)
    return MoEParams(
        w_router=dense_init(ks[0], (d_model, n_experts)),
        w_gate=(jax.random.normal(ks[1], (n_experts, d_model, d_ff)) * std),
        w_up=(jax.random.normal(ks[2], (n_experts, d_model, d_ff)) * std),
        w_down=(jax.random.normal(ks[3], (n_experts, d_ff, d_model)) * stdf),
    )


def _local_moe(x, w_router, w_gate, w_up, w_down, top_k: int,
               capacity_factor: float, e_total: int, e_offset_fn=None):
    """Per-device MoE on LOCAL tokens. x: (T, D). Weights: local expert slice.

    Router scores against ALL e_total experts; only experts in the local
    slice [e0, e0+e_loc) are computed here.  Returns (out, aux-partials).
    """
    t, d = x.shape
    e_loc = w_gate.shape[0]
    e0 = e_offset_fn() if e_offset_fn else 0

    logits = jnp.einsum("td,de->te", x.astype(jnp.float32),
                        w_router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_ids = jax.lax.top_k(probs, top_k)             # (T, k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    cap = int(max(1, round(t * top_k / e_total * capacity_factor)))
    flat_ids = top_ids.reshape(-1)                           # (T*k,) global ids
    local_ids = flat_ids - e0
    in_slice = (local_ids >= 0) & (local_ids < e_loc)

    onehot = jax.nn.one_hot(flat_ids, e_total, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - 1
    pos = jnp.sum(pos * onehot, axis=-1)                     # (T*k,)
    keep = (pos < cap) & in_slice
    slot = jnp.where(keep, local_ids * cap + pos, e_loc * cap)

    token_idx = jnp.repeat(jnp.arange(t), top_k)
    buf = jnp.zeros((e_loc * cap, d), x.dtype)
    buf = buf.at[slot].set(jnp.take(x, token_idx, axis=0), mode="drop")
    buf = buf.reshape(e_loc, cap, d)

    dt = x.dtype
    gate = jnp.einsum("ecd,edf->ecf", buf, w_gate.astype(dt))
    up = jnp.einsum("ecd,edf->ecf", buf, w_up.astype(dt))
    h = jax.nn.silu(gate) * up
    out_buf = jnp.einsum("ecf,efd->ecd", h, w_down.astype(dt)).reshape(-1, d)

    gathered = out_buf.at[slot].get(mode="fill", fill_value=0)  # (T*k, D)
    w = (top_w.reshape(-1) * keep).astype(dt)
    out = jax.ops.segment_sum(gathered * w[:, None], token_idx, num_segments=t)

    frac = jnp.mean(jax.nn.one_hot(top_ids, e_total, dtype=jnp.float32), axis=(0, 1))
    mean_prob = jnp.mean(probs, axis=0)
    lb_loss = e_total * jnp.sum(frac * mean_prob)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    drop = 1.0 - jnp.mean(((pos < cap) & (flat_ids >= 0)).astype(jnp.float32))
    return out, lb_loss, z_loss, drop


def moe_forward(p: MoEParams, x: jax.Array, top_k: int,
                capacity_factor: float = 1.25):
    """x: (B, S, D). Returns (out, aux)."""
    b, s, d = x.shape
    e = p.w_router.shape[1]
    mesh = _ACT["mesh"]

    if mesh is None:  # single-device path (unit tests, host runs)
        out, lb, zl, drop = _local_moe(
            x.reshape(-1, d), p.w_router, p.w_gate, p.w_up, p.w_down,
            top_k, capacity_factor, e,
        )
        return out.reshape(b, s, d), {
            "load_balance_loss": lb, "router_z_loss": zl, "drop_fraction": drop
        }

    dp = _ACT["dp"] or ()
    # dp_only folds the model axis into data parallelism: inside this layer
    # there is no separate model axis to use for EP or token splitting.
    model_size = 1 if flags.flag("dp_only") else mesh.shape["model"]
    shard_map = functools.partial(
        jax.shard_map, mesh=mesh, check_vma=False
    )

    ep = model_size > 1 and e % model_size == 0
    b_spec = dp if (dp and b % _size(mesh, dp) == 0) else None
    if ep:
        # EP: tokens replicated over 'model'; each shard computes its slice.
        def fn(xl, wr, wg, wu, wd):
            t_loc = xl.shape[0] * xl.shape[1]
            e_loc = wg.shape[0]
            e0 = jax.lax.axis_index("model") * e_loc
            out, lb, zl, drop = _local_moe(
                xl.reshape(t_loc, d), wr, wg, wu, wd, top_k,
                capacity_factor, e, lambda: e0,
            )
            out = jax.lax.psum(out, "model")
            all_axes = tuple(mesh.axis_names)
            return (out.reshape(xl.shape),
                    jax.lax.pmean(lb, all_axes), jax.lax.pmean(zl, all_axes),
                    jax.lax.pmean(drop, all_axes))

        in_specs = (
            P(b_spec, None, None), P(None, None),
            P("model", None, None), P("model", None, None), P("model", None, None),
        )
        out_specs = (P(b_spec, None, None), P(), P(), P())
    else:
        # Replicated experts; tokens also split over 'model' (seq dim when
        # divisible, else redundant compute — only tiny decode batches).
        s_spec = "model" if (model_size > 1 and s % model_size == 0) else None

        def fn(xl, wr, wg, wu, wd):
            t_loc = xl.shape[0] * xl.shape[1]
            out, lb, zl, drop = _local_moe(
                xl.reshape(t_loc, d), wr, wg, wu, wd, top_k, capacity_factor, e,
            )
            all_axes = tuple(mesh.axis_names)
            return (out.reshape(xl.shape), jax.lax.pmean(lb, all_axes),
                    jax.lax.pmean(zl, all_axes), jax.lax.pmean(drop, all_axes))

        in_specs = (
            P(b_spec, s_spec, None), P(None, None),
            P(None, None, None), P(None, None, None), P(None, None, None),
        )
        out_specs = (P(b_spec, s_spec, None), P(), P(), P())

    out, lb, zl, drop = shard_map(fn, in_specs=in_specs, out_specs=out_specs)(
        x, p.w_router, p.w_gate, p.w_up, p.w_down
    )
    return out, {"load_balance_loss": lb, "router_z_loss": zl,
                 "drop_fraction": drop}


def _size(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n
