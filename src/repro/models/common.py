"""Shared model components: norms, RoPE, initializers, losses."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "rmsnorm",
    "rope_freqs",
    "apply_rope",
    "dense_init",
    "embed_init",
    "cross_entropy_loss",
]


def rmsnorm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * weight).astype(dtype)


def rope_freqs(head_dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: (..., S, H, D); positions: (..., S) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (D/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., 0::2], x[..., 1::2]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    out = jnp.stack([out1, out2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32):
    fan_in = shape[in_axis]
    std = 1.0 / jnp.sqrt(fan_in)
    return (jax.random.normal(key, shape) * std).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


def cross_entropy_loss(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean next-token CE; logits (B,S,V) fp32, labels (B,S) int32."""
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)
