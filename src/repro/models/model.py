"""Model driver: params init, forward, train/prefill/serve step builders.

One entry point per shape kind:
  * ``make_train_step(cfg)``   — fwd + CE loss (+ MoE aux) + bwd + AdamW
  * ``make_prefill_step(cfg)`` — full-sequence forward, returns last-token
    logits + the populated decode cache
  * ``make_decode_step(cfg)``  — one token against a KV/state cache

Everything is a pure function of (params, opt_state, batch) pytrees so the
launchers can pjit them with the partition specs from ``repro.sharding``.
Dry-run lowers these exact functions abstractly.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .. import flags
from ..configs.base import ArchConfig
from ..optim.optimizer import adamw, apply_updates, clip_by_global_norm
from ..sharding import constrain
from .common import cross_entropy_loss, dense_init, embed_init, rmsnorm
from .transformer import decode_blocks, forward_blocks, init_blocks, init_decode_state

__all__ = [
    "init_params",
    "abstract_params",
    "init_opt_state",
    "abstract_opt_state",
    "forward",
    "make_train_step",
    "make_prefill_step",
    "make_decode_step",
    "abstract_decode_cache",
]

COMPUTE_DTYPE = jnp.bfloat16
MOE_AUX_WEIGHT = 0.01
MOE_Z_WEIGHT = 1e-3


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------
def init_params(key: jax.Array, cfg: ArchConfig) -> dict:
    k_emb, k_blocks, k_head = jax.random.split(key, 3)
    # Megatron-style padded vocab: shards evenly over the model axis; the
    # pad logit columns are masked to -inf in _head_logits.
    params = {
        "embed": embed_init(k_emb, cfg.padded_vocab, cfg.d_model),
        "blocks": init_blocks(k_blocks, cfg),
        "final_norm": jnp.ones((cfg.d_model,)),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(k_head, (cfg.d_model, cfg.padded_vocab))
    return params


def _compute_params(params):
    """Mixed precision: bf16 compute copies of the fp32 masters.

    The cast is elementwise on the SHARDED leaves, so every downstream
    FSDP all-gather moves bf16 (half the wire bytes) and parameter
    cotangents come back as bf16 (halving the gradient reduction too).
    Masters + optimizer state stay fp32.
    """
    if not flags.flag("bf16_params"):
        return params

    def cast(p):
        if (p is None or not hasattr(p, "dtype") or p.dtype != jnp.float32
                or p.ndim < 2):
            return p
        # optimization_barrier stops XLA's excess-precision pass from
        # folding f32->bf16->f32 back to f32, which would silently move
        # the FSDP all-gathers back to 4-byte words.
        return jax.lax.optimization_barrier(p.astype(jnp.bfloat16))

    return jax.tree.map(cast, params, is_leaf=lambda x: x is None)


def _head_logits(params, cfg, h):
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum(
        "bsd,dv->bsv", h, head.astype(COMPUTE_DTYPE),
        preferred_element_type=jnp.float32,
    )
    if cfg.padded_vocab != cfg.vocab_size:  # mask pad columns
        valid = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(valid[None, None, :], logits, -1e30)
    return logits


def abstract_params(cfg: ArchConfig):
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


def init_opt_state(params):
    _, state = adamw(params=params)
    return state


def abstract_opt_state(params_abstract):
    return jax.eval_shape(
        lambda: {
            "mu": jax.tree.map(jnp.zeros_like, params_abstract),
            "nu": jax.tree.map(jnp.zeros_like, params_abstract),
        }
    )


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------
def forward(
    params: dict,
    cfg: ArchConfig,
    tokens: Optional[jax.Array] = None,   # (B, S) int32
    embeds: Optional[jax.Array] = None,   # (B, S, D) — stub-frontend input
    remat: bool = False,
    return_cache: bool = False,
):
    """Returns (logits, aux, cache)."""
    if embeds is None:
        h = params["embed"][tokens].astype(COMPUTE_DTYPE)
    else:
        h = embeds.astype(COMPUTE_DTYPE)
    # The embedding gather is where XLA propagation loses the batch
    # sharding — re-pin it before entering the layer stack.
    h = constrain(h, "dp", None, None)
    h, aux, cache = forward_blocks(
        params["blocks"], h, cfg, remat=remat, return_cache=return_cache
    )
    h = rmsnorm(h, params["final_norm"].astype(jnp.float32), cfg.rmsnorm_eps)
    logits = constrain(_head_logits(params, cfg, h), "dp", None, "model")
    return logits, aux, cache


# ---------------------------------------------------------------------------
# Train step (fwd + bwd + AdamW, grad-clipped)
# ---------------------------------------------------------------------------
def make_train_step(cfg: ArchConfig, lr: float = 3e-4, grad_clip: float = 1.0,
                    weight_decay: float = 0.1, remat: bool = True,
                    accum_steps: int = 1):
    """``accum_steps > 1`` scans over microbatches, accumulating fp32
    grads — activation memory scales with B/accum_steps while the
    optimizer sees the full-batch mean gradient."""
    update_fn, _ = adamw(lr=lr, weight_decay=weight_decay)

    def loss_fn(params, batch):
        params = _compute_params(params)
        tokens = batch.get("tokens")
        embeds = batch.get("embeds")
        logits, aux, _ = forward(params, cfg, tokens=tokens, embeds=embeds, remat=remat)
        # next-token prediction: shift by one
        loss = cross_entropy_loss(logits[:, :-1], batch["labels"][:, 1:])
        total = loss
        if aux:
            total = (
                total
                + MOE_AUX_WEIGHT * aux.get("load_balance_loss", 0.0)
                + MOE_Z_WEIGHT * aux.get("router_z_loss", 0.0)
            )
        return total, {"ce_loss": loss, **aux}

    def _grads_fp32(grads):
        # bf16 cotangents -> fp32 for the optimizer (masters are fp32)
        return jax.tree.map(
            lambda g: g.astype(jnp.float32)
            if g is not None and g.dtype == jnp.bfloat16 else g,
            grads, is_leaf=lambda x: x is None,
        )

    def train_step(params, opt_state, step, batch):
        if accum_steps == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch
            )
            grads = _grads_fp32(grads)
        else:
            # Microbatch scan: split the leading (batch) dim of every input.
            micro = jax.tree.map(
                lambda x: x.reshape(accum_steps, x.shape[0] // accum_steps,
                                    *x.shape[1:]),
                batch,
            )

            def mb_body(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                g = _grads_fp32(g)
                g_acc = jax.tree.map(
                    lambda a, b: a if b is None else a + b, g_acc, g,
                    is_leaf=lambda x: x is None,
                )
                return (g_acc, l_acc + l), None

            zeros = jax.tree.map(
                lambda p: None if p is None else jnp.zeros(p.shape, jnp.float32),
                params, is_leaf=lambda x: x is None,
            )
            (grads, loss_sum), _ = jax.lax.scan(mb_body, (zeros, 0.0), micro)
            grads = jax.tree.map(
                lambda g: None if g is None else g / accum_steps, grads,
                is_leaf=lambda x: x is None,
            )
            loss = loss_sum / accum_steps
            metrics = {"ce_loss": loss}
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        updates, opt_state = update_fn(grads, opt_state, params, step)
        params = apply_updates(params, updates)
        metrics = {"loss": loss, "grad_norm": gnorm, **metrics}
        return params, opt_state, metrics

    return train_step


# ---------------------------------------------------------------------------
# Prefill / decode steps
# ---------------------------------------------------------------------------
def make_prefill_step(cfg: ArchConfig):
    def prefill_step(params, batch):
        params = _compute_params(params)
        tokens = batch.get("tokens")
        embeds = batch.get("embeds")
        logits, _, cache = forward(
            params, cfg, tokens=tokens, embeds=embeds, return_cache=True
        )
        return logits[:, -1, :], cache

    return prefill_step


def make_decode_step(cfg: ArchConfig):
    def decode_step(params, cache, batch):
        params = _compute_params(params)
        tokens = batch.get("tokens")
        embeds = batch.get("embeds")
        if embeds is None:
            h = params["embed"][tokens].astype(COMPUTE_DTYPE)
        else:
            h = embeds.astype(COMPUTE_DTYPE)
        h = constrain(h, "dp", None, None)
        h, new_cache = decode_blocks(params["blocks"], h, cache, cfg)
        h = rmsnorm(h, params["final_norm"].astype(jnp.float32), cfg.rmsnorm_eps)
        logits = _head_logits(params, cfg, h)
        return logits[:, 0, :], new_cache

    return decode_step


def abstract_decode_cache(cfg: ArchConfig, batch: int, seq_len: int):
    return jax.eval_shape(lambda: init_decode_state(cfg, batch, seq_len))
