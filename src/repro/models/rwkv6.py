"""RWKV6 "Finch" — attention-free time-mix with data-dependent decay.

This is the arch where SpiDR's C1 maps most directly (DESIGN.md §4): the
per-head wkv state S (d_k x d_v) is a membrane potential — a stationary
accumulator updated by a decayed outer-product "event" per token, held in
fast memory while tokens stream through, exactly the weight/Vmem
co-location story.

Per head (head size N, here 64), with data-dependent per-channel decay
w_t in (0,1)^N and bonus u:

    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)

Training/prefill uses the CHUNKED parallel form (flash-linear-attention
style): within a chunk of C tokens the pairwise decay products are
computed in log space (all exponents <= 0, numerically safe) as a
(C, C, N) tensor contracted on the fly; across chunks a lax.scan carries S.
Decode is the plain recurrence.

Token-shift "ddlerp" (the Finch data-dependent lerp) uses the official
low-rank parameterization: 5-way tm LoRA (rank 32) + decay LoRA (rank 64).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..sharding import constrain
from .common import dense_init, rmsnorm

__all__ = [
    "RWKV6Params",
    "init_rwkv6_layer",
    "rwkv6_time_mix",
    "rwkv6_channel_mix",
    "rwkv6_time_mix_decode",
    "rwkv6_channel_mix_decode",
    "init_rwkv6_state",
]

TM_RANK = 32
TD_RANK = 64
HEAD_SIZE = 64


class RWKV6Params(NamedTuple):
    # time-mix ddlerp
    mu_x: jax.Array      # (D,)
    tm_w1: jax.Array     # (D, 5*TM_RANK)
    tm_w2: jax.Array     # (5, TM_RANK, D)
    mu_rkvwg: jax.Array  # (5, D)
    # projections
    wr: jax.Array        # (D, D)
    wk: jax.Array
    wv: jax.Array
    wg: jax.Array
    wo: jax.Array
    # decay
    td_w1: jax.Array     # (D, TD_RANK)
    td_w2: jax.Array     # (TD_RANK, D)
    time_decay: jax.Array  # (D,)
    bonus_u: jax.Array     # (D,)
    ln_x: jax.Array        # (D,) per-head groupnorm scale
    # channel-mix
    cm_mu_k: jax.Array   # (D,)
    cm_mu_r: jax.Array   # (D,)
    cm_wk: jax.Array     # (D, F)
    cm_wv: jax.Array     # (F, D)
    cm_wr: jax.Array     # (D, D)


def init_rwkv6_layer(key, cfg) -> RWKV6Params:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 12)
    return RWKV6Params(
        mu_x=jnp.full((d,), 0.5),
        tm_w1=dense_init(ks[0], (d, 5 * TM_RANK)),
        tm_w2=(jax.random.normal(ks[1], (5, TM_RANK, d)) * 0.01),
        mu_rkvwg=jnp.full((5, d), 0.5),
        wr=dense_init(ks[2], (d, d)),
        wk=dense_init(ks[3], (d, d)),
        wv=dense_init(ks[4], (d, d)),
        wg=dense_init(ks[5], (d, d)),
        wo=dense_init(ks[6], (d, d)),
        td_w1=dense_init(ks[7], (d, TD_RANK)),
        td_w2=(jax.random.normal(ks[8], (TD_RANK, d)) * 0.01),
        time_decay=jnp.full((d,), -2.0),
        bonus_u=(jax.random.normal(ks[9], (d,)) * 0.1),
        ln_x=jnp.ones((d,)),
        cm_mu_k=jnp.full((d,), 0.5),
        cm_mu_r=jnp.full((d,), 0.5),
        cm_wk=dense_init(ks[10], (d, f)),
        cm_wv=dense_init(ks[11], (f, d)),
        cm_wr=dense_init(jax.random.fold_in(key, 99), (d, d)),
    )


def _ddlerp(p: RWKV6Params, x, x_prev):
    """Finch data-dependent token shift -> (xr, xk, xv, xw, xg)."""
    sx = x_prev - x
    xxx = x + sx * p.mu_x.astype(x.dtype)
    lora = jnp.tanh(jnp.einsum("...d,dr->...r", xxx, p.tm_w1.astype(x.dtype)))
    b, s, _ = lora.shape if lora.ndim == 3 else (*lora.shape, None)[:3]
    lora = lora.reshape(*lora.shape[:-1], 5, TM_RANK)
    mix = jnp.einsum("...nr,nrd->...nd", lora, p.tm_w2.astype(x.dtype))
    mu = p.mu_rkvwg.astype(x.dtype)  # (5, D)
    streams = x[..., None, :] + sx[..., None, :] * (mu + mix)  # (..., 5, D)
    return [streams[..., i, :] for i in range(5)]


def _decay_log(p: RWKV6Params, xw):
    """log(w_t) = -exp(time_decay + lora(xw)); always < 0."""
    ww = jnp.einsum(
        "...d,dr->...r", jnp.tanh(xw.astype(jnp.float32)), p.td_w1.astype(jnp.float32)
    )
    ww = jnp.einsum("...r,rd->...d", ww, p.td_w2.astype(jnp.float32))
    return -jnp.exp(p.time_decay.astype(jnp.float32) + ww)


def _wkv_chunked(r, k, v, lw, u, s0, chunk: int):
    """Chunked wkv over a full sequence.

    r/k/v: (B, S, H, N); lw: (B, S, H, N) log-decay (<0); u: (H, N)
    s0: (B, H, N, N) initial state.  Returns (y, s_final).
    """
    b, s, h, n = r.shape
    nc = s // chunk

    def reshape_c(x):
        return x.reshape(b, nc, chunk, h, n).transpose(1, 0, 3, 2, 4)  # (nc,B,H,C,N)

    rc, kc, vc, lwc = map(reshape_c, (r, k, v, lw))

    tri_strict = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)

    def body(s_prev, inp):
        rb, kb, vb, lwb = inp  # (B,H,C,N)
        s_prev = constrain(s_prev, "dp", "model", None, None)
        lw_incl = jnp.cumsum(lwb, axis=2)           # (B,H,C,N)
        lw_excl = lw_incl - lwb
        # inter-chunk: y_i += (r_i * e^{lw_excl_i}) @ S_prev
        y_inter = jnp.einsum("bhcn,bhnm->bhcm", rb * jnp.exp(lw_excl), s_prev)
        # intra-chunk: A_ij = sum_n r_i k_j e^{lw_excl_i - lw_incl_j}, j<i
        ratio = jnp.exp(
            jnp.where(
                tri_strict[None, None, :, :, None],
                lw_excl[:, :, :, None, :] - lw_incl[:, :, None, :, :],
                -jnp.inf,
            )
        )  # (B,H,C,C,N) — exponents <= 0
        a = jnp.einsum("bhin,bhjn,bhijn->bhij", rb, kb, ratio)
        y_intra = jnp.einsum("bhij,bhjn->bhin", a, vb)
        # diagonal bonus term (j == i): y_i += (sum_n r_i u k_i) v_i
        diag_coef = jnp.sum(rb * u[None, :, None, :] * kb, axis=-1, keepdims=True)
        y_intra = y_intra + diag_coef * vb
        # state update
        decay_all = jnp.exp(lw_incl[:, :, -1:, :])          # (B,H,1,N)
        k_scaled = kb * jnp.exp(lw_incl[:, :, -1:, :] - lw_incl)
        s_new = s_prev * decay_all.squeeze(2)[..., None] + jnp.einsum(
            "bhcn,bhcm->bhnm", k_scaled, vb
        )
        return constrain(s_new, "dp", "model", None, None), y_inter + y_intra

    s_final, ys = jax.lax.scan(body, s0, (rc, kc, vc, lwc))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(b, s, h, n)
    return y, s_final


def _wkv_kernel_path(r, k, v, lw, u, s0, chunk):
    from ..kernels.wkv_chunk import wkv_sequence

    return wkv_sequence(r, k, v, lw, u, s0, chunk=chunk,
                        interpret=jax.default_backend() != "tpu")


def rwkv6_time_mix(p: RWKV6Params, x, x_prev, s0, cfg, chunk: int = 32,
                   use_kernel: bool | None = None):
    """Full-sequence time-mix. x: (B,S,D). Returns (y, x_last, s_final).

    ``use_kernel`` selects the Pallas wkv kernel (kernels/wkv_chunk.py);
    default: on real TPU only (the jnp chunked form is the oracle and the
    CPU path).
    """
    b, s, d = x.shape
    h, n = d // HEAD_SIZE, HEAD_SIZE
    xs = jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)
    xr, xk, xv, xw, xg = _ddlerp(p, x, xs)
    dt = x.dtype
    r = constrain(jnp.einsum("bsd,de->bse", xr, p.wr.astype(dt)).reshape(b, s, h, n), "dp", None, "model", None)
    k = constrain(jnp.einsum("bsd,de->bse", xk, p.wk.astype(dt)).reshape(b, s, h, n), "dp", None, "model", None)
    v = constrain(jnp.einsum("bsd,de->bse", xv, p.wv.astype(dt)).reshape(b, s, h, n), "dp", None, "model", None)
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, p.wg.astype(dt)))
    lw = _decay_log(p, xw).reshape(b, s, h, n)

    u = p.bonus_u.astype(jnp.float32).reshape(h, n)
    pad = -s % chunk
    if pad:
        zf = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zf(r), zf(k), zf(v)
        lw = jnp.pad(lw, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=-0.1)
    s0 = constrain(s0.astype(jnp.float32), "dp", "model", None, None)
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    wkv_fn = _wkv_kernel_path if use_kernel else _wkv_chunked
    y, s_f = wkv_fn(
        r.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        lw, u, s0, min(chunk, r.shape[1]),
    )
    y = y[:, :s]
    # per-head groupnorm then gate + out proj
    y = rmsnorm(y.reshape(b, s, h, n), jnp.ones((n,)), 64e-5).reshape(b, s, d)
    y = (y.astype(dt) * p.ln_x.astype(dt)) * g
    out = jnp.einsum("bsd,de->bse", y, p.wo.astype(dt))
    return out, x[:, -1, :], s_f


def rwkv6_channel_mix(p: RWKV6Params, x, x_prev):
    """Finch channel-mix (squared-relu FFN with token shift)."""
    xs = jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)
    sx = xs - x
    dt = x.dtype
    xk = x + sx * p.cm_mu_k.astype(dt)
    xr = x + sx * p.cm_mu_r.astype(dt)
    k = jnp.einsum("bsd,df->bsf", xk, p.cm_wk.astype(dt))
    k = jnp.square(jax.nn.relu(k))
    kv = jnp.einsum("bsf,fd->bsd", k, p.cm_wv.astype(dt))
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p.cm_wr.astype(dt)))
    return r * kv, x[:, -1, :]


def rwkv6_channel_mix_decode(p: RWKV6Params, x, x_prev):
    """Single-token channel mix. x: (B, 1, D); x_prev: (B, D)."""
    out, _ = rwkv6_channel_mix(p, x, x_prev)
    return out, x[:, -1, :]


def rwkv6_time_mix_decode(p: RWKV6Params, x, x_prev, s0, cfg):
    """Single-token time-mix via the plain recurrence. x: (B, 1, D).

    Returns (out, x_last, s_new) — same contract as rwkv6_time_mix.
    """
    b, _, d = x.shape
    h, n = d // HEAD_SIZE, HEAD_SIZE
    xs = x_prev[:, None, :]
    xr, xk, xv, xw, xg = _ddlerp(p, x, xs)
    dt = x.dtype
    r = jnp.einsum("bsd,de->bse", xr, p.wr.astype(dt)).reshape(b, h, n)
    k = jnp.einsum("bsd,de->bse", xk, p.wk.astype(dt)).reshape(b, h, n)
    v = jnp.einsum("bsd,de->bse", xv, p.wv.astype(dt)).reshape(b, h, n)
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, p.wg.astype(dt))).reshape(b, h, n)
    w = jnp.exp(_decay_log(p, xw)).reshape(b, h, n)
    u = p.bonus_u.astype(jnp.float32).reshape(h, n)

    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    kv = jnp.einsum("bhn,bhm->bhnm", kf, vf)
    y = jnp.einsum("bhn,bhnm->bhm", rf, s0 + u[None, :, :, None] * kv)
    s_new = s0 * w[..., None] + kv
    y = rmsnorm(y.reshape(b, 1, h, n), jnp.ones((n,)), 64e-5).reshape(b, 1, d)
    y = (y.astype(dt) * p.ln_x.astype(dt)) * g.reshape(b, 1, d)
    out = jnp.einsum("bsd,de->bse", y, p.wo.astype(dt))
    return out, x[:, -1, :], s_new


def init_rwkv6_state(batch: int, d_model: int, dtype=jnp.float32):
    h, n = d_model // HEAD_SIZE, HEAD_SIZE
    return (
        jnp.zeros((batch, d_model), dtype),
        jnp.zeros((batch, d_model), dtype),
        jnp.zeros((batch, h, n, n), jnp.float32),
    )
