"""Mamba2 (SSD) blocks — the zamba2-7b backbone.

Scalar-per-head decay state-space duality form (Dao & Gu 2024).  Per head
(head dim P=64, state N=cfg.ssm_state):

    h_t = a_t h_{t-1} + dt_t * B_t x_t^T          h: (N, P)
    y_t = C_t^T h_t + D * x_t

with a_t = exp(-dt_t * exp(A_log)) scalar per head, dt_t = softplus(dt_raw
+ bias).  Like RWKV6's wkv state, h is a Vmem-analogue: a stationary
accumulator updated by per-token events (DESIGN.md §4).

Training/prefill uses the chunked parallel form (all decay ratios are
scalars — cheaper than RWKV6's per-channel case):

    G_ij   = C_i . B_j                       (C x C inner products)
    D_ij   = exp(la_i - la_j) * dt_j         (j <= i, log-space safe)
    y_intra= (G*D) X,   y_inter = exp(la_i) C_i S0
    S1     = exp(la_C) S0 + sum_j exp(la_C - la_j) dt_j B_j x_j^T

Decode is the plain recurrence.  A depthwise causal conv (kernel 4) over
(x, B, C) precedes the SSM, as in the reference implementation.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..sharding import constrain
from .common import dense_init, rmsnorm

__all__ = [
    "Mamba2Params",
    "init_mamba2_layer",
    "mamba2_forward",
    "mamba2_decode_step",
    "init_mamba2_state",
]

HEAD_P = 64     # head dim
CONV_K = 4      # depthwise conv kernel


class Mamba2Params(NamedTuple):
    w_in: jax.Array       # (D, 2*Di + 2*N + H) -> z, x, B, C, dt
    conv_w: jax.Array     # (K, Di + 2*N) depthwise
    conv_b: jax.Array     # (Di + 2*N,)
    a_log: jax.Array      # (H,)
    dt_bias: jax.Array    # (H,)
    d_skip: jax.Array     # (H,)
    norm_w: jax.Array     # (Di,) gated RMSNorm
    w_out: jax.Array      # (Di, D)


def _dims(cfg):
    di = cfg.d_inner
    n = cfg.ssm_state
    h = di // HEAD_P
    return di, n, h


def init_mamba2_layer(key, cfg) -> Mamba2Params:
    d = cfg.d_model
    di, n, h = _dims(cfg)
    ks = jax.random.split(key, 3)
    return Mamba2Params(
        w_in=dense_init(ks[0], (d, 2 * di + 2 * n + h)),
        conv_w=(jax.random.normal(ks[1], (CONV_K, di + 2 * n)) * 0.2),
        conv_b=jnp.zeros((di + 2 * n,)),
        a_log=jnp.log(jnp.linspace(1.0, 16.0, h)),
        dt_bias=jnp.full((h,), -2.0),
        d_skip=jnp.ones((h,)),
        norm_w=jnp.ones((di,)),
        w_out=dense_init(ks[2], (di, d)),
    )


def _split_in(p: Mamba2Params, proj, cfg):
    di, n, h = _dims(cfg)
    z = proj[..., :di]
    xbc = proj[..., di : di + di + 2 * n]
    dt_raw = proj[..., di + di + 2 * n :]
    return z, xbc, dt_raw


def _causal_conv(xbc, conv_w, conv_b, conv_state=None):
    """Depthwise causal conv along time. xbc: (B, S, C)."""
    k = conv_w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = conv_state.astype(xbc.dtype)  # (B, K-1, C)
    full = jnp.concatenate([pad, xbc], axis=1)
    out = sum(
        full[:, i : i + xbc.shape[1], :] * conv_w[i][None, None, :].astype(xbc.dtype)
        for i in range(k)
    )
    new_state = full[:, -(k - 1) :, :]
    return jax.nn.silu(out + conv_b.astype(xbc.dtype)), new_state


def _ssd_chunked(xh, bb, cc, dt, la, s0, chunk: int):
    """xh: (B,S,H,P); bb/cc: (B,S,N); dt: (B,S,H); la: (B,S,H) log-decay.

    s0: (B,H,N,P). Returns (y, s_final).
    """
    b, s, h, p_ = xh.shape
    n = bb.shape[-1]
    nc = s // chunk

    xc = xh.reshape(b, nc, chunk, h, p_).transpose(1, 0, 3, 2, 4)   # (nc,B,H,C,P)
    bc = bb.reshape(b, nc, chunk, n).transpose(1, 0, 2, 3)          # (nc,B,C,N)
    cc_ = cc.reshape(b, nc, chunk, n).transpose(1, 0, 2, 3)
    dtc = dt.reshape(b, nc, chunk, h).transpose(1, 0, 3, 2)         # (nc,B,H,C)
    lac = la.reshape(b, nc, chunk, h).transpose(1, 0, 3, 2)

    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def body(s_prev, inp):
        xb, bbk, ccb, dtb, lab = inp
        s_prev = constrain(s_prev, "dp", "model", None, None)
        la_incl = jnp.cumsum(lab, axis=-1)                       # (B,H,C)
        g = jnp.einsum("bin,bjn->bij", ccb, bbk)                 # (B,C,C)
        ratio = jnp.exp(
            jnp.where(
                tri[None, None], la_incl[:, :, :, None] - la_incl[:, :, None, :],
                -jnp.inf,
            )
        )                                                        # (B,H,C,C)
        m = g[:, None] * ratio * dtb[:, :, None, :]              # (B,H,C,C)
        y_intra = jnp.einsum("bhij,bhjp->bhip", m, xb)
        y_inter = jnp.einsum(
            "bhc,bcn,bhnp->bhcp", jnp.exp(la_incl), ccb, s_prev
        )
        la_last = la_incl[:, :, -1]                              # (B,H)
        k_scaled = jnp.exp(la_last[:, :, None] - la_incl) * dtb  # (B,H,C)
        s_new = s_prev * jnp.exp(la_last)[..., None, None] + jnp.einsum(
            "bhc,bcn,bhcp->bhnp", k_scaled, bbk, xb
        )
        return (constrain(s_new, "dp", "model", None, None),
                (y_intra + y_inter).transpose(0, 2, 1, 3))  # (B,C,H,P)

    s_final, ys = jax.lax.scan(body, s0, (xc, bc, cc_, dtc, lac))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h, p_)
    return y, s_final


def mamba2_forward(p: Mamba2Params, x, state, cfg, chunk: int = 64):
    """Full-sequence Mamba2 block. x: (B,S,D) (pre-normed by caller).

    state = (conv_state (B,K-1,Di+2N), ssm_state (B,H,N,P)).
    """
    b, s, d = x.shape
    di, n, h = _dims(cfg)
    conv_state, s0 = state
    dt_ = x.dtype

    proj = jnp.einsum("bsd,de->bse", x, p.w_in.astype(dt_))
    z, xbc, dt_raw = _split_in(p, proj, cfg)
    xbc, conv_state_new = _causal_conv(xbc, p.conv_w, p.conv_b, conv_state)
    xh = constrain(xbc[..., :di].reshape(b, s, h, HEAD_P), "dp", None, "model", None)
    bb = xbc[..., di : di + n]
    cc = xbc[..., di + n :]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p.dt_bias)     # (B,S,H)
    la = -dt * jnp.exp(p.a_log)[None, None, :]                       # log a_t < 0

    pad = -s % chunk
    if pad:
        zp = lambda t: jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
        xh, bb, cc, dt, la = map(zp, (xh, bb, cc, dt, la))
    s0 = constrain(s0.astype(jnp.float32), "dp", "model", None, None)
    y, s_f = _ssd_chunked(
        xh.astype(jnp.float32), bb.astype(jnp.float32), cc.astype(jnp.float32),
        dt, la, s0, min(chunk, xh.shape[1]),
    )
    y = y[:, :s]
    y = y + p.d_skip[None, None, :, None] * xh[:, :s].astype(jnp.float32)
    y = y.reshape(b, s, di).astype(dt_)
    y = rmsnorm(y, p.norm_w.astype(jnp.float32), cfg.rmsnorm_eps) * jax.nn.silu(z)
    out = jnp.einsum("bsd,de->bse", y, p.w_out.astype(dt_))
    return out, (conv_state_new, s_f)


def mamba2_decode_step(p: Mamba2Params, x, state, cfg):
    """Single-token recurrence. x: (B, 1, D)."""
    b, _, d = x.shape
    di, n, h = _dims(cfg)
    conv_state, s0 = state
    dt_ = x.dtype

    proj = jnp.einsum("bsd,de->bse", x, p.w_in.astype(dt_))
    z, xbc, dt_raw = _split_in(p, proj, cfg)
    xbc, conv_state_new = _causal_conv(xbc, p.conv_w, p.conv_b, conv_state)
    xh = xbc[:, 0, :di].reshape(b, h, HEAD_P)
    bb = xbc[:, 0, di : di + n]
    cc = xbc[:, 0, di + n :]

    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p.dt_bias)  # (B,H)
    a = jnp.exp(-dt * jnp.exp(p.a_log)[None, :])                        # (B,H)

    xf, bf, cf = (t.astype(jnp.float32) for t in (xh, bb, cc))
    s_new = s0 * a[..., None, None] + jnp.einsum(
        "bh,bn,bhp->bhnp", dt, bf, xf
    )
    y = jnp.einsum("bn,bhnp->bhp", cf, s_new)
    y = y + p.d_skip[None, :, None] * xf
    y = y.reshape(b, 1, di).astype(dt_)
    y = rmsnorm(y, p.norm_w.astype(jnp.float32), cfg.rmsnorm_eps) * jax.nn.silu(z)
    out = jnp.einsum("bsd,de->bse", y, p.w_out.astype(dt_))
    return out, (conv_state_new, s_new)


def init_mamba2_state(batch: int, cfg, dtype=jnp.float32):
    di, n, h = _dims(cfg)
    return (
        jnp.zeros((batch, CONV_K - 1, di + 2 * n), dtype),
        jnp.zeros((batch, h, n, HEAD_P), jnp.float32),
    )
