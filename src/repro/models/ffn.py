"""Dense FFN: SwiGLU (3-matrix) or GELU (2-matrix) variants.

The serving path can swap the einsums for the ``quant_matmul`` Pallas
kernel (SpiDR C2: low-precision weights, wide accumulators) via the
``spidr_quant`` flag in the model builder.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..sharding import constrain
from .common import dense_init

__all__ = ["FFNParams", "init_ffn", "ffn_forward"]


class FFNParams(NamedTuple):
    w_gate: Optional[jax.Array]  # (D, F)  — None for the gelu variant
    w_up: jax.Array              # (D, F)
    w_down: jax.Array            # (F, D)


def init_ffn(key, d_model: int, d_ff: int, variant: str = "swiglu") -> FFNParams:
    k1, k2, k3 = jax.random.split(key, 3)
    return FFNParams(
        w_gate=dense_init(k1, (d_model, d_ff)) if variant == "swiglu" else None,
        w_up=dense_init(k2, (d_model, d_ff)),
        w_down=dense_init(k3, (d_ff, d_model)),
    )


def ffn_forward(p: FFNParams, x: jax.Array) -> jax.Array:
    dt = x.dtype
    up = constrain(jnp.einsum("bsd,df->bsf", x, p.w_up.astype(dt)), "dp", None, "model")
    if p.w_gate is not None:  # SwiGLU
        gate = jnp.einsum("bsd,df->bsf", x, p.w_gate.astype(dt))
        h = jax.nn.silu(gate) * up
    else:  # GELU
        h = jax.nn.gelu(up)
    return jnp.einsum("bsf,fd->bsd", h, p.w_down.astype(dt))
