"""Block composition + scan-over-layers for every assigned family.

Families:
  dense / audio / vlm : pre-norm attention + FFN (SwiGLU or GELU)
  moe                 : pre-norm attention + top-k MoE FFN
  ssm (rwkv6)         : time-mix + channel-mix with carried wkv state
  hybrid (zamba2)     : Mamba2 backbone, one SHARED attention+FFN block
                        applied every ``attn_period`` slots (weight reuse)

All stacks lax.scan over stacked layer params (one compiled block body per
family — keeps HLO size and compile time flat in depth) with
jax.checkpoint around the body in training (activation remat: only layer
boundaries are saved).
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from .. import flags
from ..sharding import constrain
from .attention import (
    AttentionParams,
    attention_forward,
    decode_attention,
    init_attention,
)
from .common import rmsnorm
from .ffn import FFNParams, ffn_forward, init_ffn
from .mamba2 import (
    CONV_K,
    HEAD_P,
    Mamba2Params,
    init_mamba2_layer,
    init_mamba2_state,
    mamba2_decode_step,
    mamba2_forward,
)
from .moe import MoEParams, init_moe, moe_forward
from .rwkv6 import (
    HEAD_SIZE,
    RWKV6Params,
    init_rwkv6_layer,
    rwkv6_channel_mix,
    rwkv6_channel_mix_decode,
    rwkv6_time_mix,
    rwkv6_time_mix_decode,
)

__all__ = ["init_blocks", "forward_blocks", "decode_blocks", "init_decode_state"]


def _boundary(h):
    """Residual-stream layer boundary: sharding (SP optional) + remat name."""
    if flags.flag("sequence_parallel"):
        h = constrain(h, "dp", "model", None)   # sequence-sharded residuals
    else:
        h = constrain(h, "dp", None, None)
    return checkpoint_name(h, "block_out")


def _block_input(h):
    """Gather the sequence dim back before attention/ffn projections."""
    if flags.flag("sequence_parallel"):
        return constrain(h, "dp", None, None)
    return h


def _remat(fn, remat: bool):
    if not remat:
        return fn
    if flags.flag("remat_saveout"):
        policy = jax.checkpoint_policies.save_only_these_names("block_out")
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _zamba2_layout(cfg):
    p = cfg.attn_period or 6
    n_groups = cfg.n_layers // p
    per_group = p - 1
    tail = cfg.n_layers - n_groups * p
    return n_groups, per_group, tail


def init_blocks(key, cfg) -> dict:
    d = cfg.d_model
    fam = cfg.family
    if fam in ("dense", "audio", "vlm"):
        keys = jax.random.split(key, cfg.n_layers)
        layers = _stack(
            [
                {
                    "ln1": jnp.ones((d,)),
                    "attn": init_attention(k, cfg),
                    "ln2": jnp.ones((d,)),
                    "ffn": init_ffn(jax.random.fold_in(k, 1), d, cfg.d_ff, cfg.ffn_variant),
                }
                for k in keys
            ]
        )
        return {"layers": layers}
    if fam == "moe":
        keys = jax.random.split(key, cfg.n_layers)
        layers = _stack(
            [
                {
                    "ln1": jnp.ones((d,)),
                    "attn": init_attention(k, cfg),
                    "ln2": jnp.ones((d,)),
                    "moe": init_moe(jax.random.fold_in(k, 1), d, cfg.d_ff, cfg.n_experts),
                }
                for k in keys
            ]
        )
        return {"layers": layers}
    if fam == "ssm":
        keys = jax.random.split(key, cfg.n_layers)
        layers = _stack(
            [
                {"ln1": jnp.ones((d,)), "ln2": jnp.ones((d,)), "rwkv": init_rwkv6_layer(k, cfg)}
                for k in keys
            ]
        )
        return {"layers": layers}
    if fam == "hybrid":
        n_groups, per_group, tail = _zamba2_layout(cfg)
        kg, kt, ka = jax.random.split(key, 3)
        group_keys = jax.random.split(kg, n_groups * per_group)
        groups = _stack(
            [
                _stack(
                    [
                        {"ln": jnp.ones((d,)), "mamba": init_mamba2_layer(k, cfg)}
                        for k in group_keys[g * per_group : (g + 1) * per_group]
                    ]
                )
                for g in range(n_groups)
            ]
        )
        tail_layers = (
            _stack(
                [
                    {"ln": jnp.ones((d,)), "mamba": init_mamba2_layer(k, cfg)}
                    for k in jax.random.split(kt, tail)
                ]
            )
            if tail
            else None
        )
        shared = {
            "ln1": jnp.ones((d,)),
            "attn": init_attention(ka, cfg),
            "ln2": jnp.ones((d,)),
            "ffn": init_ffn(jax.random.fold_in(ka, 1), d, cfg.d_ff, cfg.ffn_variant),
        }
        return {"groups": groups, "tail": tail_layers, "shared": shared}
    raise ValueError(f"unknown family {fam}")


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------
def _attn_block(layer, h, cfg, return_cache=False):
    a_in = _block_input(rmsnorm(h, layer["ln1"].astype(jnp.float32), cfg.rmsnorm_eps))
    if return_cache:
        attn_out, kv = attention_forward(layer["attn"], a_in, cfg, return_cache=True)
    else:
        attn_out, kv = attention_forward(layer["attn"], a_in, cfg), None
    h = _boundary(h + attn_out)
    f_in = _block_input(rmsnorm(h, layer["ln2"].astype(jnp.float32), cfg.rmsnorm_eps))
    if "moe" in layer:
        out, aux = moe_forward(layer["moe"], f_in, cfg.top_k)
    else:
        out, aux = ffn_forward(layer["ffn"], f_in), {}
    return _boundary(h + out), aux, kv


def _rwkv_block(layer, h, state, cfg):
    x_tm, x_cm, s0 = state
    tm_in = _block_input(rmsnorm(h, layer["ln1"].astype(jnp.float32), cfg.rmsnorm_eps))
    y, x_tm_new, s_f = rwkv6_time_mix(layer["rwkv"], tm_in, x_tm, s0, cfg)
    h = _boundary(h + y)
    cm_in = _block_input(rmsnorm(h, layer["ln2"].astype(jnp.float32), cfg.rmsnorm_eps))
    y2, x_cm_new = rwkv6_channel_mix(layer["rwkv"], cm_in, x_cm)
    return _boundary(h + y2), (x_tm_new, x_cm_new, s_f)


def _mamba_block(layer, h, state, cfg):
    m_in = _block_input(rmsnorm(h, layer["ln"].astype(jnp.float32), cfg.rmsnorm_eps))
    out, state_new = mamba2_forward(layer["mamba"], m_in, state, cfg)
    return _boundary(h + out), state_new


def forward_blocks(
    blocks: dict,
    h: jax.Array,          # (B, S, D)
    cfg,
    remat: bool = False,
    return_cache: bool = False,
):
    """Run all layers. Returns (h, aux, cache_or_None)."""
    fam = cfg.family
    b, s, d = h.shape

    if fam in ("dense", "audio", "vlm", "moe"):

        def body(carry, layer):
            hh, lb, zl = carry
            hh, aux, kv = _attn_block(layer, hh, cfg, return_cache)
            lb = lb + aux.get("load_balance_loss", 0.0)
            zl = zl + aux.get("router_z_loss", 0.0)
            return (hh, lb, zl), kv

        body_fn = _remat(body, remat)
        (h, lb, zl), kvs = jax.lax.scan(body_fn, (h, 0.0, 0.0), blocks["layers"])
        aux = {"load_balance_loss": lb / cfg.n_layers, "router_z_loss": zl / cfg.n_layers}
        cache = None
        if return_cache:
            cache = {"k": kvs[0], "v": kvs[1]}  # (L, B, Hkv, S, hd)
        return h, aux, cache

    if fam == "ssm":
        hsz, n = d // HEAD_SIZE, HEAD_SIZE
        state0 = (
            jnp.zeros((cfg.n_layers, b, d), h.dtype),
            jnp.zeros((cfg.n_layers, b, d), h.dtype),
            jnp.zeros((cfg.n_layers, b, hsz, n, n), jnp.float32),
        )

        def body(hh, inp):
            layer, st = inp
            hh, st_new = _rwkv_block(layer, hh, st, cfg)
            return hh, st_new

        body_fn = _remat(body, remat)
        h, states = jax.lax.scan(body_fn, h, (blocks["layers"], state0))
        cache = None
        if return_cache:
            cache = {"x_tm": states[0], "x_cm": states[1], "s": states[2]}
        return h, {}, cache

    if fam == "hybrid":
        n_groups, per_group, tail = _zamba2_layout(cfg)
        di, nst, nh = cfg.d_inner, cfg.ssm_state, cfg.d_inner // HEAD_P
        conv_ch = di + 2 * nst

        def mamba_scan(hh, layers, n_l):
            st0 = (
                jnp.zeros((n_l, b, CONV_K - 1, conv_ch), hh.dtype),
                jnp.zeros((n_l, b, nh, nst, HEAD_P), jnp.float32),
            )

            def body(carry, inp):
                layer, st = inp
                out, st_new = _mamba_block(layer, carry, st, cfg)
                return out, st_new

            body_fn = _remat(body, remat)
            hh, states = jax.lax.scan(body_fn, hh, (layers, st0))
            return hh, states

        def group_body(hh, group_layers):
            hh, m_states = mamba_scan(hh, group_layers, per_group)
            hh, _, kv = _attn_block(blocks["shared"], hh, cfg, return_cache)
            return hh, (m_states, kv)

        group_fn = _remat(group_body, remat)
        h, (g_states, g_kvs) = jax.lax.scan(group_fn, h, blocks["groups"])
        tail_states = None
        if blocks["tail"] is not None:
            h, tail_states = mamba_scan(h, blocks["tail"], tail)
        cache = None
        if return_cache:
            cache = {
                "group_conv": g_states[0], "group_ssm": g_states[1],
                "tail_conv": tail_states[0] if tail_states else None,
                "tail_ssm": tail_states[1] if tail_states else None,
                "k": g_kvs[0] if g_kvs else None,  # (G, B, Hkv, S, hd)
                "v": g_kvs[1] if g_kvs else None,
            }
        return h, {}, cache

    raise ValueError(fam)


# ---------------------------------------------------------------------------
# Decode (one token against carried state)
# ---------------------------------------------------------------------------
def init_decode_state(cfg, batch: int, seq_len: int, dtype=jnp.bfloat16) -> dict:
    """Zero-initialized decode cache pytree for a given context capacity."""
    d, hd, hkv = cfg.d_model, cfg.head_dim_, cfg.n_kv_heads
    fam = cfg.family
    if fam in ("dense", "audio", "vlm", "moe"):
        return {
            "k": jnp.zeros((cfg.n_layers, batch, hkv, seq_len, hd), dtype),
            "v": jnp.zeros((cfg.n_layers, batch, hkv, seq_len, hd), dtype),
            "len": jnp.zeros((), jnp.int32),
        }
    if fam == "ssm":
        h, n = d // HEAD_SIZE, HEAD_SIZE
        return {
            "x_tm": jnp.zeros((cfg.n_layers, batch, d), dtype),
            "x_cm": jnp.zeros((cfg.n_layers, batch, d), dtype),
            "s": jnp.zeros((cfg.n_layers, batch, h, n, n), jnp.float32),
            "len": jnp.zeros((), jnp.int32),
        }
    if fam == "hybrid":
        n_groups, per_group, tail = _zamba2_layout(cfg)
        di, nst, nh = cfg.d_inner, cfg.ssm_state, cfg.d_inner // HEAD_P
        conv_ch = di + 2 * nst
        out = {
            "group_conv": jnp.zeros((n_groups, per_group, batch, CONV_K - 1, conv_ch), dtype),
            "group_ssm": jnp.zeros((n_groups, per_group, batch, nh, nst, HEAD_P), jnp.float32),
            "k": jnp.zeros((n_groups, batch, hkv, seq_len, hd), dtype),
            "v": jnp.zeros((n_groups, batch, hkv, seq_len, hd), dtype),
            "len": jnp.zeros((), jnp.int32),
        }
        if tail:
            out["tail_conv"] = jnp.zeros((tail, batch, CONV_K - 1, conv_ch), dtype)
            out["tail_ssm"] = jnp.zeros((tail, batch, nh, nst, HEAD_P), jnp.float32)
        return out
    raise ValueError(fam)


def _attn_block_decode(layer, h, k_cache, v_cache, cache_len, cfg):
    a_in = rmsnorm(h, layer["ln1"].astype(jnp.float32), cfg.rmsnorm_eps)
    attn_out, k_new, v_new = decode_attention(
        layer["attn"], a_in, k_cache, v_cache, cache_len, cfg
    )
    h = h + attn_out
    f_in = rmsnorm(h, layer["ln2"].astype(jnp.float32), cfg.rmsnorm_eps)
    if "moe" in layer:
        out, _ = moe_forward(layer["moe"], f_in, cfg.top_k)
    else:
        out = ffn_forward(layer["ffn"], f_in)
    return h + out, k_new, v_new


def decode_blocks(blocks: dict, h: jax.Array, cache: dict, cfg):
    """One-token step. h: (B, 1, D). Returns (h, new_cache)."""
    fam = cfg.family
    cache_len = cache["len"]

    if fam in ("dense", "audio", "vlm", "moe"):

        def body(hh, inp):
            layer, k_c, v_c = inp
            hh, k_n, v_n = _attn_block_decode(layer, hh, k_c, v_c, cache_len, cfg)
            return hh, (k_n, v_n)

        h, (k_all, v_all) = jax.lax.scan(body, h, (blocks["layers"], cache["k"], cache["v"]))
        return h, {"k": k_all, "v": v_all, "len": cache_len + 1}

    if fam == "ssm":

        def body(hh, inp):
            layer, x_tm, x_cm, s0 = inp
            tm_in = rmsnorm(hh, layer["ln1"].astype(jnp.float32), cfg.rmsnorm_eps)
            y, x_tm_n, s_n = rwkv6_time_mix_decode(
                layer["rwkv"], tm_in, x_tm.astype(tm_in.dtype), s0, cfg
            )
            hh = hh + y.astype(hh.dtype)
            cm_in = rmsnorm(hh, layer["ln2"].astype(jnp.float32), cfg.rmsnorm_eps)
            y2, x_cm_n = rwkv6_channel_mix_decode(
                layer["rwkv"], cm_in, x_cm.astype(cm_in.dtype)
            )
            return hh + y2.astype(hh.dtype), (
                x_tm_n.astype(x_tm.dtype), x_cm_n.astype(x_cm.dtype), s_n
            )

        h, (x_tm, x_cm, s) = jax.lax.scan(
            body, h, (blocks["layers"], cache["x_tm"], cache["x_cm"], cache["s"])
        )
        return h, {"x_tm": x_tm, "x_cm": x_cm, "s": s, "len": cache_len + 1}

    if fam == "hybrid":
        n_groups, per_group, tail = _zamba2_layout(cfg)

        def mamba_body(hh, inp):
            layer, conv_st, ssm_st = inp
            m_in = rmsnorm(hh, layer["ln"].astype(jnp.float32), cfg.rmsnorm_eps)
            out, (conv_n, ssm_n) = mamba2_decode_step(
                layer["mamba"], m_in, (conv_st, ssm_st), cfg
            )
            return hh + out.astype(hh.dtype), (conv_n.astype(conv_st.dtype), ssm_n)

        def group_body(hh, inp):
            layers, conv_st, ssm_st, k_c, v_c = inp
            hh, (conv_n, ssm_n) = jax.lax.scan(mamba_body, hh, (layers, conv_st, ssm_st))
            hh, k_n, v_n = _attn_block_decode(blocks["shared"], hh, k_c, v_c, cache_len, cfg)
            return hh, (conv_n, ssm_n, k_n, v_n)

        h, (g_conv, g_ssm, k_all, v_all) = jax.lax.scan(
            group_body, h,
            (blocks["groups"], cache["group_conv"], cache["group_ssm"], cache["k"], cache["v"]),
        )
        new_cache = {
            "group_conv": g_conv, "group_ssm": g_ssm,
            "k": k_all, "v": v_all, "len": cache_len + 1,
        }
        if blocks["tail"] is not None:
            h, (t_conv, t_ssm) = jax.lax.scan(
                mamba_body, h, (blocks["tail"], cache["tail_conv"], cache["tail_ssm"])
            )
            new_cache["tail_conv"] = t_conv
            new_cache["tail_ssm"] = t_ssm
        return h, new_cache

    raise ValueError(fam)
