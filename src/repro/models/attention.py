"""GQA attention: flash-style chunked prefill/train + cached decode.

Memory-efficient attention is mandatory here: prefill_32k materializing
(S x S) scores would need terabytes.  We scan over KV chunks with an
online-softmax carry (running max / denominator / weighted accumulator),
vectorized over query positions — the standard flash decomposition
expressed in lax.scan so it lowers to one fused while-loop per layer.

GQA is computed WITHOUT materializing repeated KV heads: queries are
reshaped to (B, H_kv, group, S, D) and contracted against (B, H_kv, S, D).

qk_norm (qwen3): per-head RMSNorm on q and k before RoPE.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..sharding import constrain
from .common import apply_rope, rmsnorm

__all__ = ["AttentionParams", "init_attention", "attention_forward", "decode_attention"]

NEG_INF = -1e30


class AttentionParams(NamedTuple):
    wq: jax.Array           # (D, Hq*hd)
    wk: jax.Array           # (D, Hkv*hd)
    wv: jax.Array           # (D, Hkv*hd)
    wo: jax.Array           # (Hq*hd, D)
    bq: Optional[jax.Array]
    bk: Optional[jax.Array]
    bv: Optional[jax.Array]
    q_norm: Optional[jax.Array]  # (hd,) qk_norm scales
    k_norm: Optional[jax.Array]


def init_attention(key, cfg) -> AttentionParams:
    from .common import dense_init

    d, hd = cfg.d_model, cfg.head_dim_
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    return AttentionParams(
        wq=dense_init(ks[0], (d, hq * hd)),
        wk=dense_init(ks[1], (d, hkv * hd)),
        wv=dense_init(ks[2], (d, hkv * hd)),
        wo=dense_init(ks[3], (hq * hd, d)),
        bq=jnp.zeros((hq * hd,)) if cfg.qkv_bias else None,
        bk=jnp.zeros((hkv * hd,)) if cfg.qkv_bias else None,
        bv=jnp.zeros((hkv * hd,)) if cfg.qkv_bias else None,
        q_norm=jnp.ones((hd,)) if cfg.qk_norm else None,
        k_norm=jnp.ones((hd,)) if cfg.qk_norm else None,
    )


def _project_qkv(p: AttentionParams, x: jax.Array, cfg, positions: jax.Array):
    b, s, _ = x.shape
    hd, hq, hkv = cfg.head_dim_, cfg.n_heads, cfg.n_kv_heads
    q = jnp.einsum("bsd,dh->bsh", x, p.wq.astype(x.dtype))
    k = jnp.einsum("bsd,dh->bsh", x, p.wk.astype(x.dtype))
    v = jnp.einsum("bsd,dh->bsh", x, p.wv.astype(x.dtype))
    if p.bq is not None:
        q, k, v = q + p.bq.astype(x.dtype), k + p.bk.astype(x.dtype), v + p.bv.astype(x.dtype)
    q = constrain(q.reshape(b, s, hq, hd), "dp", None, "model", None)
    k = constrain(k.reshape(b, s, hkv, hd), "dp", None, "model", None)
    v = constrain(v.reshape(b, s, hkv, hd), "dp", None, "model", None)
    if p.q_norm is not None:
        q = rmsnorm(q, p.q_norm.astype(jnp.float32), cfg.rmsnorm_eps)
        k = rmsnorm(k, p.k_norm.astype(jnp.float32), cfg.rmsnorm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _flash_inner(q, k, v, q_pos0, kv_chunk: int, causal: bool):
    """Online-softmax over KV chunks.

    q: (B, Hkv, G, Sq, D) fp32-scaled; k/v: (B, Hkv, Skv, D).
    Returns (B, Hkv, G, Sq, D).
    """
    b, hkv, g, sq, d = q.shape
    skv = k.shape[2]
    n_chunks = skv // kv_chunk

    k_c = k.reshape(b, hkv, n_chunks, kv_chunk, d).transpose(2, 0, 1, 3, 4)
    v_c = v.reshape(b, hkv, n_chunks, kv_chunk, d).transpose(2, 0, 1, 3, 4)

    q_idx = q_pos0 + jnp.arange(sq)

    def body(carry, inp):
        m, l, acc = carry
        idx, k_blk, v_blk = inp
        s = jnp.einsum(
            "bhgqd,bhkd->bhgqk", q, k_blk.astype(q.dtype),
            preferred_element_type=jnp.float32,
        )
        if causal:
            kv_idx = idx * kv_chunk + jnp.arange(kv_chunk)
            mask = q_idx[:, None] >= kv_idx[None, :]
            s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p.astype(v_blk.dtype), v_blk,
            preferred_element_type=jnp.float32,
        )
        return (m_new, l, acc), None

    m0 = jnp.full((b, hkv, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    acc0 = jnp.zeros((b, hkv, g, sq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0), (jnp.arange(n_chunks), k_c, v_c)
    )
    return acc / jnp.maximum(l, 1e-30)[..., None]


def attention_forward(
    p: AttentionParams,
    x: jax.Array,              # (B, S, D)
    cfg,
    positions: Optional[jax.Array] = None,
    kv_chunk: int = 1024,
    return_cache: bool = False,
):
    """Causal self-attention over a full sequence (train / prefill)."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q, k, v = _project_qkv(p, x, cfg, positions)
    hd, hq, hkv = cfg.head_dim_, cfg.n_heads, cfg.n_kv_heads
    g = hq // hkv

    scale = hd**-0.5
    qg = (q * scale).astype(jnp.float32)
    qg = qg.reshape(b, s, hkv, g, hd).transpose(0, 2, 3, 1, 4)  # (B,Hkv,G,S,D)
    kk = k.transpose(0, 2, 1, 3)  # (B,Hkv,S,D)
    vv = v.transpose(0, 2, 1, 3)

    chunk = min(kv_chunk, s)
    while s % chunk:
        chunk //= 2
    out = _flash_inner(qg, kk, vv, 0, chunk, causal=True)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, s, hq * hd).astype(x.dtype)
    out = jnp.einsum("bsh,hd->bsd", out, p.wo.astype(x.dtype))
    if return_cache:
        return out, (kk, vv)  # cache layout (B, Hkv, S, D)
    return out


def decode_attention(
    p: AttentionParams,
    x: jax.Array,                # (B, 1, D)
    cache_k: jax.Array,          # (B, Hkv, S_cache, D)
    cache_v: jax.Array,
    cache_len: jax.Array,        # scalar int32: valid prefix length
    cfg,
):
    """One-token decode against a KV cache; returns (out, new_k, new_v)."""
    b = x.shape[0]
    hd, hq, hkv = cfg.head_dim_, cfg.n_heads, cfg.n_kv_heads
    g = hq // hkv
    positions = jnp.broadcast_to(cache_len, (b, 1))
    q, k_new, v_new = _project_qkv(p, x, cfg, positions)

    # Insert the new token's K/V at cache_len (static-shape dynamic update).
    k_new = k_new.transpose(0, 2, 1, 3)  # (B,Hkv,1,D)
    v_new = v_new.transpose(0, 2, 1, 3)
    cache_k = jax.lax.dynamic_update_slice(
        cache_k, k_new.astype(cache_k.dtype), (0, 0, cache_len, 0)
    )
    cache_v = jax.lax.dynamic_update_slice(
        cache_v, v_new.astype(cache_v.dtype), (0, 0, cache_len, 0)
    )

    scale = hd**-0.5
    qg = (q * scale).astype(jnp.float32).reshape(b, 1, hkv, g, hd)
    qg = qg.transpose(0, 2, 3, 1, 4)  # (B,Hkv,G,1,D)
    s = jnp.einsum(
        "bhgqd,bhkd->bhgqk", qg, cache_k.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    s_cache = cache_k.shape[2]
    valid = jnp.arange(s_cache)[None, None, None, None, :] <= cache_len
    s = jnp.where(valid, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bhgqk,bhkd->bhgqd", w, cache_v.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, 1, hq * hd).astype(x.dtype)
    out = jnp.einsum("bsh,hd->bsd", out, p.wo.astype(x.dtype))
    return out, cache_k, cache_v
