"""Production mesh factory.

A FUNCTION, not a module constant — importing this module never touches
jax device state (required so smoke tests see 1 device while the dry-run
sees 512 placeholder host devices).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_host_mesh():
    """Whatever devices this host actually has — used by runnable examples."""
    n = len(jax.devices())
    return jax.make_mesh(
        (n, 1), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )
