"""Production mesh factory.

A FUNCTION, not a module constant — importing this module never touches
jax device state (required so smoke tests see 1 device while the dry-run
sees 512 placeholder host devices).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh", "make_mesh_compat"]


def make_mesh_compat(shape, axes):
    """jax.make_mesh across jax versions: ``axis_types`` (and the
    ``jax.sharding.AxisType`` enum) only exist on newer releases."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_host_mesh():
    """Whatever devices this host actually has — used by runnable examples."""
    n = len(jax.devices())
    return make_mesh_compat((n, 1), ("data", "model"))
