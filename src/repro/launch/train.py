"""End-to-end training driver.

Runs REAL training on whatever devices exist (CPU here, TPU pod in prod):
  python -m repro.launch.train --arch qwen1.5-0.5b --reduced --steps 50
  python -m repro.launch.train --snn gesture --weight-bits 4 --steps 200
  python -m repro.launch.train --snn optical-flow --weight-bits 8 --reduced

LM archs train on the synthetic token pipeline; the paper's SNNs train on
synthetic DVS streams.  ``--snn`` runs the full train->deploy QAT pipeline:
deploy-exact surrogate-gradient training (``snn.train.fit``), export into
the engine's signed-integer format, checkpoint of both the float params and
the integer artifact, and a round-trip proof that the deployed engine
reproduces the training graph's spike trains bit-exactly (on 1 core and,
when ``--n-cores`` > 1, on the compiled multi-core plan).  Fault tolerance:
checkpoint every N steps, watchdog, straggler stats; resume is automatic
from the checkpoint directory.
"""
from __future__ import annotations

import argparse
import logging
import time

import jax

from repro.checkpoint.checkpoint import Checkpointer
from repro.configs.base import get_config
from repro.data.pipeline import TokenPipeline
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.runtime.loop import LoopConfig, TrainingLoop

logging.basicConfig(level=logging.INFO, format="%(asctime)s %(name)s %(message)s")
log = logging.getLogger("repro.train")


def train_lm(args):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_host_mesh()
    log.info("arch=%s params=%.2fM mesh=%s", cfg.name, cfg.param_count() / 1e6,
             dict(mesh.shape))

    key = jax.random.PRNGKey(args.seed)
    params = M.init_params(key, cfg)
    opt_state = M.init_opt_state(params)

    train_step = M.make_train_step(cfg, lr=args.lr)
    with mesh:
        jitted = jax.jit(train_step, donate_argnums=(0, 1))

        pipe = TokenPipeline(
            batch=args.batch, seq_len=args.seq, vocab=cfg.vocab_size,
            seed=args.seed, embeds_dim=0 if cfg.embed_inputs else cfg.d_model,
        )
        ckpt = Checkpointer(args.ckpt_dir)
        loop = TrainingLoop(
            step_fn=lambda p, o, s, b: jitted(p, o, s, b),
            batch_fn=pipe.batch_at,
            checkpointer=ckpt,
            cfg=LoopConfig(
                total_steps=args.steps,
                checkpoint_every=args.ckpt_every,
                watchdog_deadline_s=args.watchdog_s,
            ),
        )
        t0 = time.time()
        params, opt_state, history = loop.run(params, opt_state)
        dt = time.time() - t0
    log.info(
        "done: %d steps in %.1fs; loss %.4f -> %.4f; stragglers=%d restarts=%d",
        args.steps, dt, history[0], history[-1],
        loop.stragglers.flagged, loop.restarts,
    )
    return history


def train_snn(args):
    """The train->deploy QAT pipeline for the paper's SNNs.

    fit (deploy-exact QAT) -> export integers -> checkpoint both artifacts
    -> reload -> deploy through the compiler -> prove bit-exact parity.
    """
    import os

    from repro import spidr
    from repro.snn.export import export_network
    from repro.snn.train import (
        TrainConfig, effective_spec, fit, make_batch_fn, spec_for,
    )

    task = args.snn or args.arch.removeprefix("spidr-")
    spec = spec_for(task)
    hw = (32, 32) if args.reduced and spec.readout == "rate" else None
    hw = (24, 32) if args.reduced and spec.readout == "vmem" else hw
    tcfg = TrainConfig(
        weight_bits=args.weight_bits, lr=args.lr, steps=args.steps,
        batch=args.batch, seed=args.seed,
        hw=hw, timesteps=5 if args.reduced else None,
        ckpt_every=args.ckpt_every,
    )
    ckpt = Checkpointer(args.ckpt_dir)
    state, history = fit(spec, tcfg, ckpt=ckpt)

    # Fold into the integer engine format and persist both artifacts: the
    # facade's save/load ride on the snn.export checkpoint format.
    from repro.core.quant import QuantSpec

    run_spec = effective_spec(spec, tcfg)
    exported = export_network(state.params, run_spec, QuantSpec(args.weight_bits))
    export_dir = os.path.join(args.ckpt_dir, "exported")
    spidr.compile(
        exported, run_spec,
        spidr.DeployTarget(weight_bits=args.weight_bits),
    ).save(export_dir, step=args.steps)

    # Round-trip proof on a fresh stream, single- and multi-core, through
    # the reloaded artifact (what production would actually deploy).
    ev, _ = make_batch_fn(run_spec, tcfg, batch=2)(jax.random.PRNGKey(99))
    for n_cores in sorted({1, args.n_cores}):
        target = spidr.DeployTarget(weight_bits=args.weight_bits,
                                    n_cores=n_cores)
        compiled = spidr.load(export_dir, spec=run_spec, target=target)
        report = compiled.verify(ev, params=state.params)
        rt = report.roundtrip
        log.info("round-trip %d-core: exact=%s (readout_mismatch=%g, "
                 "spike_mismatch=%d)", n_cores, report.exact,
                 rt.readout_mismatch, rt.spike_mismatch)
        if not report.exact:
            raise SystemExit(
                f"train->deploy parity broken on {n_cores} core(s): {report}")
    log.info("done: loss %.4f -> %.4f, %s=%.4f; exported %d-bit integers "
             "to %s", history["loss"][0], history["loss"][-1],
             history["metric"], history["final"], args.weight_bits,
             export_dir)
    return history["loss"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="LM arch name, or spidr-gesture / spidr-optical-flow")
    ap.add_argument("--snn", choices=("gesture", "optical-flow"), default=None,
                    help="train one of the paper's SNNs through the "
                         "train->export->deploy QAT pipeline")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--weight-bits", type=int, default=4, choices=(4, 6, 8))
    ap.add_argument("--n-cores", type=int, default=1,
                    help="also prove parity on a compiled n-core plan")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--watchdog-s", type=float, default=3600.0)
    args = ap.parse_args()
    if args.snn is None and args.arch is None:
        ap.error("pass --snn gesture|optical-flow or --arch <name>")
    if args.snn or args.arch.startswith("spidr-"):
        train_snn(args)
    else:
        train_lm(args)


if __name__ == "__main__":
    main()
