"""End-to-end training driver.

Runs REAL training on whatever devices exist (CPU here, TPU pod in prod):
  python -m repro.launch.train --arch qwen1.5-0.5b --reduced --steps 50
  python -m repro.launch.train --arch spidr-gesture --steps 200

LM archs train on the synthetic token pipeline; the paper's SNNs train on
synthetic DVS streams.  Fault tolerance: checkpoint every N steps, watchdog,
straggler stats; resume is automatic from the checkpoint directory.
"""
from __future__ import annotations

import argparse
import logging
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpoint import Checkpointer
from repro.configs.base import get_config
from repro.data.pipeline import TokenPipeline
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.runtime.loop import LoopConfig, TrainingLoop
from repro import sharding as S

logging.basicConfig(level=logging.INFO, format="%(asctime)s %(name)s %(message)s")
log = logging.getLogger("repro.train")


def train_lm(args):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_host_mesh()
    log.info("arch=%s params=%.2fM mesh=%s", cfg.name, cfg.param_count() / 1e6,
             dict(mesh.shape))

    key = jax.random.PRNGKey(args.seed)
    params = M.init_params(key, cfg)
    opt_state = M.init_opt_state(params)

    train_step = M.make_train_step(cfg, lr=args.lr)
    p_specs = S.param_specs(params)
    with mesh:
        in_shardings = (
            jax.tree.map(lambda s: jax.sharding.NamedSharding(mesh, s), p_specs,
                         is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)),
        )
        jitted = jax.jit(train_step, donate_argnums=(0, 1))

        pipe = TokenPipeline(
            batch=args.batch, seq_len=args.seq, vocab=cfg.vocab_size,
            seed=args.seed, embeds_dim=0 if cfg.embed_inputs else cfg.d_model,
        )
        ckpt = Checkpointer(args.ckpt_dir)
        loop = TrainingLoop(
            step_fn=lambda p, o, s, b: jitted(p, o, s, b),
            batch_fn=pipe.batch_at,
            checkpointer=ckpt,
            cfg=LoopConfig(
                total_steps=args.steps,
                checkpoint_every=args.ckpt_every,
                watchdog_deadline_s=args.watchdog_s,
            ),
        )
        t0 = time.time()
        params, opt_state, history = loop.run(params, opt_state)
        dt = time.time() - t0
    log.info(
        "done: %d steps in %.1fs; loss %.4f -> %.4f; stragglers=%d restarts=%d",
        args.steps, dt, history[0], history[-1],
        loop.stragglers.flagged, loop.restarts,
    )
    return history


def train_snn(args):
    from repro.core.network import gesture_net, optical_flow_net
    from repro.snn.data import make_gesture_batch, make_flow_batch
    from repro.snn.train import TrainConfig, init_train_state, train_step

    spec = gesture_net() if "gesture" in args.arch else optical_flow_net()
    tcfg = TrainConfig(weight_bits=args.weight_bits, lr=args.lr)
    state = init_train_state(jax.random.PRNGKey(args.seed), spec, tcfg)
    key = jax.random.PRNGKey(args.seed + 1)
    hw = (32, 32) if args.reduced else spec.input_hw
    ts = 5 if args.reduced else spec.timesteps
    ckpt = Checkpointer(args.ckpt_dir)
    history = []
    for step in range(args.steps):
        key, k = jax.random.split(key)
        if spec.readout == "rate":
            ev, target = make_gesture_batch(k, batch=args.batch, timesteps=ts, hw=hw)
        else:
            ev, target = make_flow_batch(k, batch=args.batch, timesteps=ts, hw=hw)
        state, metrics = train_step(state, (ev, target), spec, tcfg)
        history.append(float(metrics["loss"]))
        if step % 10 == 0:
            extras = {k_: round(float(v), 4) for k_, v in metrics.items()}
            log.info("step %d %s", step, extras)
        if (step + 1) % args.ckpt_every == 0:
            ckpt.save_async(step + 1, state.params)
    ckpt.wait()
    log.info("done: loss %.4f -> %.4f", history[0], history[-1])
    return history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--weight-bits", type=int, default=4, choices=(4, 6, 8))
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--watchdog-s", type=float, default=3600.0)
    args = ap.parse_args()
    if args.arch.startswith("spidr-"):
        train_snn(args)
    else:
        train_lm(args)


if __name__ == "__main__":
    main()
