"""Batched serving driver: LM continuous batching + SNN event-stream serving.

Runs a real serving loop on host devices (reduced configs on CPU):
  python -m repro.launch.serve --arch qwen1.5-0.5b --reduced --requests 16
  python -m repro.launch.serve --snn gesture --requests 8
  python -m repro.launch.serve --snn optical-flow --requests 4 --jnp
  python -m repro.launch.serve --snn gesture --streaming --chunk-T 2
  python -m repro.launch.serve --snn gesture --n-cores 4 --jnp

The SNN path deploys through the unified facade (``repro.spidr``): one
``DeployTarget`` declares precision/cores/backend/chunking, and the
resulting ``CompiledSNN`` serves whole DVS event streams — requests are
batched up to a fixed capacity (shapes never change -> no recompilation),
each batch runs one fused scan-over-time inference, and the reply carries
the rate/Vmem readout plus the chip-cost estimate (cycles/energy) from the
calibrated models.

With ``--streaming`` the SNN path switches to *stateful* serving: each
request's events are delivered in chunks of ``--chunk-T`` timesteps, live
streams keep persistent per-slot Vmem between chunks
(``CompiledSNN.open_stream()``), newly arrived streams are admitted into
retired slots mid-flight (continuous batching over neuron state), and every
reply carries the incremental readout plus cumulative cycles/energy for
that stream alone.  Results are bit-identical to whole-stream serving.

The SNN serving loop itself lives in ``repro.serving`` behind the
``spidr.serve`` facade — this module is now a thin CLI over it
(``--replicas N`` spreads streams across a fleet of N engine replicas).
The old in-module server classes remain as deprecated shims below.

Design (scaled-down vLLM-style):
  * a request queue feeds a PREFILL worker (one request at a time — CPU
    demo; on a pod this is a separate prefill mesh),
  * decoded requests join the DECODE batch, stepped together; finished
    sequences retire and free their cache slot for the next waiter
    (continuous batching with slot reuse),
  * the decode step is one jit'd function over a fixed-capacity batch —
    shapes never change, so no recompilation during serving.
"""
from __future__ import annotations

import argparse
import dataclasses
import logging
import time
import warnings
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.base import get_config
from repro.models import model as M
from repro.models.transformer import init_decode_state
from repro.serving import BatchWorker, StreamRequest, StreamWorker

# Structured logging (repro.obs.logs): ``main()`` calls
# ``obs.logging_setup(json_mode=args.log_json)`` — every record carries the
# current stream's request id (``rid=...`` in text mode, ``"request_id"``
# in --log-json mode) via a contextvar, replacing the old module-level
# ``logging.basicConfig``.
log = logging.getLogger("repro.serve")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    generated: list = dataclasses.field(default_factory=list)
    submitted_at: float = 0.0
    first_token_at: Optional[float] = None
    done_at: Optional[float] = None


class Server:
    """Fixed-capacity continuous-batching server."""

    def __init__(self, cfg, params, capacity: int = 8, ctx_len: int = 256):
        self.cfg, self.params = cfg, params
        self.capacity, self.ctx_len = capacity, ctx_len
        self.decode_step = jax.jit(M.make_decode_step(cfg), donate_argnums=(1,))
        self.prefill = jax.jit(M.make_prefill_step(cfg))
        # Batched cache: slot i belongs to active request i (or empty).
        self.cache = init_decode_state(cfg, capacity, ctx_len)
        self.slots: list = [None] * capacity
        self.slot_len = np.zeros(capacity, np.int32)
        self.next_tok = np.zeros((capacity, 1), np.int32)
        self.waiting: list = []
        self.done: list = []

    def submit(self, req: Request):
        req.submitted_at = time.monotonic()
        self.waiting.append(req)

    def _admit(self):
        for i in range(self.capacity):
            if self.slots[i] is None and self.waiting:
                req = self.waiting.pop(0)
                # Prefill one request; copy its KV into slot i.
                logits, cache1 = self.prefill(
                    self.params, {"tokens": jnp.asarray(req.prompt[None, :])}
                )
                tok = int(jnp.argmax(logits[0]))
                req.generated.append(tok)
                req.first_token_at = time.monotonic()
                self._copy_into_slot(i, cache1, len(req.prompt))
                self.slots[i] = req
                self.slot_len[i] = len(req.prompt)
                self.next_tok[i, 0] = tok

    def _copy_into_slot(self, i, cache1, plen):
        def put(dst, src):
            if dst is None or not hasattr(dst, "ndim"):
                return dst
            if dst.ndim >= 2 and src is not None:
                # layer-stacked: (L, B=cap, ...) <- (L, 1, ...)
                pad = [(0, 0)] * src.ndim
                if dst.ndim == src.ndim and dst.shape[1] == self.capacity:
                    sl = [slice(None)] * dst.ndim
                    sl[1] = slice(i, i + 1)
                    upd = src
                    if src.shape[3:4] and dst.shape[3] != src.shape[3] and dst.ndim > 3:
                        # seq capacity differs: right-pad/truncate
                        tgt = dst.shape[3]
                        if src.shape[3] < tgt:
                            pad[3] = (0, tgt - src.shape[3])
                            upd = jnp.pad(src, pad)
                        else:
                            upd = src[:, :, :, :tgt]
                    return dst.at[tuple(sl)].set(upd.astype(dst.dtype))
                return dst
            return dst

        # dense/moe KV caches: prefill returns k/v as (L, B, Hkv, S, hd)
        for key in self.cache:
            if key == "len":
                continue
            src = cache1.get(key) if isinstance(cache1, dict) else None
            if src is None:
                continue
            if key in ("k", "v"):
                # cache1 seq dim = prompt len; place at [.., :plen, :]
                dst = self.cache[key]
                upd = src.astype(dst.dtype)
                self.cache[key] = jax.lax.dynamic_update_slice(
                    dst, upd, (0, i, 0, 0, 0)[: dst.ndim]
                )
            else:
                self.cache[key] = put(self.cache[key], src)

    def step(self):
        self._admit()
        active = [i for i in range(self.capacity) if self.slots[i] is not None]
        if not active:
            return False
        # One batched decode step for every active slot (idle slots ride along).
        self.cache["len"] = jnp.asarray(int(self.slot_len.max()), jnp.int32)
        logits, self.cache = self.decode_step(
            self.params, self.cache, {"tokens": jnp.asarray(self.next_tok)}
        )
        toks = np.asarray(jnp.argmax(logits, axis=-1))
        for i in active:
            req = self.slots[i]
            tok = int(toks[i])
            req.generated.append(tok)
            self.slot_len[i] += 1
            self.next_tok[i, 0] = tok
            if len(req.generated) >= req.max_new or self.slot_len[i] >= self.ctx_len - 1:
                req.done_at = time.monotonic()
                self.done.append(req)
                self.slots[i] = None  # free slot: continuous batching
                self.slot_len[i] = 0
        return True


# ---------------------------------------------------------------------------
# SNN event-stream serving (fused multi-timestep engine).
# ---------------------------------------------------------------------------
#: Deprecated alias -- the request object moved to ``repro.serving``.
SNNRequest = StreamRequest


def _warn_deprecated(old: str) -> None:
    warnings.warn(
        f"repro.launch.serve.{old} is deprecated; serve through "
        "spidr.serve(compiled, spidr.ServeConfig(...)) instead "
        "(see docs/serving.md)",
        DeprecationWarning, stacklevel=3)


class SNNServer(BatchWorker):
    """Deprecated shim: use ``spidr.serve(compiled, batch=True)``.

    The whole-stream batching loop now lives in
    :class:`repro.serving.BatchWorker`; this subclass only adds the
    ``DeprecationWarning``.
    """

    def __init__(self, compiled, capacity: int = 4):
        _warn_deprecated("SNNServer")
        super().__init__(compiled, capacity)


class StreamingSNNServer(StreamWorker):
    """Deprecated shim: use ``spidr.serve(compiled, spidr.ServeConfig(...))``.

    The stateful continuous-batching loop (persistent-Vmem slots,
    watchdog/rewind durability, snapshot/restore) now lives in
    :class:`repro.serving.StreamWorker`; this subclass only adds the
    ``DeprecationWarning``.  ``restore`` is inherited and returns this
    class, so drilled snapshots keep resuming through the old name.
    """

    def __init__(self, *args, **kwargs):
        _warn_deprecated("StreamingSNNServer")
        super().__init__(*args, **kwargs)


def serve_snn(args):
    from repro import spidr
    from repro.configs import spidr_gesture, spidr_optflow
    from repro.core.network import init_params
    from repro.snn.data import make_flow_batch, make_gesture_batch

    spec = (spidr_gesture.reduced() if args.snn == "gesture"
            else spidr_optflow.reduced())
    # Telemetry opt-in must precede spidr.compile so the autotune sweep and
    # compile spans land in the same registry/trace as the serving loop.
    metrics_out = getattr(args, "metrics_out", None)
    metrics_every = getattr(args, "metrics_every", 0)
    trace_out = getattr(args, "trace_out", None)
    if metrics_out:
        obs.enable_metrics()
    if trace_out:
        obs.enable_tracing()
    params = init_params(jax.random.PRNGKey(0), spec)
    # One declarative target covers what used to be EngineConfig + the
    # compile_network/compile_engine hand-wiring: precision pair, backend
    # (interpret auto-selects off-TPU), core count, stream geometry.
    target = spidr.DeployTarget(
        weight_bits=args.weight_bits,
        backend="jnp" if args.jnp else "fused",
        n_cores=args.n_cores,
        chunk_T=args.chunk_T,
        stream_capacity=args.capacity,
    )
    compiled = spidr.compile(spec, params, target)

    if compiled.schedule is not None:
        log.info("compiled %s onto %d cores (%d channel-split layers, "
                 "device_parallel=%s)\n%s", spec.name, args.n_cores,
                 compiled.schedule.n_split_layers,
                 compiled.engine.device_parallel,
                 compiled.schedule.describe())

    make = make_gesture_batch if args.snn == "gesture" else make_flow_batch
    ev, _ = make(jax.random.PRNGKey(1), batch=args.requests,
                 timesteps=spec.timesteps, hw=spec.input_hw)

    # Per-stream pipeline timelines need per-chunk input counts, which only
    # exist on the multi-core (scheduled) deployment.
    want_timeline = bool(trace_out) and compiled.schedule is not None

    replicas = getattr(args, "replicas", 1)
    if args.streaming:
        fleet = spidr.serve(compiled, spidr.ServeConfig(
            n_replicas=replicas,
            capacity=args.capacity,
            chunk_T=args.chunk_T,
            max_queue=max(64, args.requests),
            watchdog_s=getattr(args, "watchdog_s", None),
            snapshot_dir=getattr(args, "snapshot_dir", None),
            snapshot_every=getattr(args, "snapshot_every", 0),
            collect_chunk_counts=want_timeline))
        for r in range(args.requests):
            fleet.submit(np.asarray(ev[:, r]), rid=r)
        t0 = time.monotonic()
        ticks = 0
        while fleet.step():
            ticks += 1
            if metrics_out and metrics_every and ticks % metrics_every == 0:
                obs.default_registry().write(metrics_out)
        dt = time.monotonic() - t0
        done = fleet.done
        lat = [r.done_at - r.submitted_at for r in done]
        ttfr = [r.first_reply_at - r.submitted_at for r in done]
        log.info(
            "streamed %d %s streams (%d timesteps, chunk_T=%d) over %d "
            "replica(s) in %.2fs (%.1f streams/s, %d fleet ticks); "
            "first-reply p50 %.3fs; latency p50 %.3fs; backend=%s",
            len(done), args.snn, spec.timesteps, args.chunk_T,
            fleet.n_replicas, dt, len(done) / dt, ticks,
            float(np.median(ttfr)), float(np.median(lat)),
            compiled.engine.cfg.backend,
        )
        cyc = [r.cycles for r in done]
        uj = [r.energy_uj for r in done]
        log.info(
            "chip estimate/stream (cumulative): %.0f cycles p50, %.1f uJ p50",
            float(np.median(cyc)), float(np.median(uj)),
        )
        _export_telemetry(compiled, metrics_out, trace_out,
                          [(r.rid, r.input_counts) for r in done]
                          if want_timeline else [])
        fleet.shutdown()
        return fleet

    fleet = spidr.serve(compiled, spidr.ServeConfig(
        n_replicas=replicas, capacity=args.capacity, batch=True,
        max_queue=max(64, args.requests)))
    for r in range(args.requests):
        fleet.submit(np.asarray(ev[:, r]), rid=r)

    t0 = time.monotonic()
    fleet.drain()
    dt = time.monotonic() - t0
    done = fleet.done
    lat = [r.done_at - r.submitted_at for r in done]
    total_counts = None
    batches = 0
    for w in fleet.workers:
        batches += w.batches
        if w.total_input_counts is not None:
            total_counts = (w.total_input_counts if total_counts is None
                            else total_counts + w.total_input_counts)
    mean_counts = total_counts / max(len(done), 1)
    cost = compiled.cost(input_counts=mean_counts)
    log.info(
        "served %d %s streams (%d timesteps each) over %d replica(s) in "
        "%.2fs (%.1f streams/s, %d batches); latency p50 %.3fs; backend=%s",
        len(done), args.snn, spec.timesteps, fleet.n_replicas, dt,
        len(done) / dt, batches, float(np.median(lat)),
        compiled.engine.cfg.backend,
    )
    if compiled.schedule is None:
        log.info(
            "chip estimate/stream: %.2f ms @%dMHz, %.1f uJ, sparsity "
            "%.1f%%, async speedup %.2fx",
            cost.latency_ms, 50, cost.energy_uj, 100 * cost.mean_sparsity,
            cost.async_speedup,
        )
    else:
        log.info(
            "multi-core attribution/stream: makespan %d cycles, per-core "
            "busy %s, routing %s, load imbalance %.2fx, energy %.1f uJ "
            "(%.2f uJ routing)",
            cost.makespan_cycles, cost.busy_cycles.tolist(),
            cost.routing_cycles.tolist(), cost.load_imbalance,
            cost.energy_uj, cost.routing_energy_uj,
        )
    _export_telemetry(compiled, metrics_out, trace_out,
                      [("batch-mean", mean_counts)] if want_timeline else [])
    fleet.shutdown()
    return fleet


def _export_telemetry(compiled, metrics_out, trace_out, stream_counts):
    """Final metrics dump + Chrome-trace export for the serving run.

    ``stream_counts``: (label, per-timestep input counts) pairs — each is
    re-priced through the multi-core pipeline model and merged into the
    trace as its own process row (pid 100+i), so Perfetto shows the host
    spans and every stream's per-core busy/routing/idle clocks side by
    side.
    """
    if metrics_out:
        obs.default_registry().write(metrics_out)
        log.info("metrics written to %s", metrics_out)
    if not trace_out:
        return
    extra = []
    for i, (label, counts) in enumerate(stream_counts):
        if counts is None:
            continue
        extra.extend(compiled.pipeline_trace(
            input_counts=counts, label=f"stream {label}", pid=100 + i))
    obs.default_tracer().export(trace_out, extra_events=extra)
    log.info("chrome trace written to %s (%d pipeline-timeline events)",
             trace_out, len(extra))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--capacity", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--snn", choices=["gesture", "optical-flow"], default=None,
                    help="serve DVS event streams through the SNN engine "
                         "instead of the LM decode path")
    ap.add_argument("--weight-bits", type=int, default=4, choices=[4, 6, 8])
    ap.add_argument("--jnp", action="store_true",
                    help="SNN path: pure-jnp backend instead of Pallas")
    ap.add_argument("--streaming", action="store_true",
                    help="SNN path: stateful streaming serving — events "
                         "arrive in chunks, Vmem persists per slot between "
                         "chunks, replies are incremental")
    ap.add_argument("--chunk-T", type=int, default=2, dest="chunk_T",
                    help="timesteps per delivered chunk in --streaming mode")
    ap.add_argument("--replicas", type=int, default=1,
                    help="SNN path: serve through a fleet of N engine "
                         "replicas (spidr.serve) — streams are scheduled "
                         "across them")
    ap.add_argument("--watchdog-s", type=float, default=None,
                    dest="watchdog_s",
                    help="--streaming: per-tick watchdog deadline; a hung "
                         "tick rewinds to the last completed tick and "
                         "replays")
    ap.add_argument("--snapshot-dir", default=None, dest="snapshot_dir",
                    help="--streaming: persist the full serving state here "
                         "(weights + live sessions + cursors) for "
                         "zero-downtime restore")
    ap.add_argument("--snapshot-every", type=int, default=0,
                    dest="snapshot_every",
                    help="--streaming: snapshot every N ticks (0 = never)")
    ap.add_argument("--n-cores", type=int, default=1, dest="n_cores",
                    help="SNN path: compile the network across a grid of N "
                         "SpiDR cores (repro.compiler) — bit-exact outputs, "
                         "per-core cost attribution; uses a shard_map cores "
                         "mesh when the host has N devices")
    ap.add_argument("--metrics-out", default=None, dest="metrics_out",
                    help="enable telemetry and write the final metrics dump "
                         "here (.json -> JSON, else Prometheus text)")
    ap.add_argument("--metrics-every", type=int, default=0,
                    dest="metrics_every",
                    help="--streaming: also rewrite --metrics-out every N "
                         "ticks (0 = only at the end)")
    ap.add_argument("--trace-out", default=None, dest="trace_out",
                    help="enable span tracing and export a Chrome-trace/"
                         "Perfetto JSON (compile + autotune + serving spans; "
                         "multi-core runs add per-stream pipeline timelines)")
    ap.add_argument("--log-json", action="store_true", dest="log_json",
                    help="emit one JSON object per log record instead of "
                         "text (each record carries the stream request id)")
    args = ap.parse_args()

    obs.logging_setup(json_mode=args.log_json)

    if args.snn:
        serve_snn(args)
        return

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    server = Server(cfg, params, capacity=args.capacity, ctx_len=64)

    rng = np.random.default_rng(0)
    for r in range(args.requests):
        server.submit(Request(
            rid=r,
            prompt=rng.integers(0, cfg.vocab_size, args.prompt_len).astype(np.int32),
            max_new=args.max_new,
        ))
    t0 = time.monotonic()
    steps = 0
    while server.step():
        steps += 1
    dt = time.monotonic() - t0
    lat = [r.done_at - r.submitted_at for r in server.done]
    ttft = [r.first_token_at - r.submitted_at for r in server.done]
    toks = sum(len(r.generated) for r in server.done)
    log.info(
        "served %d requests, %d tokens in %.2fs (%.1f tok/s); "
        "TTFT p50 %.3fs; latency p50 %.3fs; decode steps %d",
        len(server.done), toks, dt, toks / dt,
        float(np.median(ttft)), float(np.median(lat)), steps,
    )


if __name__ == "__main__":
    main()
