"""Batched serving driver: LM continuous batching + SNN event-stream serving.

Runs a real serving loop on host devices (reduced configs on CPU):
  python -m repro.launch.serve --arch qwen1.5-0.5b --reduced --requests 16
  python -m repro.launch.serve --snn gesture --requests 8
  python -m repro.launch.serve --snn optical-flow --requests 4 --jnp
  python -m repro.launch.serve --snn gesture --streaming --chunk-T 2
  python -m repro.launch.serve --snn gesture --n-cores 4 --jnp

The SNN path deploys through the unified facade (``repro.spidr``): one
``DeployTarget`` declares precision/cores/backend/chunking, and the
resulting ``CompiledSNN`` serves whole DVS event streams — requests are
batched up to a fixed capacity (shapes never change -> no recompilation),
each batch runs one fused scan-over-time inference, and the reply carries
the rate/Vmem readout plus the chip-cost estimate (cycles/energy) from the
calibrated models.

With ``--streaming`` the SNN path switches to *stateful* serving: each
request's events are delivered in chunks of ``--chunk-T`` timesteps, live
streams keep persistent per-slot Vmem between chunks
(``CompiledSNN.open_stream()``), newly arrived streams are admitted into
retired slots mid-flight (continuous batching over neuron state), and every
reply carries the incremental readout plus cumulative cycles/energy for
that stream alone.  Results are bit-identical to whole-stream serving.

Design (scaled-down vLLM-style):
  * a request queue feeds a PREFILL worker (one request at a time — CPU
    demo; on a pod this is a separate prefill mesh),
  * decoded requests join the DECODE batch, stepped together; finished
    sequences retire and free their cache slot for the next waiter
    (continuous batching with slot reuse),
  * the decode step is one jit'd function over a fixed-capacity batch —
    shapes never change, so no recompilation during serving.
"""
from __future__ import annotations

import argparse
import dataclasses
import logging
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.base import get_config
from repro.models import model as M
from repro.models.transformer import init_decode_state
from repro.obs.logs import request_context

# Structured logging (repro.obs.logs): ``main()`` calls
# ``obs.logging_setup(json_mode=args.log_json)`` — every record carries the
# current stream's request id (``rid=...`` in text mode, ``"request_id"``
# in --log-json mode) via a contextvar, replacing the old module-level
# ``logging.basicConfig``.
log = logging.getLogger("repro.serve")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    generated: list = dataclasses.field(default_factory=list)
    submitted_at: float = 0.0
    first_token_at: Optional[float] = None
    done_at: Optional[float] = None


class Server:
    """Fixed-capacity continuous-batching server."""

    def __init__(self, cfg, params, capacity: int = 8, ctx_len: int = 256):
        self.cfg, self.params = cfg, params
        self.capacity, self.ctx_len = capacity, ctx_len
        self.decode_step = jax.jit(M.make_decode_step(cfg), donate_argnums=(1,))
        self.prefill = jax.jit(M.make_prefill_step(cfg))
        # Batched cache: slot i belongs to active request i (or empty).
        self.cache = init_decode_state(cfg, capacity, ctx_len)
        self.slots: list = [None] * capacity
        self.slot_len = np.zeros(capacity, np.int32)
        self.next_tok = np.zeros((capacity, 1), np.int32)
        self.waiting: list = []
        self.done: list = []

    def submit(self, req: Request):
        req.submitted_at = time.monotonic()
        self.waiting.append(req)

    def _admit(self):
        for i in range(self.capacity):
            if self.slots[i] is None and self.waiting:
                req = self.waiting.pop(0)
                # Prefill one request; copy its KV into slot i.
                logits, cache1 = self.prefill(
                    self.params, {"tokens": jnp.asarray(req.prompt[None, :])}
                )
                tok = int(jnp.argmax(logits[0]))
                req.generated.append(tok)
                req.first_token_at = time.monotonic()
                self._copy_into_slot(i, cache1, len(req.prompt))
                self.slots[i] = req
                self.slot_len[i] = len(req.prompt)
                self.next_tok[i, 0] = tok

    def _copy_into_slot(self, i, cache1, plen):
        def put(dst, src):
            if dst is None or not hasattr(dst, "ndim"):
                return dst
            if dst.ndim >= 2 and src is not None:
                # layer-stacked: (L, B=cap, ...) <- (L, 1, ...)
                pad = [(0, 0)] * src.ndim
                if dst.ndim == src.ndim and dst.shape[1] == self.capacity:
                    sl = [slice(None)] * dst.ndim
                    sl[1] = slice(i, i + 1)
                    upd = src
                    if src.shape[3:4] and dst.shape[3] != src.shape[3] and dst.ndim > 3:
                        # seq capacity differs: right-pad/truncate
                        tgt = dst.shape[3]
                        if src.shape[3] < tgt:
                            pad[3] = (0, tgt - src.shape[3])
                            upd = jnp.pad(src, pad)
                        else:
                            upd = src[:, :, :, :tgt]
                    return dst.at[tuple(sl)].set(upd.astype(dst.dtype))
                return dst
            return dst

        # dense/moe KV caches: prefill returns k/v as (L, B, Hkv, S, hd)
        for key in self.cache:
            if key == "len":
                continue
            src = cache1.get(key) if isinstance(cache1, dict) else None
            if src is None:
                continue
            if key in ("k", "v"):
                # cache1 seq dim = prompt len; place at [.., :plen, :]
                dst = self.cache[key]
                upd = src.astype(dst.dtype)
                self.cache[key] = jax.lax.dynamic_update_slice(
                    dst, upd, (0, i, 0, 0, 0)[: dst.ndim]
                )
            else:
                self.cache[key] = put(self.cache[key], src)

    def step(self):
        self._admit()
        active = [i for i in range(self.capacity) if self.slots[i] is not None]
        if not active:
            return False
        # One batched decode step for every active slot (idle slots ride along).
        self.cache["len"] = jnp.asarray(int(self.slot_len.max()), jnp.int32)
        logits, self.cache = self.decode_step(
            self.params, self.cache, {"tokens": jnp.asarray(self.next_tok)}
        )
        toks = np.asarray(jnp.argmax(logits, axis=-1))
        for i in active:
            req = self.slots[i]
            tok = int(toks[i])
            req.generated.append(tok)
            self.slot_len[i] += 1
            self.next_tok[i, 0] = tok
            if len(req.generated) >= req.max_new or self.slot_len[i] >= self.ctx_len - 1:
                req.done_at = time.monotonic()
                self.done.append(req)
                self.slots[i] = None  # free slot: continuous batching
                self.slot_len[i] = 0
        return True


# ---------------------------------------------------------------------------
# SNN event-stream serving (fused multi-timestep engine).
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class SNNRequest:
    rid: int
    events: np.ndarray                     # (T, H, W, C) binary event frames
    readout: Optional[np.ndarray] = None   # filled on completion
    submitted_at: float = 0.0
    done_at: Optional[float] = None
    # Streaming-path extras: progress + cumulative chip cost for this stream.
    cursor: int = 0                        # timesteps delivered so far
    first_reply_at: Optional[float] = None
    cycles: int = 0
    energy_uj: float = 0.0
    # Concatenated per-chunk input-spike counts (T_so_far, n_layers) —
    # populated only when the server collects chunk counts for the
    # per-stream pipeline-timeline export (``--trace-out`` on multi-core).
    input_counts: Optional[np.ndarray] = None


class SNNServer:
    """Fixed-capacity batched SNN inference server.

    Waiting requests are packed into a fixed (T, capacity, H, W, C) batch —
    idle slots carry zero events, which the zero-skipping engine makes nearly
    free — and one fused ``CompiledSNN.run`` serves the whole batch.
    """

    def __init__(self, compiled, capacity: int = 4):
        self.compiled = compiled
        self.capacity = capacity
        self.waiting: list = []
        self.done: list = []
        self.total_input_counts = None
        self.batches = 0
        self._metrics = obs.default_registry()

    def submit(self, req: SNNRequest):
        req.submitted_at = time.monotonic()
        self.waiting.append(req)

    def step(self) -> bool:
        if not self.waiting:
            return False
        t0 = time.monotonic()
        batch = self.waiting[: self.capacity]
        self.waiting = self.waiting[self.capacity:]
        ev = np.zeros(
            (batch[0].events.shape[0], self.capacity) + batch[0].events.shape[1:],
            np.float32,
        )
        for i, req in enumerate(batch):
            ev[:, i] = req.events
        out = self.compiled.run(jnp.asarray(ev))
        readout = np.asarray(out.readout)
        now = time.monotonic()
        for i, req in enumerate(batch):
            req.readout = readout[i]
            req.done_at = now
            self.done.append(req)
        counts = np.asarray(out.input_counts)
        self.total_input_counts = (
            counts if self.total_input_counts is None
            else self.total_input_counts + counts
        )
        self.batches += 1
        if self._metrics:
            reg = self._metrics
            reg.counter("spidr_serve_batches_total",
                        "Whole-stream batches served").inc()
            reg.histogram("spidr_serve_batch_seconds",
                          "Whole-stream batch wall latency",
                          edges=obs.metrics.LATENCY_BUCKETS_S
                          ).observe(time.monotonic() - t0)
            reg.gauge("spidr_serve_queue_depth",
                      "Requests waiting for a slot").set(len(self.waiting))
        return True


class StreamingSNNServer:
    """Stateful continuous-batching server over persistent Vmem sessions.

    The SNN mirror of :class:`Server`'s decode loop: a fixed bank of
    ``capacity`` slots, each holding one live stream's neuron state inside a
    ``CompiledSNN.open_stream()`` session; every ``step()`` delivers each
    live stream's next ``chunk_T`` event frames and advances all slots in
    one fixed-shape jitted chunk step.  Finished streams retire and free
    their slot for the next waiter; idle slots ride along as all-zero spike
    tiles that the zero-skip path eliminates.

    Durability (``runtime.fault_tolerance`` + ``CompiledSNN.snapshot``):

      * ``watchdog_s`` arms a :class:`StepWatchdog` around every session
        step — a hung tick becomes a :class:`RestartableFailure`;
      * every tick runs through ``retrying``: a poisoned tick rewinds the
        session (and all request cursors) to the last completed tick and
        replays, up to ``max_restarts`` times;
      * ``snapshot_dir``/``snapshot_every`` persist the full serving state
        (weights, session slots, stream-id/cursor table, finished results)
        every N ticks; :meth:`restore` resumes it in a fresh process,
        bit-exactly — the upgrade drill (``tools/upgrade_drill.py``)
        SIGKILLs a serving process mid-chunk and proves zero streams lose
        state.
    """

    def __init__(self, compiled, capacity: int = 4, chunk_T: int = 2, *,
                 watchdog_s: Optional[float] = None, max_restarts: int = 3,
                 snapshot_dir: Optional[str] = None, snapshot_every: int = 0,
                 fail_at_tick: Optional[int] = None, _session=None,
                 collect_chunk_counts: bool = False):
        from repro.runtime.fault_tolerance import StepWatchdog, retrying

        self.compiled = compiled
        self.sessions = (_session if _session is not None
                         else compiled.open_stream(
                             capacity=capacity, chunk_T=chunk_T,
                             collect_chunk_counts=collect_chunk_counts))
        self.chunk_T = chunk_T
        self.waiting: list = []
        self.done: list = []
        self.slots: dict = {}          # slot -> SNNRequest
        self.ticks = 0
        self.snapshot_dir = snapshot_dir
        self.snapshot_every = snapshot_every
        # Telemetry: the process-wide registry/tracer (disabled unless
        # obs.enable_metrics()/enable_tracing() ran, e.g. via the
        # --metrics-out/--trace-out flags).
        self._metrics = obs.default_registry()
        self._tracer = obs.default_tracer()
        # Fault injection for tests/drills: raise RestartableFailure once,
        # mid-tick (after the session stepped, before bookkeeping) — the
        # worst case the rewind has to undo.  ``mid_tick_hook`` is the
        # generic form (the upgrade drill SIGKILLs the process from it).
        self.fail_at_tick = fail_at_tick
        self.mid_tick_hook = None
        self._watchdog = (StepWatchdog(
            watchdog_s,
            counter=self._metrics.counter(
                "spidr_serve_watchdog_timeouts_total",
                "Watchdog deadline firings") if self._metrics else None)
            if watchdog_s is not None else None)
        self._rewind_point = None
        self._step = retrying(self._tick, self._rewind,
                              max_restarts=max_restarts,
                              on_restart=self._count_rewind)
        self._mark()

    def _count_rewind(self) -> None:
        if self._metrics:
            self._metrics.counter(
                "spidr_serve_rewinds_total",
                "Rewind-and-replay recoveries").inc()

    @property
    def restarts(self) -> int:
        """Rewind-and-replay count since the server started."""
        return self._step.state["restarts"]

    def submit(self, req: SNNRequest):
        req.submitted_at = time.monotonic()
        self.waiting.append(req)

    def _admit(self):
        while self.waiting:
            slot = self.sessions.open()
            if slot is None:
                # Admission deferred: every waiter stays queued this tick.
                if self._metrics:
                    self._metrics.counter(
                        "spidr_serve_rejections_total",
                        "Ticks on which waiting streams found no free slot"
                    ).inc()
                return
            req = self.waiting.pop(0)
            self.slots[slot] = req
            if self._metrics:
                self._metrics.counter(
                    "spidr_serve_admissions_total",
                    "Streams admitted into a session slot").inc()
            with request_context(req.rid):
                log.debug("admitted stream %d into slot %d", req.rid, slot)

    # -- fault tolerance: rewind-and-replay --------------------------------
    def _mark(self):
        """Record the last-completed-tick state the next rewind returns to.

        The session part is a pure-numpy ``state_dict`` (never aliases live
        buffers); the request part saves each request's mutable progress
        fields so the *same* objects callers hold are rolled back.
        """
        reqs = list(self.slots.values()) + self.waiting + self.done
        self._rewind_point = {
            "session": self.sessions.state_dict(),
            "slots": dict(self.slots),
            "waiting": list(self.waiting),
            "done": list(self.done),
            "ticks": self.ticks,
            "reqs": [(r, r.cursor, r.readout, r.cycles, r.energy_uj,
                      r.first_reply_at, r.done_at, r.input_counts)
                     for r in reqs],
        }

    def _rewind(self, *args, **kwargs):
        cp = self._rewind_point
        self.sessions.load_state_dict(cp["session"])
        self.slots = dict(cp["slots"])
        self.waiting = list(cp["waiting"])
        self.done = list(cp["done"])
        self.ticks = cp["ticks"]
        for r, cur, ro, cyc, uj, fr, da, ic in cp["reqs"]:
            r.cursor, r.readout, r.cycles, r.energy_uj = cur, ro, cyc, uj
            r.first_reply_at, r.done_at, r.input_counts = fr, da, ic
        log.info("rewound to tick %d and replaying", self.ticks)

    def _tick(self) -> bool:
        self._admit()
        if not self.slots:
            return False
        chunks = {slot: req.events[req.cursor:req.cursor + self.chunk_T]
                  for slot, req in self.slots.items()}
        if self._watchdog is not None:
            self._watchdog.arm()
        try:
            updates = self.sessions.step(chunks)
        finally:
            if self._watchdog is not None:
                self._watchdog.disarm()
        if self._watchdog is not None:
            self._watchdog.check()
        if self.mid_tick_hook is not None:
            self.mid_tick_hook(self.ticks + 1)
        if self.fail_at_tick is not None and self.ticks + 1 >= self.fail_at_tick:
            from repro.runtime.fault_tolerance import RestartableFailure

            self.fail_at_tick = None
            raise RestartableFailure(
                f"injected fault at tick {self.ticks + 1}")
        now = time.monotonic()
        for slot, up in updates.items():
            req = self.slots[slot]
            req.cursor += chunks[slot].shape[0]
            # Incremental reply: cumulative readout + chip cost so far.
            req.readout = up.readout
            req.cycles, req.energy_uj = up.cycles, up.energy_uj
            if up.input_counts is not None:
                req.input_counts = (
                    up.input_counts if req.input_counts is None
                    else np.concatenate([req.input_counts, up.input_counts]))
            if req.first_reply_at is None:
                req.first_reply_at = now
            if req.cursor >= req.events.shape[0]:
                req.done_at = now
                self.done.append(req)
                self.sessions.close(slot)   # free the slot: continuous batching
                del self.slots[slot]
                with request_context(req.rid):
                    log.info(
                        "stream %d done: %d timesteps, %d cycles, %.2f uJ",
                        req.rid, req.cursor, req.cycles, req.energy_uj)
        self.ticks += 1
        return True

    def step(self) -> bool:
        # Mark *now*, not after: requests submitted since the last tick are
        # part of the state a mid-tick failure must rewind to.
        self._mark()
        t0 = time.monotonic()
        if self._tracer:
            with self._tracer.span("serve.tick", cat="serve",
                                   tick=self.ticks):
                alive = self._step()
        else:
            alive = self._step()
        if self._metrics and alive:
            reg = self._metrics
            reg.histogram("spidr_serve_tick_seconds",
                          "Streaming tick wall latency",
                          edges=obs.metrics.LATENCY_BUCKETS_S
                          ).observe(time.monotonic() - t0)
            reg.gauge("spidr_serve_queue_depth",
                      "Requests waiting for a slot").set(len(self.waiting))
        if alive and self.snapshot_dir and self.snapshot_every \
                and self.ticks % self.snapshot_every == 0:
            self.save_snapshot()
        return alive

    # -- durability: process-level snapshot/restore ------------------------
    @staticmethod
    def _result_json(req: SNNRequest) -> dict:
        return {"rid": int(req.rid), "cursor": int(req.cursor),
                "readout": (None if req.readout is None
                            else np.asarray(req.readout).tolist()),
                "cycles": int(req.cycles),
                "energy_uj": float(req.energy_uj)}

    def save_snapshot(self) -> None:
        """Persist the complete serving state (atomic, checksummed).

        One ``CompiledSNN.snapshot`` step at ``step=self.ticks``: weights +
        the live session, plus the server's own bookkeeping (stream-id <->
        slot map, per-stream cursors, finished results) as JSON ``extra``.
        Replay after :meth:`restore` is implicit — chunks are re-derived
        from the restored cursors.
        """
        assert self.snapshot_dir, "construct the server with snapshot_dir="
        t0 = time.monotonic()
        extra = {"server": {
            "ticks": int(self.ticks),
            "slots": {str(slot): int(req.rid)
                      for slot, req in self.slots.items()},
            "cursors": {str(req.rid): int(req.cursor)
                        for req in list(self.slots.values()) + self.waiting},
            "waiting": [int(req.rid) for req in self.waiting],
            "done": [self._result_json(req) for req in self.done],
        }}
        self.compiled.snapshot(self.snapshot_dir, step=self.ticks,
                               sessions=[self.sessions], extra=extra)
        if self._metrics:
            self._metrics.histogram(
                "spidr_serve_snapshot_seconds",
                "save_snapshot wall duration (server bookkeeping + "
                "checkpoint write)",
                edges=obs.metrics.LATENCY_BUCKETS_S
            ).observe(time.monotonic() - t0)

    @classmethod
    def restore(cls, path, requests_by_rid: dict, compiled=None, *,
                watchdog_s: Optional[float] = None, max_restarts: int = 3,
                snapshot_every: int = 0, step: Optional[int] = None
                ) -> "StreamingSNNServer":
        """Resume a server from its latest :meth:`save_snapshot`.

        ``requests_by_rid`` maps stream id -> :class:`SNNRequest` carrying
        the stream's (deterministically regenerated) events; in-flight
        requests resume at their snapshotted cursor, finished results are
        reloaded from the snapshot.  The restored server then serves every
        stream bit-identically to one that was never killed.
        """
        from repro import spidr

        info = spidr.read_snapshot_meta(path, step)
        compiled = spidr.restore(path, compiled=compiled, step=info["step"])
        session = compiled.sessions[-1]
        srv = cls(compiled, capacity=session.capacity,
                  chunk_T=session.chunk_T, watchdog_s=watchdog_s,
                  max_restarts=max_restarts, snapshot_dir=str(path),
                  snapshot_every=snapshot_every, _session=session)
        state = info["extra"]["server"]
        srv.ticks = int(state["ticks"])
        cursors = {int(k): int(v) for k, v in state["cursors"].items()}
        for slot, rid in state["slots"].items():
            req = requests_by_rid[int(rid)]
            req.cursor = cursors[int(rid)]
            srv.slots[int(slot)] = req
        srv.waiting = [requests_by_rid[int(rid)]
                       for rid in state["waiting"]]
        for req in srv.waiting:
            req.cursor = cursors[int(req.rid)]
        for d in state["done"]:
            req = requests_by_rid.get(int(d["rid"])) or SNNRequest(
                rid=int(d["rid"]), events=np.zeros((0,), np.float32))
            req.cursor = int(d["cursor"])
            req.readout = (None if d["readout"] is None
                           else np.asarray(d["readout"], np.int32))
            req.cycles = int(d["cycles"])
            req.energy_uj = float(d["energy_uj"])
            srv.done.append(req)
        srv._mark()
        return srv


def serve_snn(args):
    from repro import spidr
    from repro.configs import spidr_gesture, spidr_optflow
    from repro.core.network import init_params
    from repro.snn.data import make_flow_batch, make_gesture_batch

    spec = (spidr_gesture.reduced() if args.snn == "gesture"
            else spidr_optflow.reduced())
    # Telemetry opt-in must precede spidr.compile so the autotune sweep and
    # compile spans land in the same registry/trace as the serving loop.
    metrics_out = getattr(args, "metrics_out", None)
    metrics_every = getattr(args, "metrics_every", 0)
    trace_out = getattr(args, "trace_out", None)
    if metrics_out:
        obs.enable_metrics()
    if trace_out:
        obs.enable_tracing()
    params = init_params(jax.random.PRNGKey(0), spec)
    # One declarative target covers what used to be EngineConfig + the
    # compile_network/compile_engine hand-wiring: precision pair, backend
    # (interpret auto-selects off-TPU), core count, stream geometry.
    target = spidr.DeployTarget(
        weight_bits=args.weight_bits,
        backend="jnp" if args.jnp else "fused",
        n_cores=args.n_cores,
        chunk_T=args.chunk_T,
        stream_capacity=args.capacity,
    )
    compiled = spidr.compile(spec, params, target)

    if compiled.schedule is not None:
        log.info("compiled %s onto %d cores (%d channel-split layers, "
                 "device_parallel=%s)\n%s", spec.name, args.n_cores,
                 compiled.schedule.n_split_layers,
                 compiled.engine.device_parallel,
                 compiled.schedule.describe())

    make = make_gesture_batch if args.snn == "gesture" else make_flow_batch
    ev, _ = make(jax.random.PRNGKey(1), batch=args.requests,
                 timesteps=spec.timesteps, hw=spec.input_hw)

    # Per-stream pipeline timelines need per-chunk input counts, which only
    # exist on the multi-core (scheduled) deployment.
    want_timeline = bool(trace_out) and compiled.schedule is not None

    if args.streaming:
        server = StreamingSNNServer(
            compiled, capacity=args.capacity, chunk_T=args.chunk_T,
            watchdog_s=getattr(args, "watchdog_s", None),
            snapshot_dir=getattr(args, "snapshot_dir", None),
            snapshot_every=getattr(args, "snapshot_every", 0),
            collect_chunk_counts=want_timeline)
        for r in range(args.requests):
            server.submit(SNNRequest(rid=r, events=np.asarray(ev[:, r])))
        t0 = time.monotonic()
        ticks = 0
        while server.step():
            ticks += 1
            if metrics_out and metrics_every and ticks % metrics_every == 0:
                obs.default_registry().write(metrics_out)
        dt = time.monotonic() - t0
        lat = [r.done_at - r.submitted_at for r in server.done]
        ttfr = [r.first_reply_at - r.submitted_at for r in server.done]
        log.info(
            "streamed %d %s streams (%d timesteps, chunk_T=%d) in %.2fs "
            "(%.1f streams/s, %d ticks); first-reply p50 %.3fs; "
            "latency p50 %.3fs; backend=%s",
            len(server.done), args.snn, spec.timesteps, args.chunk_T, dt,
            len(server.done) / dt, ticks, float(np.median(ttfr)),
            float(np.median(lat)), compiled.engine.cfg.backend,
        )
        cyc = [r.cycles for r in server.done]
        uj = [r.energy_uj for r in server.done]
        log.info(
            "chip estimate/stream (cumulative): %.0f cycles p50, %.1f uJ p50",
            float(np.median(cyc)), float(np.median(uj)),
        )
        _export_telemetry(compiled, metrics_out, trace_out,
                          [(r.rid, r.input_counts) for r in server.done]
                          if want_timeline else [])
        return server

    server = SNNServer(compiled, capacity=args.capacity)
    for r in range(args.requests):
        server.submit(SNNRequest(rid=r, events=np.asarray(ev[:, r])))

    t0 = time.monotonic()
    while server.step():
        pass
    dt = time.monotonic() - t0
    lat = [r.done_at - r.submitted_at for r in server.done]
    mean_counts = server.total_input_counts / max(len(server.done), 1)
    cost = compiled.cost(input_counts=mean_counts)
    log.info(
        "served %d %s streams (%d timesteps each) in %.2fs "
        "(%.1f streams/s, %d batches); latency p50 %.3fs; backend=%s",
        len(server.done), args.snn, spec.timesteps, dt,
        len(server.done) / dt, server.batches, float(np.median(lat)),
        compiled.engine.cfg.backend,
    )
    if compiled.schedule is None:
        log.info(
            "chip estimate/stream: %.2f ms @%dMHz, %.1f uJ, sparsity "
            "%.1f%%, async speedup %.2fx",
            cost.latency_ms, 50, cost.energy_uj, 100 * cost.mean_sparsity,
            cost.async_speedup,
        )
    else:
        log.info(
            "multi-core attribution/stream: makespan %d cycles, per-core "
            "busy %s, routing %s, load imbalance %.2fx, energy %.1f uJ "
            "(%.2f uJ routing)",
            cost.makespan_cycles, cost.busy_cycles.tolist(),
            cost.routing_cycles.tolist(), cost.load_imbalance,
            cost.energy_uj, cost.routing_energy_uj,
        )
    _export_telemetry(compiled, metrics_out, trace_out,
                      [("batch-mean", mean_counts)] if want_timeline else [])
    return server


def _export_telemetry(compiled, metrics_out, trace_out, stream_counts):
    """Final metrics dump + Chrome-trace export for the serving run.

    ``stream_counts``: (label, per-timestep input counts) pairs — each is
    re-priced through the multi-core pipeline model and merged into the
    trace as its own process row (pid 100+i), so Perfetto shows the host
    spans and every stream's per-core busy/routing/idle clocks side by
    side.
    """
    if metrics_out:
        obs.default_registry().write(metrics_out)
        log.info("metrics written to %s", metrics_out)
    if not trace_out:
        return
    extra = []
    for i, (label, counts) in enumerate(stream_counts):
        if counts is None:
            continue
        extra.extend(compiled.pipeline_trace(
            input_counts=counts, label=f"stream {label}", pid=100 + i))
    obs.default_tracer().export(trace_out, extra_events=extra)
    log.info("chrome trace written to %s (%d pipeline-timeline events)",
             trace_out, len(extra))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--capacity", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--snn", choices=["gesture", "optical-flow"], default=None,
                    help="serve DVS event streams through the SNN engine "
                         "instead of the LM decode path")
    ap.add_argument("--weight-bits", type=int, default=4, choices=[4, 6, 8])
    ap.add_argument("--jnp", action="store_true",
                    help="SNN path: pure-jnp backend instead of Pallas")
    ap.add_argument("--streaming", action="store_true",
                    help="SNN path: stateful streaming serving — events "
                         "arrive in chunks, Vmem persists per slot between "
                         "chunks, replies are incremental")
    ap.add_argument("--chunk-T", type=int, default=2, dest="chunk_T",
                    help="timesteps per delivered chunk in --streaming mode")
    ap.add_argument("--watchdog-s", type=float, default=None,
                    dest="watchdog_s",
                    help="--streaming: per-tick watchdog deadline; a hung "
                         "tick rewinds to the last completed tick and "
                         "replays")
    ap.add_argument("--snapshot-dir", default=None, dest="snapshot_dir",
                    help="--streaming: persist the full serving state here "
                         "(weights + live sessions + cursors) for "
                         "zero-downtime restore")
    ap.add_argument("--snapshot-every", type=int, default=0,
                    dest="snapshot_every",
                    help="--streaming: snapshot every N ticks (0 = never)")
    ap.add_argument("--n-cores", type=int, default=1, dest="n_cores",
                    help="SNN path: compile the network across a grid of N "
                         "SpiDR cores (repro.compiler) — bit-exact outputs, "
                         "per-core cost attribution; uses a shard_map cores "
                         "mesh when the host has N devices")
    ap.add_argument("--metrics-out", default=None, dest="metrics_out",
                    help="enable telemetry and write the final metrics dump "
                         "here (.json -> JSON, else Prometheus text)")
    ap.add_argument("--metrics-every", type=int, default=0,
                    dest="metrics_every",
                    help="--streaming: also rewrite --metrics-out every N "
                         "ticks (0 = only at the end)")
    ap.add_argument("--trace-out", default=None, dest="trace_out",
                    help="enable span tracing and export a Chrome-trace/"
                         "Perfetto JSON (compile + autotune + serving spans; "
                         "multi-core runs add per-stream pipeline timelines)")
    ap.add_argument("--log-json", action="store_true", dest="log_json",
                    help="emit one JSON object per log record instead of "
                         "text (each record carries the stream request id)")
    args = ap.parse_args()

    obs.logging_setup(json_mode=args.log_json)

    if args.snn:
        serve_snn(args)
        return

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    server = Server(cfg, params, capacity=args.capacity, ctx_len=64)

    rng = np.random.default_rng(0)
    for r in range(args.requests):
        server.submit(Request(
            rid=r,
            prompt=rng.integers(0, cfg.vocab_size, args.prompt_len).astype(np.int32),
            max_new=args.max_new,
        ))
    t0 = time.monotonic()
    steps = 0
    while server.step():
        steps += 1
    dt = time.monotonic() - t0
    lat = [r.done_at - r.submitted_at for r in server.done]
    ttft = [r.first_token_at - r.submitted_at for r in server.done]
    toks = sum(len(r.generated) for r in server.done)
    log.info(
        "served %d requests, %d tokens in %.2fs (%.1f tok/s); "
        "TTFT p50 %.3fs; latency p50 %.3fs; decode steps %d",
        len(server.done), toks, dt, toks / dt,
        float(np.median(ttft)), float(np.median(lat)), steps,
    )


if __name__ == "__main__":
    main()
