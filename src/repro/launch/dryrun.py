"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be the very first two lines (before any jax-importing module) so the
512 placeholder host devices exist before jax locks the device count:
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse          # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro import flags as perf_flags                                        # noqa: E402
from repro.configs.base import SHAPES, get_config, input_specs, list_archs  # noqa: E402
from repro.launch.mesh import make_production_mesh                          # noqa: E402
from repro.models import model as M                                         # noqa: E402
from repro import sharding as S                                             # noqa: E402
from repro.roofline.analysis import analyze_compiled                        # noqa: E402

RESULT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")


def _cell_id(arch: str, shape: str, multi_pod: bool) -> str:
    return f"{arch}__{shape}__{'pod2' if multi_pod else 'pod1'}"


def build_lowerable(arch: str, shape_name: str, multi_pod: bool, variant: str = "base"):
    """Returns (fn, args_abstract, in_shardings, out_shardings, meta)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if not cfg.supports(shape):
        raise ValueError(f"skip: {cfg.skip_reason(shape)}")

    mesh = make_production_mesh(multi_pod=multi_pod)
    params_abs = M.abstract_params(cfg)
    if perf_flags.flag("serve_bf16_weights") and shape.kind != "train":
        # Serving from a bf16 checkpoint: no fp32 masters at inference.
        params_abs = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, jnp.bfloat16)
            if a.dtype == jnp.float32 and len(a.shape) >= 2 else a,
            params_abs,
        )
    p_specs = S.validate_tree(S.param_specs(params_abs), params_abs, mesh)
    batch_abs = input_specs(cfg, shape)
    b_specs = S.validate_tree(S.batch_specs(batch_abs, multi_pod), batch_abs, mesh)

    if shape.kind == "train":
        opt_abs = M.abstract_opt_state(params_abs)
        o_specs = {"mu": p_specs, "nu": p_specs}
        step_abs = jax.ShapeDtypeStruct((), jnp.int32)
        fn = M.make_train_step(cfg)
        args = (params_abs, opt_abs, step_abs, batch_abs)
        in_sh = (p_specs, o_specs, None, b_specs)
        out_sh = (p_specs, o_specs, None)
        meta = {"kind": "train"}
    elif shape.kind == "prefill":
        fn = M.make_prefill_step(cfg)
        args = (params_abs, batch_abs)
        in_sh = (p_specs, b_specs)
        # The prefill cache structure differs from the decode cache (no
        # 'len' counter) — derive specs from the actual output structure.
        logits_abs, cache_abs = jax.eval_shape(fn, params_abs, batch_abs)
        c_specs = S.validate_tree(
            S.decode_cache_specs(cache_abs, multi_pod, shape.global_batch),
            cache_abs, mesh,
        )
        l_spec = S.validate_spec(
            S.logits_spec(multi_pod, shape.global_batch), logits_abs.shape, mesh
        )
        out_sh = (l_spec, c_specs)
        meta = {"kind": "prefill"}
    else:  # decode
        cache_abs = M.abstract_decode_cache(cfg, shape.global_batch, shape.seq_len)
        c_specs = S.validate_tree(
            S.decode_cache_specs(cache_abs, multi_pod, shape.global_batch),
            cache_abs, mesh,
        )
        fn = M.make_decode_step(cfg)
        args = (params_abs, cache_abs, batch_abs)
        in_sh = (p_specs, c_specs, b_specs)
        l_spec = S.validate_spec(
            S.logits_spec(multi_pod, shape.global_batch),
            (shape.global_batch, cfg.padded_vocab), mesh,
        )
        out_sh = (l_spec, c_specs)
        meta = {"kind": "decode"}
    meta.update(
        {
            "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
            "params": cfg.param_count(), "active_params": cfg.active_param_count(),
            "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        }
    )
    return fn, args, in_sh, out_sh, meta


def resolve_auto(shape, multi_pod: bool) -> str:
    """Per-cell optimal policy from the EXPERIMENTS.md §Perf iterations:

      train/prefill, batch divisible by ALL mesh axes -> dp_only_bf16
        (no TP: collective drops 14-24x; confirmed on qwen1.5/chameleon)
      train/prefill otherwise -> bf16 wire only (TP retained; dp_only with
        batch < mesh size replicates activations — refuted on rwkv6 pod1
        and chameleon pod2)
      decode -> serve_opt (TP-only bf16 weights: no per-token param
        all-gathers; confirmed 70x on chameleon decode)
    """
    n_devices = 512 if multi_pod else 256
    if shape.kind == "decode":
        return "serve_opt"
    if shape.global_batch % n_devices == 0:
        return "dp_only_bf16"
    if shape.kind == "train":
        # TP retained; sequence parallelism is the memory lever that makes
        # batch-nondivisible train cells FIT 16 GiB (41->13.6 GiB measured
        # on qwen3 pod2) at ~18% step-time cost — fitting is binding.
        return "bf16_seqpar"
    return "bf16"


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             with_roofline: bool = True, force: bool = False,
             variant: str = "base") -> dict:
    requested = variant
    if variant == "auto":
        variant = resolve_auto(SHAPES[shape_name], multi_pod)
    perf_flags.set_variant(variant)
    cell = _cell_id(arch, shape_name, multi_pod)
    if requested != "base":
        cell = f"{cell}__{requested}"
    os.makedirs(out_dir, exist_ok=True)
    out_path = os.path.join(out_dir, cell + ".json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    record = {"cell": cell, "arch": arch, "shape": shape_name,
              "multi_pod": multi_pod, "status": "unknown"}
    if not cfg.supports(shape):
        record.update(status="skipped", reason=cfg.skip_reason(shape))
        _write(out_path, record)
        return record

    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        # Arm activation sharding constraints (batch dim unshardable when 1).
        S.set_activation_mesh(mesh, multi_pod=multi_pod,
                              batch_sharded=shape.global_batch > 1)
        fn, args, in_sh, out_sh, meta = build_lowerable(arch, shape_name, multi_pod)
        with mesh:
            in_shardings = jax.tree.map(
                lambda s: jax.sharding.NamedSharding(mesh, s) if s is not None else None,
                in_sh, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec) or x is None,
            )
            out_shardings = jax.tree.map(
                lambda s: jax.sharding.NamedSharding(mesh, s) if s is not None else None,
                out_sh, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec) or x is None,
            )
            donate = (1,) if meta["kind"] == "decode" else ()
            jitted = jax.jit(fn, in_shardings=in_shardings,
                             out_shardings=out_shardings, donate_argnums=donate)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            if isinstance(cost, list):
                cost = cost[0]
            record.update(
                status="ok",
                resolved_variant=variant,
                meta=meta,
                lower_s=round(t_lower, 1),
                compile_s=round(t_compile, 1),
                memory_analysis={
                    k: int(getattr(mem, k))
                    for k in (
                        "argument_size_in_bytes", "output_size_in_bytes",
                        "temp_size_in_bytes", "generated_code_size_in_bytes",
                    )
                    if hasattr(mem, k)
                },
                cost_analysis={
                    "flops": float(cost.get("flops", -1)),
                    "bytes_accessed": float(cost.get("bytes accessed", -1)),
                },
            )
            if with_roofline:
                record["roofline"] = analyze_compiled(
                    compiled, cfg, shape, mesh_devices=mesh.size,
                    model_axis=mesh.shape.get("model", 1),
                    bf16_wire=perf_flags.flag("bf16_params"),
                )
    except Exception as e:  # record failures — they are bugs to fix
        record.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-4000:])
    finally:
        S.set_activation_mesh(None)
    record["wall_s"] = round(time.time() - t0, 1)
    _write(out_path, record)
    return record


def _write(path, record):
    with open(path, "w") as f:
        json.dump(record, f, indent=1, default=str)


def main():
    ap = argparse.ArgumentParser(description="SpiDR-framework multi-pod dry-run")
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", choices=["pod1", "pod2", "both"], default="both")
    ap.add_argument("--out", default=RESULT_DIR)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-roofline", action="store_true")
    ap.add_argument("--variant", default="base",
                    choices=list(perf_flags.VARIANTS))
    args = ap.parse_args()

    archs = list_archs() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"pod1": [False], "pod2": [True], "both": [False, True]}[args.mesh]

    n_ok = n_skip = n_err = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, mp, args.out,
                               with_roofline=not args.no_roofline,
                               force=args.force, variant=args.variant)
                tag = rec["status"]
                n_ok += tag == "ok"
                n_skip += tag == "skipped"
                n_err += tag == "error"
                extra = ""
                if tag == "ok":
                    per_dev = rec["memory_analysis"].get("argument_size_in_bytes", 0)
                    extra = (
                        f" args={per_dev/2**30:.2f}GiB"
                        f" temp={rec['memory_analysis'].get('temp_size_in_bytes',0)/2**30:.2f}GiB"
                        f" compile={rec.get('compile_s', 0):.0f}s"
                    )
                elif tag == "error":
                    extra = " " + rec.get("error", "")[:120]
                print(f"[{tag:7s}] {rec['cell']}{extra}", flush=True)
    print(f"done: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
