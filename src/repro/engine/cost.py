"""Cycle / energy accounting for an engine run (threads C7 + C9 models).

Converts the per-timestep per-layer spike statistics an ``EngineOutput``
records into chip-level cost using the calibrated models:

  * ``core.pipeline.simulate_pipeline`` — the async-handshake discrete-event
    model gives the makespan in cycles (and the speedup vs a rigid
    synchronous pipeline, the paper's Fig 13 motivation).
  * ``core.energy`` — the Table I / Fig 14 calibrated chunk-energy model
    gives energy per inference at the run's measured sparsity.

The mapping from spikes to compute-macro cycles follows Sec II-E/II-F:
each input spike of a weight layer triggers 2 row operations (even+odd
Vmem rows) per weight-stationary channel tile; rows are balanced across
the 9 compute macros, so per-macro cycles are the layer total divided by
the macros in the layer's pipeline configuration.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.energy import HW, chunk_energy_total_nj, gops, power_mw
from ..core.modes import CoreConfig, map_layer
from ..core.network import SNNSpec
from ..core.pipeline import PipelineConfig, PipelineState, simulate_pipeline
from ..core.quant import QuantSpec

__all__ = ["EngineCost", "estimate_cost"]


@dataclasses.dataclass
class EngineCost:
    makespan_cycles: int        # async-handshake makespan for the whole stream
    sync_makespan_cycles: int   # rigid synchronous worst-case alternative
    async_speedup: float
    latency_ms: float           # makespan at the operating frequency
    energy_uj: float            # calibrated chunk-energy model
    avg_power_mw: float
    mean_sparsity: float        # measured input sparsity across layers/steps
    gops_equivalent: float      # dense-equivalent throughput at that sparsity
    pipeline_state: PipelineState | None = None  # resume point for streaming


def estimate_cost(
    spec: SNNSpec,
    qspec: QuantSpec,
    input_counts: np.ndarray,   # (T, n_weight_layers) input spikes per layer
    hw: HW = HW(),
    n_cm: int = 9,
    pipeline_state: PipelineState | None = None,
) -> EngineCost:
    """Chip cost of one engine run from its recorded spike statistics.

    For a stream priced chunk by chunk, pass the previous chunk's
    ``cost.pipeline_state`` as ``pipeline_state``: the async-handshake
    clocks resume, so ``makespan_cycles`` is the *cumulative* makespan
    since the stream began and is bit-identical to pricing the whole
    stream in one call, for any chunking.  (Energy is additive across
    chunks either way.)
    """
    counts = np.asarray(input_counts, dtype=np.float64)
    T, n_layers = counts.shape
    shapes = spec.layer_shapes()
    assert len(shapes) == n_layers, (len(shapes), n_layers)
    core = CoreConfig(qspec)
    mappings = [map_layer(s, core) for s in shapes]

    # Row ops per layer-timestep: 2 per spike per sequential channel tile,
    # balanced over the macros active in that layer's pipeline config.
    compute_cycles = np.zeros((T, n_cm), dtype=np.int64)
    for li, m in enumerate(mappings):
        active = m.pipelines * m.macros_per_pipeline
        per_macro = 2.0 * counts[:, li] * m.channel_tiles / active
        compute_cycles[:, :active] += np.ceil(per_macro)[:, None].astype(np.int64)

    res = simulate_pipeline(compute_cycles, PipelineConfig(n_cm=n_cm),
                            state=pipeline_state)

    # Sparsity across all layer inputs (position-weighted).
    positions = np.array(
        [s.fan_in * s.out_positions for s in shapes], dtype=np.float64
    )
    density = counts.sum() / (positions.sum() * T)
    sparsity = float(np.clip(1.0 - density, 0.0, 1.0))

    passes = sum(m.total_passes for m in mappings)
    energy_uj = passes * T * chunk_energy_total_nj(sparsity, hw) / 1e3

    return EngineCost(
        makespan_cycles=res.makespan,
        sync_makespan_cycles=res.sync_makespan,
        async_speedup=res.speedup_vs_sync,
        latency_ms=res.makespan / hw.freq_hz * 1e3,
        energy_uj=float(energy_uj),
        avg_power_mw=power_mw(hw),
        mean_sparsity=sparsity,
        gops_equivalent=gops(sparsity, qspec.weight_bits, hw.freq_hz),
        pipeline_state=res.state,
    )
