"""Cycle / energy accounting for an engine run (threads C7 + C9 models).

Converts the per-timestep per-layer spike statistics an ``EngineOutput``
records into chip-level cost using the calibrated models:

  * ``core.pipeline.simulate_pipeline`` — the async-handshake discrete-event
    model gives the makespan in cycles (and the speedup vs a rigid
    synchronous pipeline, the paper's Fig 13 motivation).
  * ``core.energy`` — the Table I / Fig 14 calibrated chunk-energy model
    gives energy per inference at the run's measured sparsity.

The mapping from spikes to compute-macro cycles follows Sec II-E/II-F:
each input spike of a weight layer triggers 2 row operations (even+odd
Vmem rows) per weight-stationary channel tile; rows are balanced across
the 9 compute macros, so per-macro cycles are the layer total divided by
the macros in the layer's pipeline configuration.

``estimate_multicore_cost`` extends the same row-op model to a compiled
``repro.compiler`` CoreSchedule: one async-handshake simulation per core
over the layers placed on it, AER spike-routing charged on the receiving
core (``core.pipeline.ROUTE_CYCLES_PER_SPIKE``), routed traffic priced at
the calibrated data-movement energy, and a load-imbalance metric
(max/mean per-core busy cycles).  Per-core cycle sums equal the
single-core total plus exactly the modeled overheads (routing +
split-layer duplication + rounding) — tested in ``tests/test_compiler.py``.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from ..compiler.schedule import CoreSchedule
from ..core.energy import (
    HW, chunk_energy_breakdown_nj, chunk_energy_total_nj, cycles_per_chunk,
    gops, power_mw,
)
from ..core.modes import CoreConfig, map_layer
from ..core.network import SNNSpec
from ..core.pipeline import (
    PipelineConfig, PipelineState, route_cycles, simulate_pipeline,
)
from ..core.quant import QuantSpec

__all__ = ["EngineCost", "MulticoreCost", "estimate_cost",
           "estimate_multicore_cost"]


@dataclasses.dataclass
class EngineCost:
    makespan_cycles: int        # async-handshake makespan for the whole stream
    sync_makespan_cycles: int   # rigid synchronous worst-case alternative
    async_speedup: float
    latency_ms: float           # makespan at the operating frequency
    energy_uj: float            # calibrated chunk-energy model
    avg_power_mw: float
    mean_sparsity: float        # measured input sparsity across layers/steps
    gops_equivalent: float      # dense-equivalent throughput at that sparsity
    pipeline_state: PipelineState | None = None  # resume point for streaming


def estimate_cost(
    spec: SNNSpec,
    qspec: QuantSpec,
    input_counts: np.ndarray,   # (T, n_weight_layers) input spikes per layer
    hw: HW = HW(),
    n_cm: int = 9,
    pipeline_state: PipelineState | None = None,
) -> EngineCost:
    """Chip cost of one engine run from its recorded spike statistics.

    For a stream priced chunk by chunk, pass the previous chunk's
    ``cost.pipeline_state`` as ``pipeline_state``: the async-handshake
    clocks resume, so ``makespan_cycles`` is the *cumulative* makespan
    since the stream began and is bit-identical to pricing the whole
    stream in one call, for any chunking.  (Energy is additive across
    chunks either way.)
    """
    counts = np.asarray(input_counts, dtype=np.float64)
    T, n_layers = counts.shape
    shapes = spec.layer_shapes()
    assert len(shapes) == n_layers, (len(shapes), n_layers)
    core = CoreConfig(qspec)
    mappings = [map_layer(s, core) for s in shapes]

    # Row ops per layer-timestep: 2 per spike per sequential channel tile,
    # balanced over the macros active in that layer's pipeline config.
    compute_cycles = np.zeros((T, n_cm), dtype=np.int64)
    for li, m in enumerate(mappings):
        active = m.pipelines * m.macros_per_pipeline
        per_macro = 2.0 * counts[:, li] * m.channel_tiles / active
        compute_cycles[:, :active] += np.ceil(per_macro)[:, None].astype(np.int64)

    res = simulate_pipeline(compute_cycles, PipelineConfig(n_cm=n_cm),
                            state=pipeline_state)

    # Sparsity across all layer inputs (position-weighted).
    positions = np.array(
        [s.fan_in * s.out_positions for s in shapes], dtype=np.float64
    )
    density = counts.sum() / (positions.sum() * T)
    sparsity = float(np.clip(1.0 - density, 0.0, 1.0))

    passes = sum(m.total_passes for m in mappings)
    energy_uj = passes * T * chunk_energy_total_nj(sparsity, hw) / 1e3

    return EngineCost(
        makespan_cycles=res.makespan,
        sync_makespan_cycles=res.sync_makespan,
        async_speedup=res.speedup_vs_sync,
        latency_ms=res.makespan / hw.freq_hz * 1e3,
        energy_uj=float(energy_uj),
        avg_power_mw=power_mw(hw),
        mean_sparsity=sparsity,
        gops_equivalent=gops(sparsity, qspec.weight_bits, hw.freq_hz),
        pipeline_state=res.state,
    )


# ---------------------------------------------------------------------------
# Multi-core attribution: price a compiled CoreSchedule per core.
# ---------------------------------------------------------------------------

# Energy to push one spike one hop across the inter-core AER fabric, derived
# from the calibrated model's data-movement share: movement energy per cycle
# at the reference point, times the fabric's cycles per routed spike.
_MOVE_NJ_PER_CYCLE = (
    chunk_energy_breakdown_nj(0.95)["data_movement"] / cycles_per_chunk(0.95)
)


@dataclasses.dataclass
class MulticoreCost:
    """Per-core cost of one engine run under a compiled multi-core plan.

    ``compute_cycles`` / ``routing_cycles`` are the raw per-core sums of the
    spike-driven row-op model and the AER receive model; ``per_core`` holds
    the full async-handshake :class:`EngineCost` of each core's pipeline.
    The attribution invariant (tested):

        sum(compute_cycles) == single_core_compute_cycles + duplication

    i.e. splitting work across cores conserves total row-op cycles exactly,
    except for the *modeled* overheads — channel-split layers re-scan the
    routed input spikes on every core holding a slice (``duplication``),
    and every routed spike pays the fabric cost (``routing_cycles``).
    """

    per_core: list                       # of EngineCost, len n_cores
    makespan_cycles: int                 # max over cores (plan latency)
    compute_cycles: np.ndarray           # (C,) summed row-op cycles
    routing_cycles: np.ndarray           # (C,) AER receive cycles
    single_core_compute_cycles: int      # same row-op model, one core
    duplication_cycles: int              # split-layer re-scan overhead
    load_imbalance: float                # max/mean per-core busy (>= 1.0)
    energy_uj: float                     # compute + routing energy
    routing_energy_uj: float
    mean_sparsity: float
    pipeline_states: list                # per-core resume points (streaming)
    # Optional per-(layer, core) busy-cycle breakdown for the Chrome-trace
    # exporter (repro.obs.timeline): list of {layer, name, core, cycles}
    # records where ``cycles[t]`` is exactly what that layer contributed to
    # this core's ``compute`` matrix at timestep t.  None unless the run
    # was priced with ``collect_timeline=True``.
    timeline: list | None = None

    @property
    def busy_cycles(self) -> np.ndarray:
        return self.compute_cycles + self.routing_cycles


def _slice_channel_tiles(width: int, parallel_channels: int) -> int:
    return max(1, math.ceil(width / parallel_channels))


def estimate_multicore_cost(
    spec: SNNSpec,
    schedule: CoreSchedule,
    input_counts: np.ndarray,   # (T, n_weight_layers) input spikes per layer
    hw: HW = HW(),
    n_cm: int = 9,
    pipeline_states: list | None = None,
    collect_timeline: bool = False,
) -> MulticoreCost:
    """Price one multi-core engine run, attributing cycles/energy per core.

    The spike statistics are the *same* ones the single-core model consumes
    (``EngineOutput.input_counts`` — the engine's outputs are bit-exact
    either way); what changes is where the row ops land.  Each core runs
    its own async-handshake pipeline simulation over the layers placed on
    it; routed spikes are charged at the fabric rate on the receiving core
    and priced at the calibrated data-movement energy.

    For streams priced chunk by chunk, thread ``pipeline_states`` (the
    previous chunk's ``cost.pipeline_states``) exactly like the single-core
    ``estimate_cost`` — per-core makespans stay chunking-invariant.

    ``collect_timeline=True`` additionally records the per-(layer, core)
    busy cycles of every timestep — exactly the values accumulated into
    the ``compute`` matrix, so the Chrome-trace exporter in
    ``repro.obs.timeline`` conserves ``busy_cycles`` cycle for cycle.
    """
    counts = np.asarray(input_counts, dtype=np.float64)
    T, n_layers = counts.shape
    assert len(schedule.layers) == n_layers, (len(schedule.layers), n_layers)
    C = schedule.n_cores
    rcps = schedule.grid.route_cycles_per_spike

    compute = np.zeros((C, T, n_cm), dtype=np.int64)
    routing = np.zeros(C, dtype=np.int64)
    routed_spikes = 0.0
    single_total = 0
    passes_per_core = np.zeros(C, dtype=np.float64)
    # (layer index, core) -> per-timestep busy cycles, filled only when the
    # caller asked for the Chrome-trace breakdown.
    lane_cycles: dict = {}

    for li, ls in enumerate(schedule.layers):
        m = ls.plan.mapping
        active = m.pipelines * m.macros_per_pipeline
        full_ct = _slice_channel_tiles(ls.out_channels, m.parallel_channels)
        single_total += int(np.ceil(2.0 * counts[:, li] * full_ct).sum())
        for s in ls.slices:
            ct = _slice_channel_tiles(s.width, m.parallel_channels)
            per_macro = 2.0 * counts[:, li] * ct / active
            per_macro_cycles = np.ceil(per_macro).astype(np.int64)
            compute[s.core, :, :active] += per_macro_cycles[:, None]
            passes_per_core[s.core] += (
                ct * m.position_tiles * m.fan_in_tiles)
            if collect_timeline:
                # Total contribution to this core's compute matrix per
                # timestep: the per-macro ceil lands on ``active`` macros.
                key = (li, int(s.core))
                lane = lane_cycles.setdefault(
                    key, np.zeros(T, dtype=np.int64))
                lane += per_macro_cycles * active
        # Routing truth lives on the schedule (LayerSchedule.route_fractions,
        # computed once at compile time): charge each consumer core for the
        # share of the input plane it receives over the fabric.
        for c, frac in enumerate(ls.route_fractions):
            if frac > 0.0:
                recv = counts[:, li].sum() * frac
                routing[c] += route_cycles(recv, rcps)
                routed_spikes += recv

    states = pipeline_states or [None] * C
    per_core, new_states = [], []
    compute_sums = np.zeros(C, dtype=np.int64)
    for c in range(C):
        res = simulate_pipeline(compute[c], PipelineConfig(n_cm=n_cm),
                                state=states[c])
        compute_sums[c] = int(compute[c].sum())
        new_states.append(res.state)
        per_core.append(EngineCost(
            makespan_cycles=res.makespan,
            sync_makespan_cycles=res.sync_makespan,
            async_speedup=res.speedup_vs_sync,
            latency_ms=res.makespan / hw.freq_hz * 1e3,
            energy_uj=0.0,           # filled below (per-core passes share)
            avg_power_mw=power_mw(hw),
            mean_sparsity=0.0,
            gops_equivalent=0.0,
            pipeline_state=res.state,
        ))

    # Sparsity across all layer inputs, identical to the single-core model.
    shapes = spec.layer_shapes()
    positions = np.array(
        [s.fan_in * s.out_positions for s in shapes], dtype=np.float64)
    density = counts.sum() / (positions.sum() * T)
    sparsity = float(np.clip(1.0 - density, 0.0, 1.0))

    e_chunk = chunk_energy_total_nj(sparsity, hw)
    routing_energy_uj = routed_spikes * rcps * _MOVE_NJ_PER_CYCLE / 1e3
    energy_uj = float(passes_per_core.sum() * T * e_chunk / 1e3
                      + routing_energy_uj)
    for c in range(C):
        per_core[c].energy_uj = float(passes_per_core[c] * T * e_chunk / 1e3)
        per_core[c].mean_sparsity = sparsity

    busy = compute_sums + routing
    # An all-idle chunk (no spikes anywhere) is perfectly balanced: keep
    # the >= 1.0 invariant rather than reporting a meaningless 0.
    imbalance = float(busy.max() / busy.mean()) if busy.sum() else 1.0
    makespans = np.array([pc.makespan_cycles for pc in per_core])
    timeline = None
    if collect_timeline:
        timeline = [
            {
                "layer": li,
                "name": f"L{schedule.layers[li].node}:"
                        f"{schedule.layers[li].kind}",
                "core": core,
                "cycles": [int(v) for v in lane],
            }
            for (li, core), lane in sorted(lane_cycles.items())
        ]
    return MulticoreCost(
        per_core=per_core,
        makespan_cycles=int((makespans + routing).max()),
        compute_cycles=compute_sums,
        routing_cycles=routing,
        single_core_compute_cycles=int(single_total),
        duplication_cycles=int(compute_sums.sum() - single_total),
        load_imbalance=imbalance,
        energy_uj=energy_uj,
        routing_energy_uj=float(routing_energy_uj),
        mean_sparsity=sparsity,
        pipeline_states=new_states,
        timeline=timeline,
    )
